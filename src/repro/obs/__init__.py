"""Unified observability: tracing, metrics, and range provenance.

Three zero-dependency (stdlib-only) pillars threaded through every layer
of the repo — the FINN-R lesson that a dataflow-DSE framework lives or
dies on the quality of its per-stage reports:

* :mod:`repro.obs.trace` — nested spans + counters with Chrome
  ``trace_event`` JSON export (Perfetto / ``chrome://tracing``).  A
  process-global default tracer is a no-op until enabled, so the
  instrumentation in ``core/flow.py``, ``core/propagate.py``,
  ``core/lower.py``, ``serve/engine.py`` and ``dataflow/folding.py``
  costs one flag check when disabled.
* :mod:`repro.obs.metrics` — typed Counter / Gauge / Histogram registry
  with label support and Prometheus text-format + JSON export; the
  serving metrics and every ``BENCH_*.json`` flow through it.
* :mod:`repro.obs.explain` — per-tensor range provenance: which op
  handler and abstract domain produced the final bounds and which input
  interval was the widening culprit (``SiraModel.explain(tensor)``).
"""
from .trace import (Tracer, SpanRecord, NULL_SPAN,          # noqa: F401
                    get_tracer, set_tracer,
                    enable_tracing, disable_tracing,
                    validate_chrome_trace)
from .metrics import (Counter, Gauge, Histogram,            # noqa: F401
                      MetricsRegistry, get_registry, set_registry,
                      export_bench)
from .explain import (RangeProvenance, ProvenanceChain,     # noqa: F401
                      build_chain)
