"""Tracing: nested spans, counters, Chrome ``trace_event`` JSON export.

A :class:`Tracer` records *spans* (named, nested, attributed wall-clock
intervals) and *counters* (monotonic named tallies).  The process-global
default tracer (:func:`get_tracer`) is **disabled** until
:func:`enable_tracing` is called: a disabled tracer's ``span()`` returns
a shared no-op singleton and ``count()`` returns after one flag check,
so instrumented hot paths (the compiled backend dispatch, the serving
decode loop) pay nothing measurable — ``bench_backend.py`` gates the
enabled-tracer overhead on the compiled TFC path under 5%.

Export is the Chrome ``trace_event`` JSON format (the ``traceEvents``
array of ``"ph": "X"`` complete events and ``"ph": "C"`` counter
events), loadable in Perfetto or ``chrome://tracing``:

    from repro.obs.trace import enable_tracing, get_tracer
    tracer = enable_tracing()
    result = build_flow(make_cnv())        # spans recorded
    tracer.write_chrome_trace("out.json")

Everything here is stdlib-only by design — the observability layer must
never constrain what it observes.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Union

Number = Union[int, float]

#: ``ph`` values this module emits (and :func:`validate_chrome_trace`
#: accepts): complete spans, counter samples, metadata.
_PHASES = ("X", "C", "M")


@dataclasses.dataclass
class SpanRecord:
    """One finished span, in completion order (children before parents)."""
    name: str
    ts_us: float               # start, microseconds since tracer epoch
    dur_us: float
    depth: int                 # nesting depth at entry (0 = top level)
    tid: int
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)


class _NullSpan:
    """Shared no-op span — what a disabled tracer hands out."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: object) -> None:
        return None

    def set_attr(self, key: str, value: Any) -> None:
        return None


NULL_SPAN = _NullSpan()


class _Span:
    """Context manager recording one span on exit.  An exception
    propagating through the span closes it with an ``error`` attr — a
    failed build flow still produces a usable trace."""

    __slots__ = ("_tracer", "name", "attrs", "_t0", "_depth", "dur_s")

    def __init__(self, tracer: "Tracer", name: str,
                 attrs: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self._t0 = 0.0
        self._depth = 0
        self.dur_s: Optional[float] = None   # set on exit

    def set_attr(self, key: str, value: Any) -> None:
        self.attrs[key] = value

    def __enter__(self) -> "_Span":
        stack = self._tracer._stack()
        self._depth = len(stack)
        stack.append(self)
        self._t0 = self._tracer.clock()
        self._tracer._touch_epoch(self._t0)
        return self

    def __exit__(self, exc_type: Any, exc: Any, tb: Any) -> None:
        t1 = self._tracer.clock()
        self.dur_s = t1 - self._t0
        if exc_type is not None:
            self.attrs["error"] = f"{exc_type.__name__}: {exc}"
        stack = self._tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        self._tracer._record(self, t1)
        return None


class Tracer:
    """Span + counter recorder with Chrome ``trace_event`` export.

    ``clock`` is injectable (seconds, monotonic) so tests can drive
    deterministic time; the epoch is the first clock sample so exported
    timestamps start near zero.
    """

    def __init__(self, enabled: bool = True,
                 clock: Callable[[], float] = time.perf_counter):
        self.enabled = enabled
        self.clock = clock
        self.spans: List[SpanRecord] = []      # completion order
        self.counters: Dict[str, float] = {}   # cumulative totals
        self._counter_events: List[Dict[str, Any]] = []
        self._epoch: Optional[float] = None
        self._local = threading.local()
        self._lock = threading.Lock()

    # ------------------------------------------------------------ recording
    def _stack(self) -> List[_Span]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = []
            self._local.stack = stack
        return stack

    def _touch_epoch(self, t: float) -> None:
        """Anchor exported timestamps at the earliest sample seen."""
        with self._lock:
            if self._epoch is None or t < self._epoch:
                self._epoch = t

    def _us(self, t: float) -> float:
        if self._epoch is None:
            self._epoch = t
        return (t - self._epoch) * 1e6

    def span(self, name: str, **attrs: Any) -> Union[_Span, _NullSpan]:
        """Start a nested span; use as a context manager.  Disabled
        tracers return the shared :data:`NULL_SPAN` singleton."""
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, name, attrs)

    def count(self, name: str, n: Number = 1, **attrs: Any) -> None:
        """Bump a named counter (one Chrome ``"ph": "C"`` sample per
        call; ``attrs`` land in the sample's ``args``)."""
        if not self.enabled:
            return
        t = self.clock()
        with self._lock:
            total = self.counters.get(name, 0.0) + n
            self.counters[name] = total
            ev: Dict[str, Any] = dict(
                name=name, ph="C", ts=self._us(t), pid=os.getpid(),
                tid=threading.get_ident() & 0xFFFF,
                args={name: total, **attrs})
            self._counter_events.append(ev)

    def set_attr(self, key: str, value: Any) -> None:
        """Attach an attribute to the innermost open span (no-op when
        disabled or outside any span)."""
        stack = self._stack()
        if stack:
            stack[-1].set_attr(key, value)

    def _record(self, span: _Span, t1: float) -> None:
        with self._lock:
            self.spans.append(SpanRecord(
                name=span.name, ts_us=self._us(span._t0),
                dur_us=(t1 - span._t0) * 1e6, depth=span._depth,
                tid=threading.get_ident() & 0xFFFF, attrs=span.attrs))

    # -------------------------------------------------------------- export
    def to_chrome_json(self) -> Dict[str, Any]:
        """The Chrome ``trace_event`` payload (Perfetto-loadable)."""
        events: List[Dict[str, Any]] = [dict(
            name="process_name", ph="M", ts=0.0, pid=os.getpid(), tid=0,
            args={"name": "sira"})]
        with self._lock:
            for s in self.spans:
                ev: Dict[str, Any] = dict(
                    name=s.name, ph="X", ts=s.ts_us, dur=s.dur_us,
                    pid=os.getpid(), tid=s.tid)
                if s.attrs:
                    ev["args"] = _jsonable(s.attrs)
                events.append(ev)
            events.extend(self._counter_events)
        events.sort(key=lambda e: (e["ts"], -e.get("dur", 0.0)))
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path: str) -> None:
        payload = self.to_chrome_json()
        validate_chrome_trace(payload)
        with open(path, "w") as fh:
            json.dump(payload, fh, indent=1)

    def reset(self) -> None:
        with self._lock:
            self.spans.clear()
            self.counters.clear()
            self._counter_events.clear()
            self._epoch = None


def _jsonable(attrs: Dict[str, Any]) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    for k, v in attrs.items():
        if isinstance(v, (str, int, float, bool)) or v is None:
            out[k] = v
        else:
            out[k] = str(v)
    return out


# --------------------------------------------------------------------------
# process-global default tracer
# --------------------------------------------------------------------------

_default = Tracer(enabled=False)


def get_tracer() -> Tracer:
    """The process-global tracer all in-repo instrumentation reports to.
    Disabled (no-op) until :func:`enable_tracing`."""
    return _default


def set_tracer(tracer: Tracer) -> Tracer:
    global _default
    _default = tracer
    return tracer


def enable_tracing(clock: Callable[[], float] = time.perf_counter
                   ) -> Tracer:
    """Install (and return) a fresh enabled global tracer."""
    return set_tracer(Tracer(enabled=True, clock=clock))


def disable_tracing() -> None:
    """Restore the no-op global tracer (records are dropped)."""
    set_tracer(Tracer(enabled=False))


# --------------------------------------------------------------------------
# Chrome trace_event schema validation (CI smoke / tests)
# --------------------------------------------------------------------------

def validate_chrome_trace(payload: Any) -> None:
    """Validate the subset of the Chrome ``trace_event`` schema this
    module emits; raises ``ValueError`` with the offending event on
    violation.  Used by the tier-1 tracing smoke test and by
    ``write_chrome_trace`` itself, so an exported trace is guaranteed
    loadable."""
    if not isinstance(payload, dict) or "traceEvents" not in payload:
        raise ValueError("trace payload must be an object with a "
                         "'traceEvents' array")
    events = payload["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be an array")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event #{i} is not an object: {ev!r}")
        for field, types in (("name", str), ("ph", str),
                             ("ts", (int, float)), ("pid", int),
                             ("tid", int)):
            if not isinstance(ev.get(field), types):
                raise ValueError(
                    f"event #{i} field {field!r} missing or mistyped: "
                    f"{ev!r}")
        ph = ev["ph"]
        if ph not in _PHASES:
            raise ValueError(f"event #{i} has unknown phase {ph!r}")
        if ev["ts"] < 0:
            raise ValueError(f"event #{i} has negative ts: {ev!r}")
        if ph == "X":
            if not isinstance(ev.get("dur"), (int, float)) \
                    or ev["dur"] < 0:
                raise ValueError(
                    f"complete event #{i} needs a non-negative 'dur': "
                    f"{ev!r}")
        if ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args:
                raise ValueError(
                    f"counter event #{i} needs a non-empty 'args': {ev!r}")
        if "args" in ev and not isinstance(ev["args"], dict):
            raise ValueError(f"event #{i} 'args' must be an object")


__all__ = ["Tracer", "SpanRecord", "NULL_SPAN", "get_tracer",
           "set_tracer", "enable_tracing", "disable_tracing",
           "validate_chrome_trace"]
