"""Range provenance: why does a tensor have the bounds it has?

During :func:`repro.core.propagate.analyze` every tensor's final range
is attributed to the op handler and abstract domain that produced it,
together with the *widening culprit* — the dynamic input whose interval
was widest and therefore dominated the output width.  The per-tensor
records form a chain back to a graph input:

    chain = model.explain("b0c0_mm")
    print(chain.render())

turning "the CNV accumulator is 58 bits, why?" from print-debugging
archaeology into one call (``examples/sira_report.py --explain``).

Stdlib-only; records are plain dataclasses built by the propagation
loop, not recomputed here.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class RangeProvenance:
    """How one tensor's final range came to be."""
    tensor: str
    node_name: str               # producing node ("" for graph seeds)
    op_type: str                 # "MatMul", ... ("input"/"const" seeds)
    handler: str                 # registry handler name that ran
    domain: str                  # "interval" | "affine"
    affine_tightened: bool       # affine hull strictly narrowed interval
    inputs: Tuple[str, ...]      # dynamic (non-constant) input tensors
    culprit: Optional[str]       # widest dynamic input, None for seeds
    width: float                 # max elementwise width of this range
    in_widths: Dict[str, float]  # width per dynamic input
    bits: Optional[int]          # required_signed_bits if scaled-int
    range_str: str               # human-readable "[lo, hi]" summary

    def describe(self) -> str:
        dom = self.domain + ("+affine-tightened" if self.affine_tightened
                             else "")
        bits = f", {self.bits} bits" if self.bits is not None else ""
        line = (f"{self.tensor}: {self.range_str} (width {self.width:g}"
                f"{bits}) <- {self.op_type}"
                f"[{self.handler}] @ {self.node_name or '<seed>'} "
                f"({dom})")
        if self.culprit is not None:
            line += f"; widened by {self.culprit}"
        return line


@dataclasses.dataclass(frozen=True)
class ProvenanceChain:
    """Culprit-linked walk from a tensor back to a graph seed."""
    tensor: str
    entries: Tuple[RangeProvenance, ...]

    def render(self) -> str:
        lines = [f"provenance of {self.tensor!r} "
                 f"({len(self.entries)} links):"]
        for i, e in enumerate(self.entries):
            lines.append("  " * i + ("`- " if i else "") + e.describe())
        return "\n".join(lines)

    def __iter__(self):
        return iter(self.entries)

    def __len__(self) -> int:
        return len(self.entries)


def build_chain(tensor: str,
                provenance: Mapping[str, RangeProvenance],
                max_depth: int = 32) -> ProvenanceChain:
    """Follow widening-culprit links from ``tensor`` back to a seed.

    Stops at graph inputs/initializers (no culprit), on cycles, on
    tensors with no record, or after ``max_depth`` links.
    """
    if tensor not in provenance:
        known = ", ".join(sorted(provenance)[:8])
        raise KeyError(
            f"no provenance recorded for {tensor!r}; known tensors "
            f"include: {known} ... (run analysis first)")
    entries: List[RangeProvenance] = []
    seen = set()
    cur: Optional[str] = tensor
    while cur is not None and cur not in seen and \
            len(entries) < max_depth:
        seen.add(cur)
        rec = provenance.get(cur)
        if rec is None:
            break
        entries.append(rec)
        cur = rec.culprit
    return ProvenanceChain(tensor=tensor, entries=tuple(entries))


__all__ = ["RangeProvenance", "ProvenanceChain", "build_chain"]
