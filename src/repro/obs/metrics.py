"""Typed metrics: Counter / Gauge / Histogram registry with labels and
Prometheus text-format + JSON export.

One consistent metrics pipeline for every layer (the Ducasse et al. FINN
benchmarking lesson — reproducible cross-workload measurement needs a
single substrate): ``serve.metrics.ServingMetrics`` is a facade over a
registry from this module, and the ``benchmarks/bench_*.py`` artifacts
are routed through :func:`export_bench`, so the same numbers that land
in ``BENCH_*.json`` are scrapeable as Prometheus text.

    reg = MetricsRegistry()
    hits = reg.counter("cache_hits_total", "range-cache hits",
                       labels=("domain",))
    hits.labels(domain="interval").inc()
    print(reg.to_prometheus())

Stdlib-only by design.
"""
from __future__ import annotations

import json
import math
import re
from typing import Any, Dict, Iterable, List, Mapping, Optional, Sequence
from typing import Tuple, Union

Number = Union[int, float]

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

#: default histogram buckets (seconds-ish; override per histogram)
DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0)


def _fmt(v: float) -> str:
    """Prometheus sample formatting: integral floats render as ints."""
    if v != v:
        return "NaN"
    if v in (math.inf, -math.inf):
        return "+Inf" if v > 0 else "-Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


class _Child:
    """One (metric, label-values) time series."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, n: Number = 1) -> None:
        if n < 0:
            raise ValueError("counters only go up; use a Gauge")
        self.value += n

    def set(self, v: Number) -> None:
        self.value = float(v)

    def dec(self, n: Number = 1) -> None:
        self.value -= n


class _HistChild:
    __slots__ = ("buckets", "counts", "sum", "count")

    def __init__(self, buckets: Tuple[float, ...]):
        self.buckets = buckets
        self.counts = [0] * (len(buckets) + 1)   # +1 for +Inf
        self.sum = 0.0
        self.count = 0

    def observe(self, v: Number) -> None:
        self.sum += v
        self.count += 1
        for i, b in enumerate(self.buckets):
            if v <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1


class Metric:
    """A named metric family; label() it to get a settable child."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 labels: Sequence[str] = ()):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        for lbl in labels:
            if not _LABEL_RE.match(lbl):
                raise ValueError(f"invalid label name {lbl!r}")
        self.name = name
        self.help = help
        self.label_names: Tuple[str, ...] = tuple(labels)
        self._children: Dict[Tuple[str, ...], Any] = {}

    def _new_child(self) -> Any:
        return _Child()

    def labels(self, **kv: Any) -> Any:
        if set(kv) != set(self.label_names):
            raise ValueError(
                f"metric {self.name!r} takes labels {self.label_names}, "
                f"got {tuple(sorted(kv))}")
        key = tuple(str(kv[name]) for name in self.label_names)
        child = self._children.get(key)
        if child is None:
            child = self._new_child()
            self._children[key] = child
        return child

    def _default_child(self) -> Any:
        if self.label_names:
            raise ValueError(
                f"metric {self.name!r} is labeled {self.label_names}; "
                f"call .labels(...) first")
        return self.labels()

    @property
    def children(self) -> Dict[Tuple[str, ...], Any]:
        return self._children

    def _series(self, key: Tuple[str, ...]) -> str:
        if not key:
            return self.name
        pairs = ",".join(f'{n}="{_escape(v)}"'
                         for n, v in zip(self.label_names, key))
        return f"{self.name}{{{pairs}}}"


class Counter(Metric):
    kind = "counter"

    def inc(self, n: Number = 1) -> None:
        self._default_child().inc(n)

    @property
    def value(self) -> float:
        child = self._children.get(())
        return child.value if child is not None else 0.0


class Gauge(Metric):
    kind = "gauge"

    def set(self, v: Number) -> None:
        self._default_child().set(v)

    def inc(self, n: Number = 1) -> None:
        self._default_child().value += n

    def dec(self, n: Number = 1) -> None:
        self._default_child().value -= n

    @property
    def value(self) -> float:
        child = self._children.get(())
        return child.value if child is not None else 0.0


class Histogram(Metric):
    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labels: Sequence[str] = (),
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        super().__init__(name, help, labels)
        bs = tuple(sorted(float(b) for b in buckets))
        if not bs:
            raise ValueError("histogram needs at least one bucket")
        self.buckets = bs

    def _new_child(self) -> Any:
        return _HistChild(self.buckets)

    def observe(self, v: Number) -> None:
        self._default_child().observe(v)

    @property
    def sum(self) -> float:
        child = self._children.get(())
        return child.sum if child is not None else 0.0

    @property
    def count(self) -> int:
        child = self._children.get(())
        return child.count if child is not None else 0


class MetricsRegistry:
    """Create-or-get metric families; export Prometheus text / JSON."""

    def __init__(self) -> None:
        self._metrics: Dict[str, Metric] = {}

    def _register(self, cls: type, name: str, help: str,
                  labels: Sequence[str], **kw: Any) -> Any:
        existing = self._metrics.get(name)
        if existing is not None:
            if not isinstance(existing, cls) or \
                    existing.label_names != tuple(labels):
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{existing.kind} with labels "
                    f"{existing.label_names}")
            return existing
        m = cls(name, help, labels, **kw)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> Counter:
        return self._register(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> Gauge:
        return self._register(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._register(Histogram, name, help, labels,
                              buckets=buckets)

    def collect(self) -> Iterable[Metric]:
        return [self._metrics[k] for k in sorted(self._metrics)]

    # -------------------------------------------------------------- export
    def to_prometheus(self) -> str:
        """Prometheus text exposition format (0.0.4)."""
        lines: List[str] = []
        for m in self.collect():
            if m.help:
                lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            for key in sorted(m.children):
                child = m.children[key]
                if isinstance(child, _HistChild):
                    cum = 0
                    base = m._series(key)
                    for b, c in zip(m.buckets, child.counts):  # type: ignore[attr-defined]
                        cum += c
                        if base.endswith("}"):
                            series = (base[:-1] +
                                      f',le="{_fmt(b)}"}}')
                        else:
                            series = base + f'{{le="{_fmt(b)}"}}'
                        lines.append(f"{m.name}_bucket"
                                     f"{series[len(m.name):]} {cum}")
                    inf = (base[:-1] + ',le="+Inf"}') if \
                        base.endswith("}") else base + '{le="+Inf"}'
                    lines.append(f"{m.name}_bucket"
                                 f"{inf[len(m.name):]} {child.count}")
                    lines.append(f"{m.name}_sum{base[len(m.name):]} "
                                 f"{_fmt(child.sum)}")
                    lines.append(f"{m.name}_count{base[len(m.name):]} "
                                 f"{child.count}")
                else:
                    lines.append(f"{m._series(key)} {_fmt(child.value)}")
        return "\n".join(lines) + "\n"

    def to_json(self) -> Dict[str, Any]:
        """JSON export: ``{name: {type, help, samples: [...]}}``."""
        out: Dict[str, Any] = {}
        for m in self.collect():
            samples: List[Dict[str, Any]] = []
            for key in sorted(m.children):
                child = m.children[key]
                labels = dict(zip(m.label_names, key))
                if isinstance(child, _HistChild):
                    samples.append(dict(labels=labels, sum=child.sum,
                                        count=child.count,
                                        buckets=dict(zip(
                                            map(_fmt, child.buckets),
                                            child.counts[:-1])),
                                        inf=child.counts[-1]))
                else:
                    samples.append(dict(labels=labels, value=child.value))
            out[m.name] = dict(type=m.kind, help=m.help, samples=samples)
        return out


# --------------------------------------------------------------------------
# process-global default registry
# --------------------------------------------------------------------------

_default = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return _default


def set_registry(reg: MetricsRegistry) -> MetricsRegistry:
    global _default
    _default = reg
    return reg


# --------------------------------------------------------------------------
# benchmark artifact export
# --------------------------------------------------------------------------

def _metric_name(prefix: str, key: str) -> str:
    name = re.sub(r"[^a-zA-Z0-9_]", "_", f"{prefix}_{key}")
    return name if _NAME_RE.match(name) else f"m_{name}"


def export_bench(payload: Mapping[str, Any], json_path: str,
                 prom_path: Optional[str] = None,
                 key: Sequence[str] = ("workload",),
                 registry: Optional[MetricsRegistry] = None
                 ) -> MetricsRegistry:
    """Route a ``BENCH_*.json`` payload through a metrics registry.

    Every numeric metric of every result row becomes a labeled gauge
    (labels = the row's ``key`` fields), then the registry is exported
    as Prometheus text next to the JSON artifact — the same numbers the
    CI gate (``scripts/check_bench.py``) diffs are scrapeable.  The JSON
    schema is unchanged (baselines stay valid); a self-check asserts the
    JSON and registry views agree before anything is written.
    """
    reg = registry if registry is not None else MetricsRegistry()
    prefix = _metric_name("bench", str(json_path).rsplit("/", 1)[-1]
                          .removeprefix("BENCH_").removesuffix(".json"))
    rows = payload.get("results", [])
    label_names = tuple(re.sub(r"[^a-zA-Z0-9_]", "_", k) for k in key)
    for row in rows:
        labels = {ln: str(row.get(k)) for ln, k in zip(label_names, key)}
        for k, v in row.items():
            if k in key or isinstance(v, bool) or \
                    not isinstance(v, (int, float)):
                continue
            g = reg.gauge(_metric_name(prefix, k),
                          f"{k} from {json_path}", labels=label_names)
            g.labels(**labels).set(float(v))
    # self-check: the registry must reproduce the JSON numbers exactly
    for row in rows:
        labels = {ln: str(row.get(k)) for ln, k in zip(label_names, key)}
        for k, v in row.items():
            if k in key or isinstance(v, bool) or \
                    not isinstance(v, (int, float)):
                continue
            child = reg.gauge(_metric_name(prefix, k),
                              labels=label_names).labels(**labels)
            if child.value != float(v):
                raise AssertionError(
                    f"registry/JSON divergence on {k} of {labels}")
    with open(json_path, "w") as fh:
        json.dump(payload, fh, indent=2)
    if prom_path is None:
        prom_path = str(json_path).removesuffix(".json") + ".prom"
    with open(prom_path, "w") as fh:
        fh.write(reg.to_prometheus())
    return reg


__all__ = ["Counter", "Gauge", "Histogram", "Metric", "MetricsRegistry",
           "DEFAULT_BUCKETS", "get_registry", "set_registry",
           "export_bench"]
