from .adamw import AdamW, AdamWState  # noqa: F401
