"""AdamW with cosine schedule, global-norm clipping and bf16-param /
f32-master-weight mixed precision (pure-JAX pytrees; no optax).

The optimizer state holds f32 master weights plus first/second moments;
model params may live in bf16 (TPU matmul dtype) and are re-materialized
from the masters each step — the standard large-model recipe.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    master: Any          # f32 master weights
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    # Optional post-step projection onto a constraint set (pytree ->
    # pytree, jit-traceable).  Applied to the f32 *master* weights — the
    # params handed back each step are re-materialized from the masters,
    # so projecting params alone would be undone on the next update.
    # Used by repro.qat for A2Q accumulator-budget projection.
    project: Optional[Callable[[Any], Any]] = None

    def schedule(self, step: jnp.ndarray) -> jnp.ndarray:
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / max(self.warmup_steps, 1), 1.0)
        t = jnp.clip((step - self.warmup_steps)
                     / max(self.total_steps - self.warmup_steps, 1), 0, 1)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        frac = self.min_lr_frac + (1 - self.min_lr_frac) * cos
        return self.lr * warm * frac

    def init(self, params: Any) -> AdamWState:
        # copy=True: astype on an already-f32 param would alias the same
        # buffer, breaking donation (donate(params) + donate(master))
        f32 = jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params)
        zeros = jax.tree.map(jnp.zeros_like, f32)
        return AdamWState(step=jnp.zeros((), jnp.int32), master=f32,
                          m=zeros, v=jax.tree.map(jnp.zeros_like, f32))

    def update(self, grads: Any, state: AdamWState, params: Any
               ) -> Tuple[Any, AdamWState]:
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.clip_norm:
            gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                                 for g in jax.tree.leaves(g32)))
            scale = jnp.minimum(1.0, self.clip_norm
                                / jnp.maximum(gnorm, 1e-12))
            g32 = jax.tree.map(lambda g: g * scale, g32)
        step = state.step + 1
        lr = self.schedule(step)
        b1c = 1 - self.b1 ** step.astype(jnp.float32)
        b2c = 1 - self.b2 ** step.astype(jnp.float32)

        m = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g,
                         state.m, g32)
        v = jax.tree.map(lambda v, g: self.b2 * v + (1 - self.b2) * g * g,
                         state.v, g32)

        def upd(p, m_, v_):
            mh = m_ / b1c
            vh = v_ / b2c
            return p - lr * (mh / (jnp.sqrt(vh) + self.eps)
                             + self.weight_decay * p)

        master = jax.tree.map(upd, state.master, m, v)
        if self.project is not None:
            master = self.project(master)
        new_params = jax.tree.map(
            lambda mp, p: mp.astype(p.dtype), master, params)
        return new_params, AdamWState(step=step, master=master, m=m, v=v)
