"""musicgen-medium [audio]: decoder-only over EnCodec tokens
(arXiv:2306.05284).  48L d_model=1536 24H(kv=24) d_ff=6144 vocab=2048.
Frontend (EnCodec + text conditioning) is a stub supplying precomputed
frame embeddings per the assignment."""
from .base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="musicgen-medium", family="audio",
        n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
        d_ff=6144, vocab=2048, mlp_act="gelu",
        frontend="frame", frontend_len=64,
    ),
    reduced=lambda: ArchConfig(
        name="musicgen-medium", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=64, mlp_act="gelu",
        frontend="frame", frontend_len=8,
        dtype=__import__("jax.numpy", fromlist=["float32"]).float32,
    ),
)
