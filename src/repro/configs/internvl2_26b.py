"""internvl2-26b [vlm]: InternViT frontend (stub) + InternLM2 backbone
(arXiv:2404.16821).  48L d_model=6144 48H(GQA kv=8) d_ff=16384
vocab=92553."""
from .base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="internvl2-26b", family="vlm",
        n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16384, vocab=92553,
        frontend="patch", frontend_len=256,
    ),
    reduced=lambda: ArchConfig(
        name="internvl2-26b", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, frontend="patch", frontend_len=8,
        dtype=__import__("jax.numpy", fromlist=["float32"]).float32,
    ),
)
