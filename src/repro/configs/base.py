"""Architecture configuration schema + registry + assigned input shapes.

Every assigned architecture provides one module ``configs/<id>.py`` holding
its exact published configuration; reduced variants are generated for CPU
smoke tests.  Shapes follow the assignment:

    train_4k     seq 4096   global_batch 256   (training step)
    prefill_32k  seq 32768  global_batch 32    (inference prefill)
    decode_32k   seq 32768  global_batch 128   (single-token decode w/ KV)
    long_500k    seq 524288 global_batch 1     (long-context decode;
                 sub-quadratic archs only — see DESIGN.md §4)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    d_expert: int = 0
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    chunk: int = 256
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    mlp_act: str = "silu"           # silu (SwiGLU) | gelu (GeGLU)
    tie_embeddings: bool = False
    # gemma2-style features
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    sliding_window: int = 0         # >0: alternate local/global layers
    post_norms: bool = False
    # MoE / SSM / hybrid
    moe: MoEConfig = MoEConfig()
    ssm: SSMConfig = SSMConfig()
    attn_every: int = 0             # hybrid: shared attn block cadence
    # modality frontend stubs
    frontend: str = "none"          # none | patch (vlm) | frame (audio)
    frontend_len: int = 0           # prepended embedding positions
    # numerics
    dtype: Any = jnp.bfloat16
    # which shape cells apply (long_500k only for sub-quadratic archs)
    supports_long_context: bool = False

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to a multiple of 256 so the embedding/head can
        shard over the 16-way model axis (pad logits are masked to -inf)."""
        return (self.vocab + 255) // 256 * 256

    @property
    def n_experts_padded(self) -> int:
        """Experts rounded up to a multiple of 16 for expert parallelism
        (pad experts are never routed to — router emits n_experts logits)."""
        e = self.moe.n_experts
        return (e + 15) // 16 * 16 if e else 0

    def param_count(self) -> int:
        """Approximate parameter count (used for roofline MODEL_FLOPS)."""
        d, ff, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        hd = self.hd
        emb = V * d * (1 if self.tie_embeddings else 2)
        if self.family in ("ssm",):
            d_in = self.ssm.expand * d
            per = (d * (2 * d_in + 2 * self.ssm.n_groups * self.ssm.d_state
                        + d_in // self.ssm.head_dim)
                   + d_in * d + d_in * self.ssm.d_conv)
            return emb + L * per
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + \
            self.n_heads * hd * d
        if self.moe.n_experts:
            fe = self.moe.d_expert
            mlp = (self.moe.n_experts + self.moe.n_shared) * 3 * d * fe + \
                d * self.moe.n_experts
        else:
            mlp = 3 * d * ff if self.mlp_act in ("silu", "gelu") else 2 * d * ff
        per = attn + mlp
        if self.family == "hybrid":
            d_in = self.ssm.expand * d
            ssm_per = (d * (2 * d_in + 2 * self.ssm.n_groups *
                            self.ssm.d_state + d_in // self.ssm.head_dim)
                       + d_in * d + d_in * self.ssm.d_conv)
            n_attn = 1  # one shared block
            return emb + L * ssm_per + n_attn * per
        return emb + L * per

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if not self.moe.n_experts:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        hd = self.hd
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + \
            self.n_heads * hd * d
        fe = self.moe.d_expert
        act_mlp = (self.moe.top_k + self.moe.n_shared) * 3 * d * fe + \
            d * self.moe.n_experts
        return emb + L * (attn + act_mlp)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # train | prefill | decode


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------- registry

REGISTRY: Dict[str, ArchConfig] = {}
REDUCED: Dict[str, Callable[[], ArchConfig]] = {}


def register(cfg: ArchConfig, reduced: Callable[[], ArchConfig]) -> ArchConfig:
    REGISTRY[cfg.name] = cfg
    REDUCED[cfg.name] = reduced
    return cfg


def get_config(name: str, reduced: bool = False) -> ArchConfig:
    import repro.configs  # ensure all modules registered  # noqa: F401
    if reduced:
        return REDUCED[name]()
    return REGISTRY[name]


def list_archs() -> List[str]:
    import repro.configs  # noqa: F401
    return sorted(REGISTRY)


def cell_applicable(cfg: ArchConfig, shape: ShapeCell) -> Tuple[bool, str]:
    """Whether a (arch × shape) cell runs; reason recorded if skipped."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("full-attention arch: 512k dense decode is "
                       "quadratic — skipped per assignment (DESIGN.md §4)")
    return True, ""


# ----------------------------------------------------------- input specs

def input_specs(cfg: ArchConfig, shape: ShapeCell) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of the given cell
    (no device allocation; used by the dry-run .lower())."""
    B, S = shape.global_batch, shape.seq_len
    f = jax.ShapeDtypeStruct
    specs: Dict[str, Any] = {}
    if shape.kind == "train":
        specs["tokens"] = f((B, S), jnp.int32)
        specs["labels"] = f((B, S), jnp.int32)
    elif shape.kind == "prefill":
        specs["tokens"] = f((B, S), jnp.int32)
    else:  # decode: one new token against a seq_len-deep cache
        specs["tokens"] = f((B, 1), jnp.int32)
        specs["cache_index"] = f((), jnp.int32)
    if cfg.frontend == "patch":
        n = cfg.frontend_len or 256
        specs["frontend_embed"] = f((B, n, cfg.d_model), cfg.dtype)
    elif cfg.frontend == "frame":
        n = cfg.frontend_len or 64
        specs["frontend_embed"] = f((B, n, cfg.d_model), cfg.dtype)
    return specs
