"""mamba2-780m [ssm]: SSD state-space duality, attention-free
(arXiv:2405.21060).  48L d_model=1536 d_ff=0 vocab=50280, ssm_state=128."""
from .base import ArchConfig, SSMConfig, register

CONFIG = register(
    ArchConfig(
        name="mamba2-780m", family="ssm",
        n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab=50280,
        ssm=SSMConfig(d_state=128, head_dim=64, expand=2),
        supports_long_context=True,
    ),
    reduced=lambda: ArchConfig(
        name="mamba2-780m", family="ssm",
        n_layers=4, d_model=64, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab=256,
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2, chunk=32),
        supports_long_context=True,
        dtype=__import__("jax.numpy", fromlist=["float32"]).float32,
    ),
)
