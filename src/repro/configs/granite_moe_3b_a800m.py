"""granite-moe-3b-a800m [moe]: 40 experts top-8
(hf:ibm-granite/granite-3.0-1b-a400m-base family).  32L d_model=1536
24H(GQA kv=8) d_ff=512 vocab=49155."""
from .base import ArchConfig, MoEConfig, register

CONFIG = register(
    ArchConfig(
        name="granite-moe-3b-a800m", family="moe",
        n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
        d_ff=512, vocab=49155,
        moe=MoEConfig(n_experts=40, top_k=8, n_shared=0, d_expert=512),
        tie_embeddings=True,
    ),
    reduced=lambda: ArchConfig(
        name="granite-moe-3b-a800m", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=32, vocab=256,
        moe=MoEConfig(n_experts=8, top_k=4, n_shared=0, d_expert=32),
        tie_embeddings=True,
        dtype=__import__("jax.numpy", fromlist=["float32"]).float32,
    ),
)
