"""qwen2-moe-a2.7b [moe]: 4 shared + 60 routed experts top-4
(hf:Qwen/Qwen1.5-MoE-A2.7B).  24L d_model=2048 16H(kv=16) d_ff=1408
vocab=151936."""
from .base import ArchConfig, MoEConfig, register

CONFIG = register(
    ArchConfig(
        name="qwen2-moe-a2.7b", family="moe",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab=151936,
        moe=MoEConfig(n_experts=60, top_k=4, n_shared=4, d_expert=1408),
    ),
    reduced=lambda: ArchConfig(
        name="qwen2-moe-a2.7b", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=32, vocab=256,
        moe=MoEConfig(n_experts=8, top_k=2, n_shared=1, d_expert=32),
        dtype=__import__("jax.numpy", fromlist=["float32"]).float32,
    ),
)
