"""glm4-9b [dense]: RoPE + GQA (hf:THUDM/glm-4-9b).
40L d_model=4096 32H(GQA kv=2) d_ff=13696 vocab=151552."""
from .base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="glm4-9b", family="dense",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2,
        d_ff=13696, vocab=151552, qkv_bias=True,
    ),
    reduced=lambda: ArchConfig(
        name="glm4-9b", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, qkv_bias=True,
        dtype=__import__("jax.numpy", fromlist=["float32"]).float32,
    ),
)
