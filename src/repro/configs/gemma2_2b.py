"""gemma2-2b [dense]: local+global alternating attention, logit softcaps,
post-norms, GeGLU (arXiv:2408.00118).  26L d_model=2304 8H(GQA kv=4)
d_ff=9216 vocab=256000, head_dim=256."""
from .base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="gemma2-2b", family="dense",
        n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4,
        d_ff=9216, vocab=256000, head_dim=256, mlp_act="gelu",
        attn_softcap=50.0, final_softcap=30.0,
        sliding_window=4096, post_norms=True, tie_embeddings=True,
    ),
    reduced=lambda: ArchConfig(
        name="gemma2-2b", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, head_dim=16, mlp_act="gelu",
        attn_softcap=50.0, final_softcap=30.0,
        sliding_window=32, post_norms=True, tie_embeddings=True,
        dtype=__import__("jax.numpy", fromlist=["float32"]).float32,
    ),
)
