"""Assigned architecture configs (10 archs from the public pool)."""
from .base import (ArchConfig, MoEConfig, SSMConfig, ShapeCell, SHAPES,  # noqa
                   REGISTRY, get_config, list_archs, input_specs,
                   cell_applicable, register)
from . import (zamba2_2p7b, internvl2_26b, qwen2_1p5b, gemma2_2b,  # noqa
               glm4_9b, granite3_2b, qwen2_moe_a2p7b,
               granite_moe_3b_a800m, mamba2_780m, musicgen_medium)
