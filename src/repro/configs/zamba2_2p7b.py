"""zamba2-2.7b [hybrid]: 54L Mamba2 backbone + shared attention blocks
(arXiv:2411.15242).  54L d_model=2560 32H(kv=32) d_ff=10240 vocab=32000,
ssm_state=64."""
from .base import ArchConfig, SSMConfig, register

CONFIG = register(
    ArchConfig(
        name="zamba2-2.7b", family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
        d_ff=10240, vocab=32000,
        ssm=SSMConfig(d_state=64, head_dim=64, expand=2),
        attn_every=6,                     # shared attn block every 6 mamba
        supports_long_context=True,       # Mamba2 backbone: O(S) decode
    ),
    reduced=lambda: ArchConfig(
        name="zamba2-2.7b", family="hybrid",
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256,
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2, chunk=32),
        attn_every=3, supports_long_context=True,
        dtype=__import__("jax.numpy", fromlist=["float32"]).float32,
    ),
)
