"""Production mesh definitions.

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model); the pod axis
is pure data parallelism whose gradient all-reduce crosses the DCN.

Defined as functions (never module-level constants) so importing this
module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int = 1):
    """Small mesh over whatever devices exist (tests / CPU)."""
    n = min(n_devices, len(jax.devices()))
    model = 2 if n % 2 == 0 else 1
    return jax.make_mesh((n // model, model), ("data", "model"))


def use_mesh(mesh):
    """Context manager activating ``mesh`` for in-jit ``shard()``
    constraints — ``jax.set_mesh`` on jax >= 0.5, the ``Mesh`` object
    itself (it is a context manager) on the pinned 0.4.x."""
    set_mesh = getattr(jax, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh
