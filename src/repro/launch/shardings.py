"""Parameter / state / input PartitionSpec assignment (DP x TP x EP).

Rules are assigned by parameter path name, applied to the *last* dims so
layer-stacking axes (scan) are untouched:

  embed (V, d)            → ("model", None)      vocab-parallel
  lm_head (d, V)          → (None, "model")
  attn wq/wk/wv (d, Hh)   → (None, "model")      head-parallel
  attn wo (Hh, d)         → ("model", None)
  mlp w_gate/up (d, f)    → (None, "model")
  mlp w_down (f, d)       → ("model", None)
  moe experts (E, d, f)   → ("model", None, None) expert-parallel
  mamba in_proj (d, p)    → (None, "model")
  mamba out_proj (p, d)   → ("model", None)
  conv_w (K, ch)          → (None, "model"); conv_b/bq/bk/bv → ("model",)
  norms / scalars         → replicated

Optimizer moments & master weights additionally shard their largest
replicated dim over "data" (ZeRO-style) so 20B-param optimizer state fits
per chip.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

_RULES = [
    ("embed", ("model", None)),
    ("lm_head", (None, "model")),
    ("wq", (None, "model")), ("wk", (None, "model")),
    ("wv", (None, "model")),
    ("wo", ("model", None)),
    ("bq", ("model",)), ("bk", ("model",)), ("bv", ("model",)),
    ("w_gate", (None, "model")), ("w_up", (None, "model")),
    ("w_down", ("model", None)),
    ("router", (None, None)),
    ("in_proj", (None, "model")),
    ("out_proj", ("model", None)),
    ("conv_w", (None, "model")), ("conv_b", ("model",)),
    ("norm_scale", (None,)),
    ("A_log", (None,)), ("D", (None,)), ("dt_bias", (None,)),
]

_EXPERT_RULES = [
    ("w_gate", ("model", None, None)), ("w_up", ("model", None, None)),
    ("w_down", ("model", None, None)),
]


def _spec_for(path: str, ndim: int, in_experts: bool) -> P:
    # int8-packed weights: {.../wq/q, .../wq/s} — rule on the parent name
    if path.endswith("/q"):
        path = path[:-2]
    elif path.endswith("/s"):
        return P(*((None,) * ndim))
    rules = _EXPERT_RULES + _RULES if in_experts else _RULES
    for key, tail in rules:
        if path.endswith("/" + key) or path == key:
            pad = (None,) * (ndim - len(tail))
            return P(*(pad + tuple(tail)))
    return P(*((None,) * ndim))


def param_pspecs(params: Any) -> Any:
    """PartitionSpec pytree mirroring the params pytree."""
    def walk(tree, path, in_experts):
        if isinstance(tree, dict):
            return {k: walk(v, path + "/" + k,
                            in_experts or k == "experts")
                    for k, v in tree.items()}
        return _spec_for(path, np.ndim(tree), in_experts)
    return walk(params, "", False)


def zero_shard(spec: P, shape) -> P:
    """Additionally shard the largest None dim over 'data' (ZeRO-style),
    for optimizer state."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    best, best_size = None, 0
    for i, (s, n) in enumerate(zip(parts, shape)):
        if s is None and n > best_size and n >= 16:
            best, best_size = i, n
    if best is not None:
        parts[best] = "data"
    return P(*parts)


def opt_pspecs(params: Any, pspec_tree: Any) -> Any:
    return jax.tree.map(
        lambda p, s: zero_shard(s, np.shape(p)), params, pspec_tree)


def filter_pspec_for_mesh(spec: P, mesh, shape=None) -> P:
    """Drop axis names the mesh does not have (pod-less single mesh), and —
    when ``shape`` is given — drop assignments that do not divide the dim
    (XLA argument shardings require exact divisibility; the model pads
    vocab/experts so the big tensors stay sharded, anything odd degrades
    to replication)."""
    names = set(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def f(s, dim):
        if s is None:
            return None
        parts = s if isinstance(s, (tuple, list)) else (s,)
        kept = tuple(a for a in parts if a in names)
        if not kept:
            return None
        if dim is not None:
            total = 1
            for a in kept:
                total *= sizes[a]
            if dim % total != 0:
                return None
        return kept if len(kept) > 1 else kept[0]

    dims = list(shape) + [None] * (len(spec) - len(shape)) \
        if shape is not None else [None] * len(spec)
    return P(*[f(s, d) for s, d in zip(spec, dims)])


def named(mesh, spec_tree: Any, shape_tree: Any = None) -> Any:
    """NamedSharding tree; pass the matching ShapeDtypeStruct tree to get
    divisibility-guarded argument shardings."""
    if shape_tree is None:
        return jax.tree.map(
            lambda s: NamedSharding(mesh, filter_pspec_for_mesh(s, mesh)),
            spec_tree, is_leaf=lambda x: isinstance(x, P))
    return jax.tree.map(
        lambda s, a: NamedSharding(
            mesh, filter_pspec_for_mesh(s, mesh, np.shape(a)
                                        if not hasattr(a, "shape")
                                        else a.shape)),
        spec_tree, shape_tree, is_leaf=lambda x: isinstance(x, P))


def batch_pspec(ndim: int) -> P:
    return P(("pod", "data"), *((None,) * (ndim - 1)))


def kv_page_pspec() -> P:
    """PartitionSpec for a serving KV page pool of shape
    (num_pages, page_size, n_kv_heads, head_dim): the KV-head dim over
    "model" — tensor-parallel decode with a per-device shard of every
    physical page, so the host-side page table / free list stay global
    while the KV bytes split across the mesh."""
    return P(None, None, "model", None)


def kv_pool_sharding(mesh, n_kv_heads: int) -> NamedSharding:
    """Divisibility-guarded NamedSharding for the page pools: the head
    dim degrades to replication when the mesh's "model" axis does not
    divide ``n_kv_heads`` (2 KV heads on a 16-way axis would pad 8x)."""
    return NamedSharding(
        mesh, filter_pspec_for_mesh(kv_page_pspec(), mesh,
                                    (1, 1, n_kv_heads, 1)))
