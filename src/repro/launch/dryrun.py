import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# NOTE: the two lines above MUST run before any other import (jax locks the
# device count at first init).  Do not move them.

__doc__ = """Multi-pod dry-run driver.

For every (architecture × input shape × mesh) cell:
  * build the step function (train_step / prefill forward / decode_step),
  * jit with explicit in/out shardings over the production mesh,
  * ``.lower(**ShapeDtypeStruct specs).compile()``,
  * record memory_analysis / cost_analysis / collective schedule →
    experiments/dryrun/<arch>__<shape>__<mesh>.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b \
      --shape train_4k --mesh pod          # single cell, 256-chip mesh
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (SHAPES, cell_applicable, get_config,
                           input_specs, list_archs)
from repro.launch.mesh import make_production_mesh
from repro.launch.shardings import (batch_pspec, filter_pspec_for_mesh,
                                    named, opt_pspecs, param_pspecs)
from repro.models import get_model
from repro.optim.adamw import AdamW, AdamWState
from repro.quant.quantizer import QuantSpec
from repro.roofline.analysis import model_flops_for, roofline_from
from repro.roofline.hlo_cost import analyze_hlo, normalize_cost_analysis
from repro.train.train_step import TrainState, init_train_state, \
    make_train_step

BATCH = ("pod", "data")


# ------------------------------------------------------------- cache specs

def cache_pspecs(cache: Any, kv_heads: int = 0,
                 model_size: int = 16) -> Any:
    """PartitionSpecs for decode caches, assigned by leaf key name.

    KV caches shard their head axis on "model" when divisible; otherwise
    the *sequence* axis is model-sharded (flash-decode style: GSPMD adds
    the partial-softmax all-reduce), which keeps the cache 16-way sharded
    for the GQA archs with 2-8 KV heads instead of replicating it."""
    def walk(tree, key):
        if isinstance(tree, dict):
            return {k: walk(v, k) for k, v in tree.items()}
        nd = len(tree.shape)
        if key in ("k", "v"):        # (n..., B, S, KV, hd)
            pad = (None,) * (nd - 4)
            if kv_heads and kv_heads % model_size == 0:
                return P(*pad, BATCH, None, "model", None)
            return P(*pad, BATCH, "model", None, None)
        if key == "conv":            # (n..., B, K-1, ch)
            pad = (None,) * (nd - 3)
            return P(*pad, BATCH, None, "model")
        if key == "ssd":             # (n..., B, H, P, N)
            pad = (None,) * (nd - 4)
            return P(*pad, BATCH, "model", None, None)
        return P(*((None,) * nd))
    return walk(cache, "")


def state_pspecs(state_shapes: TrainState, pspecs_params) -> TrainState:
    op = opt_pspecs(state_shapes.opt.master, pspecs_params)
    return TrainState(
        params=pspecs_params,
        opt=AdamWState(step=P(), master=op,
                       m=jax.tree.map(lambda s: s, op),
                       v=jax.tree.map(lambda s: s, op)),
        error_feedback=None,
        rng=P())


# --------------------------------------------------------------- one cell

@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    ok: bool
    skipped: bool = False
    reason: str = ""
    compile_s: float = 0.0
    memory: Dict[str, float] = dataclasses.field(default_factory=dict)
    cost: Dict[str, float] = dataclasses.field(default_factory=dict)
    collectives: Dict[str, Any] = dataclasses.field(default_factory=dict)
    roofline: Dict[str, float] = dataclasses.field(default_factory=dict)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             microbatches: int = 8, quant_bits: int = 0,
             save_hlo: Optional[str] = None,
             zero2: bool = False, remat: bool = True,
             int8_weights: bool = False,
             int8_kv: bool = False,
             capacity_factor: float = 0.0) -> CellResult:
    cfg = get_config(arch)
    if capacity_factor and cfg.moe.n_experts:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, moe=_dc.replace(
            cfg.moe, capacity_factor=capacity_factor))
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    ok, reason = cell_applicable(cfg, shape)
    if not ok:
        return CellResult(arch, shape_name, mesh_name, ok=False,
                          skipped=True, reason=reason)

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    model = get_model(cfg)
    quant = QuantSpec(bits=quant_bits) if quant_bits else None
    specs = input_specs(cfg, shape)

    def make_params_shapes():
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        if int8_weights:
            from repro.quant.quantizer import pack_weights_int8
            shapes = jax.eval_shape(pack_weights_int8, shapes)
        return shapes

    pspecs = param_pspecs(make_params_shapes())

    # jax >= 0.5 activates a mesh with jax.set_mesh; on older releases the
    # Mesh object itself is the context manager.
    set_mesh = getattr(jax, "set_mesh", None) or (lambda m: m)

    t0 = time.time()
    try:
        with set_mesh(mesh):
            if shape.kind == "train":
                optimizer = AdamW(total_steps=1000)
                step_fn = make_train_step(model, optimizer,
                                          microbatches=microbatches,
                                          quant=quant, remat=remat)
                state_shapes = jax.eval_shape(
                    lambda k: init_train_state(model, optimizer, k),
                    jax.random.PRNGKey(0))
                sspec = state_pspecs(state_shapes, pspecs)
                if zero2:
                    pass  # grads constrained inside train_step via flag
                bspec = {k: batch_pspec(len(v.shape))
                         for k, v in specs.items()}
                lowered = jax.jit(
                    step_fn,
                    in_shardings=(named(mesh, sspec, state_shapes),
                                  named(mesh, bspec, specs)),
                    out_shardings=(named(mesh, sspec, state_shapes), None),
                    donate_argnums=(0,),
                ).lower(state_shapes, specs)
            elif shape.kind == "prefill":
                def fwd(params, batch):
                    return model.forward(params, batch["tokens"],
                                         batch.get("frontend_embed"),
                                         quant=quant, remat=remat)
                params_shapes = make_params_shapes()
                bspec = {k: batch_pspec(len(v.shape))
                         for k, v in specs.items()}
                s_total = shape.seq_len + (
                    specs["frontend_embed"].shape[1]
                    if "frontend_embed" in specs else 0)
                logits_shape = (shape.global_batch, s_total,
                                cfg.vocab_padded)
                out_spec = NamedSharding(
                    mesh, filter_pspec_for_mesh(P(BATCH, None, "model"),
                                                mesh, logits_shape))
                lowered = jax.jit(
                    fwd,
                    in_shardings=(named(mesh, pspecs, params_shapes),
                                  named(mesh, bspec, specs)),
                    out_shardings=out_spec,
                ).lower(params_shapes, specs)
            else:  # decode
                params_shapes = make_params_shapes()
                cache_shapes = jax.eval_shape(
                    lambda: model.init_cache(
                        shape.global_batch, shape.seq_len,
                        kv_dtype=jnp.int8 if int8_kv else None))
                cspec = cache_pspecs(cache_shapes, cfg.n_kv_heads,
                                     mesh.devices.shape[-1])

                def dec(params, tokens, cache, idx):
                    return model.decode_step(params, tokens, cache, idx,
                                             quant=quant)
                logits_shape = (shape.global_batch, 1,
                                cfg.vocab_padded)
                out_spec = (NamedSharding(mesh, filter_pspec_for_mesh(
                    P(BATCH, None, "model"), mesh, logits_shape)),
                    named(mesh, cspec, cache_shapes))
                lowered = jax.jit(
                    dec,
                    in_shardings=(named(mesh, pspecs, params_shapes),
                                  NamedSharding(mesh, filter_pspec_for_mesh(
                                      P(BATCH, None), mesh,
                                      specs["tokens"].shape)),
                                  named(mesh, cspec, cache_shapes),
                                  NamedSharding(mesh, P())),
                    out_shardings=out_spec,
                    donate_argnums=(2,),
                ).lower(params_shapes, specs["tokens"], cache_shapes,
                        specs["cache_index"])
            compiled = lowered.compile()
    except Exception:
        return CellResult(arch, shape_name, mesh_name, ok=False,
                          reason=traceback.format_exc()[-2000:],
                          compile_s=time.time() - t0)
    compile_s = time.time() - t0

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            mem[f] = float(getattr(ma, f))
        mem["total_per_device_gb"] = (
            mem["argument_size_in_bytes"] + mem["output_size_in_bytes"]
            + mem["temp_size_in_bytes"] - mem["alias_size_in_bytes"]) / 2**30
    except Exception as e:  # pragma: no cover
        mem["error"] = str(e)

    cost_xla = normalize_cost_analysis(compiled.cost_analysis())
    hlo = compiled.as_text()
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    pod_group = 2 if multi_pod else None
    # trip-count-aware totals (XLA cost_analysis counts scan bodies once)
    totals = analyze_hlo(hlo, pod_group_size=pod_group)
    cost = {"flops": totals.flops, "bytes accessed": totals.bytes,
            "xla_flops_1trip": float(cost_xla.get("flops", 0.0)),
            "xla_bytes_1trip": float(cost_xla.get("bytes accessed", 0.0))}
    from repro.roofline.analysis import CollectiveStats
    colls = CollectiveStats(
        counts={k: int(v) for k, v in totals.collective_counts.items()},
        operand_bytes={k: int(v)
                       for k, v in totals.collective_bytes.items()},
        wire_bytes={k: int(v) for k, v in totals.collective_bytes.items()},
        cross_pod_bytes=int(totals.cross_pod_bytes))
    mf = model_flops_for(cfg, shape, shape.kind)
    rl = roofline_from(cost, colls, n_chips, mf)

    return CellResult(
        arch, shape_name, mesh_name, ok=True, compile_s=compile_s,
        memory=mem,
        cost=cost,
        collectives=dict(counts=colls.counts,
                         operand_bytes=colls.operand_bytes,
                         wire_bytes=colls.wire_bytes,
                         cross_pod_bytes=colls.cross_pod_bytes),
        roofline=rl.to_dict())


# -------------------------------------------------------------------- CLI

def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="pod",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--quant-bits", type=int, default=0)
    ap.add_argument("--int8-weights", action="store_true")
    ap.add_argument("--int8-kv", action="store_true")
    ap.add_argument("--capacity-factor", type=float, default=0.0)
    ap.add_argument("--attn-p-bf16", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args()

    archs = list_archs() if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
                if args.attn_p_bf16:
                    import repro.models.attention as attn_mod
                    attn_mod.P_DTYPE = jnp.bfloat16
                res = run_cell(arch, shape, mp,
                               microbatches=args.microbatches,
                               quant_bits=args.quant_bits,
                               remat=not args.no_remat,
                               int8_weights=args.int8_weights,
                               int8_kv=args.int8_kv,
                               capacity_factor=args.capacity_factor,
                               save_hlo=args.save_hlo)
                results.append(res)
                path = os.path.join(args.out, tag + ".json")
                with open(path, "w") as f:
                    json.dump(dataclasses.asdict(res), f, indent=1)
                status = ("SKIP" if res.skipped else
                          "OK" if res.ok else "FAIL")
                rl = res.roofline
                extra = ""
                if res.ok:
                    extra = (f" compile={res.compile_s:.0f}s "
                             f"mem={res.memory.get('total_per_device_gb', -1):.2f}GB "
                             f"bottleneck={rl['bottleneck']}")
                print(f"[{status}] {tag}{extra}", flush=True)
                if not res.ok and not res.skipped:
                    print(res.reason[-600:], flush=True)
    n_ok = sum(r.ok for r in results)
    n_skip = sum(r.skipped for r in results)
    print(f"\n{n_ok} ok, {n_skip} skipped, "
          f"{len(results) - n_ok - n_skip} failed / {len(results)} cells")


if __name__ == "__main__":
    main()
