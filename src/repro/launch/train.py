"""End-to-end training driver with fault tolerance.

Runs QAT (or full-precision) training of any registered architecture on
the deterministic synthetic pipeline, with periodic atomic checkpoints and
automatic resume from the latest checkpoint — kill the process at any
point and relaunch with the same command to continue bit-exactly.

On this CPU container use reduced configs:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --reduced \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import TokenPipeline
from repro.models import get_model
from repro.optim import AdamW
from repro.quant.quantizer import QuantSpec
from repro.train import (init_train_state, latest_checkpoint,
                         make_train_step, restore_checkpoint,
                         save_checkpoint, step_of)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--quant-bits", type=int, default=0)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    model = get_model(cfg)
    optimizer = AdamW(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                      total_steps=args.steps)
    quant = QuantSpec(bits=args.quant_bits) if args.quant_bits else None

    pipeline = TokenPipeline(args.seq, args.batch, cfg.vocab,
                             seed=args.seed)
    state = init_train_state(model, optimizer, jax.random.PRNGKey(args.seed),
                             compress=args.compress_grads)
    start_step = 0
    if args.ckpt_dir:
        ck = latest_checkpoint(args.ckpt_dir)
        if ck:
            state, extra = restore_checkpoint(ck, state)
            start_step = extra.get("data_step", step_of(ck))
            print(f"resumed from {ck} at step {start_step}", flush=True)

    step_fn = jax.jit(make_train_step(
        model, optimizer, microbatches=args.microbatches, quant=quant,
        remat=False, compress=args.compress_grads), donate_argnums=(0,))

    losses = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = pipeline.batch_at(step)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} ({dt:.1f}s)", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step + 1, state,
                            extra={"data_step": step + 1})
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps, state,
                        extra={"data_step": args.steps})
    first = np.mean(losses[:10]) if len(losses) >= 10 else losses[0]
    last = np.mean(losses[-10:])
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
