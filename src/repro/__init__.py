"""repro: SIRA (scaled-integer range analysis) as a production JAX framework.

Subpackages:
  core     — the paper's contribution (SIRA analysis + FDNA optimizations)
  quant    — quantization substrate (QAT/PTQ quantizers)
  kernels  — Pallas TPU kernels (int matmul, multithreshold, quantize)
  models   — LM model zoo (dense/GQA, MoE, SSM, hybrid)
  configs  — assigned architecture configs
  data     — deterministic synthetic data pipeline
  optim    — AdamW optimizer
  train    — training loop, checkpointing, fault tolerance
  serve    — batched serving engine
  launch   — mesh, dry-run, train/serve drivers
  roofline — roofline analysis from compiled artifacts
"""
__version__ = "1.0.0"
