"""Uniform affine quantizers in JAX (paper §2.1/§2.3; Brevitas-analog).

Supports the full QONNX Quant parameter space: arbitrary bitwidth,
signed/unsigned, narrow range, per-tensor / per-channel / per-group scale
granularity, float or power-of-two (PoT) scales, zero-points, and
straight-through-estimator (STE) fake quantization for QAT.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    bits: int = 8
    signed: bool = True
    narrow: bool = False
    granularity: str = "per_tensor"   # per_tensor | per_channel | per_group
    channel_axis: int = -1
    group_size: int = 32
    pot: bool = False                 # power-of-two scale restriction
    symmetric: bool = True            # zero_point == 0
    rounding: str = "nearest"         # nearest | toward_zero

    @property
    def qmin(self) -> int:
        if self.signed:
            return -(2 ** (self.bits - 1)) + (1 if self.narrow else 0)
        return 0

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1 if self.signed else 2 ** self.bits - 1


def _reduce_axes(x: jnp.ndarray, spec: QuantSpec) -> Tuple[int, ...]:
    if spec.granularity == "per_tensor":
        return tuple(range(x.ndim))
    ax = spec.channel_axis % x.ndim
    return tuple(i for i in range(x.ndim) if i != ax)


def compute_scale(x: jnp.ndarray, spec: QuantSpec,
                  eps: float = 1e-8) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Min/max calibration → (scale, zero_point), broadcastable to x."""
    if spec.granularity == "per_group":
        ax = spec.channel_axis % x.ndim
        g = spec.group_size
        shp = list(x.shape)
        assert shp[ax] % g == 0, "group_size must divide the channel dim"
        xg = jnp.moveaxis(x, ax, -1).reshape(-1, shp[ax] // g, g)
        amax = jnp.abs(xg).max(axis=(0, 2), keepdims=True)       # (1, G, 1)
        s = jnp.maximum(amax / spec.qmax, eps)
        s = jnp.broadcast_to(s, (1, shp[ax] // g, g)).reshape(shp[ax])
        shape = [1] * x.ndim
        shape[ax] = shp[ax]
        s = s.reshape(shape)
        z = jnp.zeros_like(s)
    elif spec.symmetric:
        axes = _reduce_axes(x, spec)
        amax = jnp.abs(x).max(axis=axes, keepdims=True)
        s = jnp.maximum(amax / spec.qmax, eps)
        z = jnp.zeros_like(s)
    else:
        axes = _reduce_axes(x, spec)
        x_lo = x.min(axis=axes, keepdims=True)
        x_hi = x.max(axis=axes, keepdims=True)
        s = jnp.maximum((x_hi - x_lo) / (spec.qmax - spec.qmin), eps)
        z = jnp.round(spec.qmin - x_lo / s)
    if spec.pot:
        s = jnp.exp2(jnp.ceil(jnp.log2(s)))
    return s, z


def quantize_int(x: jnp.ndarray, scale: jnp.ndarray, zero_point: jnp.ndarray,
                 spec: QuantSpec) -> jnp.ndarray:
    """g ∘ f⁻¹: real → clipped integer (float dtype carrier).

    ``rounding="toward_zero"`` truncates instead of rounding to nearest,
    which guarantees |q| <= |x/scale| element-wise — the property the
    accumulator-aware QAT projection (repro.qat) relies on to turn an
    L1 bound on x/scale into an L1 bound on the quantized integers."""
    u = x / scale + zero_point
    if spec.rounding == "toward_zero":
        q = jnp.trunc(u)
    elif spec.rounding == "nearest":
        q = jnp.round(u)
    else:
        raise ValueError(f"unknown rounding mode: {spec.rounding!r}")
    return jnp.clip(q, spec.qmin, spec.qmax)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray, zero_point: jnp.ndarray
               ) -> jnp.ndarray:
    return scale * (q - zero_point)


def fake_quant(x: jnp.ndarray, scale: jnp.ndarray, zero_point: jnp.ndarray,
               spec: QuantSpec) -> jnp.ndarray:
    """Q(x) = f(g(f⁻¹(x))) with a straight-through gradient (QAT).

    The STE passes gradients through the round+clip as identity within the
    representable range and zero outside (clipped STE)."""
    q = quantize_int(jax.lax.stop_gradient(x), scale, zero_point, spec)
    y = dequantize(q, scale, zero_point)
    # clipped STE: identity gradient inside the clip range
    lo = dequantize(jnp.asarray(float(spec.qmin)), scale, zero_point)
    hi = dequantize(jnp.asarray(float(spec.qmax)), scale, zero_point)
    x_clipped = jnp.clip(x, lo, hi)
    return x_clipped + jax.lax.stop_gradient(y - x_clipped)


def fake_quant_dynamic(x: jnp.ndarray, spec: QuantSpec) -> jnp.ndarray:
    """Fake-quant with scales computed on the fly from the current batch
    (used for QAT activation quantizers before calibration freezes them)."""
    s, z = compute_scale(jax.lax.stop_gradient(x), spec)
    return fake_quant(x, s, z, spec)


# --------------------------------------------------------------------------
# integer-arithmetic helpers (serving path)
# --------------------------------------------------------------------------

def to_int_dtype(q: jnp.ndarray, spec: QuantSpec) -> jnp.ndarray:
    if spec.bits <= 8:
        return q.astype(jnp.int8)
    if spec.bits <= 16:
        return q.astype(jnp.int16)
    return q.astype(jnp.int32)


def int_matmul(qx: jnp.ndarray, qw: jnp.ndarray,
               acc_dtype=jnp.int32) -> jnp.ndarray:
    """Integer matmul on the MXU int path: int8 × int8 → int32."""
    return jax.lax.dot_general(
        qx, qw, (((qx.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=acc_dtype)


def pack_weights_int8(params, min_size: int = 1 << 12):
    """Pack every 2D+ float weight as {q: int8, s: f32 per-out-channel} —
    the deployed form of the paper's streamlined integer graph (weight-only
    W8): HBM weight traffic halves vs bf16 and the integer MatMul kernel
    consumes q directly.  Small tensors (norms, biases) stay float."""
    PACKABLE = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
                "in_proj", "out_proj", "lm_head")

    def pack(path, w):
        keys = [str(getattr(k, "key", k)) for k in path]
        if not keys or keys[-1] not in PACKABLE:
            return w
        if w.ndim < 2 or w.size < min_size or \
                w.dtype not in (jnp.float32, jnp.bfloat16):
            return w
        # per-output-channel scale over the fan-in axis only, so stacked
        # (L, d, m) layer weights keep their leading scan axis
        wf = w.astype(jnp.float32)
        sc = jnp.maximum(jnp.abs(wf).max(axis=-2, keepdims=True) / 127.0,
                         1e-8)
        q = jnp.clip(jnp.round(wf / sc), -128, 127).astype(jnp.int8)
        return {"q": q, "s": sc.astype(jnp.float32)}

    return jax.tree_util.tree_map_with_path(
        lambda kp, w: pack(kp, w), params)
