"""PTQ calibration (paper §2.1): derive quantizer scales from data.

Min/max and percentile calibrators over activation batches, plus a helper
that freezes dynamic QAT activation quantizers into static ones so the
graph becomes fully static for SIRA analysis and integer serving.
"""
from __future__ import annotations

from typing import Dict, Iterable, Tuple

import numpy as np

from .quantizer import QuantSpec


class MinMaxObserver:
    def __init__(self, spec: QuantSpec):
        self.spec = spec
        self.lo: float | None = None
        self.hi: float | None = None

    def update(self, x) -> None:
        x = np.asarray(x)
        lo, hi = float(x.min()), float(x.max())
        self.lo = lo if self.lo is None else min(self.lo, lo)
        self.hi = hi if self.hi is None else max(self.hi, hi)

    def scale_zp(self) -> Tuple[np.ndarray, np.ndarray]:
        spec = self.spec
        assert self.lo is not None, "observer saw no data"
        if spec.symmetric:
            amax = max(abs(self.lo), abs(self.hi), 1e-8)
            s = amax / spec.qmax
            return np.asarray(s), np.zeros(())
        s = max((self.hi - self.lo) / (spec.qmax - spec.qmin), 1e-8)
        z = round(spec.qmin - self.lo / s)
        return np.asarray(s), np.asarray(float(z))


class PercentileObserver(MinMaxObserver):
    """Clips calibration range to the [p, 100-p] percentile — robust to
    activation outliers (common for transformer activations)."""

    def __init__(self, spec: QuantSpec, percentile: float = 0.01):
        super().__init__(spec)
        self.p = percentile
        self._samples: list = []

    def update(self, x) -> None:
        x = np.asarray(x).ravel()
        if x.size > 65536:
            idx = np.random.default_rng(0).choice(x.size, 65536,
                                                  replace=False)
            x = x[idx]
        self._samples.append(x)
        lo = float(np.percentile(np.concatenate(self._samples), self.p))
        hi = float(np.percentile(np.concatenate(self._samples),
                                 100.0 - self.p))
        self.lo, self.hi = lo, hi


def calibrate_model(apply_fn, params, batches: Iterable,
                    taps: Iterable[str], spec: QuantSpec,
                    observer_cls=MinMaxObserver) -> Dict[str, Tuple]:
    """Run ``apply_fn(params, batch) -> dict(tap -> activation)`` over the
    calibration set and return {tap: (scale, zero_point)}."""
    obs = {t: observer_cls(spec) for t in taps}
    for batch in batches:
        acts = apply_fn(params, batch)
        for t in taps:
            obs[t].update(acts[t])
    return {t: o.scale_zp() for t, o in obs.items()}
