"""Quantization substrate: uniform affine quantizers, QAT/PTQ, integer ops."""
from .quantizer import (QuantSpec, compute_scale, quantize_int, dequantize,  # noqa: F401
                        fake_quant, fake_quant_dynamic, to_int_dtype,
                        int_matmul)
from .calibrate import (MinMaxObserver, PercentileObserver,  # noqa: F401
                        calibrate_model)
