"""Serving engine: continuous batching over a SIRA-quantized paged KV
cache (full-context attention families), with a static-batch fallback for
model families whose state cannot be paged (SSM/hybrid recurrent state,
sliding-window rolling caches).

Paged mode (the default wherever ``model.supports_paged``):

* prompts are prefilled in **jitted multi-token chunks** — one call per
  ``prefill_chunk`` tokens (B=1), not one call per token;
* decode runs one jitted call per step over *all* slots with per-slot
  cache lengths — requests at different positions batch together;
* the scheduler admits from a FIFO queue into freed slots between steps,
  terminates per request (EOS / max_new_tokens), and preempts the newest
  request when the page pool runs dry;
* KV storage is int8 with per-layer/per-head scales derived from SIRA
  range analysis of the exported K/V projection graph
  (``kv_cache.derive_kv_spec``), fp fallback per layer.

Sampling is vectorized (one ``jax.random.categorical`` over the batch via
vmap, per-request temperature) and deterministic per request: the key is
``fold_in(fold_in(seed, request_id), token_index)``, so a request draws
the same tokens whether it is served alone or packed with others.

Speculative decoding (``spec_decode=``) amortizes the per-step dispatch
cost of the decode loop: a pluggable drafter proposes up to ``spec_k``
tokens per slot, one jitted ``decode_paged`` call over (slots, spec_k+1)
verifies them all against the target model (the same multi-token path
chunked prefill uses), accepted prefixes commit to the paged cache and
rejected suffixes roll back via the per-slot length pointers — pages
stay allocated, no pool churn.  Because sampling is a deterministic
function of (seed, request_id, token index, logits), acceptance is exact
at any temperature: the emitted stream is bit-identical to per-token
decoding, speculation only changes how many jitted steps it takes.

Prefix caching (``ServingConfig(prefix_cache=True)``) makes prefill
incremental across requests: the engine attaches the longest cached
prefix of each new prompt (copy-on-write shared pages, see
``kv_cache.PagedKVCache``) and recomputes only the suffix — emitted
tokens are bit-identical to a cold prefill because the shared pages hold
exactly the KV the slot would have recomputed.  A ``mesh`` on the config
shards params and the KV page pools (KV-head dim over the "model" axis)
and runs every jitted call under the mesh context for tensor-parallel
decode.
"""
from __future__ import annotations

import contextlib
import warnings
from typing import List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import Model
from repro.obs.trace import get_tracer
from repro.quant.quantizer import QuantSpec

from .config import ServingConfig
from .draft import DraftProposer, get_drafter
from .kv_cache import KVCacheSpec, PagedKVCache, derive_kv_spec
from .metrics import ServingMetrics
from .scheduler import Request, Scheduler

_SENTINEL = object()


def _legacy_config(batch_slots, max_seq, quant, seed, kw) -> ServingConfig:
    """Build a ServingConfig from the pre-config loose kwargs."""
    fields = dict(batch_slots=batch_slots, max_seq=max_seq,
                  quant=quant, seed=0 if seed is _SENTINEL else seed)
    for k, v in kw.items():
        if v is not _SENTINEL:
            fields[k] = v
    return ServingConfig(**fields)


class ServingEngine:
    def __init__(self, model: Model, params,
                 config: Union[ServingConfig, int, None] = None,
                 max_seq: Optional[int] = None,
                 quant: Optional[QuantSpec] = None,
                 seed=_SENTINEL, *,
                 batch_slots: Optional[int] = None,
                 kv_cache=_SENTINEL, page_size=_SENTINEL,
                 prefill_chunk=_SENTINEL, num_pages=_SENTINEL,
                 mode=_SENTINEL, spec_decode=_SENTINEL,
                 spec_k=_SENTINEL):
        """Preferred: ``ServingEngine(model, params, ServingConfig(...))``
        — every knob lives on :class:`ServingConfig`, validated there.

        The pre-config surface (``batch_slots``/``max_seq`` positional or
        keyword, loose ``kv_cache=…``/``page_size=…``/… kwargs) still
        works through a shim that assembles the equivalent config and
        emits one ``DeprecationWarning`` per construction."""
        legacy_kw = dict(kv_cache=kv_cache, page_size=page_size,
                         prefill_chunk=prefill_chunk, num_pages=num_pages,
                         mode=mode, spec_decode=spec_decode, spec_k=spec_k)
        if isinstance(config, ServingConfig):
            if (max_seq is not None or quant is not None or
                    seed is not _SENTINEL or batch_slots is not None or
                    any(v is not _SENTINEL for v in legacy_kw.values())):
                raise TypeError(
                    "pass every option on the ServingConfig — mixing a "
                    "config with loose legacy kwargs is ambiguous")
            cfg = config
        else:
            if isinstance(config, int):          # legacy positional
                if batch_slots is not None:
                    raise TypeError("batch_slots given twice")
                batch_slots = config
            elif config is not None:
                raise TypeError(
                    f"third argument must be a ServingConfig (or the "
                    f"legacy batch_slots int), got {type(config).__name__}")
            if batch_slots is None or max_seq is None:
                raise TypeError(
                    "ServingEngine needs a ServingConfig (or legacy "
                    "batch_slots + max_seq)")
            warnings.warn(
                "loose ServingEngine(...) kwargs are deprecated — "
                "construct a repro.serve.ServingConfig and pass it as "
                "the third argument",
                DeprecationWarning, stacklevel=2)
            cfg = _legacy_config(batch_slots, max_seq, quant, seed,
                                 legacy_kw)

        self.config = cfg
        self.model = model
        self.B = cfg.batch_slots
        self.S = cfg.max_seq
        self.quant = cfg.quant
        self.seed = seed = cfg.seed
        self.prefill_chunk = cfg.prefill_chunk
        self.mesh = cfg.mesh
        quant = cfg.quant
        if self.mesh is not None:
            from repro.launch.shardings import named, param_pspecs
            params = jax.device_put(
                params, named(self.mesh, param_pspecs(params), params))
        self.params = params
        mode = cfg.mode
        if mode is None:
            mode = "paged" if model.supports_paged else "static"
        if mode == "paged" and not model.supports_paged:
            raise NotImplementedError(
                f"paged serving needs full-context attention — "
                f"family={model.cfg.family!r} "
                f"sliding_window={model.cfg.sliding_window}")
        self.mode = mode
        if cfg.spec_decode is not None and mode != "paged":
            raise NotImplementedError(
                "speculative decoding requires paged mode (the static "
                "engine has no per-slot length pointers to roll back)")
        self.drafter: Optional[DraftProposer] = (
            get_drafter(cfg.spec_decode)
            if isinstance(cfg.spec_decode, str) else cfg.spec_decode)
        self.spec_k = cfg.spec_k
        if mode == "static" and cfg.kv_cache != "fp":
            raise ValueError(
                "static mode serves a full-precision cache — a quantized "
                "kv_cache would be silently ignored")
        if mode == "static" and cfg.prefix_cache:
            raise ValueError(
                "prefix_cache requires paged mode (the static engine "
                "has no page table to share)")

        def sample(logits, temps, rids, steps):
            lg = logits.astype(jnp.float32)
            greedy = jnp.argmax(lg, axis=-1)

            def one(rid, step, row, temp):
                key = jax.random.fold_in(
                    jax.random.fold_in(jax.random.PRNGKey(seed), rid), step)
                return jax.random.categorical(
                    key, row / jnp.maximum(temp, 1e-6))

            sampled = jax.vmap(one)(rids, steps, lg, temps)
            return jnp.where(temps > 0, sampled, greedy).astype(jnp.int32)

        self._sample_fn = jax.jit(sample)

        if mode == "paged":
            mcfg = model.cfg
            if isinstance(cfg.kv_cache, KVCacheSpec):
                spec = cfg.kv_cache
            elif cfg.kv_cache == "fp":
                spec = KVCacheSpec.all_fp(mcfg.n_layers)
            else:                       # "sira-int8" / "int8" (validated)
                spec = derive_kv_spec(model, params)
            self.kv_spec = spec
            pool_sharding = None
            if self.mesh is not None:
                from repro.launch.shardings import kv_pool_sharding
                pool_sharding = kv_pool_sharding(self.mesh,
                                                 mcfg.n_kv_heads)
            self.cache = PagedKVCache(mcfg, spec, cfg.batch_slots,
                                      cfg.max_seq,
                                      page_size=cfg.page_size,
                                      num_pages=cfg.num_pages,
                                      prefix_cache=cfg.prefix_cache,
                                      sharding=pool_sharding)
            self.metrics = ServingMetrics()
            self.scheduler = Scheduler(cfg.batch_slots, cfg.max_seq,
                                       self.cache, self.metrics)
            kv_scales = spec.scales()
            page_size = cfg.page_size
            self._step_fn = jax.jit(
                lambda p, t, pages, table, lens: model.decode_paged(
                    p, t, pages, table, lens, page_size=page_size,
                    quant=quant, kv_scales=kv_scales))
        else:
            self._decode = jax.jit(
                lambda p, t, c, i, v: model.decode_step(
                    p, t, c, i, quant=quant, valid_from=v))

    def _mesh_scope(self):
        """Mesh context for jitted calls — activates the in-model
        ``shard()`` constraints; a no-op without a configured mesh."""
        if self.mesh is None:
            return contextlib.nullcontext()
        from repro.launch.mesh import use_mesh
        return use_mesh(self.mesh)

    # ------------------------------------------------------- paged mode
    def submit(self, request: Request) -> int:
        """Queue a request; returns its request id (also its PRNG id)."""
        if self.mode != "paged":
            raise NotImplementedError("submit() requires paged mode")
        return self.scheduler.submit(request)

    def step(self) -> bool:
        """One scheduler iteration: admit + prefill new requests, then one
        batched decode step.  Returns False when there is nothing to do."""
        if self.mode != "paged":
            raise NotImplementedError("step() requires paged mode")
        sched = self.scheduler
        if not sched.has_work():
            return False
        for slot, entry in sched.admit():
            self._prefill(slot, entry)
        if self.drafter is not None:
            self._decode_spec()
        else:
            self._decode_once()
        return True

    def run(self) -> None:
        while self.step():
            pass

    def reset_metrics(self) -> None:
        """Fresh counters (e.g. after a jit warm-up run)."""
        if self.mode != "paged":
            raise NotImplementedError("metrics require paged mode")
        self.metrics = ServingMetrics()
        self.scheduler.metrics = self.metrics

    def _prefill(self, slot: int, entry) -> None:
        """Chunked jitted multi-token prefill of one slot (B=1): one
        ``decode_paged`` call per ``prefill_chunk`` tokens, then sample
        the first continuation token from the last prompt position.

        With prefix caching the slot first attaches the longest cached
        prefix of its sequence (shared pages, refcounted; the mid-page
        boundary copied) and prefill recomputes only the suffix — same
        logits, fewer chunks.  After the prompt is in the cache its full
        pages are registered for the next request to attach."""
        seq = entry.seq
        L = len(seq)
        C = self.prefill_chunk
        cached = self.cache.attach_prefix(slot, seq)
        # defensive: every page at/above the recompute frontier must be
        # private before prefill writes land (no-op by construction —
        # attach copies the boundary page)
        assert self.cache.prepare_write(slot, cached)
        if self.cache.prefix_cache_enabled:
            self.metrics.on_prefix_lookup(cached, L)
        table = self.cache.slot_table(slot)
        logits = None
        tr = get_tracer()
        with tr.span("serve:prefill", slot=slot, prompt_tokens=L,
                     chunk=C, cached_tokens=cached):
            for start in range(cached, L, C):
                chunk = seq[start:start + C]
                toks = np.zeros((1, C), np.int32)
                toks[0, :len(chunk)] = chunk
                with tr.span("serve:prefill_chunk", start=start):
                    with self._mesh_scope():
                        logits, pages = self._step_fn(
                            self.params, jnp.asarray(toks),
                            self.cache.pages, table,
                            jnp.full((1,), start, jnp.int32))
                self.cache.pages = pages
                self.metrics.on_prefill_chunk()
        self.scheduler.set_prefilled(slot, L)
        # register before the first record_token: a request finishing on
        # its very first token releases the slot right there, and only
        # registered pages park in the reuse LRU
        self.cache.register_prefix(slot, seq[:len(entry.request.prompt)])

        req = entry.request
        last = (L - 1 - cached) % C    # last prompt token in final chunk
        with self._mesh_scope():
            tok = self._sample_fn(
                logits[:, last],
                jnp.full((1,), req.temperature, jnp.float32),
                jnp.full((1,), entry.prng_id, jnp.int32),
                jnp.full((1,), entry.n_generated, jnp.int32))
        handle = entry.handle
        done = self.scheduler.record_token(slot, int(np.asarray(tok)[0]))
        self.metrics.on_token(handle)
        if done:
            self.metrics.on_finish(handle)

    def _grow_for_step(self, proposals=None) -> None:
        """Map page capacity for this step's per-slot write window.

        Every slot must map the write position ``length`` (per-token) or
        the verify window ``[length, length + spec_k + 1)`` when it has
        proposals.  A window that cannot be mapped drops its proposals
        (``proposals[i]`` cleared in place) before anyone is preempted —
        speculation never evicts a victim.  When even one token cannot
        be mapped, the newest-admitted request is preempted (possibly
        the needy slot itself)."""
        sched = self.scheduler
        for i in sorted(sched.active_slots(),
                        key=lambda i: sched.slots[i].admit_seq):
            while True:
                st = sched.slots[i]
                if st is None:          # lost its slot as preemption victim
                    break
                props = proposals.get(i) if proposals else None
                if props and self.cache.reserve(
                        i, st.length + 1 + self.spec_k) and \
                        self.cache.prepare_write(i, st.length):
                    break
                if props:
                    proposals[i] = []
                if self.cache.grow(i, st.length + 1) and \
                        self.cache.prepare_write(i, st.length):
                    break
                sched.preempt(sched.newest_active())

    def _decode_once(self) -> None:
        sched = self.scheduler
        self._grow_for_step()
        active = sched.active_slots()
        if not active:
            return
        with get_tracer().span("serve:decode_step", active=len(active),
                               batch_slots=self.B):
            self._decode_once_inner(sched, active)

    def _decode_once_inner(self, sched, active) -> None:
        B = self.B
        toks = np.zeros((B,), np.int32)
        lens = np.zeros((B,), np.int32)
        temps = np.zeros((B,), np.float32)
        rids = np.zeros((B,), np.int32)
        steps = np.zeros((B,), np.int32)
        for i in active:
            st = sched.slots[i]
            toks[i] = st.entry.seq[-1]       # sampled, not yet cached
            lens[i] = st.length
            temps[i] = st.entry.request.temperature
            rids[i] = st.entry.prng_id
            steps[i] = st.entry.n_generated
        with self._mesh_scope():
            logits, pages = self._step_fn(
                self.params, jnp.asarray(toks)[:, None], self.cache.pages,
                self.cache.device_table(), jnp.asarray(lens))
            self.cache.pages = pages
            nxt = np.asarray(self._sample_fn(
                logits[:, -1], jnp.asarray(temps), jnp.asarray(rids),
                jnp.asarray(steps)))
        self.metrics.on_decode_step(len(active), B, tokens=len(active))
        for i in active:
            sched.note_cache_write(i)
            handle = sched.slots[i].entry.handle
            done = sched.record_token(i, int(nxt[i]))
            self.metrics.on_token(handle)
            if done:
                self.metrics.on_finish(handle)

    # -------------------------------------------------- speculative decode
    def _decode_spec(self) -> None:
        """One speculative decode step: propose, verify in a single
        jitted (slots, spec_k+1) call, commit accepted prefixes, roll
        back rejected suffixes.

        Per slot, the input row is ``[pending, d_1 .. d_m, pad]`` at
        positions ``length .. length+spec_k``.  ``logits[:, t]`` predicts
        the token after position ``length+t``, so draft ``d_{t+1}`` is
        accepted iff it equals the token the engine would sample from
        ``logits[:, t]`` at token index ``n_generated + t`` — the exact
        per-token stream at any temperature.  The first mismatch yields
        the corrected token; full acceptance yields a bonus token from
        the last position.  Cache commits ``1 + accepted`` positions
        (pending + accepted drafts); the rest is rolled back by leaving
        the per-slot length pointer behind (pages stay allocated).

        A slot whose drafter proposes nothing rides along with an all-pad
        tail; when *no* slot has proposals the step degrades to the
        per-token path (identical tokens, narrower jitted call).
        """
        sched = self.scheduler
        k = self.spec_k
        proposals = {}
        for i in sched.active_slots():
            e = sched.slots[i].entry
            remaining = e.request.max_new_tokens - e.n_generated
            want = min(k, remaining - 1)   # last token never needs a draft
            props = (self.drafter.propose(e.seq, want, e.prng_id)
                     if want > 0 else [])
            proposals[i] = [int(t) for t in props][:want]
        if not any(proposals.values()):
            self._decode_once()            # PR 3 path, bit-identical
            return

        T = k + 1
        self._grow_for_step(proposals)
        active = sched.active_slots()
        if not active:
            return
        with get_tracer().span("serve:spec_verify", active=len(active),
                               spec_k=k) as sp:
            self._verify_window(sched, proposals, active, T, sp)

    def _verify_window(self, sched, proposals, active, T, sp) -> None:
        B = self.B
        toks = np.zeros((B, T), np.int32)
        lens = np.zeros((B,), np.int32)
        for i in active:
            st = sched.slots[i]
            row = [st.entry.seq[-1]] + proposals.get(i, [])
            toks[i, :len(row)] = row
            lens[i] = st.length
        with self._mesh_scope():
            logits, pages = self._step_fn(
                self.params, jnp.asarray(toks), self.cache.pages,
                self.cache.device_table(), jnp.asarray(lens))
        self.cache.pages = pages

        # sample every verify position in one vectorized call: row (i, t)
        # uses the same (seed, request_id, token index) key the per-token
        # path would, so acceptance == equality with the exact stream.
        # All B*T rows are sampled (idle slots discarded) so the jitted
        # sampler sees one stable shape — per-active-count shapes would
        # retrace on every queue-depth change and dwarf the verify call.
        temps = np.zeros((B * T,), np.float32)
        rids = np.zeros((B * T,), np.int32)
        steps = np.zeros((B * T,), np.int32)
        for i in active:
            e = sched.slots[i].entry
            temps[i * T:(i + 1) * T] = e.request.temperature
            rids[i * T:(i + 1) * T] = e.prng_id
            steps[i * T:(i + 1) * T] = e.n_generated + np.arange(T)
        sampled = np.asarray(self._sample_fn(
            logits.reshape(B * T, -1), jnp.asarray(temps),
            jnp.asarray(rids), jnp.asarray(steps))).reshape(B, T)

        emitted_total = proposed = accepted_total = 0
        for i in active:
            props = proposals.get(i, [])
            exp = sampled[i]
            a = 0
            while a < len(props) and props[a] == int(exp[a]):
                a += 1
            # emit accepted drafts + the correction/bonus token;
            # record_tokens stops at EOS / max_new_tokens inside the
            # window (slot + pages freed there, tail discarded)
            emitted = [int(t) for t in exp[:a + 1]]
            handle = sched.slots[i].entry.handle
            n_rec, done = sched.record_tokens(i, emitted)
            for _ in range(n_rec):
                self.metrics.on_token(handle)
            emitted_total += n_rec
            proposed += len(props)
            # drafts accepted AND emitted — an EOS/max_new termination
            # inside the window discards the tail, which must not count
            # toward the acceptance rate
            accepted_total += min(a, n_rec)
            if done:
                self.metrics.on_finish(handle)
            else:
                sched.advance(i, 1 + a)          # pending + accepted
                self.cache.rollback(i, sched.slots[i].length)
                self.drafter.observe(sched.slots[i].entry.seq,
                                     sched.slots[i].entry.prng_id)
        self.metrics.on_decode_step(len(active), B, tokens=emitted_total)
        self.metrics.on_spec_step(proposed, accepted_total)
        sp.set_attr("proposed", proposed)
        sp.set_attr("accepted", accepted_total)
        sp.set_attr("emitted", emitted_total)

    # ---------------------------------------------------------- generate
    def generate(self, requests: List[Request]) -> List[List[int]]:
        """Serve requests to completion; outputs in submission order.

        Paged mode accepts any number of requests (the queue can be
        deeper than ``batch_slots``); static mode keeps the fixed-batch
        contract of the pre-scheduler engine."""
        if self.mode == "paged":
            rids = [self.submit(r) for r in requests]
            self.run()
            return [self.scheduler.outputs[rid] for rid in rids]
        return self._generate_static(requests)

    # ------------------------------------------------------ static mode
    def _generate_static(self, requests: List[Request]) -> List[List[int]]:
        """Static-batch fallback (≤ batch_slots requests, no paging).

        Prompts are left-padded to a common length; ``valid_from`` masks
        pad slots out of attention and shifts RoPE per slot, so each row
        computes exactly what it would when served alone.  Mixed-length
        batches are rejected for model families where pad tokens cannot
        be masked retroactively (SSM/hybrid state updates, sliding-window
        rolling caches).  Finished rows (EOS / max_new_tokens) stop
        accumulating tokens and the loop exits once every row is done."""
        assert len(requests) <= self.B
        outs: List[List[int]] = [[] for _ in requests]
        L = max(len(r.prompt) for r in requests)
        # rows are padded to a common prompt length, so the cache must
        # hold the padded prompt plus the largest per-request budget
        # (dynamic_update_slice would silently clamp out-of-range writes)
        need = L + max(r.max_new_tokens for r in requests)
        if need > self.S:
            raise ValueError(
                f"padded prompt ({L}) + max_new_tokens exceeds "
                f"max_seq {self.S} (need {need})")
        needs_mask = any(len(r.prompt) != L for r in requests)
        cfg = self.model.cfg
        if needs_mask and (cfg.sliding_window or
                           cfg.family in ("ssm", "hybrid")):
            raise NotImplementedError(
                f"mixed-length batches are not supported for "
                f"family={cfg.family!r} sliding_window={cfg.sliding_window}"
                f" — pad-token masking only covers full-context attention")
        cache = self.model.init_cache(self.B, self.S)
        toks = np.zeros((self.B, L), np.int32)
        valid = np.zeros((self.B,), np.int32)
        for i, r in enumerate(requests):
            toks[i, L - len(r.prompt):] = r.prompt   # left-pad
            valid[i] = L - len(r.prompt)             # first real slot
        valid_from = jnp.asarray(valid) if needs_mask else None
        logits = None
        with self._mesh_scope():
            for t in range(L):
                logits, cache = self._decode(
                    self.params, jnp.asarray(toks[:, t:t + 1]), cache,
                    jnp.asarray(t, jnp.int32), valid_from)

        n = len(requests)
        temps = np.zeros((self.B,), np.float32)
        rids = np.zeros((self.B,), np.int32)
        for i, r in enumerate(requests):
            temps[i] = r.temperature
            rids[i] = i if r.request_id is None else r.request_id
        temps_j, rids_j = jnp.asarray(temps), jnp.asarray(rids)
        done = np.array([False] * self.B)
        done[n:] = True
        steps = np.zeros((self.B,), np.int32)

        def sample(lg):
            return np.asarray(self._sample_fn(
                lg[:, -1], temps_j, rids_j, jnp.asarray(steps)))

        cur = sample(logits)
        for i, r in enumerate(requests):
            outs[i].append(int(cur[i]))
            steps[i] = 1
            if r.max_new_tokens <= 1 or (r.eos_id is not None and
                                         cur[i] == r.eos_id):
                done[i] = True
        step = 1
        while not done.all():
            with self._mesh_scope():
                logits, cache = self._decode(
                    self.params, jnp.asarray(cur).reshape(self.B, 1),
                    cache, jnp.asarray(L + step - 1, jnp.int32),
                    valid_from)
            cur = sample(logits)
            for i, r in enumerate(requests):
                if done[i]:
                    continue
                outs[i].append(int(cur[i]))
                steps[i] += 1
                if steps[i] >= r.max_new_tokens or (
                        r.eos_id is not None and cur[i] == r.eos_id):
                    done[i] = True
            step += 1
        return outs
