"""Batched serving engine: prefill + KV-cached decode with continuous
request slots.

The engine keeps a fixed pool of batch slots; finished sequences free
their slot for the next queued request (continuous batching at step
granularity).  Sampling: greedy or temperature.  The quantized path runs
the model with QAT fake-quant (matching the SIRA-analyzed integer graph).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import Model
from repro.quant.quantizer import QuantSpec


@dataclasses.dataclass
class Request:
    prompt: np.ndarray             # (S_prompt,)
    max_new_tokens: int = 32
    temperature: float = 0.0
    out_tokens: Optional[List[int]] = None


class ServingEngine:
    def __init__(self, model: Model, params, batch_slots: int,
                 max_seq: int, quant: Optional[QuantSpec] = None,
                 seed: int = 0):
        self.model = model
        self.params = params
        self.B = batch_slots
        self.S = max_seq
        self.quant = quant
        self.rng = jax.random.PRNGKey(seed)

        self._decode = jax.jit(
            lambda p, t, c, i, v: model.decode_step(p, t, c, i,
                                                    quant=quant,
                                                    valid_from=v))

    def generate(self, requests: List[Request]) -> List[List[int]]:
        """Serve a batch of ≤ batch_slots requests to completion.

        Prompts are left-padded to a common length so every request's
        last prompt token lands on the same decode step.  The pad slots
        do get decoded into the KV cache, but ``valid_from`` masks them
        out of every attention read and shifts RoPE positions per slot,
        so each row computes exactly what it would when served alone.
        Mixed-length batches are rejected for model families where pad
        tokens cannot be masked retroactively (SSM/hybrid state updates,
        sliding-window rolling caches)."""
        assert len(requests) <= self.B
        outs: List[List[int]] = [[] for _ in requests]
        L = max(len(r.prompt) for r in requests)
        needs_mask = any(len(r.prompt) != L for r in requests)
        cfg = self.model.cfg
        if needs_mask and (cfg.sliding_window or
                           cfg.family in ("ssm", "hybrid")):
            # rolling local caches and SSM state updates cannot mask pad
            # tokens out retroactively — refuse rather than silently
            # serve corrupted shorter prompts
            raise NotImplementedError(
                f"mixed-length batches are not supported for "
                f"family={cfg.family!r} sliding_window={cfg.sliding_window}"
                f" — pad-token masking only covers full-context attention")
        cache = self.model.init_cache(self.B, self.S)
        toks = np.zeros((self.B, L), np.int32)
        valid = np.zeros((self.B,), np.int32)
        for i, r in enumerate(requests):
            toks[i, L - len(r.prompt):] = r.prompt   # left-pad
            valid[i] = L - len(r.prompt)             # first real slot
        valid_from = jnp.asarray(valid) if needs_mask else None
        logits = None
        for t in range(L):
            logits, cache = self._decode(
                self.params, jnp.asarray(toks[:, t:t + 1]), cache,
                jnp.asarray(t, jnp.int32), valid_from)
        max_new = max(r.max_new_tokens for r in requests)
        cur = self._sample(logits, requests)
        for i, r in enumerate(requests):
            outs[i].append(int(cur[i]))
        for step in range(1, max_new):
            logits, cache = self._decode(
                self.params, jnp.asarray(cur).reshape(self.B, 1), cache,
                jnp.asarray(L + step - 1, jnp.int32), valid_from)
            cur = self._sample(logits, requests)
            for i, r in enumerate(requests):
                if step < r.max_new_tokens:
                    outs[i].append(int(cur[i]))
        return outs

    def _sample(self, logits, requests) -> np.ndarray:
        lg = np.asarray(logits[:, -1].astype(jnp.float32))
        out = np.zeros((self.B,), np.int32)
        for i, r in enumerate(requests):
            if r.temperature <= 0:
                out[i] = int(lg[i].argmax())
            else:
                self.rng, k = jax.random.split(self.rng)
                out[i] = int(jax.random.categorical(
                    k, jnp.asarray(lg[i] / r.temperature)))
        return out
