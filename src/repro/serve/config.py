"""Validated serving configuration.

``ServingConfig`` consolidates the engine's construction knobs — slot
count, cache geometry, quantized-KV selection, speculative decoding,
prefix caching and sharding — into one frozen dataclass validated at
construction, so a bad combination fails at config time with a message
naming the field, not deep inside the first jitted step.

    from repro.serve import ServingConfig, ServingEngine
    cfg = ServingConfig(batch_slots=16, max_seq=64, kv_cache="sira-int8",
                        prefix_cache=True)
    eng = ServingEngine(model, params, cfg)

The legacy loose-kwarg constructor (``ServingEngine(model, params,
batch_slots=2, max_seq=64, page_size=8, ...)``) still works via a shim
that builds a ``ServingConfig`` and emits a single ``DeprecationWarning``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Union

from repro.quant.quantizer import QuantSpec

from .kv_cache import KVCacheSpec

_MODES = (None, "paged", "static")
_KV_STRINGS = ("fp", "sira-int8", "int8")


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Everything ``ServingEngine`` needs beyond (model, params).

    Engine shape:

    * ``batch_slots`` — concurrent decode slots (the batch dimension).
    * ``max_seq`` — per-request prompt + generation budget.
    * ``mode`` — None (auto: paged wherever ``model.supports_paged``),
      "paged", or "static".

    Cache:

    * ``kv_cache`` — "fp", "sira-int8" (scales derived at engine
      construction), or a prebuilt :class:`KVCacheSpec`.
    * ``page_size`` / ``num_pages`` — pool geometry (num_pages=None sizes
      for the worst case: every slot full, plus the trash page).
    * ``prefix_cache`` — copy-on-write prompt-prefix sharing: full prompt
      pages are content-hashed and reused across requests (refcounted,
      fork-on-write), and pages released by finished requests are kept in
      an LRU so repeat traffic skips prefill for the shared head.

    Sampling / speculation:

    * ``quant`` — activation fake-quant spec threaded into the jitted
      step (weights come quantized inside ``params``).
    * ``seed`` — engine PRNG seed (per-request keys fold in request_id
      and token index).
    * ``spec_decode`` / ``spec_k`` — draft proposer (name or instance)
      and max drafts verified per step.

    Scale-out:

    * ``mesh`` — a ``jax.sharding.Mesh``; params and the KV page pools
      are placed with the ``launch.shardings`` rules (KV-head dim of
      every pool over the "model" axis) and every jitted call runs under
      the mesh context so in-model ``shard()`` constraints activate.
    """
    batch_slots: int
    max_seq: int
    quant: Optional[QuantSpec] = None
    seed: int = 0
    kv_cache: Union[str, KVCacheSpec] = "fp"
    page_size: int = 8
    prefill_chunk: int = 8
    num_pages: Optional[int] = None
    mode: Optional[str] = None
    spec_decode: Any = None
    spec_k: int = 4
    prefix_cache: bool = False
    mesh: Any = None

    def __post_init__(self) -> None:
        if self.batch_slots < 1:
            raise ValueError("batch_slots must be >= 1")
        if self.max_seq < 1:
            raise ValueError("max_seq must be >= 1")
        if self.page_size < 1:
            raise ValueError("page_size must be >= 1")
        if self.prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        if self.spec_k < 1:
            raise ValueError("spec_k must be >= 1")
        if self.mode not in _MODES:
            raise ValueError(f"mode must be one of {_MODES}, "
                             f"got {self.mode!r}")
        if not isinstance(self.kv_cache, KVCacheSpec) and \
                self.kv_cache not in _KV_STRINGS:
            raise ValueError(
                f"kv_cache must be one of {_KV_STRINGS} or a KVCacheSpec, "
                f"got {self.kv_cache!r}")
        if self.num_pages is not None and self.num_pages < 2:
            raise ValueError("num_pages must leave room for the trash "
                             "page plus at least one real page")
        if self.mode == "static":
            if self.kv_cache != "fp":
                raise ValueError(
                    "static mode serves a full-precision cache — a "
                    "quantized kv_cache would be silently ignored")
            if self.prefix_cache:
                raise ValueError(
                    "prefix_cache requires paged mode (the static engine "
                    "has no page table to share)")
        if self.mesh is not None and not hasattr(self.mesh, "axis_names"):
            raise ValueError("mesh must be a jax.sharding.Mesh")

    def replace(self, **kw) -> "ServingConfig":
        return dataclasses.replace(self, **kw)
