"""Paged KV cache with SIRA-derived scaled-integer storage.

Two halves:

* **Spec derivation** (`derive_kv_spec`): for every attention layer,
  export the K/V projection subgraph with the *actual serving weights*
  (`models.export.export_kv_proj_graph`) and run the SIRA range analysis
  (`core.propagate.analyze`) over it.  The per-output-channel value
  intervals of the K/V tensors reduce to per-KV-head amax bounds (K is
  widened by sqrt(2) for the RoPE rotation hull), giving int8 storage
  scales with a *static coverage guarantee* — saturation can only trigger
  on activations that escape their proven range (A2Q-style, Colbert et
  al. 2023).  A layer falls back to full-precision storage when its bound
  is non-finite or so wide that the int8 step exceeds ``max_step``
  (resolution cliff).  Optionally, per-layer `MinMaxObserver`s
  (`quant.calibrate`) over real token batches tighten the analyzed input
  range from the default post-norm assumption.

* **Page pool** (`PagedKVCache`): fixed pool of physical pages per layer
  (device arrays), a host-side page table (slots x logical pages) and
  free list.  Slots own pages only for the tokens they actually hold;
  finished requests return pages to the pool immediately, which is what
  lets the scheduler admit a queue much deeper than ``batch_slots``
  without sizing HBM for the worst case.  Physical page 0 is reserved as
  the trash page: idle slots' writes land there and it is never mapped
  to a live position.

* **Copy-on-write prefix caching** (``prefix_cache=True``): prompt pages
  are content-addressed in a :class:`PrefixIndex` keyed by the *chain*
  of page token-tuples (a page's identity includes everything before
  it, so position is part of the key and RoPE'd keys stay valid).  A
  new request attaches the longest indexed chain instead of re-running
  prefill over it; attached pages are mapped by multiple slots with
  per-page refcounts.  Pages released by finished requests stay
  resident in an LRU of cached-free pages and are only reclaimed (and
  unindexed) when the free list runs dry — repeat traffic re-attaches
  them for near-zero-TTFT prefill.  Sharing is safe because a slot
  only ever writes at positions >= its own recompute frontier: the
  boundary page (where the new prompt diverges mid-page) is
  copy-on-written into the slot's private page at attach time, and
  ``prepare_write`` forks any other shared page before a write could
  land on it — so a speculative rollback on one request can never
  scribble on a page another request maps.
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.propagate import analyze
from repro.models.export import export_kv_proj_graph
from repro.quant.calibrate import MinMaxObserver
from repro.quant.quantizer import QuantSpec

# RoPE rotates channel pairs within a head: |k'| <= sqrt(k1^2 + k2^2)
# <= sqrt(2) * max(|k1|, |k2|), so a per-head pre-rotation amax bound
# widens by sqrt(2) to cover the stored (post-RoPE) keys.
ROPE_HULL = math.sqrt(2.0)


@dataclasses.dataclass(frozen=True)
class LayerKVSpec:
    """Storage decision for one attention layer's KV cache."""
    int8: bool
    k_scale: Optional[np.ndarray] = None    # (KV,) int8 step per head
    v_scale: Optional[np.ndarray] = None
    k_amax: Optional[np.ndarray] = None     # (KV,) proven |K| bound
    v_amax: Optional[np.ndarray] = None
    reason: str = ""                        # why fp fallback, if not int8


@dataclasses.dataclass(frozen=True)
class KVCacheSpec:
    """Per-layer storage plan for the paged cache."""
    layers: Tuple[LayerKVSpec, ...]

    @property
    def n_int8(self) -> int:
        return sum(1 for l in self.layers if l.int8)

    def scales(self) -> List[Optional[Tuple[np.ndarray, np.ndarray]]]:
        """Per-layer (k_scale, v_scale) for ``Model.decode_paged``."""
        return [(l.k_scale, l.v_scale) if l.int8 else None
                for l in self.layers]

    @staticmethod
    def all_fp(n_layers: int) -> "KVCacheSpec":
        return KVCacheSpec(tuple(LayerKVSpec(int8=False, reason="fp cache")
                                 for _ in range(n_layers)))


def _layer_weights(params, layer: int):
    """(Wk, Wv, bk, bv) of one stacked layer, dequantizing packed int8."""
    attn = params["layers"]["attn"]

    def get(w):
        w = jax.tree.map(lambda a, i=layer: a[i], w)
        if isinstance(w, dict):                  # packed {q: int8, s: f32}
            return np.asarray(w["q"], np.float64) * np.asarray(
                w["s"], np.float64)
        return np.asarray(w, np.float64)

    bk = get(attn["bk"]) if "bk" in attn else None
    bv = get(attn["bv"]) if "bv" in attn else None
    return get(attn["wk"]), get(attn["wv"]), bk, bv


def observe_block_inputs(model, params, token_batches: Iterable
                         ) -> List[Tuple[float, float]]:
    """Per-layer ``MinMaxObserver`` over the post-norm activations feeding
    the K/V projections, walked layer by layer on real token batches.

    Returns per-layer (lo, hi) to replace the default calibrated-range
    assumption in ``derive_kv_spec`` — calibration tightens the SIRA input
    interval; the propagation itself stays static and guaranteed.
    """
    from repro.models.common import rms_norm
    from repro.models.transformer import (_dense_layer_fwd, _moe_layer_fwd)

    cfg = model.cfg
    obs = [MinMaxObserver(QuantSpec(bits=8)) for _ in range(cfg.n_layers)]
    for toks in token_batches:
        x = model._embed(params, jnp.asarray(toks), None)
        for layer in range(cfg.n_layers):
            p = jax.tree.map(lambda a, i=layer: a[i], params["layers"])
            obs[layer].update(np.asarray(
                rms_norm(x, p["ln1"]).astype(jnp.float32)))
            if cfg.family == "moe":
                x, _ = _moe_layer_fwd(p, x, cfg)
            else:
                x = _dense_layer_fwd(p, x, cfg, window=0)
    return [(o.lo, o.hi) for o in obs]


def derive_kv_spec(model, params, *, x_range: Tuple[float, float] = (-4., 4.),
                   a_bits: int = 8, max_step: float = 0.5,
                   calib_token_batches: Optional[Iterable] = None,
                   domain: str = "interval") -> KVCacheSpec:
    """SIRA-derived per-layer/per-head int8 KV-cache scales.

    ``x_range`` is the assumed post-norm activation interval feeding the
    K/V projections (export.py convention); pass ``calib_token_batches``
    to replace it with per-layer observed ranges.  ``max_step`` is the
    fp-fallback threshold: a layer stays full-precision when its int8
    resolution (amax / 127) would exceed it.  ``domain`` selects the
    range-analysis abstract domain ("interval" or "affine"); the affine
    reduced product can only tighten the derived scales.
    """
    cfg = model.cfg
    KV, hd = cfg.n_kv_heads, cfg.hd
    ranges = ([tuple(map(float, r)) for r in
               observe_block_inputs(model, params, calib_token_batches)]
              if calib_token_batches is not None
              else [x_range] * cfg.n_layers)

    layers = []
    for layer in range(cfg.n_layers):
        Wk, Wv, bk, bv = _layer_weights(params, layer)
        lo, hi = ranges[layer]
        g, inputs = export_kv_proj_graph(Wk, Wv, bk=bk, bv=bv,
                                         x_lo=lo, x_hi=hi, a_bits=a_bits)
        r = analyze(g, inputs, domain=domain)

        def head_amax(rng, rope: bool) -> np.ndarray:
            amax = np.maximum(np.abs(np.asarray(rng.lo)),
                              np.abs(np.asarray(rng.hi)))
            amax = amax.reshape(KV, hd).max(axis=1)
            return amax * (ROPE_HULL if rope else 1.0)

        k_amax = head_amax(r["k_mm"], rope=True)
        v_amax = head_amax(r["v_mm"], rope=False)
        worst = float(max(k_amax.max(), v_amax.max()))
        if not np.isfinite(worst):
            layers.append(LayerKVSpec(int8=False, k_amax=k_amax,
                                      v_amax=v_amax,
                                      reason="non-finite SIRA bound"))
        elif worst / 127.0 > max_step:
            layers.append(LayerKVSpec(
                int8=False, k_amax=k_amax, v_amax=v_amax,
                reason=f"int8 step {worst / 127.0:.3g} > "
                       f"max_step {max_step:g}"))
        else:
            layers.append(LayerKVSpec(
                int8=True,
                k_scale=np.maximum(k_amax / 127.0, 1e-8),
                v_scale=np.maximum(v_amax / 127.0, 1e-8),
                k_amax=k_amax, v_amax=v_amax))
    return KVCacheSpec(tuple(layers))


_Key = Tuple  # (parent_key | None, page-token tuple) — recursive


class PrefixIndex:
    """Content-addressed index of full prompt pages for prefix sharing.

    A page is keyed by ``(parent_key, tokens)`` where ``parent_key`` is
    the key of the page before it (``None`` at position 0) and ``tokens``
    is the page's full token tuple.  Keying by chain rather than by page
    content alone makes position part of the identity — two requests
    share a page only when *everything* up to and including it is
    identical, which is exactly the condition under which the stored
    (RoPE-rotated, possibly int8-quantized) KV is bit-identical.
    """

    def __init__(self) -> None:
        self._page_of: Dict[_Key, int] = {}
        self._key_of: Dict[int, _Key] = {}
        self._kids: Dict[Optional[_Key], Set[_Key]] = {}

    def __len__(self) -> int:
        return len(self._page_of)

    def is_registered(self, page: int) -> bool:
        return page in self._key_of

    def lookup(self, chunks: Sequence[Tuple[int, ...]]) -> List[int]:
        """Physical pages of the longest indexed chain matching the
        per-page token chunks, in position order."""
        pages: List[int] = []
        parent: Optional[_Key] = None
        for chunk in chunks:
            key = (parent, chunk)
            pg = self._page_of.get(key)
            if pg is None:
                break
            pages.append(pg)
            parent = key
        return pages

    def partial_lookup(self, n_matched: int,
                       chunks: Sequence[Tuple[int, ...]],
                       tail: Tuple[int, ...]) -> Tuple[int, Optional[int]]:
        """Best mid-page overlap after ``n_matched`` fully-matched chunks:
        among the indexed children of the matched chain, the page whose
        token tuple shares the longest common prefix with ``tail``.
        Returns (overlap_tokens, physical_page | None)."""
        parent: Optional[_Key] = None
        for chunk in chunks[:n_matched]:
            parent = (parent, chunk)
        best_m, best_pg = 0, None
        for key in self._kids.get(parent, ()):
            chunk = key[1]
            m = 0
            while m < len(tail) and m < len(chunk) and tail[m] == chunk[m]:
                m += 1
            if m > best_m:
                best_m, best_pg = m, self._page_of[key]
        return best_m, best_pg

    def register(self, chunks: Sequence[Tuple[int, ...]],
                 pages: Sequence[int]) -> List[int]:
        """Walk the chain, adding nodes for chunks not yet indexed
        (existing nodes win — the walker's duplicate page stays private).
        Returns the pages newly registered."""
        parent: Optional[_Key] = None
        new: List[int] = []
        for chunk, pg in zip(chunks, pages):
            key = (parent, chunk)
            if key not in self._page_of:
                self._page_of[key] = pg
                self._key_of[pg] = key
                self._kids.setdefault(parent, set()).add(key)
                new.append(pg)
            parent = key
        return new

    def evict(self, page: int) -> List[int]:
        """Drop the node owning ``page`` and its entire subtree (children
        would be unreachable without their parent).  Returns every page
        whose registration was removed, ``page`` first."""
        key = self._key_of[page]
        parent = key[0]
        kids = self._kids.get(parent)
        if kids is not None:
            kids.discard(key)
        dropped: List[int] = []
        stack = [key]
        while stack:
            k = stack.pop()
            pg = self._page_of.pop(k)
            self._key_of.pop(pg, None)
            stack.extend(self._kids.pop(k, ()))
            dropped.append(pg)
        return dropped


class PagedKVCache:
    """Shared physical page pool + host-side page table / free list.

    Device state: per-layer {"k", "v"} pools of shape
    (num_pages, page_size, KV, hd) — int8 for SIRA-certified layers, fp
    otherwise.  The jitted step functions consume/return the pools; the
    table and free list are plain numpy/python updated between steps.

    With ``prefix_cache=True`` pages carry refcounts (``ref[p]`` = slots
    mapping page p), full prompt pages are registered in a
    :class:`PrefixIndex`, and released pages whose content is indexed
    move to a cached-free LRU instead of the free list.  ``sharding``
    (a ``jax.sharding.Sharding``) places the page pools — the serving
    path shards the KV-head dim over the mesh's "model" axis so each
    device holds its own shard of every page.
    """

    def __init__(self, cfg, spec: KVCacheSpec, batch_slots: int,
                 max_seq: int, page_size: int = 16,
                 num_pages: Optional[int] = None, fp_dtype=None,
                 prefix_cache: bool = False, sharding=None):
        assert len(spec.layers) == cfg.n_layers
        self.cfg = cfg
        self.spec = spec
        self.page_size = page_size
        self.slots = batch_slots
        self.max_pages = -(-max_seq // page_size)
        # default pool: worst case (every slot full) + trash page
        self.num_pages = num_pages or batch_slots * self.max_pages + 1
        assert self.num_pages >= self.max_pages + 1, \
            "pool must hold at least one full-length request"
        KV, hd = cfg.n_kv_heads, cfg.hd
        fp_dtype = fp_dtype or cfg.dtype
        shape = (self.num_pages, page_size, KV, hd)

        def pool(dtype):
            z = jnp.zeros(shape, dtype)
            return jax.device_put(z, sharding) if sharding is not None \
                else z

        self.pages = [
            {"k": pool(jnp.int8 if l.int8 else fp_dtype),
             "v": pool(jnp.int8 if l.int8 else fp_dtype)}
            for l in spec.layers]
        self.table = np.zeros((batch_slots, self.max_pages), np.int32)
        self.free: List[int] = list(range(self.num_pages - 1, 0, -1))
        self.owned: List[List[int]] = [[] for _ in range(batch_slots)]
        # --- prefix sharing state (inert when prefix_cache is False) ---
        self.prefix_cache_enabled = prefix_cache
        self.index: Optional[PrefixIndex] = \
            PrefixIndex() if prefix_cache else None
        self.ref = np.zeros(self.num_pages, np.int32)   # slots mapping p
        # cached-free pages: ref == 0 but content still indexed; ordered
        # oldest-released first so reclamation evicts the coldest prefix
        self.lru: "collections.OrderedDict[int, None]" = \
            collections.OrderedDict()
        self.forks = 0                # copy-on-write page copies performed

    # ------------------------------------------------------- allocation
    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def _take_page(self) -> Optional[int]:
        """A writable page: the free list first, else reclaim the
        oldest cached-free page (evicting its prefix subtree — orphaned
        descendants drop from the LRU to the free list)."""
        if self.free:
            return self.free.pop()
        if self.lru:
            pg, _ = self.lru.popitem(last=False)
            for dropped in self.index.evict(pg):
                if dropped != pg and dropped in self.lru:
                    del self.lru[dropped]
                    self.free.append(dropped)
            return pg
        return None

    def grow(self, slot: int, new_len: int) -> bool:
        """Ensure the slot maps every logical position < new_len.

        Returns False (no change) when the pool cannot satisfy it — the
        scheduler then preempts or defers admission.  Cached-free LRU
        pages count as available: they are reclaimed on demand."""
        need = self.pages_for(new_len) - len(self.owned[slot])
        if need > len(self.free) + len(self.lru):
            return False
        for _ in range(max(need, 0)):
            pg = self._take_page()
            self.ref[pg] = 1
            self.table[slot, len(self.owned[slot])] = pg
            self.owned[slot].append(pg)
        return True

    def _drop_ref(self, pg: int) -> None:
        self.ref[pg] -= 1
        assert self.ref[pg] >= 0, "page refcount underflow"
        if self.ref[pg] == 0:
            if self.index is not None and self.index.is_registered(pg):
                self.lru[pg] = None          # most-recently released
            else:
                self.free.append(pg)

    def release(self, slot: int) -> None:
        """Return the slot's pages to the pool (request finished/evicted).

        Shared pages survive under their other mappings; pages whose
        content is registered in the prefix index park in the LRU."""
        for pg in reversed(self.owned[slot]):
            self._drop_ref(pg)
        self.owned[slot] = []
        self.table[slot, :] = 0

    # ---------------------------------------------------- prefix sharing
    def _chunks(self, tokens: Sequence[int]) -> List[Tuple[int, ...]]:
        ps = self.page_size
        return [tuple(int(t) for t in tokens[j * ps:(j + 1) * ps])
                for j in range(len(tokens) // ps)]

    def _copy_page(self, src: int, dst: int) -> None:
        for pool in self.pages:
            pool["k"] = pool["k"].at[dst].set(pool["k"][src])
            pool["v"] = pool["v"].at[dst].set(pool["v"][src])

    def attach_prefix(self, slot: int, tokens: Sequence[int]) -> int:
        """Map the longest cached prefix of ``tokens`` into the slot,
        swapping out the private pages admission allocated (each swap
        frees one private page, so attachment never needs allocation).

        Fully-matched pages are *shared*: mapped with a refcount bump,
        never written (the slot's writes all land at positions >= the
        returned frontier).  A mid-page overlap at the boundary is
        *copied* into the slot's own private page — copy-on-write done
        eagerly, because the slot will write the divergent suffix of
        that very page during prefill.

        Returns the recompute frontier: the number of leading tokens
        whose KV is already in the cache (< len(tokens); the last token
        is always recomputed so prefill has logits to sample from).
        """
        if self.index is None or len(tokens) < 2:
            return 0
        chunks = self._chunks(tokens)
        shared = self.index.lookup(chunks)
        ps = self.page_size
        matched = len(shared) * ps
        part_m, part_pg = self.index.partial_lookup(
            len(shared), chunks,
            tuple(int(t) for t in tokens[len(shared) * ps:]))
        cached = min(matched + part_m, len(tokens) - 1)
        if cached <= 0:
            return 0
        n_full = cached // ps
        for j, pg in enumerate(shared[:n_full]):
            priv = self.owned[slot][j]
            assert priv != pg, "slot already maps an indexed page"
            if pg in self.lru:
                del self.lru[pg]
            self.ref[pg] += 1
            self.table[slot, j] = pg
            self.owned[slot][j] = pg
            self._drop_ref(priv)
        if cached % ps:
            # boundary page: diverges (or ends) mid-page — copy content
            # into the private page admission gave us, don't alias it
            src = shared[n_full] if n_full < len(shared) else part_pg
            self._copy_page(src, self.owned[slot][n_full])
            self.forks += 1
        return cached

    def register_prefix(self, slot: int, tokens: Sequence[int]) -> int:
        """Index the slot's fully-written prompt pages (full pages only —
        a partly-filled tail page will still be written).  First writer
        wins: chunks already indexed keep their existing page and the
        slot's duplicate stays private.  Returns pages newly indexed."""
        if self.index is None:
            return 0
        chunks = self._chunks(tokens)
        pages = [int(self.table[slot, j]) for j in range(len(chunks))]
        return len(self.index.register(chunks, pages))

    def prepare_write(self, slot: int, start_pos: int) -> bool:
        """Fork-on-write guard: any page the slot maps at positions
        >= ``start_pos`` that is also visible elsewhere (mapped by
        another slot, or reachable through the prefix index) is forked
        to a private copy before the write.  In the normal serving flow
        this is a no-op — slots only write above their attach frontier,
        which lands in private pages — but it is what makes the
        reserve/rollback contract survive sharing: a speculative window
        (and its rolled-back garbage) can only ever touch pages no one
        else maps.  Returns False when a fork cannot be allocated."""
        if self.index is None:
            return True
        for j in range(start_pos // self.page_size,
                       len(self.owned[slot])):
            pg = self.owned[slot][j]
            if self.ref[pg] > 1 or self.index.is_registered(pg):
                if not self._fork(slot, j):
                    return False
        return True

    def _fork(self, slot: int, j: int) -> bool:
        old = self.owned[slot][j]
        new = self._take_page()
        if new is None:
            return False
        self._copy_page(old, new)
        self.ref[new] = 1
        self.table[slot, j] = new
        self.owned[slot][j] = new
        self._drop_ref(old)
        self.forks += 1
        return True

    # ------------------------------------------------- speculative window
    def reserve(self, slot: int, new_len: int) -> bool:
        """Map capacity for a speculative write window: every logical
        position < ``new_len`` addressable (``new_len`` may exceed what
        ends up committed).  Pages acquired here stay owned by the slot
        even when the window is rolled back — rejection causes no
        free-list churn, the pages are reused by the very next step."""
        return self.grow(slot, min(new_len, self.max_pages * self.page_size))

    def rollback(self, slot: int, committed_len: int) -> None:
        """Discard speculative writes beyond ``committed_len``.

        Physically a no-op by construction: every read masks key
        positions against the per-slot length pointer, so the rejected
        suffix is unreadable garbage, and the next step's writes land on
        top of it (scatter happens before gather inside
        ``paged_attention``, so it is overwritten before it could ever
        enter a live mask).  Pages stay allocated (see ``reserve``)."""
        assert self.pages_for(committed_len) <= len(self.owned[slot]), \
            "rollback below the slot's mapped extent"

    # ------------------------------------------------------------ views
    def device_table(self) -> jnp.ndarray:
        return jnp.asarray(self.table)

    def slot_table(self, slot: int) -> jnp.ndarray:
        return jnp.asarray(self.table[slot:slot + 1])

    @property
    def used_pages(self) -> int:
        """Pages mapped by live slots (cached-free LRU pages excluded —
        they are reclaimable on demand, not in use)."""
        return self.num_pages - 1 - len(self.free) - len(self.lru)

    @property
    def cached_pages(self) -> int:
        """Cached-free pages held for prefix reuse (the LRU)."""
        return len(self.lru)

    @property
    def shared_pool_occupancy(self) -> float:
        """Fraction of the pool physically holding data — live mappings
        plus cached prefixes (the trash page excluded)."""
        return (self.num_pages - 1 - len(self.free)) / (self.num_pages - 1)

    def hbm_bytes(self) -> int:
        return sum(p["k"].nbytes + p["v"].nbytes for p in self.pages)
