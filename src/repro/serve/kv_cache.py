"""Paged KV cache with SIRA-derived scaled-integer storage.

Two halves:

* **Spec derivation** (`derive_kv_spec`): for every attention layer,
  export the K/V projection subgraph with the *actual serving weights*
  (`models.export.export_kv_proj_graph`) and run the SIRA range analysis
  (`core.propagate.analyze`) over it.  The per-output-channel value
  intervals of the K/V tensors reduce to per-KV-head amax bounds (K is
  widened by sqrt(2) for the RoPE rotation hull), giving int8 storage
  scales with a *static coverage guarantee* — saturation can only trigger
  on activations that escape their proven range (A2Q-style, Colbert et
  al. 2023).  A layer falls back to full-precision storage when its bound
  is non-finite or so wide that the int8 step exceeds ``max_step``
  (resolution cliff).  Optionally, per-layer `MinMaxObserver`s
  (`quant.calibrate`) over real token batches tighten the analyzed input
  range from the default post-norm assumption.

* **Page pool** (`PagedKVCache`): fixed pool of physical pages per layer
  (device arrays), a host-side page table (slots x logical pages) and
  free list.  Slots own pages only for the tokens they actually hold;
  finished requests return pages to the pool immediately, which is what
  lets the scheduler admit a queue much deeper than ``batch_slots``
  without sizing HBM for the worst case.  Physical page 0 is reserved as
  the trash page: idle slots' writes land there and it is never mapped
  to a live position.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.propagate import analyze
from repro.models.export import export_kv_proj_graph
from repro.quant.calibrate import MinMaxObserver
from repro.quant.quantizer import QuantSpec

# RoPE rotates channel pairs within a head: |k'| <= sqrt(k1^2 + k2^2)
# <= sqrt(2) * max(|k1|, |k2|), so a per-head pre-rotation amax bound
# widens by sqrt(2) to cover the stored (post-RoPE) keys.
ROPE_HULL = math.sqrt(2.0)


@dataclasses.dataclass(frozen=True)
class LayerKVSpec:
    """Storage decision for one attention layer's KV cache."""
    int8: bool
    k_scale: Optional[np.ndarray] = None    # (KV,) int8 step per head
    v_scale: Optional[np.ndarray] = None
    k_amax: Optional[np.ndarray] = None     # (KV,) proven |K| bound
    v_amax: Optional[np.ndarray] = None
    reason: str = ""                        # why fp fallback, if not int8


@dataclasses.dataclass(frozen=True)
class KVCacheSpec:
    """Per-layer storage plan for the paged cache."""
    layers: Tuple[LayerKVSpec, ...]

    @property
    def n_int8(self) -> int:
        return sum(1 for l in self.layers if l.int8)

    def scales(self) -> List[Optional[Tuple[np.ndarray, np.ndarray]]]:
        """Per-layer (k_scale, v_scale) for ``Model.decode_paged``."""
        return [(l.k_scale, l.v_scale) if l.int8 else None
                for l in self.layers]

    @staticmethod
    def all_fp(n_layers: int) -> "KVCacheSpec":
        return KVCacheSpec(tuple(LayerKVSpec(int8=False, reason="fp cache")
                                 for _ in range(n_layers)))


def _layer_weights(params, layer: int):
    """(Wk, Wv, bk, bv) of one stacked layer, dequantizing packed int8."""
    attn = params["layers"]["attn"]

    def get(w):
        w = jax.tree.map(lambda a, i=layer: a[i], w)
        if isinstance(w, dict):                  # packed {q: int8, s: f32}
            return np.asarray(w["q"], np.float64) * np.asarray(
                w["s"], np.float64)
        return np.asarray(w, np.float64)

    bk = get(attn["bk"]) if "bk" in attn else None
    bv = get(attn["bv"]) if "bv" in attn else None
    return get(attn["wk"]), get(attn["wv"]), bk, bv


def observe_block_inputs(model, params, token_batches: Iterable
                         ) -> List[Tuple[float, float]]:
    """Per-layer ``MinMaxObserver`` over the post-norm activations feeding
    the K/V projections, walked layer by layer on real token batches.

    Returns per-layer (lo, hi) to replace the default calibrated-range
    assumption in ``derive_kv_spec`` — calibration tightens the SIRA input
    interval; the propagation itself stays static and guaranteed.
    """
    from repro.models.common import rms_norm
    from repro.models.transformer import (_dense_layer_fwd, _moe_layer_fwd)

    cfg = model.cfg
    obs = [MinMaxObserver(QuantSpec(bits=8)) for _ in range(cfg.n_layers)]
    for toks in token_batches:
        x = model._embed(params, jnp.asarray(toks), None)
        for layer in range(cfg.n_layers):
            p = jax.tree.map(lambda a, i=layer: a[i], params["layers"])
            obs[layer].update(np.asarray(
                rms_norm(x, p["ln1"]).astype(jnp.float32)))
            if cfg.family == "moe":
                x, _ = _moe_layer_fwd(p, x, cfg)
            else:
                x = _dense_layer_fwd(p, x, cfg, window=0)
    return [(o.lo, o.hi) for o in obs]


def derive_kv_spec(model, params, *, x_range: Tuple[float, float] = (-4., 4.),
                   a_bits: int = 8, max_step: float = 0.5,
                   calib_token_batches: Optional[Iterable] = None,
                   domain: str = "interval") -> KVCacheSpec:
    """SIRA-derived per-layer/per-head int8 KV-cache scales.

    ``x_range`` is the assumed post-norm activation interval feeding the
    K/V projections (export.py convention); pass ``calib_token_batches``
    to replace it with per-layer observed ranges.  ``max_step`` is the
    fp-fallback threshold: a layer stays full-precision when its int8
    resolution (amax / 127) would exceed it.  ``domain`` selects the
    range-analysis abstract domain ("interval" or "affine"); the affine
    reduced product can only tighten the derived scales.
    """
    cfg = model.cfg
    KV, hd = cfg.n_kv_heads, cfg.hd
    ranges = ([tuple(map(float, r)) for r in
               observe_block_inputs(model, params, calib_token_batches)]
              if calib_token_batches is not None
              else [x_range] * cfg.n_layers)

    layers = []
    for layer in range(cfg.n_layers):
        Wk, Wv, bk, bv = _layer_weights(params, layer)
        lo, hi = ranges[layer]
        g, inputs = export_kv_proj_graph(Wk, Wv, bk=bk, bv=bv,
                                         x_lo=lo, x_hi=hi, a_bits=a_bits)
        r = analyze(g, inputs, domain=domain)

        def head_amax(rng, rope: bool) -> np.ndarray:
            amax = np.maximum(np.abs(np.asarray(rng.lo)),
                              np.abs(np.asarray(rng.hi)))
            amax = amax.reshape(KV, hd).max(axis=1)
            return amax * (ROPE_HULL if rope else 1.0)

        k_amax = head_amax(r["k_mm"], rope=True)
        v_amax = head_amax(r["v_mm"], rope=False)
        worst = float(max(k_amax.max(), v_amax.max()))
        if not np.isfinite(worst):
            layers.append(LayerKVSpec(int8=False, k_amax=k_amax,
                                      v_amax=v_amax,
                                      reason="non-finite SIRA bound"))
        elif worst / 127.0 > max_step:
            layers.append(LayerKVSpec(
                int8=False, k_amax=k_amax, v_amax=v_amax,
                reason=f"int8 step {worst / 127.0:.3g} > "
                       f"max_step {max_step:g}"))
        else:
            layers.append(LayerKVSpec(
                int8=True,
                k_scale=np.maximum(k_amax / 127.0, 1e-8),
                v_scale=np.maximum(v_amax / 127.0, 1e-8),
                k_amax=k_amax, v_amax=v_amax))
    return KVCacheSpec(tuple(layers))


class PagedKVCache:
    """Shared physical page pool + host-side page table / free list.

    Device state: per-layer {"k", "v"} pools of shape
    (num_pages, page_size, KV, hd) — int8 for SIRA-certified layers, fp
    otherwise.  The jitted step functions consume/return the pools; the
    table and free list are plain numpy/python updated between steps.
    """

    def __init__(self, cfg, spec: KVCacheSpec, batch_slots: int,
                 max_seq: int, page_size: int = 16,
                 num_pages: Optional[int] = None, fp_dtype=None):
        assert len(spec.layers) == cfg.n_layers
        self.cfg = cfg
        self.spec = spec
        self.page_size = page_size
        self.slots = batch_slots
        self.max_pages = -(-max_seq // page_size)
        # default pool: worst case (every slot full) + trash page
        self.num_pages = num_pages or batch_slots * self.max_pages + 1
        assert self.num_pages >= self.max_pages + 1, \
            "pool must hold at least one full-length request"
        KV, hd = cfg.n_kv_heads, cfg.hd
        fp_dtype = fp_dtype or cfg.dtype
        shape = (self.num_pages, page_size, KV, hd)
        self.pages = [
            {"k": jnp.zeros(shape, jnp.int8 if l.int8 else fp_dtype),
             "v": jnp.zeros(shape, jnp.int8 if l.int8 else fp_dtype)}
            for l in spec.layers]
        self.table = np.zeros((batch_slots, self.max_pages), np.int32)
        self.free: List[int] = list(range(self.num_pages - 1, 0, -1))
        self.owned: List[List[int]] = [[] for _ in range(batch_slots)]

    # ------------------------------------------------------- allocation
    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def grow(self, slot: int, new_len: int) -> bool:
        """Ensure the slot maps every logical position < new_len.

        Returns False (no change) when the pool cannot satisfy it — the
        scheduler then preempts or defers admission."""
        need = self.pages_for(new_len) - len(self.owned[slot])
        if need > len(self.free):
            return False
        for _ in range(max(need, 0)):
            pg = self.free.pop()
            self.table[slot, len(self.owned[slot])] = pg
            self.owned[slot].append(pg)
        return True

    def release(self, slot: int) -> None:
        """Return the slot's pages to the pool (request finished/evicted)."""
        self.free.extend(reversed(self.owned[slot]))
        self.owned[slot] = []
        self.table[slot, :] = 0

    # ------------------------------------------------- speculative window
    def reserve(self, slot: int, new_len: int) -> bool:
        """Map capacity for a speculative write window: every logical
        position < ``new_len`` addressable (``new_len`` may exceed what
        ends up committed).  Pages acquired here stay owned by the slot
        even when the window is rolled back — rejection causes no
        free-list churn, the pages are reused by the very next step."""
        return self.grow(slot, min(new_len, self.max_pages * self.page_size))

    def rollback(self, slot: int, committed_len: int) -> None:
        """Discard speculative writes beyond ``committed_len``.

        Physically a no-op by construction: every read masks key
        positions against the per-slot length pointer, so the rejected
        suffix is unreadable garbage, and the next step's writes land on
        top of it (scatter happens before gather inside
        ``paged_attention``, so it is overwritten before it could ever
        enter a live mask).  Pages stay allocated (see ``reserve``)."""
        assert self.pages_for(committed_len) <= len(self.owned[slot]), \
            "rollback below the slot's mapped extent"

    # ------------------------------------------------------------ views
    def device_table(self) -> jnp.ndarray:
        return jnp.asarray(self.table)

    def slot_table(self, slot: int) -> jnp.ndarray:
        return jnp.asarray(self.table[slot:slot + 1])

    @property
    def used_pages(self) -> int:
        return self.num_pages - 1 - len(self.free)

    def hbm_bytes(self) -> int:
        return sum(p["k"].nbytes + p["v"].nbytes for p in self.pages)
