"""Serving metrics: TTFT, per-token latency, throughput, slot occupancy.

Pure host-side bookkeeping updated by the scheduler/engine between jitted
steps; ``clock`` is injectable so tests can drive deterministic time.

``ServingMetrics`` is a compatibility facade over a
:class:`repro.obs.metrics.MetricsRegistry`: the public API (event
methods, count fields, aggregate properties, ``summary()``) is unchanged
from the pre-obs implementation, but every count lives in a typed
registry metric and every latency lands in a histogram, so the same
numbers the tests assert on are scrapeable via :meth:`to_prometheus`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.obs.metrics import MetricsRegistry


@dataclasses.dataclass
class RequestRecord:
    request_id: int
    submit_t: float
    prompt_tokens: int = 0
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    token_times: List[float] = dataclasses.field(default_factory=list)
    preemptions: int = 0

    @property
    def n_tokens(self) -> int:
        return len(self.token_times)

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_t is None:
            return None
        return self.first_token_t - self.submit_t


class ServingMetrics:
    """Counters surfaced by the serving engine.

    * TTFT — submit → first generated token, per request (includes queue
      wait, which is the point: it exposes scheduling quality).
    * per-token latency — mean gap between consecutive generated tokens.
    * tokens/s — generated tokens over the busy wall-clock window.
    * slot occupancy — active slot-steps / (slots x decode steps): how
      much of the batch the scheduler actually kept filled.
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self.clock = clock
        self.requests: Dict[int, RequestRecord] = {}
        # every ServingMetrics owns a fresh registry — engines call
        # reset_metrics() by constructing a new instance, which must not
        # carry counts over
        self.registry = MetricsRegistry()
        r = self.registry
        self._decode_steps = r.counter(
            "serving_decode_steps_total", "jitted decode calls")
        self._decode_tokens = r.counter(
            "serving_decode_tokens_total",
            "tokens emitted by decode steps")
        self._active_slot_steps = r.counter(
            "serving_active_slot_steps_total",
            "sum of active slots over decode steps")
        self._slot_capacity = r.counter(
            "serving_slot_capacity_total",
            "sum of total slots over decode steps")
        self._prefill_chunks = r.counter(
            "serving_prefill_chunks_total", "jitted prefill chunk calls")
        self._preemptions = r.counter(
            "serving_preemptions_total", "requests preempted")
        self._spec_steps = r.counter(
            "serving_spec_steps_total", "speculative verify steps")
        self._spec_proposed = r.counter(
            "serving_spec_proposed_total", "draft tokens proposed")
        self._spec_accepted = r.counter(
            "serving_spec_accepted_total", "draft tokens accepted")
        self._prefix_lookups = r.counter(
            "serving_prefix_lookups_total", "prefix-cache lookups")
        self._prefix_hit_tokens = r.counter(
            "serving_prefix_hit_tokens_total",
            "prompt tokens served from cached prefix pages")
        self._prefix_lookup_tokens = r.counter(
            "serving_prefix_lookup_tokens_total",
            "prompt tokens that went through prefix lookup")
        self._submitted = r.counter(
            "serving_requests_total", "requests submitted")
        self._tokens = r.counter(
            "serving_tokens_total", "tokens generated")
        self._ttft_hist = r.histogram(
            "serving_ttft_seconds", "submit to first token")
        self._latency_hist = r.histogram(
            "serving_token_latency_seconds",
            "gap between consecutive tokens of one request")
        self._t0: Optional[float] = None
        self._t_last: Optional[float] = None

    # ------------------------------------------------- registry-backed
    # count fields keep their historical names/types (plain ints) while
    # the registry holds the authoritative value
    @property
    def decode_steps(self) -> int:
        return int(self._decode_steps.value)

    @property
    def decode_tokens(self) -> int:
        return int(self._decode_tokens.value)

    @property
    def active_slot_steps(self) -> int:
        return int(self._active_slot_steps.value)

    @property
    def slot_capacity(self) -> int:
        return int(self._slot_capacity.value)

    @property
    def prefill_chunks(self) -> int:
        return int(self._prefill_chunks.value)

    @property
    def preemptions(self) -> int:
        return int(self._preemptions.value)

    @property
    def spec_steps(self) -> int:
        return int(self._spec_steps.value)

    @property
    def spec_proposed(self) -> int:
        return int(self._spec_proposed.value)

    @property
    def spec_accepted(self) -> int:
        return int(self._spec_accepted.value)

    def to_prometheus(self) -> str:
        """Prometheus text-format export of every serving metric."""
        return self.registry.to_prometheus()

    # ----------------------------------------------------------- events
    def on_submit(self, request_id: int, prompt_tokens: int) -> None:
        t = self.clock()
        self.requests[request_id] = RequestRecord(request_id, t,
                                                  prompt_tokens)
        self._submitted.inc()
        if self._t0 is None:
            self._t0 = t

    def on_prefill_chunk(self) -> None:
        self._prefill_chunks.inc()

    def on_token(self, request_id: int) -> None:
        r = self.requests[request_id]
        t = self.clock()
        if r.first_token_t is None:
            r.first_token_t = t
            self._ttft_hist.observe(t - r.submit_t)
        else:
            self._latency_hist.observe(t - r.token_times[-1])
        r.token_times.append(t)
        self._tokens.inc()
        self._t_last = t

    def on_finish(self, request_id: int) -> None:
        self.requests[request_id].finish_t = self.clock()

    def on_decode_step(self, active_slots: int, total_slots: int,
                       tokens: int = 0) -> None:
        self._decode_steps.inc()
        self._decode_tokens.inc(tokens)
        self._active_slot_steps.inc(active_slots)
        self._slot_capacity.inc(total_slots)

    def on_spec_step(self, proposed: int, accepted: int) -> None:
        """One speculative decode step verified ``proposed`` draft tokens
        across the batch and accepted ``accepted`` of them."""
        self._spec_steps.inc()
        self._spec_proposed.inc(proposed)
        self._spec_accepted.inc(accepted)

    def on_preemption(self, request_id: int) -> None:
        self._preemptions.inc()
        self.requests[request_id].preemptions += 1

    def on_prefix_lookup(self, cached_tokens: int,
                         prompt_tokens: int) -> None:
        """One prefill consulted the prefix cache: ``cached_tokens`` of
        its ``prompt_tokens`` were attached instead of recomputed."""
        self._prefix_lookups.inc()
        self._prefix_hit_tokens.inc(cached_tokens)
        self._prefix_lookup_tokens.inc(prompt_tokens)

    # ------------------------------------------------------- aggregates
    @property
    def total_tokens(self) -> int:
        return sum(r.n_tokens for r in self.requests.values())

    @property
    def mean_ttft(self) -> float:
        ts = [r.ttft for r in self.requests.values() if r.ttft is not None]
        return sum(ts) / len(ts) if ts else float("nan")

    @property
    def mean_token_latency(self) -> float:
        gaps = []
        for r in self.requests.values():
            gaps.extend(b - a for a, b in zip(r.token_times,
                                              r.token_times[1:]))
        return sum(gaps) / len(gaps) if gaps else float("nan")

    @property
    def tokens_per_s(self) -> float:
        if self._t0 is None or self._t_last is None or \
                self._t_last <= self._t0:
            return float("nan")
        return self.total_tokens / (self._t_last - self._t0)

    @property
    def slot_occupancy(self) -> float:
        if not self.slot_capacity:
            return float("nan")
        return self.active_slot_steps / self.slot_capacity

    @property
    def acceptance_rate(self) -> float:
        """Accepted / proposed draft tokens across all speculative steps.
        High acceptance (repetitive prompts) is where speculation pays;
        near zero it degrades to the per-token path plus wasted verify
        width — watch this before raising ``spec_k``."""
        if not self.spec_proposed:
            return float("nan")
        return self.spec_accepted / self.spec_proposed

    @property
    def prefix_lookups(self) -> int:
        return int(self._prefix_lookups.value)

    @property
    def prefix_hit_tokens(self) -> int:
        return int(self._prefix_hit_tokens.value)

    @property
    def prefix_lookup_tokens(self) -> int:
        return int(self._prefix_lookup_tokens.value)

    @property
    def prefix_hit_rate(self) -> float:
        """Prompt tokens attached from cached prefix pages / prompt
        tokens that went through lookup.  Token-weighted (not
        per-request) so one long cold prompt cannot be papered over by
        many short hits."""
        if not self.prefix_lookup_tokens:
            return float("nan")
        return self.prefix_hit_tokens / self.prefix_lookup_tokens

    def ttft_percentile(self, q: float) -> float:
        """q-th percentile (0-100) of per-request TTFT, seconds."""
        ts = [r.ttft for r in self.requests.values() if r.ttft is not None]
        return float(np.percentile(ts, q)) if ts else float("nan")

    def token_latency_percentile(self, q: float) -> float:
        """q-th percentile (0-100) of inter-token gaps, seconds."""
        gaps: List[float] = []
        for r in self.requests.values():
            gaps.extend(b - a for a, b in zip(r.token_times,
                                              r.token_times[1:]))
        return float(np.percentile(gaps, q)) if gaps else float("nan")

    @property
    def tokens_per_decode_step(self) -> float:
        """Generated tokens emitted per jitted decode call, per active
        slot (1.0 without speculation; up to 1 + spec_k with it)."""
        if not self.active_slot_steps:
            return float("nan")
        return self.decode_tokens / self.active_slot_steps

    def summary(self) -> Dict[str, float]:
        return dict(
            requests=len(self.requests),
            total_tokens=self.total_tokens,
            decode_steps=self.decode_steps,
            prefill_chunks=self.prefill_chunks,
            preemptions=self.preemptions,
            spec_steps=self.spec_steps,
            spec_proposed=self.spec_proposed,
            spec_accepted=self.spec_accepted,
            acceptance_rate=self.acceptance_rate,
            tokens_per_decode_step=self.tokens_per_decode_step,
            mean_ttft_s=self.mean_ttft,
            mean_token_latency_s=self.mean_token_latency,
            tokens_per_s=self.tokens_per_s,
            slot_occupancy=self.slot_occupancy,
            prefix_lookups=self.prefix_lookups,
            prefix_hit_rate=self.prefix_hit_rate,
        )
