"""Continuous-batching scheduler: FIFO queue, slot table, paged-cache
bookkeeping, per-request termination, preemption.

The scheduler owns *what runs where* — admission of queued requests into
free batch slots (gated on page availability; with prefix caching,
cached-free LRU pages count as available and are reclaimed on demand),
per-request EOS / max-token termination (finished requests free their
slot and pages immediately, mid-batch — shared pages survive under
their other mappings, indexed pages park in the reuse LRU), and
preemption of the newest-admitted request when the page pool runs dry
(its sequence goes back to the queue front, preserving FIFO order, and
is replayed by chunked prefill on re-admission — a replay that
re-attaches its own just-released prefix pages when they are still
cached).  The engine owns *how it runs* — the jitted model calls.

Invariant for an active slot: ``len(entry.seq) == state.length + 1`` —
the sequence always ends with exactly one token that has been sampled
but not yet written to the KV cache; it is the next decode input.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from .kv_cache import PagedKVCache
from .metrics import ServingMetrics


@dataclasses.dataclass
class Request:
    """One generation request.

    ``request_id`` is the PRNG identity: sampling for a request depends
    only on (engine seed, request_id, token index), never on batch
    composition.  Left unset, the submission handle is used; pin it to
    reproduce a request's sampled stream across different submission
    orders.  The object is never mutated by the engine."""
    prompt: np.ndarray                 # (S_prompt,) token ids
    max_new_tokens: int = 32
    temperature: float = 0.0
    eos_id: Optional[int] = None
    request_id: Optional[int] = None


@dataclasses.dataclass
class _QueueEntry:
    request: Request
    seq: List[int]                     # prompt + generated (replay source)
    handle: int = 0                    # unique bookkeeping key
    prng_id: int = 0                   # sampling identity (request_id/handle)
    n_generated: int = 0


@dataclasses.dataclass
class _SlotState:
    entry: _QueueEntry
    length: int                        # tokens currently in the KV cache
    admit_seq: int                     # admission stamp (preempt newest)


class Scheduler:
    def __init__(self, batch_slots: int, max_seq: int, cache: PagedKVCache,
                 metrics: Optional[ServingMetrics] = None):
        self.batch_slots = batch_slots
        self.max_seq = max_seq
        self.cache = cache
        self.metrics = metrics
        self.queue: collections.deque = collections.deque()
        self.slots: List[Optional[_SlotState]] = [None] * batch_slots
        self.outputs: Dict[int, List[int]] = {}
        self._next_rid = 0
        self._admit_counter = 0

    # -------------------------------------------------------- submission
    def submit(self, request: Request) -> int:
        prompt = [int(t) for t in np.asarray(request.prompt).ravel()]
        if not prompt:
            raise ValueError("empty prompt")
        if request.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if len(prompt) + request.max_new_tokens > self.max_seq:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens "
                f"({request.max_new_tokens}) exceeds max_seq {self.max_seq}")
        handle = self._next_rid
        self._next_rid += 1
        prng_id = handle if request.request_id is None else \
            request.request_id
        self.outputs[handle] = []
        self.queue.append(_QueueEntry(request, prompt, handle, prng_id))
        if self.metrics:
            self.metrics.on_submit(handle, len(prompt))
        return handle

    # --------------------------------------------------------- admission
    def admit(self) -> List[Tuple[int, _QueueEntry]]:
        """FIFO-admit queued requests into free slots while pages last.

        Head-of-line blocking is deliberate: the oldest request is never
        skipped in favor of a smaller one, so no request can starve."""
        admitted = []
        while self.queue:
            free = [i for i, s in enumerate(self.slots) if s is None]
            if not free:
                break
            entry = self.queue[0]
            slot = free[0]
            # reserve one position beyond the prompt: the first decode
            # write otherwise lands exactly on a page boundary for
            # page-multiple prompts and a dry pool would preempt the
            # request right after its (wasted) prefill
            if not self.cache.grow(slot, len(entry.seq) + 1):
                break
            self.queue.popleft()
            self.slots[slot] = _SlotState(entry, 0, self._admit_counter)
            self._admit_counter += 1
            admitted.append((slot, entry))
        return admitted

    # ------------------------------------------------------- slot state
    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def has_work(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    def newest_active(self) -> Optional[int]:
        act = self.active_slots()
        if not act:
            return None
        return max(act, key=lambda i: self.slots[i].admit_seq)

    def set_prefilled(self, slot: int, length: int) -> None:
        self.slots[slot].length = length

    def advance(self, slot: int, n: int) -> None:
        """``n`` tokens were committed into the slot's KV cache this step
        (1 on the per-token path; 1 + accepted drafts on a speculative
        step — the rejected suffix never advances the pointer)."""
        self.slots[slot].length += n

    def note_cache_write(self, slot: int) -> None:
        """One decode step wrote the slot's pending token into the cache."""
        self.advance(slot, 1)

    # ------------------------------------------------------ termination
    def record_tokens(self, slot: int, tokens) -> Tuple[int, bool]:
        """Append sampled tokens in order, honoring EOS / max_new_tokens
        *inside the window*: recording stops at the terminating token
        (the slot is freed, later tokens are discarded).  Returns
        (n_recorded, finished)."""
        for n, tok in enumerate(tokens):
            if self.record_token(slot, int(tok)):
                return n + 1, True
        return len(tokens), False

    def record_token(self, slot: int, token: int) -> bool:
        """Append a sampled token; free the slot if the request finished
        (EOS hit or max_new_tokens reached).  Returns finished."""
        st = self.slots[slot]
        e = st.entry
        self.outputs[e.handle].append(token)
        e.seq.append(token)
        e.n_generated += 1
        done = e.n_generated >= e.request.max_new_tokens or (
            e.request.eos_id is not None and token == e.request.eos_id)
        if done:
            self.free_slot(slot)
        return done

    def free_slot(self, slot: int) -> None:
        self.cache.release(slot)
        self.slots[slot] = None

    def preempt(self, slot: int) -> int:
        """Evict a running request: pages freed (shared mappings just
        drop a reference), sequence (prompt + generated so far) back to
        the queue *front* — it was admitted before anything still
        queued, so FIFO order is preserved.  Returns the preempted
        request id."""
        st = self.slots[slot]
        self.cache.release(slot)
        self.slots[slot] = None
        self.queue.appendleft(st.entry)
        if self.metrics:
            self.metrics.on_preemption(st.entry.handle)
        return st.entry.handle
