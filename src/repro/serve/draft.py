"""Draft proposers for speculative decoding.

A :class:`DraftProposer` guesses the next ``k`` tokens of a sequence;
the engine verifies the whole guess in **one** jitted ``decode_paged``
call over (slots, k+1) positions and commits the accepted prefix
(``serve/engine.py``).  Because the engine's sampling is a deterministic
function of (seed, request_id, token index, logits), verification is
exact at any temperature: a draft token is accepted iff it equals the
token the per-token engine would have sampled at that position — the
output stream is bit-identical to non-speculative decoding, proposals
only change how many jitted steps it takes to produce it.

The interface is deliberately model-free (token ids in, token ids out)
so a small-model drafter can slot in later: propose() may run its own
forward pass, observe() lets it ingest committed tokens.

``NgramDrafter`` is the zero-cost baseline: prompt-lookup decoding
(suffix n-gram matching against the request's own history), which is
where speculative decoding shines on repetitive prompts — summarization,
code editing, retrieval-heavy serving.
"""
from __future__ import annotations

import abc
from typing import List, Sequence


class DraftProposer(abc.ABC):
    """Per-engine draft-token proposer (stateless across slots unless a
    subclass keeps per-request state keyed on ``request_id``)."""

    @abc.abstractmethod
    def propose(self, seq: Sequence[int], k: int,
                request_id: int = 0) -> List[int]:
        """Up to ``k`` guessed continuation tokens for ``seq`` (prompt +
        everything generated so far, including the still-uncached pending
        token).  Returning fewer than ``k`` (or none) is fine — the
        engine degrades gracefully down to the per-token path."""

    def observe(self, seq: Sequence[int], request_id: int = 0) -> None:
        """Post-commit hook (default: no-op), fired after a speculative
        commit — NOT on prefill, per-token degrade steps, or request
        termination.  ``propose()`` always receives the full sequence,
        which is the only reliable source of truth; a stateful
        small-model drafter must reconcile its own cache against ``seq``
        (e.g. in ``propose``) rather than assume ``observe`` saw every
        token."""


class NgramDrafter(DraftProposer):
    """Prompt-lookup decoding: match the longest recent n-gram suffix of
    the sequence earlier in the sequence and propose what followed it.

    ``max_ngram``/``min_ngram`` bound the suffix length tried (longest
    first — longer matches are more specific and accept more often).
    """

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1):
        if not 1 <= min_ngram <= max_ngram:
            raise ValueError("need 1 <= min_ngram <= max_ngram")
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram

    def propose(self, seq: Sequence[int], k: int,
                request_id: int = 0) -> List[int]:
        seq = list(seq)
        L = len(seq)
        if k <= 0 or L < self.min_ngram + 1:
            return []
        for n in range(min(self.max_ngram, L - 1), self.min_ngram - 1, -1):
            suffix = seq[L - n:]
            # most recent earlier occurrence of the suffix n-gram
            for start in range(L - n - 1, -1, -1):
                if seq[start:start + n] == suffix:
                    cont = seq[start + n:start + n + k]
                    if cont:
                        return cont
        return []


class FixedDrafter(DraftProposer):
    """Deterministic canned proposals — test/benchmark scaffolding."""

    def __init__(self, tokens: Sequence[int]):
        self.tokens = list(tokens)

    def propose(self, seq: Sequence[int], k: int,
                request_id: int = 0) -> List[int]:
        return self.tokens[:k]


def get_drafter(name: str, **kwargs) -> DraftProposer:
    """Drafter registry for string configuration (``spec_decode="ngram"``)."""
    if name == "ngram":
        return NgramDrafter(**kwargs)
    raise ValueError(f"unknown drafter {name!r} (have: 'ngram')")
