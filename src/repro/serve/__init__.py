"""Serving subsystem: continuous batching over a SIRA-quantized paged KV
cache.  Public API:

* ``ServingConfig`` — validated engine configuration (slots, cache
  geometry, quantized KV, speculation, prefix caching, mesh).
* ``ServingEngine`` — jitted chunked prefill + batched decode, vectorized
  per-request sampling; paged mode with a static-batch fallback.
* ``Request`` — prompt, max_new_tokens, temperature, eos_id.
* ``Scheduler`` — FIFO admission, slot/page bookkeeping, termination,
  preemption.
* ``PagedKVCache`` / ``KVCacheSpec`` / ``derive_kv_spec`` — paged pool
  with per-layer int8 scales from SIRA range analysis (fp fallback),
  copy-on-write prefix sharing (``PrefixIndex``, refcounts, reuse LRU).
* ``ServingMetrics`` — TTFT, token latency, tokens/s, slot occupancy,
  speculative acceptance rate / tokens-per-step, prefix hit rate,
  latency percentiles.
* ``DraftProposer`` / ``NgramDrafter`` — draft proposers for speculative
  decoding (``ServingConfig(spec_decode="ngram", spec_k=4)``).
"""
from .config import ServingConfig                              # noqa: F401
from .draft import (DraftProposer, FixedDrafter,               # noqa: F401
                    NgramDrafter, get_drafter)
from .engine import ServingEngine                              # noqa: F401
from .scheduler import Request, Scheduler                      # noqa: F401
from .kv_cache import (PagedKVCache, KVCacheSpec, LayerKVSpec,  # noqa: F401
                       PrefixIndex, derive_kv_spec,
                       observe_block_inputs)
from .metrics import ServingMetrics                            # noqa: F401
