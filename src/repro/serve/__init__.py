"""Serving subsystem: continuous batching over a SIRA-quantized paged KV
cache.  Public API:

* ``ServingEngine`` — jitted chunked prefill + batched decode, vectorized
  per-request sampling; paged mode with a static-batch fallback.
* ``Request`` — prompt, max_new_tokens, temperature, eos_id.
* ``Scheduler`` — FIFO admission, slot/page bookkeeping, termination,
  preemption.
* ``PagedKVCache`` / ``KVCacheSpec`` / ``derive_kv_spec`` — paged pool
  with per-layer int8 scales from SIRA range analysis (fp fallback).
* ``ServingMetrics`` — TTFT, token latency, tokens/s, slot occupancy,
  speculative acceptance rate / tokens-per-step.
* ``DraftProposer`` / ``NgramDrafter`` — draft proposers for speculative
  decoding (``ServingEngine(spec_decode="ngram", spec_k=4)``).
"""
from .draft import (DraftProposer, FixedDrafter,               # noqa: F401
                    NgramDrafter, get_drafter)
from .engine import ServingEngine                              # noqa: F401
from .scheduler import Request, Scheduler                      # noqa: F401
from .kv_cache import (PagedKVCache, KVCacheSpec, LayerKVSpec,  # noqa: F401
                       derive_kv_spec, observe_block_inputs)
from .metrics import ServingMetrics                            # noqa: F401
