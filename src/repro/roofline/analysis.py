"""Roofline analysis from compiled XLA artifacts (no hardware needed).

Per (arch × shape × mesh) cell we derive three time terms for TPU v5e:

    compute    = HLO_FLOPs_per_chip / peak_FLOP/s          (197e12 bf16)
    memory     = HLO_bytes_per_chip / HBM_bw               (819e9 B/s)
    collective = collective_bytes_per_chip / link_bw       (~50e9 B/s/link)

``cost_analysis()`` yields per-chip FLOPs and bytes post-SPMD.  Collective
bytes are parsed from the optimized HLO: for every all-reduce /
all-gather / reduce-scatter / all-to-all / collective-permute we sum the
*operand* shard sizes (looked up from the defining instructions), as the
assignment specifies.  A ring-model estimate (bytes actually on the wire)
is reported alongside.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional


PEAK_FLOPS = 197e12        # bf16 per chip, TPU v5e
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link (≈ per-direction ICI)
DCN_BW = 6.25e9            # bytes/s per chip across pods (50 Gbit/s)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """bytes of one shape token like bf16[128,1024] (tuples: sum parts)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    operand_bytes: Dict[str, int]     # per-chip operand shard bytes
    wire_bytes: Dict[str, int]        # ring-model on-the-wire bytes
    cross_pod_bytes: int = 0          # operand bytes of pod-axis collectives

    @property
    def total_operand_bytes(self) -> int:
        return sum(self.operand_bytes.values())

    @property
    def total_wire_bytes(self) -> int:
        return sum(self.wire_bytes.values())


def parse_collectives(hlo_text: str,
                      n_devices: int = 0,
                      pod_group_size: Optional[int] = None
                      ) -> CollectiveStats:
    """Scan optimized HLO for collectives; sum operand shard sizes."""
    # map instruction name -> result type string
    result_type: Dict[str, str] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            name, rhs = m.groups()
            tm = _SHAPE_RE.search(rhs)
            if tm:
                # capture the full type prefix (up to the op name)
                result_type[name] = rhs.split(")")[0]

    counts: Dict[str, int] = {}
    op_bytes: Dict[str, int] = {}
    wire: Dict[str, int] = {}
    cross_pod = 0
    for line in hlo_text.splitlines():
        mm = re.search(r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
                       r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                       r"collective-permute)(-start)?\(", line)
        if not mm:
            continue
        op = mm.group(1)
        if mm.group(2):  # async start; skip -done twin counting
            pass
        if f"{op}-done" in line:
            continue
        # operands: inside the parens, reference names %foo
        paren = line[line.index("(", mm.start()):]
        operands = re.findall(r"%([\w\.\-]+)", paren)
        ob = 0
        for o in operands:
            t = result_type.get(o)
            if t:
                ob += _shape_bytes(t)
        if ob == 0:
            # fall back to result size
            m2 = _DEF_RE.match(line)
            if m2:
                ob = _shape_bytes(m2.group(2).split(op)[0])
        # group size from replica_groups=[g,k]<=[N] or explicit lists
        gsz = 0
        gm = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[(\d+)", line)
        if gm:
            gsz = int(gm.group(2))
        else:
            gm2 = re.search(r"replica_groups=\{\{([0-9,]+)\}", line)
            if gm2:
                gsz = len(gm2.group(1).split(","))
        counts[op] = counts.get(op, 0) + 1
        op_bytes[op] = op_bytes.get(op, 0) + ob
        # ring model (per chip): AR 2(g-1)/g · b ; AG/RS (g-1)/g · b ;
        # A2A (g-1)/g · b ; permute b
        g = max(gsz, 2)
        if op == "all-reduce":
            w = int(2 * (g - 1) / g * ob)
        elif op == "collective-permute":
            w = ob
        else:
            w = int((g - 1) / g * ob)
        wire[op] = wire.get(op, 0) + w
        if pod_group_size and gsz and gsz == pod_group_size:
            cross_pod += ob
    return CollectiveStats(counts=counts, operand_bytes=op_bytes,
                           wire_bytes=wire, cross_pod_bytes=cross_pod)


@dataclasses.dataclass
class Roofline:
    flops_per_chip: float
    bytes_per_chip: float
    collective_bytes_per_chip: float
    wire_bytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float              # 6·N·D (global, fwd+bwd) or 2·N·D
    useful_flops_frac: float        # MODEL / (HLO · chips)
    mfu_bound: float                # max roofline fraction achievable

    def to_dict(self):
        return dataclasses.asdict(self)


def roofline_from(cost: Dict[str, float], colls: CollectiveStats,
                  n_chips: int, model_flops: float,
                  link_bw: float = ICI_BW) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    cb = float(colls.total_operand_bytes)
    wb = float(colls.total_wire_bytes)
    t_c = flops / PEAK_FLOPS
    t_m = byts / HBM_BW
    t_x = cb / link_bw
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    hlo_total = flops * n_chips
    useful = model_flops / hlo_total if hlo_total else 0.0
    t_star = max(t_c, t_m, t_x)
    mfu_bound = (model_flops / n_chips / PEAK_FLOPS) / t_star \
        if t_star > 0 else 0.0
    return Roofline(flops_per_chip=flops, bytes_per_chip=byts,
                    collective_bytes_per_chip=cb, wire_bytes_per_chip=wb,
                    compute_s=t_c, memory_s=t_m, collective_s=t_x,
                    bottleneck=bottleneck, model_flops=model_flops,
                    useful_flops_frac=useful, mfu_bound=mfu_bound)


def model_flops_for(cfg, shape, kind: str) -> float:
    """MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (inference)."""
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch * 1      # decode: one token per sequence
    return 2.0 * n_active * tokens
