"""Trip-count-aware cost extraction from optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop (lax.scan) bodies
*once*, which understates FLOPs/bytes/collectives of scan-over-layers
models by ~L×.  This module re-derives totals by parsing the optimized
HLO module: per-computation instruction lists, a call graph (while /
fusion / call / conditional), and ``known_trip_count`` backend configs,
then accumulates

    flops             dot/cdot (2·M·N·K), elementwise/reduce (result size)
    bytes             operand + result bytes per non-fused instruction
                      (fusion internals are VMEM-resident: callsite only)
    collective bytes  operand shard bytes per collective × trip counts

Validated against cost_analysis on unrolled graphs (tests).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_LHS_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->\s*\S.*\{\s*$")


def _split_rhs(rhs: str):
    """'TYPE op(operands...)attrs' → (type_str, op, rest).  TYPE may be a
    tuple containing parens/comments, so split with paren counting."""
    rhs = rhs.lstrip()
    if rhs.startswith("("):
        depth = 0
        end = -1
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        tstr, rest = rhs[:end + 1], rhs[end + 1:].lstrip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        tstr, rest = rhs[:sp], rhs[sp + 1:].lstrip()
    om = re.match(r"([\w\-]+)\((.*)$", rest)
    if not om:
        return None
    return tstr, om.group(1), om.group(2)

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "tanh", "rsqrt", "sqrt", "log", "power", "negate",
    "abs", "floor", "ceil", "round-nearest-even", "compare", "select",
    "and", "or", "xor", "clamp", "sign", "cosine", "sine", "logistic",
    "expm1", "log1p", "atan2", "remainder", "cbrt", "erf",
}
REDUCE_LIKE = {"reduce", "reduce-window", "cumsum"}
# pseudo-ops that move no HBM bytes themselves (aliases / tuple plumbing)
NO_BYTES_OPS = {"parameter", "get-tuple-element", "tuple", "bitcast",
                "constant", "iota", "while", "conditional", "call",
                "after-all", "partition-id", "replica-id", "custom-call",
                "opt-barrier", "domain", "rng-bit-generator"}
COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "all-reduce-start", "all-gather-start",
               "reduce-scatter-start", "collective-permute-start",
               "all-to-all-start"}


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    elems = 0
    byts = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str                      # operands + attributes raw text
    operands: List[str]


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    collective_counts: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    cross_pod_bytes: float = 0.0

    def add(self, other: "Totals", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0) \
                + v * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = self.collective_counts.get(k, 0) \
                + v * mult
        self.cross_pod_bytes += other.cross_pod_bytes * mult


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[Instr]] = {}
        self.result_type: Dict[str, str] = {}
        self.entry: Optional[str] = None
        self._parse(text)

    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        for line in text.splitlines():
            cm = _COMP_RE.match(line)
            if cm and ("->" in line) and line.rstrip().endswith("{"):
                cur = cm.group(1)
                self.computations[cur] = []
                if line.lstrip().startswith("ENTRY"):
                    self.entry = cur
                continue
            if line.strip() == "}":
                cur = None
                continue
            lm = _LHS_RE.match(line)
            if lm and cur is not None:
                name, rhs = lm.groups()
                parts = _split_rhs(rhs)
                if parts is None:
                    continue
                tstr, op, rest = parts
                # operand refs live before the closing paren of the call
                depth = 1
                end = len(rest)
                for i, ch in enumerate(rest):
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            end = i
                            break
                operands = re.findall(r"%([\w\.\-]+)", rest[:end])
                inst = Instr(name, tstr, op, rest, operands)
                self.computations[cur].append(inst)
                self.result_type[name] = tstr

    # ------------------------------------------------------------- costs
    def _dot_flops(self, inst: Instr) -> float:
        elems, _ = _shape_elems_bytes(inst.type_str)
        k = 1
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", inst.rest)
        if m and inst.operands:
            lhs_t = self.result_type.get(inst.operands[0], "")
            sm = _SHAPE_RE.search(lhs_t)
            if sm and sm.group(2):
                dims = [int(d) for d in sm.group(2).split(",")]
                for ci in m.group(1).split(","):
                    if ci and int(ci) < len(dims):
                        k *= dims[int(ci)]
        return 2.0 * elems * k

    def _instr_totals(self, inst: Instr, in_fusion: bool,
                      pod_group_size: Optional[int]) -> Totals:
        t = Totals()
        elems, rbytes = _shape_elems_bytes(inst.type_str)
        op = inst.op
        base = op.replace("-start", "")
        if base in COLLECTIVES or op in COLLECTIVES:
            ob = 0
            for o in inst.operands:
                _, b = _shape_elems_bytes(self.result_type.get(o, ""))
                ob += b
            if ob == 0:
                ob = rbytes
            key = base
            t.collective_bytes[key] = t.collective_bytes.get(key, 0) + ob
            t.collective_counts[key] = t.collective_counts.get(key, 0) + 1
            gm = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", inst.rest)
            if pod_group_size and gm and int(gm.group(2)) == pod_group_size:
                t.cross_pod_bytes += ob
            t.bytes += rbytes + ob
            return t
        if op == "dot":
            t.flops += self._dot_flops(inst)
        elif op == "convolution":
            t.flops += 2.0 * elems  # lower bound; LM models don't use it
        elif op in ELEMENTWISE or op in REDUCE_LIKE:
            t.flops += elems
        if not in_fusion and op not in NO_BYTES_OPS:
            # slice-aware traffic: windowed reads/writes touch the window,
            # not the whole buffer (scan-stacked params/grad accumulators)
            if op in ("dynamic-slice", "slice", "gather"):
                t.bytes += 2.0 * rbytes
            elif op == "dynamic-update-slice":
                ub = 0
                if len(inst.operands) > 1:
                    _, ub = _shape_elems_bytes(
                        self.result_type.get(inst.operands[1], ""))
                t.bytes += 2.0 * (ub or rbytes)
            elif op == "scatter":
                upd = 0
                if len(inst.operands) > 2:
                    _, upd = _shape_elems_bytes(
                        self.result_type.get(inst.operands[2], ""))
                t.bytes += 2.0 * (upd or rbytes)
            else:
                ob = 0
                for o in inst.operands:
                    _, b = _shape_elems_bytes(self.result_type.get(o, ""))
                    ob += b
                t.bytes += rbytes + ob
        return t

    def totals_for(self, comp: str, pod_group_size: Optional[int] = None,
                   _depth: int = 0) -> Totals:
        t = Totals()
        if comp not in self.computations or _depth > 32:
            return t
        for inst in self.computations[comp]:
            if inst.op == "while":
                body = re.search(r"body=%?([\w\.\-]+)", inst.rest)
                trips = 1
                tm = re.search(
                    r'"known_trip_count"\s*:\s*\{\s*"n"\s*:\s*"(\d+)"',
                    inst.rest)
                if tm:
                    trips = int(tm.group(1))
                if body:
                    t.add(self.totals_for(body.group(1), pod_group_size,
                                          _depth + 1), trips)
                continue
            if inst.op == "fusion":
                called = re.search(r"calls=%?([\w\.\-]+)", inst.rest)
                if called:
                    sub = self._fusion_totals(called.group(1),
                                              pod_group_size, _depth + 1)
                    t.add(sub)
                    t.bytes += self._fusion_hbm_bytes(called.group(1), inst)
                continue
            if inst.op in ("call", "conditional", "async-start"):
                for target in re.findall(
                        r"(?:to_apply|calls|branch_computations=\{|"
                        r"true_computation|false_computation)=?%?"
                        r"([\w\.\-]+)", inst.rest):
                    t.add(self.totals_for(target, pod_group_size,
                                          _depth + 1))
                continue
            t.add(self._instr_totals(inst, in_fusion=False,
                                     pod_group_size=pod_group_size))
        return t

    def _fusion_hbm_bytes(self, comp: str, callsite: Instr) -> float:
        """HBM traffic of one fusion call: result write + per-parameter
        reads.  A parameter consumed only through dynamic-slice / slice /
        gather contributes just the sliced bytes (the scan-over-layers
        stacked-params pattern); otherwise the full operand is read."""
        _, rbytes = _shape_elems_bytes(callsite.type_str)
        instrs = self.computations.get(comp, [])
        by_name = {i.name: i for i in instrs}

        def chase_producer(inst):
            """Walk back through dtype converts/bitcasts (free on TPU —
            CPU XLA's float normalization materializes them)."""
            seen = 0
            while inst.op in ("convert", "bitcast", "copy") and \
                    inst.operands and inst.operands[0] in by_name and \
                    seen < 8:
                inst = by_name[inst.operands[0]]
                seen += 1
            return inst

        # in-place dynamic-update-slice root (possibly behind converts):
        # the write is the update slice, not the whole stacked buffer
        if instrs:
            root = chase_producer(instrs[-1])
            if root.op == "dynamic-update-slice" and len(root.operands) > 1:
                upd = by_name.get(root.operands[1])
                if upd is not None:
                    _, ub = _shape_elems_bytes(upd.type_str)
                    if ub:
                        rbytes = ub
        total = float(rbytes)
        # map param name -> param index
        param_idx: Dict[str, int] = {}
        for inst in instrs:
            if inst.op == "parameter":
                m = re.match(r"\s*(\d+)", inst.rest)
                if m:
                    param_idx[inst.name] = int(m.group(1))
        consumers: Dict[str, List[Instr]] = {}
        all_consumers: Dict[str, List[Instr]] = {}
        for inst in instrs:
            for o in inst.operands:
                all_consumers.setdefault(o, []).append(inst)

        def chase_consumer(inst):
            """Walk forward through single-consumer convert/bitcast/copy
            chains to the semantic consumer."""
            seen = 0
            while inst.op in ("convert", "bitcast", "copy") and seen < 8:
                nxt = all_consumers.get(inst.name, [])
                if len(nxt) != 1:
                    break
                inst = nxt[0]
                seen += 1
            return inst

        for inst in instrs:
            for o in inst.operands:
                if o in param_idx:
                    consumers.setdefault(o, []).append(
                        chase_consumer(inst))
        for pname, idx in param_idx.items():
            if idx >= len(callsite.operands):
                continue
            _, full = _shape_elems_bytes(
                self.result_type.get(callsite.operands[idx], ""))
            cons = consumers.get(pname, [])
            if cons and all(c.op in ("dynamic-slice", "slice", "gather")
                            for c in cons):
                sliced = 0
                for c in cons:
                    _, b = _shape_elems_bytes(c.type_str)
                    sliced += b
                total += min(sliced, full)
            elif cons and all(c.op == "dynamic-update-slice"
                              for c in cons):
                upd = 0
                for c in cons:
                    if len(c.operands) > 1:
                        _, b = _shape_elems_bytes(
                            self.result_type.get(c.operands[1], ""))
                        upd += b
                total += min(upd, full) if upd else full
            else:
                total += full
        return total

    def _fusion_totals(self, comp: str, pod_group_size, _depth) -> Totals:
        """FLOPs (not bytes) of a fused computation's instructions."""
        t = Totals()
        if comp not in self.computations or _depth > 32:
            return t
        for inst in self.computations[comp]:
            if inst.op == "fusion":
                called = re.search(r"calls=%?([\w\.\-]+)", inst.rest)
                if called:
                    t.add(self._fusion_totals(called.group(1),
                                              pod_group_size, _depth + 1))
                continue
            t.add(self._instr_totals(inst, in_fusion=True,
                                     pod_group_size=pod_group_size))
        return t


def analyze_hlo(text: str, pod_group_size: Optional[int] = None) -> Totals:
    mod = HloModule(text)
    if mod.entry is None:
        return Totals()
    return mod.totals_for(mod.entry, pod_group_size)


def normalize_cost_analysis(cost) -> Dict[str, float]:
    """XLA ``Compiled.cost_analysis()`` returns a ``[dict]`` on jax < 0.5
    and a plain dict on newer releases; normalize to a dict."""
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)
