from .analysis import (parse_collectives, roofline_from,  # noqa: F401
                       model_flops_for, Roofline, CollectiveStats)
