"""Render EXPERIMENTS.md tables from experiments/dryrun/*.json."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List


def load_results(directory: str) -> List[Dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def _fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def dryrun_table(results: List[Dict], mesh: str = None) -> str:
    lines = ["| arch | shape | mesh | status | compile | mem/dev | "
             "GFLOP/chip | GB/chip | collectives |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in results:
        if mesh and r["mesh"] != mesh:
            continue
        if r["skipped"]:
            reason = r["reason"][:60]
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"SKIP | — | — | — | — | {reason} |")
            continue
        if not r["ok"]:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"FAIL | — | — | — | — | — |")
            continue
        c = r["collectives"]["counts"]
        cstr = " ".join(f"{k.replace('all-', 'a').replace('reduce-', 'r')}"
                        f"×{v}" for k, v in sorted(c.items()))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | OK | "
            f"{r['compile_s']:.0f}s | "
            f"{r['memory'].get('total_per_device_gb', 0):.2f}GB | "
            f"{r['cost'].get('flops', 0) / 1e9:.1f} | "
            f"{r['cost'].get('bytes accessed', 0) / 2**30:.1f} | {cstr} |")
    return "\n".join(lines)


def roofline_table(results: List[Dict], mesh: str = "16x16") -> str:
    lines = ["| arch | shape | compute | memory | collective | bottleneck "
             "| MODEL/HLO flops | roofline-bound MFU |",
             "|---|---|---|---|---|---|---|---|"]
    for r in results:
        if r["mesh"] != mesh or not r.get("ok") or r.get("skipped"):
            continue
        rl = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(rl['compute_s'])} | "
            f"{_fmt_s(rl['memory_s'])} | {_fmt_s(rl['collective_s'])} | "
            f"**{rl['bottleneck']}** | {rl['useful_flops_frac']:.2f} | "
            f"{min(rl['mfu_bound'], 1.0):.3f} |")
    return "\n".join(lines)


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--table", default="dryrun",
                    choices=["dryrun", "roofline"])
    args = ap.parse_args()
    rs = load_results(args.dir)
    if args.table == "dryrun":
        print(dryrun_table(rs, args.mesh))
    else:
        print(roofline_table(rs, args.mesh or "16x16"))


if __name__ == "__main__":
    main()
