"""Pallas TPU kernel: fused activation quantization (scale → round → clip).

One HBM pass from float activations to int8 — the Quant node of the
streamlined graph (paper §3.2.1) with per-channel or per-tensor scales.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, scale_ref, zp_ref, o_ref, *, qmin: int, qmax: int,
                  out_dtype):
    x = x_ref[...]                        # (bm, bc) f32
    s = scale_ref[...]                    # (1, bc)
    z = zp_ref[...]                       # (1, bc)
    q = jnp.round(x / s + z)
    o_ref[...] = jnp.clip(q, qmin, qmax).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("qmin", "qmax", "bm", "bc",
                                             "out_dtype", "interpret"))
def quantize(x: jnp.ndarray, scale: jnp.ndarray, zero_point: jnp.ndarray,
             *, qmin: int = -128, qmax: int = 127, out_dtype=jnp.int8,
             bm: int = 256, bc: int = 128,
             interpret: bool = False) -> jnp.ndarray:
    """x (M, C) float; scale/zero_point (C,) or scalars."""
    M, C = x.shape
    bm, bc = min(bm, M), min(bc, C)
    assert M % bm == 0 and C % bc == 0, \
        f"shape ({M},{C}) not divisible by block ({bm},{bc})"
    scale2 = jnp.broadcast_to(scale.astype(jnp.float32).reshape(1, -1),
                              (1, C))
    zp2 = jnp.broadcast_to(zero_point.astype(jnp.float32).reshape(1, -1),
                           (1, C))
    kernel = functools.partial(_quant_kernel, qmin=qmin, qmax=qmax,
                               out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=(M // bm, C // bc),
        in_specs=[
            pl.BlockSpec((bm, bc), lambda i, j: (i, j)),
            pl.BlockSpec((1, bc), lambda i, j: (0, j)),
            pl.BlockSpec((1, bc), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, C), out_dtype),
        interpret=interpret,
    )(x, scale2, zp2)
