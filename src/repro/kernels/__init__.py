"""Pallas TPU kernels for the SIRA-optimized integer serving path."""
from .ops import int_matmul, multithreshold, quantize  # noqa: F401
from . import ref                                      # noqa: F401
