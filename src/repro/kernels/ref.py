"""Pure-jnp oracles for the Pallas kernels (correctness references)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def int_matmul_ref(x: jnp.ndarray, w: jnp.ndarray,
                   scale: Optional[jnp.ndarray] = None,
                   bias: Optional[jnp.ndarray] = None,
                   acc_bits: int = 32, out_dtype=None) -> jnp.ndarray:
    acc_dtype = jnp.int16 if acc_bits <= 15 else jnp.int32
    acc = jax.lax.dot_general(x, w, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    acc = acc.astype(acc_dtype)  # emulate narrow accumulation (lossless if
    #                              the SIRA bound holds — tested)
    if scale is None:
        return acc.astype(out_dtype or acc_dtype)
    y = acc.astype(jnp.float32) * scale.reshape(1, -1).astype(jnp.float32) \
        + (0.0 if bias is None else bias.reshape(1, -1).astype(jnp.float32))
    return y.astype(out_dtype or jnp.float32)


def multithreshold_ref(x: jnp.ndarray, thresholds: jnp.ndarray,
                       out_bias: int = 0, out_dtype=None) -> jnp.ndarray:
    """x (M, C); thresholds (N, C). out = out_bias + sum_i(x >= T_i).

    out_dtype defaults to the smallest dtype holding [out_bias,
    out_bias + N] (see ``multithreshold.infer_out_dtype``)."""
    from .multithreshold import infer_out_dtype
    if out_dtype is None:
        out_dtype = infer_out_dtype(thresholds.shape[0], out_bias)
    cnt = (x[:, None, :] >= thresholds[None, :, :]).sum(axis=1)
    return (cnt + out_bias).astype(out_dtype)


def multithreshold_searchsorted_ref(x: jnp.ndarray, thresholds: jnp.ndarray,
                                    out_bias: int = 0,
                                    out_dtype=None) -> jnp.ndarray:
    """Bisection formulation (the paper's Fig 17 search tree, as a jnp
    vectorized searchsorted) — same function, O(log N) comparisons."""
    from .multithreshold import infer_out_dtype
    if out_dtype is None:
        out_dtype = infer_out_dtype(thresholds.shape[0], out_bias)
    def per_channel(xc, tc):
        return jnp.searchsorted(tc, xc, side="right")
    cnt = jax.vmap(per_channel, in_axes=(1, 1), out_axes=1)(x, thresholds)
    return (cnt + out_bias).astype(out_dtype)


def quantize_ref(x: jnp.ndarray, scale: jnp.ndarray, zero_point: jnp.ndarray,
                 qmin: int = -128, qmax: int = 127,
                 out_dtype=jnp.int8) -> jnp.ndarray:
    q = jnp.round(x / scale.reshape(1, -1) + zero_point.reshape(1, -1))
    return jnp.clip(q, qmin, qmax).astype(out_dtype)
