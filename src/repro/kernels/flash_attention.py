"""Pallas TPU kernel: fused flash attention (forward), GQA-aware.

The roofline analysis (EXPERIMENTS.md §Perf) shows the jnp-level chunked
attention is the dominant HBM term for train/prefill cells: every online-
softmax intermediate (scores, exp, running max/denominator) is an HBM
round-trip at the XLA level.  This kernel keeps the whole (bq × bk) score
block in VMEM — HBM traffic collapses to Q/K/V reads + O writes, moving
the attention layers from memory-bound to compute-bound (the hypothesis →
measurement log lives in EXPERIMENTS.md).

Grid: (batch·kv_heads·q_groups, Sq/bq); each program scans KV chunks with
a fori_loop carrying (m, l, acc) in VMEM scratch.  Causal masking prunes
fully-masked KV chunks via early iteration bounds.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int, sk: int,
               scale: float, causal: bool, logit_cap: float):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
    q_pos = qi * bq + jax.lax.iota(jnp.int32, bq)

    n_k = sk // bk
    # causal: KV chunks beyond the last query row are fully masked
    last = jax.lax.div(((qi + 1) * bq - 1), bk) + 1 if causal else n_k

    def body(j, carry):
        m, l, acc = carry
        k = k_ref[0, pl.ds(j * bk, bk), :].astype(jnp.float32)   # (bk, hd)
        v = v_ref[0, pl.ds(j * bk, bk), :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if logit_cap:
            s = logit_cap * jnp.tanh(s / logit_cap)
        if causal:
            k_pos = j * bk + jax.lax.iota(jnp.int32, bk)
            mask = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, q.shape[-1]), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, last, body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "causal",
                                             "logit_cap", "interpret"))
def flash_attention_fwd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, bq: int = 512, bk: int = 512,
                        causal: bool = True, logit_cap: float = 0.0,
                        interpret: bool = False) -> jnp.ndarray:
    """q (B, Sq, H, hd); k/v (B, Sk, KV, hd) with H = KV·g → out like q.

    HBM traffic: read Q,K,V once; write O once.  Scores live in VMEM."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    g = H // KV
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0
    scale = hd ** -0.5

    # flatten (B, KV, g) into one grid axis; kv index = flat // g % KV
    qf = q.reshape(B, Sq, KV, g, hd).transpose(0, 2, 3, 1, 4) \
        .reshape(B * KV * g, Sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, Sk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, Sk, hd)

    kernel = functools.partial(_fa_kernel, bq=bq, bk=bk, sk=Sk,
                               scale=scale, causal=causal,
                               logit_cap=logit_cap)
    out = pl.pallas_call(
        kernel,
        grid=(B * KV * g, Sq // bq),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, Sk, hd), lambda h, i: (h // g, 0, 0)),
            pl.BlockSpec((1, Sk, hd), lambda h, i: (h // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV * g, Sq, hd), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, KV, g, Sq, hd).transpose(0, 3, 1, 2, 4) \
        .reshape(B, Sq, H, hd)


def flash_attention_ref(q, k, v, *, causal=True, logit_cap=0.0):
    """Pure-jnp oracle (materialized softmax)."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    g = H // KV
    qf = (q.astype(jnp.float32) * hd ** -0.5).reshape(B, Sq, KV, g, hd)
    s = jnp.einsum("bqkgh,bskh->bqkgs", qf, k.astype(jnp.float32))
    if logit_cap:
        s = logit_cap * jnp.tanh(s / logit_cap)
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqkgs,bskh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)
