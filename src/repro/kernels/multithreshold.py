"""Pallas TPU kernel: fused MultiThreshold layer tail (paper §4.1.3/§5.3).

Replaces the dequant → BN → activation → requant elementwise chain with a
single HBM pass: for each activation x and its channel's sorted threshold
vector T (length N = 2^n_o − 1),

    out = out_bias + out_zero + sum_i (x >= T_i)

TPU adaptation (DESIGN.md §2): the paper's binary-search RTL pipeline
(Fig 17) relies on per-stage LUT storage and does not transfer to the VPU.
The TPU-idiomatic equivalent is a vectorized broadcast-compare-accumulate
over the threshold axis with the thresholds resident in VMEM: for n_o ≤ 8
bits that is ≤255 comparisons amortized over 8×128 vector lanes, and the
whole tail stays memory-bound (one read of the accumulator tensor, one
write of the activation tensor) — the same economy the binary-search tree
buys on the FPGA.

Thresholds are stored transposed (N, C) so each compare step is a full
(bm, bc) vector op against a broadcast (1, bc) threshold row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def infer_out_dtype(n_thresholds: int, out_bias: int):
    """Smallest signed dtype that holds every possible output level.

    The count runs over [0, N], so the output range is
    [out_bias, out_bias + N].  A fixed int8 default silently wraps for
    8-bit unsigned tails (out_bias=0, N=255 → count 255 → -1), so the
    dtype must be derived from the actual range (or passed explicitly).
    """
    lo, hi = int(out_bias), int(out_bias) + int(n_thresholds)
    for dt, dmin, dmax in ((jnp.int8, -128, 127), (jnp.int16, -2**15, 2**15 - 1)):
        if dmin <= lo and hi <= dmax:
            return dt
    return jnp.int32


def _mt_kernel(x_ref, thr_ref, o_ref, *, n_thresholds: int, out_bias: int,
               out_dtype):
    x = x_ref[...]                       # (bm, bc) int32
    cnt = jnp.zeros(x.shape, jnp.int32)

    def body(i, cnt):
        t = thr_ref[i, :][None, :]       # (1, bc)
        return cnt + (x >= t).astype(jnp.int32)

    cnt = jax.lax.fori_loop(0, n_thresholds, body, cnt)
    o_ref[...] = (cnt + out_bias).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bc", "out_bias",
                                             "out_dtype", "interpret"))
def multithreshold(x: jnp.ndarray, thresholds: jnp.ndarray,
                   *, out_bias: int = 0, out_dtype=None,
                   bm: int = 256, bc: int = 128,
                   interpret: bool = False) -> jnp.ndarray:
    """x (M, C) integer accumulators; thresholds (N, C) ascending per column.

    Returns out (M, C): out_bias + #{i : x >= T[i, c]} as out_dtype
    (default: derived from the [out_bias, out_bias + N] output range).
    """
    M, C = x.shape
    N, C2 = thresholds.shape
    assert C == C2
    if out_dtype is None:
        out_dtype = infer_out_dtype(N, out_bias)
    bm, bc = min(bm, M), min(bc, C)
    assert M % bm == 0 and C % bc == 0, \
        f"shape ({M},{C}) not divisible by block ({bm},{bc})"
    kernel = functools.partial(_mt_kernel, n_thresholds=N,
                               out_bias=out_bias, out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=(M // bm, C // bc),
        in_specs=[
            pl.BlockSpec((bm, bc), lambda i, j: (i, j)),
            pl.BlockSpec((N, bc), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, C), out_dtype),
        interpret=interpret,
    )(x, thresholds)
