"""Pallas TPU kernel: integer matmul with SIRA-minimized accumulation and a
fused scale/bias dequantization epilogue.

This is the MXU realization of the paper's streamlined integer MatMul
(§4.1.2) + accumulator minimization (§4.2):

  * inputs are int8 (the revealed integer kernel), multiplied on the MXU's
    native 8-bit path with integer accumulation;
  * the accumulator dtype is *selected from the SIRA bound*: int16 tiles
    when the lossless width ≤ 15 bits (halving VMEM accumulator footprint,
    allowing 2× larger fused tiles), else int32;
  * the single aggregated scale/bias (the whole layer tail after
    aggregation) is applied as a fused epilogue on the final K step —
    exactly one HBM pass for matmul + tail.

Block sizes default to MXU-aligned (128×128×128) tiles, double-buffered by
the Pallas pipeline across the K grid axis.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, w_ref, scale_ref, bias_ref, o_ref, acc_ref, *,
                   k_steps: int, out_dtype, dequant: bool):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=acc_ref.dtype)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        acc = acc_ref[...]
        if dequant:
            s = scale_ref[...]            # (1, bn)
            b = bias_ref[...]             # (1, bn)
            o_ref[...] = (acc.astype(jnp.float32) * s + b).astype(out_dtype)
        else:
            o_ref[...] = acc.astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "acc_bits",
                                             "out_dtype", "interpret"))
def int_matmul(x: jnp.ndarray, w: jnp.ndarray,
               scale: Optional[jnp.ndarray] = None,
               bias: Optional[jnp.ndarray] = None,
               *, bm: int = 128, bn: int = 128, bk: int = 128,
               acc_bits: int = 32, out_dtype=None,
               interpret: bool = False) -> jnp.ndarray:
    """x (M, K) int8 @ w (K, N) int8 → int accumulate → optional dequant.

    acc_bits: SIRA-minimized accumulator width; ≤15 selects int16 tiles.
    scale/bias: per-output-channel (N,) aggregated layer-tail parameters;
    if given, output is float32 (dequantized), else the raw accumulator.
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, \
        f"shape ({M},{K},{N}) not divisible by block ({bm},{bk},{bn})"
    acc_dtype = jnp.int16 if acc_bits <= 15 else jnp.int32
    dequant = scale is not None
    if out_dtype is None:
        out_dtype = jnp.float32 if dequant else acc_dtype
    if scale is None:
        scale = jnp.ones((N,), jnp.float32)
    if bias is None:
        bias = jnp.zeros((N,), jnp.float32)
    scale2 = scale.reshape(1, N).astype(jnp.float32)
    bias2 = bias.reshape(1, N).astype(jnp.float32)

    k_steps = K // bk
    grid = (M // bm, N // bn, k_steps)
    kernel = functools.partial(_matmul_kernel, k_steps=k_steps,
                               out_dtype=out_dtype, dequant=dequant)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn),
                                   jnp.int16 if acc_bits <= 15
                                   else jnp.int32)],
        interpret=interpret,
    )(x, w, scale2, bias2)
