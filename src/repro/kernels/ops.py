"""Jitted public wrappers around the Pallas kernels.

On TPU the Pallas path is used; elsewhere (this CPU container) the wrappers
fall back to the jnp reference implementations, and the Pallas kernels are
validated in interpret mode by the test suite.  ``use_pallas`` can be
forced for interpret-mode execution.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .int_matmul import int_matmul as _int_matmul_pallas
from .multithreshold import multithreshold as _multithreshold_pallas
from .quantize import quantize as _quantize_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def int_matmul(x, w, scale=None, bias=None, *, acc_bits: int = 32,
               out_dtype=None, use_pallas: Optional[bool] = None,
               interpret: Optional[bool] = None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return _int_matmul_pallas(
            x, w, scale, bias, acc_bits=acc_bits, out_dtype=out_dtype,
            interpret=bool(interpret if interpret is not None
                           else not _on_tpu()))
    return ref.int_matmul_ref(x, w, scale, bias, acc_bits=acc_bits,
                              out_dtype=out_dtype)


def multithreshold(x, thresholds, *, out_bias: int = 0, out_dtype=jnp.int8,
                   use_pallas: Optional[bool] = None,
                   interpret: Optional[bool] = None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return _multithreshold_pallas(
            x, thresholds, out_bias=out_bias, out_dtype=out_dtype,
            interpret=bool(interpret if interpret is not None
                           else not _on_tpu()))
    return ref.multithreshold_ref(x, thresholds, out_bias=out_bias,
                                  out_dtype=out_dtype)


def quantize(x, scale, zero_point, *, qmin: int = -128, qmax: int = 127,
             out_dtype=jnp.int8, use_pallas: Optional[bool] = None,
             interpret: Optional[bool] = None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return _quantize_pallas(
            x, scale, zero_point, qmin=qmin, qmax=qmax, out_dtype=out_dtype,
            interpret=bool(interpret if interpret is not None
                           else not _on_tpu()))
    return ref.quantize_ref(x, scale, zero_point, qmin=qmin, qmax=qmax,
                            out_dtype=out_dtype)
