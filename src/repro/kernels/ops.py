"""Jitted public wrappers around the Pallas kernels.

On TPU the Pallas path is used; elsewhere (this CPU container) the wrappers
fall back to the jnp reference implementations, and the Pallas kernels are
validated in interpret mode by the test suite.  ``use_pallas`` can be
forced for interpret-mode execution.

The raw kernels hard-assert block divisibility (MXU/VPU tiles); these
wrappers make them total over real workload shapes (10-class heads,
3-channel inputs, odd batch sizes) by padding every blocked axis up to a
block multiple and slicing the result back.  Padding values are chosen so
the visible region is unaffected: zeros along contraction axes (contribute
nothing to the dot product), ones for padded scales (no 0/0), and padded
rows/columns are discarded by the final slice.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref
from .int_matmul import int_matmul as _int_matmul_pallas
from .multithreshold import infer_out_dtype  # noqa: F401  (re-exported)
from .multithreshold import multithreshold as _multithreshold_pallas
from .quantize import quantize as _quantize_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def _sublane(dtype) -> int:
    """Minimum second-to-last-dim tile for a dtype ((8,128) f32/i32,
    (16,128) bf16, (32,128) int8)."""
    size = jnp.dtype(dtype).itemsize
    return {1: 32, 2: 16}.get(size, 8)


def _block(dim: int, requested: int, base: int) -> int:
    """Shrink a requested block to the dimension (rounded up to the tile
    base) so small shapes get one padded block instead of a huge grid."""
    return min(requested, _round_up(max(dim, 1), base))


def _pad2d(x: jnp.ndarray, rows: int, cols: int, value=0) -> jnp.ndarray:
    r, c = x.shape
    if r == rows and c == cols:
        return x
    return jnp.pad(x, ((0, rows - r), (0, cols - c)),
                   constant_values=value)


def _pad1d(x: jnp.ndarray, n: int, value=0) -> jnp.ndarray:
    if x.shape[0] == n:
        return x
    return jnp.pad(x, (0, n - x.shape[0]), constant_values=value)


def _padded_blocks(dim: int, requested: int, base: int) -> Tuple[int, int]:
    b = _block(dim, requested, base)
    return b, _round_up(dim, b)


def int_matmul(x, w, scale=None, bias=None, *, acc_bits: int = 32,
               out_dtype=None, bm: int = 128, bn: int = 128, bk: int = 128,
               use_pallas: Optional[bool] = None,
               interpret: Optional[bool] = None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        M, K = x.shape
        _, N = w.shape
        bm, Mp = _padded_blocks(M, bm, _sublane(x.dtype))
        bk, Kp = _padded_blocks(K, bk, 128)
        bn, Np = _padded_blocks(N, bn, 128)
        xp = _pad2d(x, Mp, Kp)                   # zero K-pad: adds nothing
        wp = _pad2d(w, Kp, Np)
        # broadcast per-tensor (size-1) scale/bias to all N columns before
        # padding — padding a scalar with ones would scale only column 0
        sp = None if scale is None else _pad1d(
            jnp.broadcast_to(jnp.asarray(scale).reshape(-1), (N,)), Np, 1)
        bp = None if bias is None else _pad1d(
            jnp.broadcast_to(jnp.asarray(bias).reshape(-1), (N,)), Np, 0)
        out = _int_matmul_pallas(
            xp, wp, sp, bp, bm=bm, bn=bn, bk=bk, acc_bits=acc_bits,
            out_dtype=out_dtype,
            interpret=bool(interpret if interpret is not None
                           else not _on_tpu()))
        return out[:M, :N]
    return ref.int_matmul_ref(x, w, scale, bias, acc_bits=acc_bits,
                              out_dtype=out_dtype)


def multithreshold(x, thresholds, *, out_bias: int = 0, out_dtype=None,
                   bm: int = 256, bc: int = 128,
                   use_pallas: Optional[bool] = None,
                   interpret: Optional[bool] = None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        M, C = x.shape
        N = thresholds.shape[0]
        bm, Mp = _padded_blocks(M, bm, _sublane(x.dtype))
        bc, Cp = _padded_blocks(C, bc, 128)
        xp = _pad2d(x, Mp, Cp)
        tp = _pad2d(thresholds, N, Cp)           # padded columns sliced off
        out = _multithreshold_pallas(
            xp, tp, out_bias=out_bias,
            out_dtype=out_dtype if out_dtype is not None
            else infer_out_dtype(N, out_bias),
            bm=bm, bc=bc,
            interpret=bool(interpret if interpret is not None
                           else not _on_tpu()))
        return out[:M, :C]
    return ref.multithreshold_ref(x, thresholds, out_bias=out_bias,
                                  out_dtype=out_dtype)


def quantize(x, scale, zero_point, *, qmin: int = -128, qmax: int = 127,
             out_dtype=jnp.int8, bm: int = 256, bc: int = 128,
             use_pallas: Optional[bool] = None,
             interpret: Optional[bool] = None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        M, C = x.shape
        scale = jnp.broadcast_to(jnp.asarray(scale).reshape(1, -1),
                                 (1, C)).reshape(-1)
        zero_point = jnp.broadcast_to(jnp.asarray(zero_point).reshape(1, -1),
                                      (1, C)).reshape(-1)
        bm, Mp = _padded_blocks(M, bm, _sublane(x.dtype))
        bc, Cp = _padded_blocks(C, bc, 128)
        xp = _pad2d(x, Mp, Cp)
        sp = _pad1d(scale, Cp, 1)                # ones: no 0/0 in the pad
        zp = _pad1d(zero_point, Cp, 0)
        out = _quantize_pallas(
            xp, sp, zp, qmin=qmin, qmax=qmax, out_dtype=out_dtype,
            bm=bm, bc=bc,
            interpret=bool(interpret if interpret is not None
                           else not _on_tpu()))
        return out[:M, :C]
    return ref.quantize_ref(x, scale, zero_point, qmin=qmin, qmax=qmax,
                            out_dtype=out_dtype)
