"""Mamba2 block — SSD (state-space duality) chunked form (arXiv:2405.21060).

Prefill uses the chunked dual algorithm: quadratic attention-like compute
*within* chunks (MXU-friendly (C×C) blocks) and a linear recurrence over
per-chunk states *between* chunks (lax.scan).  Decode is the O(1) stateful
update.  The selective recurrence is input-dependent, so scaled-integer
structure does not propagate through the scan (DESIGN.md §4) — SIRA still
covers in/out projections and the conv.

Layout: x (B, S, d) → in_proj → [z (d_in), x (d_in), B (G·N), C (G·N),
dt (H)]; causal depthwise conv over (x, B, C); SSD over H heads of P =
d_in/H channels with state N; gated RMSNorm; out_proj.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from .common import BATCH, MODEL, dense_init, linear, rms_norm, shard


def init_mamba2(key, d: int, ssm: SSMConfig, dtype) -> Dict[str, Any]:
    d_in = ssm.expand * d
    H = d_in // ssm.head_dim
    G, N = ssm.n_groups, ssm.d_state
    d_proj = 2 * d_in + 2 * G * N + H
    d_conv_ch = d_in + 2 * G * N
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (d, d_proj), dtype=dtype),
        "conv_w": dense_init(ks[1], (ssm.d_conv, d_conv_ch),
                             scale=0.5, dtype=dtype),
        "conv_b": jnp.zeros((d_conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.linspace(1e-3, 0.1, H))).astype(jnp.float32),
        "norm_scale": jnp.zeros((d_in,), dtype),
        "out_proj": dense_init(ks[2], (d_in, d), scale=d_in ** -0.5,
                               dtype=dtype),
    }


def _split_proj(proj, d_in, G, N, H):
    z = proj[..., :d_in]
    xbc = proj[..., d_in:d_in + d_in + 2 * G * N]
    dt = proj[..., -H:]
    return z, xbc, dt


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv along S.  xbc: (B, S, Ch); w: (K, Ch).
    Returns (y, new_state) where state is the last K-1 inputs."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], K - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, xbc], axis=1)          # (B, S+K-1, Ch)
    y = sum(xp[:, i:i + xbc.shape[1], :] * w[i] for i in range(K)) + b
    new_state = xp[:, -(K - 1):, :]
    return jax.nn.silu(y), new_state


def ssd_chunked(xh, dt, A, B_, C_, chunk: int):
    """SSD dual-form scan.

    xh (B,S,H,P), dt (B,S,H) softplus'd, A (H,) >0 decay rates,
    B_/C_ (B,S,G,N) with G=1 broadcast over H.
    Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    Bb, S, H, P = xh.shape
    N = B_.shape[-1]
    nc = S // chunk
    assert S % chunk == 0

    lam = (dt * A[None, None, :]).astype(jnp.float32)      # (B,S,H) decay
    xw = (xh.astype(jnp.float32) * dt[..., None])          # dt-weighted input

    def resh(t, tail):
        return t.reshape((Bb, nc, chunk) + tail)

    lam_c = resh(lam, (H,))
    x_c = resh(xw, (H, P))
    B_c = resh(B_.astype(jnp.float32), (1, N))[:, :, :, 0]  # (B,nc,c,N) G=1
    C_c = resh(C_.astype(jnp.float32), (1, N))[:, :, :, 0]

    # lam >= 0 is the *negative* log decay: step decay = exp(-lam).
    csum = jnp.cumsum(lam_c, axis=2)                        # (B,nc,c,H)
    seg = csum[:, :, :, None, :] - csum[:, :, None, :, :]   # (B,nc,c,c,H)
    idx = jnp.arange(chunk)
    causal = idx[:, None] >= idx[None, :]
    L = jnp.where(causal[None, None, :, :, None],
                  jnp.exp(-seg), 0.0)                       # decay matrix

    # intra-chunk (quadratic, attention-like)
    scores = jnp.einsum("bncj,bnmj->bncm", C_c, B_c)        # (B,nc,c,c)
    y_intra = jnp.einsum("bncm,bncmh,bnmhp->bnchp",
                         scores, L, x_c)

    # per-chunk input→state: S_n = sum_m exp(-(csum_end - csum_m)) B_m x_m
    decay_to_end = jnp.exp(-(csum[:, :, -1:, :] - csum))    # (B,nc,c,H)
    state_in = jnp.einsum("bncj,bnch,bnchp->bnhpj",
                          B_c, decay_to_end, x_c)           # (B,nc,H,P,N)
    chunk_decay = jnp.exp(-csum[:, :, -1, :])               # (B,nc,H)

    def scan_fn(s, inp):
        s_in, dec = inp                                     # (B,H,P,N),(B,H)
        s_new = s * dec[:, :, None, None] + s_in
        return s_new, s

    s0 = jnp.zeros((Bb, H, P, N), jnp.float32)
    s_final, s_prevs = jax.lax.scan(
        scan_fn, s0,
        (jnp.moveaxis(state_in, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)                   # (B,nc,H,P,N)

    # inter-chunk: y_i += C_i · exp(-csum_i) S_prev (inclusive decay, since
    # h_i = dec_i·h_{i-1} + in_i applies dec_1..dec_i to the carry)
    decay_from_start = jnp.exp(-csum)
    y_inter = jnp.einsum("bncj,bnch,bnhpj->bnchp",
                         C_c, decay_from_start, s_prevs)

    y = (y_intra + y_inter).reshape(Bb, S, H, P)
    return y, s_final


def apply_mamba2(params, x, ssm: SSMConfig, *, quant=None,
                 state: Optional[Dict[str, jnp.ndarray]] = None,
                 decode: bool = False):
    """x (B, S, d).  Prefill: state=None, decode=False → (y, final_states).
    Decode: S==1 with state dict → (y, new_state)."""
    Bb, S, d = x.shape
    d_in = ssm.expand * d
    H = d_in // ssm.head_dim
    G, N, P = ssm.n_groups, ssm.d_state, ssm.head_dim

    proj = linear(x, params["in_proj"], quant=quant)
    z, xbc, dt_raw = _split_proj(proj, d_in, G, N, H)
    xbc = shard(xbc, BATCH, None, MODEL)

    conv_state = state.get("conv") if state else None
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                 conv_state)
    xs = xbc[..., :d_in]
    B_ = xbc[..., d_in:d_in + G * N].reshape(Bb, S, G, N)
    C_ = xbc[..., d_in + G * N:].reshape(Bb, S, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         params["dt_bias"])                  # (B,S,H)
    A = jnp.exp(params["A_log"])                             # (H,) > 0
    xh = xs.reshape(Bb, S, H, P)
    xh = shard(xh, BATCH, None, MODEL, None)

    if decode:
        assert S == 1 and state is not None
        s_prev = state["ssd"]                                # (B,H,P,N)
        lam = (dt[:, 0, :] * A[None, :])                     # (B,H)
        dec = jnp.exp(-lam)
        xw = xh[:, 0].astype(jnp.float32) * dt[:, 0, :, None]
        s_new = s_prev * dec[:, :, None, None] + \
            jnp.einsum("bj,bhp->bhpj", B_[:, 0, 0].astype(jnp.float32), xw)
        y = jnp.einsum("bj,bhpj->bhp", C_[:, 0, 0].astype(jnp.float32),
                       s_new)
        y = y[:, None]                                       # (B,1,H,P)
        s_final = s_new
    else:
        y, s_final = ssd_chunked(xh, dt, A, B_, C_, min(ssm.chunk, S))

    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(Bb, S, d_in).astype(x.dtype)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = rms_norm(y * jax.nn.silu(z), params["norm_scale"])
    out = linear(y, params["out_proj"], quant=quant)
    out = shard(out, BATCH, None, None)
    new_state = {"conv": new_conv, "ssd": s_final}
    return out, new_state


def init_mamba_state(batch: int, d: int, ssm: SSMConfig, dtype
                     ) -> Dict[str, jnp.ndarray]:
    d_in = ssm.expand * d
    H = d_in // ssm.head_dim
    ch = d_in + 2 * ssm.n_groups * ssm.d_state
    return {
        "conv": jnp.zeros((batch, ssm.d_conv - 1, ch), dtype),
        "ssd": jnp.zeros((batch, H, ssm.head_dim, ssm.d_state),
                         jnp.float32),
    }
