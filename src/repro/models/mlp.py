"""Gated MLP (SwiGLU / GeGLU) with QAT hooks."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from .common import BATCH, MODEL, dense_init, linear, shard


def init_mlp(key, d: int, ff: int, dtype) -> Dict[str, Any]:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d, ff), dtype=dtype),
        "w_up": dense_init(ks[1], (d, ff), dtype=dtype),
        "w_down": dense_init(ks[2], (ff, d), scale=ff ** -0.5, dtype=dtype),
    }


def apply_mlp(params, x, act: str = "silu", quant=None) -> jnp.ndarray:
    g = linear(x, params["w_gate"], quant=quant)
    u = linear(x, params["w_up"], quant=quant)
    g = shard(g, BATCH, None, MODEL)
    u = shard(u, BATCH, None, MODEL)
    h = (jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)) * u
    y = linear(h, params["w_down"], quant=quant)
    return shard(y, BATCH, None, None)
