"""Top-k routed MoE with shared experts (GShard-style grouped dispatch).

Tokens are grouped by batch row (G = B groups of S·k slots, the GShard
"group" that bounds dispatch memory); within each group tokens are placed
into per-expert capacity queues with a sort-free rank computation, then
scattered into the (B, E, C, d) expert-parallel layout.  The expert axis
shards on the mesh "model" axis, so GSPMD materializes the dispatch/return
all_to_all pair — the EP collective that the roofline analysis tracks.

Aux losses: Switch load-balance + router z-loss.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .common import BATCH, MODEL, dense_init, shard
from .mlp import apply_mlp, init_mlp


def init_moe(key, d: int, n_experts: int, d_expert: int, n_shared: int,
             dtype, n_experts_padded: int = 0) -> Dict[str, Any]:
    """n_experts_padded (>= n_experts, multiple of the model-axis size)
    sizes the expert arrays for expert parallelism; pad experts are never
    routed to."""
    ep = n_experts_padded or n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, n_experts), scale=d ** -0.5,
                             dtype=jnp.float32),
        "experts": {
            "w_gate": dense_init(ks[1], (ep, d, d_expert),
                                 dtype=dtype),
            "w_up": dense_init(ks[2], (ep, d, d_expert), dtype=dtype),
            "w_down": dense_init(ks[3], (ep, d_expert, d),
                                 scale=d_expert ** -0.5, dtype=dtype),
        },
    }
    if n_shared:
        p["shared"] = init_mlp(ks[4], d, n_shared * d_expert, dtype)
    return p


def _rank_in_expert(flat_e: jnp.ndarray, n_experts: int) -> jnp.ndarray:
    """Position of each slot within its expert's queue (stable order).

    flat_e: (n,) int expert ids → (n,) int ranks, without materializing a
    (n, E) one-hot (argsort-based; O(n log n))."""
    n = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    start = jnp.searchsorted(sorted_e, jnp.arange(n_experts), side="left")
    pos_sorted = jnp.arange(n) - start[sorted_e]
    return jnp.zeros((n,), jnp.int32).at[order].set(
        pos_sorted.astype(jnp.int32))


def apply_moe(params, x, *, top_k: int, capacity_factor: float = 1.25,
              act: str = "silu", quant=None
              ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: (B, S, d) → (y, aux_losses)."""
    B, S, d = x.shape
    E = params["router"].shape[-1]

    E_pad = params["experts"]["w_gate"].shape[0]
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"])                    # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)        # (B, S, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    C = int(max(top_k, capacity_factor * S * top_k / E))
    C = min(C, S * top_k)

    flat_e = gate_idx.reshape(B, S * top_k)                  # (B, n)
    pos = jax.vmap(lambda fe: _rank_in_expert(fe, E_pad))(flat_e)
    in_cap = pos < C
    pos_c = jnp.clip(pos, 0, C - 1)

    # dispatch: scatter x into (B, E, C, d)
    x_rep = jnp.repeat(x, top_k, axis=1).reshape(B, S * top_k, d)
    x_disp = jnp.where(in_cap[..., None], x_rep, 0)

    def scatter_group(xg, eg, pg):
        return jnp.zeros((E_pad, C, d), xg.dtype).at[eg, pg].add(xg)

    xe = jax.vmap(scatter_group)(x_disp, flat_e, pos_c)      # (B, E, C, d)
    xe = shard(xe, BATCH, MODEL, None, None)                 # EP layout

    we = params["experts"]
    g = jnp.einsum("becd,edf->becf", xe, we["w_gate"])
    u = jnp.einsum("becd,edf->becf", xe, we["w_up"])
    h = (jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)) * u
    ye = jnp.einsum("becf,efd->becd", h, we["w_down"])
    ye = shard(ye, BATCH, MODEL, None, None)

    # combine: gather back and weight by gates
    def gather_group(yg, eg, pg):
        return yg[eg, pg]                                    # (n, d)

    y_tok = jax.vmap(gather_group)(ye, flat_e, pos_c)        # (B, n, d)
    w_tok = (gate_vals.reshape(B, S * top_k) *
             in_cap.astype(gate_vals.dtype))
    y = (y_tok.astype(jnp.float32) * w_tok[..., None]).reshape(
        B, S, top_k, d).sum(axis=2).astype(x.dtype)
    y = shard(y, BATCH, None, None)

    if "shared" in params:
        y = y + apply_mlp(params["shared"], x, act=act, quant=quant)

    # aux: Switch load-balance + router z-loss
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)   # (B,S,k,E)
    density = onehot.sum(2).mean((0, 1))                      # (E,)
    density_proxy = probs.mean((0, 1))
    lb_loss = E * jnp.sum(density * density_proxy)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return y, {"load_balance": lb_loss, "router_z": z_loss}
