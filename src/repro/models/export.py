"""Export quantized LM blocks into the SIRA graph IR.

This is the bridge between the JAX model zoo and the paper's analysis: a
transformer block's weight-static matmul chains (QKV/O projections, the
gated MLP, MoE expert FFNs, Mamba in/out projections) are materialized as
a QONNX-style graph with Quant nodes, so SIRA can aggregate scales, size
accumulators, and convert eligible tails to thresholds for the integer
serving path (DESIGN.md §4).

Dynamic×dynamic parts (attention scores, SSM recurrence, gate products)
propagate plain interval ranges only — scaled-integer structure stops
there by the paper's rules, and the next Quant re-anchors it.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.graph import Graph
from repro.core.intervals import ScaledIntRange


def _quant(g: Graph, x: str, scale, bits: int, signed: int, out: str) -> str:
    s = g.add_initializer(scale)
    z = g.add_initializer(0.0)
    b = g.add_initializer(float(bits))
    g.add_node("Quant", [x, s, z, b], [out], dict(signed=signed, narrow=0))
    return out


def _qmatmul(g: Graph, rng, x: str, k: int, m: int, w_bits: int,
             prefix: str) -> str:
    W = rng.normal(size=(k, m)) * (1.0 / np.sqrt(k))
    w = g.add_initializer(W, f"{prefix}_W")
    sw = np.maximum(np.abs(W).max(axis=0) / (2 ** (w_bits - 1) - 1), 1e-8)
    wq = _quant(g, w, sw, w_bits, 1, f"{prefix}_Wq")
    g.add_node("MatMul", [x, wq], [f"{prefix}_mm"])
    return f"{prefix}_mm"


def export_block_graph(cfg: ArchConfig, w_bits: int = 4, a_bits: int = 4,
                       seed: int = 0
                       ) -> Tuple[Graph, Dict[str, ScaledIntRange]]:
    """One quantized block of ``cfg`` as a SIRA graph.

    Returns (graph, input_ranges).  The block input is assumed calibrated
    to [-4, 4] (typical post-norm activation range)."""
    rng = np.random.default_rng(seed)
    d = cfg.d_model
    g = Graph(inputs=["X"], outputs=[])
    x = _quant(g, "X", 8.0 / (2 ** a_bits), a_bits, 1, "Xq")

    outs = []
    if cfg.n_heads:
        hh = cfg.n_heads * cfg.hd
        kvh = cfg.n_kv_heads * cfg.hd
        for name, m in [("wq", hh), ("wk", kvh), ("wv", kvh)]:
            mm = _qmatmul(g, rng, x, d, m, w_bits, name)
            outs.append(_quant(g, mm, 0.1, a_bits, 1, f"{name}_out"))
        # o-projection fed by a re-quantized attention output
        attn = _quant(g, "Attn", 8.0 / (2 ** a_bits), a_bits, 1, "attn_q")
        mm = _qmatmul(g, rng, attn, hh, d, w_bits, "wo")
        outs.append(_quant(g, mm, 0.1, a_bits, 1, "wo_out"))

    if cfg.family == "ssm" or cfg.family == "hybrid":
        d_in = cfg.ssm.expand * d
        d_proj = 2 * d_in + 2 * cfg.ssm.n_groups * cfg.ssm.d_state + \
            max(d_in // cfg.ssm.head_dim, 1)
        mm = _qmatmul(g, rng, x, d, d_proj, w_bits, "in_proj")
        outs.append(_quant(g, mm, 0.1, a_bits, 1, "in_proj_out"))
        ssm_out = _quant(g, "SSMout", 8.0 / (2 ** a_bits), a_bits, 1,
                         "ssm_q")
        mm = _qmatmul(g, rng, ssm_out, d_in, d, w_bits, "out_proj")
        outs.append(_quant(g, mm, 0.1, a_bits, 1, "out_proj_out"))
    elif cfg.moe.n_experts:
        fe = cfg.moe.d_expert
        mm = _qmatmul(g, rng, x, d, fe, w_bits, "expert_up")
        # gated product is dynamic×dynamic → range-only region; the next
        # quantizer re-anchors the integer structure
        g.add_node("Silu", [mm], ["expert_act"])
        h = _quant(g, "expert_act", 0.05, a_bits, 1, "expert_h")
        mm2 = _qmatmul(g, rng, h, fe, d, w_bits, "expert_down")
        outs.append(_quant(g, mm2, 0.1, a_bits, 1, "expert_out"))
    elif cfg.d_ff:
        ff = cfg.d_ff
        mm = _qmatmul(g, rng, x, d, ff, w_bits, "w_up")
        if cfg.mlp_act == "gelu":
            g.add_node("Gelu", [mm], ["mlp_act"])
        else:
            g.add_node("Silu", [mm], ["mlp_act"])
        h = _quant(g, "mlp_act", 0.05, a_bits, 1, "mlp_h")
        mm2 = _qmatmul(g, rng, h, ff, d, w_bits, "w_down")
        outs.append(_quant(g, mm2, 0.1, a_bits, 1, "mlp_out"))

    g.outputs = outs
    inputs = {"X": ScaledIntRange(lo=np.asarray(-4.0), hi=np.asarray(4.0))}
    if cfg.n_heads:
        inputs["Attn"] = ScaledIntRange(lo=np.asarray(-4.0),
                                        hi=np.asarray(4.0))
    if cfg.family in ("ssm", "hybrid"):
        inputs["SSMout"] = ScaledIntRange(lo=np.asarray(-4.0),
                                          hi=np.asarray(4.0))
    g.inputs = list(inputs)
    return g, inputs


def export_kv_proj_graph(Wk: np.ndarray, Wv: np.ndarray, *,
                         bk: np.ndarray = None, bv: np.ndarray = None,
                         x_lo: float = -4.0, x_hi: float = 4.0,
                         a_bits: int = 8, w_bits: int = 8
                         ) -> Tuple[Graph, Dict[str, ScaledIntRange]]:
    """K/V projection subgraph of one attention layer, built from the
    *actual serving weights*, as a SIRA graph.

    This is what makes the serving KV cache the first consumer of SIRA
    ranges outside the graph IR: running ``core.propagate.analyze`` on
    this graph yields per-output-channel value intervals for the K and V
    tensors entering the cache (outputs ``k_mm`` / ``v_mm``), from which
    ``serve.kv_cache`` derives guaranteed-coverage int8 storage scales
    (A2Q-style: saturation only outside the statically-proven range).

    The input X models the post-norm activation feeding wk/wv, quantized
    per the serving activation precision; weights carry per-output-channel
    Quant nodes so the MatMul propagates scaled-integer structure.
    """
    g = Graph(inputs=["X"], outputs=[])
    s = g.add_initializer(max(abs(x_lo), abs(x_hi)) / (2 ** (a_bits - 1)))
    z = g.add_initializer(0.0)
    b = g.add_initializer(float(a_bits))
    g.add_node("Quant", ["X", s, z, b], ["Xq"], dict(signed=1, narrow=0))
    for name, W, bias in (("k", Wk, bk), ("v", Wv, bv)):
        W = np.asarray(W, np.float64)
        w = g.add_initializer(W, f"{name}_W")
        sw = np.maximum(np.abs(W).max(axis=0) / (2 ** (w_bits - 1) - 1),
                        1e-8)
        ws = g.add_initializer(sw)
        wb = g.add_initializer(float(w_bits))
        g.add_node("Quant", [w, ws, z, wb], [f"{name}_Wq"],
                   dict(signed=1, narrow=0))
        if bias is not None:
            g.add_node("MatMul", ["Xq", f"{name}_Wq"], [f"{name}_proj"])
            bi = g.add_initializer(np.asarray(bias, np.float64),
                                   f"{name}_b")
            g.add_node("Add", [f"{name}_proj", bi], [f"{name}_mm"])
        else:
            g.add_node("MatMul", ["Xq", f"{name}_Wq"], [f"{name}_mm"])
    g.outputs = ["k_mm", "v_mm"]
    inputs = {"X": ScaledIntRange(lo=np.asarray(float(x_lo)),
                                  hi=np.asarray(float(x_hi)))}
    return g, inputs
