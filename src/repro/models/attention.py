"""GQA attention: flash-style chunked prefill, KV-cached decode.

Features per the assigned configs: grouped KV heads, RoPE, optional QKV
bias, attention logit soft-capping (gemma2), sliding-window masking for
local layers (gemma2 alternation).

The prefill path is a jax-native flash attention: lax.scan over KV chunks
with online softmax — memory O(S · chunk) instead of O(S²), which is what
lets the 32k-prefill cells fit (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .common import BATCH, MODEL, apply_rope, dense_init, linear, shard

NEG_INF = -1e30

# dtype for the post-softmax probabilities entering the PV matmul.
# f32 is the conservative baseline; bf16 halves the dominant score-class
# HBM traffic (hillclimb iteration, EXPERIMENTS.md §Perf).
P_DTYPE = jnp.float32

# int8 KV-cache scale (SIRA-style scaled-integer cache): k/v values are
# stored as round(x / KV_SCALE) in int8; post-norm attention activations
# sit in ~[-4, 4], so 1/16 covers the range with 6+ bits of resolution.
KV_SCALE = 1.0 / 16.0


def init_attention(key, d: int, n_heads: int, n_kv: int, hd: int,
                   qkv_bias: bool, dtype) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, n_heads * hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, n_kv * hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, n_kv * hd), dtype=dtype),
        "wo": dense_init(ks[3], (n_heads * hd, d),
                         scale=(n_heads * hd) ** -0.5, dtype=dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((n_kv * hd,), dtype)
        p["bv"] = jnp.zeros((n_kv * hd,), dtype)
    return p


def _qkv(params, x, n_heads, n_kv, hd, positions, theta, quant=None):
    B, S, _ = x.shape
    q = linear(x, params["wq"], params.get("bq"), quant)
    k = linear(x, params["wk"], params.get("bk"), quant)
    v = linear(x, params["wv"], params.get("bv"), quant)
    q = q.reshape(B, S, n_heads, hd)
    k = k.reshape(B, S, n_kv, hd)
    v = v.reshape(B, S, n_kv, hd)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    q = shard(q, BATCH, None, MODEL, None)
    k = shard(k, BATCH, None, MODEL, None)
    v = shard(v, BATCH, None, MODEL, None)
    return q, k, v


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    *, causal: bool = True, window: int = 0,
                    logit_cap: float = 0.0, chunk: int = 1024,
                    q_offset: int = 0) -> jnp.ndarray:
    """Online-softmax attention, scanning KV chunks.

    q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd).  window > 0 restricts each
    query to the last ``window`` keys (sliding-window local attention)."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    groups = H // KV
    scale = hd ** -0.5
    chunk = min(chunk, Sk)
    while Sk % chunk != 0:      # largest divisor of Sk not above chunk
        chunk -= 1
    n_chunks = Sk // chunk

    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, KV, groups, hd)
    kc = k.astype(jnp.float32).reshape(B, n_chunks, chunk, KV, hd)
    vc = v.astype(jnp.float32).reshape(B, n_chunks, chunk, KV, hd)
    kc = jnp.moveaxis(kc, 1, 0)       # (n, B, chunk, KV, hd)
    vc = jnp.moveaxis(vc, 1, 0)

    q_pos = q_offset + jnp.arange(Sq)

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, idx = inp
        k_pos = idx * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqkgh,bckh->bqkgc", qf, kb)      # (B,Sq,KV,g,chunk)
        if logit_cap:
            s = logit_cap * jnp.tanh(s / logit_cap)
        mask = jnp.ones((Sq, chunk), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window:
            mask &= q_pos[:, None] - k_pos[None, :] < window
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bqkgc,bckh->bqkgh", p.astype(P_DTYPE),
                        vb.astype(P_DTYPE)).astype(jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, KV, groups), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, KV, groups), jnp.float32)
    a0 = jnp.zeros((B, Sq, KV, groups, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (kc, vc, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def attention_prefill(params, x, *, n_heads, n_kv, hd, theta,
                      qkv_bias=False, logit_cap=0.0, window=0,
                      chunk=1024, quant=None,
                      return_kv=False):
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    q, k, v = _qkv(params, x, n_heads, n_kv, hd, positions, theta, quant)
    out = flash_attention(q, k, v, causal=True, window=window,
                          logit_cap=logit_cap, chunk=min(chunk, S))
    y = linear(out.reshape(B, S, n_heads * hd), params["wo"], quant=quant)
    y = shard(y, BATCH, None, None)
    if return_kv:
        return y, (k, v)
    return y


def attention_decode(params, x, cache: Dict[str, jnp.ndarray],
                     cache_index: jnp.ndarray, *, n_heads, n_kv, hd, theta,
                     qkv_bias=False, logit_cap=0.0, window=0, quant=None,
                     rolling: bool = False, valid_from=None
                     ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Single-token decode against a (B, S_max, KV, hd) cache.

    rolling=True treats the cache as a circular buffer of length S_max
    (sliding-window local attention): writes go to ``index mod S_max``;
    once the buffer has wrapped, every slot is a valid in-window key.

    valid_from (B,) marks each row's first valid cache slot: left-padded
    prompts occupy slots [valid_from[b], cache_index]; earlier slots hold
    pad garbage and are masked out of the attention, and RoPE positions
    are shifted per row so slot valid_from[b] is position 0 — making each
    batch row's math identical to serving that request alone.  Not
    supported for rolling (sliding-window) caches."""
    B, S1, _ = x.shape  # S1 == 1
    S_max = cache["k"].shape[1]
    if valid_from is not None and rolling:
        raise NotImplementedError("valid_from with a rolling cache")
    if valid_from is None:
        positions = jnp.broadcast_to(cache_index[None, None], (B, S1))
    else:
        positions = jnp.maximum(cache_index - valid_from, 0)[:, None]
    q, k, v = _qkv(params, x, n_heads, n_kv, hd, positions, theta, quant)
    slot = jnp.mod(cache_index, S_max) if rolling else cache_index
    int_cache = cache["k"].dtype == jnp.int8
    if int_cache:  # scaled-integer KV cache (2x HBM saving on the
        #            dominant decode term; see EXPERIMENTS.md §Perf)
        k_st = jnp.clip(jnp.round(k.astype(jnp.float32) / KV_SCALE),
                        -127, 127).astype(jnp.int8)
        v_st = jnp.clip(jnp.round(v.astype(jnp.float32) / KV_SCALE),
                        -127, 127).astype(jnp.int8)
    else:
        k_st, v_st = k.astype(cache["k"].dtype), v.astype(cache["v"].dtype)
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k_st,
                                           (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v_st,
                                           (0, slot, 0, 0))
    kv_deq = KV_SCALE if int_cache else 1.0
    groups = n_heads // n_kv
    qf = (q.astype(jnp.float32) * hd ** -0.5 * kv_deq).reshape(
        B, S1, n_kv, groups, hd)
    s = jnp.einsum("bqkgh,bskh->bqkgs", qf, k_cache.astype(jnp.float32))
    if logit_cap:
        s = logit_cap * jnp.tanh(s / logit_cap)
    k_pos = jnp.arange(S_max)
    mask = k_pos <= cache_index
    if rolling:
        mask = mask | (cache_index >= S_max)
    if window:
        mask &= k_pos > cache_index - window
    if valid_from is not None:
        mask = mask[None, :] & (k_pos[None, :] >= valid_from[:, None])
        s = jnp.where(mask[:, None, None, None, :], s, NEG_INF)
    else:
        s = jnp.where(mask[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgs,bskh->bqkgh", p,
                     v_cache.astype(jnp.float32) * kv_deq)
    out = out.reshape(B, S1, n_heads * hd).astype(x.dtype)
    y = linear(out, params["wo"], quant=quant)
    return y, {"k": k_cache, "v": v_cache}


def init_kv_cache(batch: int, s_max: int, n_kv: int, hd: int, dtype
                  ) -> Dict[str, jnp.ndarray]:
    return {"k": jnp.zeros((batch, s_max, n_kv, hd), dtype),
            "v": jnp.zeros((batch, s_max, n_kv, hd), dtype)}


# --------------------------------------------------------------------------
# paged KV cache attention (serving path)
# --------------------------------------------------------------------------

def quantize_kv(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Scaled-integer KV storage: round(x / scale) saturated to int8.

    ``scale`` is per-KV-head (KV,) — derived from SIRA range analysis of
    the exported K/V projection graph (serve/kv_cache.py), so saturation
    only triggers when an activation escapes its statically-proven range.
    """
    s = jnp.asarray(scale, jnp.float32).reshape(1, 1, -1, 1)
    q = jnp.round(x.astype(jnp.float32) / s)
    return jnp.clip(q, -127, 127).astype(jnp.int8)


def paged_attention(params, x, k_pages, v_pages, page_table, lengths, *,
                    n_heads, n_kv, hd, theta, page_size,
                    logit_cap=0.0, quant=None, k_scale=None, v_scale=None
                    ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Multi-token attention against a paged KV cache.

    One function covers all three serving phases: chunked prefill is a
    call with B=1, T=chunk; batched decode is B=slots, T=1; speculative
    verification is B=slots, T=spec_k+1 — each slot's draft window sits
    at its own offset ``lengths[b]``, and the position mask makes token t
    attend exactly to the cache plus the drafts before it, so
    ``logits[:, t]`` equals what t sequential single-token calls would
    produce.

      x          (B, T, d)    chunk of new tokens per slot
      k_pages    (P, page_size, KV, hd)   shared physical page pool
      v_pages    (P, page_size, KV, hd)   (int8 → scaled-integer storage)
      page_table (B, n_pages) int32 physical page per logical page; page 0
                 is the trash page (idle slots write there, never read live)
      lengths    (B,) tokens already cached per slot; the chunk occupies
                 logical positions [lengths[b], lengths[b] + T)

    The chunk's K/V are written (quantized if the pool is int8) *before*
    the read, so queries attend to the same storage roundtrip the next
    step will see — keys at k_pos <= own position (causal within chunk
    falls out of the position mask).  Dequantization happens here, folded
    into the query scaling (K) and the PV output (V), per KV head.
    Returns (y, k_pages, v_pages).

    Scatter-before-gather is also the speculative-rollback contract: a
    rejected draft's K/V stay in the pool as garbage past the slot's
    committed length, unreadable (every mask is position <= query, and
    queries never precede the length pointer) until the next call's
    scatter overwrites them — rolling back is just not advancing the
    pointer (``serve/kv_cache.py rollback``).
    """
    B, T, _ = x.shape
    n_pages = page_table.shape[1]
    S_v = n_pages * page_size
    positions = lengths[:, None] + jnp.arange(T)[None, :]        # (B, T)
    q, k, v = _qkv(params, x, n_heads, n_kv, hd, positions, theta, quant)

    int_cache = k_pages.dtype == jnp.int8
    if int_cache:
        k_st, v_st = quantize_kv(k, k_scale), quantize_kv(v, v_scale)
    else:
        k_st = k.astype(k_pages.dtype)
        v_st = v.astype(v_pages.dtype)

    # scatter the chunk into its pages: position p lives in physical page
    # page_table[b, p // page_size] at row p % page_size.  Positions past
    # the table (pad tail of a prefill chunk at max_seq) are redirected to
    # the trash page — take_along_axis would otherwise clamp them onto the
    # last live page and corrupt it.
    in_range = positions < S_v
    page_ids = jnp.take_along_axis(
        page_table, jnp.where(in_range, positions // page_size, 0), axis=1)
    page_ids = jnp.where(in_range, page_ids, 0)                  # (B, T)
    offs = jnp.where(in_range, positions % page_size, 0)
    flat_p, flat_o = page_ids.reshape(-1), offs.reshape(-1)
    k_pages = k_pages.at[flat_p, flat_o].set(k_st.reshape(B * T, n_kv, hd))
    v_pages = v_pages.at[flat_p, flat_o].set(v_st.reshape(B * T, n_kv, hd))

    # gather each slot's logical view (trash/garbage slots masked below)
    kc = k_pages[page_table].reshape(B, S_v, n_kv, hd).astype(jnp.float32)
    vc = v_pages[page_table].reshape(B, S_v, n_kv, hd).astype(jnp.float32)

    groups = n_heads // n_kv
    qf = (q.astype(jnp.float32) * hd ** -0.5).reshape(B, T, n_kv, groups, hd)
    if int_cache:  # fold K dequant into q, per KV head
        qf = qf * jnp.asarray(k_scale, jnp.float32).reshape(1, 1, n_kv, 1, 1)
    s = jnp.einsum("btkgh,bskh->btkgs", qf, kc)
    if logit_cap:
        s = logit_cap * jnp.tanh(s / logit_cap)
    k_pos = jnp.arange(S_v)
    mask = k_pos[None, None, :] <= positions[:, :, None]         # (B, T, S_v)
    s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("btkgs,bskh->btkgh", p, vc)
    if int_cache:
        out = out * jnp.asarray(v_scale, jnp.float32).reshape(1, 1, n_kv,
                                                              1, 1)
    out = out.reshape(B, T, n_heads * hd).astype(x.dtype)
    y = linear(out, params["wo"], quant=quant)
    return y, k_pages, v_pages
