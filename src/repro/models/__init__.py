"""LM model zoo: dense/GQA, MoE, Mamba2 SSD, Zamba2 hybrid, VLM/audio."""
from .transformer import Model, get_model          # noqa: F401
from .common import shard, rms_norm, linear        # noqa: F401
from .attention import flash_attention             # noqa: F401
