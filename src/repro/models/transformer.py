"""Top-level model assembly for all assigned architecture families.

One functional ``Model`` facade per ArchConfig:

  * ``init(key)``        → params pytree (repeated layers stacked for scan)
  * ``forward(...)``     → logits (training teacher-forcing / prefill)
  * ``init_cache(...)``  → decode cache pytree (KV / SSM states)
  * ``decode_step(...)`` → (logits, new_cache) for one token
  * ``loss(...)``        → mean token cross-entropy (+ MoE aux)

Families: dense (incl. gemma2 local/global alternation + softcaps), moe,
ssm (Mamba2), hybrid (Zamba2: Mamba2 backbone + one shared attention
block applied every ``attn_every`` layers), vlm/audio (dense backbone +
frontend embedding stub prepended per the assignment).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .attention import (attention_decode, attention_prefill, init_attention,
                        init_kv_cache, paged_attention)
from .common import (BATCH, MODEL, dense_init, embed_init, rms_norm,
                     shard, softcap)
from .mlp import apply_mlp, init_mlp
from .moe import apply_moe, init_moe
from .ssm import apply_mamba2, init_mamba2, init_mamba_state


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


# --------------------------------------------------------------------------
# layer init
# --------------------------------------------------------------------------

def _init_dense_layer(key, cfg: ArchConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 2)
    d = cfg.d_model
    p = {
        "ln1": jnp.zeros((d,), cfg.dtype),
        "attn": init_attention(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                               cfg.hd, cfg.qkv_bias, cfg.dtype),
        "ln2": jnp.zeros((d,), cfg.dtype),
        "mlp": init_mlp(ks[1], d, cfg.d_ff, cfg.dtype),
    }
    if cfg.post_norms:
        p["post_ln1"] = jnp.zeros((d,), cfg.dtype)
        p["post_ln2"] = jnp.zeros((d,), cfg.dtype)
    return p


def _init_moe_layer(key, cfg: ArchConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 2)
    d = cfg.d_model
    return {
        "ln1": jnp.zeros((d,), cfg.dtype),
        "attn": init_attention(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                               cfg.hd, cfg.qkv_bias, cfg.dtype),
        "ln2": jnp.zeros((d,), cfg.dtype),
        "moe": init_moe(ks[1], d, cfg.moe.n_experts, cfg.moe.d_expert,
                        cfg.moe.n_shared, cfg.dtype,
                        n_experts_padded=cfg.n_experts_padded),
    }


def _init_mamba_layer(key, cfg: ArchConfig) -> Dict[str, Any]:
    return {
        "ln": jnp.zeros((cfg.d_model,), cfg.dtype),
        "mamba": init_mamba2(key, cfg.d_model, cfg.ssm, cfg.dtype),
    }


# --------------------------------------------------------------------------
# layer apply (prefill & decode variants)
# --------------------------------------------------------------------------

def _dense_layer_fwd(p, x, cfg: ArchConfig, *, window: int, quant=None):
    h = attention_prefill(
        p["attn"], rms_norm(x, p["ln1"]), n_heads=cfg.n_heads,
        n_kv=cfg.n_kv_heads, hd=cfg.hd, theta=cfg.rope_theta,
        logit_cap=cfg.attn_softcap, window=window, quant=quant)
    if cfg.post_norms:
        h = rms_norm(h, p["post_ln1"])
    x = x + h
    h = apply_mlp(p["mlp"], rms_norm(x, p["ln2"]), act=cfg.mlp_act,
                  quant=quant)
    if cfg.post_norms:
        h = rms_norm(h, p["post_ln2"])
    return x + h


def _dense_layer_dec(p, x, cache, idx, cfg: ArchConfig, *, window: int,
                     quant=None, rolling: bool = False, valid_from=None):
    h, cache = attention_decode(
        p["attn"], rms_norm(x, p["ln1"]), cache, idx, n_heads=cfg.n_heads,
        n_kv=cfg.n_kv_heads, hd=cfg.hd, theta=cfg.rope_theta,
        logit_cap=cfg.attn_softcap, window=window, quant=quant,
        rolling=rolling, valid_from=valid_from)
    if cfg.post_norms:
        h = rms_norm(h, p["post_ln1"])
    x = x + h
    h = apply_mlp(p["mlp"], rms_norm(x, p["ln2"]), act=cfg.mlp_act,
                  quant=quant)
    if cfg.post_norms:
        h = rms_norm(h, p["post_ln2"])
    return x + h, cache


def _moe_layer_fwd(p, x, cfg: ArchConfig, *, quant=None):
    h = attention_prefill(
        p["attn"], rms_norm(x, p["ln1"]), n_heads=cfg.n_heads,
        n_kv=cfg.n_kv_heads, hd=cfg.hd, theta=cfg.rope_theta, quant=quant)
    x = x + h
    h, aux = apply_moe(p["moe"], rms_norm(x, p["ln2"]),
                       top_k=cfg.moe.top_k,
                       capacity_factor=cfg.moe.capacity_factor,
                       act=cfg.mlp_act, quant=quant)
    return x + h, aux


def _moe_layer_dec(p, x, cache, idx, cfg: ArchConfig, *, quant=None,
                   valid_from=None):
    h, cache = attention_decode(
        p["attn"], rms_norm(x, p["ln1"]), cache, idx, n_heads=cfg.n_heads,
        n_kv=cfg.n_kv_heads, hd=cfg.hd, theta=cfg.rope_theta, quant=quant,
        valid_from=valid_from)
    x = x + h
    h, _ = apply_moe(p["moe"], rms_norm(x, p["ln2"]), top_k=cfg.moe.top_k,
                     capacity_factor=cfg.moe.capacity_factor,
                     act=cfg.mlp_act, quant=quant)
    return x + h, cache


# --------------------------------------------------------------------------
# Model facade
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # ------------------------------------------------------------- init
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        k_emb, k_layers, k_head, k_shared = jax.random.split(key, 4)
        params: Dict[str, Any] = {
            "embed": embed_init(k_emb, cfg.vocab_padded, cfg.d_model,
                                cfg.dtype),
            "final_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(
                k_head, (cfg.d_model, cfg.vocab_padded), dtype=cfg.dtype)

        if cfg.family in ("dense", "vlm", "audio"):
            group = 2 if cfg.sliding_window else 1
            n_groups = cfg.n_layers // group
            keys = jax.random.split(k_layers, cfg.n_layers)
            layers = [_init_dense_layer(k, cfg) for k in keys]
            if group == 2:
                pairs = [{"local": layers[2 * i], "global": layers[2 * i + 1]}
                         for i in range(n_groups)]
                params["layers"] = _stack(pairs)
            else:
                params["layers"] = _stack(layers)
        elif cfg.family == "moe":
            keys = jax.random.split(k_layers, cfg.n_layers)
            params["layers"] = _stack([_init_moe_layer(k, cfg)
                                       for k in keys])
        elif cfg.family == "ssm":
            keys = jax.random.split(k_layers, cfg.n_layers)
            params["layers"] = _stack([_init_mamba_layer(k, cfg)
                                       for k in keys])
        elif cfg.family == "hybrid":
            keys = jax.random.split(k_layers, cfg.n_layers)
            n_groups = cfg.n_layers // cfg.attn_every
            blocks = [_init_mamba_layer(k, cfg) for k in keys]
            stacked = _stack(blocks)
            params["layers"] = jax.tree.map(
                lambda a: a.reshape((n_groups, cfg.attn_every)
                                    + a.shape[1:]), stacked)
            params["shared_attn"] = _init_dense_layer(k_shared, cfg)
        else:
            raise ValueError(cfg.family)
        return params

    # ------------------------------------------------------ embeddings
    def _embed(self, params, tokens, frontend_embed):
        cfg = self.cfg
        x = params["embed"][tokens] * jnp.asarray(
            cfg.d_model ** 0.5, cfg.dtype)
        if frontend_embed is not None:
            x = jnp.concatenate(
                [frontend_embed.astype(x.dtype), x], axis=1)
        return shard(x, BATCH, None, None)

    def _logits(self, params, x):
        cfg = self.cfg
        x = rms_norm(x, params["final_norm"])
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        if isinstance(head, dict):       # int8-packed lm_head
            head = head["q"].astype(x.dtype) * head["s"].astype(x.dtype)
        logits = jnp.einsum("bsd,dv->bsv", x, head)
        logits = softcap(logits, cfg.final_softcap)
        if cfg.vocab_padded != cfg.vocab:
            pad_mask = jnp.arange(cfg.vocab_padded) < cfg.vocab
            logits = jnp.where(pad_mask, logits,
                               jnp.asarray(-1e30, logits.dtype))
        return shard(logits, BATCH, None, MODEL)

    # ---------------------------------------------------------- forward
    def forward(self, params, tokens, frontend_embed=None, *, quant=None,
                remat: bool = False, return_aux: bool = False):
        """Teacher-forcing / prefill forward → logits (B, S_total, V)
        (with MoE aux losses when return_aux)."""
        cfg = self.cfg
        aux = None
        x = self._embed(params, tokens, frontend_embed)

        if cfg.family in ("dense", "vlm", "audio"):
            def body(x, p):
                if cfg.sliding_window:
                    x = _dense_layer_fwd(p["local"], x, cfg,
                                         window=cfg.sliding_window,
                                         quant=quant)
                    x = _dense_layer_fwd(p["global"], x, cfg, window=0,
                                         quant=quant)
                else:
                    x = _dense_layer_fwd(p, x, cfg, window=0, quant=quant)
                return x
            f = jax.checkpoint(body) if remat else body
            x, _ = jax.lax.scan(lambda c, p: (f(c, p), None), x,
                                params["layers"])
        elif cfg.family == "moe":
            def body_moe(x, p):
                return _moe_layer_fwd(p, x, cfg, quant=quant)
            f = jax.checkpoint(body_moe) if remat else body_moe
            x, auxs = jax.lax.scan(lambda c, p: f(c, p), x,
                                   params["layers"])
            aux = jax.tree.map(jnp.mean, auxs)
        elif cfg.family == "ssm":
            def body_ssm(x, p):
                h, _ = apply_mamba2(p["mamba"], rms_norm(x, p["ln"]),
                                    cfg.ssm, quant=quant)
                return x + h
            f = jax.checkpoint(body_ssm) if remat else body_ssm
            x, _ = jax.lax.scan(lambda c, p: (f(c, p), None), x,
                                params["layers"])
        elif cfg.family == "hybrid":
            def inner(x, p):
                h, _ = apply_mamba2(p["mamba"], rms_norm(x, p["ln"]),
                                    cfg.ssm, quant=quant)
                return x + h
            fi = jax.checkpoint(inner) if remat else inner

            def group_body(x, pg):
                x, _ = jax.lax.scan(lambda c, p: (fi(c, p), None), x, pg)
                return _dense_layer_fwd(params["shared_attn"], x, cfg,
                                        window=0, quant=quant)
            fg = jax.checkpoint(group_body) if remat else group_body
            x, _ = jax.lax.scan(lambda c, pg: (fg(c, pg), None), x,
                                params["layers"])
        logits = self._logits(params, x)
        if return_aux:
            return logits, aux
        return logits

    # ------------------------------------------------------------ cache
    def init_cache(self, batch: int, s_max: int,
                   kv_dtype=None) -> Dict[str, Any]:
        cfg = self.cfg
        kv_dtype = kv_dtype or cfg.dtype
        if cfg.family in ("dense", "vlm", "audio", "moe"):
            group = 2 if cfg.sliding_window else 1
            n = cfg.n_layers // group
            one = init_kv_cache(batch, s_max, cfg.n_kv_heads, cfg.hd,
                                kv_dtype)
            if group == 2:
                # local layers only need a sliding_window-deep rolling cache
                local = init_kv_cache(batch, min(cfg.sliding_window, s_max),
                                      cfg.n_kv_heads, cfg.hd, kv_dtype)
                cache = {"local": local, "global": one}
            else:
                cache = one
            return jax.tree.map(
                lambda a: jnp.zeros((n,) + a.shape, a.dtype), cache)
        if cfg.family == "ssm":
            one = init_mamba_state(batch, cfg.d_model, cfg.ssm, cfg.dtype)
            return jax.tree.map(
                lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), one)
        if cfg.family == "hybrid":
            n_groups = cfg.n_layers // cfg.attn_every
            m = init_mamba_state(batch, cfg.d_model, cfg.ssm, cfg.dtype)
            mamba = jax.tree.map(
                lambda a: jnp.zeros((n_groups, cfg.attn_every) + a.shape,
                                    a.dtype), m)
            kv = init_kv_cache(batch, s_max, cfg.n_kv_heads, cfg.hd,
                               kv_dtype)
            kv = jax.tree.map(
                lambda a: jnp.zeros((n_groups,) + a.shape, a.dtype), kv)
            return {"mamba": mamba, "attn": kv}
        raise ValueError(cfg.family)

    # ------------------------------------------------------ decode step
    def decode_step(self, params, tokens, cache, cache_index, *,
                    quant=None, valid_from=None) -> Tuple[jnp.ndarray, Any]:
        """tokens (B, 1) → (logits (B, 1, V), new cache).

        valid_from (B,): first valid cache slot per batch row for
        left-padded batches — pad slots are masked out of attention and
        RoPE positions shifted per row (see ``attention_decode``).  Only
        supported for full-context attention: SSM/hybrid state updates
        cannot be masked this way (ignored), and sliding-window rolling
        caches raise ``NotImplementedError``."""
        cfg = self.cfg
        x = self._embed(params, tokens, None)

        if cfg.family in ("dense", "vlm", "audio"):
            def body(x, pc):
                p, c = pc
                if cfg.sliding_window:
                    # local cache is a rolling window buffer: the buffer
                    # length == window enforces locality; rope positions
                    # were applied at write time so slots stay valid.
                    # valid_from is forwarded so attention_decode raises
                    # rather than silently serving the local layers
                    # unmasked (rolling buffers cannot mask pad slots).
                    x, cl = _dense_layer_dec(
                        p["local"], x, c["local"], cache_index, cfg,
                        window=0, quant=quant, rolling=True,
                        valid_from=valid_from)
                    x, cg = _dense_layer_dec(
                        p["global"], x, c["global"], cache_index, cfg,
                        window=0, quant=quant, valid_from=valid_from)
                    return x, {"local": cl, "global": cg}
                return _dense_layer_dec(p, x, c, cache_index, cfg,
                                        window=0, quant=quant,
                                        valid_from=valid_from)
            x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
        elif cfg.family == "moe":
            def body_m(x, pc):
                p, c = pc
                return _moe_layer_dec(p, x, c, cache_index, cfg,
                                      quant=quant, valid_from=valid_from)
            x, new_cache = jax.lax.scan(body_m, x,
                                        (params["layers"], cache))
        elif cfg.family == "ssm":
            def body_s(x, pc):
                p, c = pc
                h, cn = apply_mamba2(p["mamba"], rms_norm(x, p["ln"]),
                                     cfg.ssm, quant=quant, state=c,
                                     decode=True)
                return x + h, cn
            x, new_cache = jax.lax.scan(body_s, x,
                                        (params["layers"], cache))
        elif cfg.family == "hybrid":
            def body_h(x, pc):
                pg, cm, ckv = pc

                def inner(x, pci):
                    p, c = pci
                    h, cn = apply_mamba2(p["mamba"], rms_norm(x, p["ln"]),
                                         cfg.ssm, quant=quant, state=c,
                                         decode=True)
                    return x + h, cn
                x, cm_new = jax.lax.scan(inner, x, (pg, cm))
                x, ckv_new = _dense_layer_dec(
                    params["shared_attn"], x, ckv, cache_index, cfg,
                    window=0, quant=quant, valid_from=valid_from)
                return x, (cm_new, ckv_new)
            x, (cm, ckv) = jax.lax.scan(
                body_h, x, (params["layers"], cache["mamba"],
                            cache["attn"]))
            new_cache = {"mamba": cm, "attn": ckv}
        return self._logits(params, x), new_cache

    # ------------------------------------------------- paged decode path
    @property
    def supports_paged(self) -> bool:
        """The paged serving path needs full-context attention at every
        layer: SSM/hybrid carry recurrent state that paging cannot evict,
        and sliding-window rolling caches pin physical layout to position."""
        cfg = self.cfg
        return cfg.family in ("dense", "vlm", "audio", "moe") and \
            not cfg.sliding_window

    def decode_paged(self, params, tokens, kv_pages, page_table, lengths, *,
                     page_size: int, quant=None, kv_scales=None
                     ) -> Tuple[jnp.ndarray, Any]:
        """Multi-token step against a paged KV cache (serving path).

        tokens (B, T) → (logits (B, T, V), new kv_pages).  Covers chunked
        prefill (B=1, T=chunk), batched continuous decode (B=slots, T=1)
        and speculative verification (B=slots, T=spec_k+1, each slot's
        window at its own ``lengths[b]`` offset) with one code path —
        see ``attention.paged_attention``.

        kv_pages: length-n_layers list of {"k": (P, page, KV, hd),
        "v": ...} page pools — a Python list (not a stacked scan axis) so
        each layer can carry its own storage dtype (int8 where SIRA
        certifies the range, fp fallback elsewhere).  kv_scales: per-layer
        (k_scale, v_scale) arrays for the int8 layers, None entries for fp
        layers.  page_table (B, n_pages) and lengths (B,) are shared by
        all layers (every layer sees the same token positions).
        """
        cfg = self.cfg
        if not self.supports_paged:
            raise NotImplementedError(
                f"paged decode needs full-context attention — "
                f"family={cfg.family!r} sliding_window={cfg.sliding_window}")
        x = self._embed(params, tokens, None)
        new_pages = []
        for layer in range(cfg.n_layers):
            p = jax.tree.map(lambda a, i=layer: a[i], params["layers"])
            ks, vs = (kv_scales[layer] if kv_scales and
                      kv_scales[layer] is not None else (None, None))
            h, kp, vp = paged_attention(
                p["attn"], rms_norm(x, p["ln1"]),
                kv_pages[layer]["k"], kv_pages[layer]["v"], page_table,
                lengths, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                hd=cfg.hd, theta=cfg.rope_theta, page_size=page_size,
                logit_cap=cfg.attn_softcap, quant=quant,
                k_scale=ks, v_scale=vs)
            if cfg.post_norms:
                h = rms_norm(h, p["post_ln1"])
            x = x + h
            if cfg.family == "moe":
                h, _ = apply_moe(p["moe"], rms_norm(x, p["ln2"]),
                                 top_k=cfg.moe.top_k,
                                 capacity_factor=cfg.moe.capacity_factor,
                                 act=cfg.mlp_act, quant=quant)
            else:
                h = apply_mlp(p["mlp"], rms_norm(x, p["ln2"]),
                              act=cfg.mlp_act, quant=quant)
            if cfg.post_norms:
                h = rms_norm(h, p["post_ln2"])
            x = x + h
            new_pages.append({"k": kp, "v": vp})
        return self._logits(params, x), new_pages

    # -------------------------------------------------------------- loss
    def loss(self, params, tokens, labels, frontend_embed=None, *,
             quant=None, remat: bool = False) -> jnp.ndarray:
        cfg = self.cfg
        logits = self.forward(params, tokens, frontend_embed, quant=quant,
                              remat=remat)
        if frontend_embed is not None:
            logits = logits[:, frontend_embed.shape[1]:]
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None],
                                   axis=-1)[..., 0]
        nll = (logz - gold).mean()
        aux = getattr(self, "_last_aux", None)
        if aux is not None:
            nll = nll + 0.01 * aux["load_balance"] + 1e-3 * aux["router_z"]
        return nll


def get_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
