"""Shared model building blocks (pure JAX, functional).

Conventions:
  * params are nested dicts of jnp arrays; repeated layers are stacked
    along a leading axis and consumed with jax.lax.scan (keeps the HLO
    O(1) in depth — essential for the 512-device dry-run compiles).
  * ``shard(x, *axes)`` applies a sharding constraint when a mesh is
    active, silently filtering axis names the mesh does not have (so the
    same model code runs on 1-device CPU, the 256-chip pod and the
    512-chip multi-pod mesh).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.quant.quantizer import QuantSpec, compute_scale, fake_quant, \
    fake_quant_dynamic


# --------------------------------------------------------------- sharding

def _abstract_mesh():
    """Current abstract mesh, or None outside any mesh context.

    ``jax.sharding.get_abstract_mesh`` only exists on jax >= 0.5; on older
    releases (the pinned 0.4.x) fall back to the active ``Mesh`` context
    tracked by the thread resource env."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        return get()
    try:
        from jax._src.mesh import thread_resources
        pm = thread_resources.env.physical_mesh
    except Exception:
        return None
    if pm is None or pm.empty:
        return None
    return getattr(pm, "abstract_mesh", pm)


def _mesh_axes() -> Sequence[str]:
    m = _abstract_mesh()
    return tuple(m.axis_names) if m is not None and m.axis_names else ()


def shard(x: jnp.ndarray, *spec) -> jnp.ndarray:
    """with_sharding_constraint filtered to the current mesh's axes AND to
    divisible dims (a non-divisible constraint makes GSPMD pad the tensor —
    e.g. 2 KV heads padded to a 16-way model axis inflate attention
    buffers 8x; dropping the axis keeps them exact and replicated).

    spec entries: None, an axis name, or a tuple of axis names."""
    m = _abstract_mesh()
    if m is None or not m.axis_names:
        return x
    axes = set(m.axis_names)
    sizes = dict(zip(m.axis_names, m.axis_sizes))

    def filt(s, dim):
        if s is None:
            return None
        parts = s if isinstance(s, (tuple, list)) else (s,)
        kept = tuple(a for a in parts if a in axes)
        if not kept:
            return None
        total = 1
        for a in kept:
            total *= sizes[a]
        if dim % total != 0:
            return None
        return kept if len(kept) > 1 else kept[0]

    dims = list(x.shape) + [1] * (len(spec) - len(x.shape))
    return jax.lax.with_sharding_constraint(
        x, P(*[filt(s, d) for s, d in zip(spec, dims)]))


BATCH = ("pod", "data")     # data-parallel axes (pod crosses DCN)
MODEL = "model"             # tensor/expert-parallel axis


# ------------------------------------------------------------------ init

def dense_init(key, shape, scale: Optional[float] = None, dtype=jnp.float32):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.truncated_normal(key, -2.0, 2.0, (vocab, d),
                                        jnp.float32)).astype(dtype)


# ----------------------------------------------------------------- norms

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray,
             eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


# ------------------------------------------------------------------ rope

def rope_frequencies(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- linear

def linear(x: jnp.ndarray, w: jnp.ndarray, b: Optional[jnp.ndarray] = None,
           quant: Optional[QuantSpec] = None) -> jnp.ndarray:
    """y = x @ w (+ b), optionally fake-quantized (QAT).

    With ``quant``: weights are fake-quantized per output channel and the
    activation per tensor — the standard weight-activation QAT recipe the
    paper's workloads use (§2.1), so the trained model is SIRA-analyzable.
    """
    if isinstance(w, dict):           # packed int8 weights {q, s}
        w = w["q"].astype(x.dtype) * w["s"].astype(x.dtype)
    if quant is not None:
        w_spec = QuantSpec(bits=quant.bits, granularity="per_channel",
                           channel_axis=-1, pot=quant.pot)
        sw, zw = compute_scale(jax.lax.stop_gradient(w), w_spec)
        w = fake_quant(w, sw, zw, w_spec)
        x = fake_quant_dynamic(x, quant)
    y = jnp.einsum("...k,km->...m", x, w)
    if b is not None:
        y = y + b
    return y


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)
