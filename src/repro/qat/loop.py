"""Jitted accumulator-aware QAT driver: ``make_train_step`` + ``AdamW``
with per-step hard budget projection and fault-tolerant checkpointing.

The projection rides inside the jitted train step via
``AdamW(project=...)`` — it is applied to the f32 *master* weights, the
only place it sticks (params are re-materialized from the masters every
step).  Checkpoints round-trip the full constrained ``TrainState``
through ``repro.train.checkpoint`` and resume bit-identically (the data
stream is keyed by step, the schedule by the optimizer step counter).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import numpy as np

from repro.optim.adamw import AdamW
from repro.train.checkpoint import (latest_checkpoint, restore_checkpoint,
                                    save_checkpoint, step_of)
from repro.train.train_step import (TrainState, init_train_state,
                                    make_train_step)
from .model import QATMLP


@dataclasses.dataclass(frozen=True)
class QATConfig:
    """One accumulator-aware QAT run (workload + budget + optimizer)."""
    in_dim: int = 16
    hidden: Tuple[int, ...] = (32,)
    classes: int = 4
    weight_bits: int = 4
    act_bits: int = 4
    input_bits: int = 8
    budget: int = 0              # target accumulator bits; 0 = off
    zero_center: bool = False    # A2Q+ variant
    lam: float = 1e-2            # penalty weight
    steps: int = 150
    batch: int = 64
    lr: float = 5e-3
    weight_decay: float = 1e-4
    seed: int = 0
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50

    def make_model(self) -> QATMLP:
        return QATMLP(in_dim=self.in_dim, hidden=self.hidden,
                      classes=self.classes, weight_bits=self.weight_bits,
                      act_bits=self.act_bits, input_bits=self.input_bits,
                      budget_bits=self.budget,
                      zero_center=self.zero_center, lam=self.lam,
                      seed=self.seed)


@dataclasses.dataclass
class QATResult:
    config: QATConfig
    model: QATMLP
    state: TrainState
    losses: List[float]
    resumed_from: int = 0
    checkpoint_path: Optional[str] = None

    @property
    def final_loss(self) -> float:
        tail = self.losses[-10:] or [float("nan")]
        return float(np.mean(tail))


def make_optimizer(cfg: QATConfig, model: QATMLP) -> AdamW:
    proj = model.make_projector() if cfg.budget else None
    return AdamW(lr=cfg.lr, weight_decay=cfg.weight_decay,
                 warmup_steps=max(cfg.steps // 10, 1),
                 total_steps=cfg.steps, project=proj)


def run_qat(cfg: QATConfig, model: Optional[QATMLP] = None) -> QATResult:
    """Train (or resume) a QAT run to ``cfg.steps`` and return the final
    constrained state."""
    model = model or cfg.make_model()
    opt = make_optimizer(cfg, model)
    state = init_train_state(model, opt, jax.random.PRNGKey(cfg.seed))
    step_fn = jax.jit(make_train_step(model, opt, remat=False))

    start, losses = 0, []
    ckpt_path: Optional[str] = None
    if cfg.ckpt_dir:
        ckpt_path = latest_checkpoint(cfg.ckpt_dir)
        if ckpt_path is not None:
            state, extra = restore_checkpoint(ckpt_path, state)
            start = int(extra.get("step", step_of(ckpt_path)))
            losses = list(extra.get("losses", []))

    for step in range(start, cfg.steps):
        batch = model.synth_batch(step, cfg.batch)
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        done = step + 1
        if cfg.ckpt_dir and (done % cfg.ckpt_every == 0
                             or done == cfg.steps):
            ckpt_path = save_checkpoint(
                cfg.ckpt_dir, done, state,
                extra={"step": done, "losses": losses})
    return QATResult(config=cfg, model=model, state=state, losses=losses,
                     resumed_from=start, checkpoint_path=ckpt_path)
