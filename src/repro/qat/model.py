"""Small quantized MLP classifier driven by the accumulator-aware QAT
loop — the "training knob" end of the train -> SIRA -> DSE chain.

Implements the model protocol ``make_train_step`` expects
(``init(key)`` and ``loss(params, x, labels, frontend_embed, quant=...,
remat=...)``), with:

  * fake-quant forward passes from ``repro.quant.quantizer`` — unsigned
    input/activation quantizers, per-output-channel **round-toward-zero**
    weight quantizers (the rounding mode the A2Q guarantee needs);
  * **frozen** quantization scales, computed once at construction from
    the init weights / a calibration batch.  Freezing is load-bearing:
    the projection, the penalty, and the exported SIRA graph must all
    measure weights against the *same* scale, or the L1 bound proven on
    ``W/s`` stops meaning anything about the deployed integers;
  * per-layer :class:`~repro.qat.constraints.AccumulatorBudget` when
    ``budget_bits > 0``: an L1 hinge penalty inside the loss plus a
    ``make_projector()`` pytree hook for ``AdamW(project=...)``.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant.quantizer import QuantSpec, fake_quant
from .constraints import (AccumulatorBudget, budget_penalty,
                          project_weights, weight_quant_spec)


class QATMLP:
    """ReLU MLP with quantized input/weights/activations and an optional
    accumulator budget on every layer."""

    def __init__(self, in_dim: int = 16, hidden=(32,), classes: int = 4,
                 weight_bits: int = 4, act_bits: int = 4,
                 input_bits: int = 8, budget_bits: int = 0,
                 zero_center: bool = False, lam: float = 1e-2,
                 seed: int = 0):
        self.in_dim = in_dim
        self.hidden = tuple(hidden)
        self.classes = classes
        self.weight_bits = weight_bits
        self.act_bits = act_bits
        self.input_bits = input_bits
        self.budget_bits = budget_bits
        self.zero_center = zero_center
        self.lam = lam
        self.seed = seed

        dims = [in_dim] + list(self.hidden) + [classes]
        self.layer_dims = list(zip(dims[:-1], dims[1:]))
        self.w_spec = weight_quant_spec(weight_bits)
        self.in_spec = QuantSpec(bits=input_bits, signed=False)
        self.act_spec = QuantSpec(bits=act_bits, signed=False)
        # inputs live in [0, 1]; this scale puts the integer grid exactly
        # on [0, 2^N - 1] so SIRA sees a pure unsigned N-bit input
        self.input_scale = 1.0 / (2 ** input_bits - 1)

        # deterministic class centers for the synthetic task
        rng = np.random.default_rng(seed + 7)
        self._centers = rng.uniform(0.25, 0.75, size=(classes, in_dim))

        # frozen per-output-channel weight scales from the init weights,
        # with 2x headroom so training can grow weights before the
        # clipped STE saturates
        init = self._raw_init(jax.random.PRNGKey(seed))
        self.w_scales: List[np.ndarray] = [
            np.maximum(np.abs(np.asarray(l["W"], np.float64)).max(axis=0)
                       * 2.0 / self.w_spec.qmax, 1e-8)
            for l in init["layers"]]
        # frozen per-tensor activation scales from a calibration pass
        self.a_scales: List[float] = self._calibrate(init)

    # ------------------------------------------------------------- budgets
    def budgets(self) -> List[Optional[AccumulatorBudget]]:
        """Per-layer accumulator budgets (None when unconstrained).
        Layer 0 accumulates the quantized input, deeper layers the
        unsigned activation quantizer output."""
        if not self.budget_bits:
            return [None] * len(self.layer_dims)
        out: List[Optional[AccumulatorBudget]] = []
        for i in range(len(self.layer_dims)):
            n = self.input_bits if i == 0 else self.act_bits
            out.append(AccumulatorBudget(
                bits=self.budget_bits, input_bits=n, input_signed=False,
                zero_center=self.zero_center))
        return out

    def make_projector(self):
        """Pytree -> pytree hard projection onto every layer's budget,
        suitable for ``AdamW(project=...)`` (jit-traceable; applied to
        the f32 master weights after each optimizer step)."""
        budgets = self.budgets()
        scales = [jnp.asarray(s, jnp.float32)[None, :]
                  for s in self.w_scales]

        def proj(params: Dict[str, Any]) -> Dict[str, Any]:
            layers = []
            for layer, s, b in zip(params["layers"], scales, budgets):
                if b is None:
                    layers.append(dict(layer))
                else:
                    layers.append(
                        {**layer, "W": project_weights(layer["W"], s, b)})
            return {**params, "layers": layers}

        return proj

    # ---------------------------------------------------------------- init
    def _raw_init(self, key) -> Dict[str, Any]:
        layers = []
        for i, (k, m) in enumerate(self.layer_dims):
            key, sub = jax.random.split(key)
            layers.append({
                "W": jax.random.normal(sub, (k, m), jnp.float32)
                / jnp.sqrt(jnp.asarray(float(k), jnp.float32)),
                "b": jnp.zeros((m,), jnp.float32)})
        return {"layers": layers}

    def init(self, key) -> Dict[str, Any]:
        """Init params; already projected onto the budget set so step 0
        satisfies the constraint (AdamW copies these into its masters)."""
        params = self._raw_init(key)
        if self.budget_bits:
            params = self.make_projector()(params)
        return params

    def _calibrate(self, params) -> List[float]:
        x = jnp.asarray(self.synth_batch(0, 256)["tokens"])
        h = fake_quant(x, self.input_scale, 0.0, self.in_spec)
        scales: List[float] = []
        for i, layer in enumerate(params["layers"][:-1]):
            s_w = jnp.asarray(self.w_scales[i], jnp.float32)[None, :]
            wq = fake_quant(layer["W"], s_w, 0.0, self.w_spec)
            h = jax.nn.relu(h @ wq + layer["b"])
            s = max(float(jnp.max(h)), 1e-6) * 2.0 / self.act_spec.qmax
            scales.append(s)
            h = fake_quant(h, s, 0.0, self.act_spec)
        return scales

    # ------------------------------------------------------------- forward
    def apply(self, params, x: jnp.ndarray) -> jnp.ndarray:
        h = fake_quant(x, self.input_scale, 0.0, self.in_spec)
        n = len(params["layers"])
        for i, layer in enumerate(params["layers"]):
            s_w = jnp.asarray(self.w_scales[i], h.dtype)[None, :]
            wq = fake_quant(layer["W"], s_w, 0.0, self.w_spec)
            h = h @ wq + layer["b"]
            if i < n - 1:
                h = jax.nn.relu(h)
                h = fake_quant(h, self.a_scales[i], 0.0, self.act_spec)
        return h

    def loss(self, params, x, labels, frontend_embed=None, *,
             quant=None, remat: bool = True) -> jnp.ndarray:
        """Cross-entropy + the differentiable accumulator-budget penalty
        (``quant``/``remat``/``frontend_embed`` accepted for the
        make_train_step protocol; quantization here is structural)."""
        del frontend_embed, quant, remat
        logits = self.apply(params, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ce = -jnp.mean(
            jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32),
                                axis=1))
        pen = jnp.zeros((), jnp.float32)
        for layer, s, b in zip(params["layers"], self.w_scales,
                               self.budgets()):
            if b is not None:
                pen = pen + budget_penalty(
                    layer["W"], jnp.asarray(s, jnp.float32)[None, :], b)
        return ce + self.lam * pen

    # ---------------------------------------------------------------- data
    def synth_batch(self, step: int, batch: int) -> Dict[str, np.ndarray]:
        """Deterministic synthetic classification batch: Gaussian blobs
        around per-class centers, clipped to the quantizer's [0, 1]
        input box.  Keyed by (seed, step) so resumed runs replay the
        exact data stream (bit-identical-resume tests rely on this)."""
        rng = np.random.default_rng(self.seed * 1_000_003 + step)
        labels = rng.integers(self.classes, size=batch)
        x = self._centers[labels] + rng.normal(
            0.0, 0.08, size=(batch, self.in_dim))
        return {"tokens": np.clip(x, 0.0, 1.0).astype(np.float32),
                "labels": labels.astype(np.int32)}
