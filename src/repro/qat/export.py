"""Trained QAT checkpoint -> ``SiraModel`` graph -> proven accumulator
bits -> DSE deltas: the back half of the train -> SIRA -> DSE chain.

The exported graph mirrors the ``core.workloads`` QNN conventions
(input Quant, per-layer weight Quant -> MatMul -> Add bias -> Relu ->
unsigned activation Quant, raw final gemm) so the default ``build_flow``
streamlines it to pure-integer MatMuls that ``minimize_accumulators``
prices.  Weights are exported **snapped**: ``W_snap = s * toz(W / s)``,
so the graph's round-half-to-even Quant executor lands on exactly the
round-toward-zero integers training constrained — the A2Q guarantee
(SIRA-proven bits <= trained budget) then holds by construction and is
asserted, not hoped for.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.accumulator import AccumulatorReport
from repro.core.flow import BuildResult, build_flow
from repro.core.graph import Graph
from repro.core.intervals import ScaledIntRange
from repro.core.model import SiraModel
from .constraints import quantize_weights
from .model import QATMLP


def _quant(g: Graph, x: str, scale, bits: int, signed: int,
           out: str) -> str:
    s = g.add_initializer(scale)
    z = g.add_initializer(0.0)
    b = g.add_initializer(float(bits))
    g.add_node("Quant", [x, s, z, b], [out], dict(signed=signed, narrow=0))
    return out


def export_qat_model(model: QATMLP, params,
                     name: str = "qat-mlp") -> SiraModel:
    """Build the inference graph of a trained :class:`QATMLP` with
    snapped integer-exact weights and the training-time frozen scales."""
    g = Graph(inputs=["X"], outputs=[])
    x = _quant(g, "X", model.input_scale, model.input_bits, 0, "Xq")
    n = len(model.layer_dims)
    for i, layer in enumerate(params["layers"]):
        W = np.asarray(layer["W"], np.float64)
        s_w = np.asarray(model.w_scales[i], np.float64)
        q = quantize_weights(W, s_w, model.weight_bits)
        w_name = g.add_initializer(q * s_w[None, :], f"l{i}_W")
        wq = _quant(g, w_name, s_w, model.weight_bits, 1, f"l{i}_Wq")
        g.add_node("MatMul", [x, wq], [f"l{i}_mm"], name=f"l{i}_matmul")
        b_name = g.add_initializer(np.asarray(layer["b"], np.float64),
                                   f"l{i}_B")
        g.add_node("Add", [f"l{i}_mm", b_name], [f"l{i}_gemm"])
        x = f"l{i}_gemm"
        if i < n - 1:
            g.add_node("Relu", [x], [f"l{i}_act"])
            x = _quant(g, f"l{i}_act", model.a_scales[i], model.act_bits,
                       0, f"l{i}_out")
    g.outputs = [x]
    budgets = model.budgets()
    return SiraModel(
        g, {"X": ScaledIntRange(lo=np.zeros(()), hi=np.ones(()))},
        name=name,
        metadata=dict(
            input_shape=(1, model.in_dim),
            weight_bits=model.weight_bits,
            act_bits=model.act_bits,
            qat_budgets=[b.bits if b else None for b in budgets],
            qat_zero_center=model.zero_center))


def proven_layer_bits(model: QATMLP, params, *,
                      domain: str = "interval",
                      name: str = "qat-mlp"
                      ) -> Tuple[BuildResult, List[int]]:
    """Export + full default ``build_flow``; returns the build result and
    the SIRA-proven accumulator bits per layer (graph order)."""
    sm = export_qat_model(model, params, name=name)
    result = build_flow(sm, input_bits=model.input_bits,
                        weight_bits=model.weight_bits, domain=domain)
    by_layer: Dict[int, AccumulatorReport] = {}
    for rep in result.accumulator_reports:
        if rep.op_type not in ("MatMul", "Gemm"):
            continue
        if rep.node_name.startswith("l") and "_matmul" in rep.node_name:
            by_layer[int(rep.node_name[1:].split("_")[0])] = rep
    n = len(model.layer_dims)
    missing = sorted(set(range(n)) - set(by_layer))
    if missing:
        raise AssertionError(
            f"layers {missing} did not streamline to pure-integer "
            f"MatMuls; accumulator reports: "
            f"{[r.node_name for r in result.accumulator_reports]}")
    return result, [by_layer[i].sira_bits for i in range(n)]


def check_budget_invariant(model: QATMLP, params,
                           bits: Optional[List[int]] = None
                           ) -> List[int]:
    """Assert the A2Q invariant: SIRA-proven accumulator bits never
    exceed the trained budget on any constrained layer.  Returns the
    proven per-layer bits."""
    if bits is None:
        _, bits = proven_layer_bits(model, params)
    for i, (b, budget) in enumerate(zip(bits, model.budgets())):
        if budget is not None and b > budget.bits:
            raise AssertionError(
                f"layer {i}: SIRA proves {b} accumulator bits, but the "
                f"QAT budget was {budget.bits} — the projection or the "
                f"export scale chain is unsound")
    return bits
