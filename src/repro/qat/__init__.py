"""Accumulator-aware QAT (A2Q / A2Q+): train weights that provably fit
a chosen accumulator width, then prove it with SIRA and price it with
the dataflow DSE — the paper stack's train -> analyze -> optimize ->
price loop in one subsystem.

    from repro.qat import QATConfig, run_qat, check_budget_invariant
    res = run_qat(QATConfig(budget=14, steps=200))
    bits = check_budget_invariant(res.model, res.state.params)
"""
from .constraints import (AccumulatorBudget, ProjectionFuzzReport,
                          budget_penalty, channel_bits, fuzz_projection,
                          project_weights, quantize_weights,
                          weight_quant_spec, worst_case_inputs)
from .export import (check_budget_invariant, export_qat_model,
                     proven_layer_bits)
from .loop import QATConfig, QATResult, make_optimizer, run_qat
from .model import QATMLP

__all__ = [
    "AccumulatorBudget", "ProjectionFuzzReport", "budget_penalty",
    "channel_bits", "fuzz_projection", "project_weights",
    "quantize_weights", "weight_quant_spec", "worst_case_inputs",
    "check_budget_invariant", "export_qat_model", "proven_layer_bits",
    "QATConfig", "QATResult", "make_optimizer", "run_qat", "QATMLP",
]
