"""A2Q-style accumulator-budget constraints for QAT (PAPERS.md: A2Q,
A2Q+): train weights that *provably* fit a chosen accumulator width.

Setting
-------
A dot-product layer accumulates ``z[j] = sum_k q[k, j] * x[k]`` with
integer inputs ``x`` and per-output-channel integer weights
``q = toz(W / s)`` (round-toward-zero, frozen per-channel scale ``s``).
``z`` fits ``P`` signed bits iff (repro.core.intervals
``required_signed_bits``)

    z_hi <= 2^(P-1) - 1   and   -z_lo <= 2^(P-1).

Unsigned N-bit inputs, ``x in [0, M]`` with ``M = 2^N - 1``:

    z_hi = M * sum(q+),   z_lo = -M * sum(q-),

so the budget is a pair of L1-type bounds on the weight column masses:

    sum(q+) <= (2^(P-1) - 1) / M,     sum(q-) <= 2^(P-1) / M.

* **A2Q** (``zero_center=False``) uses the symmetric tight side:
  ``||q||_1 <= (2^(P-1) - 1) / M``.
* **A2Q+** (``zero_center=True``) zero-centers ``v = W/s`` per channel
  and constrains the positive and negative masses *separately* —
  roughly twice the feasible mass for the same budget.

Signed N-bit inputs (``|x| <= M = 2^(N-1)``): either input sign can
flip every product, so both bounds collapse to ``M * ||q||_1`` and only
the symmetric form applies (zero-centering then still conditions the
weights but buys no extra mass).

The guarantee survives quantization because round-toward-zero gives
``|q_k| <= |v_k|`` element-wise (``QuantSpec(rounding="toward_zero")``)
and clipping to ``qmax`` only shrinks magnitudes — so any bound proved
on ``v = W/s`` transfers to ``q``.  It is enforced as (a) a
differentiable L1 hinge penalty inside the loss and (b) a hard
Euclidean projection applied to the optimizer's master weights after
every step (``AdamW(project=...)``), and it is validated against
``repro.core.accumulator`` (``exact_worst_case_bits`` /
``channel_worst_case_bits``) as the oracle — including a seeded
"lying projector" mode the fuzzer must catch, mirroring
``repro.core.fuzz``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.accumulator import (channel_worst_case_bits,
                                    exact_worst_case_bits)
from repro.quant.quantizer import QuantSpec


@dataclasses.dataclass(frozen=True)
class AccumulatorBudget:
    """Per-layer accumulator-budget: prove ``<= bits`` signed bits for a
    dot product over ``input_bits``-bit integer inputs."""
    bits: int                      # target accumulator width P (signed)
    input_bits: int = 8            # N: width of the dynamic input
    input_signed: bool = False
    zero_center: bool = False      # A2Q+ asymmetric variant

    def __post_init__(self) -> None:
        if self.bits < 2:
            raise ValueError(f"accumulator budget needs bits >= 2, "
                             f"got {self.bits}")

    @property
    def input_mag(self) -> int:
        """Worst-case |x| of the integer input."""
        if self.input_signed:
            return 2 ** (self.input_bits - 1)
        return 2 ** self.input_bits - 1

    def input_range(self) -> Tuple[int, int]:
        """Integer input range (x_lo, x_hi) the budget defends against."""
        if self.input_signed:
            return -(2 ** (self.input_bits - 1)), \
                2 ** (self.input_bits - 1) - 1
        return 0, 2 ** self.input_bits - 1

    def caps(self) -> Tuple[float, float]:
        """(cap_pos, cap_neg) L1 limits on the integer-weight column
        masses.  ``cap_neg < 0`` signals the symmetric regime (bound
        ``||q||_1 <= cap_pos`` instead of separate masses)."""
        cap_pos = (2.0 ** (self.bits - 1) - 1.0) / self.input_mag
        if self.zero_center and not self.input_signed:
            return cap_pos, (2.0 ** (self.bits - 1)) / self.input_mag
        return cap_pos, -1.0


def _project_l1_nonneg(u: jnp.ndarray, radius: float) -> jnp.ndarray:
    """Euclidean projection of each *column* of the non-negative matrix
    ``u`` (K, M) onto ``{y >= 0 : sum(y) <= radius}`` (Duchi et al.
    sort-and-threshold; jit/vmap-friendly, no data-dependent shapes)."""
    K = u.shape[0]
    s = -jnp.sort(-u, axis=0)                       # descending
    css = jnp.cumsum(s, axis=0)
    k = jnp.arange(1, K + 1, dtype=u.dtype)[:, None]
    theta_k = (css - radius) / k
    rho = jnp.maximum(jnp.sum(s > theta_k, axis=0), 1)
    theta = jnp.take_along_axis(theta_k, (rho - 1)[None, :], axis=0)[0]
    # feasible columns have theta <= 0: clamp so they project to themselves
    theta = jnp.maximum(theta, 0.0)
    return jnp.maximum(u - theta[None, :], 0.0)


def _int_domain(W: jnp.ndarray, scale) -> Tuple[jnp.ndarray, jnp.ndarray]:
    s = jnp.asarray(scale, dtype=W.dtype)
    if s.ndim == 1:
        s = s[None, :]
    return W / s, s


def project_weights(W: jnp.ndarray, scale,
                    budget: AccumulatorBudget) -> jnp.ndarray:
    """Hard Euclidean projection of a (K, M) weight matrix onto the
    budget's constraint set, in integer units ``v = W / scale``
    (``scale``: per-output-channel, broadcastable to (1, M)).

    Symmetric regime: project ``|v|`` columns onto the L1 ball (signs
    kept) — the exact Euclidean projection onto ``||v||_1 <= cap``.
    A2Q+ regime: zero-center each column (reparameterization, as in
    A2Q+), then project the positive and negative parts onto their own
    simplex caps; the parts live on disjoint coordinates, so this is
    the exact projection onto the pair constraint."""
    v, s = _int_domain(W, scale)
    cap_pos, cap_neg = budget.caps()
    if cap_neg >= 0.0:
        v = v - jnp.mean(v, axis=0, keepdims=True)
        pos = _project_l1_nonneg(jnp.maximum(v, 0.0), cap_pos)
        neg = _project_l1_nonneg(jnp.maximum(-v, 0.0), cap_neg)
        v = pos - neg
    else:
        mag = _project_l1_nonneg(jnp.abs(v), cap_pos)
        v = jnp.sign(v) * mag
    return v * s


def budget_penalty(W: jnp.ndarray, scale,
                   budget: AccumulatorBudget) -> jnp.ndarray:
    """Differentiable L1-norm hinge penalty: mean squared excess of the
    per-channel integer-domain column masses over the budget caps.
    Zero on the feasible set, so it never fights the projection."""
    v, _ = _int_domain(W, scale)
    cap_pos, cap_neg = budget.caps()
    if cap_neg >= 0.0:
        v = v - jnp.mean(v, axis=0, keepdims=True)
        e_pos = jnp.maximum(
            jnp.sum(jnp.maximum(v, 0.0), axis=0) - cap_pos, 0.0)
        e_neg = jnp.maximum(
            jnp.sum(jnp.maximum(-v, 0.0), axis=0) - cap_neg, 0.0)
        return jnp.mean(e_pos ** 2 + e_neg ** 2)
    excess = jnp.maximum(
        jnp.sum(jnp.abs(v), axis=0) - cap_pos, 0.0)
    return jnp.mean(excess ** 2)


def weight_quant_spec(weight_bits: int) -> QuantSpec:
    """The toz weight quantizer every constrained layer must use (the
    |q| <= |v| property is what transfers the L1 bound to integers)."""
    return QuantSpec(bits=weight_bits, signed=True,
                     granularity="per_channel", channel_axis=-1,
                     rounding="toward_zero")


def quantize_weights(W, scale, weight_bits: int) -> np.ndarray:
    """(K, M) float weights -> integer q, the float64 numpy reference of
    the toz quantizer (``quantize_int`` with rounding="toward_zero") —
    used by export and the fuzzer so proofs run at full precision."""
    spec = weight_quant_spec(weight_bits)
    s = np.asarray(scale, np.float64)
    if s.ndim == 1:
        s = s[None, :]
    q = np.trunc(np.asarray(W, np.float64) / s)
    return np.clip(q, spec.qmin, spec.qmax)


def worst_case_inputs(q: np.ndarray, budget: AccumulatorBudget,
                      maximize: bool = True) -> np.ndarray:
    """The adversarial integer input per output channel: X (K, M) where
    column j maximizes (or minimizes) channel j's accumulator
    ``sum_k q[k, j] * X[k, j]``."""
    x_lo, x_hi = budget.input_range()
    if maximize:
        return np.where(np.asarray(q) > 0, x_hi, x_lo).astype(np.float64)
    return np.where(np.asarray(q) > 0, x_lo, x_hi).astype(np.float64)


def channel_bits(q: np.ndarray, budget: AccumulatorBudget) -> np.ndarray:
    """Exact per-channel worst-case accumulator bits of integer weights
    ``q`` under the budget's input range (the core oracle)."""
    return channel_worst_case_bits(np.asarray(q), *budget.input_range())


# --------------------------------------------------------------------------
# guarantee fuzzer (mirrors repro.core.fuzz: honest run must be clean,
# seeded lying variants must be caught)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class ProjectionFuzzReport:
    cases: int
    channels_checked: int
    violations: List[str]
    oracle_mismatches: List[str]

    @property
    def clean(self) -> bool:
        return not self.violations and not self.oracle_mismatches

    def summary(self) -> str:
        return (f"{self.cases} cases / {self.channels_checked} channels: "
                f"{len(self.violations)} budget violations, "
                f"{len(self.oracle_mismatches)} oracle mismatches")


def fuzz_projection(n_cases: int = 40, seed: int = 0,
                    lie: Optional[str] = None) -> ProjectionFuzzReport:
    """Differential fuzz of the A2Q guarantee: random layers -> project
    -> toz-quantize -> the exact worst case (both the closed-form oracle
    and a concrete adversarial input) must fit the budget.

    ``lie`` injects a deliberately unsound projector that a sound
    checker must flag (mirroring core.fuzz's lying certifier):
      * ``"loose"`` — projects against a 2-bit-looser budget;
      * ``"skip"``  — does not project at all.
    """
    if lie not in (None, "loose", "skip"):
        raise ValueError(f"unknown lie mode {lie!r}")
    rng = np.random.default_rng(seed)
    violations: List[str] = []
    mismatches: List[str] = []
    channels = 0
    for case in range(n_cases):
        K = int(rng.integers(4, 48))
        M = int(rng.integers(2, 12))
        wbits = int(rng.integers(3, 9))
        budget = AccumulatorBudget(
            bits=int(rng.integers(6, 15)),
            input_bits=int(rng.integers(2, 9)),
            input_signed=bool(rng.integers(2)),
            zero_center=bool(rng.integers(2)))
        W = rng.normal(size=(K, M)) * rng.uniform(0.5, 3.0)
        scale = np.maximum(
            np.abs(W).max(axis=0) / (2 ** (wbits - 1) - 1), 1e-8)
        if lie == "skip":
            Wp = W
        else:
            target = budget if lie is None else dataclasses.replace(
                budget, bits=budget.bits + 2)
            Wp = np.asarray(project_weights(
                jnp.asarray(W), jnp.asarray(scale), target))
        q = quantize_weights(Wp, scale, wbits)
        bits = channel_bits(q, budget)
        channels += M
        # the per-channel oracle must be consistent with the scalar
        # range oracle and with a concrete adversarial execution
        x_lo, x_hi = budget.input_range()
        scalar = exact_worst_case_bits(K, x_lo, x_hi,
                                       int(q.min()), int(q.max()))
        if np.any(bits > scalar):
            mismatches.append(
                f"case {case}: channel bits {bits.max()} exceed scalar "
                f"oracle {scalar}")
        z_hi = (q * worst_case_inputs(q, budget, True)).sum(axis=0)
        z_lo = (q * worst_case_inputs(q, budget, False)).sum(axis=0)
        m = np.maximum(np.abs(z_lo), np.abs(z_hi) + 1.0)
        concrete = np.ceil(np.log2(np.maximum(m, 2.0))) + 1
        if np.any(concrete != bits):
            # the adversarial input achieves the oracle's extremes, so
            # the concrete bit count must match exactly
            mismatches.append(
                f"case {case}: concrete worst case disagrees with "
                f"channel_worst_case_bits")
        if np.any(bits > budget.bits):
            violations.append(
                f"case {case}: K={K} M={M} w{wbits} "
                f"N={budget.input_bits}{'s' if budget.input_signed else 'u'}"
                f"{' zc' if budget.zero_center else ''} budget "
                f"{budget.bits} -> proven {int(bits.max())} bits"
                + (f" (lie={lie})" if lie else ""))
    return ProjectionFuzzReport(cases=n_cases, channels_checked=channels,
                                violations=violations,
                                oracle_mismatches=mismatches)
