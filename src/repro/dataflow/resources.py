"""Per-node FPGA resource and throughput models for dataflow accelerators.

FINN-R-style fast analytical models: every compute node of a streamlined
graph becomes a :class:`NodeModel` (geometry + SIRA bitwidths), and this
module prices one *implementation style* of it under a *folding*
assignment (PE = output-channel parallelism, SIMD = dot-product
parallelism):

  * ``cycles_per_frame`` — initiation interval of the node: how many
    clock cycles it occupies per input frame.  The graph-level II is the
    max over nodes; FPS = fclk / max-II.
  * ``node_resources``   — LUT / DSP / BRAM estimate for a style.
  * ``select_style``     — cheapest admissible style in LUT-equivalents
    (DSPs weighted by ``dsp_lut_equiv``), generalizing the paper's
    §7.3.2 two-way tail rule to thresholding / composite / DSP-mapped
    MAC across the whole graph, driven by SIRA bitwidths.
  * ``fifo_depth`` / ``fifo_resources`` — inter-node stream FIFOs sized
    from the producer/consumer rate imbalance plus branch-latency skew
    (validated against the cycle-accurate simulator in
    :mod:`repro.dataflow.simulate`).

The per-tail LUT primitives (paper Table 4) come from
:mod:`repro.dataflow.costmodel`; coefficients below that are not from the
paper are FINN-R-shaped and documented inline — they only need to be
*relatively* right for the style/folding decisions to be meaningful.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple, Union

from .costmodel import (lut_add, lut_composite_memory, lut_composite_total,
                        lut_max, lut_meta_kernel, lut_mul,
                        lut_threshold_total, lut_toint)

# ------------------------------------------------------------------ devices


@dataclasses.dataclass(frozen=True)
class DeviceBudget:
    """Resource budget of one FPGA part (BRAMs counted as 18Kb blocks)."""
    name: str
    luts: int
    dsps: int
    brams: int
    fclk_mhz: float = 100.0

    def limit(self, resource: str) -> int:
        return {"luts": self.luts, "dsps": self.dsps,
                "brams": self.brams}[resource]


DEVICES: Dict[str, DeviceBudget] = {
    # Zynq-7020 (PYNQ-Z1/Z2): the paper's embedded class
    "pynq-z1": DeviceBudget("pynq-z1", luts=53_200, dsps=220, brams=280,
                            fclk_mhz=100.0),
    # ZU7EV (ZCU104): mid-range MPSoC
    "zcu104": DeviceBudget("zcu104", luts=230_400, dsps=1_728, brams=624,
                           fclk_mhz=200.0),
    # VU13P-class datacenter card
    "u250": DeviceBudget("u250", luts=1_728_000, dsps=12_288, brams=5_376,
                         fclk_mhz=300.0),
}


def get_device(device: Union[str, DeviceBudget]) -> DeviceBudget:
    if isinstance(device, DeviceBudget):
        return device
    try:
        return DEVICES[device]
    except KeyError:
        raise KeyError(f"unknown device {device!r}; known: "
                       f"{sorted(DEVICES)} (or pass a DeviceBudget)")


# ------------------------------------------------------- model coefficients

#: fixed-point parameter width of composite tails (paper's fixed16.8)
PARAM_BITS = 16
#: LUT-equivalents of one DSP slice when comparing styles — DSPs are the
#: scarcer resource on embedded parts (Zynq-7020: 242 LUTs per DSP), but
#: pricing them at full scarcity would never map a MAC to a DSP; 70 keeps
#: the paper's behaviour (8×8 products on DSP, SIRA-narrowed ones in LUTs)
DSP_LUT_EQUIV = 70.0
#: two MACs pack into one DSP48 when both operands fit 8 bits (INT8 trick)
DSP_PACK_BITS = 8
#: weight/threshold memories at or below this many bits stay in LUTRAM
LUTRAM_CUTOFF_BITS = 4096
#: capacity of one BRAM block as counted by DeviceBudget
BRAM_BITS = 18 * 1024
#: FIFOs at or below this many bits are SRL shift registers, not BRAM
FIFO_LUT_CUTOFF_BITS = 1024


@dataclasses.dataclass
class Resources:
    luts: float = 0.0
    dsps: int = 0
    brams: int = 0

    def __add__(self, other: "Resources") -> "Resources":
        return Resources(self.luts + other.luts, self.dsps + other.dsps,
                         self.brams + other.brams)

    def as_dict(self) -> Dict[str, float]:
        return dict(luts=self.luts, dsps=self.dsps, brams=self.brams)


# ------------------------------------------------------------- node models

#: node kinds priced by this module
KINDS = ("mvau", "threshold", "elementwise", "pool", "toint")

#: elementwise ops with no exact composite (Mul/Add/Max) decomposition —
#: they need the piecewise meta-kernel unless threshold-converted
NONLINEAR_ELEMENTWISE = {"Sigmoid", "Tanh", "Silu", "Gelu", "Softcap",
                         "HardSwish", "Abs"}


@dataclasses.dataclass
class NodeModel:
    """Style-independent description of one compute node.

    ``pixels`` is the number of output positions per frame (spatial sites
    for Conv, 1 for a plain MatMul), ``channels`` the per-position output
    width (Cout / M / C) — PE folds over channels, SIMD over the dot
    length K (mvau only).  Bitwidths come from the SIRA analysis (or the
    datatype-bound baseline)."""
    name: str
    op_type: str
    kind: str
    pixels: int
    channels: int
    K: int = 1
    window: int = 1          # pool kernel footprint (elements reduced)
    in_bits: int = 8
    out_bits: int = 8
    weight_bits: int = 0     # mvau only
    acc_bits: int = 32       # mvau accumulator width
    param_bits: int = PARAM_BITS
    in_elems: int = 0        # dynamic input elements per frame
    reason: str = ""         # why an elementwise tail stayed unconverted
    certificate: str = ""    # monotonicity certificate (threshold kind)

    @property
    def out_elems(self) -> int:
        return self.pixels * self.channels


def _divisors(n: int) -> List[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


def fold_options(node: NodeModel) -> List[Tuple[int, int]]:
    """Admissible (pe, simd) assignments: PE divides channels, SIMD
    divides K (SIMD fixed at 1 for non-mvau kinds)."""
    pes = _divisors(max(node.channels, 1))
    simds = _divisors(max(node.K, 1)) if node.kind == "mvau" else [1]
    return [(pe, simd) for pe in pes for simd in simds]


def cycles_per_frame(node: NodeModel, pe: int = 1, simd: int = 1) -> int:
    """Initiation interval of the node under a folding assignment."""
    ch = math.ceil(max(node.channels, 1) / pe)
    if node.kind == "mvau":
        return node.pixels * ch * math.ceil(node.K / simd)
    if node.kind == "pool":
        return node.pixels * ch * node.window
    # threshold / elementwise / toint: one output element per PE per cycle
    return node.pixels * ch


def node_styles(node: NodeModel) -> List[str]:
    """Admissible implementation styles, cheapest-first preference left to
    :func:`select_style`."""
    if node.kind == "mvau":
        return ["lut_mac", "dsp_mac"]
    if node.kind == "threshold":
        # a certificate other than plain monotone:transfer means the
        # original tail was not an affine+ReLU composite shape (grid
        # certification or mixed per-channel directions) — re-expanding
        # it needs the meta-kernel, not the composite chain
        if node.certificate and node.certificate != "monotone:transfer":
            return ["thresholding", "meta_kernel"]
        return ["thresholding", "composite", "dsp_mac"]
    if node.kind == "elementwise":
        # an uncertifiable tail (machine-readable reason from the
        # monotonicity certifier) or an intrinsically nonlinear op has no
        # exact composite form — only the meta-kernel implements it
        if node.reason or node.op_type in NONLINEAR_ELEMENTWISE:
            return ["meta_kernel"]
        if node.op_type in ("Mul", "Div"):
            return ["composite", "dsp_mac"]
    return ["composite"]


def node_resources(node: NodeModel, style: str, pe: int = 1,
                   simd: int = 1) -> Resources:
    """Price one style of the node under a folding assignment."""
    r = Resources()
    n_i, n_o = node.in_bits, node.out_bits
    if node.kind == "mvau":
        w, a, acc = node.weight_bits, n_i, node.acc_bits
        if style == "dsp_mac":
            pack = 2 if max(w, a) <= DSP_PACK_BITS else 1
            r.dsps = math.ceil(pe * simd / pack)
            # control/routing + accumulator register per PE
            r.luts = pe * simd * 5.0 + pe * acc
        elif style == "lut_mac":
            # fabric multiplier ~0.9 LUT per partial-product bit plus the
            # SIMD adder tree / accumulator (2 LUTs per accumulator bit)
            r.luts = pe * simd * (0.9 * w * a + 2.0) + pe * 2.0 * acc
        else:
            raise ValueError(f"mvau style {style!r}")
        w_bits_total = node.K * node.channels * max(w, 1)
        if w_bits_total <= LUTRAM_CUTOFF_BITS:
            r.luts += w_bits_total / 64.0
        else:
            r.brams += math.ceil(w_bits_total / BRAM_BITS)
        return r
    if node.kind == "threshold":
        if style == "thresholding":
            r.luts = lut_threshold_total(n_i, n_o, node.channels, pe)
        elif style == "meta_kernel":
            r.luts = lut_meta_kernel(n_i, node.param_bits,
                                     node.channels, pe)
        elif style == "composite":
            r.luts = lut_composite_total(n_i, node.param_bits,
                                         node.channels, pe)
        elif style == "dsp_mac":
            # scale & bias stages on DSP slices; params as in composite
            r.dsps = 2 * pe
            r.luts = pe * (n_i + n_o) + \
                lut_composite_memory(node.param_bits, node.channels)
        else:
            raise ValueError(f"threshold style {style!r}")
        return r
    if node.kind == "pool":
        if node.op_type == "MaxPool":
            r.luts = lut_max(n_i, pe)
        else:  # Average/GlobalAveragePool: accumulate + scale by 1/window
            r.luts = lut_add(n_i, n_i, pe) + \
                lut_mul(n_i, node.param_bits, pe)
        return r
    if node.kind == "toint":
        r.luts = lut_toint(n_i, pe)
        return r
    # elementwise (Table 4 meta-kernels)
    op = node.op_type
    if style == "meta_kernel":
        r.luts = lut_meta_kernel(n_i, node.param_bits, node.channels, pe)
        return r
    if style == "dsp_mac" and op in ("Mul", "Div"):
        r.dsps = pe
        r.luts = pe * 4.0 + node.channels * node.param_bits / 64.0
        return r
    if op in ("Mul", "Div"):
        r.luts = lut_mul(n_i, node.param_bits, pe)
        if op == "Div":
            r.luts *= 1.5  # reciprocal stage
    elif op in ("Add", "Sub"):
        r.luts = lut_add(n_i, node.param_bits, pe)
    elif op == "Relu":
        r.luts = lut_max(n_i, pe)
    else:  # conservative fallback for exotic elementwise ops
        r.luts = lut_mul(n_i, node.param_bits, pe)
    # per-channel parameter memory (one set, in LUTs)
    r.luts += node.channels * node.param_bits / 128.0
    return r


def resource_score(r: Resources,
                   dsp_lut_equiv: float = DSP_LUT_EQUIV) -> float:
    """Scalar cost used for style selection / folding tie-breaks: LUTs
    plus DSPs and BRAMs priced in LUT-equivalents (a BRAM18 ~ its LUTRAM
    replacement cost)."""
    return r.luts + dsp_lut_equiv * r.dsps + 128.0 * r.brams


def select_style(node: NodeModel, pe: int = 1, simd: int = 1,
                 dsp_lut_equiv: float = DSP_LUT_EQUIV) -> str:
    """Cheapest admissible style for the node — the graph-level
    generalization of ``costmodel.select_tail_style`` (§7.3.2): SIRA
    bitwidths decide thresholding vs composite vs DSP-mapped MAC."""
    styles = node_styles(node)
    return min(styles, key=lambda s: resource_score(
        node_resources(node, s, pe, simd), dsp_lut_equiv))


def baseline_style(node: NodeModel) -> str:
    """Conservative no-SIRA style: every MAC on DSP slices, every tail as
    the composite elementwise chain (no proven ranges → no exact
    threshold extraction); nonlinear elementwise ops need the meta-kernel
    regardless of analysis."""
    if node.kind == "mvau":
        return "dsp_mac"
    if node.kind == "elementwise" and \
            node.op_type in NONLINEAR_ELEMENTWISE:
        return "meta_kernel"
    if node.kind == "threshold" and node.certificate and \
            node.certificate != "monotone:transfer":
        # no-SIRA baseline keeps the original (nonlinear) tail: meta-kernel
        return "meta_kernel"
    return "composite"


# ------------------------------------------------------------------- FIFOs

def fifo_depth(elems: int, ii_producer: float, ii_consumer: float,
               ipo: int = 1, skew_cycles: float = 0.0) -> int:
    """Analytical stream-FIFO depth for one edge.

    ``elems`` move per frame; the producer emits them over
    ``ii_producer`` cycles, the consumer drains them over
    ``ii_consumer``.  A producer faster than its consumer builds up
    ``elems * (1 - ii_p/ii_c)`` entries within a frame before
    backpressure paces it; ``ipo`` (elements consumed per consumer
    output) adds the burst margin; ``skew_cycles`` covers branch-latency
    mismatch at join nodes (the shorter branch buffers while the longer
    one fills), converted to elements at the producer's rate."""
    imbalance = 0.0
    if ii_consumer > 0 and ii_producer < ii_consumer:
        imbalance = elems * (1.0 - ii_producer / ii_consumer)
    skew_elems = 0.0
    if skew_cycles > 0 and ii_producer > 0:
        skew_elems = skew_cycles * elems / ii_producer
    return int(math.ceil(imbalance + skew_elems)) + int(ipo) + 2


def fifo_resources(depth: int, width_bits: int) -> Resources:
    bits = depth * max(width_bits, 1)
    if bits <= FIFO_LUT_CUTOFF_BITS:
        # SRL32 shift registers: one LUT per bit-slice per 32 entries
        return Resources(luts=max(width_bits, 1) * math.ceil(depth / 32)
                         + 4.0)
    return Resources(luts=8.0, brams=math.ceil(bits / BRAM_BITS))


__all__ = [
    "DeviceBudget", "DEVICES", "get_device", "Resources", "NodeModel",
    "KINDS", "NONLINEAR_ELEMENTWISE", "fold_options", "cycles_per_frame",
    "node_styles", "node_resources", "resource_score", "select_style",
    "baseline_style", "fifo_depth", "fifo_resources", "PARAM_BITS",
    "DSP_LUT_EQUIV",
]
