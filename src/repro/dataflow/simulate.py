"""Cycle-accurate stream simulator for tiny dataflow graphs.

Used **only** to validate the analytical II / FIFO-depth models in tests
(the DSE itself never simulates).  The machine model matches the
analytical one:

  * a node emits at most one output element per firing, with at least
    ``stride`` cycles between firings (II = stride × outputs-per-frame);
  * each input edge carries ``cin`` elements per ``cout`` consumer
    outputs; the k-th firing needs ``ceil((k+1)·cin/cout) −
    ceil(k·cin/cout)`` fresh elements (uniform-rate schedule, handles
    both up- and down-sampling edges);
  * edges are finite FIFOs: a node blocked on a full output FIFO or an
    empty input FIFO stalls (backpressure propagates upstream);
  * source nodes (no input edges) free-run, throttled only by their
    stride and downstream FIFO space — the worst case for FIFO sizing.

``simulate`` reports the steady-state cycles-per-frame (interval between
the last two frame completions at the sink), per-edge peak occupancy and
a deadlock flag.  ``from_estimate`` converts a
:class:`~repro.dataflow.estimate.GraphEstimate` of a uniform-rate graph
(integer strides — MLP-style chains like TFC) into simulator form.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class SimNode:
    name: str
    stride: int              # min cycles between consecutive outputs
    outputs_per_frame: int


@dataclasses.dataclass
class SimEdge:
    src: str
    dst: str
    cin: int                 # elements consumed per frame on this edge
    cout: int                # consumer outputs per frame
    depth: int               # FIFO capacity (elements)


@dataclasses.dataclass
class SimResult:
    cycles_per_frame: float  # steady-state interval at the sink
    frame_times: List[int]   # completion cycle of each frame
    max_occupancy: Dict[Tuple[str, str], int]
    total_cycles: int
    deadlocked: bool


def _need(edge: SimEdge, k: int) -> int:
    """Fresh elements the consumer's k-th firing consumes from ``edge``."""
    return (math.ceil((k + 1) * edge.cin / edge.cout)
            - math.ceil(k * edge.cin / edge.cout))


def simulate(nodes: List[SimNode], edges: List[SimEdge],
             frames: int = 4,
             max_cycles: Optional[int] = None) -> SimResult:
    order = {n.name: i for i, n in enumerate(nodes)}
    for e in edges:
        if order[e.src] >= order[e.dst]:
            raise ValueError("nodes must be listed in topological order")
    in_edges: Dict[str, List[SimEdge]] = {n.name: [] for n in nodes}
    out_edges: Dict[str, List[SimEdge]] = {n.name: [] for n in nodes}
    for e in edges:
        in_edges[e.dst].append(e)
        out_edges[e.src].append(e)

    sinks = [n for n in nodes if not out_edges[n.name]]
    if len(sinks) != 1:
        raise ValueError("graph must have exactly one sink")
    sink = sinks[0]

    fifo: Dict[Tuple[str, str], int] = {(e.src, e.dst): 0 for e in edges}
    occ_max = dict(fifo)
    produced = {n.name: 0 for n in nodes}
    ready = {n.name: 0 for n in nodes}
    goal = {n.name: frames * n.outputs_per_frame for n in nodes}
    by_name = {n.name: n for n in nodes}

    if max_cycles is None:
        worst_ii = max(n.stride * n.outputs_per_frame for n in nodes)
        max_cycles = (frames + 4) * worst_ii * (len(nodes) + 2)

    frame_times: List[int] = []
    t = 0
    while produced[sink.name] < goal[sink.name] and t < max_cycles:
        for n in nodes:                   # topo order: same-cycle bypass
            name = n.name
            if produced[name] >= goal[name] or t < ready[name]:
                continue
            k = produced[name]
            needs = [(e, _need(e, k)) for e in in_edges[name]]
            if any(fifo[(e.src, e.dst)] < nd for e, nd in needs):
                continue
            if any(fifo[(e.src, e.dst)] >= e.depth
                   for e in out_edges[name]):
                continue
            for e, nd in needs:
                fifo[(e.src, e.dst)] -= nd
            for e in out_edges[name]:
                key = (e.src, e.dst)
                fifo[key] += 1
                occ_max[key] = max(occ_max[key], fifo[key])
            produced[name] = k + 1
            ready[name] = t + n.stride
            if n is sink and \
                    produced[name] % n.outputs_per_frame == 0:
                frame_times.append(t)
        t += 1

    done = produced[sink.name] >= goal[sink.name]
    if len(frame_times) >= 2:
        interval = float(frame_times[-1] - frame_times[-2])
    elif frame_times:
        interval = float(frame_times[0] + 1)
    else:
        interval = float("inf")
    return SimResult(cycles_per_frame=interval, frame_times=frame_times,
                     max_occupancy=occ_max, total_cycles=t,
                     deadlocked=not done)


def analytical_ii(nodes: List[SimNode]) -> int:
    """The analytical steady-state cycles-per-frame: max node II."""
    return max(n.stride * n.outputs_per_frame for n in nodes)


def from_estimate(est) -> Tuple[List[SimNode], List[SimEdge]]:
    """Build simulator form from a :class:`GraphEstimate` — only valid
    for uniform-rate graphs whose node II divides evenly by the output
    element count (MLP-style chains such as TFC)."""
    out_elems = {n.name: n.pixels * n.channels for n in est.nodes}
    nodes = []
    for n in est.nodes:
        if n.cycles % out_elems[n.name]:
            raise ValueError(
                f"{n.name}: II {n.cycles} is not an integer multiple of "
                f"its {out_elems[n.name]} output elements — uniform-rate "
                f"simulation unsupported")
        nodes.append(SimNode(name=n.name,
                             stride=n.cycles // out_elems[n.name],
                             outputs_per_frame=out_elems[n.name]))
    edges = [SimEdge(src=f.producer, dst=f.consumer, cin=f.elems,
                     cout=out_elems[f.consumer], depth=f.depth)
             for f in est.fifos]
    return nodes, edges


__all__ = ["SimNode", "SimEdge", "SimResult", "simulate",
           "analytical_ii", "from_estimate"]
