"""Analytical FPGA layer-tail cost models (paper §5.4, Tables 4/7, Fig 23).

These models reproduce the paper's LUT predictions for the two layer-tail
implementation styles and drive the composite-vs-thresholding crossover
analysis.  They are kept verbatim from the paper (coefficients from
Table 4); a TPU mirror (HBM bytes moved per tail) is provided for the
hardware-adaptation analysis in DESIGN.md §2.

This module absorbed ``repro.core.costmodel`` (which remains as an
import-compatible shim); the graph-level resource/throughput models that
build on these per-tail primitives live in :mod:`repro.dataflow.resources`.
"""
from __future__ import annotations

import dataclasses

from ..core.ops import COST_REGISTRY

# Table 4: LUT = alpha * f(n_i, n_p) * PE + beta.  Coefficients are
# registered in the unified per-op registry by repro.core.ops itself;
# ELEMENTWISE_COEFFS is the legacy dict-compatible view over them.
ELEMENTWISE_COEFFS = COST_REGISTRY


def lut_mul(n_i: int, n_p: int, pe: int) -> float:
    c = ELEMENTWISE_COEFFS["Mul"]
    return c["alpha"] * n_i * n_p * pe + c["beta"]


def lut_add(n_i: int, n_p: int, pe: int) -> float:
    c = ELEMENTWISE_COEFFS["Add"]
    return c["alpha"] * (n_i + n_p) * pe + c["beta"]


def lut_toint(n_i: int, pe: int) -> float:
    c = ELEMENTWISE_COEFFS["ToInt"]
    return c["alpha"] * n_i * pe + c["beta"]


def lut_max(n_i: int, pe: int) -> float:
    c = ELEMENTWISE_COEFFS["Max"]
    return c["alpha"] * n_i * pe + c["beta"]


#: piecewise segments of the nonlinear elementwise meta-kernel
META_KERNEL_SEGMENTS = 16


def lut_meta_kernel(n_i: int, n_p: int, channels: int, pe: int) -> float:
    """Nonlinear elementwise meta-kernel (FINN-style piecewise-linear
    interpolator): per-PE segment-select comparators feeding one
    fixed-point multiply-add, a shared slope/intercept segment table, and
    the per-channel scale/bias parameter memory.  Strictly costlier than
    a same-width ``Mul`` (alpha 2.6 vs 1.18) — this is the price of a
    tail that could *not* be certified for threshold conversion."""
    c = ELEMENTWISE_COEFFS["MetaKernel"]
    compute = c["alpha"] * n_i * n_p * pe + c["beta"]
    table = META_KERNEL_SEGMENTS * 2.0 * n_p / 64.0
    return compute + table + lut_composite_memory(n_p, channels)


# --------------------------------------------------------------------------
# §5.4.2 composite layer tail:  Mul → Add → Max(ReLU) → Mul → ToInt
# --------------------------------------------------------------------------

def lut_composite_compute(n_i: int, n_p: int, pe: int) -> float:
    """LUT_comp(n_i, n_p, PE) with lossless fixed-point width growth."""
    return (lut_mul(n_i, n_p, pe)
            + lut_add(n_i + n_p, n_p, pe)
            + lut_max(n_i + n_p + 1, pe)
            + lut_mul(n_i + n_p + 1, n_p, pe)
            + lut_toint(n_i + n_p + 1, pe))


def lut_composite_memory(n_p: int, channels: int) -> float:
    """Two per-channel parameter sets (Mul, Add) stored in 6-input LUTs."""
    return 2.0 * channels * n_p / 64.0


def lut_composite_total(n_i: int, n_p: int, channels: int, pe: int) -> float:
    return lut_composite_compute(n_i, n_p, pe) + \
        lut_composite_memory(n_p, channels)


# --------------------------------------------------------------------------
# §5.4.3 thresholding layer tail
# --------------------------------------------------------------------------

def n_thresholds(n_o: int, channels: int) -> int:
    """Sum_T = (2^n_o - 1) * C."""
    return (2 ** n_o - 1) * channels


def lut_threshold_memory(n_i: int, n_o: int, channels: int) -> float:
    mem_bits = n_thresholds(n_o, channels) * n_i
    return mem_bits / 64.0


def lut_threshold_compute(n_i: int, n_o: int, pe: int) -> float:
    return n_o * pe * n_i


def lut_threshold_total(n_i: int, n_o: int, channels: int, pe: int) -> float:
    return lut_threshold_compute(n_i, n_o, pe) + \
        lut_threshold_memory(n_i, n_o, channels)


# --------------------------------------------------------------------------
# crossover + style selection (Fig 23 / §7.3.2)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class TailCost:
    thresholding_luts: float
    composite_luts: float

    @property
    def best(self) -> str:
        return ("thresholding"
                if self.thresholding_luts <= self.composite_luts
                else "composite")


def tail_cost(n_i: int, n_o: int, n_p: int, channels: int,
              pe: int) -> TailCost:
    return TailCost(
        thresholding_luts=lut_threshold_total(n_i, n_o, channels, pe),
        composite_luts=lut_composite_total(n_i, n_p, channels, pe))


def select_tail_style(n_i: int, n_o: int, n_p: int, channels: int,
                      pe: int) -> str:
    """Automated implementation-style choice the paper suggests as future
    work (§7.3.2): <4-bit outputs → thresholding, >8-bit → composite,
    in between decided by the analytical models.

    This is the *two-way* per-tail rule from the paper; the graph-level
    three-way generalization (thresholding / composite / DSP-mapped) is
    :func:`repro.dataflow.resources.select_style`."""
    if n_o < 4:
        return "thresholding"
    if n_o > 8:
        return "composite"
    return tail_cost(n_i, n_o, n_p, channels, pe).best


# --------------------------------------------------------------------------
# TPU mirror (DESIGN.md §2): HBM bytes per tail invocation
# --------------------------------------------------------------------------

def _dtype_bytes(bits: int) -> int:
    for b in (8, 16, 32):
        if bits <= b:
            return b // 8
    return 8


def tpu_tail_bytes(n_elems: int, n_i_bits: int, n_o_bits: int,
                   channels: int, style: str, fused: bool = True) -> int:
    """HBM traffic of one layer-tail application over n_elems activations.

    composite, unfused: each elementwise op re-reads/writes activations
    (Mul, Add, act, Mul, ToInt → 5 read+write passes at intermediate
    width).  thresholding (or a fused composite): single read at
    accumulator width + single write at activation width + threshold/param
    table read (VMEM-resident, counted once).
    """
    in_b = _dtype_bytes(n_i_bits)
    out_b = _dtype_bytes(n_o_bits)
    if style == "composite" and not fused:
        mid_b = 4  # f32/fixed32 intermediates
        return n_elems * (in_b + out_b + 4 * 2 * mid_b) + channels * 2 * 4
    if style == "composite":  # fused composite (one pass)
        return n_elems * (in_b + out_b) + channels * 2 * 4
    # thresholding: param table = (2^n_o - 1) * C thresholds at in width
    table = n_thresholds(n_o_bits, channels) * in_b
    return n_elems * (in_b + out_b) + table
