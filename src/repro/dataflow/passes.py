"""Build-flow integration of the dataflow DSE subsystem.

Two graph-preserving :class:`~repro.core.passes.Transformation`s,
registered with the flow driver as ``step_dataflow_estimate`` and
``step_dataflow_fold`` (see :mod:`repro.core.flow`).  Both reuse the
model's cached range analysis, so appending them to a flow adds zero
extra full propagations; the extracted dataflow graph (one executor
shape probe) and a folding search result are shared between the two
steps via metadata, keyed on the graph's mutation counter."""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Union

from ..core.passes import Transformation
from .estimate import compare_sira_vs_baseline, extract_dataflow
from .folding import search_folding
from .resources import DeviceBudget, get_device


def _shared_dfg(model, input_shapes):
    """Extract (or reuse) the dataflow graph, stashed with the graph
    version so a mutation between steps invalidates it."""
    cached = model.metadata.get("dataflow_graph")
    if cached is not None and cached[0] == model.graph.version:
        return cached[1]
    dfg = extract_dataflow(model, input_shapes)
    model.metadata["dataflow_graph"] = (model.graph.version, dfg)
    return dfg


class DataflowEstimate(Transformation):
    """Graph-level resource/throughput estimate + SIRA-vs-baseline
    comparison.  Stores a :class:`DataflowComparison` under
    ``metadata['dataflow_report']`` (its ``.sira`` side additionally
    under ``metadata['dataflow_estimate']``); with ``target_fps`` set,
    the folding search result also lands under ``metadata['folding']``
    (so a following :class:`DataflowFold` at the same target is free)."""

    def __init__(self, device: Union[str, DeviceBudget] = "pynq-z1",
                 target_fps: Optional[float] = None,
                 input_shapes: Optional[Dict[str, Sequence[int]]] = None):
        self.device = device
        self.target_fps = target_fps
        self.input_shapes = input_shapes

    def apply(self, model):
        dfg = _shared_dfg(model, self.input_shapes)
        folding = None
        if self.target_fps is not None:
            fold = search_folding(model, target_fps=self.target_fps,
                                  device=self.device, dataflow_graph=dfg)
            model.metadata["folding"] = fold
            if fold.feasible:
                folding = fold.folding
        report = compare_sira_vs_baseline(model, device=self.device,
                                          folding=folding,
                                          dataflow_graph=dfg)
        model.metadata["dataflow_report"] = report
        model.metadata["dataflow_estimate"] = report.sira
        return model, False


class DataflowFold(Transformation):
    """Folding search toward a target FPS under a device budget.  Stores
    the :class:`FoldingResult` (feasible or not, with the binding
    constraint) under ``metadata['folding']``.  Reuses the result a
    preceding :class:`DataflowEstimate` already computed when the graph
    and target are unchanged."""

    def __init__(self, target_fps: float = 30.0,
                 device: Union[str, DeviceBudget] = "pynq-z1",
                 input_shapes: Optional[Dict[str, Sequence[int]]] = None):
        self.target_fps = target_fps
        self.device = device
        self.input_shapes = input_shapes

    def apply(self, model):
        cached = model.metadata.get("dataflow_graph")
        existing = model.metadata.get("folding")
        if (existing is not None and cached is not None
                and cached[0] == model.graph.version
                and existing.target_fps == self.target_fps
                and existing.device == get_device(self.device).name):
            return model, False
        model.metadata["folding"] = search_folding(
            model, target_fps=self.target_fps, device=self.device,
            dataflow_graph=_shared_dfg(model, self.input_shapes))
        return model, False


__all__ = ["DataflowEstimate", "DataflowFold"]
