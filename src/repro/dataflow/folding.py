"""Folding search: balance per-node initiation intervals to a target FPS.

FINN-style DSE: a target frame rate fixes a cycle budget
``T = fclk / fps``; every node independently picks the *cheapest*
(PE, SIMD) assignment whose initiation interval fits ``T`` (cycles are
monotone in folding, so the cheapest feasible assignment exists iff the
fully-parallel one fits).  The folded graph is then priced and checked
against the device budget.  Infeasibility is reported with its **binding
constraint**:

  * ``ii:<node>``  — the node cannot reach the cycle budget even fully
    parallelized (throughput-bound);
  * ``luts`` / ``dsps`` / ``brams`` — the resource whose utilization
    overshoots the device the most (resource-bound).

``max_throughput`` binary-searches the cycle budget for the fastest
feasible design point on a device.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence, Tuple, Union

from ..core.model import SiraModel
from ..obs.trace import get_tracer
from .estimate import (DataflowGraph, GraphEstimate, extract_dataflow,
                       estimate, widen_dataflow)
from .resources import (DeviceBudget, DSP_LUT_EQUIV, NodeModel,
                        baseline_style, cycles_per_frame, fold_options,
                        get_device, node_resources, resource_score,
                        select_style)


@dataclasses.dataclass
class FoldingResult:
    feasible: bool
    folding: Dict[str, Tuple[int, int]]    # node -> (pe, simd)
    target_fps: float
    achieved_fps: float
    utilization: Dict[str, float]
    binding: Optional[str]                 # None when feasible
    estimate: GraphEstimate
    device: str = ""                       # DeviceBudget.name searched on

    def summary(self) -> Dict[str, object]:
        return dict(feasible=self.feasible, target_fps=self.target_fps,
                    achieved_fps=self.achieved_fps, binding=self.binding,
                    utilization=self.utilization, device=self.device)


def _cheapest_folding_for(node: NodeModel, target_cycles: int,
                          styles: str, dsp_lut_equiv: float = DSP_LUT_EQUIV
                          ) -> Optional[Tuple[int, int]]:
    """Least-resource (pe, simd) meeting the cycle budget, or None."""
    best: Optional[Tuple[int, int]] = None
    best_score = math.inf
    n_cand = 0
    for pe, simd in fold_options(node):
        n_cand += 1
        if cycles_per_frame(node, pe, simd) > target_cycles:
            continue
        style = (baseline_style(node) if styles == "baseline"
                 else select_style(node, pe, simd, dsp_lut_equiv))
        score = resource_score(node_resources(node, style, pe, simd),
                               dsp_lut_equiv)
        if score < best_score:
            best, best_score = (pe, simd), score
    get_tracer().count("folding.candidates", n_cand, node=node.name)
    return best


def search_folding(model: SiraModel, *,
                   target_fps: float,
                   device: Union[str, DeviceBudget] = "pynq-z1",
                   widths: str = "sira",
                   styles: str = "auto",
                   input_shapes: Optional[Dict[str, Sequence[int]]] = None,
                   dataflow_graph: Optional[DataflowGraph] = None
                   ) -> FoldingResult:
    """Find a folding that hits ``target_fps`` within the device budget,
    or report the binding constraint that prevents it."""
    tr = get_tracer()
    with tr.span("dse:search_folding", target_fps=target_fps) as sp:
        result = _search_folding(model, target_fps=target_fps,
                                 device=device, widths=widths,
                                 styles=styles,
                                 input_shapes=input_shapes,
                                 dataflow_graph=dataflow_graph)
        sp.set_attr("device", result.device)
        sp.set_attr("feasible", result.feasible)
        if result.binding is not None:
            sp.set_attr("binding", result.binding)
        return result


def _search_folding(model: SiraModel, *,
                    target_fps: float,
                    device: Union[str, DeviceBudget],
                    widths: str, styles: str,
                    input_shapes: Optional[Dict[str, Sequence[int]]],
                    dataflow_graph: Optional[DataflowGraph]
                    ) -> FoldingResult:
    tr = get_tracer()
    d = get_device(device)
    dfg = dataflow_graph or extract_dataflow(model, input_shapes)
    target_cycles = max(1, int(d.fclk_mhz * 1e6 / target_fps))

    # price width-attached nodes — the same cost model estimate() judges
    # the folded design with (raw extracted nodes carry placeholder
    # acc_bits=32, which would inflate every MAC toward dsp_mac)
    wide = widen_dataflow(model, dfg, widths)

    def attempt(dsp_lut_equiv: float) -> FoldingResult:
        folding: Dict[str, Tuple[int, int]] = {}
        for nm in dfg.nodes:
            pick = _cheapest_folding_for(wide[nm.name], target_cycles,
                                         styles, dsp_lut_equiv)
            if pick is None:
                est = estimate(model, widths=widths, styles=styles,
                               folding=folding, device=d,
                               dataflow_graph=dfg,
                               dsp_lut_equiv=dsp_lut_equiv)
                tr.count("folding.reject.ii", node=nm.name)
                return FoldingResult(
                    feasible=False, folding=folding,
                    target_fps=target_fps, achieved_fps=est.fps,
                    utilization=est.utilization(d),
                    binding=f"ii:{nm.name}", estimate=est,
                    device=d.name)
            folding[nm.name] = pick
        est = estimate(model, widths=widths, styles=styles,
                       folding=folding, device=d, dataflow_graph=dfg,
                       dsp_lut_equiv=dsp_lut_equiv)
        util = est.utilization(d)
        over = {k: v for k, v in util.items() if v > 1.0}
        if over:
            binding = max(over, key=over.get)
            tr.count(f"folding.reject.{binding}",
                     utilization=round(over[binding], 3))
            return FoldingResult(feasible=False, folding=folding,
                                 target_fps=target_fps,
                                 achieved_fps=est.fps, utilization=util,
                                 binding=binding, estimate=est,
                                 device=d.name)
        return FoldingResult(feasible=True, folding=folding,
                             target_fps=target_fps, achieved_fps=est.fps,
                             utilization=util, binding=None, estimate=est,
                             device=d.name)

    result = attempt(DSP_LUT_EQUIV)
    # styles trade DSPs against LUTs: before declaring infeasibility,
    # retry with pricing averse to the binding resource (a DSP-starved
    # budget may fit entirely in fabric, a LUT-starved one on DSPs) so
    # the reported binding constraint reflects the *design space*, not
    # one pricing of it
    if not result.feasible:
        retry_equiv = {"dsps": 1e9, "luts": 1.0}.get(result.binding)
        if retry_equiv is not None:
            alt = attempt(retry_equiv)
            if alt.feasible:
                return alt
    return result


def max_throughput(model: SiraModel, *,
                   device: Union[str, DeviceBudget] = "pynq-z1",
                   widths: str = "sira",
                   styles: str = "auto",
                   input_shapes: Optional[Dict[str, Sequence[int]]] = None,
                   dataflow_graph: Optional[DataflowGraph] = None
                   ) -> FoldingResult:
    """Fastest feasible design point: binary search over the cycle budget
    between the fully-parallel II and the fully-folded II."""
    with get_tracer().span("dse:max_throughput",
                           device=get_device(device).name) as sp:
        result = _max_throughput(model, device=device, widths=widths,
                                 styles=styles,
                                 input_shapes=input_shapes,
                                 dataflow_graph=dataflow_graph)
        sp.set_attr("feasible", result.feasible)
        sp.set_attr("achieved_fps", result.achieved_fps)
        return result


def _max_throughput(model: SiraModel, *,
                    device: Union[str, DeviceBudget],
                    widths: str, styles: str,
                    input_shapes: Optional[Dict[str, Sequence[int]]],
                    dataflow_graph: Optional[DataflowGraph]
                    ) -> FoldingResult:
    d = get_device(device)
    dfg = dataflow_graph or extract_dataflow(model, input_shapes)
    # the graph II can never beat the slowest node's fully-parallel II
    lo = max(max(cycles_per_frame(nm, *max(
        fold_options(nm), key=lambda f: f[0] * f[1]))
        for nm in dfg.nodes), 1)
    hi = max(cycles_per_frame(nm, 1, 1) for nm in dfg.nodes)

    def attempt(cycles: int) -> FoldingResult:
        # +0.5 so the derived integer cycle budget is exactly `cycles`
        # (guarding against float round-down to cycles - 1)
        fps = d.fclk_mhz * 1e6 / (cycles + 0.5)
        return search_folding(model, target_fps=fps, device=d,
                              widths=widths, styles=styles,
                              dataflow_graph=dfg)

    best = attempt(hi)
    if not best.feasible:
        return best                      # even fully folded doesn't fit
    while lo < hi:
        mid = (lo + hi) // 2
        r = attempt(mid)
        if r.feasible:
            best, hi = r, mid
        else:
            lo = mid + 1
    return best


__all__ = ["FoldingResult", "search_folding", "max_throughput"]
