"""Dataflow accelerator design-space exploration (DSE) on SIRA analyses.

The paper's headline results come from applying SIRA bitwidths to a whole
FPGA dataflow accelerator; this package turns an analyzed
:class:`~repro.core.model.SiraModel` into accelerator-level numbers:

  * :mod:`costmodel`  — the paper's per-tail LUT models (Table 4/Fig 23;
    absorbed from ``repro.core.costmodel``, which remains as a shim);
  * :mod:`resources`  — per-node LUT/DSP/BRAM + cycles models, device
    budgets, style selection (thresholding / composite / DSP-MAC);
  * :mod:`estimate`   — whole-graph estimates, FIFO sizing, and the
    SIRA-vs-datatype-baseline comparison;
  * :mod:`folding`    — PE/SIMD folding search to a target FPS with
    binding-constraint reporting, plus max-throughput search;
  * :mod:`simulate`   — cycle-accurate stream simulator validating the
    analytical II/FIFO models (tests only);
  * :mod:`passes`     — ``step_dataflow_estimate`` / ``step_dataflow_fold``
    build-flow steps.
"""
from .costmodel import (ELEMENTWISE_COEFFS, TailCost, lut_add,  # noqa: F401
                        lut_composite_compute, lut_composite_memory,
                        lut_composite_total, lut_max, lut_meta_kernel,
                        lut_mul, lut_threshold_compute,
                        lut_threshold_memory, lut_threshold_total,
                        lut_toint, n_thresholds, select_tail_style,
                        tail_cost, tpu_tail_bytes)
from .resources import (DEVICES, DeviceBudget, NodeModel,      # noqa: F401
                        NONLINEAR_ELEMENTWISE, Resources, baseline_style,
                        cycles_per_frame, fifo_depth, fifo_resources,
                        fold_options, get_device, node_resources,
                        node_styles, resource_score, select_style)
from .estimate import (DataflowComparison, DataflowGraph, Edge,  # noqa: F401
                       FifoEstimate, GraphEstimate, NodeEstimate,
                       compare_sira_vs_baseline, estimate,
                       extract_dataflow, widen_dataflow)
from .folding import (FoldingResult, max_throughput,           # noqa: F401
                      search_folding)
from .simulate import (SimEdge, SimNode, SimResult,            # noqa: F401
                       analytical_ii, from_estimate, simulate)
from .passes import DataflowEstimate, DataflowFold             # noqa: F401
