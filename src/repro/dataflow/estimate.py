"""Graph-level dataflow estimates from an analyzed ``SiraModel``.

``extract_dataflow`` turns the optimized graph into compute
:class:`~repro.dataflow.resources.NodeModel` records plus stream edges:
geometry from a one-off shape probe through the numpy executor, bitwidths
from the model's cached SIRA analysis and the §4.2 accumulator reports.
``estimate`` prices the whole graph (per-node LUT/DSP/BRAM, II, style;
inter-node FIFO depths; totals and the throughput bottleneck) under a
folding assignment, and ``compare_sira_vs_baseline`` produces the paper's
headline SIRA-vs-datatype-bound resource deltas (−LUTs, −DSPs, −accumulator
bits) on the *same* topology and folding — widths and style decisions are
the only difference, which is exactly what SIRA contributes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.accumulator import (AccumulatorReport, _dot_length,
                                minimize_accumulators)
from ..core.intervals import InvalidRangeError
from ..core.model import SiraModel
from .resources import (DeviceBudget, NodeModel, baseline_style,
                        cycles_per_frame, fifo_depth, fifo_resources,
                        get_device, node_resources, select_style,
                        DSP_LUT_EQUIV)

#: ops that are pure wiring on a dataflow accelerator (no compute unit)
WIRE_OPS = {"Flatten", "Reshape", "Identity", "Transpose"}

#: container stream widths available to a no-SIRA (datatype-bound) design
CONTAINER_BITS = (8, 16, 32)


def container_bits(bits: int) -> int:
    for b in CONTAINER_BITS:
        if bits <= b:
            return b
    return CONTAINER_BITS[-1]


@dataclasses.dataclass
class Edge:
    """One stream between two compute nodes (``elems`` per frame)."""
    producer: str
    consumer: str
    elems: int
    width_bits: int


@dataclasses.dataclass
class DataflowGraph:
    nodes: List[NodeModel]
    edges: List[Edge]

    def node(self, name: str) -> NodeModel:
        return next(n for n in self.nodes if n.name == name)


# --------------------------------------------------------------- extraction

def _shape_probe(model: SiraModel,
                 input_shapes: Optional[Dict[str, Sequence[int]]] = None
                 ) -> Dict[str, Tuple[int, ...]]:
    """Shapes of every tensor via one executor pass (batch-1 frames)."""
    shapes = dict(input_shapes or {})
    single = model.metadata.get("input_shape")
    if single is not None and len(model.graph.inputs) == 1:
        shapes.setdefault(model.graph.inputs[0], tuple(single))
    missing = [t for t in model.graph.inputs if t not in shapes]
    if missing:
        raise ValueError(
            f"dataflow estimate needs frame shapes for inputs {missing}; "
            f"set metadata['input_shape'] or pass input_shapes=")
    feeds = {}
    for t in model.graph.inputs:
        r = model.input_ranges[t]
        mid = (np.asarray(r.lo) + np.asarray(r.hi)) * 0.5
        feeds[t] = np.broadcast_to(mid, shapes[t]).astype(np.float64)
    env = model.graph.execute(feeds, record_all=True)
    return {name: np.shape(v) for name, v in env.items()}


def _range_bits(model: SiraModel, tensor: str, default: int = 32) -> int:
    """Stream width of a tensor from its SIRA range (unsigned when the
    proven integer interval is non-negative); ``default`` for tensors
    whose scaled-integer structure was lost (fixed32 regions)."""
    r = model.ranges.get(tensor)
    if r is None or not r.is_scaled_int:
        return default
    try:
        if np.min(r.int_lo) >= 0:
            bits = r.required_unsigned_bits()
        else:
            bits = r.required_signed_bits()
    except InvalidRangeError:
        return default
    return max(1, min(int(bits), 32))


def _channel_geometry(shape: Tuple[int, ...], axis: int
                      ) -> Tuple[int, int]:
    """(pixels, channels) of a frame tensor given its channel axis."""
    if not shape:
        return 1, 1
    channels = int(shape[axis])
    pixels = int(np.prod(shape)) // max(channels, 1)
    return max(pixels, 1), max(channels, 1)


def _dyn_inputs(node, const_tensors) -> List[str]:
    return [t for t in node.inputs if t not in const_tensors]


def extract_dataflow(model: SiraModel,
                     input_shapes: Optional[Dict[str, Sequence[int]]] = None
                     ) -> DataflowGraph:
    """Compute nodes + stream edges of the model's optimized graph.

    Constant subgraphs (weight preparation) are folded into the consuming
    node's weight memory; ``WIRE_OPS`` are transparent."""
    g = model.graph
    g.toposort()
    shapes = _shape_probe(model, input_shapes)
    ranges = model.ranges

    alias: Dict[str, str] = {}          # wire-op output -> real source

    def resolve(t: str) -> str:
        while t in alias:
            t = alias[t]
        return t

    producer_of: Dict[str, str] = {}    # tensor -> compute node name
    nodes: List[NodeModel] = []
    edges: List[Edge] = []
    # constness propagates through folded weight-prep subgraphs: the
    # outputs of an all-constant node are constants too (e.g. a wscale
    # Mul producing a quantized FC weight must stay a weight memory, not
    # become a dynamic stream)
    const_tensors = set(g.initializers)

    for node in g.nodes:
        dyn = _dyn_inputs(node, const_tensors)
        if not dyn:
            const_tensors.update(node.outputs)
            continue                    # constant fold: weight prep
        if node.op_type in WIRE_OPS:
            alias[node.outputs[0]] = dyn[0]
            continue
        out = node.outputs[0]
        out_shape = shapes.get(out, ())
        # channel axis: channels-first for 4D (Conv-side), last otherwise
        axis = 1 if len(out_shape) == 4 else -1
        in0 = resolve(dyn[0])
        in_bits = max((_range_bits(model, t) for t in map(resolve, dyn)),
                      default=32)
        out_bits = _range_bits(model, out)
        in_elems = int(np.prod(shapes.get(in0, (1,))))

        if node.op_type in ("MatMul", "Gemm", "Conv"):
            K = _dot_length(g, node) or 1
            pixels, channels = _channel_geometry(out_shape, axis)
            w_tensor = next((t for t in node.inputs if t not in dyn),
                            None)
            w_bits = _range_bits(model, w_tensor, default=8) \
                if w_tensor else 8
            nm = NodeModel(name=node.name, op_type=node.op_type,
                           kind="mvau", pixels=pixels, channels=channels,
                           K=K, in_bits=in_bits, out_bits=out_bits,
                           weight_bits=w_bits, in_elems=in_elems)
        elif node.op_type == "MultiThreshold":
            thr = g.initializers[node.inputs[1]]
            C, steps = thr.shape
            n_o = max(1, int(math.ceil(math.log2(steps + 1))))
            t_axis = int(node.attrs.get("axis", -1))
            pixels, channels = _channel_geometry(out_shape, t_axis)
            nm = NodeModel(name=node.name, op_type=node.op_type,
                           kind="threshold", pixels=pixels,
                           channels=int(C), in_bits=in_bits, out_bits=n_o,
                           in_elems=in_elems,
                           certificate=str(node.attrs.get("certificate",
                                                          "")))
        elif node.op_type in ("MaxPool", "AveragePool",
                              "GlobalAveragePool"):
            pixels, channels = _channel_geometry(out_shape, axis)
            if node.op_type == "GlobalAveragePool":
                in_shape = shapes.get(in0, (1, 1, 1, 1))
                window = int(np.prod(in_shape[2:])) or 1
            else:
                k = int(node.attrs.get("kernel", 2))
                window = k * k
            nm = NodeModel(name=node.name, op_type=node.op_type,
                           kind="pool", pixels=pixels, channels=channels,
                           window=window, in_bits=in_bits,
                           out_bits=out_bits, in_elems=in_elems)
        elif node.op_type == "Quant":
            bits = int(np.asarray(g.initializers[node.inputs[3]]))
            pixels, channels = _channel_geometry(out_shape, axis)
            nm = NodeModel(name=node.name, op_type=node.op_type,
                           kind="toint", pixels=pixels, channels=channels,
                           in_bits=in_bits, out_bits=bits,
                           in_elems=in_elems)
        else:                           # elementwise (Table 4 meta-kernel)
            pixels, channels = _channel_geometry(out_shape, axis)
            reason = str(node.attrs.get("meta_kernel_reason")
                         or node.attrs.get("unconverted_reason") or "")
            nm = NodeModel(name=node.name, op_type=node.op_type,
                           kind="elementwise", pixels=pixels,
                           channels=channels, in_bits=in_bits,
                           out_bits=out_bits, in_elems=in_elems,
                           reason=reason)
        nodes.append(nm)
        for t in dyn:
            src = resolve(t)
            if src in producer_of:
                edges.append(Edge(producer=producer_of[src],
                                  consumer=nm.name,
                                  elems=int(np.prod(shapes.get(src, (1,)))),
                                  width_bits=_range_bits(model, src)))
        for o in node.outputs:
            producer_of[o] = nm.name
    return DataflowGraph(nodes=nodes, edges=edges)


# --------------------------------------------------------------- estimates

@dataclasses.dataclass
class NodeEstimate:
    name: str
    op_type: str
    kind: str
    style: str
    pe: int
    simd: int
    cycles: int
    luts: float
    dsps: int
    brams: int
    in_bits: int
    out_bits: int
    weight_bits: int
    acc_bits: int
    channels: int
    K: int
    pixels: int


@dataclasses.dataclass
class FifoEstimate:
    producer: str
    consumer: str
    depth: int
    width_bits: int
    elems: int
    luts: float
    brams: int


@dataclasses.dataclass
class GraphEstimate:
    name: str
    widths: str                      # "sira" | "datatype"
    nodes: List[NodeEstimate]
    fifos: List[FifoEstimate]
    fclk_mhz: float

    @property
    def luts(self) -> float:
        return sum(n.luts for n in self.nodes) + \
            sum(f.luts for f in self.fifos)

    @property
    def dsps(self) -> int:
        return sum(n.dsps for n in self.nodes)

    @property
    def brams(self) -> int:
        return sum(n.brams for n in self.nodes) + \
            sum(f.brams for f in self.fifos)

    @property
    def max_cycles(self) -> int:
        return max((n.cycles for n in self.nodes), default=1)

    @property
    def bottleneck(self) -> Optional[str]:
        if not self.nodes:
            return None
        return max(self.nodes, key=lambda n: n.cycles).name

    @property
    def fps(self) -> float:
        return self.fclk_mhz * 1e6 / self.max_cycles

    def style_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for n in self.nodes:
            out[n.style] = out.get(n.style, 0) + 1
        return out

    def utilization(self, device: Union[str, DeviceBudget]
                    ) -> Dict[str, float]:
        d = get_device(device)

        def frac(used, limit):
            # a zero-resource budget ("use no DSPs") is a legal
            # DeviceBudget: unused → 0, any use → infinitely over
            if limit <= 0:
                return 0.0 if used <= 0 else math.inf
            return used / limit
        return dict(luts=frac(self.luts, d.luts),
                    dsps=frac(self.dsps, d.dsps),
                    brams=frac(self.brams, d.brams))

    def totals(self) -> Dict[str, float]:
        return dict(luts=self.luts, dsps=self.dsps, brams=self.brams,
                    max_cycles=self.max_cycles, fps=self.fps)


FoldingMap = Dict[str, Tuple[int, int]]


def _acc_table(model: SiraModel) -> Dict[str, AccumulatorReport]:
    reports = model.metadata.get("accumulator_reports")
    if reports is None:
        reports = minimize_accumulators(
            model.graph, model.input_ranges, ranges=model.ranges)
    return {r.node_name: r for r in reports}


def _widen(nm: NodeModel, acc: Optional[AccumulatorReport],
           widths: str, model: SiraModel) -> NodeModel:
    """Attach accumulator widths; for the datatype baseline, round every
    stream to its container width and use the datatype accumulator
    bound."""
    nm = dataclasses.replace(nm)
    if nm.kind == "mvau":
        if widths == "sira":
            # float-region MVAUs (no §4.2 report): the accumulator holds
            # the output value itself — its proven width, capped fixed32
            nm.acc_bits = acc.sira_bits if acc else \
                min(32, max(nm.out_bits, nm.in_bits))
        else:
            nm.acc_bits = acc.datatype_bits if acc else 32
    if widths == "datatype":
        nm.in_bits = container_bits(nm.in_bits)
        nm.out_bits = container_bits(nm.out_bits)
        if nm.kind == "mvau":
            nm.weight_bits = container_bits(nm.weight_bits)
    return nm


def widen_dataflow(model: SiraModel, dfg: DataflowGraph,
                   widths: str = "sira") -> Dict[str, NodeModel]:
    """Width-attached NodeModels — the form every pricing decision must
    see (raw extracted nodes carry a placeholder acc_bits=32).  Used by
    both :func:`estimate` and the folding search so they optimize the
    same cost model."""
    acc_table = _acc_table(model)
    wide = {nm.name: _widen(nm, acc_table.get(nm.name), widths, model)
            for nm in dfg.nodes}
    if widths == "datatype":
        _propagate_container_streams(wide, dfg)
    return wide


def _stream_out_bits(nm: NodeModel) -> int:
    """Container width of the stream leaving a node in the no-SIRA
    baseline: MVAUs emit at their (datatype-bound) accumulator width."""
    if nm.kind == "mvau":
        return container_bits(nm.acc_bits)
    return container_bits(nm.out_bits)


def _propagate_container_streams(wide: Dict[str, NodeModel],
                                 dfg: DataflowGraph) -> None:
    """Baseline stream widths flow from producers (edges are listed in
    consumer-topological order, so one pass suffices)."""
    incoming: Dict[str, int] = {}
    for e in dfg.edges:
        w = _stream_out_bits(wide[e.producer])
        incoming[e.consumer] = max(incoming.get(e.consumer, 0), w)
    for name, bits in incoming.items():
        wide[name].in_bits = bits


def estimate(model: SiraModel, *,
             widths: str = "sira",
             styles: str = "auto",
             folding: Optional[FoldingMap] = None,
             device: Union[str, DeviceBudget] = "pynq-z1",
             input_shapes: Optional[Dict[str, Sequence[int]]] = None,
             dsp_lut_equiv: float = DSP_LUT_EQUIV,
             dataflow_graph: Optional[DataflowGraph] = None
             ) -> GraphEstimate:
    """Whole-graph resource/throughput estimate.

    ``widths``: "sira" (proven ranges) or "datatype" (container widths +
    datatype-bound accumulators).  ``styles``: "auto" (cheapest per node,
    SIRA-driven) or "baseline" (DSP MACs + composite tails).  ``folding``
    maps node name → (pe, simd); unmapped nodes run fully folded (1, 1).
    """
    if widths not in ("sira", "datatype"):
        raise ValueError(f"widths={widths!r}")
    if styles not in ("auto", "baseline"):
        raise ValueError(f"styles={styles!r}")
    d = get_device(device)
    dfg = dataflow_graph or extract_dataflow(model, input_shapes)
    folding = folding or {}

    wide = widen_dataflow(model, dfg, widths)
    nodes: List[NodeEstimate] = []
    for nm in dfg.nodes:
        nm_w = wide[nm.name]
        pe, simd = folding.get(nm.name, (1, 1))
        style = (baseline_style(nm_w) if styles == "baseline"
                 else select_style(nm_w, pe, simd, dsp_lut_equiv))
        res = node_resources(nm_w, style, pe, simd)
        nodes.append(NodeEstimate(
            name=nm.name, op_type=nm.op_type, kind=nm.kind, style=style,
            pe=pe, simd=simd, cycles=cycles_per_frame(nm_w, pe, simd),
            luts=res.luts, dsps=res.dsps, brams=res.brams,
            in_bits=nm_w.in_bits, out_bits=nm_w.out_bits,
            weight_bits=nm_w.weight_bits, acc_bits=nm_w.acc_bits,
            channels=nm_w.channels, K=nm_w.K, pixels=nm_w.pixels))

    cycles = {n.name: n.cycles for n in nodes}
    # first-output latency along the DAG, for join-skew FIFO sizing
    lat: Dict[str, float] = {}
    in_edges: Dict[str, List[Edge]] = {}
    for e in dfg.edges:
        in_edges.setdefault(e.consumer, []).append(e)
    for nm in dfg.nodes:                # dfg.nodes is in topo order
        own = cycles[nm.name] / max(wide[nm.name].out_elems, 1)
        best = 0.0
        for e in in_edges.get(nm.name, ()):
            stride_p = cycles[e.producer] / max(e.elems, 1)
            ipo = max(1, math.ceil(e.elems / max(wide[nm.name].out_elems,
                                                 1)))
            best = max(best, lat[e.producer] + ipo * stride_p)
        lat[nm.name] = best + own

    fifos: List[FifoEstimate] = []
    for e in dfg.edges:
        arrivals = {e2.producer: lat[e2.producer]
                    for e2 in in_edges[e.consumer]}
        skew = max(arrivals.values()) - arrivals[e.producer]
        ipo = max(1, math.ceil(e.elems / max(wide[e.consumer].out_elems,
                                             1)))
        depth = fifo_depth(e.elems, cycles[e.producer],
                           cycles[e.consumer], ipo=ipo, skew_cycles=skew)
        width = e.width_bits if widths == "sira" \
            else _stream_out_bits(wide[e.producer])
        res = fifo_resources(depth, width)
        fifos.append(FifoEstimate(
            producer=e.producer, consumer=e.consumer, depth=depth,
            width_bits=width, elems=e.elems, luts=res.luts,
            brams=res.brams))
    return GraphEstimate(name=model.name or "model", widths=widths,
                         nodes=nodes, fifos=fifos, fclk_mhz=d.fclk_mhz)


# -------------------------------------------------------------- comparison

@dataclasses.dataclass
class DataflowComparison:
    """SIRA vs datatype-bound baseline on the same topology + folding."""
    sira: GraphEstimate
    baseline: GraphEstimate
    mean_acc_bits_sira: float
    mean_acc_bits_datatype: float

    @property
    def lut_reduction(self) -> float:
        return 1.0 - self.sira.luts / self.baseline.luts

    @property
    def dsp_reduction(self) -> float:
        if self.baseline.dsps == 0:
            return 0.0
        return 1.0 - self.sira.dsps / self.baseline.dsps

    @property
    def bram_reduction(self) -> float:
        if self.baseline.brams == 0:
            return 0.0
        return 1.0 - self.sira.brams / self.baseline.brams

    @property
    def acc_bits_reduction(self) -> float:
        if self.mean_acc_bits_datatype == 0:
            return 0.0
        return 1.0 - self.mean_acc_bits_sira / self.mean_acc_bits_datatype

    @property
    def tail_lut_ratio(self) -> float:
        """Layer-tail-only LUT ratio (threshold/elementwise/toint nodes)
        — comparable to the paper's Table 6 rLUT column."""
        kinds = ("threshold", "elementwise", "toint")
        opt = sum(n.luts for n in self.sira.nodes if n.kind in kinds)
        base = sum(n.luts for n in self.baseline.nodes if n.kind in kinds)
        return opt / base if base else 1.0

    def summary(self) -> Dict[str, float]:
        return dict(
            lut_reduction=self.lut_reduction,
            dsp_reduction=self.dsp_reduction,
            bram_reduction=self.bram_reduction,
            acc_bits_reduction=self.acc_bits_reduction,
            mean_acc_bits_sira=self.mean_acc_bits_sira,
            mean_acc_bits_datatype=self.mean_acc_bits_datatype)


def compare_sira_vs_baseline(
        model: SiraModel, *,
        device: Union[str, DeviceBudget] = "pynq-z1",
        folding: Optional[FoldingMap] = None,
        input_shapes: Optional[Dict[str, Sequence[int]]] = None,
        dataflow_graph: Optional[DataflowGraph] = None
        ) -> DataflowComparison:
    """The headline deltas: estimate the same dataflow graph with SIRA
    widths/auto styles vs datatype-bound widths/baseline styles.  Cycle
    counts are width-independent, so both sides share the folding and the
    comparison isolates exactly what SIRA contributes."""
    dfg = dataflow_graph or extract_dataflow(model, input_shapes)
    est_s = estimate(model, widths="sira", styles="auto", folding=folding,
                     device=device, dataflow_graph=dfg)
    est_b = estimate(model, widths="datatype", styles="baseline",
                     folding=folding, device=device, dataflow_graph=dfg)
    accs = list(_acc_table(model).values())
    mu_s = float(np.mean([a.sira_bits for a in accs])) if accs else 0.0
    mu_d = float(np.mean([a.datatype_bits for a in accs])) if accs else 0.0
    return DataflowComparison(sira=est_s, baseline=est_b,
                              mean_acc_bits_sira=mu_s,
                              mean_acc_bits_datatype=mu_d)
