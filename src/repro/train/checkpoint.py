"""Fault-tolerant checkpointing: atomic-rename npz of the full train state
(params, optimizer moments, data cursor, RNG) + resume.

Guarantees:
  * atomicity — write to a temp file, fsync, rename; a crash mid-write
    never corrupts the latest checkpoint;
  * bitwise-deterministic resume (tested in tests/test_train.py);
  * retention — keep the last ``keep`` checkpoints, delete older;
  * multi-host discipline — only host 0 writes (callers gate on
    ``jax.process_index() == 0``); all hosts restore identically.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_state(state: Any) -> Dict[str, np.ndarray]:
    flat = {}
    leaves, treedef = jax.tree.flatten(state)
    flat["__treedef__"] = np.frombuffer(
        str(treedef).encode(), dtype=np.uint8)
    for i, leaf in enumerate(leaves):
        if leaf is None:
            continue
        flat[f"leaf_{i}"] = np.asarray(leaf)
    flat["__nleaves__"] = np.asarray(len(leaves))
    flat["__none_mask__"] = np.asarray(
        [leaf is None for leaf in leaves])
    return flat


def save_checkpoint(directory: str, step: int, state: Any,
                    extra: Optional[Dict[str, Any]] = None,
                    keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    flat = _flatten_state(state)
    if extra:
        flat["__extra__"] = np.frombuffer(
            json.dumps(extra).encode(), dtype=np.uint8)
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **flat)
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, path)            # atomic on POSIX
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    _gc(directory, keep)
    return path


def latest_checkpoint(directory: str) -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    cands = sorted(p for p in os.listdir(directory)
                   if p.startswith("ckpt_") and p.endswith(".npz"))
    return os.path.join(directory, cands[-1]) if cands else None


def restore_checkpoint(path: str, state_like: Any
                       ) -> Tuple[Any, Dict[str, Any]]:
    """Restore into the structure of ``state_like`` (treedef template).

    The saved treedef / leaf count / leaf shapes are validated against the
    template: leaves are stored positionally, so restoring into a state
    with a different structure (e.g. a constrained-QAT state into a plain
    trainer, or a different model width) would silently assign tensors to
    the wrong slots.  Mismatches raise ``ValueError`` instead."""
    data = np.load(path, allow_pickle=False)
    leaves, treedef = jax.tree.flatten(state_like)
    if "__treedef__" in data:
        saved_td = bytes(data["__treedef__"]).decode()
        if saved_td != str(treedef):
            raise ValueError(
                f"checkpoint {path} was saved with a different state "
                f"structure; leaves are positional so restoring would "
                f"scramble them.\n  saved:    {saved_td}\n"
                f"  template: {treedef}")
    if "__nleaves__" in data and int(data["__nleaves__"]) != len(leaves):
        raise ValueError(
            f"checkpoint {path} holds {int(data['__nleaves__'])} leaves "
            f"but the template has {len(leaves)}")
    none_mask = data["__none_mask__"]
    out = []
    for i, leaf in enumerate(leaves):
        if none_mask[i]:
            out.append(None)
        else:
            arr = data[f"leaf_{i}"]
            if leaf is not None and hasattr(leaf, "shape") and \
                    tuple(arr.shape) != tuple(np.shape(leaf)):
                raise ValueError(
                    f"checkpoint {path} leaf {i}: saved shape "
                    f"{tuple(arr.shape)} != template shape "
                    f"{tuple(np.shape(leaf))}")
            if leaf is not None and hasattr(leaf, "dtype"):
                arr = arr.astype(leaf.dtype)
            out.append(arr)
    extra = {}
    if "__extra__" in data:
        extra = json.loads(bytes(data["__extra__"]).decode())
    return jax.tree.unflatten(treedef, out), extra


def step_of(path: str) -> int:
    return int(os.path.basename(path)[5:13])


def _gc(directory: str, keep: int) -> None:
    cands = sorted(p for p in os.listdir(directory)
                   if p.startswith("ckpt_") and p.endswith(".npz"))
    for p in cands[:-keep]:
        os.unlink(os.path.join(directory, p))
