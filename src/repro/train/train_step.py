"""Training step: microbatched gradient accumulation, QAT fake-quant,
remat, optional scaled-integer gradient compression with error feedback.

The microbatch loop is a lax.scan — under XLA's latency-hiding scheduler
the per-microbatch gradient all-reduce overlaps the next microbatch's
backward compute (the standard accumulate-overlap trick); it also divides
activation memory by the microbatch count.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.transformer import Model
from repro.optim.adamw import AdamW, AdamWState
from repro.quant.quantizer import QuantSpec
from .compression import compress_grads, init_error_feedback


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    error_feedback: Any       # None when compression disabled
    rng: jnp.ndarray


def init_train_state(model: Model, optimizer: AdamW, key,
                     compress: bool = False) -> TrainState:
    params = model.init(key)
    opt = optimizer.init(params)
    ef = init_error_feedback(params) if compress else None
    return TrainState(params=params, opt=opt, error_feedback=ef, rng=key)


def make_train_step(model: Model, optimizer: AdamW, *,
                    microbatches: int = 1,
                    quant: Optional[QuantSpec] = None,
                    remat: bool = True,
                    compress: bool = False):
    """Returns train_step(state, batch) -> (state, metrics).

    batch: tokens/labels (B_global, S) (+ optional frontend_embed)."""

    def loss_fn(params, mb):
        return model.loss(params, mb["tokens"], mb["labels"],
                          mb.get("frontend_embed"), quant=quant,
                          remat=remat)

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        if microbatches > 1:
            mbs = jax.tree.map(
                lambda a: a.reshape((microbatches,
                                     a.shape[0] // microbatches)
                                    + a.shape[1:]), batch)

            def micro(carry, mb):
                loss, grads = jax.value_and_grad(loss_fn)(state.params, mb)
                acc_loss, acc_g = carry
                return (acc_loss + loss,
                        jax.tree.map(jnp.add, acc_g, grads)), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss_sum, grads), _ = jax.lax.scan(
                micro, (jnp.zeros((), jnp.float32), zero), mbs)
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)

        ef = state.error_feedback
        if compress:
            grads, ef = compress_grads(grads, ef)

        new_params, new_opt = optimizer.update(grads, state.opt,
                                               state.params)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr": optimizer.schedule(new_opt.step)}
        return TrainState(params=new_params, opt=new_opt,
                          error_feedback=ef, rng=state.rng), metrics

    return train_step
