"""SIRA-flavored scaled-integer gradient compression with error feedback.

The paper's core representation — a tensor as (integer payload, scale) —
applied to the distributed-training communication layer: gradients are
quantized to int8 with a per-tensor scale before the cross-pod (DCN)
all-reduce, an 8/32 wire-byte reduction on the slowest link; the residual
quantization error is carried to the next step (error feedback), which is
what keeps SGD/Adam convergence intact (Karimireddy et al., 2019).

``compressed_psum`` is the shard_map building block for an explicit
pod-axis exchange; ``compress_grads``/``ef_update`` are the in-step pieces
used by train_step when ``compress_grads=True``.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_tensor(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor symmetric int8 quantization → (payload, scale)."""
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_tensor(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_grads(grads: Any, error_feedback: Any
                   ) -> Tuple[Any, Any]:
    """Quantize (grads + carried error) to int8; return (dequantized
    grads, new error feedback)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = quantize_tensor(g32)
        deq = dequantize_tensor(q, s)
        return deq, g32 - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_feedback)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    deq = jax.tree.unflatten(tdef, [o[0] for o in outs])
    ef = jax.tree.unflatten(tdef, [o[1] for o in outs])
    return deq, ef


def init_error_feedback(grads_like: Any) -> Any:
    return jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """int8-payload all-reduce over a mesh axis (for use inside shard_map):
    quantize → sum of integer payloads (int32 accumulate) → rescale by the
    max scale.  Wire bytes: 1/4 of f32 psum on the DCN pod axis."""
    q, s = quantize_tensor(x)
    s_max = jax.lax.pmax(s, axis_name)
    # renormalize payloads to the common scale before the integer sum
    q_common = jnp.round(q.astype(jnp.float32) * (s / s_max)
                         ).astype(jnp.int32)
    total = jax.lax.psum(q_common, axis_name)
    return total.astype(jnp.float32) * s_max
