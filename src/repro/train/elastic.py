"""Elastic / fault-tolerant training supervision.

SPMD collectives make in-step straggler work-stealing impossible — the
production mitigation is (a) cheap frequent checkpoints, (b) a supervisor
that restarts the job on failure, resuming from the latest checkpoint,
and (c) elastic re-partitioning of the data stream when the healthy host
set changes (the pipeline is indexed by global example id, so any host
count re-partitions the same stream with no replay — tested in
tests/test_train.py).

``run_supervised`` is the single-host embodiment used by the integration
test: it runs a training function that may raise (simulated preemption /
hardware fault) and resumes from the latest checkpoint until the step
budget completes.  On a real cluster the same loop runs under the cluster
scheduler with ``jax.distributed.initialize`` per restart.

Checkpoint cadence guidance: with mean-time-between-failures F and
checkpoint cost c, the optimal interval is ~sqrt(2·c·F) (Young/Daly);
at c ≈ 30 s (async npz of a 2.5 B-param state) and F ≈ 6 h per 512 chips,
that is every ~19 min — the default --ckpt-every targets of the train
driver express steps at roughly that wall-time.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from .checkpoint import latest_checkpoint, step_of


@dataclasses.dataclass
class SupervisionReport:
    restarts: int
    completed_steps: int
    resumed_from: list


def run_supervised(train_fn: Callable[[int], int], total_steps: int,
                   ckpt_dir: str, max_restarts: int = 16
                   ) -> SupervisionReport:
    """Run ``train_fn(start_step) -> reached_step`` to completion.

    ``train_fn`` trains from ``start_step`` and either returns the step it
    reached (== total_steps when done) or raises on a (simulated) fault.
    After each fault we resume from the latest checkpoint's step."""
    restarts = 0
    resumed_from = []
    step = 0
    while step < total_steps:
        try:
            step = train_fn(step)
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
            ck = latest_checkpoint(ckpt_dir)
            step = step_of(ck) if ck else 0
            resumed_from.append(step)
    return SupervisionReport(restarts=restarts, completed_steps=step,
                             resumed_from=resumed_from)
