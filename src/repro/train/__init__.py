"""Training loop, checkpointing, compression, fault tolerance."""
from .train_step import TrainState, init_train_state, make_train_step  # noqa: F401
from .checkpoint import (save_checkpoint, restore_checkpoint,  # noqa: F401
                         latest_checkpoint, step_of)
from .compression import (compress_grads, init_error_feedback,  # noqa: F401
                          compressed_psum, quantize_tensor,
                          dequantize_tensor)
