from .pipeline import TokenPipeline, DataState  # noqa: F401
