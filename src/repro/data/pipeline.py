"""Deterministic, shardable, resumable synthetic token pipeline.

Every example is a pure function of its global example id (counter-based
PRNG), so the stream is:
  * deterministic across restarts (resume = set the cursor),
  * elastically re-shardable: any host count H re-partitions the same
    global stream as ids {host, host+H, host+2H, ...} without replay,
  * order-independent for validation.

The synthetic task is Zipf-distributed token n-gram copying — enough
structure for loss to fall during the example runs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


def _example_tokens(example_id: int, seq_len: int, vocab: int,
                    seed: int) -> np.ndarray:
    rng = np.random.Philox(key=np.uint64(seed) + np.uint64(example_id))
    g = np.random.Generator(rng)
    # zipf-ish marginal + copy structure: second half echoes the first
    base = (g.zipf(1.5, size=seq_len).astype(np.int64) - 1) % vocab
    half = seq_len // 2
    base[half:half * 2] = base[:half]
    return base.astype(np.int32)


@dataclasses.dataclass
class DataState:
    step: int = 0


class TokenPipeline:
    def __init__(self, seq_len: int, global_batch: int, vocab: int,
                 seed: int = 0, host_id: int = 0, n_hosts: int = 1):
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.vocab = vocab
        self.seed = seed
        self.host_id = host_id
        self.n_hosts = n_hosts
        assert global_batch % n_hosts == 0
        self.local_batch = global_batch // n_hosts

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Local shard of the global batch for ``step`` (stateless)."""
        ids = (step * self.global_batch + self.host_id
               + np.arange(self.local_batch) * self.n_hosts)
        toks = np.stack([_example_tokens(int(i), self.seq_len + 1,
                                         self.vocab, self.seed)
                         for i in ids])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def iterate(self, state: Optional[DataState] = None
                ) -> Iterator[Dict[str, np.ndarray]]:
        state = state or DataState()
        while True:
            yield self.batch_at(state.step)
            state.step += 1
