"""Deprecated location — absorbed into :mod:`repro.dataflow.costmodel`.

The analytical FPGA cost models (paper §5.4, Tables 4/7, Fig 23) now live
in the dataflow DSE subsystem alongside the graph-level resource models
that build on them.  This module remains as an import-compatible shim:
every public name resolves to the ``repro.dataflow.costmodel`` original
(lazily, so ``repro.core`` and ``repro.dataflow`` can import each other's
submodules without a cycle)."""
from __future__ import annotations


def __getattr__(name: str):
    from ..dataflow import costmodel as _cm
    try:
        return getattr(_cm, name)
    except AttributeError:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}") from None


def __dir__():
    from ..dataflow import costmodel as _cm
    return sorted(set(dir(_cm)))
