"""Paper QNN workload topologies (Table 5), built in the graph IR.

Scaled-down but structurally faithful versions of the paper's four
evaluation networks — used by benchmarks (Table 6 / Fig 21 / Fig 22
reproductions) and tests.  Name encodes quantization: wXaY.

  TFC-w2a2   3-layer MLP                      (f)
  CNV-w2a2   VGG10-like conv stack            (c, f)
  RN8-w3a3   ResNet-8 with residuals          (c, 8, r)
  MNv1-w4a4  MobileNet-v1 depthwise-separable (c, d, 8)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from .graph import Graph
from .intervals import ScaledIntRange


@dataclasses.dataclass
class QNNWorkload:
    name: str
    graph: Graph
    input_range: Dict[str, ScaledIntRange]
    input_shape: Tuple[int, ...]
    weight_bits: int
    act_bits: int


def _quant(g: Graph, x: str, scale, bits: int, signed: int, out: str,
           narrow: int = 0, zp: float = 0.0) -> str:
    s = g.add_initializer(scale)
    z = g.add_initializer(zp)
    b = g.add_initializer(float(bits))
    g.add_node("Quant", [x, s, z, b], [out], dict(signed=signed,
                                                  narrow=narrow))
    return out


def _qlinear(g: Graph, rng, x: str, k: int, m: int, wbits: int, abits: int,
             prefix: str, relu: bool = True, per_channel: bool = True,
             bn: bool = True, final: bool = False) -> str:
    """Quant MatMul + (bias) + (BatchNorm lowered) + Relu + Quant."""
    W = rng.normal(size=(k, m)) * (1.5 / np.sqrt(k))
    w_name = g.add_initializer(W, f"{prefix}_W")
    if per_channel:
        s_w = np.abs(W).max(axis=0) / (2 ** (wbits - 1) - 1)
    else:
        s_w = np.abs(W).max() / (2 ** (wbits - 1) - 1)
    wq = _quant(g, w_name, np.maximum(s_w, 1e-8), wbits, 1,
                f"{prefix}_Wq")
    mm = f"{prefix}_mm"
    g.add_node("MatMul", [x, wq], [mm])
    cur = mm
    bias = rng.normal(size=(m,)) * 0.1
    b_name = g.add_initializer(bias, f"{prefix}_B")
    g.add_node("Add", [cur, b_name], [f"{prefix}_gemm"])
    cur = f"{prefix}_gemm"
    if bn:
        mvals = np.abs(rng.normal(size=(m,))) * 0.5 + 0.05
        nvals = rng.normal(size=(m,)) * 0.2
        mn = g.add_initializer(mvals, f"{prefix}_M")
        nn = g.add_initializer(nvals, f"{prefix}_N")
        g.add_node("Mul", [cur, mn], [f"{prefix}_bnm"])
        g.add_node("Add", [f"{prefix}_bnm", nn], [f"{prefix}_bn"])
        cur = f"{prefix}_bn"
    if final:
        return cur
    if relu:
        g.add_node("Relu", [cur], [f"{prefix}_act"])
        cur = f"{prefix}_act"
        out = _quant(g, cur, 0.11, abits, 0, f"{prefix}_out")
    else:
        out = _quant(g, cur, 0.11, abits, 1, f"{prefix}_out")
    return out


def _qconv(g: Graph, rng, x: str, cin: int, cout: int, wbits: int,
           abits: int, prefix: str, k: int = 3, stride: int = 1,
           pad: int = 1, groups: int = 1, relu: bool = True,
           signed_act: bool = False) -> str:
    W = rng.normal(size=(cout, cin // groups, k, k)) * \
        (1.5 / np.sqrt(cin // groups * k * k))
    w_name = g.add_initializer(W, f"{prefix}_W")
    s_w = np.abs(W).reshape(cout, -1).max(axis=1).reshape(cout, 1, 1, 1)
    s_w = np.maximum(s_w / (2 ** (wbits - 1) - 1), 1e-8)
    wq = _quant(g, w_name, s_w, wbits, 1, f"{prefix}_Wq")
    conv = f"{prefix}_conv"
    g.add_node("Conv", [x, wq], [conv],
               dict(stride=stride, pad=pad, groups=groups))
    # BatchNorm lowered to Mul/Add (per channel, shape (C,1,1))
    mvals = (np.abs(rng.normal(size=(cout, 1, 1))) * 0.5 + 0.05)
    nvals = rng.normal(size=(cout, 1, 1)) * 0.2
    mn = g.add_initializer(mvals, f"{prefix}_M")
    nn = g.add_initializer(nvals, f"{prefix}_N")
    g.add_node("Mul", [conv, mn], [f"{prefix}_bnm"])
    g.add_node("Add", [f"{prefix}_bnm", nn], [f"{prefix}_bn"])
    cur = f"{prefix}_bn"
    if relu:
        g.add_node("Relu", [cur], [f"{prefix}_act"])
        cur = f"{prefix}_act"
    out = _quant(g, cur, 0.13, abits, 1 if signed_act else 0,
                 f"{prefix}_out")
    return out


def make_tfc(wbits: int = 2, abits: int = 2, width: int = 64,
             in_dim: int = 49, seed: int = 0) -> QNNWorkload:
    """TFC: 3-layer MLP on (downscaled) MNIST-like input."""
    rng = np.random.default_rng(seed)
    g = Graph(inputs=["X"], outputs=[])
    x = _quant(g, "X", 1.0 / 127, 8, 0, "Xq")
    x = _qlinear(g, rng, x, in_dim, width, wbits, abits, "fc1")
    x = _qlinear(g, rng, x, width, width, wbits, abits, "fc2")
    x = _qlinear(g, rng, x, width, 10, wbits, abits, "fc3", final=True,
                 bn=False)
    g.outputs = [x]
    return QNNWorkload("TFC-w%da%d" % (wbits, abits), g,
                       {"X": ScaledIntRange(lo=np.zeros(()), hi=np.ones(()))},
                       (1, in_dim), wbits, abits)


def make_cnv(wbits: int = 2, abits: int = 2, ch: int = 16,
             img: int = 16, seed: int = 1) -> QNNWorkload:
    """CNV: VGG10-like — conv-conv-pool x3 then two FC layers."""
    rng = np.random.default_rng(seed)
    g = Graph(inputs=["X"], outputs=[])
    x = _quant(g, "X", 1.0 / 127, 8, 1, "Xq")
    cin, cur_img = 3, img
    for blk, cout in enumerate([ch, 2 * ch, 4 * ch]):
        x = _qconv(g, rng, x, cin, cout, wbits, abits, f"b{blk}c0", pad=1)
        x = _qconv(g, rng, x, cout, cout, wbits, abits, f"b{blk}c1", pad=1)
        g.add_node("MaxPool", [x], [f"b{blk}_pool"], dict(kernel=2, stride=2))
        x = f"b{blk}_pool"
        cin, cur_img = cout, cur_img // 2
    g.add_node("GlobalAveragePool", [x], ["gap"],
               dict(window=cur_img * cur_img))
    g.add_node("Flatten", ["gap"], ["flat"])
    x = _qlinear(g, rng, "flat", cin, 2 * ch, wbits, abits, "fc1")
    x = _qlinear(g, rng, x, 2 * ch, 10, wbits, abits, "fc2", final=True,
                 bn=False)
    g.outputs = [x]
    return QNNWorkload("CNV-w%da%d" % (wbits, abits), g,
                       {"X": ScaledIntRange(lo=-np.ones(()), hi=np.ones(()))},
                       (1, 3, img, img), wbits, abits)


def make_rn8(wbits: int = 3, abits: int = 3, ch: int = 16,
             img: int = 16, seed: int = 2) -> QNNWorkload:
    """ResNet-8: stem + 3 residual stages; 8-bit first/last layers."""
    rng = np.random.default_rng(seed)
    g = Graph(inputs=["X"], outputs=[])
    x = _quant(g, "X", 1.0 / 127, 8, 1, "Xq")
    x = _qconv(g, rng, x, 3, ch, 8, abits, "stem", pad=1)  # 8-bit first
    cin = ch
    for stage, cout in enumerate([ch, 2 * ch, 4 * ch]):
        stride = 1 if stage == 0 else 2
        skip = x
        y = _qconv(g, rng, x, cin, cout, wbits, abits, f"s{stage}c0",
                   stride=stride, pad=1)
        y = _qconv(g, rng, y, cout, cout, wbits, abits, f"s{stage}c1",
                   pad=1, relu=False, signed_act=True)
        if stride != 1 or cin != cout:
            skip = _qconv(g, rng, skip, cin, cout, wbits, abits,
                          f"s{stage}sc", k=1, stride=stride, pad=0,
                          relu=False, signed_act=True)
        add = f"s{stage}_add"
        g.add_node("Add", [y, skip], [add])
        g.add_node("Relu", [add], [f"s{stage}_act"])
        x = _quant(g, f"s{stage}_act", 0.13, abits, 0, f"s{stage}_out")
        cin = cout
    g.add_node("GlobalAveragePool", [x], ["gap"],
               dict(window=(img // 4) * (img // 4)))
    g.add_node("Flatten", ["gap"], ["flat"])
    x = _qlinear(g, rng, "flat", cin, 100, 8, 8, "head", final=True,
                 bn=False)  # 8-bit last
    g.outputs = [x]
    return QNNWorkload("RN8-w%da%d" % (wbits, abits), g,
                       {"X": ScaledIntRange(lo=-np.ones(()), hi=np.ones(()))},
                       (1, 3, img, img), wbits, abits)


def make_mnv1(wbits: int = 4, abits: int = 4, ch: int = 8,
              img: int = 16, depth: int = 4, seed: int = 3) -> QNNWorkload:
    """MobileNet-v1: stem conv + depthwise-separable blocks."""
    rng = np.random.default_rng(seed)
    g = Graph(inputs=["X"], outputs=[])
    x = _quant(g, "X", 1.0 / 127, 8, 1, "Xq")
    x = _qconv(g, rng, x, 3, ch, 8, abits, "stem", stride=2, pad=1)
    cin = ch
    for blk in range(depth):
        cout = min(cin * 2, 8 * ch) if blk % 2 == 1 else cin
        # depthwise 3x3 (per-channel activation scaling per paper §6.2)
        x = _qconv(g, rng, x, cin, cin, wbits, abits, f"dw{blk}",
                   groups=cin, pad=1)
        # pointwise 1x1
        x = _qconv(g, rng, x, cin, cout, wbits, abits, f"pw{blk}", k=1,
                   pad=0)
        cin = cout
    g.add_node("GlobalAveragePool", [x], ["gap"],
               dict(window=(img // 2) * (img // 2)))
    g.add_node("Flatten", ["gap"], ["flat"])
    x = _qlinear(g, rng, "flat", cin, 100, 8, 8, "head", final=True,
                 bn=False)
    g.outputs = [x]
    return QNNWorkload("MNv1-w%da%d" % (wbits, abits), g,
                       {"X": ScaledIntRange(lo=-np.ones(()), hi=np.ones(()))},
                       (1, 3, img, img), wbits, abits)


def make_hsw(wbits: int = 3, abits: int = 4, width: int = 48,
             in_dim: int = 16, seed: int = 7) -> QNNWorkload:
    """HSW: hard-swish/Silu MLP — the non-ReLU threshold-conversion
    stressor (beyond the paper's ReLU-only workloads).

    Layer tails exercise every certificate outcome:
      * fc1 ends in Silu + *unsigned* Quant: the proven range straddles
        the stationary point (x* ≈ −1.28) so transfer composition cannot
        decide, but the quantized output is monotone (the dip saturates
        at level 0) — certified by the on-grid fallback;
      * fc2 ends in Tanh behind a mixed-sign BatchNorm multiplier:
        per-channel reversed directions, certified ``representable`` by
        transfer composition (signed per-channel out_scale);
      * fc3 ends in hard-swish + *signed* fine-grained Quant: the dip
        around x* = −1.5 is resolved by the quantizer — uncertifiable,
        left as an elementwise chain for meta-kernel pricing.
    """
    rng = np.random.default_rng(seed)
    g = Graph(inputs=["X"], outputs=[])
    x = _quant(g, "X", 1.0 / 127, 8, 0, "Xq")

    def layer(x: str, k: int, m: int, act: str, prefix: str,
              signed_act: int, mixed_bn: bool = False) -> str:
        W = rng.normal(size=(k, m)) * (1.5 / np.sqrt(k))
        w_name = g.add_initializer(W, f"{prefix}_W")
        s_w = np.abs(W).max(axis=0) / (2 ** (wbits - 1) - 1)
        wq = _quant(g, w_name, np.maximum(s_w, 1e-8), wbits, 1,
                    f"{prefix}_Wq")
        g.add_node("MatMul", [x, wq], [f"{prefix}_mm"])
        b_name = g.add_initializer(rng.normal(size=(m,)) * 0.1,
                                   f"{prefix}_B")
        g.add_node("Add", [f"{prefix}_mm", b_name], [f"{prefix}_gemm"])
        mvals = np.abs(rng.normal(size=(m,))) * 0.5 + 0.05
        if mixed_bn:
            mvals = mvals * np.where(np.arange(m) % 3 == 0, -1.0, 1.0)
        mn = g.add_initializer(mvals, f"{prefix}_M")
        nn = g.add_initializer(rng.normal(size=(m,)) * 0.2, f"{prefix}_N")
        g.add_node("Mul", [f"{prefix}_gemm", mn], [f"{prefix}_bnm"])
        g.add_node("Add", [f"{prefix}_bnm", nn], [f"{prefix}_bn"])
        g.add_node(act, [f"{prefix}_bn"], [f"{prefix}_act"])
        return _quant(g, f"{prefix}_act", 0.11, abits, signed_act,
                      f"{prefix}_out")

    x = layer(x, in_dim, width, "Silu", "fc1", signed_act=0)
    x = layer(x, width, width, "Tanh", "fc2", signed_act=1, mixed_bn=True)
    x = layer(x, width, width, "HardSwish", "fc3", signed_act=1)
    x = _qlinear(g, rng, x, width, 10, wbits, abits, "head", final=True,
                 bn=False)
    g.outputs = [x]
    return QNNWorkload("HSW-w%da%d" % (wbits, abits), g,
                       {"X": ScaledIntRange(lo=np.zeros(()), hi=np.ones(()))},
                       (1, in_dim), wbits, abits)


WORKLOADS = {
    "TFC-w2a2": make_tfc,
    "CNV-w2a2": make_cnv,
    "RN8-w3a3": make_rn8,
    "MNv1-w4a4": make_mnv1,
}

# non-ReLU variants kept out of WORKLOADS: the paper's Table 5/6
# reproductions (and the compiled-backend bit-exactness suite) iterate the
# four paper networks; benchmarks and threshold-conversion tests iterate
# ALL_WORKLOADS.
EXTRA_WORKLOADS = {
    "HSW-w3a4": make_hsw,
}

ALL_WORKLOADS = {**WORKLOADS, **EXTRA_WORKLOADS}


def make_all(**kw) -> List[QNNWorkload]:
    return [fn() for fn in WORKLOADS.values()]
