"""``SiraModel`` — the unit of work of the transformation pipeline.

A ``SiraModel`` bundles a :class:`~repro.core.graph.Graph` with the input
ranges SIRA needs and a **cached** range analysis that is invalidated only
by graph mutation.  This mirrors the QONNX ``ModelWrapper`` + shared
``range_analysis`` design the paper ships SIRA as: many transformations
consume one analysis through a single entry point, so a pipeline of N
read-only passes performs O(1) full propagations instead of O(N).

Cache contract
--------------
``Graph`` bumps a monotonic ``version`` on every structural edit made
through its API (``add_node``/``add_initializer``/``remove_node``/
``nodes``-assignment/``replace_input``).  ``SiraModel.ranges`` recomputes
iff the cached ``graph.cache_key`` (version, node count) differs — the
node count also catches raw ``graph.nodes.append/remove`` mutations that
bypass the API.  Code that edits ``node.inputs``, ``node.outputs`` or
initializer *values* in place must call ``graph.touch()`` (all in-repo
passes do).
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, Optional, Sequence, Tuple

import numpy as np

from .graph import Graph
from .intervals import ScaledIntRange
from .propagate import analyze
from ..obs.explain import ProvenanceChain, RangeProvenance, build_chain
from ..obs.trace import get_tracer


class SiraModel:
    """Graph + input ranges + cached SIRA analysis + pass artifacts."""

    def __init__(self, graph: Graph,
                 input_ranges: Dict[str, ScaledIntRange],
                 name: str = "",
                 metadata: Optional[Dict[str, Any]] = None,
                 domain: str = "interval"):
        self.graph = graph
        self.input_ranges: Dict[str, ScaledIntRange] = dict(input_ranges)
        self.name = name
        # abstract domain for the cached analysis: "interval" (paper) or
        # "affine" (zonotope reduced product — see repro.core.affine)
        self.domain = domain
        # free-form artifact store written by passes (threshold specs,
        # accumulator reports, verification reports, ...)
        self.metadata: Dict[str, Any] = dict(metadata or {})
        self._ranges: Optional[Dict[str, ScaledIntRange]] = None
        self._cache_version: Optional[Tuple[int, int]] = None
        self._provenance: Optional[Dict[str, RangeProvenance]] = None

    # ------------------------------------------------------------ construct
    @classmethod
    def from_workload(cls, wl, domain: str = "interval") -> "SiraModel":
        """Wrap a :class:`~repro.core.workloads.QNNWorkload` (graph copied,
        so the workload object stays pristine)."""
        return cls(wl.graph.copy(), wl.input_range, name=wl.name,
                   metadata=dict(input_shape=wl.input_shape,
                                 weight_bits=wl.weight_bits,
                                 act_bits=wl.act_bits),
                   domain=domain)

    def copy(self) -> "SiraModel":
        m = SiraModel(self.graph.copy(), self.input_ranges, name=self.name,
                      metadata=dict(self.metadata), domain=self.domain)
        if self._ranges is not None and \
                self._cache_version == self.graph.cache_key:
            # graph.copy() is semantics-preserving → the analysis carries over
            m._ranges = self._ranges
            m._cache_version = m.graph.cache_key
            m._provenance = self._provenance
        return m

    # -------------------------------------------------------------- analysis
    @property
    def ranges(self) -> Dict[str, ScaledIntRange]:
        """Cached ``{tensor: ScaledIntRange}`` — recomputed only when the
        graph has been mutated since the last analysis."""
        if self._ranges is None or \
                self._cache_version != self.graph.cache_key:
            get_tracer().count("range_cache.miss",
                               graph_version=self.graph.version,
                               model=self.name)
            record: Dict[str, RangeProvenance] = {}
            self._ranges = analyze(self.graph, self.input_ranges,
                                   domain=self.domain, record=record)
            self._provenance = record
            # analyze() toposorts, which may bump the version once
            self._cache_version = self.graph.cache_key
        else:
            get_tracer().count("range_cache.hit",
                               graph_version=self.graph.version,
                               model=self.name)
        return self._ranges

    def range_of(self, tensor: str) -> Optional[ScaledIntRange]:
        return self.ranges.get(tensor)

    @property
    def analysis_cached(self) -> bool:
        return (self._ranges is not None and
                self._cache_version == self.graph.cache_key)

    def invalidate(self) -> None:
        """Drop the cached analysis (automatic for API-mediated edits)."""
        get_tracer().count("range_cache.invalidate",
                           graph_version=self.graph.version,
                           model=self.name)
        self._ranges = None
        self._cache_version = None
        self._provenance = None

    def explain(self, tensor: str) -> ProvenanceChain:
        """Why does ``tensor`` have the bounds it has?  Returns the
        culprit-linked :class:`~repro.obs.explain.ProvenanceChain` from
        the tensor back to a graph input — which op handler and abstract
        domain produced each range, and which input widened it."""
        self.ranges  # ensure analysis (and its provenance) is current
        assert self._provenance is not None
        return build_chain(tensor, self._provenance)

    @property
    def provenance(self) -> Dict[str, RangeProvenance]:
        """Per-tensor :class:`RangeProvenance` for the cached analysis."""
        self.ranges
        assert self._provenance is not None
        return self._provenance

    # ------------------------------------------------------------- execution
    def execute(self, feeds: Dict[str, np.ndarray],
                want: Optional[Sequence[str]] = None,
                record_all: bool = False) -> Dict[str, np.ndarray]:
        return self.graph.execute(feeds, want=want, record_all=record_all)

    def sample_inputs(self, rng=None, n: int = 1
                      ) -> Iterable[Dict[str, np.ndarray]]:
        """Random feed dicts drawn uniformly from the declared input ranges
        (requires ``input_shape`` metadata, single-input graphs only)."""
        shape = self.metadata.get("input_shape")
        if shape is None or len(self.graph.inputs) != 1:
            raise ValueError("sample_inputs needs metadata['input_shape'] "
                             "and a single graph input")
        rng = np.random.default_rng(0) if rng is None else rng
        (inp,) = self.graph.inputs
        r = self.input_ranges[inp]
        # sample elementwise between the broadcast bounds — collapsing a
        # per-channel range to its global hull would draw out-of-range
        # values and spuriously fail strict verification
        lo = np.broadcast_to(np.asarray(r.lo, dtype=np.float64), shape)
        hi = np.broadcast_to(np.asarray(r.hi, dtype=np.float64), shape)
        for _ in range(n):
            yield {inp: rng.uniform(lo, hi, size=shape)}

    def compile(self, **kwargs) -> "Any":
        """Lower this (optimized) model to a single jitted JAX callable
        backed by the Pallas kernels — see :func:`repro.core.lower.lower`
        for the options.  Returns a :class:`CompiledSiraModel`."""
        from .lower import lower as _lower
        return _lower(self, **kwargs)

    # ----------------------------------------------------------- transforms
    def transform(self, *transformations, copy: bool = True) -> "SiraModel":
        """Apply transformations in sequence (each once; wrap one in
        ``.fixpoint()`` for to-convergence application) and return the
        resulting model.  ``copy=True`` (default) leaves ``self`` untouched.
        """
        model = self.copy() if copy else self
        for tx in transformations:
            model, _ = tx.apply(model)
        return model

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        cached = "cached" if self.analysis_cached else "stale"
        return (f"SiraModel({self.name or 'unnamed'}, "
                f"{len(self.graph.nodes)} nodes, analysis={cached})")
