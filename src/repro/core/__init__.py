"""SIRA core: scaled-integer range analysis and FDNA-style optimizations."""
from .intervals import ScaledIntRange                      # noqa: F401
from .graph import Graph, Node, quant_bounds               # noqa: F401
from .propagate import SIRA, analyze, POISON               # noqa: F401
from .streamline import (streamline, aggregate_scales_biases,   # noqa: F401
                         explicitize_quantizers, remove_identity_ops)
from .thresholds import (convert_tails_to_thresholds,      # noqa: F401
                         find_layer_tails, extract_thresholds)
from .accumulator import (minimize_accumulators, datatype_bound_bits,  # noqa: F401
                          sira_bits, summarize, accumulator_dtype,
                          exact_worst_case_bits)
from . import costmodel                                    # noqa: F401
from .verify import verify_ranges, instrument, stuck_channels  # noqa: F401
