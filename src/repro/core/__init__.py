"""SIRA core: scaled-integer range analysis and FDNA-style optimizations.

New (preferred) API — ``SiraModel`` + transformation passes + build flow:

    from repro.core import SiraModel, build_flow
    result = build_flow(SiraModel.from_workload(make_tfc()))

The loose functions (``analyze``, ``convert_tails_to_thresholds``,
``minimize_accumulators``, ``verify_ranges``) remain as deprecated shims
over the pass pipeline.  The ``streamline`` function family
(``streamline``, ``aggregate_scales_biases``, ``explicitize_quantizers``,
``duplicate_shared_constants``) has been removed — use
``passes.Streamline`` / ``flow.build_flow`` or the ``*_inplace`` cores in
``streamline.py``.
"""
from .intervals import ScaledIntRange, InvalidRangeError   # noqa: F401
from .ops import (OpDef, OP_REGISTRY, register_op, get_op,  # noqa: F401
                  EXEC_REGISTRY, PROP_REGISTRY, COST_REGISTRY,
                  AFFINE_REGISTRY, MONOTONE_REGISTRY)
from .graph import Graph, Node, quant_bounds               # noqa: F401
from .propagate import (SIRA, analyze, analysis_calls,     # noqa: F401
                        POISON, DOMAINS)
from .affine import (AffineForm, tighten_range,            # noqa: F401
                     fresh_symbol)
from .model import SiraModel                               # noqa: F401
from .streamline import (remove_identity_ops,              # noqa: F401
                         AggregationResult)
from .monotone import (MonotoneCertificate, MonotoneStep,  # noqa: F401
                       certify_tail, compose_direction)
from .thresholds import (convert_tails_to_thresholds,      # noqa: F401
                         find_layer_tails, extract_thresholds,
                         convert_tails, ThresholdConversionError,
                         TailReport, ThresholdSpec)
from .accumulator import (minimize_accumulators, datatype_bound_bits,  # noqa: F401
                          sira_bits, summarize, accumulator_dtype,
                          exact_worst_case_bits)
from . import costmodel  # noqa: F401  (lazy shim over dataflow.costmodel)
from .verify import verify_ranges, instrument, stuck_channels  # noqa: F401
from .passes import (Transformation, Fixpoint, Sequence,   # noqa: F401
                     FunctionTransformation, ExplicitizeQuantizers,
                     DuplicateSharedConstants, AggregateScalesBiases,
                     RemoveIdentityOps, Streamline,
                     ConvertTailsToThresholds, MinimizeAccumulators,
                     VerifyRanges, VerificationError, LintGraph)
from .lint import (lint_graph, LintReport, LintFinding,    # noqa: F401
                   LintError)
from .fuzz import (run_fuzz, check_containment,            # noqa: F401
                   random_graph, FuzzReport, run_tail_fuzz,
                   check_tail_exactness, random_tail_graph)
from .lower import (lower, CompiledSiraModel, CompileBackend,  # noqa: F401
                    LoweringError)
from .flow import (BuildConfig, BuildResult, StepReport,   # noqa: F401
                   build_flow, register_step, STEP_REGISTRY,
                   DEFAULT_STEPS, DATAFLOW_STEPS)
