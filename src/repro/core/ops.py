"""Unified per-op registry (one ``OpDef`` per op type).

Historically the repo kept three disjoint registries that all had to be
edited to teach the system a new op:

  * ``graph.EXEC_REGISTRY``        — numpy executor
  * ``propagate.PROP_REGISTRY``    — SIRA range-propagation handler
  * ``costmodel.ELEMENTWISE_COEFFS`` — analytical LUT coefficients

They are now *views* over a single ``OP_REGISTRY`` of :class:`OpDef`
records, so registering an op is one declaration:

    register_op("MyOp", execute=my_exec, propagate=my_prop,
                cost=dict(alpha=1.0, beta=10))

The legacy dict names keep working (both reads and writes), so existing
``EXEC_REGISTRY["X"] = fn`` style code and the ``@executor`` /
``@handler`` decorators are unaffected.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterator, Optional

from collections.abc import MutableMapping


@dataclasses.dataclass
class OpDef:
    """Everything the system knows about one op type."""
    op_type: str
    execute: Optional[Callable] = None      # (node, *arrays) -> array(s)
    propagate: Optional[Callable] = None    # (node, graph, ranges) -> range(s)
    # affine-domain transfer: (node, graph, forms, ranges) -> form(s);
    # ops without one fall back to a fresh form over the interval result
    affine: Optional[Callable] = None
    # monotonicity transfer: (node, graph, lo, hi) -> MonotoneStep | None;
    # consumed by core.monotone to certify layer-tail threshold conversion
    monotone: Optional[Callable] = None
    cost: Optional[Dict[str, float]] = None  # analytical LUT coefficients
    # free-form metadata (e.g. is_nonlinear, absorbable) for transform passes
    attrs: Dict[str, object] = dataclasses.field(default_factory=dict)


OP_REGISTRY: Dict[str, OpDef] = {}


def _ensure(op_type: str) -> OpDef:
    d = OP_REGISTRY.get(op_type)
    if d is None:
        d = OpDef(op_type)
        OP_REGISTRY[op_type] = d
    return d


def register_op(op_type: str,
                execute: Optional[Callable] = None,
                propagate: Optional[Callable] = None,
                affine: Optional[Callable] = None,
                monotone: Optional[Callable] = None,
                cost: Optional[Dict[str, float]] = None,
                **attrs) -> OpDef:
    """Register (or extend) the definition of one op type.

    Fields that are ``None`` leave any previous registration untouched, so
    executors / propagation handlers / cost models may be contributed from
    separate modules but land in the same record."""
    d = _ensure(op_type)
    if execute is not None:
        d.execute = execute
    if propagate is not None:
        d.propagate = propagate
    if affine is not None:
        d.affine = affine
    if monotone is not None:
        d.monotone = monotone
    if cost is not None:
        d.cost = dict(cost)
    if attrs:
        d.attrs.update(attrs)
    return d


def get_op(op_type: str) -> Optional[OpDef]:
    return OP_REGISTRY.get(op_type)


class RegistryView(MutableMapping):
    """Dict-like facade exposing one field of every ``OpDef``.

    ``view[op]`` raises ``KeyError`` when the op exists but the field is
    unset, so it behaves exactly like the legacy per-field dicts."""

    def __init__(self, field: str):
        self._field = field

    def __getitem__(self, op_type: str):
        d = OP_REGISTRY.get(op_type)
        v = getattr(d, self._field) if d is not None else None
        if v is None:
            raise KeyError(op_type)
        return v

    def __setitem__(self, op_type: str, value) -> None:
        register_op(op_type, **{self._field: value})

    def __delitem__(self, op_type: str) -> None:
        d = OP_REGISTRY.get(op_type)
        if d is None or getattr(d, self._field) is None:
            raise KeyError(op_type)
        setattr(d, self._field, None)

    def __iter__(self) -> Iterator[str]:
        return (op for op, d in OP_REGISTRY.items()
                if getattr(d, self._field) is not None)

    def __len__(self) -> int:
        return sum(1 for _ in self)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"RegistryView({self._field!r}, ops={sorted(self)})"


# legacy-compatible views (imported by graph.py / propagate.py / costmodel.py)
EXEC_REGISTRY = RegistryView("execute")
PROP_REGISTRY = RegistryView("propagate")
AFFINE_REGISTRY = RegistryView("affine")
MONOTONE_REGISTRY = RegistryView("monotone")
COST_REGISTRY = RegistryView("cost")

# Table 4 analytical LUT coefficients (LUT = alpha * f(n_i, n_p) * PE +
# beta), registered here — where the unified registry lives — so that
# repro.core never has to import its consumer subsystem
# (repro.dataflow.costmodel) for the side effect.  "ToInt" and "Max" are
# meta-kernel styles rather than graph op types, registered cost-only.
register_op("Mul", cost=dict(alpha=1.18, beta=124))
register_op("Add", cost=dict(alpha=2.0, beta=24))
register_op("ToInt", cost=dict(alpha=4.2, beta=13))
register_op("Max", cost=dict(alpha=4.0, beta=21))
# Elementwise meta-kernel (FINN PR #1040 shape): a generic per-channel
# lookup/evaluation unit pricing layer tails that the monotonicity
# certifier could not convert to thresholds.  LUT = alpha*n_i*n_o*PE +
# beta plus per-channel parameter memory; coefficients follow the Table-4
# fitting style (beyond-paper, calibrated against the Mul/Add entries so
# a meta-kernel is strictly costlier than a same-width multiplier).
register_op("MetaKernel", cost=dict(alpha=2.6, beta=180))
