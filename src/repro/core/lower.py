"""Compiled execution backend: lower an optimized ``SiraModel`` into one
jitted JAX callable backed by the Pallas kernels.

``Graph.execute`` is a per-node numpy interpreter — fine for analysis and
verification, but it leaves the Pallas kernels in ``repro.kernels`` unused
and re-dispatches Python per node per call.  This module closes the
analysis→execution gap (ROADMAP "fast as the hardware allows"; the paper's
§4.1–4.2 optimizations only pay off at execution time, as FINN-R's
end-to-end build flow demonstrates):

  * integer ``MatMul``/``Conv`` (im2col, grouped) → :func:`kernels.int_matmul`
    with ``acc_bits`` taken from the SIRA accumulator bound of the output
    range (§4.2 — int16 tiles when the lossless width ≤ 15 bits);
  * ``MultiThreshold`` → the fused :func:`kernels.multithreshold` kernel
    (transposing the graph's (C, N) threshold layout to the kernel's
    (N, C), handling ``axis`` and the ``out_scale``/``out_bias`` attrs);
  * ``Quant`` → the fused :func:`kernels.quantize` kernel;
  * a ``MatMul/Conv → Mul(const) → Add(const)`` chain is fused into the
    int_matmul's aggregated scale/bias epilogue (float32 mode only — the
    kernel epilogue computes in f32, so exact-mode lowering keeps the
    elementwise nodes separate);
  * residual elementwise ops, pooling and reshapes → jnp;
  * constant subgraphs (e.g. leftover ``Mul(q_W, s_w)`` weight scaling)
    are folded at build time through the *numpy executor itself*, so
    folded values match ``Graph.execute`` bit for bit.

The lowering is dtype-faithful: tensors whose SIRA range proves them
integer-valued (scale 1, integral bias) are kept as int32 end to end, so
the integer core of the network — quantizers, integer matmuls/convs,
thresholds, residual adds — is **bit-exact** against the numpy
interpreter (asserted per-tensor by the backend tests).  Float epilogues
may differ from numpy in the last ulp: XLA contracts mul+add chains into
single-rounding FMAs and chooses its own reduction order for float
matmuls/means (both at least as accurate as two-rounding IEEE).  Pass
``dtype=jnp.float64`` (with x64 enabled) for tightest-tolerance
comparisons.

Everything runs everywhere: on TPU the Pallas kernels compile natively;
on CPU the wrappers either fall back to the jnp references
(``use_pallas=None``, the fast path) or run the Pallas kernels in
interpret mode (``use_pallas=True, interpret=True``, the validation path).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops

from ..obs.trace import get_tracer
from .graph import Graph, Node, quant_bounds
from .intervals import ScaledIntRange
from .model import SiraModel
from .ops import EXEC_REGISTRY
from .passes import Transformation

Env = Dict[str, jnp.ndarray]

INT_DTYPE = jnp.int32
MAX_INT32_BITS = 31


class LoweringError(NotImplementedError):
    """A node the compiled backend cannot lower (op type, dtype, or shape
    combination outside the supported surface)."""


@dataclasses.dataclass
class LoweredOp:
    """One plan entry — which kernel/route a node was lowered to."""
    node_name: str
    op_type: str
    kind: str            # "int_matmul" | "int_conv" | "multithreshold" |
    #                      "quantize" | "const_fold" | "jnp" | "fused:<...>"
    acc_bits: Optional[int] = None


def _signed_bits(lo: float, hi: float) -> int:
    """Two's-complement width for an integer value interval (paper §4.2)."""
    m = max(abs(lo), abs(hi) + 1.0)
    if m <= 1.0:
        return 1
    return int(np.ceil(np.log2(m))) + 1


def _integral(a: np.ndarray) -> bool:
    return bool(np.all(np.isfinite(a)) and np.all(a == np.round(a)))


class _Lowerer:
    """Single-use builder: walks the toposorted graph once and emits a list
    of closures over a name→array environment."""

    def __init__(self, model: SiraModel, *, use_pallas: Optional[bool],
                 interpret: Optional[bool], dtype, fuse_epilogue: bool):
        self.model = model
        self.g: Graph = model.graph
        # local copy: the Gemm lowering registers synthetic sub-tensor
        # ranges, which must not leak into the model's cached analysis
        self.ranges = dict(model.ranges)
        self.use_pallas = use_pallas
        self.interpret = interpret
        self.dtype = dtype
        self.fuse_epilogue = fuse_epilogue
        # build-time constant store, seeded from the initializers and grown
        # by constant folding (numpy, via the graph's own executors)
        self.consts: Dict[str, np.ndarray] = dict(self.g.initializers)
        self._const_cache: Dict[Tuple[str, bool], jnp.ndarray] = {}
        self.is_int: Dict[str, bool] = {}      # dynamic tensors only
        self.steps: List[Callable[[Env], None]] = []
        # one human-readable label per step closure (for profile() spans);
        # NOT 1:1 with self.plan — const folds add plan entries only
        self.step_labels: List[str] = []
        self.plan: List[LoweredOp] = []
        self._skip: set = set()                # nodes consumed by fusion

    # ------------------------------------------------------------- helpers
    def _kargs(self) -> Dict[str, Any]:
        return dict(use_pallas=self.use_pallas, interpret=self.interpret)

    def _const(self, name: str, as_int: bool = False) -> np.ndarray:
        """Constant as a dtype-converted *numpy* array.  Numpy (never jnp)
        so the cached value is safe to reuse across jit traces — a jnp
        conversion executed inside a trace would cache a leaked tracer."""
        key = (name, as_int)
        cached = self._const_cache.get(key)
        if cached is None:
            dt = np.int32 if as_int else np.dtype(self.dtype)
            cached = np.asarray(self.consts[name], dt)
            self._const_cache[key] = cached
        return cached

    def _int_range_bits(self, tensor: str) -> Optional[int]:
        """Accumulator width for an integer-valued tensor, from its SIRA
        range (None when the range does not prove integrality)."""
        r = self.ranges.get(tensor)
        if r is None or not r.is_scaled_int:
            return None
        if not (np.all(r.scale == 1.0) and _integral(np.asarray(r.bias))):
            return None
        return _signed_bits(float(np.min(r.lo)), float(np.max(r.hi)))

    def _tensor_is_int(self, tensor: str) -> bool:
        if tensor in self.consts:
            return _integral(self.consts[tensor])
        return self.is_int.get(tensor, False)

    def _fits(self, tensor: str, lo: int, hi: int) -> bool:
        if tensor in self.consts:
            v = self.consts[tensor]
            return bool(v.min() >= lo and v.max() <= hi)
        r = self.ranges.get(tensor)
        return (r is not None and float(np.min(r.lo)) >= lo
                and float(np.max(r.hi)) <= hi)

    def _get(self, env: Env, name: str, *, as_int=False) -> jnp.ndarray:
        if name in self.consts:
            return self._const(name, as_int=as_int)
        return env[name]

    def _getf(self, env: Env, name: str) -> jnp.ndarray:
        """Fetch as the float compute dtype (casting int tensors)."""
        v = self._get(env, name)
        return v.astype(self.dtype) if v.dtype != self.dtype else v

    def _push(self, run: Callable[[Env], None]) -> None:
        self.steps.append(run)

    # ---------------------------------------------------------------- build
    def build(self) -> None:
        self.g.toposort()
        for node in self.g.nodes:
            if node.name in self._skip:
                continue
            n0 = len(self.steps)
            if all(t in self.consts for t in node.inputs):
                self._fold(node)
                continue
            fn = getattr(self, f"_lower_{node.op_type.lower()}", None)
            if fn is None:
                raise LoweringError(
                    f"no lowering for op {node.op_type!r} "
                    f"(node {node.name})")
            fn(node)
            self.step_labels.extend(
                f"{node.op_type}:{node.name}"
                for _ in range(len(self.steps) - n0))
        for out in self.g.outputs:
            if out not in self.consts and out not in self.is_int:
                raise LoweringError(f"graph output {out} was never lowered")
        # the step closures only touch consts/dtype/kernel args at trace
        # time — drop the graph/analysis references so a long-lived
        # CompiledSiraModel does not pin the range arrays and model
        self.ranges = None
        self.model = None
        self.g = None

    def _fold(self, node: Node) -> None:
        """Constant-fold through the numpy executor — bit-identical to what
        Graph.execute would compute for this node."""
        fn = EXEC_REGISTRY.get(node.op_type)
        if fn is None:
            raise LoweringError(f"no executor to fold {node.op_type}")
        args = [np.asarray(self.consts[t], np.float64) for t in node.inputs]
        outs = fn(node, *args)
        if not isinstance(outs, tuple):
            outs = (outs,)
        for name, val in zip(node.outputs, outs):
            self.consts[name] = np.asarray(val, np.float64)
        self.plan.append(LoweredOp(node.name, node.op_type, "const_fold"))

    # ------------------------------------------------------------ epilogue
    def _epilogue_chain(self, node: Node
                        ) -> Optional[Tuple[np.ndarray, np.ndarray,
                                            List[Node], str]]:
        """Detect MatMul/Conv → Mul(const) → [Add(const)] with single
        consumers, returning (scale, bias, fused_nodes, final_tensor)."""
        t = node.outputs[0]
        if t in self.g.outputs:
            return None
        cons = self.g.consumers(t)
        if len(cons) != 1 or cons[0].op_type != "Mul":
            return None
        mul = cons[0]
        if mul.inputs[0] != t or mul.inputs[1] not in self.consts:
            return None
        scale = np.asarray(self.consts[mul.inputs[1]], np.float64).reshape(-1)
        fused = [mul]
        final = mul.outputs[0]
        bias = np.zeros((1,))
        if final not in self.g.outputs:
            cons2 = self.g.consumers(final)
            if (len(cons2) == 1 and cons2[0].op_type == "Add"
                    and cons2[0].inputs[0] == final
                    and cons2[0].inputs[1] in self.consts):
                add = cons2[0]
                bias = np.asarray(self.consts[add.inputs[1]],
                                  np.float64).reshape(-1)
                fused.append(add)
                final = add.outputs[0]
        n_out = self._matmul_out_channels(node)
        if scale.size not in (1, n_out) or bias.size not in (1, n_out):
            return None
        return (np.broadcast_to(scale, (n_out,)).astype(np.float32),
                np.broadcast_to(bias, (n_out,)).astype(np.float32),
                fused, final)

    def _matmul_out_channels(self, node: Node) -> int:
        w = self.consts.get(node.inputs[1])
        if w is None:
            return -1
        return int(w.shape[0] if node.op_type == "Conv" else w.shape[-1])

    # ------------------------------------------------------------- lowering
    def _lower_quant(self, node: Node) -> None:
        x_t, s_t, z_t, b_t = node.inputs
        out = node.outputs[0]
        s = np.asarray(self.consts[s_t], np.float64)
        z = np.asarray(self.consts[z_t], np.float64)
        bits = int(np.asarray(self.consts[b_t]).reshape(-1)[0])
        signed = bool(node.attrs.get("signed", 1))
        narrow = bool(node.attrs.get("narrow", 0))
        qmin, qmax = quant_bounds(bits, signed, narrow)
        qmin, qmax = int(qmin), int(qmax)
        trivial = bool(np.all(s == 1.0) and np.all(z == 0.0))
        # the fused kernel needs a per-last-axis (C,) or scalar layout
        kernelable = s.size == 1 and z.size == 1
        dtype, kargs = self.dtype, self._kargs()
        if kernelable:
            s_arr = jnp.asarray(s.reshape(-1), jnp.float32)
            z_arr = jnp.asarray(z.reshape(-1), jnp.float32)

            def run(env: Env) -> None:
                x = self._getf(env, x_t)
                c = x.shape[-1]
                q = kops.quantize(x.reshape(-1, c), s_arr, z_arr,
                                  qmin=qmin, qmax=qmax,
                                  out_dtype=INT_DTYPE, **kargs)
                q = q.reshape(x.shape)
                if trivial:
                    env[out] = q
                else:
                    sd = jnp.asarray(s, dtype)
                    zd = jnp.asarray(z, dtype)
                    env[out] = sd * (q.astype(dtype) - zd)
            kind = "quantize"
        else:  # arbitrary-granularity scale: plain jnp (still one pass)
            def run(env: Env) -> None:
                x = self._getf(env, x_t)
                s_j = jnp.asarray(s, dtype)
                z_j = jnp.asarray(z, dtype)
                q = jnp.clip(jnp.round(x / s_j + z_j), qmin, qmax)
                env[out] = q.astype(INT_DTYPE) if trivial \
                    else s_j * (q - z_j)
            kind = "jnp"
        self.is_int[out] = trivial
        self._push(run)
        self.plan.append(LoweredOp(node.name, "Quant", kind))

    # ---- integer / float matmul ------------------------------------------
    def _acc_bits(self, out_tensor: str) -> int:
        bits = self._int_range_bits(out_tensor)
        return MAX_INT32_BITS + 1 if bits is None else bits

    def _lower_matmul(self, node: Node) -> None:
        a_t, b_t = node.inputs
        out = node.outputs[0]
        w = self.consts.get(b_t)
        int_ok = (w is not None and _integral(w)
                  and self._tensor_is_int(a_t)
                  and self._acc_bits(out) <= MAX_INT32_BITS)
        if not int_ok:
            def run(env: Env) -> None:
                a = self._getf(env, a_t)
                b = self._getf(env, b_t)
                env[out] = a @ b
            self.is_int[out] = False
            self._push(run)
            self.plan.append(LoweredOp(node.name, "MatMul", "jnp"))
            return

        acc_bits = self._acc_bits(out)
        in8 = self._fits(a_t, -128, 127) and self._fits(b_t, -128, 127)
        in_dtype = jnp.int8 if in8 else INT_DTYPE
        wq = jnp.asarray(w, in_dtype)
        K = int(w.shape[0])
        fused = self.fuse_epilogue and self._epilogue_chain(node)
        kargs = self._kargs()
        if fused:
            scale, bias, fused_nodes, final = fused
            s_arr, b_arr = jnp.asarray(scale), jnp.asarray(bias)

            def run(env: Env) -> None:
                a = self._get(env, a_t)
                lead = a.shape[:-1]
                y = kops.int_matmul(a.reshape(-1, K).astype(in_dtype), wq,
                                    s_arr, b_arr, acc_bits=acc_bits,
                                    out_dtype=jnp.float32, **kargs)
                env[final] = y.reshape(lead + (y.shape[-1],))
            for n in fused_nodes:
                self._skip.add(n.name)
            self.is_int[final] = False
            self._push(run)
            self.plan.append(LoweredOp(node.name, "MatMul",
                                       "fused:int_matmul+epilogue",
                                       acc_bits=acc_bits))
            return

        def run(env: Env) -> None:
            a = self._get(env, a_t)
            lead = a.shape[:-1]
            y = kops.int_matmul(a.reshape(-1, K).astype(in_dtype), wq,
                                acc_bits=acc_bits, out_dtype=INT_DTYPE,
                                **kargs)
            env[out] = y.reshape(lead + (y.shape[-1],))
        self.is_int[out] = True
        self._push(run)
        self.plan.append(LoweredOp(node.name, "MatMul", "int_matmul",
                                   acc_bits=acc_bits))

    def _lower_gemm(self, node: Node) -> None:
        # Gemm = MatMul + optional bias; reuse the matmul route then add
        if len(node.inputs) == 2:
            return self._lower_matmul(node)
        a_t, b_t, c_t = node.inputs
        out = node.outputs[0]
        mm = Node("MatMul", [a_t, b_t], [out + "_mm_tmp"], {},
                  name=node.name + "_mm")
        # the synthetic matmul output has no SIRA range of its own; when
        # the Gemm output is proven integer and the bias is an integral
        # constant, shift the output range by the bias so the matmul part
        # still gets its accumulator bound (and the int_matmul route)
        r_out = self.ranges.get(out)
        if (r_out is not None and self._int_range_bits(out) is not None
                and c_t in self.consts and _integral(self.consts[c_t])):
            b = np.asarray(self.consts[c_t], np.float64)
            self.ranges[mm.outputs[0]] = ScaledIntRange.from_scaled_int(
                r_out.lo - b, r_out.hi - b, 1.0, 0.0)
        # lower the matmul part without epilogue fusion (bias follows)
        saved = self.fuse_epilogue
        self.fuse_epilogue = False
        try:
            self._lower_matmul(mm)
        finally:
            self.fuse_epilogue = saved
        mm_out = mm.outputs[0]
        # the synthetic sub-tensor is popped from the env below and must
        # not be advertised (int_tensors / extra_outputs) as addressable
        mm_int = self.is_int.pop(mm_out, False)
        bias_int = (c_t in self.consts and _integral(self.consts[c_t])
                    and mm_int)
        dtype = self.dtype

        def run(env: Env) -> None:
            y = env.pop(mm_out)
            if bias_int:
                env[out] = y + self._get(env, c_t, as_int=True)
            else:
                env[out] = y.astype(dtype) + self._getf(env, c_t)
        self.is_int[out] = bias_int
        self._push(run)
        self.plan.append(LoweredOp(node.name, "Gemm", "jnp"))

    # ---- conv (im2col) ----------------------------------------------------
    @staticmethod
    def _im2col(x: jnp.ndarray, kh: int, kw: int, stride: int, pad: int
                ) -> Tuple[jnp.ndarray, int, int]:
        """(n, c, h, w) → (n, c*kh*kw, ho*wo), matching the numpy executor's
        patch ordering."""
        n, c = x.shape[0], x.shape[1]
        if pad:
            x = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
        ho = (x.shape[2] - kh) // stride + 1
        wo = (x.shape[3] - kw) // stride + 1
        parts = [x[:, :, i:i + stride * ho:stride, j:j + stride * wo:stride]
                 for i in range(kh) for j in range(kw)]
        cols = jnp.stack(parts, axis=2)          # (n, c, kh*kw, ho, wo)
        return cols.reshape(n, c * kh * kw, ho * wo), ho, wo

    def _lower_conv(self, node: Node) -> None:
        x_t, w_t = node.inputs[:2]
        b_t = node.inputs[2] if len(node.inputs) > 2 else None
        out = node.outputs[0]
        stride = int(node.attrs.get("stride", 1))
        pad = int(node.attrs.get("pad", 0))
        groups = int(node.attrs.get("groups", 1))
        w = self.consts.get(w_t)
        if w is None:
            raise LoweringError(f"Conv {node.name} needs a constant weight")
        cout, cin_g, kh, kw = (int(d) for d in w.shape)
        og = cout // groups
        acc_bits = self._acc_bits(out)
        int_ok = (_integral(w) and self._tensor_is_int(x_t)
                  and acc_bits <= MAX_INT32_BITS
                  and (b_t is None or _integral(self.consts[b_t])))
        dtype, kargs = self.dtype, self._kargs()
        # no epilogue fusion over a biased conv: the kernel epilogue runs
        # scale/bias on the raw accumulator, but the conv bias must be
        # added *before* the Mul/Add chain
        fused = (int_ok and b_t is None and self.fuse_epilogue
                 and self._epilogue_chain(node)) or None
        if int_ok:
            in8 = self._fits(x_t, -128, 127) and self._fits(w_t, -128, 127)
            in_dtype = jnp.int8 if in8 else INT_DTYPE
            wmats = [jnp.asarray(
                w[g * og:(g + 1) * og].reshape(og, cin_g * kh * kw).T,
                in_dtype) for g in range(groups)]
        else:
            in_dtype = dtype
            wmats = [jnp.asarray(
                w[g * og:(g + 1) * og].reshape(og, cin_g * kh * kw).T,
                dtype) for g in range(groups)]
        if fused:
            scale, bias, fused_nodes, final = fused
            for n in fused_nodes:
                self._skip.add(n.name)
        else:
            final = out

        def run(env: Env) -> None:
            x = self._get(env, x_t) if int_ok else self._getf(env, x_t)
            n = x.shape[0]
            outs = []
            for g in range(groups):
                xg = x[:, g * cin_g:(g + 1) * cin_g]
                cols, ho, wo = self._im2col(xg, kh, kw, stride, pad)
                p = ho * wo
                a2 = jnp.swapaxes(cols, 1, 2).reshape(n * p, cin_g * kh * kw)
                if int_ok:
                    sg = bg = None
                    if fused:
                        sg = jnp.asarray(scale[g * og:(g + 1) * og])
                        bg = jnp.asarray(bias[g * og:(g + 1) * og])
                    y2 = kops.int_matmul(
                        a2.astype(in_dtype), wmats[g], sg, bg,
                        acc_bits=acc_bits,
                        out_dtype=jnp.float32 if fused else INT_DTYPE,
                        **kargs)
                else:
                    y2 = a2 @ wmats[g]
                yg = jnp.swapaxes(y2.reshape(n, p, og), 1, 2)
                outs.append(yg.reshape(n, og, ho, wo))
            y = outs[0] if groups == 1 else jnp.concatenate(outs, axis=1)
            if b_t is not None:
                b = self._const(b_t, as_int=int_ok).reshape(1, -1, 1, 1)
                y = y + b
            env[final] = y
        self.is_int[final] = bool(int_ok and not fused)
        self._push(run)
        kind = ("fused:int_conv+epilogue" if fused
                else ("int_conv" if int_ok else "jnp"))
        self.plan.append(LoweredOp(node.name, "Conv", kind,
                                   acc_bits=acc_bits if int_ok else None))

    # ---- multithreshold ----------------------------------------------------
    def _lower_multithreshold(self, node: Node) -> None:
        x_t, thr_t = node.inputs[:2]
        out = node.outputs[0]
        axis = int(node.attrs.get("axis", -1))
        out_scale = np.asarray(node.attrs.get("out_scale", 1.0),
                               np.float64).reshape(-1)
        out_bias = np.asarray(node.attrs.get("out_bias", 0.0),
                              np.float64).reshape(-1)
        thr = np.asarray(self.consts[thr_t], np.float64)   # (C, N)
        C, N = thr.shape
        x_int = self._tensor_is_int(x_t)
        thr_int = _integral(thr)
        # Integer fast path when both the input and the thresholds are
        # integral; scaled-entry tails (thresholds in real units at grid
        # midpoints, see core.thresholds) fall back to a float compare —
        # the count is exact either way because the midpoint placement
        # absorbs floating-point noise on the entry tensor.
        int_cmp = x_int and thr_int
        thrT = jnp.asarray(thr.T, INT_DTYPE if int_cmp else self.dtype)
        unit = bool(np.all(out_scale == 1.0))
        int_bias = _integral(out_bias) and out_bias.size == 1
        int_out = unit and int_bias
        ob = int(out_bias[0]) if int_bias else 0
        dtype, kargs = self.dtype, self._kargs()
        os_j = jnp.asarray(out_scale, self.dtype)
        ob_j = jnp.asarray(out_bias, self.dtype)

        def run(env: Env) -> None:
            x = env[x_t]
            xm = jnp.moveaxis(x, axis, -1)
            lead = xm.shape[:-1]
            cx = xm.shape[-1]
            t = thrT if C == cx else jnp.broadcast_to(thrT, (N, cx))
            x2 = xm.reshape(-1, cx)
            if not int_cmp and x2.dtype != t.dtype:
                x2 = x2.astype(t.dtype)
            if int_out:
                y2 = kops.multithreshold(x2, t, out_bias=ob,
                                         out_dtype=INT_DTYPE, **kargs)
            else:
                cnt = kops.multithreshold(x2, t, out_bias=0,
                                          out_dtype=INT_DTYPE, **kargs)
                y2 = ob_j + os_j * cnt.astype(dtype)
            env[out] = jnp.moveaxis(y2.reshape(lead + (cx,)), -1, axis)
        self.is_int[out] = int_out
        self._push(run)
        self.plan.append(LoweredOp(node.name, "MultiThreshold",
                                   "multithreshold"))

    # ---- elementwise / structural -----------------------------------------
    def _lower_binary(self, node: Node, op) -> None:
        a_t, b_t = node.inputs
        out = node.outputs[0]
        # integer-closed only for Add/Sub/Mul on integer operands
        closed = node.op_type in ("Add", "Sub", "Mul")
        bits = self._int_range_bits(out)
        int_out = (closed and self._tensor_is_int(a_t)
                   and self._tensor_is_int(b_t)
                   and bits is not None and bits <= MAX_INT32_BITS)

        def run(env: Env) -> None:
            if int_out:
                a = self._get(env, a_t, as_int=True)
                b = self._get(env, b_t, as_int=True)
            else:
                a, b = self._getf(env, a_t), self._getf(env, b_t)
            env[out] = op(a, b)
        self.is_int[out] = int_out
        self._push(run)
        self.plan.append(LoweredOp(node.name, node.op_type, "jnp"))

    def _lower_add(self, node):
        self._lower_binary(node, lambda a, b: a + b)

    def _lower_sub(self, node):
        self._lower_binary(node, lambda a, b: a - b)

    def _lower_mul(self, node):
        self._lower_binary(node, lambda a, b: a * b)

    def _lower_div(self, node):
        a_t, b_t = node.inputs
        out = node.outputs[0]

        def run(env: Env) -> None:
            env[out] = self._getf(env, a_t) / self._getf(env, b_t)
        self.is_int[out] = False
        self._push(run)
        self.plan.append(LoweredOp(node.name, "Div", "jnp"))

    def _lower_unary(self, node: Node, op, preserves_int: bool) -> None:
        x_t = node.inputs[0]
        out = node.outputs[0]
        int_out = preserves_int and self._tensor_is_int(x_t)

        def run(env: Env) -> None:
            x = self._get(env, x_t) if int_out else self._getf(env, x_t)
            env[out] = op(x)
        self.is_int[out] = int_out
        self._push(run)
        self.plan.append(LoweredOp(node.name, node.op_type, "jnp"))

    def _lower_relu(self, node):
        self._lower_unary(node, lambda x: jnp.maximum(x, 0), True)

    def _lower_identity(self, node):
        self._lower_unary(node, lambda x: x, True)

    def _lower_sigmoid(self, node):
        self._lower_unary(node, jax.nn.sigmoid, False)

    def _lower_tanh(self, node):
        self._lower_unary(node, jnp.tanh, False)

    def _lower_silu(self, node):
        self._lower_unary(node, jax.nn.silu, False)

    def _lower_gelu(self, node):
        sqrt2 = float(np.sqrt(2.0))
        self._lower_unary(
            node, lambda x: 0.5 * x * (1.0 + jax.lax.erf(x / sqrt2)),
            False)

    def _lower_softcap(self, node):
        cap = float(node.attrs["cap"])
        self._lower_unary(node, lambda x: cap * jnp.tanh(x / cap), False)

    def _lower_floor(self, node):
        self._lower_unary(node, jnp.floor, True)

    def _lower_round(self, node):
        self._lower_unary(node, jnp.round, True)

    def _lower_clip(self, node):
        lo = (self.consts[node.inputs[1]] if len(node.inputs) > 1 else None)
        hi = (self.consts[node.inputs[2]] if len(node.inputs) > 2 else None)
        lo = -np.inf if lo is None else lo
        hi = np.inf if hi is None else hi
        self._lower_unary(node, lambda x: jnp.clip(x, lo, hi), False)

    def _lower_softmax(self, node):
        ax = int(node.attrs.get("axis", -1))
        self._lower_unary(node, lambda x: jax.nn.softmax(x, axis=ax), False)

    def _lower_flatten(self, node):
        self._lower_unary(node, lambda x: x.reshape(x.shape[0], -1), True)

    def _lower_reshape(self, node):
        shape = tuple(node.attrs["shape"])
        self._lower_unary(node, lambda x: x.reshape(shape), True)

    def _lower_transpose(self, node):
        perm = tuple(node.attrs["perm"])
        self._lower_unary(node, lambda x: jnp.transpose(x, perm), True)

    def _lower_maxpool(self, node):
        k = int(node.attrs.get("kernel", 2))
        s = int(node.attrs.get("stride", k))

        def op(x):
            ho = (x.shape[2] - k) // s + 1
            wo = (x.shape[3] - k) // s + 1
            slices = [x[:, :, i:i + s * ho:s, j:j + s * wo:s]
                      for i in range(k) for j in range(k)]
            out = slices[0]
            for sl in slices[1:]:
                out = jnp.maximum(out, sl)
            return out
        self._lower_unary(node, op, True)

    def _lower_averagepool(self, node):
        k = int(node.attrs.get("kernel", 2))
        s = int(node.attrs.get("stride", k))
        dtype = self.dtype

        def op(x):
            ho = (x.shape[2] - k) // s + 1
            wo = (x.shape[3] - k) // s + 1
            acc = sum(x[:, :, i:i + s * ho:s, j:j + s * wo:s]
                      for i in range(k) for j in range(k))
            return acc.astype(dtype) / (k * k)
        self._lower_unary(node, op, False)

    def _lower_globalaveragepool(self, node):
        dtype = self.dtype

        def op(x):
            # exact for integer inputs: the sum is an exact float, and one
            # IEEE division matches numpy's mean
            n = x.shape[2] * x.shape[3]
            return x.sum(axis=(2, 3), keepdims=True).astype(dtype) / n
        self._lower_unary(node, op, False)

    def _lower_concat(self, node):
        ax = int(node.attrs.get("axis", -1))
        in_ts = list(node.inputs)
        out = node.outputs[0]
        int_out = all(self._tensor_is_int(t) for t in in_ts)

        def run(env: Env) -> None:
            xs = [self._get(env, t, as_int=True) if int_out
                  else self._getf(env, t) for t in in_ts]
            env[out] = jnp.concatenate(xs, axis=ax)
        self.is_int[out] = int_out
        self._push(run)
        self.plan.append(LoweredOp(node.name, "Concat", "jnp"))


# --------------------------------------------------------------------------
# public API
# --------------------------------------------------------------------------

class CompiledSiraModel:
    """A jitted, kernel-backed executable for an optimized SiraModel.

    Call with a feed dict (like ``Graph.execute``); returns numpy arrays
    for the graph outputs (plus any ``extra_outputs`` requested at lower
    time).  Shapes are traced on first call and retraced per new shape.
    """

    def __init__(self, name: str, steps, plan, outputs, int_tensors,
                 dtype, step_labels: Optional[Sequence[str]] = None):
        # only the name — holding the SiraModel would pin its graph and
        # cached range arrays (and create a cycle via metadata['compiled'])
        self.name = name
        self.plan: List[LoweredOp] = plan
        self.outputs: List[str] = list(outputs)
        self.int_tensors: List[str] = list(int_tensors)
        self.dtype = dtype
        self._steps = steps
        self.step_labels: List[str] = list(
            step_labels if step_labels is not None
            else (f"step{i}" for i in range(len(steps))))
        self._jfn = jax.jit(self._forward)

    def _forward(self, feeds: Dict[str, jnp.ndarray]
                 ) -> Dict[str, jnp.ndarray]:
        # the dtype cast happens *inside* the jitted program: an eager
        # per-call jnp.asarray(v, dtype) costs more host time than the
        # whole XLA executable on small graphs (the TFC-w2a2 regression —
        # tiny all-dense graphs are dispatch-bound, so every eager device
        # op in the call path shows up directly in us/sample)
        env: Env = {k: v.astype(self.dtype) for k, v in feeds.items()}
        for run in self._steps:
            run(env)
        return {t: env[t] for t in self.outputs}

    def __call__(self, feeds: Dict[str, Any]) -> Dict[str, np.ndarray]:
        tr = get_tracer()
        if tr.enabled:
            with tr.span("compiled:call", model=self.name):
                out = self._jfn({k: np.asarray(v)
                                 for k, v in feeds.items()})
                return {k: np.asarray(v) for k, v in out.items()}
        out = self._jfn({k: np.asarray(v) for k, v in feeds.items()})
        return {k: np.asarray(v) for k, v in out.items()}

    def profile(self, feeds: Dict[str, Any]) -> Dict[str, np.ndarray]:
        """Per-kernel dispatch timing: execute the step closures eagerly
        (no jit) with one span per lowered kernel, blocking on each so
        span durations reflect device time.  Slower than ``__call__`` by
        construction — a diagnostics path, never the serving path."""
        tr = get_tracer()
        env: Env = {k: jnp.asarray(np.asarray(v)).astype(self.dtype)
                    for k, v in feeds.items()}
        with tr.span("compiled:profile", model=self.name,
                     kernels=len(self._steps)):
            for label, run in zip(self.step_labels, self._steps):
                with tr.span(f"kernel:{label}"):
                    run(env)
                    env = jax.block_until_ready(env)
        return {t: np.asarray(env[t]) for t in self.outputs}

    @property
    def kernel_calls(self) -> Dict[str, int]:
        """Plan summary: how many nodes hit each lowering route."""
        counts: Dict[str, int] = {}
        for op in self.plan:
            counts[op.kind] = counts.get(op.kind, 0) + 1
        return counts

    def __repr__(self) -> str:   # pragma: no cover - debug aid
        return (f"CompiledSiraModel({self.name or 'unnamed'}, "
                f"{len(self.plan)} ops, {self.kernel_calls})")


def lower(model: SiraModel, *, use_pallas: Optional[bool] = None,
          interpret: Optional[bool] = None, dtype=None,
          fuse_epilogue: Optional[bool] = None,
          extra_outputs: Sequence[str] = ()) -> CompiledSiraModel:
    """Lower an optimized model to a single jitted callable.

    use_pallas: None → Pallas on TPU, jnp reference kernels elsewhere;
        True forces the Pallas kernels (pair with ``interpret=True`` off-TPU).
    interpret: run Pallas kernels in interpreter mode (None → auto).
    dtype: float compute dtype (None → float64 iff x64 is enabled).
    fuse_epilogue: fuse MatMul/Conv→Mul→Add chains into the int_matmul
        scale/bias epilogue.  Default: only in float32 mode (the kernel
        epilogue computes in f32, which would break float64 exactness).
    extra_outputs: additional tensor names to return on every call
        (e.g. integer intermediates for bit-exactness checks).
    """
    if dtype is None:
        dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    if fuse_epilogue is None:
        fuse_epilogue = jnp.dtype(dtype) == jnp.dtype(jnp.float32)
    with get_tracer().span("compile:lower", model=model.name,
                           nodes=len(model.graph.nodes),
                           dtype=str(jnp.dtype(dtype))) as sp:
        lw = _Lowerer(model, use_pallas=use_pallas, interpret=interpret,
                      dtype=dtype, fuse_epilogue=fuse_epilogue)
        with get_tracer().span("compile:build_plan"):
            lw.build()
        sp.set_attr("plan_ops", len(lw.plan))
        outputs = list(model.graph.outputs)
        for t in extra_outputs:
            if t not in lw.consts and t not in lw.is_int:
                raise LoweringError(
                    f"extra output {t!r} is not materialized by the "
                    f"lowered program (unknown tensor, or eliminated by "
                    f"epilogue fusion — retry with fuse_epilogue=False)")
            if t not in outputs:
                outputs.append(t)
        # constant outputs (fully folded graphs) are materialized up front
        const_outs = {t for t in outputs if t in lw.consts}
        labels = list(lw.step_labels)
        if const_outs:
            consts = {t: np.asarray(lw.consts[t]) for t in const_outs}
            inner_steps = list(lw.steps)

            def emit_consts(env: Env) -> None:
                for t, v in consts.items():
                    env[t] = jnp.asarray(v)
            steps = [emit_consts] + inner_steps
            labels = ["consts"] + labels
        else:
            steps = lw.steps
        int_tensors = [t for t, flag in lw.is_int.items() if flag]
        return CompiledSiraModel(model.name, steps, lw.plan, outputs,
                                 int_tensors, dtype, step_labels=labels)


class CompileBackend(Transformation):
    """Build-flow step (``step_compile``): lower the current model and
    stash the executable under ``metadata['compiled']``.  Never modifies
    the graph."""

    def __init__(self, use_pallas: Optional[bool] = None,
                 interpret: Optional[bool] = None, dtype=None,
                 fuse_epilogue: Optional[bool] = None):
        self.kwargs = dict(use_pallas=use_pallas, interpret=interpret,
                           dtype=dtype, fuse_epilogue=fuse_epilogue)

    @property
    def name(self) -> str:
        return "step_compile"

    def apply(self, model: SiraModel):
        model.metadata["compiled"] = lower(model, **self.kwargs)
        return model, False
