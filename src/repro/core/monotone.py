"""Monotonicity certification for layer tails (abstract interpretation).

Threshold conversion (paper §4.1.3, Eq. 3) is only *exact* when the
elementwise layer tail is monotone over the SIRA-proven input range.  The
paper's workloads satisfy that trivially (ReLU tails), but the repo's
``TAIL_ELEMENTWISE`` set admits Silu / Gelu / hard-swish, which dip around
a stationary point — converting such a tail blindly miscompiles.

This module certifies, per channel, whether a tail is monotone (and in
which direction) *before* any thresholds are extracted:

1. **Transfer composition** — every op carries a monotonicity transfer
   function registered via ``register_op(..., monotone=fn)``.  Each
   transfer maps a per-channel input interval to an output interval plus a
   direction factor in {-1, 0, +1} (NaN = unknown); factors compose by
   sign multiplication, so a negative ``Mul`` flips the chain's direction
   and a saturated ``Clip`` collapses it to constant.  Ops with a known
   stationary point (Silu, Gelu, hard-swish, Abs) certify whenever the
   incoming interval lies entirely on one side of it.
2. **On-grid finite differences** — when transfer composition cannot
   decide (range straddles a stationary point), the *quantized* tail
   output is evaluated over the full proven integer grid.  A real-valued
   dip smaller than one quantization step still yields a monotone
   staircase, which is all Eq. 3 needs.

The resulting :class:`MonotoneCertificate` gates the extraction strategy
in ``core.thresholds`` (bisection vs direction-aware enumeration) and, for
uncertifiable tails, carries a machine-readable reason code that the
dataflow DSE uses to price the elementwise meta-kernel instead.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Callable, Dict, Optional

import numpy as np

from .graph import Graph, Node
from .intervals import ScaledIntRange
from .ops import MONOTONE_REGISTRY, register_op

if TYPE_CHECKING:  # circular at runtime: thresholds imports this module
    from .thresholds import LayerTail

__all__ = [
    "MonotoneStep", "MonotoneCertificate", "certify_tail",
    "compose_direction", "MONOTONE_REGISTRY",
]


@dataclasses.dataclass(frozen=True)
class MonotoneStep:
    """One op's effect on a per-channel interval: output bounds plus a
    direction factor per channel (-1 reverses, 0 collapses to constant,
    +1 preserves, NaN = unknown)."""
    lo: np.ndarray
    hi: np.ndarray
    factor: np.ndarray


@dataclasses.dataclass(frozen=True)
class MonotoneCertificate:
    """Per-channel monotonicity verdict for one layer tail.

    status:
      * ``"monotone"``      — every channel monotone, uniform direction
      * ``"representable"`` — every channel monotone, mixed directions
        (still exactly convertible with per-channel signed out_scale)
      * ``"uncertified"``   — some channel could not be certified;
        ``reason`` carries a machine-readable code
    method: ``"transfer"`` (composition alone), ``"grid"`` (finite
    differences of the quantized output decided >=1 channel), ``""`` when
    uncertified.
    direction: (C,) ints in {-1, 0, +1}; zeros when uncertified.
    """
    status: str
    method: str
    direction: np.ndarray
    reason: str = ""
    detail: str = ""

    @property
    def certified(self) -> bool:
        return self.status != "uncertified"

    @property
    def summary(self) -> str:
        """Compact string form stored on converted MultiThreshold nodes."""
        return f"{self.status}:{self.method}" if self.certified \
            else f"uncertified:{self.reason}"


def compose_direction(direction: np.ndarray,
                      factor: np.ndarray) -> np.ndarray:
    """Compose per-channel direction with an op's factor.  A zero factor
    makes the output constant regardless of what came before (including
    unknown), hence the explicit branch instead of plain NaN-propagating
    multiplication."""
    return np.where(factor == 0.0, 0.0, direction * factor)


# --------------------------------------------------------------------------
# per-op transfer functions
# --------------------------------------------------------------------------

TransferFn = Callable[[Node, Graph, np.ndarray, np.ndarray],
                      Optional[MonotoneStep]]


def _const_operand(g: Graph, node: Node, C: int) -> Optional[np.ndarray]:
    """Second operand as a (C,) array, or None when dynamic / mismatched."""
    if len(node.inputs) < 2 or not g.is_constant(node.inputs[1]):
        return None
    v = np.asarray(g.initializers[node.inputs[1]], np.float64).reshape(-1)
    if v.size == 1:
        return np.full(C, v[0])
    if v.size == C:
        return v.copy()
    return None


def _mono_add(node: Node, g: Graph, lo: np.ndarray,
              hi: np.ndarray) -> Optional[MonotoneStep]:
    c = _const_operand(g, node, lo.size)
    if c is None:
        return None
    sign = -1.0 if node.op_type == "Sub" else 1.0
    return MonotoneStep(lo + sign * c, hi + sign * c, np.ones_like(lo))


def _mono_mul(node: Node, g: Graph, lo: np.ndarray,
              hi: np.ndarray) -> Optional[MonotoneStep]:
    c = _const_operand(g, node, lo.size)
    if c is None:
        return None
    if node.op_type == "Div":
        if np.any(c == 0.0):
            return None
        c = 1.0 / c
    a, b = lo * c, hi * c
    return MonotoneStep(np.minimum(a, b), np.maximum(a, b), np.sign(c))


def _mono_increasing(fn: Callable[[np.ndarray], np.ndarray]) -> TransferFn:
    """Elementwise nondecreasing function: direction is preserved."""
    def step(node: Node, g: Graph, lo: np.ndarray,
             hi: np.ndarray) -> Optional[MonotoneStep]:
        return MonotoneStep(fn(lo), fn(hi), np.ones_like(lo))
    return step


def _mono_softcap(node: Node, g: Graph, lo: np.ndarray,
                  hi: np.ndarray) -> Optional[MonotoneStep]:
    cap = float(node.attrs.get("cap", 0.0))
    if cap <= 0.0:
        return None
    fn = lambda x: cap * np.tanh(x / cap)
    return MonotoneStep(fn(lo), fn(hi), np.ones_like(lo))


def _mono_clip(node: Node, g: Graph, lo: np.ndarray,
               hi: np.ndarray) -> Optional[MonotoneStep]:
    def bound(idx: int, default: float) -> Optional[np.ndarray]:
        if len(node.inputs) <= idx:
            return np.full(lo.size, default)
        if not g.is_constant(node.inputs[idx]):
            return None
        v = np.asarray(g.initializers[node.inputs[idx]],
                       np.float64).reshape(-1)
        if v.size == 1:
            return np.full(lo.size, v[0])
        return v.copy() if v.size == lo.size else None

    clip_lo = bound(1, -np.inf)
    clip_hi = bound(2, np.inf)
    if clip_lo is None or clip_hi is None:
        return None
    out_lo = np.clip(lo, clip_lo, clip_hi)
    out_hi = np.clip(hi, clip_lo, clip_hi)
    # interval entirely inside a saturation plateau → constant output
    flat = (hi <= clip_lo) | (lo >= clip_hi)
    return MonotoneStep(out_lo, out_hi, np.where(flat, 0.0, 1.0))


def _mono_stationary(fn: Callable[[np.ndarray], np.ndarray],
                     x_star: float) -> TransferFn:
    """Unimodal function with a single interior minimum at ``x_star``:
    decreasing before it, nondecreasing after.  An interval entirely on
    one side certifies; a straddling interval stays unknown (NaN) and
    falls through to the on-grid check."""
    def step(node: Node, g: Graph, lo: np.ndarray,
             hi: np.ndarray) -> Optional[MonotoneStep]:
        f_lo, f_hi = fn(lo), fn(hi)
        out_lo = np.minimum(f_lo, f_hi)
        out_hi = np.maximum(f_lo, f_hi)
        inside = (lo < x_star) & (x_star < hi)
        out_lo = np.where(inside, fn(np.asarray(x_star)), out_lo)
        factor = np.where(hi <= x_star, -1.0,
                          np.where(lo >= x_star, 1.0, np.nan))
        return MonotoneStep(out_lo, out_hi, factor)
    return step


def _silu(x: np.ndarray) -> np.ndarray:
    return x / (1.0 + np.exp(-x))


def _gelu(x: np.ndarray) -> np.ndarray:
    from scipy.special import erf
    return 0.5 * x * (1.0 + erf(x / np.sqrt(2.0)))


def _hardswish(x: np.ndarray) -> np.ndarray:
    return x * np.clip(x + 3.0, 0.0, 6.0) / 6.0


register_op("Add", monotone=_mono_add)
register_op("Sub", monotone=_mono_add)
register_op("Mul", monotone=_mono_mul)
register_op("Div", monotone=_mono_mul)
register_op("Identity", monotone=_mono_increasing(lambda x: x))
register_op("Relu", monotone=_mono_increasing(
    lambda x: np.maximum(x, 0.0)))
register_op("Sigmoid", monotone=_mono_increasing(
    lambda x: 1.0 / (1.0 + np.exp(-x))))
register_op("Tanh", monotone=_mono_increasing(np.tanh))
register_op("Softcap", monotone=_mono_softcap)
register_op("Clip", monotone=_mono_clip)
# stationary points match the unimodal range handlers in core.propagate
register_op("Silu", monotone=_mono_stationary(_silu, -1.2784645))
register_op("Gelu", monotone=_mono_stationary(_gelu, -0.75179))
register_op("HardSwish", monotone=_mono_stationary(_hardswish, -1.5))
register_op("Abs", monotone=_mono_stationary(np.abs, 0.0))


# --------------------------------------------------------------------------
# certification
# --------------------------------------------------------------------------

def _per_channel_bounds(r: ScaledIntRange,
                        C: int) -> "tuple[np.ndarray, np.ndarray]":
    """Per-channel value bounds of the tail input; falls back to the
    channel hull when the range granularity does not match (sound: a
    wider interval can only *fail* to certify, never lie)."""
    lo = np.asarray(r.lo, np.float64).reshape(-1)
    hi = np.asarray(r.hi, np.float64).reshape(-1)
    if lo.size == C and hi.size == C:
        return lo.copy(), hi.copy()
    return (np.full(C, float(np.min(lo))), np.full(C, float(np.max(hi))))


def _verdict(direction: np.ndarray, method: str,
             detail: str) -> MonotoneCertificate:
    d = np.sign(direction).astype(np.int64)
    uniform = bool(np.all(d >= 0) or np.all(d <= 0))
    status = "monotone" if uniform else "representable"
    return MonotoneCertificate(status=status, method=method, direction=d,
                               detail=detail)


def certify_tail(g: Graph, tail: "LayerTail",
                 ranges: Dict[str, ScaledIntRange],
                 max_grid: Optional[int] = None) -> MonotoneCertificate:
    """Certify per-channel monotonicity of ``tail`` over its proven range.

    Runs transfer composition first; channels it cannot decide fall back
    to finite differences of the quantized output over the full integer
    grid (bounded by ``max_grid``, default ``EDGE_DETECT_MAX_RANGE``)."""
    from .thresholds import (EDGE_DETECT_MAX_RANGE, ThresholdConversionError,
                             _entry_int_bounds, _tail_params_channels,
                             tail_evaluator)
    if max_grid is None:
        max_grid = EDGE_DETECT_MAX_RANGE
    r_in = ranges[tail.input_tensor]
    C = _tail_params_channels(g, tail)
    int_lo, int_hi = _entry_int_bounds(r_in, C)
    lo0, hi0 = int(int_lo.min()), int(int_hi.max())
    lo, hi = _per_channel_bounds(r_in, C)

    direction = np.ones(C, np.float64)
    detail = ""
    for node in tail.nodes[:-1]:  # the final node is the quantizer
        fn = MONOTONE_REGISTRY.get(node.op_type)
        step = fn(node, g, lo, hi) if fn is not None else None
        if step is None:
            direction[:] = np.nan
            detail = (f"no-monotone-rule:{node.op_type}" if fn is None
                      else f"monotone-rule-failed:{node.op_type}")
            break
        direction = compose_direction(direction, step.factor)
        lo, hi = step.lo, step.hi
    # the terminating quantizer (scale > 0, round, saturate) is
    # nondecreasing — it never changes the direction

    unknown = np.isnan(direction)
    if not unknown.any():
        return _verdict(direction, "transfer", detail)

    # on-grid fallback: finite differences of the *quantized* output over
    # the full proven integer grid; certifies even when the real-valued
    # tail dips within one quantization step
    R = hi0 - lo0 + 1
    if R > max_grid:
        return MonotoneCertificate(
            status="uncertified", method="", direction=np.zeros(C, np.int64),
            reason=f"grid-too-large:{R}", detail=detail)
    try:
        ev = tail_evaluator(g, tail, ranges)
    except ThresholdConversionError as e:
        return MonotoneCertificate(
            status="uncertified", method="", direction=np.zeros(C, np.int64),
            reason=e.reason, detail=str(e))
    xs = np.arange(lo0, hi0 + 1, dtype=np.int64)
    try:
        levels = ev.f_int(xs)                  # (R, C)
    except NotImplementedError:
        # an op the transfer layer rejected may be unexecutable too
        return MonotoneCertificate(
            status="uncertified", method="", direction=np.zeros(C, np.int64),
            reason=detail or "evaluation-failed", detail=detail)
    # restrict each channel's finite differences to its *own* proven
    # integer range — outside it the certificate makes no claim, and the
    # extractors never place thresholds there either
    up = np.empty(C, bool)
    down = np.empty(C, bool)
    for c in range(C):
        i0, i1 = int(int_lo[c] - lo0), int(int_hi[c] - lo0)
        dseg = np.diff(levels[i0:i1 + 1, c])
        up[c] = bool(np.all(dseg >= 0))
        down[c] = bool(np.all(dseg <= 0))
    grid_dir = np.where(up & down, 0.0,
                        np.where(up, 1.0, np.where(down, -1.0, np.nan)))
    direction = np.where(unknown, grid_dir, direction)
    if np.isnan(direction).any():
        return MonotoneCertificate(
            status="uncertified", method="", direction=np.zeros(C, np.int64),
            reason="nonmonotone-on-grid", detail=detail)
    return _verdict(direction, "grid", detail)
