"""Composable transformation passes over :class:`~repro.core.model.SiraModel`.

QONNX/FINN-style design: every pass is a :class:`Transformation` with

    apply(model) -> (model, modified)

``modified`` reports whether the graph was structurally changed; the
``SiraModel`` analysis cache is keyed on the graph version, so read-only
passes (accumulator minimization, verification, reporting) share one full
range propagation instead of re-running it per pass.

Combinators: ``tx.fixpoint()`` applies a pass until it stops reporting
changes; ``Sequence([...])`` chains passes.  ``flow.build_flow`` drives
declarative step lists of these with timing/verification hooks.
"""
from __future__ import annotations

from typing import Callable, Dict, Iterable, List, Optional, Tuple

import numpy as np

from .accumulator import minimize_accumulators as _minimize_accumulators
from .model import SiraModel
from .streamline import (aggregate_with_ranges,
                         duplicate_shared_constants_inplace,
                         explicitize_quantizers_inplace,
                         remove_identity_ops as _remove_identity_ops)
from .thresholds import convert_tails
from .verify import verify_ranges as _verify_ranges

TransformResult = Tuple[SiraModel, bool]


class Transformation:
    """Base class: ``apply(model) -> (model, modified)``.

    Passes mutate ``model.graph`` in place (the model owns its graph; use
    ``SiraModel.transform(...)`` or ``build_flow`` for copy-on-entry
    semantics) and must report structural changes truthfully — the analysis
    cache depends on it."""

    @property
    def name(self) -> str:
        return type(self).__name__

    def apply(self, model: SiraModel) -> TransformResult:
        raise NotImplementedError

    def __call__(self, model: SiraModel) -> SiraModel:
        return self.apply(model)[0]

    def fixpoint(self, max_iter: int = 20) -> "Fixpoint":
        return Fixpoint(self, max_iter=max_iter)


class Fixpoint(Transformation):
    """Apply an inner pass until it reports no modification."""

    def __init__(self, inner: Transformation, max_iter: int = 20):
        self.inner = inner
        self.max_iter = max_iter

    @property
    def name(self) -> str:
        return f"fixpoint({self.inner.name})"

    def apply(self, model: SiraModel) -> TransformResult:
        any_mod = False
        for _ in range(self.max_iter):
            model, mod = self.inner.apply(model)
            any_mod |= mod
            if not mod:
                return model, any_mod
        raise RuntimeError(
            f"{self.inner.name} did not reach a fixpoint in "
            f"{self.max_iter} iterations")


class Sequence(Transformation):
    """Chain passes; modified = any inner pass modified."""

    def __init__(self, transformations: Iterable[Transformation],
                 name: str = ""):
        self.transformations = list(transformations)
        self._name = name

    @property
    def name(self) -> str:
        return self._name or "+".join(t.name for t in self.transformations)

    def apply(self, model: SiraModel) -> TransformResult:
        any_mod = False
        for tx in self.transformations:
            model, mod = tx.apply(model)
            any_mod |= mod
        return model, any_mod


class FunctionTransformation(Transformation):
    """Adapt a plain callable.  The callable may return ``None`` (in-place,
    unknown modification → treated as modified), a model, or a
    ``(model, modified)`` pair."""

    def __init__(self, fn: Callable, name: str = ""):
        self.fn = fn
        self._name = name or getattr(fn, "__name__", "fn")

    @property
    def name(self) -> str:
        return self._name

    def apply(self, model: SiraModel) -> TransformResult:
        out = self.fn(model)
        if out is None:
            model.graph.touch()
            return model, True
        if isinstance(out, tuple):
            return out
        return out, True


def as_transformation(step) -> Transformation:
    if isinstance(step, Transformation):
        return step
    if callable(step):
        return FunctionTransformation(step)
    raise TypeError(f"cannot interpret {step!r} as a Transformation")


# --------------------------------------------------------------------------
# streamlining passes (paper §4.1.2)
# --------------------------------------------------------------------------

class ExplicitizeQuantizers(Transformation):
    """Rewrite non-trivial ``Quant`` nodes into explicit Div/Add/Quant/Sub/
    Mul chains (idempotent: second application is a no-op)."""

    def apply(self, model: SiraModel) -> TransformResult:
        return model, explicitize_quantizers_inplace(model.graph)


class DuplicateSharedConstants(Transformation):
    """Private per-consumer copies of shared constants (idempotent)."""

    def apply(self, model: SiraModel) -> TransformResult:
        return model, duplicate_shared_constants_inplace(model.graph)


class AggregateScalesBiases(Transformation):
    """Scale/bias aggregation at every safe boundary tensor, driven by the
    model's (cached) contribution-tracking analysis.  Stores the
    :class:`~repro.core.streamline.AggregationResult` under
    ``metadata['aggregation']``."""

    def __init__(self, explicitize: bool = True):
        self.explicitize = explicitize

    def apply(self, model: SiraModel) -> TransformResult:
        changed = False
        if self.explicitize:
            changed |= explicitize_quantizers_inplace(model.graph)
        changed |= duplicate_shared_constants_inplace(model.graph)
        result, agg_changed = aggregate_with_ranges(model.graph,
                                                        model.ranges)
        model.metadata["aggregation"] = result
        return model, changed or agg_changed


class RemoveIdentityOps(Transformation):
    """Remove Mul(x,1)/Div(x,1)/Add(x,0)/Sub(x,0) (idempotent)."""

    def apply(self, model: SiraModel) -> TransformResult:
        return model, _remove_identity_ops(model.graph)


class Streamline(Sequence):
    """Full SIRA streamlining (explicitize + aggregate; aggregation already
    removes identities and dead code)."""

    def __init__(self):
        super().__init__([AggregateScalesBiases(explicitize=True)],
                         name="Streamline")


# --------------------------------------------------------------------------
# threshold conversion (paper §4.1.3)
# --------------------------------------------------------------------------

class ConvertTailsToThresholds(Transformation):
    """Collapse quantized layer tails into MultiThreshold nodes.  Stores the
    extracted specs under ``metadata['threshold_specs']`` and the per-tail
    conversion outcomes (certificate status, reason codes for tails left
    as elementwise chains) under ``metadata['tail_reports']``."""

    def __init__(self, method: str = "auto"):
        self.method = method

    def apply(self, model: SiraModel) -> TransformResult:
        specs, reports = convert_tails(model.graph, model.ranges,
                                       method=self.method)
        model.metadata["threshold_specs"] = specs
        model.metadata["tail_reports"] = reports
        return model, bool(specs)


# --------------------------------------------------------------------------
# analysis passes (graph-preserving; share the cached analysis)
# --------------------------------------------------------------------------

class MinimizeAccumulators(Transformation):
    """Accumulator-width reports (paper §4.2) under
    ``metadata['accumulator_reports']``.  Never modifies the graph."""

    def __init__(self, input_bits: int = 8, weight_bits: int = 8):
        self.input_bits = input_bits
        self.weight_bits = weight_bits

    def apply(self, model: SiraModel) -> TransformResult:
        model.metadata["accumulator_reports"] = _minimize_accumulators(
            model.graph, model.input_ranges,
            input_bits=self.input_bits, weight_bits=self.weight_bits,
            ranges=model.ranges)
        return model, False


class LintGraph(Transformation):
    """Static well-formedness lint (:func:`repro.core.lint.lint_graph`).
    Stores the :class:`~repro.core.lint.LintReport` under
    ``metadata['lint']``; raises :class:`~repro.core.lint.LintError` when
    ``strict`` and error-level findings exist.  Never modifies the graph.

    Range validation covers the *declared input ranges* plus any cached
    analysis — it deliberately does not force a fresh propagation, so the
    lint stays runnable on graphs too malformed to analyze."""

    def __init__(self, strict: bool = True,
                 input_shapes: Optional[Dict[str, tuple]] = None):
        self.strict = strict
        self.input_shapes = input_shapes

    def apply(self, model: SiraModel) -> TransformResult:
        from .lint import LintError, lint_graph
        shapes = self.input_shapes
        if shapes is None:
            shape = model.metadata.get("input_shape")
            if shape is not None and len(model.graph.inputs) == 1:
                shapes = {model.graph.inputs[0]: tuple(shape)}
        cached = model.ranges if model.analysis_cached else None
        report = lint_graph(model.graph, model.input_ranges,
                            input_shapes=shapes, ranges=cached)
        model.metadata["lint"] = report
        if self.strict and not report.ok:
            raise LintError(report)
        return model, False


class VerificationError(AssertionError):
    pass


class VerifyRanges(Transformation):
    """Empirical containment check (paper §6.1): execute the graph on a
    dataset (given, or sampled from the declared input ranges) and assert
    every observation lies inside its SIRA range.  Stores the report under
    ``metadata['verification']``; raises :class:`VerificationError` when
    ``strict`` and containment fails.  Never modifies the graph."""

    def __init__(self, dataset: Optional[List[Dict[str, np.ndarray]]] = None,
                 samples: int = 4, seed: int = 0, strict: bool = True):
        self.dataset = dataset
        self.samples = samples
        self.seed = seed
        self.strict = strict

    def apply(self, model: SiraModel) -> TransformResult:
        data = self.dataset
        if data is None:
            try:
                data = list(model.sample_inputs(
                    rng=np.random.default_rng(self.seed), n=self.samples))
            except ValueError:
                model.metadata["verification"] = None  # no shapes known
                return model, False
        report = _verify_ranges(model.graph, model.ranges, data)
        model.metadata["verification"] = report
        if self.strict and not report.contained:
            raise VerificationError(
                f"SIRA containment violated: {report.violations[:3]}")
        return model, False
