"""A lightweight QONNX-like graph IR.

The paper implements SIRA as a shared optimization over QONNX graphs. We
mirror the essentials here: a flat list of nodes over named tensors, a dict
of constant initializers, declared graph inputs/outputs, plus a numpy
executor used by (a) the threshold-conversion subgraph evaluation (§4.1.3),
(b) streamline-equivalence tests and (c) instrumentation-based verification
(§6.1).

Layout conventions (matching ONNX):
  * MatMul:   x (..., K) @ W (K, M)       — channels last
  * Conv:     x (N, C, H, W), W (Cout, Cin/groups, kh, kw)  — channels first
Per-channel parameter arrays use broadcastable shapes, e.g. (M,) for MatMul
outputs and (Cout, 1, 1) for Conv outputs.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .ops import EXEC_REGISTRY, register_op  # noqa: F401  (re-exported)

Array = np.ndarray


# --------------------------------------------------------------------------
# IR
# --------------------------------------------------------------------------

_counter = itertools.count()


def fresh_name(prefix: str) -> str:
    return f"{prefix}_{next(_counter)}"


@dataclasses.dataclass
class Node:
    op_type: str
    inputs: List[str]
    outputs: List[str]
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    name: str = ""

    def __post_init__(self):
        if not self.name:
            self.name = fresh_name(self.op_type)


class Graph:
    def __init__(self, inputs: Sequence[str] = (), outputs: Sequence[str] = ()):
        self._version = 0
        self._nodes: List[Node] = []
        self.initializers: Dict[str, Array] = {}
        self.inputs: List[str] = list(inputs)
        self.outputs: List[str] = list(outputs)
        # lazily-built producer/consumer maps, keyed on cache_key
        self._idx_version = None
        self._producers: Dict[str, Node] = {}
        self._consumers: Dict[str, List[Node]] = {}

    # ----------------------------------------------------------- versioning
    @property
    def version(self) -> int:
        """Monotonic mutation counter.  ``SiraModel`` keys its cached range
        analysis on this; every structural edit made through the Graph API
        bumps it.  ``nodes`` returns the *live* internal list — code that
        mutates it directly (``g.nodes.append(...)``) or edits
        ``node.inputs`` / initializer values in place must call ``touch()``.
        As a safety net, cache consumers key on ``cache_key`` (version,
        node count), which also catches raw list append/remove."""
        return self._version

    @property
    def cache_key(self) -> Tuple[int, int]:
        return (self._version, len(self._nodes))

    def touch(self) -> None:
        """Mark the graph as mutated (invalidates indexes and any cached
        analysis).  Call after editing ``node.inputs``/``node.outputs`` or
        initializer *values* in place — the editing methods below call it
        automatically."""
        self._version += 1

    @property
    def nodes(self) -> List[Node]:
        return self._nodes

    @nodes.setter
    def nodes(self, value: Sequence[Node]) -> None:
        self._nodes = list(value)
        self.touch()

    # -------------------------------------------------------------- editing
    def add_node(self, op_type: str, inputs: Sequence[str],
                 outputs: Optional[Sequence[str]] = None,
                 attrs: Optional[Dict[str, Any]] = None,
                 name: str = "") -> Node:
        if outputs is None:
            outputs = [fresh_name(op_type.lower() + "_out")]
        node = Node(op_type, list(inputs), list(outputs), dict(attrs or {}),
                    name=name)
        self._nodes.append(node)
        self.touch()
        return node

    def add_initializer(self, value, name: Optional[str] = None) -> str:
        name = name or fresh_name("const")
        self.initializers[name] = np.asarray(value, dtype=np.float64)
        self.touch()
        return name

    def is_constant(self, tensor: str) -> bool:
        return tensor in self.initializers

    def _index(self) -> None:
        if self._idx_version == self.cache_key:
            return
        producers: Dict[str, Node] = {}
        consumers: Dict[str, List[Node]] = {}
        for n in self._nodes:
            for t in n.outputs:
                if t not in producers:
                    producers[t] = n
            for t in set(n.inputs):
                consumers.setdefault(t, []).append(n)
        self._producers = producers
        self._consumers = consumers
        self._idx_version = self.cache_key

    def producer(self, tensor: str) -> Optional[Node]:
        self._index()
        return self._producers.get(tensor)

    def consumers(self, tensor: str) -> List[Node]:
        self._index()
        return list(self._consumers.get(tensor, ()))

    def remove_node(self, node: Node) -> None:
        self._nodes.remove(node)
        self.touch()

    def replace_input(self, old: str, new: str) -> None:
        """Rewire every consumer of ``old`` (and the graph outputs) to read
        ``new`` instead."""
        for n in self.consumers(old):
            n.inputs = [new if t == old else t for t in n.inputs]
        if old in self.outputs:
            self.outputs = [new if o == old else o for o in self.outputs]
        self.touch()

    def toposort(self) -> None:
        """Stable topological sort of self.nodes."""
        produced = set(self.inputs) | set(self.initializers)
        remaining = list(self._nodes)
        ordered: List[Node] = []
        while remaining:
            progress = False
            for n in list(remaining):
                if all(i in produced for i in n.inputs):
                    ordered.append(n)
                    produced.update(n.outputs)
                    remaining.remove(n)
                    progress = True
            if not progress:
                missing = {i for n in remaining for i in n.inputs
                           if i not in produced}
                raise ValueError(f"graph has a cycle or dangling inputs: "
                                 f"{sorted(missing)[:5]}")
        if ordered != self._nodes:     # already sorted → keep version (and
            self.nodes = ordered       # any cached analysis) intact

    def dead_code_eliminate(self) -> None:
        live = set(self.outputs)
        keep: List[Node] = []
        for n in reversed(self._nodes):
            if any(o in live for o in n.outputs):
                keep.append(n)
                live.update(n.inputs)
        keep = list(reversed(keep))
        inits = {k: v for k, v in self.initializers.items() if k in live}
        if keep != self._nodes or len(inits) != len(self.initializers):
            self.nodes = keep
            self.initializers = inits
            self.touch()

    def copy(self) -> "Graph":
        g = Graph(self.inputs, self.outputs)
        g.nodes = [Node(n.op_type, list(n.inputs), list(n.outputs),
                        dict(n.attrs), name=n.name) for n in self.nodes]
        g.initializers = {k: v.copy() for k, v in self.initializers.items()}
        return g

    # ------------------------------------------------------------ execution
    def execute(self, feeds: Dict[str, Array],
                want: Optional[Sequence[str]] = None,
                record_all: bool = False) -> Dict[str, Array]:
        """Numpy forward execution. Returns {tensor: value} for ``want``
        (default: graph outputs), or every intermediate if record_all."""
        env: Dict[str, Array] = {k: np.asarray(v, dtype=np.float64)
                                 for k, v in self.initializers.items()}
        env.update({k: np.asarray(v, dtype=np.float64)
                    for k, v in feeds.items()})
        for node in self.nodes:
            fn = EXEC_REGISTRY.get(node.op_type)
            if fn is None:
                raise NotImplementedError(f"no executor for {node.op_type}")
            args = [env[i] for i in node.inputs]
            outs = fn(node, *args)
            if not isinstance(outs, tuple):
                outs = (outs,)
            for name, val in zip(node.outputs, outs):
                env[name] = np.asarray(val, dtype=np.float64)
        if record_all:
            return env
        want = list(want) if want is not None else self.outputs
        return {k: env[k] for k in want}


# --------------------------------------------------------------------------
# op executors (registered into the unified ops.OP_REGISTRY)
# --------------------------------------------------------------------------

def executor(op_type: str):
    def deco(fn):
        register_op(op_type, execute=fn)
        return fn
    return deco


def quant_bounds(bitwidth: int, signed: bool, narrow: bool) -> Tuple[float, float]:
    b = int(bitwidth)
    if signed:
        qmin = -(2 ** (b - 1)) + (1 if narrow else 0)
        qmax = 2 ** (b - 1) - 1
    else:
        qmin = 0
        qmax = 2 ** b - 1
    return float(qmin), float(qmax)


def round_half_to_even(x: Array) -> Array:
    return np.round(x)  # numpy rounds half to even, matching ONNX Round


@executor("Quant")
def _exec_quant(node, x, scale, zero_point, bitwidth):
    signed = bool(node.attrs.get("signed", 1))
    narrow = bool(node.attrs.get("narrow", 0))
    qmin, qmax = quant_bounds(int(bitwidth), signed, narrow)
    q = np.clip(round_half_to_even(x / scale + zero_point), qmin, qmax)
    return scale * (q - zero_point)


@executor("MultiThreshold")
def _exec_multithreshold(node, x, thresholds, *rest):
    """x: (..., C) if axis=-1 (MatMul style) or (N, C, ...) if axis=1.
    thresholds: (C, N) ascending. out = bias + scale * sum_i(x >= thr_i).
    out_scale/out_bias: scalar, or (C,) per-channel arrays."""
    axis = int(node.attrs.get("axis", -1))
    out_scale = np.asarray(node.attrs.get("out_scale", 1.0), dtype=np.float64)
    out_bias = np.asarray(node.attrs.get("out_bias", 0.0), dtype=np.float64)
    C, N = thresholds.shape
    xm = np.moveaxis(x, axis, -1)  # (..., C)
    cnt = (xm[..., :, None] >= thresholds).sum(axis=-1)  # (..., C)
    out = out_bias + out_scale * cnt
    return np.moveaxis(out.astype(np.float64), -1, axis)


@executor("MatMul")
def _exec_matmul(node, a, b):
    return a @ b


@executor("Gemm")
def _exec_gemm(node, a, b, c=None):
    y = a @ b
    return y + c if c is not None else y


def _im2col(x: Array, kh: int, kw: int, stride: int, pad: int) -> Array:
    n, c, h, w = x.shape
    if pad:
        x = np.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    ho = (x.shape[2] - kh) // stride + 1
    wo = (x.shape[3] - kw) // stride + 1
    cols = np.empty((n, c, kh, kw, ho, wo), dtype=x.dtype)
    for i in range(kh):
        for j in range(kw):
            cols[:, :, i, j] = x[:, :, i:i + stride * ho:stride,
                                 j:j + stride * wo:stride]
    return cols.reshape(n, c * kh * kw, ho * wo), ho, wo


@executor("Conv")
def _exec_conv(node, x, w, b=None):
    stride = int(node.attrs.get("stride", 1))
    pad = int(node.attrs.get("pad", 0))
    groups = int(node.attrs.get("groups", 1))
    cout, cin_g, kh, kw = w.shape
    n, c, _, _ = x.shape
    assert c == cin_g * groups
    outs = []
    for g in range(groups):
        xg = x[:, g * cin_g:(g + 1) * cin_g]
        wg = w[g * (cout // groups):(g + 1) * (cout // groups)]
        cols, ho, wo = _im2col(xg, kh, kw, stride, pad)
        wmat = wg.reshape(cout // groups, cin_g * kh * kw)
        outs.append(np.einsum("ok,nkp->nop", wmat, cols).reshape(
            n, cout // groups, ho, wo))
    y = np.concatenate(outs, axis=1)
    if b is not None:
        y = y + b.reshape(1, -1, 1, 1)
    return y


@executor("Add")
def _exec_add(node, a, b):
    return a + b


@executor("Sub")
def _exec_sub(node, a, b):
    return a - b


@executor("Mul")
def _exec_mul(node, a, b):
    return a * b


@executor("Div")
def _exec_div(node, a, b):
    return a / b


@executor("Relu")
def _exec_relu(node, x):
    return np.maximum(x, 0.0)


@executor("Sigmoid")
def _exec_sigmoid(node, x):
    return 1.0 / (1.0 + np.exp(-x))


@executor("Tanh")
def _exec_tanh(node, x):
    return np.tanh(x)


@executor("Softcap")
def _exec_softcap(node, x):
    cap = float(node.attrs["cap"])
    return cap * np.tanh(x / cap)


@executor("Silu")
def _exec_silu(node, x):
    return x / (1.0 + np.exp(-x))


@executor("Gelu")
def _exec_gelu(node, x):
    from scipy.special import erf
    return 0.5 * x * (1.0 + erf(x / np.sqrt(2.0)))


@executor("HardSwish")
def _exec_hardswish(node, x):
    return x * np.clip(x + 3.0, 0.0, 6.0) / 6.0


@executor("Abs")
def _exec_abs(node, x):
    return np.abs(x)


@executor("Clip")
def _exec_clip(node, x, lo=None, hi=None):
    lo = -np.inf if lo is None else lo
    hi = np.inf if hi is None else hi
    return np.clip(x, lo, hi)


@executor("Floor")
def _exec_floor(node, x):
    return np.floor(x)


@executor("Round")
def _exec_round(node, x):
    return round_half_to_even(x)


@executor("Concat")
def _exec_concat(node, *xs):
    return np.concatenate(xs, axis=int(node.attrs.get("axis", -1)))


@executor("MaxPool")
def _exec_maxpool(node, x):
    k = int(node.attrs.get("kernel", 2))
    s = int(node.attrs.get("stride", k))
    n, c, h, w = x.shape
    ho, wo = (h - k) // s + 1, (w - k) // s + 1
    out = np.full((n, c, ho, wo), -np.inf)
    for i in range(k):
        for j in range(k):
            out = np.maximum(out, x[:, :, i:i + s * ho:s, j:j + s * wo:s])
    return out


@executor("AveragePool")
def _exec_avgpool(node, x):
    k = int(node.attrs.get("kernel", 2))
    s = int(node.attrs.get("stride", k))
    n, c, h, w = x.shape
    ho, wo = (h - k) // s + 1, (w - k) // s + 1
    out = np.zeros((n, c, ho, wo))
    for i in range(k):
        for j in range(k):
            out = out + x[:, :, i:i + s * ho:s, j:j + s * wo:s]
    return out / (k * k)


@executor("GlobalAveragePool")
def _exec_gap(node, x):
    return x.mean(axis=(2, 3), keepdims=True)


@executor("Flatten")
def _exec_flatten(node, x):
    return x.reshape(x.shape[0], -1)


@executor("Reshape")
def _exec_reshape(node, x):
    return x.reshape(node.attrs["shape"])


@executor("Transpose")
def _exec_transpose(node, x):
    return np.transpose(x, node.attrs["perm"])


@executor("Identity")
def _exec_identity(node, x):
    return x


@executor("Gather")
def _exec_gather(node, table, idx):
    return table[idx.astype(np.int64)]


@executor("Softmax")
def _exec_softmax(node, x):
    ax = int(node.attrs.get("axis", -1))
    z = x - x.max(axis=ax, keepdims=True)
    e = np.exp(z)
    return e / e.sum(axis=ax, keepdims=True)
