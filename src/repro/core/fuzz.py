"""Soundness fuzzing: differential testing of the two abstract domains.

For a graph under test the harness checks, on sampled concrete
executions, the two properties the analyses promise:

  * **soundness** — every observed tensor value lies inside the proven
    range, for the interval domain *and* for the affine reduced product;
  * **domain order** — the affine result is contained in the interval
    result for every tensor (the reduced product guarantees this
    structurally; the fuzzer re-checks it empirically so a regression in
    the intersection logic cannot hide).

Inputs come from three sources: randomly generated small graphs
(:func:`random_graph` — elementwise chains, constant matmuls, residual
forks, thresholds), the four paper QNN workloads as-imported, and the
same workloads after the full streamlining flow.  ``run_fuzz`` drives
all of them and returns a :class:`FuzzReport`; ``tests/test_lint_fuzz``
gates on zero violations.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .graph import Graph
from .intervals import ScaledIntRange
from .propagate import analyze

Shape = Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class FuzzViolation:
    graph: str
    tensor: str
    kind: str         # "interval" | "affine" | "domain-order"
    detail: str

    def __str__(self) -> str:
        return f"{self.graph}/{self.tensor} [{self.kind}]: {self.detail}"


@dataclasses.dataclass
class FuzzReport:
    graphs: int = 0
    tensors_checked: int = 0
    samples: int = 0
    violations: List[FuzzViolation] = dataclasses.field(
        default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.violations

    def merge(self, other: "FuzzReport") -> None:
        self.graphs += other.graphs
        self.tensors_checked += other.tensors_checked
        self.samples += other.samples
        self.violations.extend(other.violations)

    def summary(self) -> str:
        return (f"{self.graphs} graphs, {self.tensors_checked} tensor "
                f"checks over {self.samples} samples, "
                f"{len(self.violations)} violations")


# --------------------------------------------------------------------------
# random graph generation
# --------------------------------------------------------------------------

def random_graph(rng: np.random.Generator, n_nodes: int = 6,
                 dim: int = 4) -> Tuple[Graph, Dict[str, ScaledIntRange],
                                        Shape]:
    """A random well-formed graph over (dim,)-shaped tensors.

    Draws from elementwise arithmetic (constant and dynamic operands),
    ReLU, constant-weight MatMul, MultiThreshold and residual forks —
    the op mix SIRA's transfer functions disagree on most.  Returns
    ``(graph, input_ranges, input_shape)``.
    """
    g = Graph(inputs=["x"], outputs=[])
    lo = float(rng.uniform(-4.0, 0.0))
    hi = lo + float(rng.uniform(0.5, 6.0))
    input_ranges = {"x": ScaledIntRange(lo=np.asarray(lo),
                                        hi=np.asarray(hi))}
    # live: tensors usable as dynamic operands, with their current width
    live: List[Tuple[str, int]] = [("x", dim)]

    def pick() -> Tuple[str, int]:
        return live[int(rng.integers(len(live)))]

    for i in range(n_nodes):
        op = str(rng.choice(
            ["Add", "Sub", "Mul", "Div", "Relu", "MatMul",
             "AddDyn", "SubDyn", "MultiThreshold"]))
        t, d = pick()
        out = f"t{i}"
        if op in ("Add", "Sub", "Mul", "Div"):
            c = rng.uniform(-2.0, 2.0, size=(d,))
            if op == "Div":
                c = np.sign(c) * np.maximum(np.abs(c), 0.25)
            cname = g.add_initializer(c, name=f"c{i}")
            g.add_node(op, [t, cname], [out])
        elif op in ("AddDyn", "SubDyn"):
            t2, d2 = pick()
            if d2 != d:
                continue
            g.add_node(op[:3], [t, t2], [out])
        elif op == "Relu":
            g.add_node("Relu", [t], [out])
        elif op == "MatMul":
            m = int(rng.integers(2, 6))
            W = rng.uniform(-1.5, 1.5, size=(d, m))
            wname = g.add_initializer(W, name=f"w{i}")
            g.add_node("MatMul", [t, wname], [out])
            d = m
        else:  # MultiThreshold
            n_thr = int(rng.integers(2, 6))
            thr = np.sort(rng.uniform(-6.0, 6.0, size=(d, n_thr)), axis=1)
            tname = g.add_initializer(thr, name=f"thr{i}")
            g.add_node("MultiThreshold", [t, tname], [out],
                       attrs=dict(axis=-1, out_scale=1.0, out_bias=0.0))
        live.append((out, d))
    g.outputs = [live[-1][0]]
    return g, input_ranges, (dim,)


# --------------------------------------------------------------------------
# differential containment check
# --------------------------------------------------------------------------

def _hull(a) -> Tuple[float, float]:
    return float(np.min(a)), float(np.max(a))


def _contained(r: ScaledIntRange, val: np.ndarray, atol: float) -> bool:
    """Elementwise containment when the bound arrays match the value
    shape exactly (or are scalar); global-hull containment otherwise —
    range arrays use *broadcastable* layouts ((C,) / (C,1,1)) that do
    not always align with the concrete value shape (same convention as
    :func:`repro.core.verify.verify_ranges`)."""
    lo, hi = np.asarray(r.lo), np.asarray(r.hi)
    if lo.shape == val.shape or lo.size == 1:
        return bool(np.all(val >= lo - atol) and
                    np.all(val <= hi + atol))
    return (float(np.min(val)) >= float(np.min(lo)) - atol and
            float(np.max(val)) <= float(np.max(hi)) + atol)


def check_containment(graph: Graph,
                      input_ranges: Dict[str, ScaledIntRange],
                      input_shape: Shape,
                      n_samples: int = 8,
                      rng: Optional[np.random.Generator] = None,
                      atol: float = 1e-6,
                      name: str = "graph") -> FuzzReport:
    """Differentially test both domains on one graph."""
    rng = np.random.default_rng(0) if rng is None else rng
    rep = FuzzReport(graphs=1)
    r_int = analyze(graph, input_ranges, domain="interval")
    r_aff = analyze(graph, input_ranges, domain="affine")

    # domain order: affine hull inside interval hull, every tensor
    for tensor, ri in r_int.items():
        ra = r_aff.get(tensor)
        if ra is None:
            continue
        rep.tensors_checked += 1
        (il, ih), (al, ah) = _hull_pair(ri), _hull_pair(ra)
        if al < il - atol or ah > ih + atol:
            rep.violations.append(FuzzViolation(
                name, tensor, "domain-order",
                f"affine [{al:.6g}, {ah:.6g}] not inside "
                f"interval [{il:.6g}, {ih:.6g}]"))

    # sampled executions inside both proven bounds
    (inp,) = graph.inputs
    r_in = input_ranges[inp]
    lo = np.broadcast_to(np.asarray(r_in.lo, np.float64), input_shape)
    hi = np.broadcast_to(np.asarray(r_in.hi, np.float64), input_shape)
    for _ in range(n_samples):
        rep.samples += 1
        feeds = {inp: rng.uniform(lo, hi, size=input_shape)}
        env = graph.execute(feeds, record_all=True)
        for tensor, val in env.items():
            if graph.is_constant(tensor):
                continue
            for kind, ranges in (("interval", r_int), ("affine", r_aff)):
                r = ranges.get(tensor)
                if r is None:
                    continue
                rep.tensors_checked += 1
                if not _contained(r, val, atol):
                    v_lo, v_hi = _hull(val)
                    b_lo, b_hi = _hull_pair(r)
                    rep.violations.append(FuzzViolation(
                        name, tensor, kind,
                        f"observed [{v_lo:.6g}, {v_hi:.6g}] escapes "
                        f"proven [{b_lo:.6g}, {b_hi:.6g}]"))
    return rep


def _hull_pair(r: ScaledIntRange) -> Tuple[float, float]:
    return float(np.min(r.lo)), float(np.max(r.hi))


# --------------------------------------------------------------------------
# differential tail-conversion fuzzing
# --------------------------------------------------------------------------

_TAIL_ACTS = ["Silu", "Gelu", "Relu", "Tanh", "Sigmoid", "HardSwish",
              "Abs"]


def random_tail_graph(rng: np.random.Generator
                      ) -> Tuple[Graph, Dict[str, ScaledIntRange], int]:
    """A random elementwise chain (incl. Silu/Gelu/Clip, negative and
    per-channel scales) terminated in a Quant — the exact shape
    threshold conversion consumes.  Returns ``(graph, input_ranges, C)``
    with an integer (scale-1, bias-0) input range."""
    C = int(rng.integers(1, 5))
    lo = int(rng.integers(-200, 1))
    hi = lo + int(rng.integers(32, 320))
    g = Graph(inputs=["x"], outputs=["y"])
    cur = "x"
    idx = 0

    def emit(op: str, const: Optional[np.ndarray] = None,
             extra: Optional[List[str]] = None) -> None:
        nonlocal cur, idx
        ins = [cur]
        if const is not None:
            ins.append(g.add_initializer(np.asarray(const, np.float64),
                                         name=f"c{idx}"))
        ins.extend(extra or [])
        out = f"t{idx}"
        idx += 1
        g.add_node(op, ins, [out])
        cur = out

    # scale the integer range into activation-relevant territory;
    # sometimes negative (direction reversal), sometimes per-channel
    s0 = rng.uniform(0.01, 0.08, size=(C,)) * np.where(
        rng.random(C) < 0.25, -1.0, 1.0)
    if rng.random() < 0.5:
        s0 = np.full(C, s0[0])
    emit("Mul", s0)
    for _ in range(int(rng.integers(0, 3))):
        op = str(rng.choice(["Add", "Sub", "Mul", "Div", "Clip"]
                            + _TAIL_ACTS))
        if op in ("Add", "Sub"):
            emit(op, rng.uniform(-2.0, 2.0, size=(C,)))
        elif op == "Mul":
            emit(op, rng.uniform(-1.5, 1.5, size=(C,)))
        elif op == "Div":
            c = rng.uniform(-2.0, 2.0, size=(C,))
            emit(op, np.sign(c) * np.maximum(np.abs(c), 0.5))
        elif op == "Clip":
            a = float(rng.uniform(-2.0, 0.0))
            b = a + float(rng.uniform(0.5, 3.0))
            nlo = g.add_initializer(np.asarray(a), name=f"cl{idx}")
            nhi = g.add_initializer(np.asarray(b), name=f"ch{idx}")
            emit("Clip", None, [nlo, nhi])
        else:
            emit(op)
    if rng.random() < 0.7:
        emit(str(rng.choice(_TAIL_ACTS)))
    bits = int(rng.integers(2, 6))
    signed = int(rng.random() < 0.7)
    for nm, v in (("qs", float(rng.uniform(0.05, 0.5))),
                  ("qz", 0.0), ("qb", float(bits))):
        g.initializers[nm] = np.asarray(v, np.float64)
    g.add_node("Quant", [cur, "qs", "qz", "qb"], ["y"],
               attrs=dict(signed=signed, narrow=0))
    input_ranges = {"x": ScaledIntRange.from_scaled_int(
        np.full(C, float(lo)), np.full(C, float(hi)), 1.0, 0.0)}
    return g, input_ranges, C


def check_tail_exactness(
        g: Graph, ranges: Dict[str, ScaledIntRange],
        method: str = "auto", name: str = "graph",
        certifier: Optional[Callable] = None,
        max_exhaustive: int = 1 << 16) -> FuzzReport:
    """Differential oracle for threshold conversion (Eq. 3 exactness).

    For every layer tail that converts, re-evaluates the *original* tail
    subgraph over the proven integer grid (exhaustively up to
    ``max_exhaustive`` points, endpoint-anchored sampling beyond) and
    compares against the emitted MultiThreshold function.  The oracle
    never consults the certificate for the comparison itself, so a lying
    certifier (``certifier=...`` seeds one) that tricks the extractor
    into bad thresholds is caught here."""
    from . import monotone as _monotone
    from .thresholds import (ThresholdConversionError, _entry_int_bounds,
                             extract_thresholds, find_layer_tails,
                             tail_evaluator)
    rep = FuzzReport(graphs=1)
    for tail in find_layer_tails(g, ranges):
        cert = (certifier or _monotone.certify_tail)(g, tail, ranges)
        try:
            spec = extract_thresholds(g, tail, ranges, method=method,
                                      certificate=cert)
        except ThresholdConversionError:
            continue    # left as an elementwise chain — safe
        except ValueError:
            continue
        ev = tail_evaluator(g, tail, ranges)
        r_in = ranges[tail.input_tensor]
        lo_c, hi_c = _entry_int_bounds(r_in, ev.C)
        lo, hi = int(lo_c.min()), int(hi_c.max())
        if hi - lo + 1 <= max_exhaustive:
            xs = np.arange(lo, hi + 1, dtype=np.int64)
        else:
            xs = np.unique(np.concatenate(
                [np.array([lo, hi], np.int64),
                 np.linspace(lo, hi, 4097).astype(np.int64)]))
        ob = np.asarray(spec.out_bias, np.float64)
        osc = np.asarray(spec.out_scale, np.float64)
        rep.tensors_checked += 1
        for start in range(0, xs.size, 8192):
            blk = xs[start:start + 8192]
            rep.samples += blk.size
            ref = ev.s_q * (ev.f_int(blk) - ev.z_q)         # (R, C)
            # entry-tensor values the MultiThreshold actually compares
            x_real = (blk[:, None].astype(np.float64) * ev.in_scale
                      + ev.in_bias)                         # (R, C)
            cnt = (x_real[:, :, None]
                   >= spec.thresholds[None]).sum(axis=-1)   # (R, C)
            out = ob + osc * cnt
            # the contract only covers each channel's own proven range
            ok = (np.isclose(out, ref, rtol=1e-9, atol=1e-9)
                  | (blk[:, None] < lo_c) | (blk[:, None] > hi_c))
            if not ok.all():
                bad = np.argwhere(~ok)
                i, c = int(bad[0][0]), int(bad[0][1])
                rep.violations.append(FuzzViolation(
                    name, tail.quant_node.outputs[0], "tail-exact",
                    f"x={int(blk[i])} ch={c}: thresholds give "
                    f"{out[i, c]:.6g}, tail gives {ref[i, c]:.6g} "
                    f"(certificate {cert.summary})"))
                break
    return rep


def run_tail_fuzz(n_random: int = 40, seed: int = 0,
                  method: str = "auto",
                  certifier: Optional[Callable] = None) -> FuzzReport:
    """Fuzz threshold conversion on random quantized tails: per-tail
    differential exactness (:func:`check_tail_exactness`) plus a
    whole-graph check that the converted graph matches the original over
    the full integer grid."""
    from .thresholds import convert_tails
    rng = np.random.default_rng(seed)
    total = FuzzReport()
    for i in range(n_random):
        g, in_ranges, C = random_tail_graph(rng)
        ranges = analyze(g, in_ranges)
        name = f"tail{i}"
        total.merge(check_tail_exactness(g, ranges, method=method,
                                         name=name, certifier=certifier))
        # whole-graph differential: conversion must preserve execution
        g2 = g.copy()
        specs, _reports = convert_tails(g2, analyze(g2, in_ranges),
                                        method=method)
        if not specs:
            continue
        r_in = in_ranges["x"]
        lo = int(np.floor(np.min(r_in.int_lo)))
        hi = int(np.ceil(np.max(r_in.int_hi)))
        xs = np.arange(lo, hi + 1, dtype=np.float64)
        X = np.ascontiguousarray(
            np.broadcast_to(xs[:, None], (xs.size, C)))
        y0 = g.execute({"x": X})["y"]
        y1 = g2.execute({"x": X})["y"]
        total.samples += xs.size
        total.tensors_checked += 1
        if not np.allclose(y0, y1, rtol=1e-9, atol=1e-9):
            bad = np.argwhere(~np.isclose(y0, y1, rtol=1e-9, atol=1e-9))
            i0, c0 = int(bad[0][0]), int(bad[0][1])
            total.violations.append(FuzzViolation(
                name, "y", "tail-exact",
                f"converted graph diverges at x={xs[i0]:.0f} ch={c0}: "
                f"{y1[i0, c0]:.6g} != {y0[i0, c0]:.6g}"))
    return total


# --------------------------------------------------------------------------
# the suite
# --------------------------------------------------------------------------

def run_fuzz(n_random: int = 20, n_samples: int = 8, seed: int = 0,
             workloads: bool = True,
             optimized: bool = True) -> FuzzReport:
    """Fuzz random graphs and (optionally) the four paper workloads, raw
    and after the full streamlining flow."""
    rng = np.random.default_rng(seed)
    total = FuzzReport()
    for i in range(n_random):
        g, in_ranges, shape = random_graph(
            rng, n_nodes=int(rng.integers(3, 10)))
        total.merge(check_containment(
            g, in_ranges, shape, n_samples=n_samples, rng=rng,
            name=f"random{i}"))
    if workloads:
        from .workloads import WORKLOADS
        for wname, factory in WORKLOADS.items():
            wl = factory()
            total.merge(check_containment(
                wl.graph, wl.input_range, wl.input_shape,
                n_samples=max(2, n_samples // 4), rng=rng, name=wname))
            if optimized:
                from .flow import build_flow
                res = build_flow(wl)
                total.merge(check_containment(
                    res.graph, res.model.input_ranges, wl.input_shape,
                    n_samples=max(2, n_samples // 4), rng=rng,
                    name=f"{wname}+flow"))
    return total
