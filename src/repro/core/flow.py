"""FINN-builder-style declarative build flow over ``SiraModel``.

    from repro.core import SiraModel, build_flow
    result = build_flow(SiraModel.from_workload(make_tfc()))
    result.graph                 # streamlined + thresholded graph
    result.accumulator_reports   # paper §4.2 widths
    result.steps                 # per-step timing / modified / #analyses

A flow is a list of *steps* — registered step names, ``Transformation``
instances, or plain callables — executed in order with per-step timing,
analysis-call accounting (how many full range propagations each step
triggered; consecutive graph-preserving steps share one cached analysis)
and optional per-step verification hooks:

  * ``verify="equivalence"``  — after each step, the graph must produce
    outputs numerically identical to the pre-flow model on random inputs.
  * ``verify="containment"``  — after each step, empirical min/max of every
    tensor must lie inside the (cached) SIRA ranges.
  * ``verify="full"``         — both.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Union

import numpy as np

from . import propagate as _prop
from ..obs.trace import get_tracer
from .lower import CompileBackend
from .model import SiraModel
from .passes import (AggregateScalesBiases, ConvertTailsToThresholds,
                     ExplicitizeQuantizers, MinimizeAccumulators,
                     RemoveIdentityOps, Transformation, VerifyRanges,
                     as_transformation)
from .verify import verify_ranges as _verify_ranges
from .workloads import QNNWorkload

Step = Union[str, Transformation, Callable]

DEFAULT_STEPS: List[str] = [
    "explicitize_quantizers",
    "aggregate_scales_biases",
    "convert_tails_to_thresholds",
    "minimize_accumulators",
    "verify_ranges",
]


@dataclasses.dataclass
class BuildConfig:
    """Declarative flow configuration (FINN ``DataflowBuildConfig`` style)."""
    steps: Sequence[Step] = tuple(DEFAULT_STEPS)
    threshold_method: str = "auto"       # "auto" | "edge" | "bisect"
    input_bits: int = 8
    weight_bits: int = 8
    verify: str = "none"                 # "none"|"equivalence"|"containment"|"full"
    verify_samples: int = 3
    seed: int = 0
    strict_verify: bool = True
    # abstract domain for range analysis: "interval" (paper default) or
    # "affine" (zonotope reduced product, repro.core.affine)
    domain: str = "interval"
    # pre-flow graph lint (repro.core.lint); "strict" raises LintError on
    # errors, "warn" records the report under metadata['lint'], "off" skips
    lint: str = "strict"
    # dataflow DSE steps (step_dataflow_estimate / step_dataflow_fold):
    # None -> unfolded estimate; DataflowFold then targets 30 FPS
    device: str = "pynq-z1"
    target_fps: Optional[float] = None


@dataclasses.dataclass
class StepReport:
    name: str
    modified: bool
    seconds: float
    analysis_calls: int       # full range propagations triggered by the step
    note: str = ""


@dataclasses.dataclass
class BuildResult:
    model: SiraModel
    steps: List[StepReport]

    @property
    def graph(self):
        return self.model.graph

    @property
    def threshold_specs(self):
        return self.model.metadata.get("threshold_specs", [])

    @property
    def tail_reports(self):
        return self.model.metadata.get("tail_reports", [])

    @property
    def accumulator_reports(self):
        return self.model.metadata.get("accumulator_reports", [])

    @property
    def verification(self):
        return self.model.metadata.get("verification")

    @property
    def aggregation(self):
        return self.model.metadata.get("aggregation")

    @property
    def total_analysis_calls(self) -> int:
        return sum(s.analysis_calls for s in self.steps)


# --------------------------------------------------------------------------
# step registry: name -> factory(BuildConfig) -> Transformation
# --------------------------------------------------------------------------

STEP_REGISTRY: Dict[str, Callable[[BuildConfig], Transformation]] = {}


def register_step(name: str):
    def deco(factory):
        STEP_REGISTRY[name] = factory
        return factory
    return deco


register_step("explicitize_quantizers")(
    lambda cfg: ExplicitizeQuantizers())
register_step("aggregate_scales_biases")(
    lambda cfg: AggregateScalesBiases(explicitize=False))
register_step("streamline")(
    lambda cfg: AggregateScalesBiases(explicitize=True))
register_step("remove_identity_ops")(
    lambda cfg: RemoveIdentityOps())
register_step("convert_tails_to_thresholds")(
    lambda cfg: ConvertTailsToThresholds(method=cfg.threshold_method))
register_step("minimize_accumulators")(
    lambda cfg: MinimizeAccumulators(input_bits=cfg.input_bits,
                                     weight_bits=cfg.weight_bits))
register_step("verify_ranges")(
    lambda cfg: VerifyRanges(samples=cfg.verify_samples, seed=cfg.seed,
                             strict=cfg.strict_verify))


def _step_lint(cfg: "BuildConfig"):
    from .passes import LintGraph
    return LintGraph(strict=cfg.lint != "warn")


# explicit mid-flow lint (build_flow always pre-lints unless lint="off")
register_step("lint_graph")(_step_lint)
# lower to the compiled Pallas-kernel backend (result under
# metadata['compiled']); optional — append to cfg.steps to enable, e.g.
#   build_flow(wl, steps=list(DEFAULT_STEPS) + ["step_compile"])
register_step("step_compile")(
    lambda cfg: CompileBackend())
register_step("compile")(
    lambda cfg: CompileBackend())


# dataflow DSE steps (imported lazily: repro.dataflow itself imports core
# submodules, so the factories must not run at module import time).
# Graph-preserving; results land under metadata['dataflow_report'] /
# metadata['dataflow_estimate'] / metadata['folding'].
def _step_dataflow_estimate(cfg: "BuildConfig"):
    from ..dataflow.passes import DataflowEstimate
    return DataflowEstimate(device=cfg.device, target_fps=cfg.target_fps)


def _step_dataflow_fold(cfg: "BuildConfig"):
    from ..dataflow.passes import DataflowFold
    return DataflowFold(target_fps=cfg.target_fps or 30.0,
                        device=cfg.device)


register_step("step_dataflow_estimate")(_step_dataflow_estimate)
register_step("step_dataflow_fold")(_step_dataflow_fold)

#: DEFAULT_STEPS plus the dataflow DSE tail — the full accelerator flow
DATAFLOW_STEPS: List[str] = list(DEFAULT_STEPS) + [
    "step_dataflow_estimate", "step_dataflow_fold"]


def resolve_step(step: Step, cfg: BuildConfig) -> Transformation:
    if isinstance(step, str):
        if step not in STEP_REGISTRY:
            raise KeyError(f"unknown build step {step!r}; registered: "
                           f"{sorted(STEP_REGISTRY)}")
        return STEP_REGISTRY[step](cfg)
    return as_transformation(step)


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------

def _as_model(model, domain: str = "interval") -> SiraModel:
    if isinstance(model, SiraModel):
        m = model.copy()
        if domain != "interval" and m.domain != domain:
            m.domain = domain
            m.invalidate()
        return m
    if isinstance(model, QNNWorkload):
        return SiraModel.from_workload(model, domain=domain)
    if isinstance(model, tuple) and len(model) == 2:
        graph, input_ranges = model
        return SiraModel(graph.copy(), input_ranges, domain=domain)
    raise TypeError(f"cannot build a SiraModel from {type(model).__name__}")


def build_flow(model, cfg: Optional[BuildConfig] = None,
               **overrides: Any) -> BuildResult:
    """Run a configured step list over a model (``SiraModel``,
    ``QNNWorkload``, or ``(graph, input_ranges)``; the input is never
    mutated).  Keyword overrides patch ``cfg`` fields, e.g.
    ``build_flow(wl, verify="equivalence")``."""
    if cfg is None:
        cfg = BuildConfig()
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    model = _as_model(model, domain=cfg.domain)
    tr = get_tracer()
    with tr.span("flow:build", model=model.name, domain=cfg.domain,
                 steps=len(cfg.steps), verify=cfg.verify):
        return _run_flow(model, cfg)


def _run_flow(model: SiraModel, cfg: BuildConfig) -> BuildResult:
    tr = get_tracer()
    reports: List[StepReport] = []
    if cfg.lint != "off":
        from .passes import LintGraph
        t0 = time.perf_counter()
        with tr.span("step:lint_graph", pre_flow=True):
            model, _ = LintGraph(strict=cfg.lint == "strict").apply(model)
        rep = model.metadata.get("lint")
        reports.append(StepReport(
            name="lint_graph", modified=False,
            seconds=time.perf_counter() - t0, analysis_calls=0,
            note=rep.summary() if rep is not None else ""))

    # reference data for per-step equivalence verification
    want_equiv = cfg.verify in ("equivalence", "full")
    want_contain = cfg.verify in ("containment", "full")
    ref_feeds: List[Dict[str, np.ndarray]] = []
    ref_outs: List[List[np.ndarray]] = []   # per feed, per graph output
    if want_equiv or want_contain:
        try:
            ref_feeds = list(model.sample_inputs(
                rng=np.random.default_rng(cfg.seed), n=cfg.verify_samples))
        except ValueError as e:
            # the user explicitly asked for verification — don't silently
            # run an unverified flow
            raise ValueError(
                f"verify={cfg.verify!r} needs sample inputs, but none can "
                f"be drawn ({e}); wrap the graph in a SiraModel with "
                f"metadata['input_shape'] set, or use verify='none'")
    if want_equiv:
        # outputs are compared positionally: passes may rename output
        # tensors (e.g. aggregation appends a Mul/Add stage) but never
        # reorder them
        for f in ref_feeds:
            outs = model.execute(f)
            ref_outs.append([outs[o] for o in model.graph.outputs])

    for step in cfg.steps:
        tx = resolve_step(step, cfg)
        calls0 = _prop.analysis_calls()
        t0 = time.perf_counter()
        # A raising step still closes its span (with an ``error`` attr
        # and partial analysis-call count), so a failed flow produces a
        # usable trace up to and including the failing step.
        with tr.span(f"step:{tx.name}") as sp:
            try:
                model, modified = tx.apply(model)
                sp.set_attr("modified", modified)
                note = ""
                if modified and ref_feeds:
                    if want_equiv:
                        for feeds, expect in zip(ref_feeds, ref_outs):
                            got = model.execute(feeds)
                            for out_name, val in zip(
                                    model.graph.outputs, expect):
                                np.testing.assert_allclose(
                                    got[out_name], val, rtol=1e-9,
                                    atol=1e-9,
                                    err_msg=f"step {tx.name} broke "
                                            f"equivalence")
                        note = "equivalence ok"
                    if want_contain:
                        rep = _verify_ranges(model.graph, model.ranges,
                                             ref_feeds)
                        if not rep.contained:
                            raise AssertionError(
                                f"step {tx.name} broke containment: "
                                f"{rep.violations[:3]}")
                        note = (note + "; " if note else "") + \
                            "containment ok"
            finally:
                sp.set_attr("analysis_calls",
                            _prop.analysis_calls() - calls0)
        seconds = sp.dur_s if getattr(sp, "dur_s", None) is not None \
            else time.perf_counter() - t0
        reports.append(StepReport(
            name=tx.name, modified=modified, seconds=seconds,
            analysis_calls=_prop.analysis_calls() - calls0, note=note))
    return BuildResult(model=model, steps=reports)
