"""Scaled-integer ranges and interval arithmetic primitives (paper §2.4, §3).

A ``ScaledIntRange`` tracks, for one tensor ``v``:

  * the full-precision value interval  ``[lo, hi]``  (elementwise arrays),
  * optionally an underlying integer interval ``[int_lo, int_hi]`` together
    with constant ``scale`` and ``bias`` arrays such that

        [lo, hi] = scale * [int_lo, int_hi] + bias        (scale > 0)

  * the set of graph tensors that *contributed* to scale/bias (used by the
    streamlining transform to erase the originals, paper §4.1.2 step 4).

All members are kept as numpy arrays broadcastable to the tensor shape.
Scale and bias must be constants (paper §3: allowing interval-valued scales
explodes the analysis).
"""
from __future__ import annotations

import dataclasses
from typing import FrozenSet, Optional, Tuple

import numpy as np

Array = np.ndarray


class InvalidRangeError(ValueError):
    """A range violates a soundness invariant (inverted interval, NaN
    bound, non-positive scale, missing integer component).

    Raised instead of a bare ``assert`` so the checks survive
    ``python -O``; the graph linter (:mod:`repro.core.lint`) reuses it via
    :meth:`ScaledIntRange.validate`.
    """


def _as_arr(x) -> Array:
    return np.asarray(x, dtype=np.float64)


@dataclasses.dataclass(frozen=True)
class ScaledIntRange:
    lo: Array
    hi: Array
    int_lo: Optional[Array] = None
    int_hi: Optional[Array] = None
    scale: Optional[Array] = None
    bias: Optional[Array] = None
    # names of graph initializers contributing to scale / bias
    scale_src: FrozenSet[str] = frozenset()
    bias_src: FrozenSet[str] = frozenset()

    def __post_init__(self):
        object.__setattr__(self, "lo", _as_arr(self.lo))
        object.__setattr__(self, "hi", _as_arr(self.hi))
        if self.int_lo is not None:
            object.__setattr__(self, "int_lo", _as_arr(self.int_lo))
            object.__setattr__(self, "int_hi", _as_arr(self.int_hi))
        if self.scale is not None:
            object.__setattr__(self, "scale", _as_arr(self.scale))
        if self.bias is not None:
            object.__setattr__(self, "bias", _as_arr(self.bias))
        self.validate()

    def validate(self) -> None:
        """Re-check the soundness invariants, raising
        :class:`InvalidRangeError` on violation.  Runs at construction;
        the graph linter calls it again on declared ranges (which may
        have been mutated or built by bypassing ``__init__``)."""
        if np.any(np.isnan(self.lo)) or np.any(np.isnan(self.hi)):
            raise InvalidRangeError("NaN range bound")
        if not np.all(self.lo <= self.hi + 1e-12):
            raise InvalidRangeError("inverted interval: lo > hi")
        if self.int_lo is not None:
            if self.int_hi is None or self.scale is None:
                raise InvalidRangeError(
                    "integer interval requires int_lo, int_hi and scale")
            if not np.all(self.int_lo <= self.int_hi + 1e-12):
                raise InvalidRangeError("inverted integer interval")
        if self.scale is not None and not np.all(self.scale > 0):
            raise InvalidRangeError("scales must be positive")

    # ------------------------------------------------------------------ api
    @property
    def is_scaled_int(self) -> bool:
        return self.int_lo is not None

    @property
    def is_point(self) -> bool:
        """Constant (point) interval — e.g. weights."""
        return bool(np.all(self.lo == self.hi))

    def width(self) -> Array:
        return self.hi - self.lo

    @staticmethod
    def point(value) -> "ScaledIntRange":
        v = _as_arr(value)
        r = ScaledIntRange(lo=v, hi=v)
        # A constant integer tensor is trivially scaled-integer (s=1, b=0).
        if np.all(np.floor(v) == v):
            r = ScaledIntRange(lo=v, hi=v, int_lo=v, int_hi=v,
                               scale=np.ones(()), bias=np.zeros(()))
        return r

    @staticmethod
    def from_scaled_int(int_lo, int_hi, scale, bias=0.0,
                        scale_src=frozenset(), bias_src=frozenset()
                        ) -> "ScaledIntRange":
        int_lo, int_hi = _as_arr(int_lo), _as_arr(int_hi)
        scale, bias = _as_arr(scale), _as_arr(bias)
        if not np.all(scale > 0):
            raise InvalidRangeError("scales must be positive")
        lo = scale * int_lo + bias
        hi = scale * int_hi + bias
        return ScaledIntRange(lo=lo, hi=hi, int_lo=int_lo, int_hi=int_hi,
                              scale=scale, bias=bias,
                              scale_src=frozenset(scale_src),
                              bias_src=frozenset(bias_src))

    def drop_scaled_int(self) -> "ScaledIntRange":
        return ScaledIntRange(lo=self.lo, hi=self.hi)

    def contains(self, x, atol: float = 1e-6) -> bool:
        x = _as_arr(x)
        return bool(np.all(x >= self.lo - atol) and np.all(x <= self.hi + atol))

    def required_signed_bits(self) -> int:
        """Two's-complement bits for the *integer* interval (paper §4.2):

            P = ceil(log2(max(|z_lo|, |z_hi| + 1))) + 1
        """
        if not self.is_scaled_int:
            raise InvalidRangeError("no integer component")
        zmin = float(np.min(self.int_lo))
        zmax = float(np.max(self.int_hi))
        m = max(abs(zmin), abs(zmax) + 1.0)
        if m <= 1.0:
            return 1
        return int(np.ceil(np.log2(m))) + 1

    def required_unsigned_bits(self) -> int:
        if not self.is_scaled_int or np.min(self.int_lo) < 0:
            raise InvalidRangeError(
                "no unsigned integer component (missing or negative)")
        zmax = float(np.max(self.int_hi))
        if zmax <= 0:
            return 1
        return max(1, int(np.ceil(np.log2(zmax + 1.0))))


# --------------------------------------------------------------------------
# plain interval arithmetic (used when scaled-int structure is lost)
# --------------------------------------------------------------------------

def add_intervals(a_lo, a_hi, b_lo, b_hi) -> Tuple[Array, Array]:
    return a_lo + b_lo, a_hi + b_hi


def mul_intervals(a_lo, a_hi, b_lo, b_hi) -> Tuple[Array, Array]:
    cands = np.stack(np.broadcast_arrays(
        a_lo * b_lo, a_lo * b_hi, a_hi * b_lo, a_hi * b_hi))
    return cands.min(axis=0), cands.max(axis=0)


def monotonic_fn_interval(fn, lo, hi) -> Tuple[Array, Array]:
    """Elementwise-monotonic function (paper §2.4.1): extrema at corners."""
    a, b = fn(lo), fn(hi)
    return np.minimum(a, b), np.maximum(a, b)


def dot_interval(w: Array, x_lo: Array, x_hi: Array) -> Tuple[Array, Array]:
    """Constant-weighted dot product (paper §2.4.2, Gowal et al. simplified).

    ``w``: (K, M) constant weights; ``x``: (..., K) interval.
    miv/mav construction via the midpoint/radius identity:
        y_c = x_c @ w ;  y_r = x_r @ |w|  →  [y_c - y_r, y_c + y_r]
    which is exactly the min/max over minimizing/maximizing input vectors.
    """
    x_c = (x_hi + x_lo) * 0.5
    x_r = (x_hi - x_lo) * 0.5
    y_c = x_c @ w
    y_r = x_r @ np.abs(w)
    return y_c - y_r, y_c + y_r


def dyn_dot_interval(a_lo, a_hi, b_lo, b_hi, k_axis_a=-1, k_axis_b=-2
                     ) -> Tuple[Array, Array]:
    """Dynamic x dynamic matmul interval (beyond-paper handler, conservative).

    Elementwise product hull summed over the contraction axis. Shapes must be
    plain matmul-compatible: a (..., M, K), b (..., K, N).
    """
    a_lo = np.expand_dims(a_lo, -1)   # (..., M, K, 1)
    a_hi = np.expand_dims(a_hi, -1)
    b_lo = np.expand_dims(b_lo, -3)   # (..., 1, K, N)
    b_hi = np.expand_dims(b_hi, -3)
    p_lo, p_hi = mul_intervals(a_lo, a_hi, b_lo, b_hi)
    return p_lo.sum(axis=-2), p_hi.sum(axis=-2)
