"""Graph linter: static well-formedness checks over the SIRA IR.

``lint_graph`` runs three layers of checks and returns a
:class:`LintReport` of node-level findings:

  * **structural** — dangling node inputs (no producer, not an initializer
    or graph input), unproduced graph outputs, two producers for one
    tensor, cycles, ops with no registered executor / propagation handler,
    nodes with no path to a graph output (warning);
  * **shape / dtype** — lightweight forward shape inference (seeded from
    initializer shapes and optional declared ``input_shapes``) catching
    MatMul/Gemm contraction mismatches, Conv weight-rank / channel /
    groups inconsistencies, non-broadcastable elementwise operands,
    MultiThreshold threshold tables that are not 2-D with ascending rows,
    Quant parameter inputs that are not constants;
  * **range soundness** — every declared / computed ``ScaledIntRange``
    must pass :meth:`ScaledIntRange.validate` (inverted or NaN bounds,
    non-positive scales — the :class:`InvalidRangeError` invariants), and
    scale/bias contribution sets may only name existing constants or the
    ``POISON`` marker.

The linter never mutates the graph (no ``toposort()``, no index writes
besides the lazily-built producer map the Graph already maintains).
``passes.LintGraph`` wraps it as a pipeline step and ``build_flow`` runs
it as a pre-flow verification hook (``BuildConfig.lint``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .graph import Graph, Node
from .intervals import InvalidRangeError, ScaledIntRange
from .ops import EXEC_REGISTRY, PROP_REGISTRY

Shape = Tuple[int, ...]


class LintError(ValueError):
    """Raised by strict lint runs when error-level findings exist."""

    def __init__(self, report: "LintReport"):
        self.report = report
        msgs = "; ".join(str(f) for f in report.errors[:5])
        more = len(report.errors) - 5
        super().__init__(
            f"graph lint failed: {msgs}" +
            (f" (+{more} more)" if more > 0 else ""))


@dataclasses.dataclass(frozen=True)
class LintFinding:
    level: str          # "error" | "warning"
    rule: str           # stable rule id, e.g. "dangling-input"
    node: str           # node name ("" for graph-level findings)
    message: str

    def __str__(self) -> str:
        where = f" @ {self.node}" if self.node else ""
        return f"[{self.rule}{where}] {self.message}"


@dataclasses.dataclass
class LintReport:
    findings: List[LintFinding] = dataclasses.field(default_factory=list)

    @property
    def errors(self) -> List[LintFinding]:
        return [f for f in self.findings if f.level == "error"]

    @property
    def warnings(self) -> List[LintFinding]:
        return [f for f in self.findings if f.level == "warning"]

    @property
    def ok(self) -> bool:
        return not self.errors

    def summary(self) -> str:
        return (f"{len(self.errors)} errors, "
                f"{len(self.warnings)} warnings")

    def __str__(self) -> str:
        if not self.findings:
            return "lint: clean"
        return "\n".join(str(f) for f in self.findings)


class _Linter:
    def __init__(self, graph: Graph):
        self.graph = graph
        self.report = LintReport()

    def error(self, rule: str, node: str, msg: str) -> None:
        self.report.findings.append(LintFinding("error", rule, node, msg))

    def warn(self, rule: str, node: str, msg: str) -> None:
        self.report.findings.append(LintFinding("warning", rule, node, msg))


# --------------------------------------------------------------------------
# structural checks
# --------------------------------------------------------------------------

def _check_structure(lt: _Linter) -> None:
    g = lt.graph
    produced: Dict[str, Node] = {}
    for n in g.nodes:
        for t in n.outputs:
            if t in produced:
                lt.error("duplicate-producer", n.name,
                         f"tensor {t!r} produced by both "
                         f"{produced[t].name!r} and {n.name!r}")
            else:
                produced[t] = n
        if not n.outputs:
            lt.error("no-outputs", n.name, "node declares no outputs")

    known = set(g.inputs) | set(g.initializers)
    for n in g.nodes:
        for t in n.inputs:
            if t not in known and t not in produced:
                lt.error("dangling-input", n.name,
                         f"input tensor {t!r} has no producer and is "
                         f"neither a graph input nor an initializer")
        if t_over := (set(n.outputs) & known):
            lt.error("shadowed-tensor", n.name,
                     f"output(s) {sorted(t_over)} shadow a graph "
                     f"input/initializer")
    for t in g.outputs:
        if t not in known and t not in produced:
            lt.error("dangling-output", "",
                     f"graph output {t!r} is never produced")

    # cycle check: Kahn's algorithm without mutating the graph
    ready = set(known)
    remaining = list(g.nodes)
    progress = True
    while remaining and progress:
        progress = False
        rest = []
        for n in remaining:
            if all(t in ready for t in n.inputs):
                ready.update(n.outputs)
                progress = True
            else:
                rest.append(n)
        remaining = rest
    for n in remaining:
        # only blame nodes whose inputs all *have* producers (pure cycle
        # members) — dangling inputs were already reported above
        if all(t in produced or t in known for t in n.inputs):
            lt.error("cycle", n.name, "node participates in a cycle")

    for n in g.nodes:
        if EXEC_REGISTRY.get(n.op_type) is None:
            lt.warn("no-executor", n.name,
                    f"op {n.op_type!r} has no registered executor")
        if PROP_REGISTRY.get(n.op_type) is None:
            lt.error("no-handler", n.name,
                     f"op {n.op_type!r} has no SIRA propagation handler")

    # reachability: nodes that cannot influence any graph output
    needed = set(g.outputs)
    for n in reversed(_topo_order(g, produced, known)):
        if any(t in needed for t in n.outputs):
            needed.update(n.inputs)
    for n in g.nodes:
        if not any(t in needed for t in n.outputs):
            lt.warn("dead-node", n.name,
                    "node output never reaches a graph output")


def _topo_order(g: Graph, produced: Dict[str, Node], known) -> List[Node]:
    ready = set(known)
    ordered: List[Node] = []
    remaining = list(g.nodes)
    progress = True
    while remaining and progress:
        progress = False
        rest = []
        for n in remaining:
            if all(t in ready for t in n.inputs):
                ready.update(n.outputs)
                ordered.append(n)
                progress = True
            else:
                rest.append(n)
        remaining = rest
    return ordered + remaining      # cycle members appended, order moot


# --------------------------------------------------------------------------
# shape checks (lightweight forward inference; None = unknown)
# --------------------------------------------------------------------------

def _broadcastable(a: Shape, b: Shape) -> bool:
    try:
        np.broadcast_shapes(a, b)
        return True
    except ValueError:
        return False


def _infer_shapes(lt: _Linter,
                  input_shapes: Optional[Dict[str, Shape]]) -> None:
    g = lt.graph
    shapes: Dict[str, Optional[Shape]] = {
        k: tuple(v.shape) for k, v in g.initializers.items()}
    for k, s in (input_shapes or {}).items():
        shapes[k] = tuple(s)

    produced = {t: n for n in g.nodes for t in n.outputs}
    known = set(g.inputs) | set(g.initializers)
    for node in _topo_order(g, produced, known):
        ins = [shapes.get(t) for t in node.inputs]
        out = _check_node_shapes(lt, node, ins)
        for t in node.outputs:
            shapes[t] = out


def _check_node_shapes(lt: _Linter, node: Node,
                       ins: Sequence[Optional[Shape]]
                       ) -> Optional[Shape]:
    op = node.op_type
    g = lt.graph

    if op in ("Add", "Sub", "Mul", "Div"):
        a, b = (ins + [None, None])[:2]
        if a is not None and b is not None:
            if not _broadcastable(a, b):
                lt.error("broadcast-mismatch", node.name,
                         f"{op} operands {a} x {b} do not broadcast")
                return None
            return tuple(np.broadcast_shapes(a, b))
        return None

    if op in ("MatMul", "Gemm"):
        a, b = (ins + [None, None])[:2]
        if a is not None and b is not None and a and b:
            if len(b) != 2:
                lt.error("weight-rank", node.name,
                         f"{op} second operand must be 2-D, got {b}")
                return None
            if a[-1] != b[0]:
                lt.error("contraction-mismatch", node.name,
                         f"{op} contraction K mismatch: x {a} @ W {b}")
                return None
            out = a[:-1] + (b[1],)
            if op == "Gemm" and len(ins) > 2 and ins[2] is not None \
                    and not _broadcastable(out, ins[2]):
                lt.error("broadcast-mismatch", node.name,
                         f"Gemm bias {ins[2]} does not broadcast to {out}")
            return out
        return None

    if op == "Conv":
        w = ins[1] if len(ins) > 1 else None
        groups = int(node.attrs.get("groups", 1))
        if w is not None:
            if len(w) != 4:
                lt.error("weight-rank", node.name,
                         f"Conv weight must be 4-D, got {w}")
                return None
            cout, cin_g = w[0], w[1]
            if cout % groups != 0:
                lt.error("groups-mismatch", node.name,
                         f"Conv groups={groups} does not divide "
                         f"Cout={cout}")
            x = ins[0]
            if x is not None and len(x) == 4 and x[1] != cin_g * groups:
                lt.error("channels-mismatch", node.name,
                         f"Conv input has {x[1]} channels, weight "
                         f"expects {cin_g * groups} "
                         f"(Cin/g={cin_g}, groups={groups})")
            x = ins[0]
            if x is not None and len(x) == 4:
                stride = int(node.attrs.get("stride", 1))
                pad = int(node.attrs.get("pad", 0))
                ho = (x[2] + 2 * pad - w[2]) // stride + 1
                wo = (x[3] + 2 * pad - w[3]) // stride + 1
                if ho <= 0 or wo <= 0:
                    lt.error("empty-output", node.name,
                             f"Conv output spatial dims ({ho}, {wo}) "
                             f"are empty")
                    return None
                return (x[0], cout, ho, wo)
        return None

    if op in ("MaxPool", "AveragePool"):
        x = ins[0]
        if x is not None and len(x) == 4:
            k = int(node.attrs.get("kernel", 2))
            s = int(node.attrs.get("stride", k))
            ho, wo = (x[2] - k) // s + 1, (x[3] - k) // s + 1
            if ho <= 0 or wo <= 0:
                lt.error("empty-output", node.name,
                         f"{op} output spatial dims ({ho}, {wo}) are "
                         f"empty")
                return None
            return (x[0], x[1], ho, wo)
        return None

    if op == "MultiThreshold":
        thr_name = node.inputs[1] if len(node.inputs) > 1 else None
        if thr_name is None or not g.is_constant(thr_name):
            lt.error("const-required", node.name,
                     "MultiThreshold thresholds must be a constant")
            return None
        thr = g.initializers[thr_name]
        if thr.ndim != 2:
            lt.error("threshold-rank", node.name,
                     f"thresholds must be 2-D (C, N), got shape "
                     f"{tuple(thr.shape)}")
            return ins[0]
        if thr.shape[1] > 1 and not np.all(np.diff(thr, axis=1) >= 0):
            lt.error("threshold-order", node.name,
                     "threshold rows must be ascending")
        x = ins[0]
        if x is not None:
            C = thr.shape[0]
            axis = int(node.attrs.get("axis", -1))
            ch = x[1] if axis == 1 and len(x) >= 2 else \
                (x[-1] if x else None)
            if ch is not None and ch != C:
                lt.error("channels-mismatch", node.name,
                         f"input has {ch} channels on axis {axis}, "
                         f"thresholds declare {C}")
        return ins[0]

    if op == "Quant":
        for i, role in ((1, "scale"), (2, "zero-point"), (3, "bits")):
            if len(node.inputs) > i and \
                    not g.is_constant(node.inputs[i]):
                lt.error("const-required", node.name,
                         f"Quant {role} input {node.inputs[i]!r} must "
                         f"be a constant")
        return ins[0]

    if op in ("Identity", "Relu", "Clip", "Sigmoid", "Tanh", "Floor",
              "Round", "Softcap", "Silu", "Gelu", "HardSwish", "Abs"):
        return ins[0]

    return None     # unknown op / data-dependent shape (Reshape, Concat...)


# --------------------------------------------------------------------------
# threshold-conversion certificate checks
# --------------------------------------------------------------------------

def _check_certificates(lt: _Linter) -> None:
    """Threshold conversions must carry a monotonicity certificate
    (paper §4.1.3 exactness only holds for certified-monotone tails), and
    tails the certifier rejected should be visible with their reason code
    — the DSE prices those as elementwise meta-kernels."""
    for n in lt.graph.nodes:
        if n.op_type == "MultiThreshold" and "certificate" not in n.attrs:
            lt.warn("uncertified-threshold", n.name,
                    "MultiThreshold without a monotonicity certificate — "
                    "Eq. 3 exactness is unverified for this conversion")
        reason = n.attrs.get("unconverted_reason")
        if reason is not None:
            lt.warn("unconverted-tail", n.name,
                    f"layer tail left unconverted ({reason}) — will be "
                    f"priced as an elementwise meta-kernel")


# --------------------------------------------------------------------------
# range checks
# --------------------------------------------------------------------------

def _check_ranges(lt: _Linter,
                  ranges: Dict[str, ScaledIntRange]) -> None:
    from .propagate import POISON
    g = lt.graph
    valid_src = set(g.initializers) | {POISON}
    for tensor, r in ranges.items():
        node = g.producer(tensor)
        where = node.name if node is not None else ""
        try:
            r.validate()
        except InvalidRangeError as e:
            lt.error("invalid-range", where,
                     f"range of {tensor!r} is unsound: {e}")
            continue
        for kind, src in (("scale_src", r.scale_src),
                          ("bias_src", r.bias_src)):
            stale = set(src) - valid_src
            if stale:
                lt.error("stale-contribution", where,
                         f"{kind} of {tensor!r} names non-constant "
                         f"tensors {sorted(stale)}")


# --------------------------------------------------------------------------
# entry point
# --------------------------------------------------------------------------

def lint_graph(graph: Graph,
               input_ranges: Optional[Dict[str, ScaledIntRange]] = None,
               input_shapes: Optional[Dict[str, Shape]] = None,
               ranges: Optional[Dict[str, ScaledIntRange]] = None
               ) -> LintReport:
    """Lint a graph; returns the report (never raises, never mutates).

    ``ranges`` — pre-computed analysis results to validate (e.g.
    ``model.ranges``); when omitted, only declared ``input_ranges`` are
    range-checked (the linter must stay useful on graphs too malformed to
    analyze).  ``input_shapes`` seeds shape inference for graph inputs.
    """
    lt = _Linter(graph)
    _check_structure(lt)
    _check_certificates(lt)
    _infer_shapes(lt, input_shapes)
    declared = dict(input_ranges or {})
    declared.update(ranges or {})
    if declared:
        _check_ranges(lt, declared)
    return lt.report
