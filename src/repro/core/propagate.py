"""SIRA: node-by-node scaled-integer range propagation (paper §3, Listing 1).

For every tensor in a Graph we compute a :class:`ScaledIntRange`. Handlers
implement the paper's propagation rules:

  * Quant anchors scaled-integer ranges (§3.2.1).
  * Add propagates when one input is constant, or both are scaled-int with an
    integer scale ratio (§3.2.2).
  * Mul propagates when one input is constant (§3.2.3).
  * MatMul/Conv propagate with per-channel weight scales, zero weight bias,
    per-tensor (per-channel for depthwise) input scales (§3.2.4).
  * Elementwise monotonic ops propagate plain ranges (§2.4.1); value-preserving
    ops (MaxPool, Concat, transpositions) keep the scaled-int structure.
  * Dynamic x dynamic matmuls propagate plain interval hulls (beyond-paper,
    conservative; needed for attention score/PV matmuls in LM blocks).

Contribution tracking (scale_src / bias_src) feeds the streamlining
transform; POISON marks ranges whose scale cannot be erased exactly
(e.g. scaled-int Add with ratio k != 1).
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from .graph import Graph, Node, quant_bounds, round_half_to_even
from .intervals import (Array, ScaledIntRange, add_intervals, dot_interval,
                        monotonic_fn_interval, mul_intervals)
from .ops import PROP_REGISTRY, register_op  # noqa: F401  (re-exported)
from ..obs.explain import RangeProvenance
from ..obs.trace import get_tracer

POISON = "!unerasable"

# Full-analysis call counter.  ``SiraModel`` caches analysis results keyed
# on the graph version; this counter lets tests (and build_flow step
# reports) assert how many *full* range propagations actually ran.
ANALYSIS_CALLS = 0


def analysis_calls() -> int:
    return ANALYSIS_CALLS


def handler(*op_types: str):
    def deco(fn):
        for op in op_types:
            register_op(op, propagate=fn)
        return fn
    return deco


DOMAINS = ("interval", "affine")


class SIRA:
    """Scaled-integer range analysis over a Graph (paper Listing 1).

    ``domain="interval"`` (default) runs the paper's propagation.
    ``domain="affine"`` runs a *reduced product* with the zonotope domain
    of :mod:`repro.core.affine`: the interval handlers see affine-tightened
    inputs and every output is intersected with the affine concretization,
    so affine results are contained in interval results by construction.
    """

    def __init__(self, graph: Graph, domain: str = "interval"):
        if domain not in DOMAINS:
            raise ValueError(f"unknown domain {domain!r}; "
                             f"expected one of {DOMAINS}")
        self.graph = graph
        self.domain = domain

    def run(self, input_ranges: Dict[str, ScaledIntRange],
            record: Optional[Dict[str, RangeProvenance]] = None
            ) -> Dict[str, ScaledIntRange]:
        global ANALYSIS_CALLS
        ANALYSIS_CALLS += 1
        with get_tracer().span("analysis:propagate", domain=self.domain,
                               nodes=len(self.graph.nodes),
                               provenance=record is not None):
            return self._run(input_ranges, record)

    def _run(self, input_ranges: Dict[str, ScaledIntRange],
             record: Optional[Dict[str, RangeProvenance]]
             ) -> Dict[str, ScaledIntRange]:
        affine = self.domain == "affine"
        if affine:
            from .affine import affine_step, seed_forms
            forms = seed_forms(self.graph, input_ranges)
        ranges: Dict[str, ScaledIntRange] = {}
        for name, val in self.graph.initializers.items():
            ranges[name] = ScaledIntRange.point(val)
            if record is not None:
                record[name] = _seed_record(name, "const", ranges[name],
                                            self.domain)
        for name, r in input_ranges.items():
            ranges[name] = r
            if record is not None:
                record[name] = _seed_record(name, "input", r, self.domain)
        missing = [i for i in self.graph.inputs if i not in ranges]
        if missing:
            raise ValueError(f"missing input ranges for {missing}")
        self.graph.toposort()
        for node in self.graph.nodes:
            fn = PROP_REGISTRY.get(node.op_type)
            if fn is None:
                raise NotImplementedError(
                    f"no SIRA handler for op {node.op_type}")
            in_ranges = [ranges[i] for i in node.inputs]
            outs = fn(node, self.graph, in_ranges)
            if not isinstance(outs, tuple):
                outs = (outs,)
            tightened = [False] * len(outs)
            if affine:
                pre = outs
                outs = tuple(affine_step(node, self.graph, forms,
                                         in_ranges, outs))
                if record is not None:
                    tightened = [_width(a) < _width(b)
                                 for a, b in zip(outs, pre)]
            for i, (name, r) in enumerate(zip(node.outputs, outs)):
                ranges[name] = r
                if record is not None:
                    record[name] = _node_record(
                        name, node, self.graph, fn, in_ranges, r,
                        self.domain, tightened[i])
        return ranges


def analyze(graph: Graph, input_ranges: Dict[str, ScaledIntRange],
            domain: str = "interval",
            record: Optional[Dict[str, RangeProvenance]] = None
            ) -> Dict[str, ScaledIntRange]:
    return SIRA(graph, domain=domain).run(input_ranges, record=record)


# --------------------------------------------------------------------------
# provenance recording (repro.obs.explain)
# --------------------------------------------------------------------------

def _width(r: ScaledIntRange) -> float:
    if r.is_point:
        return 0.0
    return float(np.max(np.asarray(r.hi) - np.asarray(r.lo)))


def _range_str(r: ScaledIntRange) -> str:
    lo, hi = float(np.min(r.lo)), float(np.max(r.hi))
    return f"[{lo:g}, {hi:g}]"


def _bits(r: ScaledIntRange) -> Optional[int]:
    return int(r.required_signed_bits()) if r.is_scaled_int else None


def _seed_record(name: str, kind: str, r: ScaledIntRange,
                 domain: str) -> RangeProvenance:
    return RangeProvenance(
        tensor=name, node_name="", op_type=kind, handler=kind,
        domain=domain, affine_tightened=False, inputs=(), culprit=None,
        width=_width(r), in_widths={}, bits=_bits(r),
        range_str=_range_str(r))


def _node_record(name: str, node: Node, graph: Graph, fn,
                 in_ranges: List[ScaledIntRange], r: ScaledIntRange,
                 domain: str, tightened: bool) -> RangeProvenance:
    in_widths: Dict[str, float] = {}
    for t, ir in zip(node.inputs, in_ranges):
        if not graph.is_constant(t):
            in_widths[t] = _width(ir)
    culprit = max(in_widths, key=in_widths.__getitem__, default=None) \
        if in_widths else None
    return RangeProvenance(
        tensor=name, node_name=node.name, op_type=node.op_type,
        handler=getattr(fn, "__name__", str(fn)), domain=domain,
        affine_tightened=tightened, inputs=tuple(in_widths),
        culprit=culprit, width=_width(r), in_widths=in_widths,
        bits=_bits(r), range_str=_range_str(r))


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def _const_val(r: ScaledIntRange) -> Array:
    return r.lo


def _is_scalar(a: Optional[Array]) -> bool:
    return a is not None and np.size(a) == 1


def _contrib_name(graph: Graph, node: Node, idx: int) -> frozenset:
    """Contribution id of a node input: its initializer name if constant."""
    t = node.inputs[idx]
    return frozenset({t}) if graph.is_constant(t) else frozenset({POISON})


# --------------------------------------------------------------------------
# Quant (§3.2.1)
# --------------------------------------------------------------------------

@handler("Quant")
def _prop_quant(node: Node, graph: Graph, rs: List[ScaledIntRange]):
    rx, rs_scale, rs_zp, rs_bits = rs
    s = _const_val(rs_scale)
    z = _const_val(rs_zp)
    b = int(_const_val(rs_bits))
    signed = bool(node.attrs.get("signed", 1))
    narrow = bool(node.attrs.get("narrow", 0))
    qmin, qmax = quant_bounds(b, signed, narrow)
    q_lo = np.clip(round_half_to_even(rx.lo / s + z), qmin, qmax)
    q_hi = np.clip(round_half_to_even(rx.hi / s + z), qmin, qmax)
    # output = s * (q - z) = s * q + (-s * z).  A trivial quantizer
    # (s=1, z=0) anchors a fresh integer range with no contributors; a
    # non-trivial one cannot be erased by constant substitution (the
    # streamliner explicitizes it first), so mark POISON.
    if np.all(s == 1.0) and np.all(z == 0.0):
        scale_src = bias_src = frozenset()
    else:
        scale_src = frozenset({POISON})
        bias_src = frozenset({POISON}) if np.any(z != 0) else frozenset()
    return ScaledIntRange.from_scaled_int(
        q_lo, q_hi, s, -s * z, scale_src=scale_src, bias_src=bias_src)


@handler("MultiThreshold")
def _prop_multithreshold(node: Node, graph: Graph, rs: List[ScaledIntRange]):
    rx, rthr = rs
    thr = _const_val(rthr)  # (C, N)
    axis = int(node.attrs.get("axis", -1))
    # scalar attrs stay 0-d (downstream consumers call float(r.scale));
    # per-channel arrays become (C,)
    out_scale = np.asarray(node.attrs.get("out_scale", 1.0), np.float64)
    out_bias = np.asarray(node.attrs.get("out_bias", 0.0), np.float64)
    out_scale = out_scale.reshape(()) if out_scale.size == 1 \
        else out_scale.reshape(-1)
    out_bias = out_bias.reshape(()) if out_bias.size == 1 \
        else out_bias.reshape(-1)
    C, N = thr.shape
    # reduce range to per-channel: take channel-hull of lo/hi
    lo_c = np.full((C,), float(np.min(rx.lo)))
    hi_c = np.full((C,), float(np.max(rx.hi)))
    if rx.lo.shape == (C,):
        lo_c, hi_c = rx.lo, rx.hi
    cnt_lo = (lo_c[:, None] >= thr).sum(axis=-1).astype(np.float64)
    cnt_hi = (hi_c[:, None] >= thr).sum(axis=-1).astype(np.float64)
    # certified-decreasing channels carry a negative out_scale; fold the
    # sign into the integer component (out = b + |s| * (sign(s) * cnt)) so
    # the scaled-int invariant (scale > 0) holds
    scale = np.asarray(out_scale, np.float64)
    if np.any(scale <= 0):
        if np.any(scale == 0):
            lo = out_bias + np.minimum(scale * cnt_lo, scale * cnt_hi)
            hi = out_bias + np.maximum(scale * cnt_lo, scale * cnt_hi)
            return ScaledIntRange(lo=lo, hi=hi)
        neg = scale < 0
        cnt_lo, cnt_hi = (np.where(neg, -cnt_hi, cnt_lo),
                          np.where(neg, -cnt_lo, cnt_hi))
        scale = np.abs(scale)
    return ScaledIntRange.from_scaled_int(
        cnt_lo, cnt_hi, scale, np.asarray(out_bias))


# --------------------------------------------------------------------------
# Add / Sub (§3.2.2) and Mul / Div (§3.2.3)
# --------------------------------------------------------------------------

def _prop_add_like(node, graph, r0, r1, sign1, src1):
    """out = r0 + sign1 * r1."""
    lo1, hi1 = (sign1 * r1.hi, sign1 * r1.lo) if sign1 < 0 else (
        sign1 * r1.lo, sign1 * r1.hi)
    lo, hi = add_intervals(r0.lo, r0.hi, lo1, hi1)
    # case 1: r0 scaled-int, r1 constant → absorb into bias
    if r0.is_scaled_int and r1.is_point:
        return ScaledIntRange.from_scaled_int(
            np.broadcast_to(r0.int_lo, np.broadcast(r0.int_lo, lo1).shape),
            np.broadcast_to(r0.int_hi, np.broadcast(r0.int_hi, hi1).shape),
            r0.scale, r0.bias + sign1 * _const_val(r1),
            scale_src=r0.scale_src, bias_src=r0.bias_src | src1)
    # case 2: both scaled-int with integer scale ratio (Add direction only)
    if sign1 > 0 and r0.is_scaled_int and r1.is_scaled_int and \
            _is_scalar(r0.scale) and _is_scalar(r1.scale):
        k = float(r1.scale) / float(r0.scale)
        if abs(k - round(k)) < 1e-9 and round(k) != 0:
            k = round(k)
            q_lo = r0.int_lo + k * r1.int_lo
            q_hi = r0.int_hi + k * r1.int_hi
            poison = frozenset() if k == 1 else frozenset({POISON})
            return ScaledIntRange.from_scaled_int(
                q_lo, q_hi, r0.scale, r0.bias + r1.bias,
                scale_src=r0.scale_src | r1.scale_src | poison,
                bias_src=r0.bias_src | r1.bias_src | poison)
    return ScaledIntRange(lo=lo, hi=hi)


@handler("Add")
def _prop_add(node: Node, graph: Graph, rs: List[ScaledIntRange]):
    r0, r1 = rs
    if r0.is_point and not r1.is_point:
        r0, r1 = r1, r0
        src1 = _contrib_name(graph, node, 0)
    else:
        src1 = _contrib_name(graph, node, 1)
    return _prop_add_like(node, graph, r0, r1, +1, src1)


@handler("Sub")
def _prop_sub(node: Node, graph: Graph, rs: List[ScaledIntRange]):
    r0, r1 = rs
    if r1.is_point:
        return _prop_add_like(node, graph, r0, r1, -1,
                              _contrib_name(graph, node, 1))
    lo, hi = add_intervals(r0.lo, r0.hi, -r1.hi, -r1.lo)
    return ScaledIntRange(lo=lo, hi=hi)


def _prop_mul_like(node, graph, r0, r1, invert, src1):
    c = _const_val(r1) if r1.is_point else None
    if invert and c is not None:
        c = 1.0 / c
    # scaled-int survives multiplication by a strictly positive constant
    # (paper §3.2.3; the constant need not be an integer).  Negative or
    # mixed-sign constants fall back to a plain interval.
    if c is not None and r0.is_scaled_int and np.all(c > 0):
        return ScaledIntRange.from_scaled_int(
            r0.int_lo, r0.int_hi, r0.scale * c, r0.bias * c,
            scale_src=r0.scale_src | src1,
            bias_src=(r0.bias_src | src1) if np.any(r0.bias != 0)
            else r0.bias_src)
    if c is not None:
        lo, hi = mul_intervals(r0.lo, r0.hi, c, c)
        return ScaledIntRange(lo=lo, hi=hi)
    lo, hi = mul_intervals(r0.lo, r0.hi, r1.lo, r1.hi)
    return ScaledIntRange(lo=lo, hi=hi)


@handler("Mul")
def _prop_mul(node: Node, graph: Graph, rs: List[ScaledIntRange]):
    r0, r1 = rs
    if r0.is_point and not r1.is_point:
        r0, r1 = r1, r0
        src1 = _contrib_name(graph, node, 0)
    else:
        src1 = _contrib_name(graph, node, 1)
    return _prop_mul_like(node, graph, r0, r1, False, src1)


@handler("Div")
def _prop_div(node: Node, graph: Graph, rs: List[ScaledIntRange]):
    r0, r1 = rs
    if not r1.is_point:
        raise NotImplementedError("Div by dynamic tensor not supported")
    return _prop_mul_like(node, graph, r0, r1, True,
                          _contrib_name(graph, node, 1))


# --------------------------------------------------------------------------
# MatMul / Gemm / Conv (§3.2.4)
# --------------------------------------------------------------------------

def _matmul_ranges(rw: ScaledIntRange, rx: ScaledIntRange, K: int):
    """Y = X @ W with W (K, M) constant. Returns ScaledIntRange for Y."""
    W = _const_val(rw)
    x_lo = np.broadcast_to(rx.lo, (K,)) if rx.lo.shape != (K,) else rx.lo
    x_hi = np.broadcast_to(rx.hi, (K,)) if rx.hi.shape != (K,) else rx.hi
    lo, hi = dot_interval(W, x_lo, x_hi)

    can_si = (
        rx.is_scaled_int and rw.is_scaled_int
        and _is_scalar(rx.scale)                       # per-tensor input scale
        and np.all(rw.bias == 0)                       # zero weight bias
        and (np.size(rw.scale) == 1 or
             bool(np.all(np.broadcast_to(rw.scale, W.shape) ==
                         np.broadcast_to(rw.scale, W.shape)[0])))
        # weight scale at most per-output-channel (constant down each column)
    )
    if not can_si:
        return ScaledIntRange(lo=lo, hi=hi)

    qW = rw.int_lo  # point
    qx_lo = np.broadcast_to(rx.int_lo, (K,)) if rx.int_lo.shape != (K,) \
        else rx.int_lo
    qx_hi = np.broadcast_to(rx.int_hi, (K,)) if rx.int_hi.shape != (K,) \
        else rx.int_hi
    q_lo, q_hi = dot_interval(qW, qx_lo, qx_hi)
    sW = np.broadcast_to(rw.scale, W.shape)[0]          # (M,)
    s_Y = float(rx.scale) * sW
    b_x = np.broadcast_to(rx.bias, (K,))
    b_Y = b_x @ W                                        # (M,)
    return ScaledIntRange.from_scaled_int(
        q_lo, q_hi, s_Y, b_Y,
        scale_src=rx.scale_src | rw.scale_src,
        bias_src=rx.bias_src | rw.scale_src,  # b_Y = W·b_x includes s_W
    )


@handler("MatMul")
def _prop_matmul(node: Node, graph: Graph, rs: List[ScaledIntRange]):
    rx, rw = rs
    if rw.is_point and not rx.is_point:
        K = _const_val(rw).shape[0]
        return _matmul_ranges(rw, rx, K)
    if rx.is_point and not rw.is_point:
        # constant @ dynamic: transpose the problem
        W = _const_val(rx)            # (M, K)
        K = W.shape[-1]
        x_lo = np.broadcast_to(rw.lo, (K,)) if rw.lo.shape != (K,) else rw.lo
        x_hi = np.broadcast_to(rw.hi, (K,)) if rw.hi.shape != (K,) else rw.hi
        lo, hi = dot_interval(W.T, x_lo, x_hi)
        return ScaledIntRange(lo=lo, hi=hi)
    # dynamic x dynamic (attention): conservative hull, per-tensor
    lo0, hi0 = float(np.min(rs[0].lo)), float(np.max(rs[0].hi))
    lo1, hi1 = float(np.min(rs[1].lo)), float(np.max(rs[1].hi))
    K = int(node.attrs.get("contract_dim", 1))
    p_lo, p_hi = mul_intervals(np.asarray(lo0), np.asarray(hi0),
                               np.asarray(lo1), np.asarray(hi1))
    return ScaledIntRange(lo=K * p_lo, hi=K * p_hi)


@handler("Gemm")
def _prop_gemm(node: Node, graph: Graph, rs: List[ScaledIntRange]):
    y = _prop_matmul(node, graph, rs[:2])
    if len(rs) == 3:
        return _prop_add_like(node, graph, y, rs[2], +1,
                              _contrib_name(graph, node, 2))
    return y


@handler("Conv")
def _prop_conv(node: Node, graph: Graph, rs: List[ScaledIntRange]):
    rx, rw = rs[0], rs[1]
    rb = rs[2] if len(rs) > 2 else None
    W = _const_val(rw)                       # (Cout, Cin_g, kh, kw)
    cout, cin_g, kh, kw = W.shape
    groups = int(node.attrs.get("groups", 1))
    pad = int(node.attrs.get("pad", 0))
    cin = cin_g * groups
    depthwise = (groups == cin and cin_g == 1)

    def chan(a, n_ch):
        """reduce a broadcastable range array to per-channel (C,) values"""
        a = np.asarray(a, dtype=np.float64)
        if a.ndim >= 3 and a.shape[-3] == n_ch:
            return a.reshape(-1, n_ch, *a.shape[-2:]).max(axis=(0, 2, 3)) \
                if False else a.mean(axis=tuple(
                    i for i in range(a.ndim) if i != a.ndim - 3)) * 0 + \
                a.max(axis=tuple(i for i in range(a.ndim) if i != a.ndim - 3))
        return np.full((n_ch,), float(np.max(a)))

    # per-input-channel bounds (hull over spatial dims).  Zero-padding
    # feeds literal zeros into border taps, so padded convs must widen the
    # input interval to include 0 — otherwise a channel whose range sits
    # strictly above (or below) zero gets an unsound output bound.
    x_lo_c = -chan(-rx.lo, cin)
    x_hi_c = chan(rx.hi, cin)
    if pad:
        x_lo_c = np.minimum(x_lo_c, 0.0)
        x_hi_c = np.maximum(x_hi_c, 0.0)

    Wmat = W.reshape(cout, cin_g * kh * kw)
    if depthwise:
        wv = W.reshape(cout, kh * kw)
        y_c = ((x_hi_c + x_lo_c) * 0.5)[:, None] * wv
        y_r = ((x_hi_c - x_lo_c) * 0.5)[:, None] * np.abs(wv)
        lo = (y_c - y_r).sum(-1).reshape(cout, 1, 1)
        hi = (y_c + y_r).sum(-1).reshape(cout, 1, 1)
    else:
        outs_lo, outs_hi = [], []
        for g in range(groups):
            xg_lo = np.repeat(x_lo_c[g * cin_g:(g + 1) * cin_g], kh * kw)
            xg_hi = np.repeat(x_hi_c[g * cin_g:(g + 1) * cin_g], kh * kw)
            Wg = Wmat[g * (cout // groups):(g + 1) * (cout // groups)]
            l, h = dot_interval(Wg.T, xg_lo, xg_hi)
            outs_lo.append(l)
            outs_hi.append(h)
        lo = np.concatenate(outs_lo).reshape(cout, 1, 1)
        hi = np.concatenate(outs_hi).reshape(cout, 1, 1)

    # scaled-int propagation conditions (§3.2.4)
    sx_scalar = _is_scalar(rx.scale)
    sx_chan = (rx.is_scaled_int and rx.scale is not None and
               np.size(rx.scale) == cin)
    sw_ok = rw.is_scaled_int and np.all(rw.bias == 0)
    # padded zeros map to integer 0 only when the input bias is zero
    # (x = s*q + b, pad value x=0 ⇒ q=0 iff b=0)
    pad_ok = (pad == 0) or bool(np.all(np.asarray(rx.bias) == 0))
    can_si = rx.is_scaled_int and sw_ok and pad_ok and (
        sx_scalar or (depthwise and sx_chan))
    out = None
    if can_si:
        qW = rw.int_lo
        qx_lo_c = -chan(-rx.int_lo, cin)
        qx_hi_c = chan(rx.int_hi, cin)
        if pad:
            qx_lo_c = np.minimum(qx_lo_c, 0.0)
            qx_hi_c = np.maximum(qx_hi_c, 0.0)
        sW = np.broadcast_to(rw.scale, W.shape).reshape(cout, -1)[:, 0]
        if depthwise:
            wv = qW.reshape(cout, kh * kw)
            y_c = ((qx_hi_c + qx_lo_c) * 0.5)[:, None] * wv
            y_r = ((qx_hi_c - qx_lo_c) * 0.5)[:, None] * np.abs(wv)
            q_lo = (y_c - y_r).sum(-1).reshape(cout, 1, 1)
            q_hi = (y_c + y_r).sum(-1).reshape(cout, 1, 1)
            sx = np.broadcast_to(
                np.asarray(rx.scale).reshape(-1, 1, 1) if sx_chan
                else rx.scale, (cin, 1, 1)).reshape(cin)
            s_Y = (sx * sW).reshape(cout, 1, 1)
        else:
            ql, qh = [], []
            qWmat = qW.reshape(cout, cin_g * kh * kw)
            for g in range(groups):
                xg_lo = np.repeat(qx_lo_c[g * cin_g:(g + 1) * cin_g], kh * kw)
                xg_hi = np.repeat(qx_hi_c[g * cin_g:(g + 1) * cin_g], kh * kw)
                Wg = qWmat[g * (cout // groups):(g + 1) * (cout // groups)]
                l, h = dot_interval(Wg.T, xg_lo, xg_hi)
                ql.append(l)
                qh.append(h)
            q_lo = np.concatenate(ql).reshape(cout, 1, 1)
            q_hi = np.concatenate(qh).reshape(cout, 1, 1)
            s_Y = (float(rx.scale) * sW).reshape(cout, 1, 1)
        b_x_c = np.broadcast_to(rx.bias, (cin,)) if np.size(rx.bias) <= cin \
            else chan(rx.bias, cin)
        b_Y = (Wmat * np.repeat(b_x_c.reshape(groups, cin_g), kh * kw
                                ).reshape(groups, -1).repeat(
            cout // groups, axis=0).reshape(cout, -1)).sum(-1) \
            if groups > 1 else (Wmat @ np.repeat(b_x_c, kh * kw))
        b_Y = np.asarray(b_Y).reshape(cout, 1, 1)
        out = ScaledIntRange.from_scaled_int(
            q_lo, q_hi, s_Y, b_Y,
            scale_src=rx.scale_src | rw.scale_src,
            bias_src=rx.bias_src | rw.scale_src)
    if out is None:
        out = ScaledIntRange(lo=lo, hi=hi)
    if rb is not None:
        out = _prop_add_like(node, graph, out,
                             ScaledIntRange.point(
                                 _const_val(rb).reshape(cout, 1, 1)),
                             +1, _contrib_name(graph, node, 2))
    return out


# --------------------------------------------------------------------------
# elementwise monotonic / unimodal / value-preserving (§2.4.1)
# --------------------------------------------------------------------------

def _mono(fn):
    def prop(node, graph, rs):
        lo, hi = monotonic_fn_interval(fn, rs[0].lo, rs[0].hi)
        return ScaledIntRange(lo=lo, hi=hi)
    return prop


PROP_REGISTRY["Sigmoid"] = _mono(lambda x: 1.0 / (1.0 + np.exp(-x)))
PROP_REGISTRY["Tanh"] = _mono(np.tanh)
PROP_REGISTRY["Floor"] = _mono(np.floor)
PROP_REGISTRY["Round"] = _mono(round_half_to_even)


@handler("Softcap")
def _prop_softcap(node: Node, graph: Graph, rs: List[ScaledIntRange]):
    cap = float(node.attrs["cap"])
    lo, hi = monotonic_fn_interval(lambda x: cap * np.tanh(x / cap),
                                   rs[0].lo, rs[0].hi)
    return ScaledIntRange(lo=lo, hi=hi)


@handler("Relu")
def _prop_relu(node: Node, graph: Graph, rs: List[ScaledIntRange]):
    return ScaledIntRange(lo=np.maximum(rs[0].lo, 0.0),
                          hi=np.maximum(rs[0].hi, 0.0))


def _unimodal(fn, x_star: float):
    """Elementwise function decreasing before x_star, increasing after."""
    def prop(node, graph, rs):
        lo, hi = rs[0].lo, rs[0].hi
        f_lo, f_hi = fn(lo), fn(hi)
        out_hi = np.maximum(f_lo, f_hi)
        out_lo = np.minimum(f_lo, f_hi)
        inside = (lo <= x_star) & (x_star <= hi)
        out_lo = np.where(inside, fn(np.asarray(x_star)), out_lo)
        return ScaledIntRange(lo=out_lo, hi=out_hi)
    return prop


def _gelu(x):
    from scipy.special import erf
    return 0.5 * x * (1.0 + erf(x / np.sqrt(2.0)))


def _hardswish(x):
    return x * np.clip(x + 3.0, 0.0, 6.0) / 6.0


PROP_REGISTRY["Silu"] = _unimodal(lambda x: x / (1.0 + np.exp(-x)),
                                  -1.2784645)
PROP_REGISTRY["Gelu"] = _unimodal(_gelu, -0.75179)
PROP_REGISTRY["HardSwish"] = _unimodal(_hardswish, -1.5)
PROP_REGISTRY["Abs"] = _unimodal(np.abs, 0.0)


@handler("Clip")
def _prop_clip(node: Node, graph: Graph, rs: List[ScaledIntRange]):
    lo_c = _const_val(rs[1]) if len(rs) > 1 else -np.inf
    hi_c = _const_val(rs[2]) if len(rs) > 2 else np.inf
    return ScaledIntRange(lo=np.clip(rs[0].lo, lo_c, hi_c),
                          hi=np.clip(rs[0].hi, lo_c, hi_c))


def _value_preserving(node, graph, rs):
    """Ops whose outputs are a subset/permutation of input values — range
    and scaled-int structure survive.  Per-tensor (scalar) scale/bias pass
    through exactly; per-channel structure is reduced to its hull because
    the channel axis may move."""
    r = rs[0]
    if r.is_scaled_int and _is_scalar(r.scale) and _is_scalar(r.bias):
        return ScaledIntRange.from_scaled_int(
            np.min(r.int_lo), np.max(r.int_hi), r.scale, r.bias,
            scale_src=r.scale_src, bias_src=r.bias_src)
    return ScaledIntRange(lo=np.min(r.lo), hi=np.max(r.hi))


for op in ["Identity", "Reshape", "Transpose", "Flatten", "Pad"]:
    PROP_REGISTRY[op] = _value_preserving


@handler("MaxPool")
def _prop_maxpool(node: Node, graph: Graph, rs: List[ScaledIntRange]):
    return rs[0]  # value-preserving per channel


@handler("AveragePool")
def _prop_avgpool(node: Node, graph: Graph, rs: List[ScaledIntRange]):
    r = rs[0]
    k = int(node.attrs.get("kernel", 2))
    n = k * k
    if r.is_scaled_int:
        return ScaledIntRange.from_scaled_int(
            r.int_lo * n, r.int_hi * n, r.scale / n, r.bias,
            scale_src=r.scale_src | frozenset({POISON}),
            bias_src=r.bias_src)
    return ScaledIntRange(lo=r.lo, hi=r.hi)


@handler("GlobalAveragePool")
def _prop_gap(node: Node, graph: Graph, rs: List[ScaledIntRange]):
    r = rs[0]
    n = int(node.attrs.get("window", 1))
    if r.is_scaled_int and n > 1:
        return ScaledIntRange.from_scaled_int(
            r.int_lo * n, r.int_hi * n, r.scale / n, r.bias,
            scale_src=r.scale_src | frozenset({POISON}),
            bias_src=r.bias_src)
    return r


@handler("Concat")
def _prop_concat(node: Node, graph: Graph, rs: List[ScaledIntRange]):
    lo = np.min([np.min(r.lo) for r in rs])
    hi = np.max([np.max(r.hi) for r in rs])
    all_si = all(r.is_scaled_int and _is_scalar(r.scale) and
                 _is_scalar(r.bias) for r in rs)
    if all_si:
        s0, b0 = float(rs[0].scale), float(rs[0].bias)
        if all(abs(float(r.scale) - s0) < 1e-12 and
               abs(float(r.bias) - b0) < 1e-12 for r in rs):
            return ScaledIntRange.from_scaled_int(
                np.min([np.min(r.int_lo) for r in rs]),
                np.max([np.max(r.int_hi) for r in rs]), s0, b0,
                scale_src=frozenset().union(*[r.scale_src for r in rs]),
                bias_src=frozenset().union(*[r.bias_src for r in rs]))
    return ScaledIntRange(lo=lo, hi=hi)


@handler("Gather")
def _prop_gather(node: Node, graph: Graph, rs: List[ScaledIntRange]):
    table = rs[0]
    if table.is_point:
        v = _const_val(table)
        if table.is_scaled_int and _is_scalar(table.scale) and \
                _is_scalar(table.bias):
            q = table.int_lo
            return ScaledIntRange.from_scaled_int(
                np.min(q), np.max(q), table.scale, table.bias,
                scale_src=table.scale_src, bias_src=table.bias_src)
        return ScaledIntRange(lo=np.min(v), hi=np.max(v))
    return ScaledIntRange(lo=np.min(table.lo), hi=np.max(table.hi))


@handler("Softmax")
def _prop_softmax(node: Node, graph: Graph, rs: List[ScaledIntRange]):
    return ScaledIntRange(lo=np.zeros(()), hi=np.ones(()))
