"""Affine-form (zonotope) abstract domain layered on ``ScaledIntRange``.

The plain interval domain in :mod:`repro.core.propagate` forgets every
correlation between tensors: ``x - x`` analyzes to a symmetric interval of
twice the input width, residual adds compound both branch widths, and
per-channel structure is collapsed to a global hull at several handlers.
This module adds a second, still-sound domain where each tensor carries an
**affine form**

    x  =  center  +  sum_s  coeff_s * eps_s,        eps_s in [-1, 1]

with named noise symbols ``s``.  Linear ops combine coefficients symbol by
symbol, so correlated terms *cancel* instead of compounding.

Noise-symbol convention
-----------------------
The analysis is shape-polymorphic (range arrays are broadcastable to the
tensor shape, never the concrete shape itself), so a noise symbol here
names an **elementwise-independent noise array** of its anchor tensor's
shape: two tensors referring to the same symbol see the *same* noise
values elementwise, and coefficient arrays broadcast against each other.
Consequences:

* elementwise linear ops (Add/Sub/Mul-by-const/Div-by-const) are exact;
  ``x - x`` has zero width;
* ops that **mix elements** (MatMul/Conv contractions, pooling, shape
  moves with non-scalar coefficients) cannot keep the symbol: the result
  is re-anchored with a fresh symbol whose per-element radius is the
  exact box hull of the mixed term — sound and elementwise-exact, but the
  cross-element correlation is dropped (the documented degeneration to
  interval precision);
* nonlinear elementwise ops (ReLU, MultiThreshold, Quant, dynamic Mul)
  use a sound linearization: scaled input terms plus a fresh symbol
  covering the linearization error.

Integration: :class:`repro.core.propagate.SIRA` runs this domain as a
*reduced product* with the interval domain (``domain="affine"``) — every
interval handler sees affine-tightened inputs, every output range is
intersected with the affine concretization (:func:`tighten_range`), so
affine results are contained in interval results **by construction**.
Ops without an affine rule in ``AFFINE_REGISTRY`` fall back to a fresh
form over their (tightened) interval output.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .graph import Graph, Node
from .intervals import Array, InvalidRangeError, ScaledIntRange
from .ops import AFFINE_REGISTRY, register_op

_sym_counter = itertools.count()


def fresh_symbol(prefix: str = "eps") -> str:
    return f"{prefix}#{next(_sym_counter)}"


class AffineForm:
    """``center + sum_s coeff_s * eps_s`` with numpy-array coefficients."""

    __slots__ = ("center", "terms")

    def __init__(self, center, terms: Optional[Dict[str, Array]] = None):
        self.center: Array = np.asarray(center, dtype=np.float64)
        self.terms: Dict[str, Array] = {}
        for s, c in (terms or {}).items():
            c = np.asarray(c, dtype=np.float64)
            if np.any(c != 0.0):
                self.terms[s] = c

    # -------------------------------------------------------- construction
    @staticmethod
    def point(value) -> "AffineForm":
        return AffineForm(value)

    @staticmethod
    def from_interval(lo, hi, symbol: Optional[str] = None) -> "AffineForm":
        lo = np.asarray(lo, dtype=np.float64)
        hi = np.asarray(hi, dtype=np.float64)
        center = (lo + hi) * 0.5
        rad = (hi - lo) * 0.5
        if np.all(rad == 0.0):
            return AffineForm(center)
        return AffineForm(center, {symbol or fresh_symbol(): rad})

    @staticmethod
    def from_range(r: ScaledIntRange,
                   symbol: Optional[str] = None) -> "AffineForm":
        return AffineForm.from_interval(r.lo, r.hi, symbol)

    # ------------------------------------------------------ concretization
    def radius(self) -> Array:
        rad: Array = np.zeros(())
        for c in self.terms.values():
            rad = rad + np.abs(c)
        return rad

    def concretize(self) -> Tuple[Array, Array]:
        rad = self.radius()
        return self.center - rad, self.center + rad

    @property
    def is_point(self) -> bool:
        return not self.terms

    # ------------------------------------------------------ linear algebra
    def __add__(self, other) -> "AffineForm":
        if not isinstance(other, AffineForm):
            return AffineForm(self.center + np.asarray(other, np.float64),
                              self.terms)
        terms = dict(self.terms)
        for s, c in other.terms.items():
            terms[s] = terms[s] + c if s in terms else c
        return AffineForm(self.center + other.center, terms)

    def __sub__(self, other) -> "AffineForm":
        if not isinstance(other, AffineForm):
            return AffineForm(self.center - np.asarray(other, np.float64),
                              self.terms)
        return self + other.scale_by(-1.0)

    def scale_by(self, c) -> "AffineForm":
        """Multiply by a constant (array) — exact for any sign."""
        c = np.asarray(c, dtype=np.float64)
        return AffineForm(self.center * c,
                          {s: a * c for s, a in self.terms.items()})

    def affine_map(self, scale, offset, err_radius=None,
                   symbol: Optional[str] = None) -> "AffineForm":
        """``scale * self + offset (+- err_radius)`` — the generic sound
        linearization: scaled input terms plus a fresh error symbol."""
        out = self.scale_by(scale) + np.asarray(offset, np.float64)
        if err_radius is not None and np.any(
                np.asarray(err_radius) != 0.0):
            out.terms[symbol or fresh_symbol()] = np.abs(
                np.asarray(err_radius, np.float64))
        return out

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"AffineForm(center~{np.ravel(self.center)[:3]}, "
                f"{len(self.terms)} terms)")


# --------------------------------------------------------------------------
# interval-range tightening (the domain reduction)
# --------------------------------------------------------------------------

def _combine_bounds(kind: str, a: Array, b: Array) -> Optional[Array]:
    """max/min of two broadcastable bound arrays; ``None`` when the
    broadcast result would not align elementwise with either operand
    (e.g. a (C,) matmul-layout array against a (C,1,1) conv-layout one —
    numpy *would* broadcast them, but to a semantically wrong (C,1,C))."""
    fn = np.maximum if kind == "lo" else np.minimum
    try:
        shape = np.broadcast_shapes(np.shape(a), np.shape(b))
    except ValueError:
        return None
    if shape != np.shape(a) and shape != np.shape(b):
        return None
    return fn(a, b)


def tighten_range(r: ScaledIntRange, a_lo: Array, a_hi: Array
                  ) -> ScaledIntRange:
    """Intersect an interval-domain range with an affine concretization.

    Sound: both are over-approximations of the same value set, so the
    intersection still contains every reachable value.  The scaled-integer
    structure (scale/bias/contribution sets) is preserved; tightening goes
    through the integer grid so ``lo = scale*int_lo + bias`` keeps holding
    exactly.  When bound-array shapes don't align elementwise (different
    broadcast layouts), the affine bounds are clamped against the interval
    *global hull* instead — still sound, since the hull bounds every
    element."""
    a_lo = np.asarray(a_lo, dtype=np.float64)
    a_hi = np.asarray(a_hi, dtype=np.float64)
    if np.any(np.isnan(a_lo)) or np.any(np.isnan(a_hi)):
        return r

    if not r.is_scaled_int:
        lo = _combine_bounds("lo", r.lo, a_lo)
        hi = _combine_bounds("hi", r.hi, a_hi)
        if lo is None or hi is None:
            lo = np.maximum(a_lo, np.min(r.lo))
            hi = np.minimum(a_hi, np.max(r.hi))
        hi = np.maximum(hi, lo)          # guard fp slack at zero width
        return ScaledIntRange(lo=lo, hi=hi)

    # scaled-int: snap the affine bounds outward onto the integer grid
    try:
        q_a_lo = np.ceil((a_lo - r.bias) / r.scale - 1e-9)
        q_a_hi = np.floor((a_hi - r.bias) / r.scale + 1e-9)
    except ValueError:                   # scale/bias don't broadcast
        return r
    int_lo = _combine_bounds("lo", r.int_lo, q_a_lo)
    int_hi = _combine_bounds("hi", r.int_hi, q_a_hi)
    if int_lo is None or int_hi is None:
        int_lo = np.maximum(q_a_lo, np.min(r.int_lo))
        int_hi = np.minimum(q_a_hi, np.max(r.int_hi))
    int_hi = np.maximum(int_hi, int_lo)
    # scale/bias must broadcast INTO the (possibly re-layouted) integer
    # bounds — e.g. a (C,) scale against (C,1,1) tightened bounds would
    # silently mis-broadcast lo to (C,1,C); keep the interval result then
    int_shape = np.shape(int_lo)
    for p in (r.scale, r.bias):
        if p is None:
            continue
        try:
            if np.broadcast_shapes(np.shape(p), int_shape) != int_shape:
                return r
        except ValueError:
            return r
    try:
        return ScaledIntRange.from_scaled_int(
            int_lo, int_hi, r.scale, r.bias,
            scale_src=r.scale_src, bias_src=r.bias_src)
    except (InvalidRangeError, ValueError):
        return r                         # shape mismatch vs scale — keep


# --------------------------------------------------------------------------
# transfer-function registry
# --------------------------------------------------------------------------

def affine_handler(*op_types: str):
    def deco(fn):
        for op in op_types:
            register_op(op, affine=fn)
        return fn
    return deco


def _const_form(r: ScaledIntRange) -> Optional[Array]:
    return r.lo if r.is_point else None


# Add / Sub — exact -------------------------------------------------------

@affine_handler("Add")
def _aff_add(node: Node, graph: Graph, forms: List[AffineForm],
             rs: List[ScaledIntRange]) -> AffineForm:
    return forms[0] + forms[1]


@affine_handler("Sub")
def _aff_sub(node: Node, graph: Graph, forms: List[AffineForm],
             rs: List[ScaledIntRange]) -> AffineForm:
    return forms[0] - forms[1]


# Mul / Div — exact by a constant, linearized otherwise -------------------

@affine_handler("Mul")
def _aff_mul(node: Node, graph: Graph, forms: List[AffineForm],
             rs: List[ScaledIntRange]) -> AffineForm:
    f0, f1 = forms
    c0, c1 = _const_form(rs[0]), _const_form(rs[1])
    if c1 is not None:
        return f0.scale_by(c1)
    if c0 is not None:
        return f1.scale_by(c0)
    # dynamic x dynamic:  x*y = cx*cy + cy*dx + cx*dy + dx*dy,
    # |dx*dy| <= rad(x)*rad(y)  — sound bilinear linearization
    out = f0.scale_by(f1.center) + f1.scale_by(f0.center)
    out = out - f0.center * f1.center
    err = f0.radius() * f1.radius()
    return out.affine_map(1.0, 0.0, err_radius=err,
                          symbol=fresh_symbol(f"mul:{node.name}"))


@affine_handler("Div")
def _aff_div(node: Node, graph: Graph, forms: List[AffineForm],
             rs: List[ScaledIntRange]) -> Optional[AffineForm]:
    c1 = _const_form(rs[1])
    if c1 is None or np.any(c1 == 0.0):
        return None                      # interval fallback
    return forms[0].scale_by(1.0 / c1)


# MatMul / Gemm — constant-weight contraction -----------------------------

def _matmul_form(f: AffineForm, W: Array) -> AffineForm:
    """``x @ W`` with constant W (K, M).  The contraction mixes the K
    elementwise-independent noise entries of every symbol, so the result
    is re-anchored: exact elementwise radius ``|coeff|^T |W|`` under a
    fresh symbol (cross-element correlation is dropped, bounds are the
    exact box hull — identical to ``dot_interval``)."""
    K = W.shape[0]

    def bcast(a: Array) -> Array:
        a = np.asarray(a, dtype=np.float64)
        return np.broadcast_to(a, (K,)) if a.shape != (K,) else a

    center = bcast(f.center) @ W
    rad = np.zeros(W.shape[1])
    for c in f.terms.values():
        rad = rad + np.abs(bcast(c)) @ np.abs(W)
    if np.all(rad == 0.0):
        return AffineForm(center)
    return AffineForm(center, {fresh_symbol("mm"): rad})


@affine_handler("MatMul")
def _aff_matmul(node: Node, graph: Graph, forms: List[AffineForm],
                rs: List[ScaledIntRange]) -> Optional[AffineForm]:
    W1 = _const_form(rs[1])
    if W1 is not None and _const_form(rs[0]) is None:
        return _matmul_form(forms[0], W1)
    W0 = _const_form(rs[0])
    if W0 is not None and _const_form(rs[1]) is None:
        return _matmul_form(forms[1], W0.T)
    return None                          # const@const or dyn@dyn: fallback


@affine_handler("Gemm")
def _aff_gemm(node: Node, graph: Graph, forms: List[AffineForm],
              rs: List[ScaledIntRange]) -> Optional[AffineForm]:
    y = _aff_matmul(node, graph, forms[:2], rs[:2])
    if y is None:
        return None
    if len(forms) == 3:
        y = y + forms[2]
    return y


# ReLU / Clip — min-area linearization keeping scaled input terms ---------

@affine_handler("Relu")
def _aff_relu(node: Node, graph: Graph, forms: List[AffineForm],
              rs: List[ScaledIntRange]) -> AffineForm:
    f = forms[0]
    lo, hi = f.concretize()
    lo = np.minimum(lo, hi)
    # three regimes, handled with elementwise masks:
    #   hi <= 0: output 0;  lo >= 0: identity;  else: y = lam*x + mu +- mu
    # with lam = hi/(hi-lo), mu = -lam*lo/2 (min-area zonotope for ReLU)
    width = hi - lo
    safe = np.where(width > 0, width, 1.0)
    lam = np.where(hi <= 0, 0.0, np.where(lo >= 0, 1.0, hi / safe))
    mu = np.where((hi > 0) & (lo < 0), -lam * lo * 0.5, 0.0)
    # saturated regimes come out exact: lam = mu = 0 zeroes everything
    return f.affine_map(lam, mu, err_radius=mu,
                        symbol=fresh_symbol(f"relu:{node.name}"))


# MultiThreshold — per-channel staircase counting -------------------------

def _per_channel(a: Array, C: int, axis: int, reduce: str) -> Array:
    """Reduce a broadcastable bound array to per-channel ``(C,)`` values.
    ``axis=1`` is the conv layout (channel axis -3 in broadcastable
    terms, e.g. (C,1,1)); anything else is channels-last ((C,))."""
    a = np.asarray(a, dtype=np.float64)
    fn = np.min if reduce == "lo" else np.max
    if axis == 1:
        if a.ndim >= 3 and a.shape[-3] == C:
            red = tuple(i for i in range(a.ndim) if i != a.ndim - 3)
            return fn(a, axis=red) if red else a.reshape(C)
    else:
        if a.ndim >= 1 and a.shape[-1] == C:
            red = tuple(range(a.ndim - 1))
            return fn(a, axis=red) if red else a
    return np.full((C,), float(fn(a)))


@affine_handler("MultiThreshold")
def _aff_multithreshold(node: Node, graph: Graph, forms: List[AffineForm],
                        rs: List[ScaledIntRange]) -> Optional[AffineForm]:
    """Fresh-symbol staircase transfer that, unlike the interval handler,
    keeps **per-channel** structure for conv-layout inputs — counting is
    elementwise-monotone, so per-channel input hulls give exact
    per-channel count bounds."""
    thr = _const_form(rs[1])
    if thr is None or np.asarray(thr).ndim != 2:
        return None
    C = thr.shape[0]
    axis = int(node.attrs.get("axis", -1))
    f_lo, f_hi = forms[0].concretize()
    lo_c = _per_channel(f_lo, C, axis, "lo")
    hi_c = _per_channel(np.maximum(f_lo, f_hi), C, axis, "hi")
    cnt_lo = (lo_c[:, None] >= thr).sum(axis=-1).astype(np.float64)
    cnt_hi = (hi_c[:, None] >= thr).sum(axis=-1).astype(np.float64)
    out_scale = np.asarray(node.attrs.get("out_scale", 1.0), np.float64)
    out_bias = np.asarray(node.attrs.get("out_bias", 0.0), np.float64)
    out_scale = out_scale.reshape(()) if out_scale.size == 1 \
        else out_scale.reshape(-1)
    out_bias = out_bias.reshape(()) if out_bias.size == 1 \
        else out_bias.reshape(-1)
    v_a = out_bias + out_scale * cnt_lo
    v_b = out_bias + out_scale * cnt_hi
    v_lo, v_hi = np.minimum(v_a, v_b), np.maximum(v_a, v_b)
    if axis == 1:                        # conv layout: (C,) -> (C,1,1)
        v_lo = v_lo.reshape(C, 1, 1)
        v_hi = v_hi.reshape(C, 1, 1)
    return AffineForm.from_interval(
        v_lo, v_hi, fresh_symbol(f"thr:{node.name}"))


# Quant — fresh anchor at the (tightened) interval output -----------------
# Registered as an explicit rule (not the generic fallback) so the fresh
# symbol is named after the node: rounding breaks elementwise linearity,
# so the correlation with the input is dropped by design.

@affine_handler("Quant")
def _aff_quant(node: Node, graph: Graph, forms: List[AffineForm],
               rs: List[ScaledIntRange]) -> Optional[AffineForm]:
    return None                          # fresh form over interval output


# wire ops — exact for position-independent (scalar) coefficients ---------

def _aff_wire(node: Node, graph: Graph, forms: List[AffineForm],
              rs: List[ScaledIntRange]) -> Optional[AffineForm]:
    f = forms[0]
    if node.op_type == "Identity":
        return f
    scalars = np.size(f.center) == 1 and all(
        np.size(c) == 1 for c in f.terms.values())
    return f if scalars else None        # element moves: fallback to hull


for _op in ("Identity", "Reshape", "Flatten", "Transpose", "Pad"):
    register_op(_op, affine=_aff_wire)


# --------------------------------------------------------------------------
# the reduced-product driver (called from propagate.SIRA)
# --------------------------------------------------------------------------

def affine_step(node: Node, graph: Graph,
                forms: Dict[str, AffineForm],
                in_ranges: List[ScaledIntRange],
                out_ranges: Sequence[ScaledIntRange]
                ) -> List[ScaledIntRange]:
    """One node of the reduced product: run the affine transfer (if any),
    intersect with the interval outputs, and record output forms.
    Returns the tightened ranges, positionally matching ``node.outputs``."""
    fn = AFFINE_REGISTRY.get(node.op_type)
    in_forms = [forms[t] for t in node.inputs]
    a_outs: Optional[Tuple] = None
    if fn is not None:
        res = fn(node, graph, in_forms, in_ranges)
        if res is not None:
            a_outs = res if isinstance(res, tuple) else (res,)
    tightened: List[ScaledIntRange] = []
    for i, (name, r) in enumerate(zip(node.outputs, out_ranges)):
        form = a_outs[i] if a_outs is not None and i < len(a_outs) \
            and a_outs[i] is not None else None
        if form is None:
            tightened.append(r)
            forms[name] = AffineForm.from_range(r, fresh_symbol(name))
            continue
        a_lo, a_hi = form.concretize()
        r2 = tighten_range(r, a_lo, a_hi)
        tightened.append(r2)
        forms[name] = form
    return tightened


def seed_forms(graph: Graph,
               input_ranges: Dict[str, ScaledIntRange]
               ) -> Dict[str, AffineForm]:
    forms: Dict[str, AffineForm] = {}
    for name, val in graph.initializers.items():
        forms[name] = AffineForm.point(np.asarray(val, np.float64))
    for name, r in input_ranges.items():
        forms[name] = AffineForm.from_range(r, f"in:{name}")
    return forms
