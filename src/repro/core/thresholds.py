"""Converting quantized layer tails to thresholds (paper §4.1.3, Fig 11).

The multi-threshold function

    f_T(x) = out_bias + out_scale * sum_i (x >= T_i)

replaces an entire *layer tail*: the chain of elementwise ops (aggregated
scale/bias, activation) terminating in a uniform quantizer.  We implement
the paper's extraction — evaluate the tail subgraph end-to-end over the
SIRA-provided integer input range and pick up the steps with an
edge-detection convolution — plus a beyond-paper *bisection* extractor that
finds each threshold by binary search (O(N log R) instead of O(R) subgraph
evaluations), used automatically for wide accumulator ranges.

Exactness contract (Eq. 3): for integer inputs within the SIRA range, the
MultiThreshold output equals the original tail output exactly.  That only
holds when the (quantized) tail is monotone per channel, so every
extraction is gated on a :class:`~repro.core.monotone.MonotoneCertificate`:

  * certified ``monotone`` / ``representable`` tails convert — increasing
    channels exactly as before, decreasing channels via direction-aware
    enumeration / descending bisection with a negated per-channel
    ``out_scale`` (out = b - s * count of thresholds passed);
  * ``uncertified`` tails are left in place, annotated with the
    certificate's machine-readable reason code so the dataflow DSE prices
    the elementwise meta-kernel instead.

Tail entry points may be *scaled* integer tensors (``x = s·q + b`` with
``s > 0`` per SIRA's scaled-int invariant), not just raw accumulators:
non-homogeneous activations (Silu, Tanh, hard-swish) block the
streamliner from pushing quantizer scales past the next matmul, so their
tails begin at a scaled tensor.  Thresholds are then extracted on the
integer grid ``q`` and emitted in real units at grid *midpoints*
(``s·(T - ½) + b``), which keeps the integer comparison exact under
floating-point accumulation noise.

Note on Eq. 2: the paper's sign-bias expression has an off-by-one typo; we
use ``out_bias = qmin`` (the count runs over N = qmax - qmin thresholds),
which is exact for signed/unsigned and narrow/wide ranges alike.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple, Union

import numpy as np

from .graph import Graph, Node, fresh_name, quant_bounds
from .intervals import ScaledIntRange
from .propagate import analyze
from . import monotone as _monotone

# elementwise ops allowed inside a layer tail (dynamic input at slot 0,
# other inputs constant)
TAIL_ELEMENTWISE = {"Mul", "Add", "Sub", "Div", "Relu", "Sigmoid", "Tanh",
                    "Softcap", "Silu", "Gelu", "HardSwish", "Abs", "Clip",
                    "Identity"}

# enumeration cutoff: above this range size, use bisection extraction
EDGE_DETECT_MAX_RANGE = 1 << 16


class ThresholdConversionError(ValueError):
    """A layer tail cannot be exactly converted to thresholds.

    ``reason`` is a machine-readable code (``nonmonotone-on-grid``,
    ``grid-too-large:<R>``, ``no-monotone-rule:<Op>``,
    ``quantizer-granularity``, ``entry-granularity``,
    ``nonmonotone-evaluation``, ...) that ends up on the unconverted
    nodes for the dataflow DSE to consume."""

    def __init__(self, reason: str, message: Optional[str] = None):
        super().__init__(message or reason)
        self.reason = reason


@dataclasses.dataclass
class LayerTail:
    quant_node: Node
    nodes: List[Node]          # tail nodes, topo order, quant included
    input_tensor: str          # (scaled-)integer tensor entering the tail
    channel_axis: int


def _is_unit_entry(r: Optional[ScaledIntRange]) -> bool:
    return (r is not None and r.is_scaled_int and
            bool(np.all(r.scale == 1.0)) and bool(np.all(r.bias == 0.0)))


def find_layer_tails(g: Graph,
                     ranges: Dict[str, ScaledIntRange]) -> List[LayerTail]:
    """Anchor at each final Quant and walk upwards through elementwise
    ops.  The preferred entry point is a raw integer (scale-1, bias-0
    scaled-int) tensor; when the walk gets stuck before reaching one
    (e.g. the producing matmul consumed a *scaled* input because a
    non-homogeneous activation blocked scale aggregation), the deepest
    scaled-int tensor seen becomes the entry — extraction handles the
    affine input grid."""
    g.toposort()
    tails: List[LayerTail] = []
    claimed: set = set()
    for node in reversed(g.nodes):
        if node.op_type != "Quant" or node.name in claimed:
            continue
        chain: List[Node] = [node]
        cur = node.inputs[0]
        ok = True
        # (tensor, chain length) of scaled-int tensors passed on the way
        fallback: Optional[Tuple[str, int]] = None
        while True:
            r = ranges.get(cur)
            if _is_unit_entry(r):
                break  # integer entry point found
            if r is not None and r.is_scaled_int:
                fallback = (cur, len(chain))
            prod = g.producer(cur)
            if prod is None or prod.op_type not in TAIL_ELEMENTWISE:
                ok = False
                break
            if len(g.consumers(cur)) != 1:
                ok = False  # branching inside the tail — unsupported
                break
            if any(not g.is_constant(t) for t in prod.inputs[1:]):
                ok = False
                break
            chain.append(prod)
            cur = prod.inputs[0]
        if not ok and fallback is not None:
            cur, depth = fallback
            chain = chain[:depth]
            ok = True
        if not ok or len(chain) < 1:
            continue
        r = ranges.get(cur)
        if r is None or not r.is_scaled_int:
            continue
        prod = g.producer(cur)
        axis = -1
        if prod is not None and prod.op_type == "Conv":
            axis = 1
        elif any(g.is_constant(t) and
                 np.asarray(g.initializers[t]).ndim == 3
                 for n in chain for t in n.inputs[1:]):
            axis = 1   # (C,1,1)-shaped params ⇒ channels-first layout
        for n in chain:
            claimed.add(n.name)
        tails.append(LayerTail(quant_node=node,
                               nodes=list(reversed(chain)),
                               input_tensor=cur, channel_axis=axis))
    return tails


# --------------------------------------------------------------------------
# tail evaluation
# --------------------------------------------------------------------------

def _tail_subgraph(g: Graph, tail: LayerTail) -> Graph:
    sub = Graph(inputs=[tail.input_tensor],
                outputs=[tail.quant_node.outputs[0]])
    sub.nodes = [Node(n.op_type, list(n.inputs), list(n.outputs),
                      dict(n.attrs), name=n.name) for n in tail.nodes]
    for n in sub.nodes:
        for t in n.inputs:
            if g.is_constant(t):
                sub.initializers[t] = g.initializers[t]
    return sub


def _tail_params_channels(g: Graph, tail: LayerTail) -> int:
    """Number of channels = finest granularity among tail parameters
    (paper: 'the finest granularity of any of the fused operators')."""
    C = 1
    for n in tail.nodes:
        for t in n.inputs[1:]:
            if g.is_constant(t):
                C = max(C, int(np.size(g.initializers[t])))
    return C


def _eval_tail(sub: Graph, xs: np.ndarray, C: int, axis: int,
               in_scale: Optional[np.ndarray] = None,
               in_bias: Optional[np.ndarray] = None) -> np.ndarray:
    """Evaluate the tail for a column of integer inputs per channel.

    xs: (R,) integer inputs; returns (R, C) outputs.  ``in_scale`` /
    ``in_bias`` map the integer grid to the entry tensor's real values
    (``x = s·q + b``) for scaled entry points."""
    s = np.ones(C) if in_scale is None else in_scale
    b = np.zeros(C) if in_bias is None else in_bias
    if axis == -1:
        x = xs[:, None] * s[None, :] + b[None, :]           # (R, C)
        y = sub.execute({sub.inputs[0]: x})[sub.outputs[0]]
        return y.reshape(xs.size, C)
    # channels-first (Conv): shape (1, C, R, 1) then move back
    x = (xs[None, None, :, None] * s[None, :, None, None]
         + b[None, :, None, None])
    y = sub.execute({sub.inputs[0]: x})[sub.outputs[0]]
    return np.moveaxis(y.reshape(C, xs.size), 0, 1)


def _entry_affine(r_in: ScaledIntRange,
                  C: int) -> Tuple[np.ndarray, np.ndarray, bool]:
    """Per-channel (scale, bias) of the entry tensor's integer grid, plus
    whether the entry is a raw integer tensor (scale 1, bias 0)."""
    s = np.asarray(r_in.scale, np.float64).reshape(-1)
    b = np.asarray(r_in.bias, np.float64).reshape(-1)
    if s.size not in (1, C) or b.size not in (1, C):
        raise ThresholdConversionError(
            "entry-granularity",
            f"entry scale/bias granularity ({s.size}/{b.size}) does not "
            f"match tail channels {C}")
    unit = bool(np.all(s == 1.0) and np.all(b == 0.0))
    s_c = np.full(C, s[0]) if s.size == 1 else s.copy()
    b_c = np.full(C, b[0]) if b.size == 1 else b.copy()
    return s_c, b_c, unit


def _entry_int_bounds(r_in: ScaledIntRange,
                      C: int) -> Tuple[np.ndarray, np.ndarray]:
    """Per-channel integer bounds of the entry tensor ((C,) int64);
    channel hull when the granularity does not match."""
    il = np.asarray(r_in.int_lo, np.float64).reshape(-1)
    ih = np.asarray(r_in.int_hi, np.float64).reshape(-1)
    if il.size == C and ih.size == C:
        return (np.floor(il).astype(np.int64),
                np.ceil(ih).astype(np.int64))
    lo = int(np.floor(np.min(il)))
    hi = int(np.ceil(np.max(ih)))
    return np.full(C, lo, np.int64), np.full(C, hi, np.int64)


@dataclasses.dataclass
class TailEvaluator:
    """Quantized end-to-end evaluation of one layer tail.

    ``f_int(xs)`` maps (R,) integer grid points to the (R, C) integer
    output *levels* (count + qmin) the terminating quantizer would emit;
    ``in_scale`` / ``in_bias`` map grid points to entry-tensor values."""
    f_int: Callable[[np.ndarray], np.ndarray]
    C: int
    qmin: int
    qmax: int
    n_steps: int
    s_q: np.ndarray            # quantizer scale, raw granularity (1 or C)
    z_q: np.ndarray            # quantizer zero point, raw granularity
    in_scale: np.ndarray       # (C,) entry grid scale
    in_bias: np.ndarray        # (C,) entry grid bias
    unit_entry: bool = True


def tail_evaluator(g: Graph, tail: LayerTail,
                   ranges: Optional[Dict[str, ScaledIntRange]] = None
                   ) -> TailEvaluator:
    qn = tail.quant_node
    bits = int(g.initializers[qn.inputs[3]])
    signed = bool(qn.attrs.get("signed", 1))
    narrow = bool(qn.attrs.get("narrow", 0))
    qmin, qmax = quant_bounds(bits, signed, narrow)

    sub = _tail_subgraph(g, tail)
    C = _tail_params_channels(g, tail)
    if ranges is not None:
        r_in = ranges[tail.input_tensor]
        if not r_in.is_scaled_int:
            raise ThresholdConversionError(
                "entry-not-integer",
                f"tail entry {tail.input_tensor!r} has no integer grid")
        in_scale, in_bias, unit = _entry_affine(r_in, C)
    else:
        in_scale, in_bias, unit = np.ones(C), np.zeros(C), True

    # Per-channel quantizer parameters: (C,) arrays broadcast over the
    # per-channel tail evaluation below.  A granularity that matches
    # neither per-tensor nor the tail's channel count cannot be expressed
    # as one threshold row per channel — reject instead of miscompiling
    # (the old code silently collapsed the arrays to element 0).
    s_q = np.asarray(g.initializers[qn.inputs[1]],
                     dtype=np.float64).reshape(-1)
    z_q = np.asarray(g.initializers[qn.inputs[2]],
                     dtype=np.float64).reshape(-1)
    for name, arr in (("scale", s_q), ("zero_point", z_q)):
        if arr.size not in (1, C):
            raise ThresholdConversionError(
                "quantizer-granularity",
                f"quantizer {name} granularity {arr.size} does not match "
                f"tail channels {C} — cannot threshold")

    def f_int(xs: np.ndarray) -> np.ndarray:
        """Integer output level (count + qmin) for integer grid points."""
        y = _eval_tail(sub, xs.astype(np.float64), C, tail.channel_axis,
                       in_scale, in_bias)
        lev = np.round(y / s_q + z_q)       # (R, C) / (C,) broadcast
        return np.clip(lev, qmin, qmax)     # the quantizer saturates

    return TailEvaluator(f_int=f_int, C=C, qmin=int(qmin), qmax=int(qmax),
                         n_steps=int(qmax - qmin), s_q=s_q, z_q=z_q,
                         in_scale=in_scale, in_bias=in_bias,
                         unit_entry=unit)


@dataclasses.dataclass
class ThresholdSpec:
    thresholds: np.ndarray     # (C, N) ascending, in entry-tensor units
    out_scale: Union[float, np.ndarray]   # scalar, or (C,) per-channel
    out_bias: Union[float, np.ndarray]
    n_steps: int
    method: str = "edge"       # extraction path actually taken
    direction: Optional[np.ndarray] = None           # (C,) in {-1, 0, +1}
    certificate: Optional[_monotone.MonotoneCertificate] = None


@dataclasses.dataclass
class TailReport:
    """Per-tail conversion outcome (attached to SiraModel metadata)."""
    anchor: str                # terminating Quant node name
    input_tensor: str
    n_ops: int                 # tail length including the quantizer
    converted: bool
    status: str                # certificate status
    method: str = ""           # extraction method when converted
    reason: str = ""           # machine-readable code when unconverted


def _extract_edge(f_int: Callable[[np.ndarray], np.ndarray],
                  lo_c: np.ndarray, hi_c: np.ndarray, qmin: int, N: int,
                  d: np.ndarray, C: int) -> np.ndarray:
    """Direction-aware enumeration (edge detection) over the full grid,
    restricted to each channel's own proven integer range.  Returns
    integer-grid thresholds (±inf proxies: lo_c / hi_c + 1)."""
    lo, hi = int(lo_c.min()), int(hi_c.max())
    xs = np.arange(lo, hi + 1, dtype=np.int64)
    levels = f_int(xs)                        # (R, C)
    thr = np.empty((C, N), np.float64)
    for c in range(C):
        i0, i1 = int(lo_c[c] - lo), int(hi_c[c] - lo)
        seg = levels[i0:i1 + 1, c]
        steps = np.diff(seg)                  # edge detection kernel [-1,1]
        sx = xs[i0 + 1:i1 + 1]
        thr[c, :] = float(hi_c[c] + 1)        # +inf proxy (right pad)
        stc = np.rint(steps * (1.0 if d[c] >= 0 else -1.0)).astype(
            np.int64)
        if np.any(stc < 0):
            # the evaluation contradicts the certificate — refuse rather
            # than emit thresholds violating the exactness contract
            raise ThresholdConversionError(
                "nonmonotone-evaluation",
                f"channel {c} steps contradict certified direction")
        t_list = np.repeat(sx, stc)           # threshold at each unit step
        if d[c] >= 0:
            # left-pad: f(lo) above qmin ⇒ thresholds below the range
            # (−inf proxy: any value ≤ all in-range inputs)
            n_left = int(round(seg[0] - qmin))
            t_full = np.concatenate(
                [np.full(n_left, float(lo_c[c])), t_list])
        else:
            # decreasing: count starts at 0 ⇒ out_bias carries f(lo); the
            # thresholds mark each unit *drop*, no left pad
            t_full = t_list.astype(np.float64)
        t_full = t_full[:N]
        thr[c, :t_full.size] = t_full
    return thr


def _extract_bisect(f_int: Callable[[np.ndarray], np.ndarray],
                    lo_c: np.ndarray, hi_c: np.ndarray, qmin: int, N: int,
                    d: np.ndarray, C: int) -> np.ndarray:
    """Direction-aware bisection: O(N log R) point evaluations.  Sound
    only under a monotonicity certificate — the certificate replaces the
    old (unsound) coarse probe-grid check."""
    thr = np.empty((C, N), np.float64)
    for c in range(C):
        lo, hi = int(lo_c[c]), int(hi_c[c])
        thr[c, :] = float(hi + 1)
        lev_lo = float(f_int(np.array([lo]))[0, c])
        lev_hi = float(f_int(np.array([hi]))[0, c])
        if d[c] >= 0:
            for j in range(N):
                level = qmin + j + 1           # first x with f(x) >= level
                if lev_hi < level:
                    break                      # +inf proxy stays
                if lev_lo >= level:
                    thr[c, j] = float(lo)      # −inf proxy
                    continue
                a, b = lo, hi                  # f(a) < level <= f(b)
                while a + 1 < b:
                    m = (a + b) // 2
                    if f_int(np.array([m]))[0, c] >= level:
                        b = m
                    else:
                        a = m
                thr[c, j] = float(b)
        else:
            drops = int(round(lev_lo - lev_hi))
            for j in range(min(drops, N)):
                target = lev_lo - (j + 1)      # first x with f(x) <= target
                a, b = lo, hi                  # f(a) > target >= f(b)
                while a + 1 < b:
                    m = (a + b) // 2
                    if f_int(np.array([m]))[0, c] <= target:
                        b = m
                    else:
                        a = m
                thr[c, j] = float(b)
    return thr


def extract_thresholds(
        g: Graph, tail: LayerTail,
        ranges: Dict[str, ScaledIntRange],
        method: str = "auto",
        certificate: Optional[_monotone.MonotoneCertificate] = None,
) -> ThresholdSpec:
    r_in = ranges[tail.input_tensor]
    ev = tail_evaluator(g, tail, ranges)
    C, qmin, N = ev.C, ev.qmin, ev.n_steps
    lo_c, hi_c = _entry_int_bounds(r_in, C)
    lo, hi = int(lo_c.min()), int(hi_c.max())

    if certificate is None:
        certificate = _monotone.certify_tail(g, tail, ranges)
    if not certificate.certified:
        raise ThresholdConversionError(
            certificate.reason,
            f"tail at {tail.quant_node.name!r} not certified monotone "
            f"({certificate.reason})")
    d = np.asarray(certificate.direction, np.int64).reshape(-1)
    if d.size == 1 and C > 1:
        d = np.full(C, d[0])
    if d.size != C:
        raise ThresholdConversionError(
            "certificate-channels",
            f"certificate covers {d.size} channels, tail has {C}")

    if method == "auto":
        method = "edge" if (hi - lo) <= EDGE_DETECT_MAX_RANGE else "bisect"
    if method == "edge":
        thr = _extract_edge(ev.f_int, lo_c, hi_c, qmin, N, d, C)
    else:
        thr = _extract_bisect(ev.f_int, lo_c, hi_c, qmin, N, d, C)
    if not ev.unit_entry:
        # scaled entry: emit real-unit thresholds at grid *midpoints* so
        # floating-point noise on the entry tensor (≪ half a grid step)
        # cannot flip a comparison; s > 0 keeps rows ascending
        thr = ev.in_scale[:, None] * (thr - 0.5) + ev.in_bias[:, None]
    # thresholds must be ascending per channel
    thr = np.sort(thr, axis=1)

    s_q, z_q = ev.s_q, ev.z_q
    if np.all(d >= 0):
        out_scale: Union[float, np.ndarray] = \
            s_q if s_q.size > 1 else float(s_q[0])
        ob = np.asarray(s_q * (qmin - z_q), dtype=np.float64).reshape(-1)
        out_bias: Union[float, np.ndarray] = \
            ob if ob.size > 1 else float(ob[0])
    else:
        # decreasing channels: out = bias - s * count, with the bias
        # carrying the (dequantized) level at the range's low end
        s_c = np.broadcast_to(s_q, (C,)).astype(np.float64)
        z_c = np.broadcast_to(z_q, (C,)).astype(np.float64)
        lev_lo = np.array([float(ev.f_int(np.array([lo_c[c]]))[0, c])
                           for c in range(C)])
        sign = np.where(d < 0, -1.0, 1.0)
        out_scale = sign * s_c
        out_bias = np.where(d < 0, s_c * (lev_lo - z_c),
                            s_c * (qmin - z_c))
    return ThresholdSpec(thresholds=thr, out_scale=out_scale,
                         out_bias=out_bias, n_steps=N, method=method,
                         direction=d, certificate=certificate)


def convert_tails(
        g: Graph, ranges: Dict[str, ScaledIntRange],
        method: str = "auto",
) -> Tuple[List[ThresholdSpec], List[TailReport]]:
    """Threshold-conversion core: replace every *certified* layer tail
    with a MultiThreshold node, **in place**, given a range analysis of
    ``g``.  Uncertifiable tails are left as elementwise chains, annotated
    with the certificate's reason code (``unconverted_reason`` on the
    quantizer, ``meta_kernel_reason`` on the chain ops) for the dataflow
    DSE and the linter."""
    tails = find_layer_tails(g, ranges)
    specs: List[ThresholdSpec] = []
    reports: List[TailReport] = []
    for tail in tails:
        cert = _monotone.certify_tail(g, tail, ranges)
        reason: Optional[str] = None
        spec: Optional[ThresholdSpec] = None
        try:
            spec = extract_thresholds(g, tail, ranges, method=method,
                                      certificate=cert)
        except ThresholdConversionError as e:
            reason = e.reason
        except ValueError:
            reason = "extraction-failed"
        if spec is None:
            tail.quant_node.attrs["unconverted_reason"] = reason
            for n in tail.nodes[:-1]:
                n.attrs["meta_kernel_reason"] = reason
            reports.append(TailReport(
                anchor=tail.quant_node.name,
                input_tensor=tail.input_tensor, n_ops=len(tail.nodes),
                converted=False, status=cert.status, reason=reason or ""))
            continue
        out_t = tail.quant_node.outputs[0]
        thr_name = g.add_initializer(spec.thresholds,
                                     name=fresh_name("thresholds"))
        for n in tail.nodes:
            g.remove_node(n)
        g.add_node("MultiThreshold", [tail.input_tensor, thr_name], [out_t],
                   attrs=dict(axis=tail.channel_axis,
                              out_scale=spec.out_scale,
                              out_bias=spec.out_bias,
                              certificate=cert.summary))
        specs.append(spec)
        reports.append(TailReport(
            anchor=tail.quant_node.name, input_tensor=tail.input_tensor,
            n_ops=len(tail.nodes), converted=True, status=cert.status,
            method=spec.method))
    g.toposort()
    g.dead_code_eliminate()
    return specs, reports


def convert_tails_with_ranges(
        g: Graph, ranges: Dict[str, ScaledIntRange],
        method: str = "auto") -> List[ThresholdSpec]:
    """Back-compat wrapper around :func:`convert_tails` returning only the
    extracted specs."""
    specs, _ = convert_tails(g, ranges, method=method)
    return specs


def convert_tails_to_thresholds(
        g: Graph, input_ranges: Dict[str, ScaledIntRange],
        method: str = "auto") -> Tuple[Graph, List[ThresholdSpec]]:
    """Deprecated shim — prefer ``passes.ConvertTailsToThresholds`` on a
    ``SiraModel`` (which reuses the model's cached analysis)."""
    g = g.copy()
    ranges = analyze(g, input_ranges)
    specs = convert_tails_with_ranges(g, ranges, method=method)
    return g, specs
