"""Converting quantized layer tails to thresholds (paper §4.1.3, Fig 11).

The multi-threshold function

    f_T(x) = out_bias + out_scale * sum_i (x >= T_i)

replaces an entire *layer tail*: the chain of elementwise ops (aggregated
scale/bias, monotonic activation) terminating in a uniform quantizer.  We
implement the paper's extraction — evaluate the tail subgraph end-to-end
over the SIRA-provided integer input range and pick up the steps with an
edge-detection convolution — plus a beyond-paper *bisection* extractor that
finds each threshold by binary search (O(N log R) instead of O(R) subgraph
evaluations), used automatically for wide accumulator ranges.

Exactness contract (Eq. 3): for integer inputs within the SIRA range, the
MultiThreshold output equals the original tail output exactly.  This is
enforced by tests (exhaustively for small ranges).

Note on Eq. 2: the paper's sign-bias expression has an off-by-one typo; we
use ``out_bias = qmin`` (the count runs over N = qmax - qmin thresholds),
which is exact for signed/unsigned and narrow/wide ranges alike.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from .graph import Graph, Node, fresh_name, quant_bounds
from .intervals import ScaledIntRange
from .propagate import analyze

# elementwise ops allowed inside a layer tail (dynamic input at slot 0,
# other inputs constant)
TAIL_ELEMENTWISE = {"Mul", "Add", "Sub", "Div", "Relu", "Sigmoid", "Tanh",
                    "Softcap", "Silu", "Gelu", "Clip", "Identity"}

# enumeration cutoff: above this range size, use bisection extraction
EDGE_DETECT_MAX_RANGE = 1 << 16


@dataclasses.dataclass
class LayerTail:
    quant_node: Node
    nodes: List[Node]          # tail nodes, topo order, quant included
    input_tensor: str          # integer tensor entering the tail
    channel_axis: int


def find_layer_tails(g: Graph,
                     ranges: Dict[str, ScaledIntRange]) -> List[LayerTail]:
    """Anchor at each final Quant and walk upwards through elementwise ops
    until reaching an integer (scale-1, bias-0 scaled-int) tensor."""
    g.toposort()
    tails: List[LayerTail] = []
    claimed: set = set()
    for node in reversed(g.nodes):
        if node.op_type != "Quant" or node.name in claimed:
            continue
        chain: List[Node] = [node]
        cur = node.inputs[0]
        ok = True
        while True:
            r = ranges.get(cur)
            if r is not None and r.is_scaled_int and \
                    np.all(r.scale == 1.0) and np.all(r.bias == 0.0):
                break  # integer entry point found
            prod = g.producer(cur)
            if prod is None or prod.op_type not in TAIL_ELEMENTWISE:
                ok = False
                break
            if len(g.consumers(cur)) != 1:
                ok = False  # branching inside the tail — unsupported
                break
            if any(not g.is_constant(t) for t in prod.inputs[1:]):
                ok = False
                break
            chain.append(prod)
            cur = prod.inputs[0]
        if not ok or len(chain) < 1:
            continue
        r = ranges.get(cur)
        if r is None or not r.is_scaled_int:
            continue
        prod = g.producer(cur)
        axis = 1 if (prod is not None and prod.op_type == "Conv") else -1
        for n in chain:
            claimed.add(n.name)
        tails.append(LayerTail(quant_node=node,
                               nodes=list(reversed(chain)),
                               input_tensor=cur, channel_axis=axis))
    return tails


# --------------------------------------------------------------------------
# tail evaluation
# --------------------------------------------------------------------------

def _tail_subgraph(g: Graph, tail: LayerTail) -> Graph:
    sub = Graph(inputs=[tail.input_tensor],
                outputs=[tail.quant_node.outputs[0]])
    sub.nodes = [Node(n.op_type, list(n.inputs), list(n.outputs),
                      dict(n.attrs), name=n.name) for n in tail.nodes]
    for n in sub.nodes:
        for t in n.inputs:
            if g.is_constant(t):
                sub.initializers[t] = g.initializers[t]
    return sub


def _tail_params_channels(g: Graph, tail: LayerTail) -> int:
    """Number of channels = finest granularity among tail parameters
    (paper: 'the finest granularity of any of the fused operators')."""
    C = 1
    for n in tail.nodes:
        for t in n.inputs[1:]:
            if g.is_constant(t):
                C = max(C, int(np.size(g.initializers[t])))
    return C


def _eval_tail(sub: Graph, xs: np.ndarray, C: int, axis: int) -> np.ndarray:
    """Evaluate the tail for a column of inputs per channel.

    xs: (R,) integer inputs; returns (R, C) outputs."""
    if axis == -1:
        x = np.broadcast_to(xs[:, None], (xs.size, C))
        y = sub.execute({sub.inputs[0]: x})[sub.outputs[0]]
        return y.reshape(xs.size, C)
    # channels-first (Conv): shape (1, C, R, 1) then move back
    x = np.broadcast_to(xs[None, None, :, None], (1, C, xs.size, 1))
    y = sub.execute({sub.inputs[0]: x})[sub.outputs[0]]
    return np.moveaxis(y.reshape(C, xs.size), 0, 1)


@dataclasses.dataclass
class ThresholdSpec:
    thresholds: np.ndarray     # (C, N) ascending
    out_scale: "float | np.ndarray"   # scalar, or (C,) per-channel
    out_bias: "float | np.ndarray"
    n_steps: int


def extract_thresholds(g: Graph, tail: LayerTail,
                       ranges: Dict[str, ScaledIntRange],
                       method: str = "auto") -> ThresholdSpec:
    r_in = ranges[tail.input_tensor]
    lo = int(np.floor(np.min(r_in.int_lo)))
    hi = int(np.ceil(np.max(r_in.int_hi)))
    qn = tail.quant_node
    bits = int(g.initializers[qn.inputs[3]])
    signed = bool(qn.attrs.get("signed", 1))
    narrow = bool(qn.attrs.get("narrow", 0))
    qmin, qmax = quant_bounds(bits, signed, narrow)
    N = int(qmax - qmin)

    sub = _tail_subgraph(g, tail)
    C = _tail_params_channels(g, tail)

    # Per-channel quantizer parameters: (C,) arrays broadcast over the
    # per-channel tail evaluation below.  A granularity that matches
    # neither per-tensor nor the tail's channel count cannot be expressed
    # as one threshold row per channel — reject instead of miscompiling
    # (the old code silently collapsed the arrays to element 0).
    s_q = np.asarray(g.initializers[qn.inputs[1]], dtype=np.float64).reshape(-1)
    z_q = np.asarray(g.initializers[qn.inputs[2]], dtype=np.float64).reshape(-1)
    for name, arr in (("scale", s_q), ("zero_point", z_q)):
        if arr.size not in (1, C):
            raise ValueError(
                f"quantizer {name} granularity {arr.size} does not match "
                f"tail channels {C} — cannot threshold")

    def f_int(xs: np.ndarray) -> np.ndarray:
        """Integer output level (count + qmin) for integer inputs."""
        y = _eval_tail(sub, xs.astype(np.float64), C, tail.channel_axis)
        lev = np.round(y / s_q + z_q)       # (R, C) / (C,) broadcast
        return np.clip(lev, qmin, qmax)     # the quantizer saturates

    if method == "auto":
        method = "edge" if (hi - lo) <= EDGE_DETECT_MAX_RANGE else "bisect"

    if method == "edge":
        xs = np.arange(lo, hi + 1, dtype=np.int64)
        levels = f_int(xs)                        # (R, C)
        steps = np.diff(levels, axis=0)           # edge detection kernel [-1,1]
        if np.any(steps < -1e-9):
            raise ValueError("layer tail is not monotonic — cannot threshold")
        thr = np.full((C, N), float(hi + 1))      # +inf proxy (right pad)
        for c in range(C):
            stc = np.rint(steps[:, c]).astype(np.int64)
            t_list = np.repeat(xs[1:], stc)       # threshold at each unit step
            # left-pad: f(lo) above qmin ⇒ thresholds below the range (−inf
            # proxy: any value ≤ all in-range inputs)
            n_left = int(round(levels[0, c] - qmin))
            t_full = np.concatenate([np.full(n_left, float(lo)), t_list])
            t_full = t_full[:N]
            thr[c, :t_full.size] = t_full
    else:  # bisection (beyond-paper; exact for monotonic tails)
        # verify monotonicity on a coarse probe grid
        probe = np.unique(np.linspace(lo, hi, 257).astype(np.int64))
        lev_probe = f_int(probe)
        if np.any(np.diff(lev_probe, axis=0) < -1e-9):
            raise ValueError("layer tail is not monotonic — cannot threshold")
        thr = np.full((C, N), float(hi + 1))
        lev_lo = f_int(np.array([lo]))[0]          # (C,)
        for c in range(C):
            for j in range(N):
                level = qmin + j + 1               # first x with f(x) >= level
                if lev_lo[c] >= level:
                    thr[c, j] = float(lo)          # −inf proxy
                    continue
                a, b = lo, hi + 1                  # invariant: f(a) < level
                found = False
                while a + 1 < b:
                    m = (a + b) // 2
                    if f_int(np.array([m]))[0, c] >= level:
                        b = m
                        found = True
                    else:
                        a = m
                if found or (b <= hi and
                             f_int(np.array([b]))[0, c] >= level):
                    thr[c, j] = float(b)
    # thresholds must be ascending per channel
    thr = np.sort(thr, axis=1)
    out_scale = s_q if s_q.size > 1 else float(s_q[0])
    ob = np.asarray(s_q * (qmin - z_q), dtype=np.float64).reshape(-1)
    out_bias = ob if ob.size > 1 else float(ob[0])
    return ThresholdSpec(thresholds=thr, out_scale=out_scale,
                         out_bias=out_bias, n_steps=N)


def convert_tails_with_ranges(
        g: Graph, ranges: Dict[str, ScaledIntRange],
        method: str = "auto") -> List[ThresholdSpec]:
    """Threshold-conversion core: replace every convertible layer tail with
    a MultiThreshold node, **in place**, given a range analysis of ``g``."""
    tails = find_layer_tails(g, ranges)
    specs: List[ThresholdSpec] = []
    for tail in tails:
        try:
            spec = extract_thresholds(g, tail, ranges, method=method)
        except ValueError:
            continue  # non-monotonic tail: leave composite (paper §4.1.3)
        out_t = tail.quant_node.outputs[0]
        thr_name = g.add_initializer(spec.thresholds,
                                     name=fresh_name("thresholds"))
        for n in tail.nodes:
            g.remove_node(n)
        g.add_node("MultiThreshold", [tail.input_tensor, thr_name], [out_t],
                   attrs=dict(axis=tail.channel_axis,
                              out_scale=spec.out_scale,
                              out_bias=spec.out_bias))
        specs.append(spec)
    g.toposort()
    g.dead_code_eliminate()
    return specs


def convert_tails_to_thresholds(
        g: Graph, input_ranges: Dict[str, ScaledIntRange],
        method: str = "auto") -> Tuple[Graph, List[ThresholdSpec]]:
    """Deprecated shim — prefer ``passes.ConvertTailsToThresholds`` on a
    ``SiraModel`` (which reuses the model's cached analysis)."""
    g = g.copy()
    ranges = analyze(g, input_ranges)
    specs = convert_tails_with_ranges(g, ranges, method=method)
    return g, specs
