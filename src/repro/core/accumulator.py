"""Accumulator minimization with SIRA (paper §4.2).

Two bounds for the accumulator width of an integer MatMul/Conv:

  * **Datatype bound** (Colbert et al., reproduced): for a K-dim dot product
    of N-bit unsigned inputs with M-bit signed weights,

        P = ceil(alpha + phi(alpha) + 1),
        alpha = log2(K) + N + M - 1,  phi(a) = log2(1 + 2^-a)

  * **SIRA bound**: from the interval-arithmetic output range [z_lo, z_hi]
    of the integer kernel,

        P = ceil(log2(max(|z_lo|, |z_hi| + 1))) + 1

The SIRA bound exploits the *actual trained weights* and is provably
lossless; on the paper's workloads it is on average 22% below the datatype
bound (validated in benchmarks/f22_accumulators.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from .graph import Graph
from .intervals import InvalidRangeError, ScaledIntRange
from .propagate import analyze


def datatype_bound_bits(K: int, input_bits: int, weight_bits: int,
                        input_signed: bool = False) -> int:
    """Colbert et al. datatype-bound accumulator width (paper §4.2).

    ``input_bits``-bit (default unsigned) inputs, ``weight_bits``-bit signed
    weights, K-element dot product.  Signed inputs spend one bit on the
    sign, so only ``input_bits - 1`` magnitude bits enter alpha."""
    N = input_bits if not input_signed else input_bits - 1
    alpha = np.log2(K) + N + weight_bits - 1
    phi = np.log2(1.0 + 2.0 ** (-alpha))
    return int(np.ceil(alpha + phi + 1))


def exact_worst_case_bits(K: int, x_lo: int, x_hi: int,
                          w_lo: int, w_hi: int) -> int:
    """Exact worst-case accumulator width from integer operand ranges
    (independent of trained values, tighter than the log-sum formula for
    asymmetric ranges)."""
    prods = [x_lo * w_lo, x_lo * w_hi, x_hi * w_lo, x_hi * w_hi]
    z_lo, z_hi = K * min(prods), K * max(prods)
    m = max(abs(z_lo), abs(z_hi) + 1)
    return int(np.ceil(np.log2(max(m, 2)))) + 1


def channel_worst_case_bits(q: np.ndarray, x_lo: int, x_hi: int
                            ) -> np.ndarray:
    """Per-output-channel refinement of :func:`exact_worst_case_bits` for
    *known* integer weights ``q`` of shape (K, M): the worst-case signed
    accumulator width of each of the M dot products when every input
    element independently takes any value in ``[x_lo, x_hi]``.

    This is the oracle the accumulator-aware QAT projection
    (``repro.qat.constraints``) is validated against: for any channel,
    ``channel_worst_case_bits(q)[j] <= exact_worst_case_bits(K, x_lo,
    x_hi, q.min(), q.max())`` (the scalar bound forgets which channel a
    weight belongs to), and both use the §4.2 bit formula."""
    q = np.asarray(q, dtype=np.float64)
    z_hi = np.maximum(q * x_lo, q * x_hi).sum(axis=0)
    z_lo = np.minimum(q * x_lo, q * x_hi).sum(axis=0)
    m = np.maximum(np.abs(z_lo), np.abs(z_hi) + 1.0)
    return (np.ceil(np.log2(np.maximum(m, 2.0))) + 1).astype(np.int64)


def sira_bits(r: ScaledIntRange) -> int:
    return r.required_signed_bits()


@dataclasses.dataclass
class AccumulatorReport:
    node_name: str
    op_type: str
    K: int
    sira_bits: int
    datatype_bits: int
    baseline_bits: int = 32

    @property
    def reduction_vs_datatype(self) -> float:
        return 1.0 - self.sira_bits / self.datatype_bits

    @property
    def reduction_vs_baseline(self) -> float:
        return 1.0 - self.sira_bits / self.baseline_bits


def _weight_value(g: Graph, tensor: str) -> Optional[np.ndarray]:
    """Resolve a weight tensor to its constant value, looking through a
    residual Mul(q_W, s) if the region was not fully aggregated."""
    if g.is_constant(tensor):
        return g.initializers[tensor]
    prod = g.producer(tensor)
    if prod is not None and prod.op_type == "Mul" and \
            all(g.is_constant(t) for t in prod.inputs):
        return g.initializers[prod.inputs[0]] * g.initializers[prod.inputs[1]]
    return None


def _dot_length(g: Graph, node) -> int:
    if node.op_type in ("MatMul", "Gemm"):
        for t in node.inputs[:2]:
            w = _weight_value(g, t)
            if w is not None:
                return int(w.shape[0])
        return 0
    if node.op_type == "Conv":
        w = _weight_value(g, node.inputs[1])
        if w is None:
            return 0
        cout, cin_g, kh, kw = w.shape
        return int(cin_g * kh * kw)
    return 0


def minimize_accumulators(g: Graph,
                          input_ranges: Dict[str, ScaledIntRange],
                          input_bits: int = 8,
                          weight_bits: int = 8,
                          ranges: Optional[Dict[str, ScaledIntRange]] = None
                          ) -> List[AccumulatorReport]:
    """Analyze every integer MatMul/Conv in a (streamlined) graph and report
    SIRA vs datatype-bound accumulator widths."""
    if ranges is None:
        ranges = analyze(g, input_ranges)
    reports: List[AccumulatorReport] = []
    for node in g.nodes:
        if node.op_type not in ("MatMul", "Gemm", "Conv"):
            continue
        r_out = ranges.get(node.outputs[0])
        if r_out is None or not r_out.is_scaled_int:
            continue
        # integer kernel requires *pure integer* inputs (scale 1, bias 0)
        rs_in = [ranges.get(t) for t in node.inputs[:2]]
        if any(x is None or not x.is_scaled_int or
               not (np.all(x.scale == 1.0) and np.all(x.bias == 0.0))
               for x in rs_in):
            continue
        K = _dot_length(g, node)
        if K == 0:
            continue
        # per-input bitwidths: from the actual integer ranges if available
        def _bits(r, signed_default):
            try:
                if np.min(r.int_lo) >= 0:
                    return r.required_unsigned_bits(), False
                return r.required_signed_bits(), True
            except InvalidRangeError:
                return (input_bits, signed_default)
        dyn = rs_in[0] if not rs_in[0].is_point else rs_in[1]
        wgt = rs_in[1] if not rs_in[1].is_point else rs_in[0]
        n_bits, n_signed = _bits(dyn, False)
        m_bits, _ = _bits(wgt, True)
        reports.append(AccumulatorReport(
            node_name=node.name, op_type=node.op_type, K=K,
            sira_bits=sira_bits(r_out),
            datatype_bits=datatype_bound_bits(K, n_bits, m_bits,
                                              input_signed=n_signed)))
    return reports


def summarize(reports: List[AccumulatorReport]) -> Dict[str, float]:
    if not reports:
        return dict(mean_sira=0.0, mean_datatype=0.0,
                    reduction_vs_datatype=0.0, reduction_vs_32b=0.0)
    mu_s = float(np.mean([r.sira_bits for r in reports]))
    mu_d = float(np.mean([r.datatype_bits for r in reports]))
    return dict(mean_sira=mu_s, mean_datatype=mu_d,
                reduction_vs_datatype=1.0 - mu_s / mu_d,
                reduction_vs_32b=1.0 - mu_s / 32.0)


def accumulator_dtype(bits: int):
    """TPU adaptation: map an exact SIRA bitwidth to the accumulation dtype
    used by the Pallas integer matmul kernel (DESIGN.md §2)."""
    import jax.numpy as jnp
    if bits <= 15:
        return jnp.int16
    if bits <= 31:
        return jnp.int32
    return jnp.int64
