"""Empirical verification of SIRA ranges (paper §6.1, §7.1).

Instrument a graph by executing it over a dataset and tracking elementwise
min/max of every intermediate tensor; assert containment in the SIRA
ranges.  Also detects *stuck channels* (point output intervals — the
generalized dying-ReLU phenomenon of §7.1).

Pipeline form: ``passes.VerifyRanges`` wraps :func:`verify_ranges` as a
graph-preserving pass that reuses the ``SiraModel`` cached analysis and
can sample its dataset from the declared input ranges.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Tuple

import numpy as np

from .graph import Graph
from .intervals import ScaledIntRange


@dataclasses.dataclass
class VerificationReport:
    contained: bool
    violations: List[str]
    observed: Dict[str, Tuple[float, float]]
    coverage: Dict[str, float]   # fraction of SIRA width actually observed


def instrument(g: Graph, dataset: Iterable[Dict[str, np.ndarray]]
               ) -> Dict[str, Tuple[np.ndarray, np.ndarray]]:
    obs: Dict[str, Tuple[np.ndarray, np.ndarray]] = {}
    for feeds in dataset:
        env = g.execute(feeds, record_all=True)
        for name, val in env.items():
            if name in g.initializers:
                continue
            lo, hi = float(np.min(val)), float(np.max(val))
            if name in obs:
                plo, phi = obs[name]
                obs[name] = (min(plo, lo), max(phi, hi))
            else:
                obs[name] = (lo, hi)
    return obs


def verify_ranges(g: Graph, ranges: Dict[str, ScaledIntRange],
                  dataset: Iterable[Dict[str, np.ndarray]],
                  atol: float = 1e-6) -> VerificationReport:
    obs = instrument(g, dataset)
    violations: List[str] = []
    coverage: Dict[str, float] = {}
    for name, (lo, hi) in obs.items():
        r = ranges.get(name)
        if r is None:
            continue
        rlo, rhi = float(np.min(r.lo)), float(np.max(r.hi))
        if lo < rlo - atol or hi > rhi + atol:
            violations.append(
                f"{name}: observed [{lo:.6g},{hi:.6g}] outside "
                f"SIRA [{rlo:.6g},{rhi:.6g}]")
        width = rhi - rlo
        coverage[name] = (hi - lo) / width if width > 0 else 1.0
    return VerificationReport(contained=not violations,
                              violations=violations,
                              observed=obs, coverage=coverage)


def stuck_channels(ranges: Dict[str, ScaledIntRange], tensor: str
                   ) -> np.ndarray:
    """Boolean mask of channels whose SIRA interval is a point (§7.1)."""
    r = ranges[tensor]
    lo = np.atleast_1d(r.lo)
    hi = np.atleast_1d(r.hi)
    return (hi - lo) == 0.0
