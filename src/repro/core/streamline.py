"""Streamlining with SIRA (paper §4.1.2): scale/bias aggregation.

Two phases:

  1. **Explicitize quantizers** — rewrite every ``Quant(x, s, z, b)`` into
         Div(s) → Add(z) → Quant(scale=1, zp=0, b) → Sub(z) → Mul(s)
     so that all scales/biases live in explicit elementwise constant ops
     inside affine regions (weight branches are constant-folded down to the
     integer tensor, keeping the trailing Mul(s_w) explicit).  This is the
     generic form of "duplicating shared scales" from the paper's step (1).

  2. **Aggregate** — run SIRA with contribution tracking; for every *target
     tensor* (a scaled-int tensor feeding a non-linear boundary node or a
     graph output), insert a single Mul(aggr_scale)+Add(aggr_bias) and erase
     all contributing constants (1 for scale contributions, 0 for bias
     contributions), then remove identity ops (paper steps 2-5).

Safety: a contributor is only erased if *every* downstream boundary it can
reach is an aggregating target (otherwise its effect would be silently
dropped); targets containing unsafe contributors are skipped, to fixpoint.

This module holds the *graph-rewrite cores* (in-place, change-reporting);
the pipeline entry points are the :class:`~repro.core.passes.Transformation`
classes in ``passes.py`` (the pre-``SiraModel`` function-style shims that
used to live at the bottom of this file are gone — drive the cores through
``passes.Streamline`` / ``flow.build_flow``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from .graph import Graph, Node, fresh_name, quant_bounds, round_half_to_even
from .intervals import ScaledIntRange
from .propagate import POISON

# ops that end an affine region (paper: activations form the boundary).
# MaxPool is *not* a boundary: max(s*q+b) = s*max(q)+b for s>0, so scales
# commute past it (classic FINN reordering) and SIRA keeps the structure.
NONLINEAR_OPS = {"Relu", "Sigmoid", "Tanh", "Softcap", "Silu", "Gelu",
                 "Quant", "MultiThreshold", "Softmax", "Clip",
                 "Floor", "Round"}

# elementwise constant ops that SIRA can absorb
ABSORBABLE = {"Mul", "Div", "Add", "Sub"}


# --------------------------------------------------------------------------
# phase 1: explicitize quantizers
# --------------------------------------------------------------------------

def explicitize_quantizers_inplace(g: Graph) -> bool:
    """Rewrite non-trivial Quant nodes in place; returns True if changed."""
    g.toposort()
    new_nodes: List[Node] = []
    changed = False
    for node in g.nodes:
        if node.op_type != "Quant":
            new_nodes.append(node)
            continue
        x, s_name, z_name, b_name = node.inputs
        s = g.initializers[s_name]
        z = g.initializers[z_name]
        bits = g.initializers[b_name]
        out = node.outputs[0]
        trivial = bool(np.all(s == 1.0) and np.all(z == 0.0))
        if trivial:
            new_nodes.append(node)
            continue
        changed = True
        if g.is_constant(x):
            # weight branch: fold the integer part, keep Mul(s) explicit
            signed = bool(node.attrs.get("signed", 1))
            narrow = bool(node.attrs.get("narrow", 0))
            qmin, qmax = quant_bounds(int(bits), signed, narrow)
            w = g.initializers[x]
            q = np.clip(round_half_to_even(w / s + z), qmin, qmax)
            qint_name = g.add_initializer(q - z, name=fresh_name("q_" + x))
            mul = Node("Mul", [qint_name, s_name], [out],
                       name=fresh_name("wscale"))
            new_nodes.append(mul)
            continue
        # dynamic branch: Div → Add(z) → Quant(1,0) → Sub(z) → Mul(s)
        t_div = fresh_name(x + "_divs")
        new_nodes.append(Node("Div", [x, s_name], [t_div]))
        cur = t_div
        if np.any(z != 0):
            t_addz = fresh_name(x + "_addz")
            new_nodes.append(Node("Add", [cur, z_name], [t_addz]))
            cur = t_addz
        one = g.add_initializer(np.ones(()), name=fresh_name("one"))
        zero = g.add_initializer(np.zeros(()), name=fresh_name("zero"))
        t_q = fresh_name(x + "_q")
        new_nodes.append(Node("Quant", [cur, one, zero, b_name], [t_q],
                              dict(node.attrs)))
        cur = t_q
        if np.any(z != 0):
            t_subz = fresh_name(x + "_subz")
            new_nodes.append(Node("Sub", [cur, z_name], [t_subz]))
            cur = t_subz
        new_nodes.append(Node("Mul", [cur, s_name], [out],
                              name=fresh_name("qscale")))
    if changed:
        g.nodes = new_nodes
        g.toposort()
    return changed


def duplicate_shared_constants_inplace(g: Graph) -> bool:
    """Give every (node, input-slot) its own private copy of any constant
    consumed more than once (paper §4.1.2 step 1).  In place."""
    seen: Dict[str, int] = {}
    changed = False
    for node in g.nodes:
        for i, t in enumerate(node.inputs):
            if not g.is_constant(t):
                continue
            if t not in seen:
                seen[t] = 1
                continue
            new_name = g.add_initializer(g.initializers[t],
                                         name=fresh_name(t + "_dup"))
            node.inputs[i] = new_name
            changed = True
    if changed:
        g.touch()
    return changed


# --------------------------------------------------------------------------
# phase 2: aggregation
# --------------------------------------------------------------------------

@dataclasses.dataclass
class AggregationResult:
    graph: Graph
    targets: Dict[str, ScaledIntRange]   # target tensor -> range used
    erased: Set[str]


def _boundary_tensors(g: Graph) -> Set[str]:
    out = set(g.outputs)
    for n in g.nodes:
        if n.op_type in NONLINEAR_OPS:
            out.add(n.inputs[0])
    return out


def _erase_value(node: Node, slot: int) -> Optional[float]:
    if node.op_type in ("Mul", "Div"):
        return 1.0
    if node.op_type in ("Add", "Sub"):
        return 0.0
    if node.op_type in ("Gemm", "Conv") and slot == 2:
        return 0.0
    return None


def _reaches_only_targets(g: Graph, const_name: str,
                          targets: Set[str]) -> bool:
    """BFS downstream from the constant; every path must hit a target
    tensor before any non-target boundary (non-linear input or output)."""
    start_nodes = g.consumers(const_name)
    frontier = [t for n in start_nodes for t in n.outputs]
    visited: Set[str] = set()
    while frontier:
        t = frontier.pop()
        if t in visited:
            continue
        visited.add(t)
        if t in targets:
            continue  # re-added here; stop this branch
        if t in g.outputs:
            return False
        for m in g.consumers(t):
            if m.op_type in NONLINEAR_OPS:
                return False
            frontier.extend(m.outputs)
    return True


def aggregate_with_ranges(g: Graph,
                          ranges: Dict[str, ScaledIntRange]
                          ) -> Tuple[AggregationResult, bool]:
    """Scale/bias aggregation core: mutate ``g`` in place given a range
    analysis of it (with contribution tracking).  The graph must already be
    explicitized and have per-consumer private constants (see the in-place
    helpers above).  Returns (result, changed)."""
    boundaries = _boundary_tensors(g)
    # candidate targets: scaled-int boundary tensors with erasable content
    targets: Dict[str, ScaledIntRange] = {}
    for t in boundaries:
        r = ranges.get(t)
        if r is None or not r.is_scaled_int:
            continue
        contribs = r.scale_src | r.bias_src
        if POISON in contribs or not contribs:
            continue
        if g.producer(t) is None:
            continue  # graph input — nothing upstream to erase
        targets[t] = r

    # Drop a target t2 when a shared contributor's effect is already
    # restored by an *affinely upstream* target t1 (no Quant anchor in
    # between) — re-adding at t2 would double-count.  Residual joins whose
    # branches pass through quantizers are unaffected: contribution sets
    # are anchored (cleared) at every trivial Quant.
    g.toposort()
    topo_idx = {t: i for i, n in enumerate(g.nodes) for t in n.outputs}

    def _affine_ancestor_targets(t: str) -> Set[str]:
        """Targets reachable from t walking producers through affine ops."""
        seen: Set[str] = set()
        stack = [t]
        anc: Set[str] = set()
        while stack:
            cur = stack.pop()
            prod = g.producer(cur)
            if prod is None or prod.op_type in NONLINEAR_OPS:
                continue  # anchor: contributions do not cross
            for ti in prod.inputs:
                if ti in seen:
                    continue
                seen.add(ti)
                if ti in targets and ti != t:
                    anc.add(ti)
                stack.append(ti)
        return anc

    for t in sorted(targets, key=lambda x: topo_idx.get(x, 0)):
        shared = set()
        for a in _affine_ancestor_targets(t):
            if a in targets:
                shared |= (targets[a].scale_src | targets[a].bias_src)
        if (targets[t].scale_src | targets[t].bias_src) & shared:
            del targets[t]

    # fixpoint: drop targets whose contributors also reach non-targets
    while True:
        tset = set(targets)
        erase: Set[str] = set()
        for r in targets.values():
            erase |= (r.scale_src | r.bias_src)
        bad_consts = {c for c in erase
                      if not _reaches_only_targets(g, c, tset)}
        if not bad_consts:
            break
        targets = {t: r for t, r in targets.items()
                   if not ((r.scale_src | r.bias_src) & bad_consts)}
        if not targets:
            break

    erase = set()
    for r in targets.values():
        erase |= (r.scale_src | r.bias_src)

    # insert aggregated Mul/Add at each target
    for t, r in targets.items():
        s_val = np.asarray(r.scale)
        b_val = np.asarray(r.bias)
        consumers = [(n, i) for n in g.consumers(t)
                     for i, ti in enumerate(n.inputs) if ti == t]
        is_out = t in g.outputs
        cur = t
        if not np.all(s_val == 1.0):
            s_name = g.add_initializer(s_val, name=fresh_name("aggr_scale"))
            nt = fresh_name(t + "_scaled")
            g.add_node("Mul", [cur, s_name], [nt], name=fresh_name("aggr"))
            cur = nt
        if not np.all(b_val == 0.0):
            b_name = g.add_initializer(b_val, name=fresh_name("aggr_bias"))
            nt = fresh_name(t + "_biased")
            g.add_node("Add", [cur, b_name], [nt], name=fresh_name("aggr"))
            cur = nt
        if cur != t:
            for n, i in consumers:
                n.inputs[i] = cur
            if is_out:
                g.outputs = [cur if o == t else o for o in g.outputs]
            g.touch()

    # erase contributing constants (value edits → touch below)
    for c in erase:
        for n in g.consumers(c):
            for i, ti in enumerate(n.inputs):
                if ti != c:
                    continue
                v = _erase_value(n, i)
                if v is None:
                    raise RuntimeError(
                        f"cannot erase contributor {c} at {n.op_type}")
                g.initializers[c] = np.full_like(g.initializers[c], v)
    if erase:
        g.touch()

    changed = bool(targets) or bool(erase)
    changed |= remove_identity_ops(g)
    g.toposort()
    g.dead_code_eliminate()
    return AggregationResult(graph=g, targets=targets, erased=erase), changed


def remove_identity_ops(g: Graph) -> bool:
    """Remove Mul(x,1), Div(x,1), Add(x,0), Sub(x,0) (paper step 5).
    In place; returns True if any node was removed."""
    any_changed = False
    changed = True
    while changed:
        changed = False
        for n in list(g.nodes):
            if n.op_type not in ABSORBABLE or len(n.inputs) != 2:
                continue
            c = n.inputs[1]
            if not g.is_constant(c):
                continue
            v = g.initializers[c]
            ident = (np.all(v == 1.0) if n.op_type in ("Mul", "Div")
                     else np.all(v == 0.0))
            if not ident:
                continue
            src, dst = n.inputs[0], n.outputs[0]
            g.remove_node(n)
            g.replace_input(dst, src)
            changed = any_changed = True
    return any_changed
