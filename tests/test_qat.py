"""Accumulator-aware QAT subsystem (repro.qat): projection geometry, the
A2Q guarantee against the core accumulator oracle (incl. the lying
projector the fuzzer must catch), the jitted train loop with per-step
projection + bit-identical checkpoint resume, and the end-to-end chain
QAT -> export -> build_flow -> proven bits <= budget -> DSE monotone.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.accumulator import (channel_worst_case_bits,
                                    exact_worst_case_bits)
from repro.qat import (AccumulatorBudget, QATConfig, QATMLP,
                       check_budget_invariant, channel_bits,
                       export_qat_model, fuzz_projection,
                       project_weights, proven_layer_bits,
                       quantize_weights, run_qat, worst_case_inputs)
from repro.quant.quantizer import QuantSpec, quantize_int


# --------------------------------------------------------------- projection

def _rand_layer(seed, K=24, M=6, wbits=4):
    rng = np.random.default_rng(seed)
    W = rng.normal(size=(K, M)) * rng.uniform(0.5, 2.0)
    scale = np.maximum(np.abs(W).max(axis=0) / (2 ** (wbits - 1) - 1),
                       1e-8)
    return W, scale


def test_projection_feasible_is_identity():
    """Weights already inside the constraint set pass through unchanged."""
    W, scale = _rand_layer(0)
    W = W * 1e-3                      # tiny weights: trivially feasible
    for zc in (False, True):
        budget = AccumulatorBudget(16, input_bits=4, zero_center=zc)
        Wp = np.asarray(project_weights(jnp.asarray(W),
                                        jnp.asarray(scale), budget))
        if zc:
            # zero-centering is a reparameterization, not a projection:
            # only the centered weights are compared
            W_ref = W - (W / scale).mean(axis=0, keepdims=True) * scale
            np.testing.assert_allclose(Wp, W_ref, atol=1e-7)
        else:
            np.testing.assert_allclose(Wp, W, atol=1e-7)


def test_projection_satisfies_caps_and_is_nonexpansive():
    for seed in range(10):
        W, scale = _rand_layer(seed)
        for zc in (False, True):
            budget = AccumulatorBudget(8, input_bits=6, zero_center=zc)
            Wp = np.asarray(project_weights(
                jnp.asarray(W), jnp.asarray(scale), budget))
            v = Wp / scale
            cap_pos, cap_neg = budget.caps()
            assert np.all(np.maximum(v, 0).sum(0) <= cap_pos + 1e-4)
            if cap_neg >= 0:
                assert np.all(np.maximum(-v, 0).sum(0) <= cap_neg + 1e-4)
            else:
                assert np.all(np.abs(v).sum(0) <= cap_pos + 1e-4)
            # projection never grows a coordinate's magnitude (after the
            # optional centering) and never flips signs
            v0 = W / scale
            if zc:
                v0 = v0 - v0.mean(axis=0, keepdims=True)
            assert np.all(np.abs(v) <= np.abs(v0) + 1e-6)
            assert np.all(v * v0 >= -1e-9)


def test_projection_jit_and_grad_safe():
    """The projection must be jit-traceable (it rides inside the train
    step) and the penalty differentiable."""
    from repro.qat import budget_penalty
    W, scale = _rand_layer(3)
    budget = AccumulatorBudget(8, input_bits=6)
    f = jax.jit(lambda w: project_weights(w, jnp.asarray(scale), budget))
    np.testing.assert_allclose(
        np.asarray(f(jnp.asarray(W, jnp.float32))),
        np.asarray(project_weights(jnp.asarray(W, jnp.float32),
                                   jnp.asarray(scale), budget)),
        rtol=1e-6)
    g = jax.grad(lambda w: budget_penalty(w, jnp.asarray(scale), budget))(
        jnp.asarray(W, jnp.float32))
    assert np.all(np.isfinite(np.asarray(g)))


def test_toz_rounding_never_grows_magnitude():
    spec = QuantSpec(bits=4, signed=True, rounding="toward_zero")
    x = jnp.asarray(np.linspace(-9, 9, 301))
    q = np.asarray(quantize_int(x, 1.0, 0.0, spec))
    assert np.all(np.abs(q) <= np.abs(np.asarray(x)))
    with pytest.raises(ValueError):
        quantize_int(x, 1.0, 0.0,
                     dataclasses.replace(spec, rounding="bogus"))


# ------------------------------------------------- the guarantee vs oracle

def test_projected_weights_fit_budget_exact_oracle():
    """For random projected matrices and worst-case integer inputs, the
    core oracle never exceeds the budget (property-based when hypothesis
    is installed, seeded sweep otherwise)."""
    hyp = pytest.importorskip("hypothesis", reason="optional dependency")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 2 ** 16), K=st.integers(2, 40),
           M=st.integers(1, 8), wbits=st.integers(2, 8),
           nbits=st.integers(2, 8), P=st.integers(4, 16),
           signed=st.booleans(), zc=st.booleans())
    def prop(seed, K, M, wbits, nbits, P, signed, zc):
        rng = np.random.default_rng(seed)
        W = rng.normal(size=(K, M)) * rng.uniform(0.1, 4.0)
        scale = np.maximum(
            np.abs(W).max(axis=0) / (2 ** (wbits - 1) - 1), 1e-8)
        budget = AccumulatorBudget(P, input_bits=nbits,
                                   input_signed=signed, zero_center=zc)
        Wp = project_weights(jnp.asarray(W), jnp.asarray(scale), budget)
        q = quantize_weights(np.asarray(Wp), scale, wbits)
        assert np.all(channel_bits(q, budget) <= P)
        # concrete adversarial input agrees
        z = (q * worst_case_inputs(q, budget, True)).sum(axis=0)
        assert np.all(z <= 2.0 ** (P - 1) - 1)
        z = (q * worst_case_inputs(q, budget, False)).sum(axis=0)
        assert np.all(-z <= 2.0 ** (P - 1))

    prop()
    del hyp


def test_fuzz_projection_honest_clean():
    rep = fuzz_projection(30, seed=1)
    assert rep.clean, rep.violations[:3] + rep.oracle_mismatches[:3]
    assert rep.channels_checked > 0


@pytest.mark.parametrize("lie", ["loose", "skip"])
def test_fuzz_projection_catches_lying_projector(lie):
    """A deliberately unsound projector must be flagged — if the checker
    can't see the lie, a real soundness bug would pass silently too."""
    rep = fuzz_projection(30, seed=1, lie=lie)
    assert rep.violations, f"lying projector ({lie}) went undetected"


def test_channel_oracle_vs_scalar_oracle():
    """channel_worst_case_bits is a refinement of exact_worst_case_bits:
    never above the scalar bound, equal when every channel contains the
    extreme weight pattern."""
    rng = np.random.default_rng(5)
    for _ in range(20):
        K, M = int(rng.integers(2, 30)), int(rng.integers(1, 6))
        q = rng.integers(-7, 8, size=(K, M))
        x_lo, x_hi = sorted(rng.integers(-64, 64, size=2).tolist())
        bits = channel_worst_case_bits(q, x_lo, x_hi)
        scalar = exact_worst_case_bits(K, x_lo, x_hi,
                                       int(q.min()), int(q.max()))
        assert np.all(bits <= scalar)
    # uniform extreme weights: the refinement collapses to the bound
    q = np.full((16, 3), 7.0)
    assert np.all(channel_worst_case_bits(q, 0, 15)
                  == exact_worst_case_bits(16, 0, 15, 7, 7))


# ----------------------------------------------------------------- training

@pytest.fixture(scope="module")
def trained():
    cfg = QATConfig(budget=12, steps=50, hidden=(24,), seed=0)
    return run_qat(cfg)


def test_qat_loss_decreases(trained):
    losses = trained.losses
    assert np.mean(losses[-8:]) < np.mean(losses[:8]), losses


def test_projection_enforced_every_step(trained):
    """After training, params AND optimizer masters sit inside the
    constraint set (the projection targets the masters; params are
    re-materialized from them)."""
    model = trained.model
    for params in (trained.state.params, trained.state.opt.master):
        for i, (layer, budget) in enumerate(zip(params["layers"],
                                                model.budgets())):
            v = np.asarray(layer["W"]) / model.w_scales[i]
            cap_pos, cap_neg = budget.caps()
            if cap_neg >= 0:
                assert np.all(np.maximum(v, 0).sum(0) <= cap_pos + 1e-3)
                assert np.all(np.maximum(-v, 0).sum(0) <= cap_neg + 1e-3)
            else:
                assert np.all(np.abs(v).sum(0) <= cap_pos + 1e-3)


def test_qat_checkpoint_resume_bitexact(tmp_path):
    """Train 6 steps straight == kill after the step-3 checkpoint +
    fresh-process resume, bit-identical — for a *constrained* state
    (projection inside the step, masters carrying the constraint)."""
    import shutil

    cfg = QATConfig(budget=12, steps=6, hidden=(16,), seed=1,
                    ckpt_dir=str(tmp_path / "a"), ckpt_every=3)
    straight = run_qat(cfg)

    # simulate the crash: a fresh directory holding only the step-3
    # checkpoint, then a fresh run_qat (new model, new jit) resumes it
    (tmp_path / "b").mkdir()
    shutil.copy(tmp_path / "a" / "ckpt_00000003.npz", tmp_path / "b")
    resumed = run_qat(dataclasses.replace(cfg,
                                          ckpt_dir=str(tmp_path / "b")))
    assert resumed.resumed_from == 3
    assert resumed.losses[:3] == straight.losses[:3]

    for a, b in zip(jax.tree.leaves(straight.state.params),
                    jax.tree.leaves(resumed.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------- export + DSE

def test_export_graph_matches_training_forward(trained):
    """The exported graph (snapped weights, f64 executor) agrees with a
    float64 reference of the fake-quant forward, and its integer weights
    are exactly the toz integers training constrained."""
    model, params = trained.model, trained.state.params
    sm = export_qat_model(model, params)
    # graph Quant recovers exactly trunc(W/s) for every layer
    for i in range(len(model.layer_dims)):
        W_snap = sm.graph.initializers[f"l{i}_W"]
        q_ref = quantize_weights(np.asarray(params["layers"][i]["W"]),
                                 model.w_scales[i], model.weight_bits)
        out = sm.execute({"X": np.zeros((1, model.in_dim))},
                         want=[f"l{i}_Wq"])
        # compare in integer space; the division reintroduces ~1 ulp
        got = out[f"l{i}_Wq"] / model.w_scales[i]
        np.testing.assert_allclose(got, q_ref, atol=1e-6)
        np.testing.assert_array_equal(np.round(got), q_ref)
        np.testing.assert_allclose(W_snap, q_ref * model.w_scales[i])
    # end-to-end logits: f64 numpy reference of the fake-quant forward
    x = model.synth_batch(123, 8)["tokens"].astype(np.float64)
    h = np.clip(np.round(x / model.input_scale), 0,
                2 ** model.input_bits - 1) * model.input_scale
    n = len(model.layer_dims)
    for i in range(n):
        q = quantize_weights(np.asarray(params["layers"][i]["W"]),
                             model.w_scales[i], model.weight_bits)
        h = h @ (q * model.w_scales[i][None, :]) \
            + np.asarray(params["layers"][i]["b"], np.float64)
        if i < n - 1:
            s = model.a_scales[i]
            h = np.maximum(h, 0.0)
            h = np.clip(np.round(h / s), 0, 2 ** model.act_bits - 1) * s
    got = sm.execute({"X": x})[sm.graph.outputs[0]]
    np.testing.assert_allclose(got, h, rtol=1e-9, atol=1e-9)


def test_end_to_end_budget_chain():
    """The acceptance-criteria chain in one test: QAT at budget B ->
    export -> build_flow -> proven bits <= B on every constrained layer
    -> DSE LUT/DSP monotone non-increasing as B tightens."""
    from repro.dataflow import compare_sira_vs_baseline
    prev_luts, prev_dsps = None, None
    for budget in (14, 12, 10):
        res = run_qat(QATConfig(budget=budget, steps=40, hidden=(24,),
                                seed=2))
        result, bits = proven_layer_bits(res.model, res.state.params)
        checked = check_budget_invariant(res.model, res.state.params,
                                         bits)
        assert all(b <= budget for b in checked)
        comp = compare_sira_vs_baseline(result.model)
        if prev_luts is not None:
            assert comp.sira.luts <= prev_luts + 1e-9
            assert comp.sira.dsps <= prev_dsps
        prev_luts, prev_dsps = comp.sira.luts, comp.sira.dsps


def test_zero_center_variant_trains_and_holds():
    res = run_qat(QATConfig(budget=12, steps=40, hidden=(24,), seed=3,
                            zero_center=True))
    bits = check_budget_invariant(res.model, res.state.params)
    assert max(bits) <= 12


def test_unconstrained_model_has_no_projection():
    model = QATMLP(budget_bits=0, hidden=(8,))
    assert all(b is None for b in model.budgets())
    from repro.qat import make_optimizer
    assert make_optimizer(QATConfig(budget=0), model).project is None


def test_budget_validation():
    with pytest.raises(ValueError):
        AccumulatorBudget(bits=1)
