"""Per-architecture smoke tests (assignment requirement): every assigned
arch instantiates a REDUCED config, runs one forward + one train step on
CPU, asserts output shapes and absence of NaNs; decode matches prefill."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import get_model
from repro.optim import AdamW
from repro.quant.quantizer import QuantSpec
from repro.train import init_train_state, make_train_step

ARCHS = list_archs()


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch, key):
    cfg = get_config(arch, reduced=True)
    model = get_model(cfg)
    params = model.init(key)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    fe = None
    if cfg.frontend != "none":
        fe = jax.random.normal(key, (B, cfg.frontend_len, cfg.d_model),
                               cfg.dtype)
    logits = model.forward(params, toks, fe)
    s_tot = S + (cfg.frontend_len if fe is not None else 0)
    assert logits.shape == (B, s_tot, cfg.vocab_padded)
    assert bool(jnp.isfinite(
        logits[..., :cfg.vocab].astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch, key):
    cfg = get_config(arch, reduced=True)
    model = get_model(cfg)
    optimizer = AdamW(lr=1e-3, warmup_steps=1, total_steps=10)
    state = init_train_state(model, optimizer, key)
    step = make_train_step(model, optimizer, remat=False)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    state2, metrics = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    diffs = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        state.params, state2.params)
    assert max(jax.tree.leaves(diffs)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch, key):
    cfg = get_config(arch, reduced=True)
    if cfg.moe.n_experts:   # no-drop capacity for exact equivalence
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    model = get_model(cfg)
    params = model.init(key)
    B, S = 2, 12
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    ref = model.forward(params, toks)
    cache = model.init_cache(B, S)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, toks[:, t:t + 1], cache,
                                      jnp.asarray(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=1e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "mamba2-780m"])
def test_qat_train_step(arch, key):
    """QAT (fake-quant) training works and produces finite grads."""
    cfg = get_config(arch, reduced=True)
    model = get_model(cfg)
    optimizer = AdamW(lr=1e-3, warmup_steps=1, total_steps=10)
    state = init_train_state(model, optimizer, key)
    step = make_train_step(model, optimizer, remat=False,
                           quant=QuantSpec(bits=8))
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    _, metrics = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))


def test_gemma2_softcap_and_window(key):
    """gemma2 features: logits bounded by final softcap; local layer
    restricted to the window."""
    cfg = get_config("gemma2-2b", reduced=True)
    model = get_model(cfg)
    params = model.init(key)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab)
    logits = model.forward(params, toks)
    real = logits[..., :cfg.vocab].astype(jnp.float32)
    assert float(jnp.abs(real).max()) <= cfg.final_softcap + 1e-3


def test_moe_load_balance_aux(key):
    cfg = get_config("qwen2-moe-a2.7b", reduced=True)
    model = get_model(cfg)
    params = model.init(key)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab)
    logits, aux = model.forward(params, toks, return_aux=True)
    assert aux is not None and float(aux["load_balance"]) > 0
