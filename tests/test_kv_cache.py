"""Paged KV cache: SIRA-derived scales, int8 accuracy bound, page pool.

The KV-cache scales are the first consumer of SIRA ranges outside the
graph IR: `derive_kv_spec` exports each layer's K/V projection with the
actual serving weights, runs `core.propagate.analyze`, and reduces the
per-output-channel intervals to per-KV-head int8 steps (K widened by
sqrt(2) for the RoPE rotation hull).  These tests pin that the scales
really come from the analysis (they track the weights), that the fp
fallback engages, and that the int8 cache stays within a documented
tolerance of the fp cache.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import get_model
from repro.serve import (KVCacheSpec, PagedKVCache, Request, ServingEngine,
                         derive_kv_spec)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-1.5b", reduced=True)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


# ---------------------------------------------------------------------------
# spec derivation
# ---------------------------------------------------------------------------

def test_spec_is_derived_from_range_analysis(setup):
    """Scales are per layer and per KV head, positive, and *track the
    weights*: doubling wk/wv doubles the proven ranges and therefore the
    scales (nothing is hardcoded)."""
    cfg, model, params = setup
    spec = derive_kv_spec(model, params)
    assert len(spec.layers) == cfg.n_layers
    for l in spec.layers:
        assert l.int8
        assert l.k_scale.shape == (cfg.n_kv_heads,)
        assert l.v_scale.shape == (cfg.n_kv_heads,)
        assert np.all(l.k_scale > 0) and np.all(l.v_scale > 0)
        # the scale covers the proven bound exactly: amax = 127 * scale
        np.testing.assert_allclose(l.k_scale * 127.0, l.k_amax, rtol=1e-6)

    attn = dict(params["layers"]["attn"])
    attn["wk"] = attn["wk"] * 2.0
    attn["wv"] = attn["wv"] * 2.0
    params2 = dict(params, layers=dict(params["layers"], attn=attn))
    # loose fallback threshold: the doubled ranges must stay int8 so the
    # scales can be compared
    spec2 = derive_kv_spec(model, params2, max_step=10.0)
    for l1, l2 in zip(spec.layers, spec2.layers):
        np.testing.assert_allclose(l2.k_scale, 2.0 * l1.k_scale, rtol=0.05)
        np.testing.assert_allclose(l2.v_scale, 2.0 * l1.v_scale, rtol=0.05)


def test_fp_fallback_per_layer(setup):
    """A layer whose int8 step would exceed max_step falls back to fp
    storage — and an all-fallback spec still serves, bit-identical to the
    plain fp cache."""
    cfg, model, params = setup
    spec = derive_kv_spec(model, params, max_step=1e-6)
    assert spec.n_int8 == 0
    assert all("max_step" in l.reason for l in spec.layers)
    assert spec.scales() == [None] * cfg.n_layers

    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, size=(5,))
    o_fb = ServingEngine(model, params, batch_slots=1, max_seq=32,
                         kv_cache=spec).generate(
        [Request(prompt=prompt, max_new_tokens=5)])[0]
    o_fp = ServingEngine(model, params, batch_slots=1, max_seq=32).generate(
        [Request(prompt=prompt, max_new_tokens=5)])[0]
    assert o_fb == o_fp


def test_calibration_tightens_scales(setup):
    """MinMaxObserver calibration of the per-layer block-input range
    (quant/calibrate.py) tightens the analyzed intervals vs the default
    post-norm assumption — scales shrink, resolution improves."""
    cfg, model, params = setup
    rng = np.random.default_rng(0)
    spec = derive_kv_spec(model, params)
    spec_c = derive_kv_spec(
        model, params,
        calib_token_batches=[rng.integers(0, cfg.vocab, size=(2, 16))])
    for l, lc in zip(spec.layers, spec_c.layers):
        assert lc.k_scale.mean() < l.k_scale.mean()
        assert lc.v_scale.mean() < l.v_scale.mean()


# ---------------------------------------------------------------------------
# int8 accuracy
# ---------------------------------------------------------------------------

def _teacher_forced_logits(cfg, model, params, spec, seq, page=8):
    cache = PagedKVCache(cfg, spec, 1, 32, page_size=page)
    cache.grow(0, len(seq))
    scales = spec.scales()
    step = jax.jit(lambda p, t, pg, tab, ln: model.decode_paged(
        p, t, pg, tab, ln, page_size=page, kv_scales=scales))
    outs = []
    for start in range(0, len(seq), page):
        lg, pages = step(params, jnp.asarray(seq[None, start:start + page]),
                         cache.pages, cache.device_table(),
                         jnp.full((1,), start, jnp.int32))
        cache.pages = pages
        outs.append(np.asarray(lg[0].astype(jnp.float32)))
    return np.concatenate(outs, axis=0)


def test_int8_cache_logits_within_tolerance(setup):
    """Documented accuracy bound: on the reduced transformer, teacher-
    forced logits with the SIRA-int8 cache stay within 2% of the fp
    cache's logit scale at every position (measured ~0.5%; the bound
    gives 4x headroom).  Calibrated scales must not be worse than 1.2x
    the static-bound error."""
    cfg, model, params = setup
    rng = np.random.default_rng(0)
    seq = rng.integers(0, cfg.vocab, size=(24,)).astype(np.int32)

    l_fp = _teacher_forced_logits(cfg, model, params,
                                  KVCacheSpec.all_fp(cfg.n_layers), seq)
    l_i8 = _teacher_forced_logits(cfg, model, params,
                                  derive_kv_spec(model, params), seq)
    scale = np.abs(l_fp).max()
    err = np.abs(l_fp - l_i8).max()
    assert err < 0.02 * scale, (err, scale)

    spec_c = derive_kv_spec(
        model, params,
        calib_token_batches=[rng.integers(0, cfg.vocab, size=(2, 16))])
    err_c = np.abs(l_fp - _teacher_forced_logits(cfg, model, params,
                                                 spec_c, seq)).max()
    assert err_c < 1.2 * err, (err_c, err)


# ---------------------------------------------------------------------------
# page pool
# ---------------------------------------------------------------------------

def test_page_pool_bookkeeping(setup):
    cfg, model, params = setup
    spec = KVCacheSpec.all_fp(cfg.n_layers)
    assert PagedKVCache(cfg, spec, batch_slots=2, max_seq=32,
                        page_size=8).num_pages == 2 * 4 + 1  # default pool
    # undersized pool (6 usable pages) to exercise refusal + release
    cache = PagedKVCache(cfg, spec, batch_slots=2, max_seq=32, page_size=8,
                         num_pages=7)
    assert cache.max_pages == 4
    assert cache.used_pages == 0
    assert 0 not in cache.free                    # trash page reserved

    assert cache.grow(0, 9)                       # 2 pages
    assert cache.used_pages == 2
    assert cache.owned[0] == list(cache.table[0, :2])
    assert np.all(cache.table[0, 2:] == 0)
    assert cache.grow(0, 9)                       # idempotent
    assert cache.used_pages == 2

    assert cache.grow(1, 32)                      # 4 pages
    assert cache.used_pages == 6
    assert not cache.grow(0, 32)                  # pool is dry...
    assert cache.used_pages == 6                  # ...and refusal is a no-op
    cache.release(1)
    assert cache.used_pages == 2
    assert np.all(cache.table[1] == 0)
    assert cache.grow(0, 32)                      # now it fits

    with pytest.raises(AssertionError):
        PagedKVCache(cfg, spec, batch_slots=1, max_seq=32, page_size=8,
                     num_pages=3)                 # can't hold one request


def test_int8_pool_is_quarter_size(setup):
    cfg, model, params = setup
    fp = PagedKVCache(cfg, KVCacheSpec.all_fp(cfg.n_layers), 2, 32)
    i8 = PagedKVCache(cfg, derive_kv_spec(model, params), 2, 32)
    assert i8.hbm_bytes() * 4 == fp.hbm_bytes()   # f32 → int8
