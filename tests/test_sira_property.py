"""Property-based tests of SIRA's core invariants (hypothesis).

Soundness: for randomly generated QNN graphs and random inputs inside the
declared range, every intermediate tensor value lies inside its SIRA
range.  Transform equivalence: streamlining and threshold conversion never
change graph semantics.
"""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: pip install .[test]
from hypothesis import given, settings, strategies as st

from repro.core import (Graph, ScaledIntRange, SiraModel, Streamline,
                        analyze, convert_tails_to_thresholds)
from repro.core.verify import verify_ranges


def _streamline(graph, input_ranges):
    """Streamline through the pass API; returns the AggregationResult."""
    model, _ = Streamline().apply(SiraModel(graph.copy(), input_ranges))
    return model.metadata["aggregation"]


def _random_qnn(seed: int, n_layers: int, wbits: int, abits: int,
                with_bn: bool, signed_in: bool) -> Graph:
    rng = np.random.default_rng(seed)
    g = Graph(inputs=["X"], outputs=[])
    dims = [int(rng.integers(2, 6)) for _ in range(n_layers + 1)]
    s_in = g.add_initializer(0.1 + float(rng.random()), "s_in")
    zp = g.add_initializer(0.0)
    bits = g.add_initializer(8.0)
    g.add_node("Quant", ["X", s_in, zp, bits], ["Xq"],
               dict(signed=int(signed_in), narrow=0))
    x = "Xq"
    for li in range(n_layers):
        k, m = dims[li], dims[li + 1]
        W = rng.normal(size=(k, m))
        w = g.add_initializer(W, f"W{li}")
        sw = np.maximum(np.abs(W).max(axis=0) / (2 ** (wbits - 1) - 1),
                        1e-6)
        swn = g.add_initializer(sw, f"sw{li}")
        zpw = g.add_initializer(0.0)
        bw = g.add_initializer(float(wbits))
        g.add_node("Quant", [w, swn, zpw, bw], [f"Wq{li}"],
                   dict(signed=1, narrow=0))
        g.add_node("MatMul", [x, f"Wq{li}"], [f"mm{li}"])
        x = f"mm{li}"
        if with_bn:
            mv = g.add_initializer(
                np.abs(rng.normal(size=(m,))) * 0.5 + 0.1)
            nv = g.add_initializer(rng.normal(size=(m,)) * 0.3)
            g.add_node("Mul", [x, mv], [f"bnm{li}"])
            g.add_node("Add", [f"bnm{li}", nv], [f"bn{li}"])
            x = f"bn{li}"
        g.add_node("Relu", [x], [f"act{li}"])
        sa = g.add_initializer(0.05 + 0.2 * float(rng.random()))
        zpa = g.add_initializer(0.0)
        ba = g.add_initializer(float(abits))
        g.add_node("Quant", [f"act{li}", sa, zpa, ba], [f"q{li}"],
                   dict(signed=0, narrow=0))
        x = f"q{li}"
    g.outputs = [x]
    return g


@given(seed=st.integers(0, 10_000), n_layers=st.integers(1, 3),
       wbits=st.sampled_from([2, 3, 4]), abits=st.sampled_from([2, 3, 4]),
       with_bn=st.booleans(), signed_in=st.booleans())
@settings(max_examples=25, deadline=None)
def test_sira_soundness(seed, n_layers, wbits, abits, with_bn, signed_in):
    g = _random_qnn(seed, n_layers, wbits, abits, with_bn, signed_in)
    lo = -2.0 if signed_in else 0.0
    inp = {"X": ScaledIntRange(lo=np.asarray(lo), hi=np.asarray(2.0))}
    ranges = analyze(g, inp)
    rng = np.random.default_rng(seed + 1)
    k = None
    for n in g.nodes:
        if n.op_type == "MatMul":
            k = g.initializers["W0"].shape[0]
            break
    dataset = [{"X": rng.uniform(lo, 2.0, size=(4, k))} for _ in range(8)]
    report = verify_ranges(g, ranges, dataset)
    assert report.contained, report.violations[:3]


@given(seed=st.integers(0, 10_000), n_layers=st.integers(1, 3),
       wbits=st.sampled_from([2, 3, 4]), abits=st.sampled_from([2, 3]),
       with_bn=st.booleans())
@settings(max_examples=20, deadline=None)
def test_streamline_equivalence(seed, n_layers, wbits, abits, with_bn):
    g = _random_qnn(seed, n_layers, wbits, abits, with_bn, True)
    inp = {"X": ScaledIntRange(lo=np.asarray(-2.0), hi=np.asarray(2.0))}
    res = _streamline(g, inp)
    rng = np.random.default_rng(seed + 2)
    k = g.initializers["W0"].shape[0]
    for _ in range(5):
        x = rng.uniform(-2, 2, size=(3, k))
        y0 = g.execute({"X": x})[g.outputs[0]]
        y1 = res.graph.execute({"X": x})[res.graph.outputs[0]]
        np.testing.assert_allclose(y0, y1, rtol=1e-9, atol=1e-9)


@given(seed=st.integers(0, 10_000), wbits=st.sampled_from([2, 3]),
       abits=st.sampled_from([2, 3]), with_bn=st.booleans())
@settings(max_examples=20, deadline=None)
def test_threshold_equivalence(seed, wbits, abits, with_bn):
    g = _random_qnn(seed, 2, wbits, abits, with_bn, True)
    inp = {"X": ScaledIntRange(lo=np.asarray(-2.0), hi=np.asarray(2.0))}
    res = _streamline(g, inp)
    g2, specs = convert_tails_to_thresholds(res.graph, inp)
    assert len(specs) >= 1
    rng = np.random.default_rng(seed + 3)
    k = g.initializers["W0"].shape[0]
    for _ in range(5):
        x = rng.uniform(-2, 2, size=(3, k))
        y0 = g.execute({"X": x})[g.outputs[0]]
        y1 = g2.execute({"X": x})[g2.outputs[0]]
        np.testing.assert_allclose(y0, y1, rtol=1e-9, atol=1e-9)


@given(seed=st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_accumulator_fit_property(seed):
    """Integer matmul outputs always fit the SIRA accumulator width, and
    SIRA width <= datatype-bound width."""
    from repro.core import minimize_accumulators
    g = _random_qnn(seed, 2, 4, 4, True, True)
    inp = {"X": ScaledIntRange(lo=np.asarray(-2.0), hi=np.asarray(2.0))}
    res = _streamline(g, inp)
    ranges = analyze(res.graph, inp)
    reps = minimize_accumulators(res.graph, inp, ranges=ranges)
    assert reps, "no integer matmuls revealed"
    rng = np.random.default_rng(seed + 4)
    k = g.initializers["W0"].shape[0]
    mm_nodes = [n for n in res.graph.nodes if n.op_type == "MatMul"]
    by_name = {r.node_name: r for r in reps}
    for _ in range(5):
        x = rng.uniform(-2, 2, size=(4, k))
        env = res.graph.execute({"X": x}, record_all=True)
        for n in mm_nodes:
            if n.name not in by_name:
                continue
            acc = env[n.outputs[0]]
            P = by_name[n.name].sira_bits
            assert np.all(acc >= -(2 ** (P - 1)))
            assert np.all(acc <= 2 ** (P - 1) - 1)
            assert P <= by_name[n.name].datatype_bits
