"""The paper's worked example (§3.3, Fig 7/9, Tables 2/3): hand-derived
values checked exactly, then streamlining + thresholding equivalence."""
import numpy as np
import pytest

from repro.core import (Graph, ScaledIntRange, SiraModel, Streamline,
                        analyze, convert_tails_to_thresholds,
                        minimize_accumulators)


def _streamline(graph, input_ranges):
    """Streamline through the pass API; returns the AggregationResult."""
    model, _ = Streamline().apply(SiraModel(graph.copy(), input_ranges))
    return model.metadata["aggregation"]


@pytest.fixture()
def example():
    g = Graph(inputs=["X"], outputs=["Y"])
    qs_X = g.add_initializer(0.7, "qs_X")
    zp = g.add_initializer(0.0, "zp0")
    b4 = g.add_initializer(4.0, "b4")
    g.add_node("Quant", ["X", qs_X, zp, b4], ["Xq"],
               dict(signed=1, narrow=0))
    W = g.add_initializer(np.array([[-2.10, 5.00, -1.30],
                                    [3.10, 0.00, -3.20]]), "W")
    qs_W = g.add_initializer(np.array([0.20, 0.30, 0.10]), "qs_W")
    zp2 = g.add_initializer(0.0, "zp1")
    b4b = g.add_initializer(4.0, "b4b")
    g.add_node("Quant", [W, qs_W, zp2, b4b], ["Wq"],
               dict(signed=1, narrow=0))
    g.add_node("MatMul", ["Xq", "Wq"], ["mm"])
    B = g.add_initializer(np.array([-3.30, 1.20, 0.50]), "B")
    g.add_node("Add", ["mm", B], ["gemm"])
    M = g.add_initializer(np.array([0.60, 0.20, 0.40]), "M")
    g.add_node("Mul", ["gemm", M], ["bn_m"])
    N = g.add_initializer(np.array([-0.20, -0.40, 1.10]), "N")
    g.add_node("Add", ["bn_m", N], ["bn"])
    g.add_node("Relu", ["bn"], ["act"])
    qs_Y = g.add_initializer(0.10, "qs_Y")
    zp3 = g.add_initializer(0.0, "zp2")
    b4c = g.add_initializer(4.0, "b4c")
    g.add_node("Quant", ["act", qs_Y, zp3, b4c], ["Y"],
               dict(signed=0, narrow=0))
    x_range = ScaledIntRange(lo=np.array([-5.10, -3.80]),
                             hi=np.array([5.10, 3.80]))
    return g, {"X": x_range}


def test_quant_ranges(example):
    g, inp = example
    r = analyze(g, inp)["Xq"]
    # round(5.1/0.7)=7, round(3.8/0.7)=5 (clip to [-8, 7])
    np.testing.assert_array_equal(r.int_lo, [-7, -5])
    np.testing.assert_array_equal(r.int_hi, [7, 5])
    assert float(r.scale) == 0.7 and float(np.asarray(r.bias)) == 0.0


def test_weight_quant_point(example):
    g, inp = example
    r = analyze(g, inp)["Wq"]
    assert r.is_point and r.is_scaled_int
    # W / qs_W rounded, clipped to [-8, 7]:
    # col0: -2.1/.2=-10.5→-8 ; 3.1/.2=15.5→7 (clipped)
    np.testing.assert_array_equal(r.int_lo,
                                  [[-8, 7, -8], [7, 0, -8]])


def test_matmul_scaled_int(example):
    g, inp = example
    r = analyze(g, inp)["mm"]
    assert r.is_scaled_int
    # s_Y = s_X * s_W = 0.7 * (0.2, 0.3, 0.1)
    np.testing.assert_allclose(r.scale, [0.14, 0.21, 0.07])
    # integer accumulator range: dot of q_W with q_x in [(-7,-5), (7,5)]
    # col0: |(-8,7)| against (7,5): max = 8*7 + 7*5 = 91
    np.testing.assert_array_equal(r.int_lo, [-91, -49, -96])
    np.testing.assert_array_equal(r.int_hi, [91, 49, 96])


def test_bn_aggregated_scale(example):
    g, inp = example
    r = analyze(g, inp)["bn"]
    assert r.is_scaled_int
    # scale picks up BN multiplier M
    np.testing.assert_allclose(
        r.scale, np.array([0.14, 0.21, 0.07]) * np.array([0.6, 0.2, 0.4]))
    # bias: (B * M) + N
    np.testing.assert_allclose(
        r.bias, np.array([-3.3, 1.2, 0.5]) * np.array([0.6, 0.2, 0.4])
        + np.array([-0.2, -0.4, 1.1]))


def test_output_quant_range(example):
    g, inp = example
    r = analyze(g, inp)["Y"]
    assert r.is_scaled_int
    assert float(r.scale) == 0.1
    assert np.all(r.int_lo == 0) and np.all(r.int_hi == 15)  # u4


def test_streamline_structure_and_equivalence(example):
    g, inp = example
    res = _streamline(g, inp)
    ops = [n.op_type for n in res.graph.nodes]
    # Fig 9 structure: Div→Quant→MatMul→Mul→Add→Relu→Div→Quant→Mul
    assert ops == ["Div", "Quant", "MatMul", "Mul", "Add", "Relu", "Div",
                   "Quant", "Mul"]
    # the MatMul operands are pure integers
    ranges = analyze(res.graph, inp)
    mm = [n for n in res.graph.nodes if n.op_type == "MatMul"][0]
    for t in mm.inputs:
        r = ranges[t]
        assert r.is_scaled_int and np.all(r.scale == 1.0) \
            and np.all(r.bias == 0.0)
    rng = np.random.default_rng(0)
    for _ in range(30):
        x = rng.uniform(-1, 1, size=(5, 2)) * np.array([5.1, 3.8])
        y0 = g.execute({"X": x})["Y"]
        y1 = res.graph.execute({"X": x})[res.graph.outputs[0]]
        np.testing.assert_allclose(y0, y1, rtol=1e-12, atol=1e-12)


def test_accumulator_bits(example):
    g, inp = example
    res = _streamline(g, inp)
    reps = minimize_accumulators(res.graph, inp)
    assert len(reps) == 1
    # max |acc| = 96 → ceil(log2(97)) + 1 = 8 bits
    assert reps[0].sira_bits == 8
    assert reps[0].sira_bits <= reps[0].datatype_bits


def test_threshold_conversion_exact(example):
    g, inp = example
    res = _streamline(g, inp)
    g2, specs = convert_tails_to_thresholds(res.graph, inp)
    assert len(specs) == 1
    assert specs[0].thresholds.shape == (3, 15)     # 3 ch, 2^4-1 steps
    ops = [n.op_type for n in g2.nodes]
    assert "MultiThreshold" in ops and "Relu" not in ops
    # exact equality on EVERY reachable integer input
    ranges = analyze(res.graph, inp)
    mm_out = [n for n in res.graph.nodes
              if n.op_type == "MatMul"][0].outputs[0]
    r = ranges[mm_out]
    lo, hi = int(np.min(r.int_lo)), int(np.max(r.int_hi))
    xs = np.arange(lo, hi + 1, dtype=np.float64)
    X = np.stack([xs] * 3, axis=1)                  # (R, 3) per channel
    # evaluate original tail vs MultiThreshold on the raw integer inputs
    sub_orig = _tail_exec(res.graph, mm_out, X)
    sub_thr = _tail_exec(g2, mm_out, X)
    np.testing.assert_array_equal(sub_orig, sub_thr)


def _tail_exec(g: Graph, start: str, x: np.ndarray) -> np.ndarray:
    """Execute the graph downstream of ``start`` feeding x directly."""
    gg = g.copy()
    gg.toposort()
    upstream = {start}
    changed = True
    while changed:
        changed = False
        for n in gg.nodes:
            if set(n.outputs) & upstream:
                new = set(n.inputs) - set(gg.initializers) - upstream
                if new or not set(n.inputs).issubset(upstream):
                    upstream |= set(n.inputs)
                    changed = True
    gg.nodes = [n for n in gg.nodes if not (set(n.outputs) & upstream)]
    gg.inputs = [start]
    return gg.execute({start: x})[gg.outputs[0]]
