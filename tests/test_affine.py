"""Affine-form (zonotope) domain: exactness, soundness, containment in
the interval domain, and the acc-bit regression on the paper workloads.

Deterministic numpy tests always run; hypothesis property tests are
skipped when hypothesis is not installed (optional dep, pip install
.[test])."""
import numpy as np
import pytest

from repro.core import (AffineForm, Graph, ScaledIntRange, analyze,
                        build_flow)
from repro.core.affine import _matmul_form, tighten_range
from repro.core.flow import DEFAULT_STEPS
from repro.core.intervals import dot_interval
from repro.core.workloads import WORKLOADS, make_tfc

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - optional dep
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed")


# --------------------------------------------------------------------------
# AffineForm algebra
# --------------------------------------------------------------------------

def test_affine_form_add_sub_scale():
    x = AffineForm(1.0, {"a": np.asarray(2.0)})
    y = AffineForm(3.0, {"a": np.asarray(1.0), "b": np.asarray(0.5)})
    s = x + y
    lo, hi = s.concretize()
    assert np.isclose(s.center, 4.0)
    assert np.isclose(lo, 4.0 - 3.5) and np.isclose(hi, 4.0 + 3.5)
    d = x - x
    assert d.is_point and np.isclose(d.center, 0.0)
    m = x.scale_by(-2.0)
    lo, hi = m.concretize()
    assert np.isclose(lo, -2.0 - 4.0) and np.isclose(hi, -2.0 + 4.0)


def test_from_interval_round_trips():
    f = AffineForm.from_interval(np.array([-1.0, 0.0]),
                                 np.array([3.0, 0.0]))
    lo, hi = f.concretize()
    np.testing.assert_allclose(lo, [-1.0, 0.0])
    np.testing.assert_allclose(hi, [3.0, 0.0])


# --------------------------------------------------------------------------
# cancellation exactness: x - x analyzes to a zero-width range
# --------------------------------------------------------------------------

def test_sub_cancellation_exact():
    g = Graph(inputs=["x"], outputs=["y"])
    g.add_node("Sub", ["x", "x"], ["y"])
    in_r = {"x": ScaledIntRange(lo=np.asarray(-3.0), hi=np.asarray(5.0))}
    r_int = analyze(g, in_r, domain="interval")["y"]
    r_aff = analyze(g, in_r, domain="affine")["y"]
    # interval forgets the correlation: width 2*(hi-lo) = 16
    assert float(np.max(r_int.width())) == pytest.approx(16.0)
    # affine cancels it exactly
    assert float(np.max(r_aff.width())) == pytest.approx(0.0, abs=1e-9)
    assert float(r_aff.lo) == pytest.approx(0.0, abs=1e-9)


def test_residual_partial_cancellation():
    # y = x - 0.5*x = 0.5*x: affine width is half the input width;
    # interval compounds both branches to 1.5x the input width
    g = Graph(inputs=["x"], outputs=["y"])
    c = g.add_initializer(np.asarray(0.5), name="half")
    g.add_node("Mul", ["x", c], ["h"])
    g.add_node("Sub", ["x", "h"], ["y"])
    in_r = {"x": ScaledIntRange(lo=np.asarray(-1.0), hi=np.asarray(1.0))}
    r_int = analyze(g, in_r, domain="interval")["y"]
    r_aff = analyze(g, in_r, domain="affine")["y"]
    assert float(np.max(r_int.width())) == pytest.approx(3.0)
    assert float(np.max(r_aff.width())) == pytest.approx(1.0)


# --------------------------------------------------------------------------
# per-op soundness + tightening
# --------------------------------------------------------------------------

def test_matmul_form_matches_dot_interval():
    """Re-anchored matmul radius |a|^T |W| equals the interval-domain
    exact box hull (midpoint/radius identity)."""
    rng = np.random.default_rng(3)
    W = rng.normal(size=(5, 3))
    x_lo = rng.normal(size=(5,)) - 1.0
    x_hi = x_lo + np.abs(rng.normal(size=(5,)))
    f = AffineForm.from_interval(x_lo, x_hi)
    lo_a, hi_a = _matmul_form(f, W).concretize()
    lo_i, hi_i = dot_interval(W, x_lo, x_hi)
    np.testing.assert_allclose(lo_a, lo_i, atol=1e-12)
    np.testing.assert_allclose(hi_a, hi_i, atol=1e-12)


def test_relu_linearization_sound_and_tight():
    g = Graph(inputs=["x"], outputs=["y"])
    g.add_node("Relu", ["x"], ["y"])
    in_r = {"x": ScaledIntRange(lo=np.asarray(-2.0), hi=np.asarray(4.0))}
    r = analyze(g, in_r, domain="affine")["y"]
    rng = np.random.default_rng(0)
    for _ in range(50):
        x = rng.uniform(-2.0, 4.0)
        y = max(x, 0.0)
        assert r.contains(y, atol=1e-9)
    # saturated regimes are exact
    in_neg = {"x": ScaledIntRange(lo=np.asarray(-3.0), hi=np.asarray(-1.0))}
    r_neg = analyze(g, in_neg, domain="affine")["y"]
    assert float(r_neg.lo) == pytest.approx(0.0, abs=1e-12)
    assert float(r_neg.hi) == pytest.approx(0.0, abs=1e-12)


def test_dynamic_mul_sound():
    g = Graph(inputs=["x"], outputs=["y"])
    g.add_node("Mul", ["x", "x"], ["y"])  # x^2 — nonlinear, correlated
    in_r = {"x": ScaledIntRange(lo=np.asarray(-2.0), hi=np.asarray(3.0))}
    r = analyze(g, in_r, domain="affine")["y"]
    for x in np.linspace(-2.0, 3.0, 41):
        assert r.contains(x * x, atol=1e-9)


def test_tighten_range_preserves_scaled_int_grid():
    r = ScaledIntRange.from_scaled_int(-10, 20, 0.25, 1.0,
                                       scale_src=frozenset({"s"}))
    t = tighten_range(r, np.asarray(-0.6), np.asarray(3.3))
    assert t.is_scaled_int
    assert float(t.scale) == 0.25 and float(t.bias) == 1.0
    assert t.scale_src == frozenset({"s"})
    # snapped outward onto the integer grid: ceil((-0.6-1)/0.25) = -6,
    # floor((3.3-1)/0.25) = 9
    assert float(t.int_lo) == -6.0 and float(t.int_hi) == 9.0
    np.testing.assert_allclose(t.lo, 0.25 * -6 + 1.0)
    # tightening never widens
    assert float(t.lo) >= float(r.lo) and float(t.hi) <= float(r.hi)


# --------------------------------------------------------------------------
# whole-graph: containment in the interval domain + acc-bit regression
# --------------------------------------------------------------------------

# read-only flow prefix (skip the sampled-execution verify step: the
# fuzz suite covers empirical containment; these tests pin the bits)
_STEPS = [s for s in DEFAULT_STEPS if s != "verify_ranges"]

# summed proven accumulator bits per workload, interval vs affine.
# TFC is MatMul-only with (M,)-shaped per-channel ranges, which the
# interval domain already keeps — delta 0.  The conv workloads gain from
# the per-channel affine MultiThreshold transfer ((C,1,1) conv layout,
# which the interval handler collapses to a global hull).
_ACC_BITS = {
    "TFC-w2a2": (26, 26),
    "CNV-w2a2": (59, 58),
    "RN8-w3a3": (105, 104),
    "MNv1-w4a4": (101, 90),
}


@pytest.mark.parametrize("wname", list(WORKLOADS))
def test_affine_contained_and_accbits_pinned(wname):
    wl = WORKLOADS[wname]()
    res_i = build_flow(wl, steps=_STEPS)
    res_a = build_flow(wl, steps=_STEPS, domain="affine")

    # containment: affine hull inside interval hull for every tensor.
    # generated tensor names depend on a global fresh-name counter, so the
    # two flows are compared node-positionally (same step list -> same
    # graph structure).
    ranges_i = res_i.model.ranges
    ranges_a = res_a.model.ranges
    assert [n.op_type for n in res_i.graph.nodes] == \
        [n.op_type for n in res_a.graph.nodes]
    for ni, na in zip(res_i.graph.nodes, res_a.graph.nodes):
        for ti, ta in zip(ni.outputs, na.outputs):
            ri, ra = ranges_i[ti], ranges_a[ta]
            assert float(np.min(ra.lo)) >= float(np.min(ri.lo)) - 1e-6, ti
            assert float(np.max(ra.hi)) <= float(np.max(ri.hi)) + 1e-6, ti

    bits_i = sum(r.sira_bits for r in res_i.accumulator_reports)
    bits_a = sum(r.sira_bits for r in res_a.accumulator_reports)
    assert bits_a <= bits_i          # affine never worse
    exp_i, exp_a = _ACC_BITS[wname]
    assert (bits_i, bits_a) == (exp_i, exp_a)


def test_domain_knob_on_model_and_flow():
    from repro.core import SiraModel
    wl = make_tfc()
    m = SiraModel.from_workload(wl, domain="affine")
    assert m.domain == "affine"
    assert m.copy().domain == "affine"
    with pytest.raises(ValueError, match="unknown domain"):
        analyze(wl.graph, wl.input_range, domain="octagon")


# --------------------------------------------------------------------------
# hypothesis property tests
# --------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @needs_hypothesis
    @given(st.integers(0, 2**31), st.integers(3, 8))
    @settings(max_examples=25, deadline=None)
    def test_random_graph_soundness_property(seed, n_nodes):
        """Both domains sound, affine contained in interval, on random
        graphs (same differential oracle as repro.core.fuzz)."""
        from repro.core.fuzz import check_containment, random_graph
        rng = np.random.default_rng(seed)
        g, in_ranges, shape = random_graph(rng, n_nodes=n_nodes)
        rep = check_containment(g, in_ranges, shape, n_samples=4, rng=rng)
        assert rep.ok, "\n".join(str(v) for v in rep.violations)

    @needs_hypothesis
    @given(st.lists(st.floats(-50, 50), min_size=2, max_size=2),
           st.floats(-10, 10), st.floats(0.1, 10))
    @settings(max_examples=100, deadline=None)
    def test_affine_map_soundness(bounds, offset, scale):
        lo, hi = min(bounds), max(bounds)
        f = AffineForm.from_interval(np.asarray(lo), np.asarray(hi))
        out = f.affine_map(scale, offset)
        o_lo, o_hi = out.concretize()
        for x in np.linspace(lo, hi, 7):
            y = scale * x + offset
            assert o_lo - 1e-6 <= y <= o_hi + 1e-6
