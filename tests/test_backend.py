"""Compiled Pallas executor backend: equivalence against the numpy
interpreter (``Graph.execute``) on the four QNN workloads, flow/API wiring,
and lowering details (acc_bits selection, epilogue fusion, error paths).

The bit-exactness contract: every tensor the SIRA analysis proves
integer-valued (quantizer outputs, integer matmul/conv accumulators,
thresholds, residual adds) must match the interpreter exactly.  Float
epilogues are compared at 1e-12 in float64 mode — XLA's FMA contraction
and reduction order differ from numpy by ≤1 ulp (documented in lower.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DEFAULT_STEPS, LoweringError, SiraModel, build_flow,
                        lower)
from repro.core.workloads import WORKLOADS

WL_NAMES = list(WORKLOADS)


def _optimized(name):
    return build_flow(WORKLOADS[name]()).model


def _feeds(model, batch=2, seed=0):
    shape = (batch,) + tuple(model.metadata["input_shape"][1:])
    (inp,) = model.graph.inputs
    r = model.input_ranges[inp]
    rng = np.random.default_rng(seed)
    lo = np.broadcast_to(np.asarray(r.lo, np.float64), shape)
    hi = np.broadcast_to(np.asarray(r.hi, np.float64), shape)
    return {inp: rng.uniform(lo, hi, size=shape)}


@pytest.mark.parametrize("name", WL_NAMES)
def test_backend_bitexact_vs_interpreter(name):
    """Pallas kernels in interpret mode, float64: the integer core is
    bit-exact and the float outputs match to 1e-12."""
    with jax.experimental.enable_x64():
        model = _optimized(name)
        compiled = model.compile(use_pallas=True, interpret=True)
        assert compiled.int_tensors, "no integer tensors lowered"
        compiled = model.compile(use_pallas=True, interpret=True,
                                 extra_outputs=compiled.int_tensors)
        feeds = _feeds(model)
        want = model.execute(feeds, record_all=True)
        got = compiled(feeds)
        for t in compiled.int_tensors:
            np.testing.assert_array_equal(
                got[t].astype(np.float64), want[t],
                err_msg=f"integer tensor {t} not bit-exact")
        for o in model.graph.outputs:
            np.testing.assert_allclose(got[o], want[o],
                                       rtol=1e-12, atol=1e-12)
        # the integer layers actually went through the kernels
        kinds = compiled.kernel_calls
        assert kinds.get("int_matmul", 0) + kinds.get("int_conv", 0) >= 1
        assert kinds.get("multithreshold", 0) >= 1
        assert kinds.get("quantize", 0) >= 1


@pytest.mark.parametrize("name", WL_NAMES)
def test_backend_fast_path_matches(name):
    """Default CPU path (jnp reference kernels, float32, fused epilogues)
    agrees with the interpreter to f32 precision on batched inputs."""
    model = _optimized(name)
    compiled = model.compile()
    feeds = _feeds(model, batch=4)
    want = model.execute(feeds)
    got = compiled(feeds)
    for o in model.graph.outputs:
        scale = max(float(np.abs(want[o]).max()), 1.0)
        np.testing.assert_allclose(got[o].astype(np.float64), want[o],
                                   rtol=1e-5, atol=1e-5 * scale)


def test_sira_acc_bits_reach_the_kernel():
    """The lowering takes acc_bits from the SIRA accumulator bound — the
    low-bit workloads must select narrow (≤15-bit → int16) accumulators
    for at least one integer matmul/conv."""
    model = _optimized("TFC-w2a2")
    compiled = model.compile()
    acc = [op.acc_bits for op in compiled.plan
           if op.kind.startswith(("int_", "fused:int_"))]
    assert acc and all(b is not None and b <= 31 for b in acc)
    assert min(acc) <= 15, f"expected a SIRA-narrowed accumulator, got {acc}"


def test_fused_epilogue_in_f32_mode():
    """float32 mode fuses MatMul→Mul→Add into the int_matmul epilogue;
    float64 (exact) mode keeps the elementwise nodes separate."""
    model = _optimized("TFC-w2a2")
    fused = model.compile(dtype=jnp.float32)
    assert any(op.kind.startswith("fused:") for op in fused.plan)
    unfused = model.compile(dtype=jnp.float32, fuse_epilogue=False)
    assert not any(op.kind.startswith("fused:") for op in unfused.plan)
    feeds = _feeds(model)
    for o in model.graph.outputs:
        np.testing.assert_allclose(fused(feeds)[o], unfused(feeds)[o],
                                   rtol=1e-5, atol=1e-5)


def test_biased_conv_not_epilogue_fused():
    """Conv-with-bias followed by Mul/Add must add the bias *before* the
    scale chain — fusing the epilogue onto the raw accumulator would
    reorder them, so the lowering must keep them separate."""
    from repro.core import Graph, ScaledIntRange
    rng = np.random.default_rng(0)
    g = Graph(inputs=["X"], outputs=["out"])
    one = g.add_initializer(1.0)
    zero = g.add_initializer(0.0)
    b8 = g.add_initializer(8.0)
    g.add_node("Quant", ["X", one, zero, b8], ["Xq"], dict(signed=1))
    w = g.add_initializer(rng.integers(-3, 4, size=(4, 3, 3, 3)).astype(
        np.float64), "W")
    cb = g.add_initializer(rng.integers(-5, 6, size=(4,)).astype(
        np.float64), "Cb")
    g.add_node("Conv", ["Xq", w, cb], ["conv"], dict(stride=1, pad=1))
    s = g.add_initializer(rng.uniform(0.1, 0.5, size=(4, 1, 1)), "S")
    a = g.add_initializer(rng.normal(size=(4, 1, 1)), "A")
    g.add_node("Mul", ["conv", s], ["scaled"])
    g.add_node("Add", ["scaled", a], ["out"])
    model = SiraModel(g, {"X": ScaledIntRange(lo=np.full((), -5.0),
                                              hi=np.full((), 5.0))},
                      metadata=dict(input_shape=(1, 3, 8, 8)))
    compiled = lower(model, dtype=jnp.float32)
    assert not any(op.kind.startswith("fused:") for op in compiled.plan)
    feeds = {"X": np.random.default_rng(1).uniform(-5, 5,
                                                   size=(2, 3, 8, 8))}
    got = compiled(feeds)["out"]
    want = model.execute(feeds)["out"]
    np.testing.assert_allclose(got.astype(np.float64), want,
                               rtol=1e-5, atol=1e-5)


def test_step_compile_flow_step():
    """``step_compile`` in a build flow stores the executable under
    metadata['compiled'] without modifying the graph."""
    wl = WORKLOADS["TFC-w2a2"]()
    res = build_flow(wl, steps=list(DEFAULT_STEPS) + ["step_compile"])
    compiled = res.model.metadata["compiled"]
    assert compiled is res.model.metadata["compiled"]
    step = res.steps[-1]
    assert step.name == "step_compile" and not step.modified
    feeds = _feeds(res.model)
    got = compiled(feeds)
    want = res.model.execute(feeds)
    for o in res.model.graph.outputs:
        np.testing.assert_allclose(got[o].astype(np.float64), want[o],
                                   rtol=1e-5, atol=1e-4)


def test_compiled_retraces_per_batch_shape():
    model = _optimized("TFC-w2a2")
    compiled = model.compile()
    for batch in (1, 3, 8):
        feeds = _feeds(model, batch=batch)
        out = compiled(feeds)[model.graph.outputs[0]]
        assert out.shape[0] == batch


def test_integer_gemm_gets_int_matmul_route():
    """Gemm with integer operands and an integral bias must still route
    its matmul part through int_matmul with a SIRA-derived acc_bits (the
    synthetic sub-tensor has no range of its own — it is derived by
    shifting the Gemm output range by the bias)."""
    from repro.core import Graph, ScaledIntRange
    rng = np.random.default_rng(0)
    g = Graph(inputs=["X"], outputs=["Y"])
    one = g.add_initializer(1.0)
    zero = g.add_initializer(0.0)
    b4 = g.add_initializer(4.0)
    g.add_node("Quant", ["X", one, zero, b4], ["Xq"], dict(signed=1))
    w = g.add_initializer(rng.integers(-3, 4, size=(16, 6)).astype(
        np.float64), "W")
    c = g.add_initializer(rng.integers(-9, 9, size=(6,)).astype(
        np.float64), "C")
    g.add_node("Gemm", ["Xq", w, c], ["Y"])
    model = SiraModel(g, {"X": ScaledIntRange(lo=np.full((), -7.0),
                                              hi=np.full((), 7.0))})
    compiled = lower(model, dtype=jnp.float32)
    mm = [op for op in compiled.plan if op.kind == "int_matmul"]
    assert mm and mm[0].acc_bits is not None and mm[0].acc_bits <= 15
    feeds = {"X": np.random.default_rng(1).uniform(-7, 7, size=(3, 16))}
    np.testing.assert_array_equal(compiled(feeds)["Y"].astype(np.float64),
                                  model.execute(feeds)["Y"])
    # the synthetic matmul sub-tensor must not leak into int_tensors —
    # requesting them as extra_outputs is the documented exactness flow
    compiled2 = lower(model, dtype=jnp.float32,
                      extra_outputs=compiled.int_tensors)
    got = compiled2(feeds)
    assert all(t in got for t in compiled.int_tensors)


def test_extra_outputs_validated_at_lower_time():
    model = _optimized("TFC-w2a2")
    with pytest.raises(LoweringError, match="extra output"):
        model.compile(extra_outputs=["no_such_tensor"])


def test_unsupported_op_raises_lowering_error():
    from repro.core import Graph, ScaledIntRange
    g = Graph(inputs=["X"], outputs=["Y"])
    g.add_node("Gather", ["T", "X"], ["Y"])
    g.add_initializer(np.arange(8.0), "T")
    model = SiraModel(g, {"X": ScaledIntRange(lo=np.zeros(()),
                                              hi=np.ones(()))})
    with pytest.raises(LoweringError):
        lower(model)


# --------------------------------------------------------------------------
# per-channel threshold extraction (regression for the .reshape(-1)[0]
# collapse of per-channel quantizer parameters)
# --------------------------------------------------------------------------

def _per_channel_tail_graph(C=4):
    from repro.core import Graph
    g = Graph(inputs=["X"], outputs=[])
    one = g.add_initializer(1.0)
    zero = g.add_initializer(0.0)
    b8 = g.add_initializer(8.0)
    g.add_node("Quant", ["X", one, zero, b8], ["Xq"], dict(signed=1))
    a = g.add_initializer(np.linspace(0.25, 2.0, C))
    b = g.add_initializer(np.linspace(-3.0, 2.0, C))
    g.add_node("Mul", ["Xq", a], ["m"])
    g.add_node("Add", ["m", b], ["n"])
    g.add_node("Relu", ["n"], ["r"])
    sq = g.add_initializer(np.linspace(0.2, 2.5, C))   # per-channel scale
    zq = g.add_initializer(0.0)
    b3 = g.add_initializer(3.0)
    g.add_node("Quant", ["r", sq, zq, b3], ["Y"], dict(signed=0))
    g.outputs = ["Y"]
    return g


@pytest.mark.parametrize("method", ["edge", "bisect"])
def test_per_channel_quantizer_thresholds_exact(method):
    from repro.core import ScaledIntRange, convert_tails_to_thresholds
    C = 4
    g = _per_channel_tail_graph(C)
    inp = {"X": ScaledIntRange(lo=np.full(C, -20.0), hi=np.full(C, 20.0))}
    g2, specs = convert_tails_to_thresholds(g, inp, method=method)
    assert len(specs) == 1
    assert np.asarray(specs[0].out_scale).size == C, \
        "per-channel out_scale collapsed"
    xs = np.arange(-20, 21, dtype=np.float64)
    X = np.broadcast_to(xs[:, None], (xs.size, C))
    want = g.execute({"X": X})["Y"]
    got = g2.execute({"X": X})["Y"]
    np.testing.assert_array_equal(got, want)


def test_mismatched_quantizer_granularity_rejected():
    """A quantizer granularity matching neither per-tensor nor the tail's
    channel count must be rejected (tail left composite), not miscompiled."""
    from repro.core import (Graph, ScaledIntRange,
                            convert_tails_to_thresholds)
    g = Graph(inputs=["X"], outputs=[])
    one = g.add_initializer(1.0)
    zero = g.add_initializer(0.0)
    b8 = g.add_initializer(8.0)
    g.add_node("Quant", ["X", one, zero, b8], ["Xq"], dict(signed=1))
    a = g.add_initializer(np.linspace(0.5, 1.5, 4).reshape(2, 2))  # C=4
    g.add_node("Mul", ["Xq", a], ["m"])
    sq = g.add_initializer(np.array([0.5, 1.0]))       # granularity 2 ≠ 1, 4
    zq = g.add_initializer(0.0)
    b3 = g.add_initializer(3.0)
    g.add_node("Quant", ["m", sq, zq, b3], ["Y"], dict(signed=0))
    g.outputs = ["Y"]
    inp = {"X": ScaledIntRange(lo=np.full((2, 2), -8.0),
                               hi=np.full((2, 2), 8.0))}
    g2, specs = convert_tails_to_thresholds(g, inp)
    assert specs == []                        # rejected, not miscompiled
    assert any(n.op_type == "Quant" and n.outputs == ["Y"]
               for n in g2.nodes)             # tail left in place
