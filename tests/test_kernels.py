"""Pallas kernel sweeps: interpret-mode kernels vs pure-jnp oracles across
shapes, dtypes, block sizes, and accumulator widths — including the padded
``kernels.ops`` wrappers on real-workload odd shapes (10-class heads,
3-channel inputs, odd batches) that violate the raw kernels' block
divisibility asserts."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.int_matmul import int_matmul
from repro.kernels.multithreshold import infer_out_dtype, multithreshold
from repro.kernels.quantize import quantize


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 128, 256),
                                   (128, 512, 128), (384, 256, 128)])
def test_int_matmul_raw(m, k, n):
    rng = np.random.default_rng(m + k + n)
    x = rng.integers(-128, 128, size=(m, k)).astype(np.int8)
    w = rng.integers(-128, 128, size=(k, n)).astype(np.int8)
    got = int_matmul(jnp.asarray(x), jnp.asarray(w), interpret=True)
    want = ref.int_matmul_ref(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bm,bk,bn", [(128, 128, 128), (256, 128, 128)])
def test_int_matmul_blocks(bm, bk, bn):
    rng = np.random.default_rng(0)
    x = rng.integers(-128, 128, size=(256, 256)).astype(np.int8)
    w = rng.integers(-128, 128, size=(256, 256)).astype(np.int8)
    got = int_matmul(jnp.asarray(x), jnp.asarray(w), bm=bm, bk=bk, bn=bn,
                     interpret=True)
    want = ref.int_matmul_ref(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_int_matmul_fused_dequant():
    rng = np.random.default_rng(1)
    x = rng.integers(-8, 8, size=(128, 128)).astype(np.int8)
    w = rng.integers(-8, 8, size=(128, 128)).astype(np.int8)
    s = rng.uniform(0.01, 0.1, size=(128,)).astype(np.float32)
    b = rng.normal(size=(128,)).astype(np.float32)
    got = int_matmul(jnp.asarray(x), jnp.asarray(w), jnp.asarray(s),
                     jnp.asarray(b), interpret=True)
    want = ref.int_matmul_ref(jnp.asarray(x), jnp.asarray(w),
                              jnp.asarray(s), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_int_matmul_sira_int16_accumulator():
    """SIRA bound <= 15 bits → int16 accumulation, still exact."""
    rng = np.random.default_rng(2)
    # |acc| <= 128*3*3 = 1152 < 2^14
    x = rng.integers(-3, 4, size=(128, 128)).astype(np.int8)
    w = rng.integers(-3, 4, size=(128, 128)).astype(np.int8)
    got = int_matmul(jnp.asarray(x), jnp.asarray(w), acc_bits=12,
                     interpret=True)
    assert got.dtype == jnp.int16
    want = ref.int_matmul_ref(jnp.asarray(x), jnp.asarray(w), acc_bits=12)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n_thr,out_dtype", [(3, jnp.int8), (15, jnp.int8),
                                             (255, jnp.int32)])
def test_multithreshold_sweep(n_thr, out_dtype):
    rng = np.random.default_rng(n_thr)
    x = rng.integers(-1000, 1000, size=(256, 128)).astype(np.int32)
    thr = np.sort(rng.integers(-900, 900, size=(n_thr, 128)), axis=0
                  ).astype(np.int32)
    got = multithreshold(jnp.asarray(x), jnp.asarray(thr), out_bias=-2,
                         out_dtype=out_dtype, interpret=True)
    want = ref.multithreshold_ref(jnp.asarray(x), jnp.asarray(thr),
                                  out_bias=-2, out_dtype=out_dtype)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_multithreshold_matches_searchsorted():
    """The VPU compare-count form equals the paper's binary-search form."""
    rng = np.random.default_rng(9)
    x = rng.integers(-500, 500, size=(128, 128)).astype(np.int32)
    thr = np.sort(rng.integers(-400, 400, size=(7, 128)), axis=0
                  ).astype(np.int32)
    a = ref.multithreshold_ref(jnp.asarray(x), jnp.asarray(thr))
    b = ref.multithreshold_searchsorted_ref(jnp.asarray(x),
                                            jnp.asarray(thr))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("qmin,qmax,dtype", [(-128, 127, jnp.int8),
                                             (-8, 7, jnp.int8),
                                             (0, 15, jnp.int8)])
def test_quantize_sweep(qmin, qmax, dtype):
    rng = np.random.default_rng(qmax)
    x = rng.normal(size=(256, 128)).astype(np.float32) * 3
    s = rng.uniform(0.01, 0.3, size=(128,)).astype(np.float32)
    z = np.zeros((128,), np.float32)
    got = quantize(jnp.asarray(x), jnp.asarray(s), jnp.asarray(z),
                   qmin=qmin, qmax=qmax, out_dtype=dtype, interpret=True)
    want = ref.quantize_ref(jnp.asarray(x), jnp.asarray(s), jnp.asarray(z),
                            qmin=qmin, qmax=qmax, out_dtype=dtype)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kernel_pipeline_matches_streamlined_graph():
    """int_matmul + multithreshold == the SIRA-streamlined graph tail."""
    from repro.core import (Graph, ScaledIntRange, SiraModel, Streamline,
                            convert_tails_to_thresholds)
    rng = np.random.default_rng(3)
    K, M = 128, 128
    g = Graph(inputs=["X"], outputs=[])
    s_in = g.add_initializer(0.05, "s_in")
    zp = g.add_initializer(0.0)
    b8 = g.add_initializer(8.0)
    g.add_node("Quant", ["X", s_in, zp, b8], ["Xq"], dict(signed=1))
    W = rng.normal(size=(K, M))
    w = g.add_initializer(W, "W")
    sw = g.add_initializer(np.abs(W).max(axis=0) / 7, "sw")
    zw = g.add_initializer(0.0)
    b4 = g.add_initializer(4.0)
    g.add_node("Quant", [w, sw, zw, b4], ["Wq"], dict(signed=1))
    g.add_node("MatMul", ["Xq", "Wq"], ["mm"])
    g.add_node("Relu", ["mm"], ["act"])
    sa = g.add_initializer(0.5)
    za = g.add_initializer(0.0)
    ba = g.add_initializer(4.0)
    g.add_node("Quant", ["act", sa, za, ba], ["Y"], dict(signed=0))
    g.outputs = ["Y"]
    inp = {"X": ScaledIntRange(lo=np.asarray(-1.0), hi=np.asarray(1.0))}
    model, _ = Streamline().apply(SiraModel(g.copy(), inp))
    res = model.metadata["aggregation"]
    g2, specs = convert_tails_to_thresholds(res.graph, inp)
    assert len(specs) == 1

    x = rng.uniform(-1, 1, size=(128, K))
    want = g.execute({"X": x})["Y"]

    # kernel pipeline: quantize → int matmul → multithreshold → rescale
    xq = np.clip(np.round(x / 0.05), -128, 127).astype(np.int8)
    wq = np.clip(np.round(W / (np.abs(W).max(axis=0) / 7)), -8, 7
                 ).astype(np.int8)
    acc = int_matmul(jnp.asarray(xq), jnp.asarray(wq), interpret=True)
    thr = specs[0].thresholds.T.astype(np.int32)      # (N, C)
    cnt = multithreshold(jnp.asarray(np.asarray(acc, np.int32)),
                         jnp.asarray(thr),
                         out_bias=int(specs[0].out_bias),
                         out_dtype=jnp.int32, interpret=True)
    got = np.asarray(cnt, np.float64) * 0.5           # final Mul(qs_Y)
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


# --------------------------------------------------------------------------
# padded wrappers: odd (non-block-divisible) shapes through the Pallas path
# --------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(1, 49, 10), (7, 3, 5), (130, 200, 10),
                                   (8, 64, 100)])
def test_int_matmul_odd_shapes_padded(m, k, n):
    rng = np.random.default_rng(m * k + n)
    x = rng.integers(-128, 128, size=(m, k)).astype(np.int8)
    w = rng.integers(-8, 8, size=(k, n)).astype(np.int8)
    got = ops.int_matmul(jnp.asarray(x), jnp.asarray(w),
                         use_pallas=True, interpret=True)
    want = ref.int_matmul_ref(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_int_matmul_odd_shapes_fused_dequant():
    rng = np.random.default_rng(5)
    x = rng.integers(-8, 8, size=(6, 49)).astype(np.int8)
    w = rng.integers(-8, 8, size=(49, 10)).astype(np.int8)
    s = rng.uniform(0.01, 0.1, size=(10,)).astype(np.float32)
    b = rng.normal(size=(10,)).astype(np.float32)
    got = ops.int_matmul(jnp.asarray(x), jnp.asarray(w), jnp.asarray(s),
                         jnp.asarray(b), use_pallas=True, interpret=True)
    want = ref.int_matmul_ref(jnp.asarray(x), jnp.asarray(w),
                              jnp.asarray(s), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_int_matmul_scalar_scale_broadcasts_to_all_columns():
    """Per-tensor (size-1) scale must apply to every output column — the
    padded wrapper used to pad a scalar with ones, scaling only col 0."""
    x = jnp.ones((4, 8), jnp.int8)
    w = jnp.ones((8, 10), jnp.int8)
    s = jnp.asarray([0.5], jnp.float32)
    got = np.asarray(ops.int_matmul(x, w, s, use_pallas=True,
                                    interpret=True))
    np.testing.assert_array_equal(got, np.full((4, 10), 4.0, np.float32))


@pytest.mark.parametrize("m,c,n_thr", [(5, 3, 3), (1, 10, 15), (33, 130, 7)])
def test_multithreshold_odd_shapes_padded(m, c, n_thr):
    rng = np.random.default_rng(m + c + n_thr)
    x = rng.integers(-500, 500, size=(m, c)).astype(np.int32)
    thr = np.sort(rng.integers(-400, 400, size=(n_thr, c)), axis=0
                  ).astype(np.int32)
    got = ops.multithreshold(jnp.asarray(x), jnp.asarray(thr), out_bias=-1,
                             out_dtype=jnp.int32, use_pallas=True,
                             interpret=True)
    want = ref.multithreshold_ref(jnp.asarray(x), jnp.asarray(thr),
                                  out_bias=-1, out_dtype=jnp.int32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("m,c", [(3, 10), (1, 1), (257, 5)])
def test_quantize_odd_shapes_padded(m, c):
    rng = np.random.default_rng(m + c)
    x = rng.normal(size=(m, c)).astype(np.float32) * 3
    s = rng.uniform(0.01, 0.3, size=(c,)).astype(np.float32)
    z = np.zeros((c,), np.float32)
    got = ops.quantize(jnp.asarray(x), jnp.asarray(s), jnp.asarray(z),
                       use_pallas=True, interpret=True)
    want = ref.quantize_ref(jnp.asarray(x), jnp.asarray(s), jnp.asarray(z))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------------------------------
# out_dtype overflow regression: the old int8 default wrapped 8-bit
# unsigned tails (count 255 → -1)
# --------------------------------------------------------------------------

def test_infer_out_dtype():
    assert infer_out_dtype(3, -2) == jnp.int8
    assert infer_out_dtype(255, -128) == jnp.int8    # signed 8-bit fits
    assert infer_out_dtype(255, 0) == jnp.int16      # unsigned 8-bit: 255
    assert infer_out_dtype(2 ** 16, 0) == jnp.int32


def test_multithreshold_default_dtype_no_overflow():
    """8-bit unsigned tail: count reaches 255 and must not wrap negative
    under the default output dtype (interpret mode)."""
    x = jnp.full((8, 4), 10_000, jnp.int32)
    thr = jnp.asarray(np.tile(np.arange(255, dtype=np.int32)[:, None],
                              (1, 4)))
    for out in (multithreshold(x, thr, interpret=True),
                ref.multithreshold_ref(x, thr)):
        arr = np.asarray(out)
        assert arr.min() >= 0, "8-bit unsigned tail wrapped negative"
        assert int(arr.max()) == 255


@pytest.mark.parametrize("B,Sq,H,KV,hd,cap", [(2, 128, 4, 2, 64, 0.0),
                                              (1, 256, 8, 8, 64, 50.0),
                                              (2, 128, 6, 2, 32, 0.0)])
def test_flash_attention_kernel(B, Sq, H, KV, hd, cap):
    from repro.kernels.flash_attention import (flash_attention_fwd,
                                               flash_attention_ref)
    rng = np.random.default_rng(Sq + H)
    q = jnp.asarray(rng.normal(size=(B, Sq, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Sq, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Sq, KV, hd)), jnp.float32)
    got = flash_attention_fwd(q, k, v, bq=64, bk=64, logit_cap=cap,
                              interpret=True)
    want = flash_attention_ref(q, k, v, logit_cap=cap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_matches_model_attention():
    """The Pallas kernel agrees with the model's jnp chunked attention."""
    from repro.kernels.flash_attention import flash_attention_fwd
    from repro.models.attention import flash_attention as model_fa
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(2, 128, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 128, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 128, 2, 32)), jnp.float32)
    a = flash_attention_fwd(q, k, v, bq=64, bk=64, interpret=True)
    b = model_fa(q, k, v, chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)
