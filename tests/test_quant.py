"""Quantizer substrate tests: round trips, granularities, PoT, STE, PTQ."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: pip install .[test]
from hypothesis import given, settings, strategies as st

from repro.quant import (MinMaxObserver, PercentileObserver, QuantSpec,
                         compute_scale, fake_quant, quantize_int)


@pytest.mark.parametrize("granularity", ["per_tensor", "per_channel",
                                         "per_group"])
@pytest.mark.parametrize("bits", [2, 3, 4, 8])
def test_fake_quant_error_bound(granularity, bits):
    rng = np.random.default_rng(bits)
    x = jnp.asarray(rng.normal(size=(16, 32)))
    spec = QuantSpec(bits=bits, granularity=granularity, group_size=8)
    s, z = compute_scale(x, spec)
    y = fake_quant(x, s, z, spec)
    # quantization error bounded by scale/2 within the clip range
    err = jnp.abs(y - jnp.clip(x, -jnp.abs(x).max(), jnp.abs(x).max()))
    assert float(err.max()) <= float(jnp.max(s)) * 0.5 + 1e-9


def test_quant_idempotent():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 16)))
    spec = QuantSpec(bits=4)
    s, z = compute_scale(x, spec)
    y1 = fake_quant(x, s, z, spec)
    y2 = fake_quant(y1, s, z, spec)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-9)


def test_pot_scales_are_pow2():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(8, 16)) * 3)
    spec = QuantSpec(bits=3, pot=True, granularity="per_channel")
    s, _ = compute_scale(x, spec)
    logs = np.log2(np.asarray(s))
    np.testing.assert_allclose(logs, np.round(logs), atol=1e-9)


def test_narrow_range():
    spec = QuantSpec(bits=4, narrow=True)
    assert spec.qmin == -7 and spec.qmax == 7
    spec2 = QuantSpec(bits=4, narrow=False)
    assert spec2.qmin == -8 and spec2.qmax == 7
    spec3 = QuantSpec(bits=4, signed=False)
    assert spec3.qmin == 0 and spec3.qmax == 15


def test_asymmetric_zero_point():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.uniform(2.0, 5.0, size=(64,)))   # skewed positive
    spec = QuantSpec(bits=4, signed=False, symmetric=False)
    s, z = compute_scale(x, spec)
    y = fake_quant(x, s, z, spec)
    # asymmetric quant must cover the range well
    assert float(jnp.abs(y - x).max()) <= float(jnp.squeeze(s)) * 0.5 + 1e-6


def test_ste_gradient():
    spec = QuantSpec(bits=4)
    x = jnp.linspace(-2, 2, 64)
    s, z = compute_scale(x, spec)

    def f(x):
        return jnp.sum(fake_quant(x, s, z, spec) ** 2)
    g = jax.grad(f)(x)
    assert bool(jnp.isfinite(g).all())
    # inside the range, gradient ≈ 2x (identity STE)
    mid = jnp.abs(x) < 1.0
    np.testing.assert_allclose(np.asarray(g)[np.asarray(mid)],
                               2 * np.asarray(x)[np.asarray(mid)],
                               atol=float(jnp.squeeze(s)) * 2 + 1e-3)


@given(st.integers(2, 8), st.booleans())
@settings(max_examples=30, deadline=None)
def test_int_range_respected(bits, signed):
    rng = np.random.default_rng(bits)
    x = jnp.asarray(rng.normal(size=(128,)) * 10)
    spec = QuantSpec(bits=bits, signed=signed)
    s, z = compute_scale(x, spec)
    q = quantize_int(x, s, z, spec)
    assert float(q.min()) >= spec.qmin and float(q.max()) <= spec.qmax


def test_minmax_observer():
    spec = QuantSpec(bits=8)
    obs = MinMaxObserver(spec)
    obs.update(np.array([-2.0, 1.0]))
    obs.update(np.array([0.5, 3.0]))
    s, z = obs.scale_zp()
    assert np.isclose(float(np.squeeze(s)), 3.0 / 127)


def test_percentile_observer_rejects_outliers():
    spec = QuantSpec(bits=8)
    obs = PercentileObserver(spec, percentile=1.0)
    rng = np.random.default_rng(0)
    x = rng.normal(size=(10000,))
    x[0] = 1000.0                     # outlier
    obs.update(x)
    s, _ = obs.scale_zp()
    assert float(np.squeeze(s)) < 0.1  # not dominated by the outlier
