"""Validation of the trip-count-aware HLO cost parser: scanned graphs must
match the unrolled graph's cost_analysis (which XLA counts correctly)."""
import pytest


@pytest.fixture(scope="module")
def jax_mod():
    import jax
    return jax


def test_scan_flops_match_unrolled(jax_mod):
    import jax
    import jax.numpy as jnp
    from repro.roofline.hlo_cost import analyze_hlo

    def f_scan(x):
        def body(c, _):
            return jnp.tanh(c @ c), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    def f_unroll(x):
        for _ in range(7):
            x = jnp.tanh(x @ x)
        return x

    xs = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c_scan = jax.jit(f_scan).lower(xs).compile()
    c_un = jax.jit(f_unroll).lower(xs).compile()
    t_scan = analyze_hlo(c_scan.as_text())
    t_un = analyze_hlo(c_un.as_text())
    expected = 7 * 2 * 64 ** 3
    assert abs(t_scan.flops - t_un.flops) / t_un.flops < 0.05
    assert t_scan.flops >= expected
    # XLA's own analysis undercounts the scan ~7x
    from repro.roofline.hlo_cost import normalize_cost_analysis
    xla_cost = normalize_cost_analysis(c_scan.cost_analysis())
    assert xla_cost["flops"] < t_scan.flops / 3


def test_nested_scan_multiplies(jax_mod):
    import jax
    import jax.numpy as jnp
    from repro.roofline.hlo_cost import analyze_hlo

    def f(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ ci, None
            y, _ = jax.lax.scan(inner, c, None, length=3)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    xs = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    t = analyze_hlo(jax.jit(f).lower(xs).compile().as_text())
    expected = 15 * 2 * 32 ** 3
    assert 0.9 * expected <= t.flops <= 1.3 * expected


def test_dus_counts_slice_not_stack(jax_mod):
    import jax
    import jax.numpy as jnp
    from repro.roofline.hlo_cost import analyze_hlo

    def f(x):
        buf = jnp.zeros((64, 32, 32), x.dtype)

        def body(carry, i):
            buf, x = carry
            x = x * 1.5
            buf = jax.lax.dynamic_update_slice(buf, x[None], (i, 0, 0))
            return (buf, x), None
        (buf, _), _ = jax.lax.scan(f=body, init=(buf, x),
                                   xs=jnp.arange(64))
        return buf

    xs = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    t = analyze_hlo(jax.jit(f).lower(xs).compile().as_text())
    stack_bytes = 64 * 32 * 32 * 4
    # if the DUS were charged at full-stack size per iteration we'd see
    # >= 64 * stack_bytes; slice-aware accounting stays far below
    assert t.bytes < 16 * stack_bytes, t.bytes
