"""Monotonicity certification + certified threshold conversion.

Covers the per-op transfer functions, the on-grid fallback, the
certificate-gated extraction paths (bisection guard regression for Silu
tails), the differential tail fuzzer with a seeded lying certifier, the
lint rules, the meta-kernel style selection, and the hard-swish/Silu MLP
workload end-to-end.
"""
import numpy as np
import pytest

from repro.core import (MonotoneCertificate, SiraModel, ScaledIntRange,
                        ThresholdConversionError, analyze, build_flow,
                        certify_tail, compose_direction, convert_tails,
                        lint_graph)
from repro.core.flow import DATAFLOW_STEPS
from repro.core.fuzz import check_tail_exactness, run_tail_fuzz
from repro.core.graph import Graph
from repro.core.passes import Streamline
from repro.core.thresholds import (extract_thresholds, find_layer_tails,
                                   tail_evaluator)
from repro.core.workloads import ALL_WORKLOADS, WORKLOADS, make_hsw
from repro.dataflow import compare_sira_vs_baseline
from repro.dataflow.resources import (NodeModel, baseline_style,
                                      node_styles, select_style)


# --------------------------------------------------------------- helpers

def tail_graph(ops, bits=4, signed=1, qscale=0.1, lo=-100, hi=100, C=1):
    """A chain of (op, const-or-None) pairs terminated in a Quant, with an
    integer scale-1 input range [lo, hi] on every channel."""
    g = Graph(inputs=["x"], outputs=["y"])
    cur = "x"
    for i, (op, const) in enumerate(ops):
        ins = [cur]
        if const is not None:
            ins.append(g.add_initializer(
                np.asarray(const, np.float64), name=f"c{i}"))
        out = f"t{i}"
        g.add_node(op, ins, [out])
        cur = out
    for nm, v in (("qs", qscale), ("qz", 0.0), ("qb", float(bits))):
        g.initializers[nm] = np.asarray(v, np.float64)
    g.add_node("Quant", [cur, "qs", "qz", "qb"], ["y"],
               attrs=dict(signed=signed, narrow=0))
    ranges = analyze(g, {"x": ScaledIntRange.from_scaled_int(
        np.full(C, float(lo)), np.full(C, float(hi)), 1.0, 0.0)})
    (tail,) = find_layer_tails(g, ranges)
    return g, ranges, tail


# ------------------------------------------------- transfer-function units

def test_compose_direction_sign_algebra():
    d = np.array([1.0, -1.0, 1.0, np.nan])
    f = np.array([-1.0, -1.0, 0.0, 0.0])
    out = compose_direction(d, f)
    np.testing.assert_array_equal(out, [-1.0, 1.0, 0.0, 0.0])
    # NaN (unknown) propagates through non-zero factors
    assert np.isnan(compose_direction(np.array([np.nan]),
                                      np.array([1.0]))[0])


def test_negative_mul_reverses_direction():
    g, ranges, tail = tail_graph([("Mul", [-0.05]), ("Tanh", None)])
    cert = certify_tail(g, tail, ranges)
    assert cert.status == "monotone"
    assert cert.method == "transfer"
    assert cert.direction.tolist() == [-1]


def test_mixed_sign_mul_is_representable():
    g, ranges, tail = tail_graph([("Mul", [0.05, -0.05]), ("Tanh", None)],
                                 C=2)
    cert = certify_tail(g, tail, ranges)
    assert cert.status == "representable"
    assert cert.direction.tolist() == [1, -1]


def test_clip_plateau_collapses_direction():
    # range * 0.05 = [-5, 5] clipped from below at 10: constant output
    g3 = Graph(inputs=["x"], outputs=["y"])
    c = g3.add_initializer(np.asarray([0.05]), name="c0")
    lo_t = g3.add_initializer(np.asarray(10.0), name="cl")
    hi_t = g3.add_initializer(np.asarray(20.0), name="ch")
    g3.add_node("Mul", ["x", c], ["t0"])
    g3.add_node("Clip", ["t0", lo_t, hi_t], ["t1"])
    for nm, v in (("qs", 0.1), ("qz", 0.0), ("qb", 4.0)):
        g3.initializers[nm] = np.asarray(v, np.float64)
    g3.add_node("Quant", ["t1", "qs", "qz", "qb"], ["y"],
                attrs=dict(signed=1, narrow=0))
    ranges3 = analyze(g3, {"x": ScaledIntRange.from_scaled_int(
        np.full(1, -100.0), np.full(1, 100.0), 1.0, 0.0)})
    (tail3,) = find_layer_tails(g3, ranges3)
    cert = certify_tail(g3, tail3, ranges3)
    assert cert.status == "monotone"
    assert cert.direction.tolist() == [0]


def test_silu_one_sided_certifies_by_transfer():
    # 0.05 * [0, 100] = [0, 5]: entirely right of the Silu minimum
    g, ranges, tail = tail_graph([("Mul", [0.05]), ("Silu", None)],
                                 lo=0, hi=100)
    cert = certify_tail(g, tail, ranges)
    assert cert.status == "monotone"
    assert cert.method == "transfer"
    assert cert.direction.tolist() == [1]


def test_silu_straddle_grid_fallback_decides():
    # straddles x* = -1.28, but a coarse unsigned quantizer flattens the
    # dip: the quantized staircase is monotone on the grid
    g, ranges, tail = tail_graph([("Mul", [0.05]), ("Silu", None)],
                                 bits=3, signed=0, qscale=0.7)
    cert = certify_tail(g, tail, ranges)
    assert cert.status == "monotone"
    assert cert.method == "grid"


def test_unknown_op_reports_reason():
    g, ranges, tail = tail_graph([("Mul", [0.05]), ("Silu", None)])
    # drop the Silu rule by spoofing an unknown op type
    tail.nodes[1].op_type = "Mystery"
    cert = certify_tail(g, tail, ranges)
    assert not cert.certified
    assert cert.reason == "no-monotone-rule:Mystery"


# ------------------------------------------ certificate-gated extraction

def test_silu_straddle_bisection_guard_regression():
    """Regression (satellite 1): a Silu tail straddling x* ~ -1.28 with a
    fine signed quantizer must be *refused*, not silently bisected into
    wrong thresholds."""
    g, ranges, tail = tail_graph([("Mul", [0.05]), ("Silu", None)],
                                 bits=5, signed=1, qscale=0.01)
    cert = certify_tail(g, tail, ranges)
    assert cert.status == "uncertified"
    assert cert.reason == "nonmonotone-on-grid"
    for method in ("bisect", "edge", "auto"):
        with pytest.raises(ThresholdConversionError) as ei:
            extract_thresholds(g, tail, ranges, method=method)
        assert ei.value.reason == "nonmonotone-on-grid"


def test_decreasing_tail_converts_exactly_via_both_methods():
    for method in ("edge", "bisect"):
        g, ranges, tail = tail_graph([("Mul", [-0.05]), ("Tanh", None)])
        spec = extract_thresholds(g, tail, ranges, method=method)
        assert spec.direction.tolist() == [-1]
        assert float(np.asarray(spec.out_scale).reshape(-1)[0]) < 0
        rep = check_tail_exactness(g, ranges, method=method)
        assert rep.tensors_checked == 1
        assert rep.violations == []


def test_uncertified_tail_marked_and_linted():
    g, ranges, tail = tail_graph([("Mul", [0.05]), ("Silu", None)],
                                 bits=5, signed=1, qscale=0.01)
    specs, reports = convert_tails(g, ranges)
    assert specs == []
    (rep,) = reports
    assert not rep.converted and rep.reason == "nonmonotone-on-grid"
    assert tail.quant_node.attrs["unconverted_reason"] == \
        "nonmonotone-on-grid"
    assert all(n.attrs.get("meta_kernel_reason") == "nonmonotone-on-grid"
               for n in tail.nodes[:-1])
    lint = lint_graph(g, ranges=ranges)
    assert any(f.rule == "unconverted-tail" for f in lint.findings)


def test_lint_flags_missing_certificate():
    g, ranges, tail = tail_graph([("Mul", [0.05]), ("Relu", None)])
    specs, _ = convert_tails(g, ranges)
    assert len(specs) == 1
    (mt,) = [n for n in g.nodes if n.op_type == "MultiThreshold"]
    assert mt.attrs["certificate"] == "monotone:transfer"
    assert not any(f.rule == "uncertified-threshold"
                   for f in lint_graph(g, ranges=ranges).findings)
    del mt.attrs["certificate"]
    assert any(f.rule == "uncertified-threshold"
               for f in lint_graph(g, ranges=ranges).findings)


# ----------------------------------------------------------- fuzz oracle

def test_tail_fuzz_no_violations():
    rep = run_tail_fuzz(n_random=25, seed=0)
    assert rep.graphs >= 25
    assert rep.tensors_checked > 0
    assert rep.violations == []


def test_tail_fuzz_catches_lying_certifier():
    """Satellite 2: a certifier that always claims 'monotone increasing'
    tricks the bisection extractor into wrong thresholds — the
    differential oracle must catch it."""
    from repro.core.thresholds import _tail_params_channels

    def liar(g, tail, ranges):
        C = _tail_params_channels(g, tail)
        return MonotoneCertificate(status="monotone", method="transfer",
                                   direction=np.ones(C, np.int64))

    rep = run_tail_fuzz(n_random=25, seed=0, method="bisect",
                        certifier=liar)
    assert len(rep.violations) > 0
    assert all(v.kind == "tail-exact" for v in rep.violations)


@pytest.mark.parametrize("name", sorted(ALL_WORKLOADS))
def test_workload_tails_bit_exact_over_proven_range(name):
    """Every converted tail on every workload matches the original chain
    over the full proven integer grid (exhaustive <= 2^16 points)."""
    m = SiraModel.from_workload(ALL_WORKLOADS[name]())
    m, _ = Streamline().apply(m)
    rep = check_tail_exactness(m.graph, m.ranges, name=name)
    assert rep.tensors_checked >= 1
    assert rep.violations == []


# ----------------------------------------------------- meta-kernel pricing

def test_select_style_meta_kernel_for_uncertified_tail():
    nm = NodeModel(name="hsw", op_type="HardSwish", kind="elementwise",
                   pixels=1, channels=32, in_bits=8, out_bits=8,
                   reason="nonmonotone-on-grid")
    assert node_styles(nm) == ["meta_kernel"]
    assert select_style(nm) == "meta_kernel"
    assert baseline_style(nm) == "meta_kernel"
    # marked affine op from an uncertified tail: also meta-kernel only
    nm2 = NodeModel(name="mul", op_type="Mul", kind="elementwise",
                    pixels=1, channels=32, reason="grid-too-large:70000")
    assert node_styles(nm2) == ["meta_kernel"]
    # unmarked affine op keeps the cheap styles
    nm3 = NodeModel(name="mul", op_type="Mul", kind="elementwise",
                    pixels=1, channels=32)
    assert "composite" in node_styles(nm3)


def test_threshold_style_alternatives_follow_certificate():
    base = dict(kind="threshold", pixels=1, channels=32, in_bits=12,
                out_bits=4)
    legacy = NodeModel(name="t", op_type="MultiThreshold", **base)
    assert node_styles(legacy) == ["thresholding", "composite", "dsp_mac"]
    relu = NodeModel(name="t", op_type="MultiThreshold",
                     certificate="monotone:transfer", **base)
    assert node_styles(relu) == ["thresholding", "composite", "dsp_mac"]
    grid = NodeModel(name="t", op_type="MultiThreshold",
                     certificate="monotone:grid", **base)
    assert node_styles(grid) == ["thresholding", "meta_kernel"]
    assert baseline_style(grid) == "meta_kernel"


# ------------------------------------------------------- HSW end-to-end

def test_hsw_workload_three_certificate_outcomes():
    res = build_flow(SiraModel.from_workload(make_hsw()))
    by_status = {}
    for r in res.tail_reports:
        by_status.setdefault((r.status, r.converted), []).append(r)
    assert ("monotone", True) in by_status        # Silu layer converts
    assert ("representable", True) in by_status   # mixed-sign Tanh layer
    assert ("uncertified", False) in by_status    # hard-swish straddle
    (unc,) = by_status[("uncertified", False)]
    assert unc.reason == "nonmonotone-on-grid"


def test_hsw_end_to_end_bit_exact():
    wl = make_hsw()
    res = build_flow(SiraModel.from_workload(wl))
    rng = np.random.default_rng(3)
    for _ in range(20):
        x = rng.uniform(0.0, 1.0, size=wl.input_shape)
        y0 = wl.graph.execute({"X": x})[wl.graph.outputs[0]]
        y1 = res.model.graph.execute({"X": x})[res.model.graph.outputs[0]]
        np.testing.assert_allclose(y1, y0, rtol=1e-9, atol=1e-9)


def test_hsw_dataflow_prices_meta_kernel():
    res = build_flow(SiraModel.from_workload(make_hsw()),
                     steps=DATAFLOW_STEPS)
    cmp = compare_sira_vs_baseline(res.model)
    counts = cmp.sira.style_counts()
    assert counts.get("meta_kernel", 0) >= 1     # uncertified fc3 chain
    assert counts.get("thresholding", 0) >= 2    # fc1 + fc2 converted
    meta = [n for n in cmp.sira.nodes if n.style == "meta_kernel"]
    assert any(n.op_type == "HardSwish" for n in meta)
    # certified-but-nonlinear thresholds keep their certificate visible
    # to the pricing layer: the baseline re-expansion is a meta-kernel
    assert cmp.baseline.style_counts().get("meta_kernel", 0) >= 1


def test_existing_workloads_unaffected_by_certification():
    """The four paper workloads are all-ReLU: every tail must still
    convert, certified monotone, with no meta-kernel nodes."""
    for name, mk in WORKLOADS.items():
        res = build_flow(SiraModel.from_workload(mk()),
                         steps=DATAFLOW_STEPS)
        assert all(r.converted for r in res.tail_reports), name
        assert all(r.status == "monotone" for r in res.tail_reports), name
        cmp = compare_sira_vs_baseline(res.model)
        assert cmp.sira.style_counts().get("meta_kernel", 0) == 0, name
