"""Unit + property tests for interval arithmetic primitives."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: pip install .[test]
from hypothesis import given, settings, strategies as st

from repro.core.intervals import (InvalidRangeError, ScaledIntRange,
                                  dot_interval, dyn_dot_interval,
                                  monotonic_fn_interval, mul_intervals)


def test_point_range_integer_detection():
    r = ScaledIntRange.point(np.array([1.0, -3.0]))
    assert r.is_point and r.is_scaled_int
    r2 = ScaledIntRange.point(np.array([1.5]))
    assert r2.is_point and not r2.is_scaled_int


def test_required_bits():
    r = ScaledIntRange.from_scaled_int(-96, 96, 1.0)
    assert r.required_signed_bits() == 8          # paper Fig 12 example
    r2 = ScaledIntRange.from_scaled_int(0, 255, 1.0)
    assert r2.required_unsigned_bits() == 8
    r3 = ScaledIntRange.from_scaled_int(-128, 127, 1.0)
    assert r3.required_signed_bits() == 8


def test_from_scaled_int_consistency():
    r = ScaledIntRange.from_scaled_int(-7, 5, 0.7, 1.0)
    np.testing.assert_allclose(r.lo, -7 * 0.7 + 1.0)
    np.testing.assert_allclose(r.hi, 5 * 0.7 + 1.0)


@given(st.lists(st.floats(-100, 100), min_size=2, max_size=2),
       st.lists(st.floats(-100, 100), min_size=2, max_size=2),
       st.floats(-100, 100), st.floats(-100, 100))
@settings(max_examples=200, deadline=None)
def test_mul_interval_soundness(a, b, xa, xb):
    a_lo, a_hi = min(a), max(a)
    b_lo, b_hi = min(b), max(b)
    x = a_lo + abs(xa) % (a_hi - a_lo + 1e-9)
    y = b_lo + abs(xb) % (b_hi - b_lo + 1e-9)
    x, y = np.clip(x, a_lo, a_hi), np.clip(y, b_lo, b_hi)
    lo, hi = mul_intervals(np.asarray(a_lo), np.asarray(a_hi),
                           np.asarray(b_lo), np.asarray(b_hi))
    assert lo - 1e-6 <= x * y <= hi + 1e-6


@given(st.integers(1, 8), st.integers(1, 5), st.data())
@settings(max_examples=50, deadline=None)
def test_dot_interval_soundness(k, m, data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    w = rng.normal(size=(k, m))
    x_lo = rng.normal(size=(k,)) - 1.0
    x_hi = x_lo + np.abs(rng.normal(size=(k,)))
    lo, hi = dot_interval(w, x_lo, x_hi)
    for _ in range(20):
        x = rng.uniform(x_lo, x_hi)
        y = x @ w
        assert np.all(y >= lo - 1e-9) and np.all(y <= hi + 1e-9)


def test_dot_interval_exact_at_extremes():
    """The bound must be achieved by the minimizing/maximizing vectors."""
    w = np.array([[1.0, -2.0], [3.0, 0.5]])
    x_lo, x_hi = np.array([-1.0, 0.0]), np.array([2.0, 1.0])
    lo, hi = dot_interval(w, x_lo, x_hi)
    # column 0: w=(1,3): max at (2,1) = 5; min at (-1,0) = -1
    assert np.isclose(hi[0], 5.0) and np.isclose(lo[0], -1.0)
    # column 1: w=(-2,0.5): max at (-1,1) = 2.5; min at (2,0) = -4
    assert np.isclose(hi[1], 2.5) and np.isclose(lo[1], -4.0)


@given(st.integers(1, 4), st.integers(1, 4), st.integers(1, 4), st.data())
@settings(max_examples=30, deadline=None)
def test_dyn_dot_soundness(m, k, n, data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    a_lo = rng.normal(size=(m, k)) - 0.5
    a_hi = a_lo + np.abs(rng.normal(size=(m, k)))
    b_lo = rng.normal(size=(k, n)) - 0.5
    b_hi = b_lo + np.abs(rng.normal(size=(k, n)))
    lo, hi = dyn_dot_interval(a_lo, a_hi, b_lo, b_hi)
    for _ in range(10):
        a = rng.uniform(a_lo, a_hi)
        b = rng.uniform(b_lo, b_hi)
        y = a @ b
        assert np.all(y >= lo - 1e-9) and np.all(y <= hi + 1e-9)


def test_monotonic_fn_interval():
    lo, hi = monotonic_fn_interval(np.tanh, np.array(-2.0), np.array(3.0))
    assert np.isclose(lo, np.tanh(-2.0)) and np.isclose(hi, np.tanh(3.0))
    # decreasing function
    lo, hi = monotonic_fn_interval(lambda x: -x, np.array(-2.0),
                                   np.array(3.0))
    assert np.isclose(lo, -3.0) and np.isclose(hi, 2.0)


# --------------------------------------------------------------------------
# invariant validation (InvalidRangeError instead of bare asserts)
# --------------------------------------------------------------------------

@given(st.floats(-1e6, 1e6), st.floats(-1e6, 1e6))
@settings(max_examples=100, deadline=None)
def test_inverted_bounds_always_rejected(a, b):
    lo, hi = min(a, b), max(a, b)
    r = ScaledIntRange(lo=np.asarray(lo), hi=np.asarray(hi))
    r.validate()                                # valid order: never raises
    if hi - lo > 1e-6:
        with pytest.raises(InvalidRangeError):
            ScaledIntRange(lo=np.asarray(hi), hi=np.asarray(lo))


@given(st.integers(-1000, 1000), st.integers(0, 1000),
       st.floats(1e-6, 1e3), st.floats(-1e3, 1e3))
@settings(max_examples=100, deadline=None)
def test_from_scaled_int_always_validates(q_lo, dq, scale, bias):
    r = ScaledIntRange.from_scaled_int(q_lo, q_lo + dq, scale, bias)
    r.validate()
    np.testing.assert_allclose(r.lo, scale * q_lo + bias)
    assert r.required_signed_bits() >= 1


@given(st.floats(-1e3, 0, exclude_max=True))
@settings(max_examples=50, deadline=None)
def test_nonpositive_scale_always_rejected(scale):
    with pytest.raises(InvalidRangeError):
        ScaledIntRange.from_scaled_int(0, 10, scale)
