"""Copy-on-write prefix caching + ServingConfig + sharded decode.

The load-bearing property: with ``prefix_cache=True`` a request's greedy
tokens are **bit-identical** to cold solo serving — on fp and int8 caches,
under preemption and under speculative decode — because shared pages hold
exactly the KV the slot would have recomputed (chain-keyed, so position is
part of a page's identity) and no slot can ever write a page another slot
maps (boundary pages are copied at attach; ``prepare_write`` forks any
other shared page before a write could land).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import get_model
from repro.serve import (KVCacheSpec, PagedKVCache, PrefixIndex, Request,
                         ServingConfig, ServingEngine, derive_kv_spec)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-1.5b", reduced=True)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def int8_spec(setup):
    cfg, model, params = setup
    return derive_kv_spec(model, params)


def _prefix_requests(cfg, n, sys_len=18, suffix_len=2, max_new=4, seed=0):
    """Shared system prompt + unique per-request suffix, request_id
    pinned so the same sampled streams reproduce under solo serving."""
    rng = np.random.default_rng(seed)
    system = rng.integers(0, cfg.vocab, size=(sys_len,))
    return [Request(prompt=np.concatenate(
                        [system, rng.integers(0, cfg.vocab,
                                              size=(suffix_len,))]),
                    max_new_tokens=max_new, request_id=i)
            for i in range(n)]


class _TinyCfg:
    """Minimal model-config stand-in for cache-level tests."""
    n_layers = 2
    n_kv_heads = 2
    hd = 4
    dtype = jnp.float32


def _tiny_cache(**kw):
    kw.setdefault("page_size", 4)
    kw.setdefault("max_seq", 16)
    kw.setdefault("batch_slots", 2)
    kw.setdefault("prefix_cache", True)
    spec = KVCacheSpec.all_fp(_TinyCfg.n_layers)
    return PagedKVCache(_TinyCfg, spec, kw.pop("batch_slots"),
                        kw.pop("max_seq"), **kw)


def _page_content(cache, pg):
    return np.asarray(cache.pages[0]["k"][pg])


def _stamp_page(cache, pg, value):
    for pool in cache.pages:
        pool["k"] = pool["k"].at[pg].set(value)
        pool["v"] = pool["v"].at[pg].set(value)


# ---------------------------------------------------------------------------
# PrefixIndex
# ---------------------------------------------------------------------------

def test_prefix_index_chain_lookup_and_position_identity():
    idx = PrefixIndex()
    a, b = (1, 2, 3, 4), (5, 6, 7, 8)
    assert idx.register([a, b], [10, 11]) == [10, 11]
    assert idx.lookup([a, b]) == [10, 11]
    assert idx.lookup([a]) == [10]
    # position is part of the key: the same tokens under a different
    # parent chain do NOT match (RoPE'd KV would differ)
    assert idx.lookup([b]) == []
    assert idx.lookup([b, a]) == []


def test_prefix_index_first_writer_wins():
    idx = PrefixIndex()
    a = (1, 2, 3, 4)
    idx.register([a], [10])
    # a second walker with the same chain keeps the existing page; its
    # duplicate page is NOT indexed
    assert idx.register([a, (9, 9, 9, 9)], [77, 12]) == [12]
    assert idx.lookup([a]) == [10]
    assert not idx.is_registered(77)


def test_prefix_index_partial_lookup_longest_overlap():
    idx = PrefixIndex()
    a, b = (1, 2, 3, 4), (5, 6, 7, 8)
    idx.register([a, b], [10, 11])
    # mid-page overlap under the matched chain: 2 leading tokens shared
    m, pg = idx.partial_lookup(1, [a], (5, 6, 99, 99))
    assert (m, pg) == (2, 11)
    # no child shares a leading token → no overlap
    m, pg = idx.partial_lookup(1, [a], (42,))
    assert (m, pg) == (0, None)


def test_prefix_index_evict_cascades_subtree():
    idx = PrefixIndex()
    a, b, c = (1,) * 4, (2,) * 4, (3,) * 4
    idx.register([a, b], [10, 11])
    idx.register([a, c], [10, 12])      # second child under the root
    dropped = idx.evict(10)
    # the whole subtree goes: children are unreachable without the root
    assert set(dropped) == {10, 11, 12} and dropped[0] == 10
    assert len(idx) == 0
    assert idx.lookup([a]) == []


# ---------------------------------------------------------------------------
# cache level: refcounts, LRU reuse, fork-on-write isolation
# ---------------------------------------------------------------------------

def test_release_parks_registered_pages_in_lru_and_reattach_reclaims():
    cache = _tiny_cache()
    toks = list(range(8))                       # 2 full pages
    assert cache.grow(0, 8)
    owned = list(cache.owned[0])
    assert cache.register_prefix(0, toks) == 2
    cache.release(0)
    # registered pages park in the LRU (reusable), not the free list
    assert cache.cached_pages == 2 and cache.used_pages == 0
    assert all(pg not in cache.free for pg in owned)
    # a repeat prompt re-attaches them: LRU drains, refcounts bump,
    # the private pages admission allocated go back to the free list
    assert cache.grow(0, 9)
    cached = cache.attach_prefix(0, toks + [99])
    assert cached == 8
    assert cache.cached_pages == 0
    assert cache.owned[0][:2] == owned
    assert all(cache.ref[pg] == 1 for pg in owned)
    # releasing again re-parks them
    cache.release(0)
    assert cache.cached_pages == 2


def test_unregistered_pages_go_straight_to_free_list():
    cache = _tiny_cache()
    assert cache.grow(0, 8)
    cache.release(0)                            # nothing registered
    assert cache.cached_pages == 0
    assert len(cache.free) == cache.num_pages - 1


def test_grow_counts_lru_as_available_and_evicts_oldest():
    cache = _tiny_cache(max_seq=8, num_pages=3)  # pages 1..2 usable
    toks = list(range(8))
    assert cache.grow(0, 8)
    cache.register_prefix(0, toks)
    cache.release(0)
    assert not cache.free and cache.cached_pages == 2
    # the pool looks full but cached-free pages are reclaimable
    assert cache.grow(1, 8)
    assert cache.cached_pages == 0 and len(cache.index) == 0


def test_lru_eviction_cascade_frees_orphaned_descendants():
    cache = _tiny_cache(max_seq=8, num_pages=3)  # free list exhausted
    toks = list(range(8))
    assert cache.grow(0, 8)
    root, leaf = cache.owned[0]
    cache.register_prefix(0, toks)
    cache.release(0)
    # force the *root* to be reclaimed first (release order naturally
    # parks leaves older; this white-box reorder exercises the cascade)
    cache.lru.move_to_end(root, last=False)
    pg = cache._take_page()
    assert pg == root
    # the leaf's registration died with its parent: it fell from the
    # LRU to the free list instead of leaking as an unreachable entry
    assert leaf in cache.free and leaf not in cache.lru
    assert len(cache.index) == 0


def test_fork_on_write_isolates_sharers():
    cache = _tiny_cache()
    toks = list(range(8))
    assert cache.grow(0, 8)
    shared = list(cache.owned[0])
    for mark, pg in enumerate(shared, start=1):
        _stamp_page(cache, pg, float(mark))
    cache.register_prefix(0, toks)
    # slot 1 attaches the same prompt (plus a divergent tail page)
    assert cache.grow(1, 9)
    assert cache.attach_prefix(1, toks + [99]) == 8
    assert cache.owned[1][:2] == shared
    assert all(cache.ref[pg] == 2 for pg in shared)
    table_before = cache.table[0].copy()

    # a write at position 0 would land on shared pages: both must fork
    forks_before = cache.forks
    assert cache.prepare_write(1, 0)
    assert cache.forks == forks_before + 2
    assert all(a != b for a, b in zip(cache.owned[1][:2], shared))
    # fork copies content...
    for mark, pg in enumerate(cache.owned[1][:2], start=1):
        np.testing.assert_array_equal(_page_content(cache, pg),
                                      np.full((4, 2, 4), float(mark)))
    # ...and slot 0 keeps its mapping, refcounts back to 1
    np.testing.assert_array_equal(cache.table[0], table_before)
    assert all(cache.ref[pg] == 1 for pg in shared)
    # slot 1 scribbling on its forked page never reaches slot 0
    _stamp_page(cache, cache.owned[1][0], -1.0)
    np.testing.assert_array_equal(_page_content(cache, shared[0]),
                                  np.full((4, 2, 4), 1.0))


def test_attach_copies_boundary_page_instead_of_sharing():
    cache = _tiny_cache()
    toks = list(range(8))
    assert cache.grow(0, 8)
    shared = list(cache.owned[0])
    _stamp_page(cache, shared[1], 7.0)
    cache.register_prefix(0, toks)
    # new prompt diverges mid-page-2: tokens 0..5 match, 6 differs
    assert cache.grow(1, 8)
    priv = cache.owned[1][1]
    cached = cache.attach_prefix(1, toks[:6] + [42, 43])
    assert cached == 6
    # page 1 shared, page 2 copied into the slot's own page (ref stays 1)
    assert cache.owned[1][0] == shared[0] and cache.owned[1][1] == priv
    assert cache.ref[shared[1]] == 1 and cache.ref[priv] == 1
    np.testing.assert_array_equal(_page_content(cache, priv),
                                  np.full((4, 2, 4), 7.0))
    # the boundary copy counts as a fork
    assert cache.forks == 1


def test_prepare_write_above_frontier_is_noop():
    cache = _tiny_cache()
    toks = list(range(8))
    assert cache.grow(0, 8)
    cache.register_prefix(0, toks)
    assert cache.grow(1, 9)
    cached = cache.attach_prefix(1, toks + [99])
    forks = cache.forks
    # the normal serving flow only writes at/above the attach frontier,
    # which lands in the slot's private tail page: nothing to fork
    assert cache.prepare_write(1, cached)
    assert cache.forks == forks


# ---------------------------------------------------------------------------
# engine level: bit-identical outputs under sharing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv", ["fp", "int8"])
def test_shared_prefix_bit_identical_to_solo(setup, int8_spec, kv):
    """Shared-prefix batch == unshared solo serving, fp AND int8 caches.

    The int8 case is the sharp one: a shared page holds *quantized* KV,
    so sharing is only sound because the chain key guarantees the
    attaching request would have quantized the exact same values."""
    cfg, model, params = setup
    spec = int8_spec if kv == "int8" else "fp"
    reqs = _prefix_requests(cfg, 4)
    eng = ServingEngine(model, params,
                        ServingConfig(batch_slots=2, max_seq=32,
                                      kv_cache=spec, prefix_cache=True))
    solo = ServingEngine(model, params,
                         ServingConfig(batch_slots=1, max_seq=32,
                                       kv_cache=spec))
    outs = eng.generate(reqs)
    for i, r in enumerate(reqs):
        ref = solo.generate([r])[0]
        assert outs[i] == ref, f"request {i} diverged under sharing ({kv})"
    # sharing actually happened: hits recorded, pages went through the LRU
    assert eng.metrics.prefix_hit_rate > 0
    assert eng.cache.cached_pages > 0
    assert eng.cache.used_pages == 0


def test_repeat_prompt_skips_prefill_chunks(setup):
    """A repeated prompt attaches its cached pages: the warm serve runs
    strictly fewer prefill chunks and reports a high hit rate."""
    cfg, model, params = setup
    req = _prefix_requests(cfg, 1, sys_len=22)[0]
    eng = ServingEngine(model, params,
                        ServingConfig(batch_slots=2, max_seq=32,
                                      prefix_cache=True))
    eng.generate([req])
    cold_chunks = eng.metrics.summary()["prefill_chunks"]
    eng.reset_metrics()
    out_warm = eng.generate([req])[0]
    m = eng.metrics
    assert m.summary()["prefill_chunks"] < cold_chunks
    # 24-token prompt, 23 cached (last token always recomputed)
    assert m.prefix_hit_rate == pytest.approx(23 / 24)
    solo = ServingEngine(model, params,
                         ServingConfig(batch_slots=1, max_seq=32))
    assert out_warm == solo.generate([req])[0]


def test_preemption_under_sharing_still_bit_identical(setup):
    """A pool tight enough to preempt with prefix caching on: preempted
    requests replay (re-attaching their own just-released pages when
    cached) and every output still matches solo serving."""
    cfg, model, params = setup
    # 12-token prompts fit admission (4 pages each on an 8-page pool)
    # but 24-token completions need 6 pages each: the pool dries up
    # mid-decode and the newest request is preempted and replayed
    reqs = _prefix_requests(cfg, 3, sys_len=10, suffix_len=2, max_new=12)
    eng = ServingEngine(model, params,
                        ServingConfig(batch_slots=2, max_seq=24,
                                      page_size=4, num_pages=9,
                                      prefix_cache=True))
    outs = eng.generate(reqs)
    assert eng.metrics.preemptions >= 1
    solo = ServingEngine(model, params,
                         ServingConfig(batch_slots=1, max_seq=24,
                                       page_size=4))
    for i, r in enumerate(reqs):
        assert outs[i] == solo.generate([r])[0], \
            f"request {i} diverged under preemption + sharing"


@pytest.mark.parametrize("kv", ["fp", "int8"])
def test_spec_decode_under_sharing_bit_identical(setup, int8_spec, kv):
    """Speculative decode + prefix sharing: rollback garbage lands only
    in pages no other request maps, so the emitted streams still equal
    the per-token, unshared ones — fp and int8."""
    cfg, model, params = setup
    spec = int8_spec if kv == "int8" else "fp"
    rng = np.random.default_rng(3)
    pat = rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32)
    system = np.tile(pat, 3)                    # repetitive → drafts accept
    reqs = [Request(prompt=np.concatenate(
                        [system, rng.integers(0, cfg.vocab, size=(2,))]),
                    max_new_tokens=8, request_id=i) for i in range(3)]
    eng = ServingEngine(model, params,
                        ServingConfig(batch_slots=2, max_seq=32,
                                      kv_cache=spec, prefix_cache=True,
                                      spec_decode="ngram", spec_k=4))
    base = ServingEngine(model, params,
                         ServingConfig(batch_slots=1, max_seq=32,
                                       kv_cache=spec))
    outs = eng.generate(reqs)
    for i, r in enumerate(reqs):
        assert outs[i] == base.generate([r])[0], \
            f"request {i} diverged under speculation + sharing ({kv})"
    assert eng.metrics.summary()["acceptance_rate"] > 0
    assert eng.metrics.prefix_hit_rate > 0


# ---------------------------------------------------------------------------
# ServingConfig
# ---------------------------------------------------------------------------

def test_legacy_kwargs_equal_config_and_warn_once(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(5)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=(6,)),
                    max_new_tokens=4, request_id=i) for i in range(2)]
    import warnings as _w
    with _w.catch_warnings(record=True) as caught:
        _w.simplefilter("always")
        legacy = ServingEngine(model, params, batch_slots=2, max_seq=32,
                               page_size=4, seed=7)
    assert sum(issubclass(w.category, DeprecationWarning)
               for w in caught) == 1
    assert "ServingConfig" in str(caught[0].message)
    new = ServingEngine(model, params,
                        ServingConfig(batch_slots=2, max_seq=32,
                                      page_size=4, seed=7))
    assert legacy.generate(reqs) == new.generate(reqs)
    assert legacy.config == new.config
    # legacy positional batch_slots still works
    with _w.catch_warnings(record=True):
        _w.simplefilter("ignore")
        pos = ServingEngine(model, params, 2, 32, page_size=4, seed=7)
    assert pos.config == new.config


def test_config_plus_loose_kwargs_rejected(setup):
    cfg, model, params = setup
    sc = ServingConfig(batch_slots=1, max_seq=16)
    with pytest.raises(TypeError, match="ambiguous"):
        ServingEngine(model, params, sc, page_size=4)
    with pytest.raises(TypeError, match="ambiguous"):
        ServingEngine(model, params, sc, max_seq=16)
    with pytest.raises(TypeError, match="ServingConfig"):
        ServingEngine(model, params, "paged")
    with pytest.raises(TypeError, match="batch_slots"):
        ServingEngine(model, params)


def test_config_validation():
    with pytest.raises(ValueError, match="batch_slots"):
        ServingConfig(batch_slots=0, max_seq=16)
    with pytest.raises(ValueError, match="max_seq"):
        ServingConfig(batch_slots=1, max_seq=0)
    with pytest.raises(ValueError, match="kv_cache"):
        ServingConfig(batch_slots=1, max_seq=16, kv_cache="int4")
    with pytest.raises(ValueError, match="mode"):
        ServingConfig(batch_slots=1, max_seq=16, mode="pageless")
    with pytest.raises(ValueError, match="num_pages"):
        ServingConfig(batch_slots=1, max_seq=16, num_pages=1)
    with pytest.raises(ValueError, match="full-precision"):
        ServingConfig(batch_slots=1, max_seq=16, mode="static",
                      kv_cache="sira-int8")
    with pytest.raises(ValueError, match="prefix_cache"):
        ServingConfig(batch_slots=1, max_seq=16, mode="static",
                      prefix_cache=True)
    with pytest.raises(ValueError, match="mesh"):
        ServingConfig(batch_slots=1, max_seq=16, mesh="tpu")
    # replace() round-trips through validation
    sc = ServingConfig(batch_slots=2, max_seq=32)
    assert sc.replace(prefix_cache=True).prefix_cache
    with pytest.raises(ValueError, match="page_size"):
        sc.replace(page_size=0)


# ---------------------------------------------------------------------------
# sharded decode
# ---------------------------------------------------------------------------

def test_sharded_decode_matches_unsharded(setup):
    """decode_paged under a mesh (params + KV pools placed, jitted calls
    in the mesh context) emits exactly the unsharded tokens."""
    from repro.launch.mesh import make_debug_mesh
    cfg, model, params = setup
    mesh = make_debug_mesh(len(jax.devices()))
    rng = np.random.default_rng(9)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=(n,)),
                    max_new_tokens=4, request_id=i)
            for i, n in enumerate((7, 5))]
    plain = ServingEngine(model, params,
                          ServingConfig(batch_slots=2, max_seq=32))
    sharded = ServingEngine(model, params,
                            ServingConfig(batch_slots=2, max_seq=32,
                                          mesh=mesh, prefix_cache=True))
    assert plain.generate(reqs) == sharded.generate(reqs)


def test_sharded_decode_two_forced_devices(setup):
    """Same tokens on a 2-device forced-host-platform mesh (subprocess:
    device count is fixed at jax import)."""
    cfg, model, params = setup
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, cfg.vocab, size=(7,))
    ref = ServingEngine(model, params,
                        ServingConfig(batch_slots=1, max_seq=32)
                        ).generate([Request(prompt=prompt,
                                            max_new_tokens=4)])[0]
    script = """
import jax, numpy as np
assert len(jax.devices()) == 2, jax.devices()
from repro.configs import get_config
from repro.models import get_model
from repro.launch.mesh import make_debug_mesh
from repro.serve import Request, ServingConfig, ServingEngine
cfg = get_config("qwen2-1.5b", reduced=True)
model = get_model(cfg)
params = model.init(jax.random.PRNGKey(0))
mesh = make_debug_mesh(2)
assert mesh.devices.size == 2
eng = ServingEngine(model, params,
                    ServingConfig(batch_slots=1, max_seq=32, mesh=mesh))
prompt = np.asarray(%r)
print("TOKENS", eng.generate([Request(prompt=prompt,
                                      max_new_tokens=4)])[0])
""" % (prompt.tolist(),)
    env = dict(os.environ,
               XLA_FLAGS=(os.environ.get("XLA_FLAGS", "") +
                          " --xla_force_host_platform_device_count=2"),
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + sys.path))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("TOKENS")]
    assert line and line[0] == f"TOKENS {ref}"
