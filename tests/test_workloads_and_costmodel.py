"""Paper QNN workloads (Table 5) end-to-end through SIRA + cost models."""
import numpy as np
import pytest

from repro.core import (SiraModel, Streamline, analyze,
                        convert_tails_to_thresholds,
                        minimize_accumulators)
from repro.core.costmodel import (lut_composite_total, lut_threshold_total,
                                  select_tail_style, tail_cost,
                                  tpu_tail_bytes)
from repro.core.verify import stuck_channels, verify_ranges
from repro.core.workloads import WORKLOADS, make_cnv, make_mnv1, make_rn8, \
    make_tfc


def _streamline(graph, input_ranges):
    """Streamline through the pass API; returns the AggregationResult."""
    model, _ = Streamline().apply(SiraModel(graph.copy(), input_ranges))
    return model.metadata["aggregation"]


@pytest.mark.parametrize("maker", [make_tfc, make_cnv, make_rn8, make_mnv1])
def test_workload_streamline_threshold_equivalence(maker):
    wl = maker()
    rng = np.random.default_rng(5)
    res = _streamline(wl.graph, wl.input_range)
    g2, specs = convert_tails_to_thresholds(res.graph, wl.input_range)
    assert len(specs) >= 1
    lo = float(np.min(wl.input_range["X"].lo))
    hi = float(np.max(wl.input_range["X"].hi))
    for _ in range(3):
        x = rng.uniform(lo, hi, size=wl.input_shape)
        y0 = wl.graph.execute({"X": x})[wl.graph.outputs[0]]
        y1 = res.graph.execute({"X": x})[res.graph.outputs[0]]
        y2 = g2.execute({"X": x})[g2.outputs[0]]
        np.testing.assert_allclose(y0, y1, rtol=1e-9, atol=1e-9)
        np.testing.assert_allclose(y0, y2, rtol=1e-9, atol=1e-9)


def test_accumulator_reduction_matches_paper_ballpark():
    """Paper: SIRA accumulators ~22% below the datatype bound on average
    (and 63% below 32-bit).  Scaled-down models land in the same range."""
    bits_s, bits_d = [], []
    for maker in WORKLOADS.values():
        wl = maker()
        res = _streamline(wl.graph, wl.input_range)
        reps = minimize_accumulators(res.graph, wl.input_range)
        bits_s += [r.sira_bits for r in reps]
        bits_d += [r.datatype_bits for r in reps]
    red = 1 - np.mean(bits_s) / np.mean(bits_d)
    assert 0.10 <= red <= 0.45, red
    red32 = 1 - np.mean(bits_s) / 32.0
    assert red32 >= 0.5, red32


def test_verification_and_stuck_channels():
    wl = make_cnv()
    ranges = analyze(wl.graph, wl.input_range)
    rng = np.random.default_rng(0)
    data = [{"X": rng.uniform(-1, 1, size=wl.input_shape)}
            for _ in range(4)]
    rep = verify_ranges(wl.graph, ranges, data)
    assert rep.contained, rep.violations[:3]
    # stuck-channel detection runs (count >= 0)
    quant_outs = [n.outputs[0] for n in wl.graph.nodes
                  if n.op_type == "Quant"]
    n_stuck = int(sum(stuck_channels(ranges, t).sum()
                      for t in quant_outs if t in ranges))
    assert n_stuck >= 0


# ------------------------------------------------------------- cost model

def test_threshold_cost_exponential_in_bits():
    c4 = lut_threshold_total(16, 4, 128, 2)
    c8 = lut_threshold_total(16, 8, 128, 2)
    assert c8 > 8 * c4            # memory term grows ~2^n_o


def test_composite_cost_linear_in_bits():
    c4 = lut_composite_total(16, 16, 128, 2)
    c8 = lut_composite_total(32, 16, 128, 2)
    assert c8 < 3 * c4


def test_crossover_matches_paper():
    """Paper §7.3.2: <4-bit outputs → thresholding wins; >8-bit →
    composite wins."""
    assert select_tail_style(24, 2, 16, 256, 4) == "thresholding"
    assert select_tail_style(24, 3, 16, 256, 4) == "thresholding"
    assert select_tail_style(24, 10, 16, 256, 4) == "composite"
    # large channel counts push the middle region toward composite
    tc = tail_cost(24, 8, 16, 512, 1)
    assert tc.composite_luts < tc.thresholding_luts


def test_tpu_tail_bytes_fusion_win():
    """The fused tail (thresholding kernel) moves ~5x fewer HBM bytes than
    the unfused composite chain — the TPU analogue of the LUT savings."""
    n = 1 << 20
    unfused = tpu_tail_bytes(n, 32, 4, 256, "composite", fused=False)
    fused = tpu_tail_bytes(n, 32, 4, 256, "thresholding")
    assert unfused > 4 * fused
