"""Training substrate: loss decreases, checkpoint/restart is bit-exact,
data pipeline determinism + elastic resharding, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import TokenPipeline
from repro.models import get_model
from repro.optim import AdamW
from repro.train import (compress_grads, init_error_feedback,
                         init_train_state, latest_checkpoint,
                         make_train_step, restore_checkpoint,
                         save_checkpoint)
from repro.train.compression import dequantize_tensor, quantize_tensor


def _setup(arch="qwen2-1.5b", compress=False, microbatches=1):
    cfg = get_config(arch, reduced=True)
    model = get_model(cfg)
    opt = AdamW(lr=3e-3, warmup_steps=2, total_steps=50)
    state = init_train_state(model, opt, jax.random.PRNGKey(0),
                             compress=compress)
    step = jax.jit(make_train_step(model, opt, remat=False,
                                   compress=compress,
                                   microbatches=microbatches))
    pipe = TokenPipeline(seq_len=32, global_batch=4, vocab=cfg.vocab)
    return cfg, model, opt, state, step, pipe


def test_loss_decreases():
    cfg, model, opt, state, step, pipe = _setup()
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]), losses


def test_microbatching_equivalent():
    """Gradient accumulation over microbatches == full-batch step."""
    cfg, model, opt, state, step1, pipe = _setup(microbatches=1)
    _, _, _, _, step4, _ = _setup(microbatches=4)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    s1, m1 = step1(state, batch)
    s4, m4 = step4(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    d = jax.tree.map(lambda a, b: float(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)).max()),
        s1.params, s4.params)
    assert max(jax.tree.leaves(d)) < 1e-4


def test_checkpoint_restart_bitexact(tmp_path):
    """Train 6 steps straight == train 3, checkpoint, kill, resume 3."""
    cfg, model, opt, state_a, step, pipe = _setup()
    state_b = state_a

    for i in range(6):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        state_a, _ = step(state_a, batch)

    for i in range(3):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        state_b, _ = step(state_b, batch)
    save_checkpoint(str(tmp_path), 3, state_b, extra={"data_step": 3})
    del state_b

    # simulate a fresh process: restore into a template
    template = init_train_state(model, opt, jax.random.PRNGKey(0))
    ck = latest_checkpoint(str(tmp_path))
    state_c, extra = restore_checkpoint(ck, template)
    assert extra["data_step"] == 3
    for i in range(extra["data_step"], 6):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        state_c, _ = step(state_c, batch)

    for pa, pc in zip(jax.tree.leaves(state_a.params),
                      jax.tree.leaves(state_c.params)):
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pc))


def test_restore_rejects_mismatched_structure(tmp_path):
    """Leaves are stored positionally: restoring into a template with a
    different tree structure must raise, not silently scramble tensors
    (regression — previously the treedef was saved but never checked)."""
    cfg, model, opt, state, step, pipe = _setup()
    path = save_checkpoint(str(tmp_path), 0, state)
    # different structure: compression adds error-feedback leaves
    bad = init_train_state(model, opt, jax.random.PRNGKey(0),
                           compress=True)
    with pytest.raises(ValueError, match="different state structure"):
        restore_checkpoint(path, bad)


def test_restore_rejects_mismatched_shapes(tmp_path):
    """Same tree structure but different tensor shapes (e.g. a different
    model width) must raise with the offending leaf named."""
    cfg, model, opt, state, step, pipe = _setup()
    path = save_checkpoint(str(tmp_path), 0, state)
    wrong = jax.tree.map(
        lambda p: jnp.zeros(p.shape + (2,), p.dtype)
        if getattr(p, "ndim", 0) == 2 else p, state)
    with pytest.raises(ValueError, match="template shape"):
        restore_checkpoint(path, wrong)


def test_checkpoint_atomic_and_gc(tmp_path):
    cfg, model, opt, state, step, pipe = _setup()
    for s in range(5):
        save_checkpoint(str(tmp_path), s, state, keep=2)
    names = sorted(os.listdir(tmp_path))
    assert len([n for n in names if n.endswith(".npz")]) == 2
    assert not [n for n in names if n.endswith(".tmp")]


def test_pipeline_determinism_and_elastic_resharding():
    pipe1 = TokenPipeline(seq_len=16, global_batch=8, vocab=100,
                          host_id=0, n_hosts=1)
    full = pipe1.batch_at(7)["tokens"]
    # two hosts each take half the stream — union equals the full batch
    shards = [TokenPipeline(seq_len=16, global_batch=8, vocab=100,
                            host_id=h, n_hosts=2).batch_at(7)["tokens"]
              for h in range(2)]
    merged = np.empty_like(full)
    merged[0::2] = shards[0]
    merged[1::2] = shards[1]
    np.testing.assert_array_equal(full, merged)
    # determinism
    np.testing.assert_array_equal(full, pipe1.batch_at(7)["tokens"])


def test_grad_compression_roundtrip():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(64, 64)) * 1e-3)
    q, s = quantize_tensor(g)
    deq = dequantize_tensor(q, s)
    assert float(jnp.abs(deq - g).max()) <= float(s) * 0.5 + 1e-12


def test_error_feedback_preserves_signal():
    """With error feedback, the *cumulative* compressed gradient tracks the
    cumulative true gradient (bounded residual)."""
    rng = np.random.default_rng(1)
    grads = {"w": jnp.zeros((32,))}
    ef = init_error_feedback(grads)
    total_true = np.zeros(32)
    total_sent = np.zeros(32)
    for i in range(50):
        g = {"w": jnp.asarray(rng.normal(size=(32,)) * (1e-4 if i % 2
                                                        else 1.0))}
        total_true += np.asarray(g["w"])
        deq, ef = compress_grads(g, ef)
        total_sent += np.asarray(deq["w"])
    resid = np.abs(total_true - total_sent).max()
    # residual bounded by one quantization step of the largest tensor
    assert resid < 0.02, resid


def test_compressed_training_converges():
    cfg, model, opt, state, step, pipe = _setup(compress=True)
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_compressed_psum_shard_map():
    """compressed_psum inside shard_map ≈ plain psum (int8 wire)."""
    from jax.sharding import PartitionSpec as P
    from repro.train import compressed_psum
    shard_map = getattr(jax, "shard_map", None)
    if shard_map is None:  # jax < 0.5
        from jax.experimental.shard_map import shard_map
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    mesh = jax.make_mesh((1,), ("pod",))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(8,)),
                    jnp.float32)
    out = jax.jit(shard_map(
        lambda v: compressed_psum(v, "pod"), mesh=mesh,
        in_specs=P(), out_specs=P()))(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x),
                               atol=float(jnp.abs(x).max()) / 100)


def test_supervised_restart_recovers(tmp_path):
    """Simulated preemptions mid-training: the supervisor resumes from the
    latest checkpoint and completes, final params identical to a fault-free
    run."""
    from repro.train.elastic import run_supervised

    cfg, model, opt, state0, step, pipe = _setup()

    def make_train_fn(fail_at):
        holder = {"state": state0, "failed": set()}

        def train_fn(start_step):
            state = holder["state"]
            ck = latest_checkpoint(str(tmp_path))
            if ck:
                state, extra = restore_checkpoint(ck, state0)
                start_step = extra["data_step"]
            for i in range(start_step, 8):
                batch = {k: jnp.asarray(v)
                         for k, v in pipe.batch_at(i).items()}
                state, _ = step(state, batch)
                save_checkpoint(str(tmp_path), i + 1, state,
                                extra={"data_step": i + 1})
                if i in fail_at and i not in holder["failed"]:
                    holder["failed"].add(i)
                    raise RuntimeError("simulated preemption")
            holder["state"] = state
            return 8
        return train_fn, holder

    fn, holder = make_train_fn(fail_at={2, 5})
    rep = run_supervised(fn, total_steps=8, ckpt_dir=str(tmp_path))
    assert rep.restarts == 2 and rep.completed_steps == 8

    # fault-free reference
    ref = state0
    for i in range(8):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        ref, _ = step(ref, batch)
    for a, b in zip(jax.tree.leaves(holder["state"].params),
                    jax.tree.leaves(ref.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
