"""Dataflow DSE subsystem: analytical resource/II/FIFO models validated
against the cycle-accurate stream simulator, Fig-23 style-selection pins,
folding search, SIRA-vs-baseline reductions (the acceptance criteria),
and build-flow integration."""
import math

import numpy as np
import pytest

from repro.core import DATAFLOW_STEPS, build_flow
from repro.core.workloads import WORKLOADS, make_cnv, make_tfc
from repro.dataflow import (DeviceBudget, NodeModel, SimEdge, SimNode,
                            analytical_ii, compare_sira_vs_baseline,
                            cycles_per_frame, estimate, extract_dataflow,
                            fifo_depth, fifo_resources, fold_options,
                            from_estimate, get_device, max_throughput,
                            node_resources, search_folding, select_style,
                            select_tail_style, simulate, widen_dataflow)


@pytest.fixture(scope="module")
def models():
    """Optimized SiraModels of all four QNN workloads (built once)."""
    return {name: build_flow(maker()).model
            for name, maker in WORKLOADS.items()}


# --------------------------------------------------------------------------
# property test: analytical II + FIFO depths vs the cycle-accurate sim
# --------------------------------------------------------------------------

def _sized_edges(nodes, topology):
    """FIFO-size a topology exactly as ``estimate`` does: analytical
    depths from rate imbalance + join-latency skew."""
    by = {n.name: n for n in nodes}
    ii = {n.name: n.stride * n.outputs_per_frame for n in nodes}
    producers_of = {}
    for s, d in topology:
        producers_of.setdefault(nodes[d].name, []).append(nodes[s].name)
    lat = {}
    for n in nodes:
        best = 0.0
        for p in producers_of.get(n.name, ()):
            cin = by[p].outputs_per_frame
            ipo = max(1, math.ceil(cin / n.outputs_per_frame))
            best = max(best, lat[p] + ipo * ii[p] / by[p].outputs_per_frame)
        lat[n.name] = best + n.stride
    edges = []
    for s, d in topology:
        p, c = nodes[s], nodes[d]
        arrivals = {pp: lat[pp] for pp in producers_of[c.name]}
        skew = max(arrivals.values()) - arrivals[p.name]
        cin = p.outputs_per_frame
        ipo = max(1, math.ceil(cin / c.outputs_per_frame))
        depth = fifo_depth(cin, ii[p.name], ii[c.name], ipo=ipo,
                           skew_cycles=skew)
        edges.append(SimEdge(src=p.name, dst=c.name, cin=cin,
                             cout=c.outputs_per_frame, depth=depth))
    return edges


def test_analytical_models_match_simulator_on_random_graphs():
    """Property: on randomized small chains and diamonds, the analytically
    sized FIFOs never deadlock and never degrade steady-state throughput —
    the simulated cycles-per-frame equals the analytical max-node-II."""
    rng = np.random.default_rng(0)
    for trial in range(60):
        if trial % 3 < 2:                       # chain, 2-5 nodes
            n = int(rng.integers(2, 6))
            nodes = [SimNode(f"n{i}", int(rng.integers(1, 6)),
                             int(rng.integers(1, 9))) for i in range(n)]
            topo = [(i, i + 1) for i in range(n - 1)]
        else:                                    # diamond (join skew)
            nodes = [SimNode(f"n{i}", int(rng.integers(1, 6)),
                             int(rng.integers(1, 9))) for i in range(4)]
            topo = [(0, 1), (0, 2), (1, 3), (2, 3)]
        edges = _sized_edges(nodes, topo)
        res = simulate(nodes, edges, frames=5)
        assert not res.deadlocked, (nodes, topo)
        assert res.cycles_per_frame == analytical_ii(nodes), (nodes, topo)
        for e in edges:                          # capacity never exceeded
            assert res.max_occupancy[(e.src, e.dst)] <= e.depth


def test_simulator_validates_real_tfc_estimate(models):
    """The analytical graph estimate of the real (streamlined) TFC model
    reproduces exactly in the cycle-accurate simulator."""
    est = estimate(models["TFC-w2a2"])
    nodes, edges = from_estimate(est)
    res = simulate(nodes, edges, frames=3)
    assert not res.deadlocked
    assert res.cycles_per_frame == est.max_cycles


def test_undersized_fifo_degrades_or_deadlocks():
    """Sanity that the simulator actually exercises backpressure: a
    depth-starved FIFO between a slow producer and a bursty consumer
    cannot sustain the analytical II."""
    nodes = [SimNode("a", 1, 8), SimNode("b", 8, 1)]
    good = simulate(nodes, [SimEdge("a", "b", 8, 1,
                                    fifo_depth(8, 8, 8, ipo=8))], frames=5)
    assert good.cycles_per_frame == analytical_ii(nodes)
    bad = simulate(nodes, [SimEdge("a", "b", 8, 1, 1)], frames=5)
    assert bad.deadlocked or bad.cycles_per_frame > analytical_ii(nodes)


# --------------------------------------------------------------------------
# Fig 23 regression pins: select_tail_style crossover points
# --------------------------------------------------------------------------

@pytest.mark.parametrize("channels,pe,crossover", [
    (64, 1, 7), (64, 4, 9),
    (256, 1, 5), (256, 4, 7), (256, 16, 8),
    (1024, 1, 4), (1024, 16, 7),
])
def test_fig23_crossover_pins(channels, pe, crossover):
    """Pin the output-bitwidth at which the per-tail style flips from
    thresholding to composite (Fig 23 shape: more channels or less
    parallelism moves the crossover down)."""
    styles = [select_tail_style(24, n_o, 16, channels, pe)
              for n_o in range(2, 11)]
    flip = next((n_o for n_o, s in zip(range(2, 11), styles)
                 if s == "composite"), None)
    assert flip == crossover
    # monotone: once composite wins it stays won (threshold memory is
    # exponential in n_o, composite is constant)
    assert styles == sorted(styles, key=lambda s: s == "composite")


def test_select_tail_style_paper_rule_boundaries():
    """§7.3.2: <4-bit outputs are always thresholding, >8-bit always
    composite, regardless of what the models would prefer."""
    assert select_tail_style(24, 3, 16, 10**6, 1) == "thresholding"
    assert select_tail_style(24, 9, 16, 1, 1) == "composite"


# --------------------------------------------------------------------------
# per-node models
# --------------------------------------------------------------------------

def test_cycles_monotone_in_folding():
    nm = NodeModel(name="m", op_type="MatMul", kind="mvau", pixels=4,
                   channels=12, K=30)
    opts = fold_options(nm)
    assert all(nm.channels % pe == 0 and nm.K % simd == 0
               for pe, simd in opts)
    full = cycles_per_frame(nm, 1, 1)
    assert full == 4 * 12 * 30
    for pe, simd in opts:
        assert cycles_per_frame(nm, pe, simd) <= full
    assert cycles_per_frame(nm, 12, 30) == 4


def test_mvau_style_follows_bitwidths():
    """SIRA-narrowed MACs map to LUTs, wide ones to DSP slices — the
    bitwidth-driven style selection of §7.3.2 generalized to MVAUs."""
    narrow = NodeModel(name="n", op_type="MatMul", kind="mvau", pixels=1,
                       channels=64, K=64, in_bits=2, weight_bits=2,
                       acc_bits=12)
    wide = NodeModel(name="w", op_type="MatMul", kind="mvau", pixels=1,
                     channels=64, K=64, in_bits=8, weight_bits=8,
                     acc_bits=24)
    assert select_style(narrow) == "lut_mac"
    assert select_style(wide) == "dsp_mac"
    # DSP packing: two 8-bit MACs per slice
    r = node_resources(wide, "dsp_mac", pe=4, simd=2)
    assert r.dsps == 4
    r16 = node_resources(
        NodeModel(name="w16", op_type="MatMul", kind="mvau", pixels=1,
                  channels=64, K=64, in_bits=16, weight_bits=16,
                  acc_bits=40), "dsp_mac", pe=4, simd=2)
    assert r16.dsps == 8


def test_fifo_resources_srl_vs_bram_cutover():
    small = fifo_resources(depth=16, width_bits=8)       # 128 bits: SRL
    assert small.brams == 0 and small.luts > 0
    big = fifo_resources(depth=4096, width_bits=32)      # 128Kb: BRAM
    assert big.brams >= 1


def test_get_device_unknown_raises():
    with pytest.raises(KeyError, match="unknown device"):
        get_device("nonexistent-part")


# --------------------------------------------------------------------------
# acceptance criteria: SIRA vs baseline on all four QNN workloads
# --------------------------------------------------------------------------

def test_sira_reduces_resources_on_all_workloads(models):
    """The paper's headline direction on every workload: fewer LUTs,
    fewer DSPs, narrower mean accumulators than the datatype-bound
    baseline on the same topology and folding."""
    for name, model in models.items():
        comp = compare_sira_vs_baseline(model)
        assert comp.lut_reduction > 0, name
        assert comp.dsp_reduction > 0, name
        assert comp.acc_bits_reduction > 0, name
        assert comp.mean_acc_bits_sira < comp.mean_acc_bits_datatype, name
        # same topology on both sides — only widths/styles differ
        assert len(comp.sira.nodes) == len(comp.baseline.nodes)
        assert [n.cycles for n in comp.sira.nodes] == \
            [n.cycles for n in comp.baseline.nodes]


def test_extract_dataflow_tfc_structure(models):
    """TFC streamlines to an MVAU/threshold ladder; every compute node
    and every inter-node stream is modeled."""
    dfg = extract_dataflow(models["TFC-w2a2"])
    kinds = [n.kind for n in dfg.nodes]
    assert kinds.count("mvau") == 3          # three FC layers
    assert kinds.count("threshold") == 2     # two quantized activations
    assert len(dfg.edges) == len(dfg.nodes) - 1   # pure chain


def test_baseline_styles_are_conservative(models):
    comp = compare_sira_vs_baseline(models["TFC-w2a2"])
    assert set(comp.baseline.style_counts()) == {"dsp_mac", "composite"}
    assert "thresholding" in comp.sira.style_counts()


# --------------------------------------------------------------------------
# folding search
# --------------------------------------------------------------------------

def test_folding_hits_target_fps_within_budget(models):
    fold = search_folding(models["TFC-w2a2"], target_fps=1000.0,
                          device="pynq-z1")
    assert fold.feasible and fold.binding is None
    assert fold.achieved_fps >= 1000.0
    assert all(v <= 1.0 for v in fold.utilization.values())
    # a tighter target than the fully-folded II (4096 cycles ≈ 24k FPS)
    # forces the search to actually parallelize the bottleneck MVAUs
    tight = search_folding(models["TFC-w2a2"], target_fps=100_000.0,
                           device="pynq-z1")
    assert tight.feasible and tight.achieved_fps >= 100_000.0
    assert any(pe * simd > 1 for pe, simd in tight.folding.values())


def test_folding_infeasible_budget_reports_binding_resource(models):
    tiny = DeviceBudget("tiny", luts=400, dsps=1, brams=1)
    fold = search_folding(models["TFC-w2a2"], target_fps=1000.0,
                          device=tiny)
    assert not fold.feasible
    assert fold.binding in ("luts", "dsps", "brams")
    assert fold.utilization[fold.binding] > 1.0


def test_folding_infeasible_throughput_reports_binding_node():
    """A conv workload cannot stream one frame per clock cycle: the
    throughput-bound node is named in the binding constraint."""
    model = build_flow(make_cnv()).model
    fold = search_folding(model, target_fps=99e6, device="u250")
    assert not fold.feasible
    assert fold.binding.startswith("ii:")


def test_folding_search_prices_widened_nodes(models):
    """The search must optimize the same cost model estimate() judges
    with: raw extracted MVAUs carry a placeholder acc_bits=32 that would
    inflate every MAC toward dsp_mac."""
    model = models["TFC-w2a2"]
    dfg = extract_dataflow(model)
    wide = widen_dataflow(model, dfg)
    mvaus = [n for n in dfg.nodes if n.kind == "mvau"]
    assert mvaus and all(wide[n.name].acc_bits < 32 for n in mvaus)
    tight = search_folding(model, target_fps=100_000.0, device="pynq-z1")
    styles = {n.name: n.style for n in tight.estimate.nodes}
    assert any(styles[n.name] == "lut_mac" for n in mvaus)


def test_extract_dataflow_folds_constant_weight_prep(models):
    """A weight produced by an all-constant subgraph (e.g. a wscale Mul)
    stays a weight memory with its proven SIRA width — it must not
    become a dynamic stream or fall back to a default width."""
    dfg = extract_dataflow(models["CNV-w2a2"])
    mvaus = [n for n in dfg.nodes if n.kind == "mvau"]
    assert all(n.weight_bits <= 4 for n in mvaus)   # w2a2 conv + fc
    consumers = {e.consumer for e in dfg.edges}
    producers = {e.producer for e in dfg.edges}
    # every modeled stream connects two compute nodes of the graph
    names = {n.name for n in dfg.nodes}
    assert consumers <= names and producers <= names


def test_max_throughput_is_feasible_and_fastest(models):
    model = models["TFC-w2a2"]
    best = max_throughput(model, device="pynq-z1")
    assert best.feasible
    slow = search_folding(model, target_fps=1000.0, device="pynq-z1")
    assert best.achieved_fps >= slow.achieved_fps


# --------------------------------------------------------------------------
# build-flow integration + shims
# --------------------------------------------------------------------------

def test_dataflow_flow_steps_ride_cached_analysis():
    result = build_flow(make_tfc(), steps=DATAFLOW_STEPS,
                        target_fps=1000.0)
    report = result.model.metadata["dataflow_report"]
    folding = result.model.metadata["folding"]
    assert report.lut_reduction > 0
    assert folding.feasible
    by_name = {s.name: s for s in result.steps}
    assert by_name["DataflowEstimate"].analysis_calls == 0
    assert by_name["DataflowFold"].analysis_calls == 0
    assert not by_name["DataflowEstimate"].modified


def test_core_costmodel_shim_resolves_to_dataflow():
    """The absorbed module keeps working: same objects, not copies."""
    from repro.core import costmodel as old
    from repro.dataflow import costmodel as new
    assert old.select_tail_style is new.select_tail_style
    assert old.lut_composite_total is new.lut_composite_total
    assert old.ELEMENTWISE_COEFFS is new.ELEMENTWISE_COEFFS
    assert "tail_cost" in dir(old)
    with pytest.raises(AttributeError):
        old.not_a_cost_model
