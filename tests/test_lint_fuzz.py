"""Graph linter (seeded malformed graphs) + soundness fuzzing harness +
InvalidRangeError invariants (always-on, no optional deps)."""
import numpy as np
import pytest

from repro.core import (Graph, InvalidRangeError, LintGraph, ScaledIntRange,
                        SiraModel, build_flow)
from repro.core.fuzz import check_containment, random_graph, run_fuzz
from repro.core.lint import LintError, lint_graph
from repro.core.workloads import WORKLOADS, make_tfc


def _rules(report):
    return {f.rule for f in report.findings}


# --------------------------------------------------------------------------
# InvalidRangeError invariants (satellite: asserts -> typed errors)
# --------------------------------------------------------------------------

def test_inverted_interval_raises():
    with pytest.raises(InvalidRangeError, match="inverted"):
        ScaledIntRange(lo=np.asarray(2.0), hi=np.asarray(1.0))


def test_nan_bound_raises():
    with pytest.raises(InvalidRangeError, match="NaN"):
        ScaledIntRange(lo=np.asarray(np.nan), hi=np.asarray(1.0))


def test_nonpositive_scale_raises():
    with pytest.raises(InvalidRangeError, match="positive"):
        ScaledIntRange.from_scaled_int(0, 10, scale=-0.5)
    with pytest.raises(InvalidRangeError, match="positive"):
        ScaledIntRange.from_scaled_int(0, 10, scale=0.0)


def test_missing_integer_component_raises():
    r = ScaledIntRange(lo=np.asarray(0.0), hi=np.asarray(1.0))
    with pytest.raises(InvalidRangeError):
        r.required_signed_bits()
    with pytest.raises(InvalidRangeError):
        r.required_unsigned_bits()
    # InvalidRangeError is a ValueError, so legacy except-clauses survive
    assert issubclass(InvalidRangeError, ValueError)


# --------------------------------------------------------------------------
# linter: seeded malformed graphs, node-level findings
# --------------------------------------------------------------------------

def _vec_range(n, lo=0.0, hi=1.0):
    return ScaledIntRange(lo=np.full(n, lo), hi=np.full(n, hi))


def test_lint_clean_graph_is_ok():
    g = Graph(inputs=["x"], outputs=["y"])
    c = g.add_initializer(np.ones(3), name="c")
    g.add_node("Add", ["x", c], ["y"], name="add0")
    rep = lint_graph(g, {"x": _vec_range(3)}, input_shapes={"x": (3,)})
    assert rep.ok and not rep.findings


def test_lint_dangling_tensor():
    g = Graph(inputs=["x"], outputs=["y"])
    g.add_node("Add", ["x", "ghost"], ["y"], name="add0")
    rep = lint_graph(g)
    assert "dangling-input" in _rules(rep)
    (f,) = [f for f in rep.errors if f.rule == "dangling-input"]
    assert f.node == "add0" and "ghost" in f.message


def test_lint_dangling_graph_output():
    g = Graph(inputs=["x"], outputs=["never_made"])
    g.add_node("Relu", ["x"], ["y"], name="r0")
    rep = lint_graph(g)
    assert "dangling-output" in _rules(rep)


def test_lint_shape_mismatch_matmul():
    g = Graph(inputs=["x"], outputs=["y"])
    w = g.add_initializer(np.ones((4, 2)), name="W")
    g.add_node("MatMul", ["x", w], ["y"], name="mm0")
    rep = lint_graph(g, input_shapes={"x": (5,)})
    (f,) = [f for f in rep.errors if f.rule == "contraction-mismatch"]
    assert f.node == "mm0"


def test_lint_conv_channels_and_groups():
    g = Graph(inputs=["x"], outputs=["y"])
    w = g.add_initializer(np.ones((6, 3, 3, 3)), name="W")
    g.add_node("Conv", ["x", w], ["y"], name="conv0",
               attrs=dict(groups=4))
    rep = lint_graph(g, input_shapes={"x": (1, 8, 8, 8)})
    assert "groups-mismatch" in _rules(rep)      # 4 does not divide 6
    assert "channels-mismatch" in _rules(rep)    # 8 != 3*4


def test_lint_broadcast_mismatch():
    g = Graph(inputs=["x"], outputs=["y"])
    c = g.add_initializer(np.ones(4), name="c")
    g.add_node("Add", ["x", c], ["y"], name="add0")
    rep = lint_graph(g, input_shapes={"x": (3,)})
    (f,) = [f for f in rep.errors if f.rule == "broadcast-mismatch"]
    assert f.node == "add0"


def test_lint_threshold_table_checks():
    g = Graph(inputs=["x"], outputs=["y"])
    thr = g.add_initializer(np.array([[3.0, 1.0, 2.0]]), name="thr")
    g.add_node("MultiThreshold", ["x", thr], ["y"], name="mt0")
    rep = lint_graph(g)
    assert "threshold-order" in _rules(rep)


def test_lint_duplicate_producer_and_cycle():
    g = Graph(inputs=["x"], outputs=["y"])
    g.add_node("Relu", ["x"], ["t"], name="r0")
    g.add_node("Relu", ["x"], ["t"], name="r1")
    rep = lint_graph(g)
    assert "duplicate-producer" in _rules(rep)

    g2 = Graph(inputs=["x"], outputs=["y"])
    g2.add_node("Add", ["x", "b"], ["a"], name="n0")
    g2.add_node("Relu", ["a"], ["b"], name="n1")
    rep2 = lint_graph(g2)
    assert "cycle" in _rules(rep2)


def test_lint_inverted_declared_range():
    r = ScaledIntRange(lo=np.asarray(0.0), hi=np.asarray(1.0))
    object.__setattr__(r, "lo", np.asarray(2.0))   # corrupt post-hoc
    g = Graph(inputs=["x"], outputs=["y"])
    g.add_node("Relu", ["x"], ["y"], name="r0")
    rep = lint_graph(g, {"x": r})
    (f,) = [f for f in rep.errors if f.rule == "invalid-range"]
    assert "inverted" in f.message


def test_lint_stale_contribution_sources():
    r = ScaledIntRange.from_scaled_int(
        0, 10, 0.5, scale_src=frozenset({"not_an_initializer"}))
    g = Graph(inputs=["x"], outputs=["y"])
    g.add_node("Relu", ["x"], ["y"], name="r0")
    rep = lint_graph(g, {"x": r})
    assert "stale-contribution" in _rules(rep)


def test_lint_unknown_op():
    g = Graph(inputs=["x"], outputs=["y"])
    g.add_node("FrobnicateOp", ["x"], ["y"], name="f0")
    rep = lint_graph(g)
    assert "no-handler" in _rules(rep)


# --------------------------------------------------------------------------
# LintGraph pass + build_flow integration
# --------------------------------------------------------------------------

def test_lintgraph_pass_strict_raises_and_records():
    g = Graph(inputs=["x"], outputs=["y"])
    g.add_node("Add", ["x", "ghost"], ["y"], name="add0")
    m = SiraModel(g, {"x": _vec_range(3)})
    with pytest.raises(LintError, match="dangling-input"):
        LintGraph(strict=True).apply(m)
    m2, modified = LintGraph(strict=False).apply(m)
    assert not modified and not m2.metadata["lint"].ok


def test_build_flow_prelints():
    wl = make_tfc()
    res = build_flow(wl)
    assert res.steps[0].name == "lint_graph"
    assert res.model.metadata["lint"].ok

    # a broken graph is rejected before any transform runs
    g = Graph(inputs=["x"], outputs=["y"])
    g.add_node("Add", ["x", "ghost"], ["y"])
    m = SiraModel(g, {"x": _vec_range(3)})
    with pytest.raises(LintError):
        build_flow(m, steps=[])
    res2 = build_flow(m, steps=[], lint="warn")
    assert not res2.model.metadata["lint"].ok
    res3 = build_flow(m, steps=[], lint="off")
    assert "lint" not in res3.model.metadata


def test_lint_all_workloads_clean():
    for name, factory in WORKLOADS.items():
        wl = factory()
        rep = lint_graph(wl.graph, wl.input_range,
                         input_shapes={wl.graph.inputs[0]: wl.input_shape})
        assert rep.ok, f"{name}: {rep}"


# --------------------------------------------------------------------------
# soundness fuzzing
# --------------------------------------------------------------------------

def test_fuzz_random_graphs_no_violations():
    rep = run_fuzz(n_random=12, n_samples=4, seed=7, workloads=False)
    assert rep.graphs == 12 and rep.samples > 0
    assert rep.ok, "\n".join(str(v) for v in rep.violations[:5])


def test_fuzz_workloads_raw_and_optimized():
    rep = run_fuzz(n_random=0, n_samples=4, workloads=True, optimized=True)
    assert rep.graphs == 2 * len(WORKLOADS)
    assert rep.ok, "\n".join(str(v) for v in rep.violations[:5])


def test_fuzz_detects_seeded_unsoundness():
    """The oracle itself must flag a deliberately broken analysis: feed a
    graph whose declared input range is narrower than the sampling box."""
    g = Graph(inputs=["x"], outputs=["y"])
    g.add_node("Relu", ["x"], ["y"])
    wide = {"x": ScaledIntRange(lo=np.asarray(-2.0), hi=np.asarray(2.0))}
    rep = check_containment(g, wide, (4,), n_samples=4,
                            rng=np.random.default_rng(0))
    assert rep.ok
    # now lie to the analysis: claim [-2, 0] but sample from [-2, 2]
    import repro.core.fuzz as fuzz_mod
    r_lie = ScaledIntRange(lo=np.asarray(-2.0), hi=np.asarray(0.0))
    r_int = {"x": r_lie, "y": ScaledIntRange(lo=np.asarray(0.0),
                                             hi=np.asarray(0.0))}
    monkey = fuzz_mod.analyze
    try:
        fuzz_mod.analyze = lambda g_, ir_, domain="interval": dict(r_int)
        rep2 = check_containment(g, wide, (4,), n_samples=8,
                                 rng=np.random.default_rng(0))
    finally:
        fuzz_mod.analyze = monkey
    assert not rep2.ok and any(v.kind == "interval"
                               for v in rep2.violations)


def test_random_graph_is_well_formed():
    rng = np.random.default_rng(5)
    for i in range(10):
        g, in_ranges, shape = random_graph(rng, n_nodes=6)
        rep = lint_graph(g, in_ranges,
                         input_shapes={g.inputs[0]: shape})
        assert rep.ok, f"random graph {i}: {rep}"
