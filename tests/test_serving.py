"""Serving engine: batched generation, determinism, quantized path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import get_model
from repro.quant.quantizer import QuantSpec
from repro.serve import Request, ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-1.5b", reduced=True)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_batched_generation(setup):
    cfg, model, params = setup
    eng = ServingEngine(model, params, batch_slots=3, max_seq=64)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=(5,)),
                    max_new_tokens=4) for _ in range(3)]
    outs = eng.generate(reqs)
    assert len(outs) == 3
    assert all(len(o) == 4 for o in outs)
    assert all(0 <= t < cfg.vocab_padded for o in outs for t in o)


def test_generation_deterministic_greedy(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, size=(6,))
    outs = []
    for _ in range(2):
        eng = ServingEngine(model, params, batch_slots=1, max_seq=32)
        outs.append(eng.generate([Request(prompt=prompt,
                                          max_new_tokens=5)])[0])
    assert outs[0] == outs[1]


def test_padded_batch_matches_solo(setup):
    """Pad-masking regression: a short prompt left-padded into a batch
    must compute exactly what it computes served alone.  Without the
    ``valid_from`` masking the pad tokens decoded into the KV cache are
    attended (and RoPE positions are shifted), corrupting the logits."""
    cfg, model, params = setup
    rng = np.random.default_rng(3)
    long_p = rng.integers(0, cfg.vocab, size=(9,)).astype(np.int32)
    short_p = rng.integers(0, cfg.vocab, size=(3,)).astype(np.int32)

    def prefill(toks, valid_from, B, S=32):
        cache = model.init_cache(B, S)
        logits = None
        for t in range(toks.shape[1]):
            logits, cache = model.decode_step(
                params, jnp.asarray(toks[:, t:t + 1]), cache,
                jnp.asarray(t, jnp.int32), valid_from=valid_from)
        return np.asarray(logits[:, -1].astype(jnp.float32))

    solo = prefill(short_p[None, :], jnp.zeros((1,), jnp.int32), 1)
    L = len(long_p)
    toks = np.zeros((2, L), np.int32)
    toks[0] = long_p
    toks[1, L - len(short_p):] = short_p            # left-pad
    valid_from = jnp.asarray(np.array([0, L - len(short_p)], np.int32))
    fixed = prefill(toks, valid_from, 2)
    np.testing.assert_allclose(fixed[1], solo[0], rtol=0, atol=1e-5)
    # sanity: without masking the pad garbage visibly corrupts the logits
    buggy = prefill(toks, None, 2)
    assert np.abs(buggy[1] - solo[0]).max() > 1e-3

    # end-to-end: batched mixed-length generation == solo generation
    eng = ServingEngine(model, params, batch_slots=2, max_seq=32)
    outs = eng.generate([Request(prompt=long_p, max_new_tokens=4),
                         Request(prompt=short_p, max_new_tokens=4)])
    solo_short = ServingEngine(model, params, batch_slots=1, max_seq=32
                               ).generate([Request(prompt=short_p,
                                                   max_new_tokens=4)])[0]
    assert outs[1] == solo_short


def test_mixed_length_rejected_for_unmaskable_families():
    """SSM/hybrid state updates and sliding-window rolling caches cannot
    mask pad tokens retroactively — mixed-length batches must be refused,
    not silently served with corrupted shorter prompts."""
    cfg = get_config("mamba2-780m", reduced=True)
    model = get_model(cfg)
    eng = ServingEngine(model, None, batch_slots=2, max_seq=32)
    with pytest.raises(NotImplementedError, match="mixed-length"):
        eng.generate([Request(prompt=np.arange(5), max_new_tokens=1),
                      Request(prompt=np.arange(2), max_new_tokens=1)])


def test_quantized_serving_close_to_fp(setup):
    """w8a8 fake-quant serving agrees with fp on most greedy tokens."""
    cfg, model, params = setup
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab, size=(6,))
    fp = ServingEngine(model, params, batch_slots=1, max_seq=32)
    q8 = ServingEngine(model, params, batch_slots=1, max_seq=32,
                       quant=QuantSpec(bits=8))
    o_fp = fp.generate([Request(prompt=prompt, max_new_tokens=6)])[0]
    o_q8 = q8.generate([Request(prompt=prompt, max_new_tokens=6)])[0]
    agree = sum(a == b for a, b in zip(o_fp, o_q8)) / len(o_fp)
    assert agree >= 0.5, (o_fp, o_q8)
