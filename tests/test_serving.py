"""Serving engine: batched generation, determinism, quantized path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import get_model
from repro.quant.quantizer import QuantSpec
from repro.serve import Request, ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-1.5b", reduced=True)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_batched_generation(setup):
    cfg, model, params = setup
    eng = ServingEngine(model, params, batch_slots=3, max_seq=64)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=(5,)),
                    max_new_tokens=4) for _ in range(3)]
    outs = eng.generate(reqs)
    assert len(outs) == 3
    assert all(len(o) == 4 for o in outs)
    assert all(0 <= t < cfg.vocab_padded for o in outs for t in o)


def test_generation_deterministic_greedy(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, size=(6,))
    outs = []
    for _ in range(2):
        eng = ServingEngine(model, params, batch_slots=1, max_seq=32)
        outs.append(eng.generate([Request(prompt=prompt,
                                          max_new_tokens=5)])[0])
    assert outs[0] == outs[1]


def test_quantized_serving_close_to_fp(setup):
    """w8a8 fake-quant serving agrees with fp on most greedy tokens."""
    cfg, model, params = setup
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab, size=(6,))
    fp = ServingEngine(model, params, batch_slots=1, max_seq=32)
    q8 = ServingEngine(model, params, batch_slots=1, max_seq=32,
                       quant=QuantSpec(bits=8))
    o_fp = fp.generate([Request(prompt=prompt, max_new_tokens=6)])[0]
    o_q8 = q8.generate([Request(prompt=prompt, max_new_tokens=6)])[0]
    agree = sum(a == b for a, b in zip(o_fp, o_q8)) / len(o_fp)
    assert agree >= 0.5, (o_fp, o_q8)
