"""Serving subsystem: continuous batching, scheduler, sampling, metrics.

The load-bearing property throughout: a request's generated tokens are
**bit-identical** whether it is served alone or packed into a busy
continuous-batching queue (greedy), because per-slot prefill chunks and
per-row decode masks make each row's math independent of its batchmates,
and sampling keys depend only on (seed, request_id, token index).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import get_model
from repro.quant.quantizer import QuantSpec
from repro.serve import Request, ServingEngine, derive_kv_spec


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-1.5b", reduced=True)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def int8_spec(setup):
    cfg, model, params = setup
    return derive_kv_spec(model, params)


def _solo(model, params, req: Request, max_seq=32, **kw):
    eng = ServingEngine(model, params, batch_slots=1, max_seq=max_seq, **kw)
    return eng.generate([Request(prompt=req.prompt,
                                 max_new_tokens=req.max_new_tokens)])[0]


def test_batched_generation(setup):
    cfg, model, params = setup
    eng = ServingEngine(model, params, batch_slots=3, max_seq=64)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=(5,)),
                    max_new_tokens=4) for _ in range(3)]
    outs = eng.generate(reqs)
    assert len(outs) == 3
    assert all(len(o) == 4 for o in outs)
    assert all(0 <= t < cfg.vocab_padded for o in outs for t in o)


def test_generation_deterministic_greedy(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, size=(6,))
    outs = []
    for _ in range(2):
        eng = ServingEngine(model, params, batch_slots=1, max_seq=32)
        outs.append(eng.generate([Request(prompt=prompt,
                                          max_new_tokens=5)])[0])
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv", ["fp", "int8"])
def test_continuous_batching_equals_solo(setup, int8_spec, kv):
    """Queue deeper than the slot count, mixed lengths, requests arriving
    mid-stream: every request's greedy tokens must be bit-identical to
    serving it alone — for the fp cache AND the int8 cache (both sides of
    the comparison see the same storage roundtrip)."""
    cfg, model, params = setup
    spec = int8_spec if kv == "int8" else "fp"
    rng = np.random.default_rng(3)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab,
                                        size=(int(n),)).astype(np.int32),
                    max_new_tokens=int(m))
            for n, m in [(9, 4), (3, 6), (5, 3), (2, 5), (7, 4), (4, 2)]]

    eng = ServingEngine(model, params, batch_slots=2, max_seq=32,
                        kv_cache=spec)
    handles = [eng.submit(r) for r in reqs[:4]]
    for _ in range(3):
        eng.step()                       # mid-stream...
    handles += [eng.submit(r) for r in reqs[4:]]   # ...late arrivals
    eng.run()
    outs = [eng.scheduler.outputs[h] for h in handles]

    for i, r in enumerate(reqs):
        assert len(outs[i]) == r.max_new_tokens
        solo = _solo(model, params, r, kv_cache=spec)
        assert outs[i] == solo, f"request {i} diverged from solo serving"


def test_per_request_termination_and_slot_reuse(setup):
    """More requests than slots with different max_new_tokens: each stops
    at its own limit (no batch-global max), finished slots are reused,
    and FIFO admission starves nobody."""
    cfg, model, params = setup
    rng = np.random.default_rng(5)
    lens = [2, 9, 3, 7, 1, 5]
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=(4,)),
                    max_new_tokens=m) for m in lens]
    eng = ServingEngine(model, params, batch_slots=2, max_seq=32)
    outs = eng.generate(reqs)
    assert [len(o) for o in outs] == lens
    # 6 admissions through 2 slots → slots were freed and reused
    assert eng.scheduler._admit_counter == 6
    assert not eng.scheduler.has_work()
    # pages all returned to the pool
    assert eng.cache.used_pages == 0


def test_eos_stops_request(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab, size=(5,))
    base = ServingEngine(model, params, batch_slots=1, max_seq=32).generate(
        [Request(prompt=prompt, max_new_tokens=8)])[0]
    eos = base[2]
    expect = base[:base.index(eos) + 1]
    eng = ServingEngine(model, params, batch_slots=2, max_seq=32)
    outs = eng.generate([Request(prompt=prompt, max_new_tokens=8,
                                 eos_id=eos),
                         Request(prompt=prompt, max_new_tokens=8)])
    assert outs[0] == expect             # stopped at EOS (EOS included)
    assert outs[1] == base               # unaffected batchmate


def test_sampling_vectorized_deterministic(setup):
    """Temperature sampling is per-request deterministic under a fixed
    seed regardless of batch composition: the key folds (seed,
    request_id, token index) — nothing about the batch."""
    cfg, model, params = setup
    mk = lambda: Request(prompt=np.asarray([5, 9, 2]), max_new_tokens=6,
                         temperature=50.0, request_id=99)
    other = lambda: Request(prompt=np.asarray([1, 2, 3, 4]),
                            max_new_tokens=9, temperature=30.0)
    packed = ServingEngine(model, params, batch_slots=3, max_seq=32,
                           seed=7).generate([other(), mk(), other()])
    alone = ServingEngine(model, params, batch_slots=1, max_seq=32,
                          seed=7).generate([mk()])
    assert packed[1] == alone[0]
    assert len(set(alone[0])) > 1, "temperature high enough that keys matter"
    # a different engine seed draws a different stream
    reseed = ServingEngine(model, params, batch_slots=1, max_seq=32,
                           seed=8).generate([mk()])
    assert reseed[0] != alone[0]


def test_preemption_requeues_and_completes(setup):
    """A page pool too small for both requests forces the scheduler to
    preempt the newest one; it must be replayed and still complete."""
    cfg, model, params = setup
    rng = np.random.default_rng(11)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=(6,)),
                    max_new_tokens=10) for _ in range(2)]
    eng = ServingEngine(model, params, batch_slots=2, max_seq=24,
                        page_size=4, num_pages=7)
    outs = eng.generate(reqs)
    assert eng.metrics.preemptions >= 1
    assert all(len(o) == 10 for o in outs)


def test_metrics_sanity(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(13)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=(4,)),
                    max_new_tokens=m) for m in (3, 6, 4)]
    eng = ServingEngine(model, params, batch_slots=2, max_seq=32)
    outs = eng.generate(reqs)
    m = eng.metrics.summary()
    assert m["requests"] == 3
    assert m["total_tokens"] == sum(len(o) for o in outs) == 13
    assert m["prefill_chunks"] >= 3
    assert m["tokens_per_s"] > 0
    assert 0 < m["slot_occupancy"] <= 1
    ttfts = [r.ttft for r in eng.metrics.requests.values()]
    assert all(t is not None and t >= 0 for t in ttfts)
    assert m["mean_token_latency_s"] >= 0


# ---------------------------------------------------------------------------
# static fallback path (unpageable families) + left-pad masking regression
# ---------------------------------------------------------------------------

def test_padded_batch_matches_solo(setup):
    """Pad-masking regression (static path): a short prompt left-padded
    into a batch must compute exactly what it computes served alone.
    Without ``valid_from`` the pad tokens decoded into the KV cache are
    attended (and RoPE positions shifted), corrupting the logits."""
    cfg, model, params = setup
    rng = np.random.default_rng(3)
    long_p = rng.integers(0, cfg.vocab, size=(9,)).astype(np.int32)
    short_p = rng.integers(0, cfg.vocab, size=(3,)).astype(np.int32)

    def prefill(toks, valid_from, B, S=32):
        cache = model.init_cache(B, S)
        logits = None
        for t in range(toks.shape[1]):
            logits, cache = model.decode_step(
                params, jnp.asarray(toks[:, t:t + 1]), cache,
                jnp.asarray(t, jnp.int32), valid_from=valid_from)
        return np.asarray(logits[:, -1].astype(jnp.float32))

    solo = prefill(short_p[None, :], jnp.zeros((1,), jnp.int32), 1)
    L = len(long_p)
    toks = np.zeros((2, L), np.int32)
    toks[0] = long_p
    toks[1, L - len(short_p):] = short_p            # left-pad
    valid_from = jnp.asarray(np.array([0, L - len(short_p)], np.int32))
    fixed = prefill(toks, valid_from, 2)
    np.testing.assert_allclose(fixed[1], solo[0], rtol=0, atol=1e-5)
    # sanity: without masking the pad garbage visibly corrupts the logits
    buggy = prefill(toks, None, 2)
    assert np.abs(buggy[1] - solo[0]).max() > 1e-3

    # end-to-end on the static engine: batched mixed-length == solo
    eng = ServingEngine(model, params, batch_slots=2, max_seq=32,
                        mode="static")
    outs = eng.generate([Request(prompt=long_p, max_new_tokens=4),
                         Request(prompt=short_p, max_new_tokens=4)])
    solo_short = ServingEngine(model, params, batch_slots=1, max_seq=32,
                               mode="static").generate(
        [Request(prompt=short_p, max_new_tokens=4)])[0]
    assert outs[1] == solo_short


def test_static_mode_matches_paged_greedy(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(17)
    prompt = rng.integers(0, cfg.vocab, size=(6,))
    o_s = ServingEngine(model, params, batch_slots=1, max_seq=32,
                        mode="static").generate(
        [Request(prompt=prompt, max_new_tokens=8)])[0]
    o_p = ServingEngine(model, params, batch_slots=1, max_seq=32).generate(
        [Request(prompt=prompt, max_new_tokens=8)])[0]
    assert o_s == o_p


def test_static_mode_eos_and_per_slot_stop(setup):
    """Static path also honors eos_id / per-request max_new_tokens: a
    finished row stops accumulating and the loop exits early when every
    row is done."""
    cfg, model, params = setup
    rng = np.random.default_rng(19)
    prompt = rng.integers(0, cfg.vocab, size=(5,))
    base = ServingEngine(model, params, batch_slots=1, max_seq=32,
                         mode="static").generate(
        [Request(prompt=prompt, max_new_tokens=6)])[0]
    eos = base[1]
    eng = ServingEngine(model, params, batch_slots=2, max_seq=32,
                        mode="static")
    outs = eng.generate([Request(prompt=prompt, max_new_tokens=6,
                                 eos_id=eos),
                         Request(prompt=prompt, max_new_tokens=3)])
    assert outs[0] == base[:base.index(eos) + 1]
    assert outs[1] == base[:3]


def test_static_mode_rejects_overlong_requests(setup):
    """Static mode must refuse prompt+max_new_tokens > max_seq like the
    scheduler does — dynamic_update_slice would silently clamp the cache
    write and corrupt the output."""
    cfg, model, params = setup
    eng = ServingEngine(model, params, batch_slots=1, max_seq=16,
                        mode="static")
    with pytest.raises(ValueError, match="max_seq"):
        eng.generate([Request(prompt=np.arange(10), max_new_tokens=10)])


def test_mixed_length_rejected_for_unmaskable_families():
    """SSM/hybrid state updates and sliding-window rolling caches cannot
    mask pad tokens retroactively — mixed-length batches must be refused,
    not silently served with corrupted shorter prompts.  These families
    auto-select the static path; requesting paged mode raises."""
    cfg = get_config("mamba2-780m", reduced=True)
    model = get_model(cfg)
    eng = ServingEngine(model, None, batch_slots=2, max_seq=32)
    assert eng.mode == "static"
    with pytest.raises(NotImplementedError, match="mixed-length"):
        eng.generate([Request(prompt=np.arange(5), max_new_tokens=1),
                      Request(prompt=np.arange(2), max_new_tokens=1)])
    with pytest.raises(NotImplementedError, match="full-context"):
        ServingEngine(model, None, batch_slots=2, max_seq=32, mode="paged")


def test_quantized_serving_close_to_fp(setup):
    """w8a8 fake-quant serving agrees with fp on most greedy tokens."""
    cfg, model, params = setup
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab, size=(6,))
    fp = ServingEngine(model, params, batch_slots=1, max_seq=32)
    q8 = ServingEngine(model, params, batch_slots=1, max_seq=32,
                       quant=QuantSpec(bits=8))
    o_fp = fp.generate([Request(prompt=prompt, max_new_tokens=6)])[0]
    o_q8 = q8.generate([Request(prompt=prompt, max_new_tokens=6)])[0]
    agree = sum(a == b for a, b in zip(o_fp, o_q8)) / len(o_fp)
    assert agree >= 0.5, (o_fp, o_q8)
