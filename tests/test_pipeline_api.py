"""Tests for the SiraModel + Transformation pass-pipeline API.

Covers: analysis-cache invalidation on graph mutation, pass idempotence and
the fixpoint combinator, old-shim vs new-pass equivalence, end-to-end
``build_flow`` numerical equivalence on all four QNN workloads, the unified
op registry, and the signed-input datatype-bound regression."""
import numpy as np
import pytest

from repro.core import (BuildConfig, ConvertTailsToThresholds,
                        ExplicitizeQuantizers, Fixpoint, Graph,
                        RemoveIdentityOps, ScaledIntRange, SiraModel,
                        Streamline, VerifyRanges, analysis_calls, analyze,
                        build_flow, convert_tails_to_thresholds,
                        datatype_bound_bits, register_op)
from repro.core import ops as ops_mod
from repro.core.workloads import WORKLOADS, make_tfc


def _function_streamline(graph, input_ranges):
    """The old function-style streamlining path, built directly from the
    in-place graph-rewrite cores (the loose shims are gone)."""
    from repro.core.streamline import (aggregate_with_ranges,
                                       duplicate_shared_constants_inplace,
                                       explicitize_quantizers_inplace)
    g = graph.copy()
    explicitize_quantizers_inplace(g)
    duplicate_shared_constants_inplace(g)
    res, _ = aggregate_with_ranges(g, analyze(g, input_ranges))
    return res


# --------------------------------------------------------------------------
# analysis cache
# --------------------------------------------------------------------------

def test_ranges_cached_until_mutation():
    model = SiraModel.from_workload(make_tfc())
    c0 = analysis_calls()
    r1 = model.ranges
    r2 = model.ranges
    assert r1 is r2
    assert analysis_calls() - c0 == 1
    assert model.analysis_cached


def test_mutation_invalidates_cache():
    model = SiraModel.from_workload(make_tfc())
    _ = model.ranges
    out = model.graph.outputs[0]
    model.graph.add_node("Relu", [out], ["extra_relu"])
    model.graph.outputs = ["extra_relu"]
    assert not model.analysis_cached
    c0 = analysis_calls()
    r = model.ranges
    assert analysis_calls() - c0 == 1
    assert "extra_relu" in r            # stale ranges were recomputed
    assert float(np.min(r["extra_relu"].lo)) >= 0.0


def test_initializer_value_edit_with_touch_invalidates():
    model = SiraModel.from_workload(make_tfc())
    _ = model.ranges
    name = next(iter(model.graph.initializers))
    model.graph.initializers[name] = \
        model.graph.initializers[name] * 2.0
    model.graph.touch()
    assert not model.analysis_cached


def test_raw_node_list_append_invalidates_cache():
    """Safety net: mutating graph.nodes directly (bypassing the API)
    still invalidates via the (version, node count) cache key."""
    from repro.core.graph import Node
    model = SiraModel.from_workload(make_tfc())
    _ = model.ranges
    out = model.graph.outputs[0]
    model.graph.nodes.append(Node("Relu", [out], ["raw_y"]))
    assert not model.analysis_cached
    assert "raw_y" in model.ranges
    assert model.graph.producer("raw_y") is not None


def test_copy_preserves_cache():
    model = SiraModel.from_workload(make_tfc())
    _ = model.ranges
    c0 = analysis_calls()
    clone = model.copy()
    _ = clone.ranges
    assert analysis_calls() - c0 == 0


# --------------------------------------------------------------------------
# graph index maps
# --------------------------------------------------------------------------

def test_producer_consumer_index_tracks_mutation():
    g = Graph(inputs=["X"], outputs=["Y"])
    w = g.add_initializer(np.eye(2), "W")
    g.add_node("MatMul", ["X", w], ["mm"])
    g.add_node("Relu", ["mm"], ["Y"])
    assert g.producer("mm").op_type == "MatMul"
    assert [n.op_type for n in g.consumers("mm")] == ["Relu"]
    relu = g.producer("Y")
    g.remove_node(relu)
    assert g.consumers("mm") == []
    g.add_node("Sigmoid", ["mm"], ["Y"])
    assert [n.op_type for n in g.consumers("mm")] == ["Sigmoid"]


def test_replace_input_rewires_consumers_and_outputs():
    g = Graph(inputs=["X"], outputs=["Y"])
    g.add_node("Relu", ["X"], ["Y"])
    g.add_node("Identity", ["X"], ["Z"])
    g.replace_input("X", "X2")
    assert all("X" not in n.inputs for n in g.nodes)
    assert all("X2" in n.inputs for n in g.nodes)


# --------------------------------------------------------------------------
# passes: idempotence + fixpoint
# --------------------------------------------------------------------------

def test_explicitize_idempotent():
    model = SiraModel.from_workload(make_tfc())
    model, mod1 = ExplicitizeQuantizers().apply(model)
    model, mod2 = ExplicitizeQuantizers().apply(model)
    assert mod1 and not mod2


def test_remove_identity_ops_idempotent_and_fixpoint():
    g = Graph(inputs=["X"], outputs=["Y"])
    one = g.add_initializer(1.0, "one")
    zero = g.add_initializer(0.0, "zero")
    g.add_node("Mul", ["X", one], ["a"])
    g.add_node("Add", ["a", zero], ["Y"])
    model = SiraModel(g, {"X": ScaledIntRange(lo=np.zeros(()),
                                              hi=np.ones(()))})
    tx = Fixpoint(RemoveIdentityOps())
    model, mod1 = tx.apply(model)
    assert mod1 and len(model.graph.nodes) == 0
    model, mod2 = tx.apply(model)
    assert not mod2


def test_fixpoint_raises_when_not_converging():
    class Always(RemoveIdentityOps):
        def apply(self, model):
            return model, True

    model = SiraModel.from_workload(make_tfc())
    with pytest.raises(RuntimeError, match="fixpoint"):
        Always().fixpoint(max_iter=3).apply(model)


def test_streamline_pass_semantically_stable():
    """Re-streamlining a streamlined model must preserve semantics."""
    wl = make_tfc()
    model = SiraModel.from_workload(wl)
    m1 = model.transform(Streamline())
    m2 = m1.transform(Streamline())
    rng = np.random.default_rng(3)
    x = rng.uniform(0, 1, size=wl.input_shape)
    y0 = wl.graph.execute({"X": x})[wl.graph.outputs[0]]
    y1 = m1.execute({"X": x})[m1.graph.outputs[0]]
    y2 = m2.execute({"X": x})[m2.graph.outputs[0]]
    np.testing.assert_allclose(y0, y1, rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(y0, y2, rtol=1e-9, atol=1e-9)


# --------------------------------------------------------------------------
# old shims == new passes
# --------------------------------------------------------------------------

def test_deprecated_function_entry_points_removed():
    """The pre-SiraModel function-style streamlining API finished its
    deprecation cycle: the loose shims no longer exist anywhere — not in
    core.streamline, not re-exported from repro.core.  Only the in-place
    cores and the pass classes remain."""
    import repro.core as core
    from repro.core import streamline as sl_mod
    for name in ("streamline", "aggregate_scales_biases",
                 "explicitize_quantizers", "duplicate_shared_constants",
                 "_aggregate_scales_biases", "_warn_deprecated"):
        assert not hasattr(sl_mod, name), name
    # repro.core.streamline resolves to the *module*, never the function
    assert core.streamline is sl_mod
    for name in ("aggregate_scales_biases", "explicitize_quantizers",
                 "duplicate_shared_constants"):
        assert not hasattr(core, name), name
    # the cores and pass entry points are still there
    assert callable(sl_mod.explicitize_quantizers_inplace)
    assert callable(sl_mod.duplicate_shared_constants_inplace)
    assert callable(sl_mod.aggregate_with_ranges)
    assert callable(core.remove_identity_ops)


def test_old_shim_equals_new_pass_path_on_tfc():
    wl = make_tfc()
    res = _function_streamline(wl.graph, wl.input_range)
    g_old, specs_old = convert_tails_to_thresholds(res.graph,
                                                   wl.input_range)

    model = SiraModel.from_workload(wl).transform(
        Streamline(), ConvertTailsToThresholds())
    g_new = model.graph

    assert [n.op_type for n in g_old.nodes] == \
        [n.op_type for n in g_new.nodes]
    assert len(specs_old) == len(model.metadata["threshold_specs"])
    rng = np.random.default_rng(11)
    for _ in range(3):
        x = rng.uniform(0, 1, size=wl.input_shape)
        y_old = g_old.execute({"X": x})[g_old.outputs[0]]
        y_new = g_new.execute({"X": x})[g_new.outputs[0]]
        np.testing.assert_array_equal(y_old, y_new)


# --------------------------------------------------------------------------
# build_flow (acceptance criterion + all workloads)
# --------------------------------------------------------------------------

def test_build_flow_single_analysis_for_unmodified_prefix():
    """After the last graph-mutating step, the whole read-only suffix
    (accumulator minimization + range verification) shares exactly one
    full range propagation — O(1) analyses instead of O(N) passes."""
    result = build_flow(make_tfc())
    names = [s.name for s in result.steps]
    assert names == ["lint_graph",
                     "ExplicitizeQuantizers", "AggregateScalesBiases",
                     "ConvertTailsToThresholds", "MinimizeAccumulators",
                     "VerifyRanges"]
    last_mutating = max(i for i, s in enumerate(result.steps) if s.modified)
    suffix = result.steps[last_mutating + 1:]
    assert len(suffix) >= 2
    assert sum(s.analysis_calls for s in suffix) == 1
    # purely structural rewrites never trigger analysis
    assert result.steps[0].analysis_calls == 0
    assert result.verification is not None and \
        result.verification.contained
    assert len(result.accumulator_reports) >= 1


def test_build_flow_matches_old_function_path_numerically():
    wl = make_tfc()
    res = _function_streamline(wl.graph, wl.input_range)
    g_old, _ = convert_tails_to_thresholds(res.graph, wl.input_range)
    result = build_flow(wl)
    rng = np.random.default_rng(0)
    x = rng.uniform(0, 1, size=wl.input_shape)
    y_old = g_old.execute({"X": x})[g_old.outputs[0]]
    y_new = result.graph.execute({"X": x})[result.graph.outputs[0]]
    np.testing.assert_array_equal(y_old, y_new)


@pytest.mark.parametrize("name", list(WORKLOADS))
def test_build_flow_equivalence_all_workloads(name):
    """End-to-end flow (with per-step equivalence+containment hooks armed)
    is numerically exact on every paper QNN workload."""
    wl = WORKLOADS[name]()
    result = build_flow(wl, verify="full", verify_samples=2)
    assert len(result.threshold_specs) >= 1
    lo = float(np.min(wl.input_range["X"].lo))
    hi = float(np.max(wl.input_range["X"].hi))
    rng = np.random.default_rng(7)
    for _ in range(2):
        x = rng.uniform(lo, hi, size=wl.input_shape)
        y0 = wl.graph.execute({"X": x})[wl.graph.outputs[0]]
        y1 = result.graph.execute({"X": x})[result.graph.outputs[0]]
        np.testing.assert_allclose(y0, y1, rtol=1e-9, atol=1e-9)


def test_build_flow_custom_steps_and_callable():
    seen = []

    def spy(model):
        seen.append(len(model.graph.nodes))
        return model, False

    cfg = BuildConfig(steps=["streamline", spy])
    result = build_flow(make_tfc(), cfg)
    assert seen and result.steps[-1].name == "spy"
    assert not result.steps[-1].modified


def test_build_flow_rejects_unknown_step():
    with pytest.raises(KeyError, match="unknown build step"):
        build_flow(make_tfc(), BuildConfig(steps=["no_such_step"]))


def test_build_flow_verify_requires_sample_inputs():
    """Explicitly requested verification must not be silently skipped when
    no reference inputs can be drawn (e.g. bare (graph, ranges) input)."""
    wl = make_tfc()
    with pytest.raises(ValueError, match="verify"):
        build_flow((wl.graph, wl.input_range), verify="equivalence")


def test_sample_inputs_respects_per_channel_ranges():
    """Per-channel input ranges must be sampled elementwise, not collapsed
    to their global hull — otherwise strict VerifyRanges spuriously fails
    on sound models."""
    g = Graph(inputs=["X"], outputs=["Y"])
    g.add_node("Identity", ["X"], ["Y"])
    lo = np.array([-5.10, -3.80])
    hi = np.array([5.10, 3.80])
    model = SiraModel(g, {"X": ScaledIntRange(lo=lo, hi=hi)},
                      metadata={"input_shape": (16, 2)})
    for feeds in model.sample_inputs(n=20):
        x = feeds["X"]
        assert np.all(x >= lo) and np.all(x <= hi)
    model, _ = VerifyRanges(samples=20).apply(model)   # must not raise


def test_verify_ranges_pass_raises_on_violation():
    wl = make_tfc()
    model = SiraModel.from_workload(wl)
    bad = [{"X": np.full(wl.input_shape, 50.0)}]   # way outside [0, 1]
    from repro.core import VerificationError
    with pytest.raises(VerificationError):
        VerifyRanges(dataset=bad).apply(model)


# --------------------------------------------------------------------------
# unified op registry
# --------------------------------------------------------------------------

def test_register_custom_op_single_declaration():
    register_op(
        "TestDouble",
        execute=lambda node, x: 2.0 * x,
        propagate=lambda node, graph, rs: ScaledIntRange(
            lo=2.0 * rs[0].lo, hi=2.0 * rs[0].hi),
        cost=dict(alpha=1.0, beta=1.0))
    try:
        g = Graph(inputs=["X"], outputs=["Y"])
        g.add_node("TestDouble", ["X"], ["Y"])
        y = g.execute({"X": np.asarray([1.0, 2.0])})["Y"]
        np.testing.assert_array_equal(y, [2.0, 4.0])
        r = analyze(g, {"X": ScaledIntRange(lo=np.zeros(()),
                                            hi=np.ones(()))})["Y"]
        assert float(r.hi) == 2.0
        from repro.core.costmodel import ELEMENTWISE_COEFFS
        assert ELEMENTWISE_COEFFS["TestDouble"]["alpha"] == 1.0
    finally:
        del ops_mod.OP_REGISTRY["TestDouble"]


def test_legacy_registry_views_are_aliased():
    from repro.core.graph import EXEC_REGISTRY
    from repro.core.propagate import PROP_REGISTRY
    assert EXEC_REGISTRY["MatMul"] is ops_mod.OP_REGISTRY["MatMul"].execute
    assert PROP_REGISTRY["MatMul"] is ops_mod.OP_REGISTRY["MatMul"].propagate
    # legacy write path registers into the unified record
    EXEC_REGISTRY["TestWriteThrough"] = lambda node, x: x
    try:
        assert ops_mod.OP_REGISTRY["TestWriteThrough"].execute is not None
    finally:
        del ops_mod.OP_REGISTRY["TestWriteThrough"]


# --------------------------------------------------------------------------
# accumulator datatype bound (signed-input regression)
# --------------------------------------------------------------------------

def test_datatype_bound_signed_vs_unsigned():
    """Colbert et al.: signed N-bit inputs carry N-1 magnitude bits, so the
    bound must be strictly tighter than for unsigned N-bit inputs (the old
    code had a dead branch making them equal)."""
    for k in (16, 128, 1024):
        for bits in (4, 8):
            u = datatype_bound_bits(k, bits, 8, input_signed=False)
            s = datatype_bound_bits(k, bits, 8, input_signed=True)
            assert s == u - 1, (k, bits, u, s)
    # spot-check the unsigned formula is unchanged:
    # alpha = log2(128) + 8 + 8 - 1 = 22, phi ~ 0 → P = 24
    assert datatype_bound_bits(128, 8, 8) == 24
