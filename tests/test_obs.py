"""Tests for the ``repro.obs`` observability subsystem.

Covers: span nesting and the disabled no-op path, error-attributed spans
from failing flow steps, Chrome trace_event export (golden file +
schema validation), the Prometheus text exposition (golden file),
registry semantics, ``export_bench`` round-trips, the range-analysis
cache counters across mutate-then-reanalyze, range provenance
(``SiraModel.explain``), the ServingMetrics facade equivalence, the
folding-search telemetry, and the tier-1 tracing smoke (traced flow +
compile validates against the Chrome schema).
"""
import json
import pathlib

import pytest

from repro.core import SiraModel, analyze, build_flow
from repro.core.workloads import make_cnv, make_tfc
from repro.obs import (NULL_SPAN, MetricsRegistry, ProvenanceChain,
                       RangeProvenance, Tracer, build_chain,
                       disable_tracing, enable_tracing, export_bench,
                       get_tracer, validate_chrome_trace)

GOLDEN = pathlib.Path(__file__).parent / "golden"


class FakeClock:
    """Deterministic monotonic clock: each call advances 1 ms."""

    def __init__(self, t0: float = 100.0, step: float = 0.001):
        self.t = t0
        self.step = step

    def __call__(self) -> float:
        t = self.t
        self.t += self.step
        return t


@pytest.fixture
def global_tracer():
    """Install a fresh enabled global tracer; restore the no-op one."""
    tracer = enable_tracing()
    yield tracer
    disable_tracing()


# --------------------------------------------------------------------------
# tracer: spans, nesting, disabled path
# --------------------------------------------------------------------------

def test_span_nesting_depth_and_order():
    tr = Tracer(clock=FakeClock())
    with tr.span("outer", kind="test"):
        with tr.span("inner"):
            pass
        with tr.span("inner2"):
            pass
    # completion order: children before parents
    assert [s.name for s in tr.spans] == ["inner", "inner2", "outer"]
    by_name = {s.name: s for s in tr.spans}
    assert by_name["outer"].depth == 0
    assert by_name["inner"].depth == 1
    assert by_name["inner2"].depth == 1
    assert by_name["outer"].attrs == {"kind": "test"}
    # children start after and end before the parent
    o, i = by_name["outer"], by_name["inner"]
    assert o.ts_us <= i.ts_us
    assert i.ts_us + i.dur_us <= o.ts_us + o.dur_us


def test_disabled_tracer_is_noop():
    tr = Tracer(enabled=False)
    sp = tr.span("anything", x=1)
    assert sp is NULL_SPAN                 # shared singleton, no alloc
    with sp:
        sp.set_attr("y", 2)
    tr.count("c", 5)
    assert tr.spans == []
    assert tr.counters == {}


def test_default_global_tracer_disabled():
    # the restored global must be the no-op tracer — instrumented
    # library code pays one flag check unless enable_tracing() ran
    disable_tracing()
    assert not get_tracer().enabled
    assert get_tracer().span("x") is NULL_SPAN


def test_span_error_attr_on_exception():
    tr = Tracer(clock=FakeClock())
    with pytest.raises(RuntimeError, match="kaboom"):
        with tr.span("will_fail", stage=3):
            raise RuntimeError("kaboom")
    (s,) = tr.spans
    assert s.name == "will_fail"
    assert s.attrs["error"] == "RuntimeError: kaboom"
    assert s.attrs["stage"] == 3
    assert s.dur_us >= 0


def test_counters_accumulate():
    tr = Tracer(clock=FakeClock())
    tr.count("hits")
    tr.count("hits", 2, where="x")
    tr.count("misses", 0.5)
    assert tr.counters == {"hits": 3.0, "misses": 0.5}


# --------------------------------------------------------------------------
# Chrome trace_event export
# --------------------------------------------------------------------------

def _normalized_chrome(payload):
    """pid/tid vary per process/thread — zero them for golden compare."""
    out = json.loads(json.dumps(payload))
    for ev in out["traceEvents"]:
        ev["pid"] = 0
        ev["tid"] = 0
    return out


def test_chrome_trace_golden():
    tr = Tracer(clock=FakeClock())
    with tr.span("flow:build", model="tfc", steps=2):
        with tr.span("step:streamline"):
            tr.count("range_cache.miss", attrs_ignored=1)
        with tr.span("step:minimize", modified=True):
            pass
    payload = tr.to_chrome_json()
    validate_chrome_trace(payload)
    got = _normalized_chrome(payload)
    golden_path = GOLDEN / "trace_chrome.json"
    want = json.loads(golden_path.read_text())
    assert got == want, (
        f"Chrome trace drifted from golden {golden_path} — if the change "
        f"is deliberate, regenerate the golden from the normalized "
        f"payload")


def test_chrome_trace_timestamps_anchor_at_outer_span():
    # the epoch must anchor at the *earliest* sample: an inner count()
    # before any span completes must not push the outer span negative
    tr = Tracer(clock=FakeClock())
    with tr.span("outer"):
        tr.count("c")
    payload = tr.to_chrome_json()
    validate_chrome_trace(payload)          # rejects negative ts
    assert all(ev["ts"] >= 0 for ev in payload["traceEvents"])


def test_validate_chrome_trace_rejects_malformed():
    with pytest.raises(ValueError):
        validate_chrome_trace([])           # not an object
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [{"name": "x"}]})
    bad_phase = {"traceEvents": [dict(name="x", ph="Z", ts=0.0,
                                      pid=1, tid=1)]}
    with pytest.raises(ValueError, match="phase"):
        validate_chrome_trace(bad_phase)
    neg = {"traceEvents": [dict(name="x", ph="X", ts=-1.0, dur=1.0,
                                pid=1, tid=1)]}
    with pytest.raises(ValueError, match="negative"):
        validate_chrome_trace(neg)


def test_write_chrome_trace_roundtrip(tmp_path):
    tr = Tracer(clock=FakeClock())
    with tr.span("a"):
        pass
    path = tmp_path / "out.json"
    tr.write_chrome_trace(str(path))
    payload = json.loads(path.read_text())
    validate_chrome_trace(payload)
    assert any(ev["name"] == "a" for ev in payload["traceEvents"])


# --------------------------------------------------------------------------
# metrics registry + Prometheus exposition
# --------------------------------------------------------------------------

def _sample_registry() -> MetricsRegistry:
    reg = MetricsRegistry()
    c = reg.counter("requests_total", "requests served",
                    labels=("engine",))
    c.labels(engine="paged").inc(3)
    c.labels(engine="static").inc()
    reg.gauge("slots", "configured batch slots").set(4)
    h = reg.histogram("ttft_seconds", "time to first token",
                      buckets=(0.01, 0.1, 1.0))
    for v in (0.005, 0.05, 0.5, 5.0):
        h.observe(v)
    return reg


def test_prometheus_golden():
    got = _sample_registry().to_prometheus()
    golden_path = GOLDEN / "metrics.prom"
    want = golden_path.read_text()
    assert got == want, (
        f"Prometheus exposition drifted from golden {golden_path}")


def test_prometheus_histogram_shape():
    text = _sample_registry().to_prometheus()
    assert 'ttft_seconds_bucket{le="0.01"} 1' in text
    assert 'ttft_seconds_bucket{le="+Inf"} 4' in text
    assert "ttft_seconds_count 4" in text
    assert "# TYPE requests_total counter" in text
    assert 'requests_total{engine="paged"} 3' in text


def test_registry_idempotent_reregistration():
    reg = MetricsRegistry()
    a = reg.counter("x_total", "first", labels=("k",))
    b = reg.counter("x_total", "ignored on re-register", labels=("k",))
    assert a is b
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x_total")                # kind mismatch
    with pytest.raises(ValueError, match="already registered"):
        reg.counter("x_total", labels=("other",))  # label mismatch


def test_metric_label_discipline():
    reg = MetricsRegistry()
    c = reg.counter("y_total", labels=("a",))
    with pytest.raises(ValueError, match="takes labels"):
        c.labels(b="1")
    with pytest.raises(ValueError, match="call .labels"):
        c.inc()                             # labeled metric, bare inc
    with pytest.raises(ValueError):
        reg.counter("bad name")
    with pytest.raises(ValueError):
        MetricsRegistry().histogram("h", buckets=())


def test_counter_rejects_negative():
    reg = MetricsRegistry()
    with pytest.raises(ValueError, match="only go up"):
        reg.counter("n_total").inc(-1)
    g = reg.gauge("g")
    g.dec(2)
    assert g.value == -2


def test_registry_json_export():
    j = _sample_registry().to_json()
    assert j["slots"]["type"] == "gauge"
    assert j["slots"]["samples"][0]["value"] == 4.0
    hist = j["ttft_seconds"]["samples"][0]
    assert hist["count"] == 4 and hist["inf"] == 1


def test_export_bench_roundtrip(tmp_path):
    payload = dict(backend="cpu", results=[
        dict(workload="TFC", speedup=2.5, nodes=10, ok=True, tag="x"),
        dict(workload="CNV", speedup=4.0, nodes=20, ok=False, tag="y"),
    ])
    out = tmp_path / "BENCH_backend.json"
    reg = export_bench(payload, str(out), key=("workload",))
    # JSON artifact unchanged (baselines keep working)
    assert json.loads(out.read_text()) == payload
    prom = (tmp_path / "BENCH_backend.prom").read_text()
    assert 'bench_backend_speedup{workload="TFC"} 2.5' in prom
    assert 'bench_backend_nodes{workload="CNV"} 20' in prom
    # bools and strings are not metrics
    assert "bench_backend_ok" not in prom
    assert "bench_backend_tag" not in prom
    g = reg.gauge("bench_backend_speedup", labels=("workload",))
    assert g.labels(workload="CNV").value == 4.0


# --------------------------------------------------------------------------
# analysis-cache counters (model layer)
# --------------------------------------------------------------------------

def test_range_cache_counters_across_mutation(global_tracer):
    model = SiraModel.from_workload(make_tfc())
    _ = model.ranges                        # cold: miss
    _ = model.ranges                        # cached: hit
    model.graph.touch()                     # version bump invalidates
    _ = model.ranges                        # recompute: miss
    c = global_tracer.counters
    assert c.get("range_cache.miss") == 2
    assert c.get("range_cache.hit") == 1
    model.invalidate()
    assert c.get("range_cache.invalidate") == 1


# --------------------------------------------------------------------------
# range provenance / explain()
# --------------------------------------------------------------------------

def test_explain_reaches_seed_on_cnv():
    model = build_flow(make_cnv()).model
    # pin the queried tensor node-positionally: the output of the first
    # accumulator node (tensor names differ between flow variants)
    rep = model.metadata["accumulator_reports"][0]
    node = next(n for n in model.graph.nodes if n.name == rep.node_name)
    tensor = node.outputs[0]
    chain = model.explain(tensor)
    assert isinstance(chain, ProvenanceChain)
    assert chain.tensor == tensor
    assert len(chain) >= 2
    first, last = chain.entries[0], chain.entries[-1]
    assert first.tensor == tensor
    assert first.node_name == rep.node_name
    assert first.culprit in first.in_widths
    assert last.culprit is None             # walked back to a graph seed
    assert last.handler in ("input", "const")
    text = chain.render()
    assert tensor in text and "widened by" in text
    # explain() must not have invalidated the analysis cache
    assert model.analysis_cached


def test_explain_unknown_tensor_raises():
    model = SiraModel.from_workload(make_tfc())
    with pytest.raises(KeyError, match="no provenance recorded"):
        model.explain("definitely_not_a_tensor")


def test_provenance_recorded_via_analyze():
    wl = make_tfc()
    model = SiraModel.from_workload(wl)
    record = {}
    analyze(model.graph, model.input_ranges, record=record)
    assert record                           # every tensor attributed
    for name, rec in record.items():
        assert isinstance(rec, RangeProvenance)
        assert rec.tensor == name
    inp = model.graph.inputs[0]
    assert record[inp].op_type == "input"
    chain = build_chain(model.graph.outputs[0], record)
    assert chain.entries[-1].culprit is None


# --------------------------------------------------------------------------
# flow + compile tracing (the tier-1 tracing smoke)
# --------------------------------------------------------------------------

def test_traced_flow_and_compile_smoke(global_tracer):
    model = build_flow(make_tfc()).model
    model.compile()
    payload = global_tracer.to_chrome_json()
    validate_chrome_trace(payload)
    by_name = {}
    for s in global_tracer.spans:
        by_name.setdefault(s.name, s)
    assert "flow:build" in by_name and by_name["flow:build"].depth == 0
    step_spans = [s for s in global_tracer.spans
                  if s.name.startswith("step:")]
    assert step_spans and all(s.depth == 1 for s in step_spans)
    prop = [s for s in global_tracer.spans
            if s.name == "analysis:propagate"]
    assert prop and all(s.depth >= 2 for s in prop)
    assert "compile:lower" in by_name
    assert "compile:build_plan" in by_name
    # StepReport timing survives the instrumentation
    assert global_tracer.counters.get("range_cache.miss", 0) >= 1


def test_failing_flow_step_closes_spans_with_error(global_tracer):
    def explode(model):
        raise RuntimeError("step boom")

    with pytest.raises(RuntimeError, match="step boom"):
        build_flow(make_tfc(), steps=["explicitize_quantizers", explode])
    names = [s.name for s in global_tracer.spans]
    assert "step:explode" in names
    failed = next(s for s in global_tracer.spans
                  if s.name == "step:explode")
    assert failed.attrs["error"] == "RuntimeError: step boom"
    assert "analysis_calls" in failed.attrs
    # the enclosing flow span also closed (children before parents)
    outer = next(s for s in global_tracer.spans
                 if s.name == "flow:build")
    assert outer.attrs["error"] == "RuntimeError: step boom"
    validate_chrome_trace(global_tracer.to_chrome_json())


# --------------------------------------------------------------------------
# folding-search telemetry
# --------------------------------------------------------------------------

def test_folding_search_telemetry(global_tracer):
    from repro.dataflow import DeviceBudget, search_folding

    model = build_flow(make_tfc()).model
    fold = search_folding(model, target_fps=1000.0, device="pynq-z1")
    assert fold.feasible
    c = global_tracer.counters
    assert c.get("folding.candidates", 0) >= 1
    spans = {s.name: s for s in global_tracer.spans}
    assert spans["dse:search_folding"].attrs["feasible"] is True

    tiny = DeviceBudget("tiny", luts=400, dsps=1, brams=1)
    search_folding(model, target_fps=1000.0, device=tiny)
    rejects = [k for k in global_tracer.counters
               if k.startswith("folding.reject.")]
    assert rejects, "infeasible search must record rejection counters"


# --------------------------------------------------------------------------
# ServingMetrics facade
# --------------------------------------------------------------------------

def test_serving_metrics_facade_equivalence():
    from repro.serve.metrics import ServingMetrics

    clock = FakeClock(t0=0.0, step=0.25)
    m = ServingMetrics(clock=clock)
    m.on_submit(0, prompt_tokens=5)
    m.on_prefill_chunk()
    m.on_prefill_chunk()
    for _ in range(4):
        m.on_decode_step(active_slots=1, total_slots=2, tokens=1)
        m.on_token(0)
    m.on_spec_step(proposed=4, accepted=2)
    m.on_finish(0)

    s = m.summary()
    assert s["requests"] == 1
    assert s["total_tokens"] == 4
    assert s["decode_steps"] == 4
    assert s["prefill_chunks"] == 2
    assert s["spec_proposed"] == 4 and s["spec_accepted"] == 2
    assert s["acceptance_rate"] == 0.5
    assert s["slot_occupancy"] == 0.5       # 4 active / 8 capacity

    # the facade's summary numbers and the Prometheus exposition come
    # from the same registry — scrape and cross-check
    text = m.to_prometheus()
    assert "serving_decode_steps_total 4" in text
    assert "serving_prefill_chunks_total 2" in text
    assert "serving_spec_accepted_total 2" in text
    assert "serving_tokens_total 4" in text
    assert "serving_ttft_seconds_count 1" in text
    # 3 inter-token gaps of one 0.25s clock tick each
    assert "serving_token_latency_seconds_count 3" in text
    assert "serving_token_latency_seconds_sum 0.75" in text
    assert s["mean_token_latency_s"] == pytest.approx(0.25)
    # count fields stay plain ints (historical API)
    assert isinstance(m.decode_steps, int)
    assert m.decode_steps == 4


def test_serving_metrics_fresh_registry_per_instance():
    from repro.serve.metrics import ServingMetrics

    a = ServingMetrics(clock=FakeClock())
    a.on_decode_step(1, 2, tokens=1)
    b = ServingMetrics(clock=FakeClock())   # reset_metrics() semantics
    assert b.decode_steps == 0
    assert a.decode_steps == 1
    assert a.registry is not b.registry


# --------------------------------------------------------------------------
# CompiledSiraModel.profile()
# --------------------------------------------------------------------------

def test_compiled_profile_spans_and_equivalence(global_tracer):
    import numpy as np

    model = build_flow(make_tfc()).model
    compiled = model.compile()
    feeds = next(model.sample_inputs())
    want = compiled(feeds)
    got = compiled.profile(feeds)
    assert set(want) == set(got)
    for k in want:
        np.testing.assert_allclose(np.asarray(want[k]),
                                   np.asarray(got[k]),
                                   rtol=1e-6, atol=1e-6)
    kernel_spans = [s for s in global_tracer.spans
                    if s.name.startswith("kernel:")]
    assert kernel_spans
    assert any(s.name == "compiled:profile"
               for s in global_tracer.spans)
