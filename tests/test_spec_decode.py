"""Speculative decoding: exactness, rollback, termination, metrics.

The load-bearing property: the emitted token stream is **bit-identical**
to PR 3's per-token decode — at any temperature, on fp and int8 paged
caches — because acceptance compares a draft against the token the
deterministic sampler would emit from the verified logits.  Drafters can
only change how many jitted steps the stream takes, never its content.
"""
import jax.numpy as jnp
import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.models import get_model
from repro.serve import (FixedDrafter, NgramDrafter, Request,
                         ServingEngine, derive_kv_spec)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-1.5b", reduced=True)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def int8_spec(setup):
    cfg, model, params = setup
    return derive_kv_spec(model, params)


def _mixed_requests(cfg, temperature=0.0):
    """Mixed queue: repetitive prompts (drafter accepts) + random ones
    (drafter mostly rejects), varying lengths and budgets."""
    rng = np.random.default_rng(3)
    pat = rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32)
    prompts = [np.tile(pat, 3),
               rng.integers(0, cfg.vocab, size=(5,)).astype(np.int32),
               np.tile(pat, 2),
               rng.integers(0, cfg.vocab, size=(9,)).astype(np.int32),
               np.tile(rng.integers(0, cfg.vocab, size=(3,)), 4),
               rng.integers(0, cfg.vocab, size=(2,)).astype(np.int32)]
    budgets = (10, 7, 8, 5, 9, 6)
    return [Request(prompt=p.copy(), max_new_tokens=m,
                    temperature=temperature)
            for p, m in zip(prompts, budgets)]


# ---------------------------------------------------------------------------
# drafters
# ---------------------------------------------------------------------------

def test_ngram_drafter_prompt_lookup():
    d = NgramDrafter(max_ngram=3)
    # suffix [1, 2] reoccurs at the start; what followed is [3, 1]
    assert d.propose([1, 2, 3, 1, 2], k=2) == [3, 1]
    # longest suffix wins: [2, 3] matched over plain [3]
    assert d.propose([1, 2, 3, 9, 2, 3], k=1) == [9]
    # no history → nothing proposed
    assert d.propose([7], k=4) == []
    assert d.propose([1, 2, 3], k=0) == []
    with pytest.raises(ValueError):
        NgramDrafter(max_ngram=0)


def test_drafter_registry():
    from repro.serve import get_drafter
    assert isinstance(get_drafter("ngram"), NgramDrafter)
    with pytest.raises(ValueError, match="unknown drafter"):
        get_drafter("tiny-model")


# ---------------------------------------------------------------------------
# exactness: speculative == per-token, greedy and sampled, fp and int8
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv", ["fp", "int8"])
def test_spec_greedy_identical_with_midstream_arrivals(setup, int8_spec, kv):
    """Queue deeper than the slot count, mixed repetitive/random prompts,
    requests arriving mid-stream: greedy speculative output must be
    bit-identical to the per-token engine on both cache dtypes."""
    cfg, model, params = setup
    spec = int8_spec if kv == "int8" else "fp"

    def serve(**kw):
        eng = ServingEngine(model, params, batch_slots=2, max_seq=64,
                            kv_cache=spec, **kw)
        reqs = _mixed_requests(cfg)
        handles = [eng.submit(r) for r in reqs[:4]]
        for _ in range(3):
            eng.step()                      # mid-stream...
        handles += [eng.submit(r) for r in reqs[4:]]   # ...late arrivals
        eng.run()
        return [eng.scheduler.outputs[h] for h in handles], eng

    base, _ = serve()
    outs, eng = serve(spec_decode="ngram", spec_k=4)
    assert outs == base
    m = eng.metrics.summary()
    assert m["spec_proposed"] > 0
    assert m["spec_accepted"] > 0, "repetitive prompts must accept"
    # speculation actually saved jitted steps on this workload
    assert m["tokens_per_decode_step"] > 1.0


def test_spec_sampled_identical(setup):
    """Deterministic sampling makes verification exact at temperature:
    the sampled stream (not just greedy) is bit-identical."""
    cfg, model, params = setup
    reqs = lambda: _mixed_requests(cfg, temperature=30.0)[:4]
    base = ServingEngine(model, params, batch_slots=2, max_seq=64,
                         seed=7).generate(reqs())
    outs = ServingEngine(model, params, batch_slots=2, max_seq=64,
                         seed=7, spec_decode="ngram",
                         spec_k=3).generate(reqs())
    assert outs == base
    assert any(len(set(o)) > 1 for o in base), "temperature visible"


def test_spec_under_page_pressure(setup):
    """A pool too small for full verify windows: proposals are dropped
    (never preempting a victim just to speculate) and, when the pool is
    dry outright, the newest request is preempted and replayed — output
    still bit-identical to the per-token engine."""
    cfg, model, params = setup
    rng = np.random.default_rng(11)
    pat = rng.integers(0, cfg.vocab, size=(3,))
    reqs = lambda: [Request(prompt=np.tile(pat, 3), max_new_tokens=10),
                    Request(prompt=np.tile(pat, 2), max_new_tokens=10)]
    kw = dict(batch_slots=2, max_seq=24, page_size=4, num_pages=7)
    base = ServingEngine(model, params, **kw).generate(reqs())
    eng = ServingEngine(model, params, spec_decode="ngram", spec_k=4, **kw)
    outs = eng.generate(reqs())
    assert outs == base
    assert eng.cache.used_pages == 0


# ---------------------------------------------------------------------------
# rollback
# ---------------------------------------------------------------------------

def test_rejected_window_leaves_cache_state_exact(setup):
    """Write-then-reject: a speculative window scattered into the page
    pool and rolled back must leave the next decode's logits bit-equal,
    and must not churn the page pool (reserved pages stay owned)."""
    cfg, model, params = setup
    eng = ServingEngine(model, params, batch_slots=2, max_seq=64)
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab, size=(6,))
    eng.submit(Request(prompt=prompt, max_new_tokens=8))
    eng.step()                              # prefill + one decode step
    st = eng.scheduler.slots[0]
    B, L = eng.B, st.length

    def probe_logits():
        toks = np.zeros((B, 1), np.int32)
        toks[0, 0] = st.entry.seq[-1]
        lens = np.zeros((B,), np.int32)
        lens[0] = L
        logits, _pages = eng._step_fn(      # discard pages: no commit
            eng.params, jnp.asarray(toks), eng.cache.pages,
            eng.cache.device_table(), jnp.asarray(lens))
        return np.asarray(logits[0, 0].astype(jnp.float32))

    before = probe_logits()
    # speculative window of garbage tokens at [L, L+4), then reject all
    assert eng.cache.reserve(0, L + 4)
    free_after_reserve = len(eng.cache.free)
    toks = np.zeros((B, 4), np.int32)
    toks[0] = (np.asarray(st.entry.seq[-1]) + np.arange(4) + 1) % cfg.vocab
    lens = np.zeros((B,), np.int32)
    lens[0] = L
    _, pages = eng._step_fn(eng.params, jnp.asarray(toks), eng.cache.pages,
                            eng.cache.device_table(), jnp.asarray(lens))
    eng.cache.pages = pages                 # garbage committed to pool...
    eng.cache.rollback(0, L)                # ...then rolled back
    assert len(eng.cache.free) == free_after_reserve, "no pool churn"
    after = probe_logits()
    np.testing.assert_array_equal(before, after)
    eng.run()                               # engine still completes


# ---------------------------------------------------------------------------
# termination inside the window
# ---------------------------------------------------------------------------

class _OracleDrafter(FixedDrafter):
    """Proposes the exact continuation stream — guarantees every draft
    is accepted, pinning EOS inside an accepted window."""

    def __init__(self, prompt_len: int, stream):
        super().__init__(stream)
        self.prompt_len = prompt_len

    def propose(self, seq, k, request_id=0):
        n_gen = len(seq) - self.prompt_len
        return self.tokens[n_gen:n_gen + k]


def test_eos_inside_accepted_window_terminates_and_frees(setup):
    """EOS accepted mid-window ends the request right there: later
    emitted tokens are discarded, the slot and its pages free.

    Greedy random-weight streams collapse to a constant token (EOS would
    land on the prefill-emitted index 0), so this uses a temperature
    stream — still exact under speculative decoding — with an oracle
    drafter so the EOS position is provably an accepted draft."""
    cfg, model, params = setup
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, cfg.vocab, size=(6,))
    mk = lambda eos=None: [Request(prompt=prompt, max_new_tokens=10,
                                   temperature=25.0, eos_id=eos)]
    base = ServingEngine(model, params, batch_slots=1, max_seq=64,
                         seed=7).generate(mk())[0]
    # an eos first emitted at a draft position of the first verify
    # window: window indices 1..4 are drafts, 5 is the bonus token
    idx, eos = next((i, t) for i, t in enumerate(base)
                    if 1 <= i <= 4 and t not in base[:i])
    eng = ServingEngine(
        model, params, batch_slots=1, max_seq=64, seed=7,
        spec_decode=_OracleDrafter(len(prompt), base), spec_k=4)
    outs = eng.generate(mk(eos))
    assert outs[0] == base[:idx + 1]        # stopped at EOS, EOS included
    assert eng.metrics.spec_accepted >= idx, "EOS was an accepted draft"
    assert eng.cache.used_pages == 0        # pages freed
    assert eng.scheduler.active_slots() == []
    assert not eng.scheduler.has_work()


def test_zero_proposals_degrade_to_per_token_path(setup):
    """A drafter that proposes nothing must reproduce PR 3 exactly —
    same tokens from the same number of T=1 decode steps, no spec
    metrics recorded."""
    cfg, model, params = setup
    reqs = lambda: _mixed_requests(cfg)[:3]
    base_eng = ServingEngine(model, params, batch_slots=2, max_seq=64)
    base = base_eng.generate(reqs())
    eng = ServingEngine(model, params, batch_slots=2, max_seq=64,
                        spec_decode=FixedDrafter([]), spec_k=4)
    outs = eng.generate(reqs())
    assert outs == base
    m = eng.metrics.summary()
    assert m["spec_steps"] == 0 and m["spec_proposed"] == 0
    assert m["decode_steps"] == base_eng.metrics.summary()["decode_steps"]
    assert m["tokens_per_decode_step"] == 1.0


# ---------------------------------------------------------------------------
# metrics + guards
# ---------------------------------------------------------------------------

def test_acceptance_metrics_sanity(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(13)
    pat = rng.integers(0, cfg.vocab, size=(4,))
    eng = ServingEngine(model, params, batch_slots=2, max_seq=64,
                        spec_decode="ngram", spec_k=3)
    outs = eng.generate([Request(prompt=np.tile(pat, 3), max_new_tokens=9),
                         Request(prompt=np.tile(pat, 2), max_new_tokens=7)])
    m = eng.metrics.summary()
    assert m["total_tokens"] == sum(len(o) for o in outs) == 16
    assert m["spec_steps"] >= 1
    assert m["spec_accepted"] <= m["spec_proposed"]
    assert 0.0 <= m["acceptance_rate"] <= 1.0
    assert 1.0 <= m["tokens_per_decode_step"] <= 1.0 + eng.spec_k
    # non-speculative engines report the metrics as nan, not garbage
    plain = ServingEngine(model, params, batch_slots=1, max_seq=32)
    plain.generate([Request(prompt=pat, max_new_tokens=2)])
    s = plain.metrics.summary()
    assert s["spec_steps"] == 0 and np.isnan(s["acceptance_rate"])
    assert s["tokens_per_decode_step"] == 1.0


def test_spec_decode_guards(setup):
    cfg, model, params = setup
    with pytest.raises(NotImplementedError, match="paged"):
        ServingEngine(model, params, batch_slots=1, max_seq=32,
                      mode="static", spec_decode="ngram")
    with pytest.raises(ValueError, match="spec_k"):
        ServingEngine(model, params, batch_slots=1, max_seq=32,
                      spec_decode="ngram", spec_k=0)
