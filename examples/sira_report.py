"""SIRA analysis report for any assigned architecture: accumulator widths,
layer-tail implementation choice, and FPGA/TPU cost projections — driven
by the SiraModel pass pipeline.

    PYTHONPATH=src python examples/sira_report.py --arch glm4-9b
"""
import argparse


from repro.configs import get_config, list_archs
from repro.core import (MinimizeAccumulators, SiraModel, Streamline,
                        summarize)
from repro.core.costmodel import select_tail_style, tail_cost
from repro.models.export import export_block_graph


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=list_archs())
    ap.add_argument("--w-bits", type=int, default=4)
    ap.add_argument("--a-bits", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    print(f"=== SIRA report: {args.arch} (reduced block, "
          f"w{args.w_bits}a{args.a_bits}) ===")
    g, inp = export_block_graph(cfg, w_bits=args.w_bits, a_bits=args.a_bits)
    model = SiraModel(g, inp, name=args.arch).transform(
        Streamline(), MinimizeAccumulators())
    reps = model.metadata["accumulator_reports"]
    print(f"{'kernel':28s} {'K':>6s} {'SIRA':>5s} {'dtype':>6s} {'save':>6s}")
    for r in reps:
        print(f"{r.node_name:28s} {r.K:6d} {r.sira_bits:4d}b "
              f"{r.datatype_bits:5d}b {r.reduction_vs_datatype:6.0%}")
    s = summarize(reps)
    print(f"\nmean accumulator: {s['mean_sira']:.1f}b SIRA vs "
          f"{s['mean_datatype']:.1f}b datatype-bound "
          f"({s['reduction_vs_datatype']:.0%} smaller; paper avg 22%)")

    n_i = int(round(s["mean_sira"]))
    style = select_tail_style(n_i, args.a_bits, 16, cfg.d_model, 4)
    tc = tail_cost(n_i, args.a_bits, 16, cfg.d_model, 4)
    print(f"\nlayer-tail style for {args.a_bits}-bit activations: {style}")
    print(f"  thresholding: {tc.thresholding_luts:,.0f} LUTs | "
          f"composite fixed16.8: {tc.composite_luts:,.0f} LUTs")
    print("TPU mapping: accumulator dtype "
          f"{'int16' if s['mean_sira'] <= 15 else 'int32'}, fused "
          f"multithreshold tail (1 HBM pass)")


if __name__ == "__main__":
    main()
