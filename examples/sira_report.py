"""SIRA analysis report: accumulator widths, layer-tail implementation
choice, and FPGA/TPU cost projections — driven by the SiraModel pass
pipeline.  With ``--workload``, additionally runs the dataflow DSE
subsystem and prints the per-node resource/II/style report plus the
SIRA-vs-baseline accelerator deltas and the folding search.

    PYTHONPATH=src python examples/sira_report.py --arch glm4-9b
    PYTHONPATH=src python examples/sira_report.py --workload TFC-w2a2

Observability hooks (``repro.obs``): ``--trace out.json`` records the
whole report run (flow steps, range analysis, compile) as a Chrome
trace_event JSON loadable in Perfetto; ``--explain TENSOR`` prints the
range-provenance chain for a tensor of the analyzed model — which op
handler produced each range, under which abstract domain, and which
input was the widening culprit.

    PYTHONPATH=src python examples/sira_report.py --workload CNV-w2a2 \
        --trace out.json --explain <acc-tensor>
"""
import argparse


from repro.configs import get_config, list_archs
from repro.core import (MinimizeAccumulators, SiraModel, Streamline,
                        build_flow, summarize)
from repro.core.workloads import ALL_WORKLOADS
from repro.dataflow import (compare_sira_vs_baseline, extract_dataflow,
                            search_folding, select_tail_style, tail_cost)
from repro.models.export import export_block_graph
from repro.obs import disable_tracing, enable_tracing


def arch_report(args) -> "SiraModel":
    cfg = get_config(args.arch, reduced=True)
    print(f"=== SIRA report: {args.arch} (reduced block, "
          f"w{args.w_bits}a{args.a_bits}) ===")
    g, inp = export_block_graph(cfg, w_bits=args.w_bits, a_bits=args.a_bits)
    model = SiraModel(g, inp, name=args.arch).transform(
        Streamline(), MinimizeAccumulators())
    reps = model.metadata["accumulator_reports"]
    print(f"{'kernel':28s} {'K':>6s} {'SIRA':>5s} {'dtype':>6s} {'save':>6s}")
    for r in reps:
        print(f"{r.node_name:28s} {r.K:6d} {r.sira_bits:4d}b "
              f"{r.datatype_bits:5d}b {r.reduction_vs_datatype:6.0%}")
    s = summarize(reps)
    print(f"\nmean accumulator: {s['mean_sira']:.1f}b SIRA vs "
          f"{s['mean_datatype']:.1f}b datatype-bound "
          f"({s['reduction_vs_datatype']:.0%} smaller; paper avg 22%)")

    n_i = int(round(s["mean_sira"]))
    style = select_tail_style(n_i, args.a_bits, 16, cfg.d_model, 4)
    tc = tail_cost(n_i, args.a_bits, 16, cfg.d_model, 4)
    print(f"\nlayer-tail style for {args.a_bits}-bit activations: {style}")
    print(f"  thresholding: {tc.thresholding_luts:,.0f} LUTs | "
          f"composite fixed16.8: {tc.composite_luts:,.0f} LUTs")
    print("TPU mapping: accumulator dtype "
          f"{'int16' if s['mean_sira'] <= 15 else 'int32'}, fused "
          f"multithreshold tail (1 HBM pass)")
    return model


def verification_report(model) -> None:
    """Surface the verify_ranges containment/coverage artifacts
    (``--verify``): violations, per-tensor range coverage, and channels
    SIRA proves stuck at a constant value."""
    from repro.core import stuck_channels
    rep = model.metadata.get("verification")
    if rep is None:
        print("\nverification: no report (no sample inputs available)")
        return
    print(f"\n=== range verification ({model.domain} domain) ===")
    status = "PASS" if rep.contained else "FAIL"
    print(f"containment: {status} "
          f"({len(rep.observed)} tensors instrumented)")
    for v in rep.violations[:10]:
        print(f"  violation: {v}")
    cov = sorted(rep.coverage.items(), key=lambda kv: kv[1])
    if cov:
        mean_cov = sum(c for _, c in cov) / len(cov)
        print(f"coverage: mean {mean_cov:.0%} of proven width observed; "
              f"loosest tensors:")
        for name, c in cov[:5]:
            lo, hi = rep.observed[name]
            print(f"  {name:28s} {c:6.1%}  observed [{lo:.4g}, {hi:.4g}]")
    n_stuck = 0
    for t in model.graph.outputs:
        if t in model.ranges:
            mask = stuck_channels(model.ranges, t)
            n_stuck += int(mask.sum())
    if n_stuck:
        print(f"stuck output channels (provably constant): {n_stuck}")


def workload_report(args) -> "SiraModel":
    print(f"=== Dataflow DSE report: {args.workload} on {args.device} "
          f"[{args.domain} domain] ===")
    model = build_flow(ALL_WORKLOADS[args.workload](),
                       domain=args.domain).model

    reports = model.metadata.get("tail_reports", [])
    if reports:
        print("\nthreshold conversion (monotonicity certificates):")
        for r in reports:
            if r.converted:
                print(f"  {r.anchor:14s} converted  {r.status}/{r.method} "
                      f"({r.n_ops} ops -> 1 MultiThreshold)")
            else:
                print(f"  {r.anchor:14s} kept chain uncertified: "
                      f"{r.reason} -> meta-kernel pricing")

    dfg = extract_dataflow(model)
    fold = search_folding(model, target_fps=args.target_fps,
                          device=args.device, dataflow_graph=dfg)
    folding = fold.folding if fold.feasible else None
    comp = compare_sira_vs_baseline(model, device=args.device,
                                    folding=folding, dataflow_graph=dfg)
    est = comp.sira

    print(f"\n{'node':22s} {'kind':11s} {'style':13s} {'PExSIMD':>8s} "
          f"{'II':>7s} {'bits i/o/acc':>12s} {'LUT':>7s} {'DSP':>4s} "
          f"{'BRAM':>5s}")
    for n in est.nodes:
        mark = " <- bottleneck" if n.name == est.bottleneck else ""
        print(f"{n.name:22s} {n.kind:11s} {n.style:13s} "
              f"{n.pe:>4d}x{n.simd:<3d} {n.cycles:>7d} "
              f"{n.in_bits:>4d}/{n.out_bits}/{n.acc_bits:<3d} "
              f"{n.luts:>7.0f} {n.dsps:>4d} {n.brams:>5d}{mark}")
    fifo_luts = sum(f.luts for f in est.fifos)
    fifo_brams = sum(f.brams for f in est.fifos)
    print(f"{'(stream FIFOs)':22s} {'':11s} {'':13s} {'':>8s} {'':>7s} "
          f"{'':>12s} {fifo_luts:>7.0f} {'':>4s} {fifo_brams:>5d}")

    b = comp.baseline
    print("\ntotals (SIRA vs datatype-bound baseline, same folding):")
    print(f"  LUTs {b.luts:,.0f} -> {est.luts:,.0f} "
          f"(-{comp.lut_reduction:.0%}; paper -17%)")
    print(f"  DSPs {b.dsps} -> {est.dsps} "
          f"(-{comp.dsp_reduction:.0%}; paper -66%)")
    print(f"  BRAMs {b.brams} -> {est.brams}")
    print(f"  mean accumulator {comp.mean_acc_bits_datatype:.1f}b -> "
          f"{comp.mean_acc_bits_sira:.1f}b "
          f"(-{comp.acc_bits_reduction:.0%}; paper -22%)")
    print(f"  layer-tail rLUT {comp.tail_lut_ratio:.2f}")

    print(f"\nfolding search @ {args.target_fps:g} FPS on {args.device}:")
    if fold.feasible:
        util = ", ".join(f"{k} {v:.0%}"
                         for k, v in fold.utilization.items())
        print(f"  feasible — achieved {fold.achieved_fps:,.0f} FPS "
              f"({util})")
    else:
        print(f"  infeasible — binding constraint: {fold.binding}")

    if args.verify:
        verification_report(model)
    return model


def explain_report(model, tensor: str) -> None:
    """Print the range-provenance chain for one tensor (``--explain``)."""
    print(f"\n=== range provenance: {tensor} ===")
    try:
        chain = model.explain(tensor)
    except KeyError as e:
        raise SystemExit(f"--explain: {e.args[0]}") from None
    print(chain.render())


def _resolve_workload(name: str) -> str:
    """Accept either the exact workload key or a unique prefix
    (``CNV`` -> ``CNV-w2a2``)."""
    if name in ALL_WORKLOADS:
        return name
    hits = sorted(k for k in ALL_WORKLOADS if k.startswith(name))
    if len(hits) == 1:
        return hits[0]
    raise SystemExit(f"--workload: unknown workload {name!r} "
                     f"(choices: {sorted(ALL_WORKLOADS)})")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=list_archs())
    ap.add_argument("--workload", metavar="NAME",
                    help="print the dataflow DSE per-node report for a "
                         "QNN workload instead of an LM-arch report "
                         f"(choices: {sorted(ALL_WORKLOADS)}; a unique "
                         "prefix like 'CNV' is accepted)")
    ap.add_argument("--device", default="pynq-z1")
    ap.add_argument("--target-fps", type=float, default=1000.0)
    ap.add_argument("--w-bits", type=int, default=4)
    ap.add_argument("--a-bits", type=int, default=4)
    ap.add_argument("--domain", default="interval",
                    choices=("interval", "affine"),
                    help="range-analysis abstract domain (affine = "
                         "zonotope reduced product, tighter bounds)")
    ap.add_argument("--verify", action="store_true",
                    help="print the verify_ranges containment/coverage "
                         "report (workload reports only)")
    ap.add_argument("--trace", default=None, metavar="TRACE_JSON",
                    help="record the report run (flow/analysis/compile "
                         "spans) and write a Chrome trace_event JSON "
                         "loadable in Perfetto")
    ap.add_argument("--explain", default=None, metavar="TENSOR",
                    help="print the range-provenance chain for TENSOR "
                         "of the analyzed model")
    args = ap.parse_args()
    if args.workload:
        args.workload = _resolve_workload(args.workload)

    tracer = enable_tracing() if args.trace else None
    try:
        model = workload_report(args) if args.workload else arch_report(args)
        if args.trace:
            # compile too, so the trace carries the backend-lowering
            # spans alongside flow/analysis — the full pipeline picture
            model.compile()
        if args.explain:
            explain_report(model, args.explain)
    finally:
        if tracer is not None:
            disable_tracing()
    if tracer is not None:
        tracer.write_chrome_trace(args.trace)
        print(f"\nwrote {args.trace} ({len(tracer.spans)} spans — open "
              f"in https://ui.perfetto.dev)")


if __name__ == "__main__":
    main()
