"""Quickstart: SIRA on a quantized MLP — analyze, streamline, threshold,
minimize accumulators, and run the integer pipeline with the TPU kernels.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (ScaledIntRange, analyze, convert_tails_to_thresholds,
                        minimize_accumulators, streamline, summarize)
from repro.core.workloads import make_tfc


def main() -> None:
    wl = make_tfc()
    print(f"=== {wl.name}: {len(wl.graph.nodes)} nodes ===")

    # 1) SIRA analysis: ranges, scales, biases for every tensor
    ranges = analyze(wl.graph, wl.input_range)
    n_si = sum(r.is_scaled_int for r in ranges.values())
    print(f"SIRA: {len(ranges)} tensors analyzed, {n_si} scaled-integer")

    # 2) streamlining: aggregate scales/biases → integer MatMul kernels
    res = streamline(wl.graph, wl.input_range)
    print(f"streamlined: {len(wl.graph.nodes)} → {len(res.graph.nodes)} "
          f"nodes, {len(res.erased)} scale/bias constants aggregated")

    # 3) accumulator minimization (paper §4.2)
    reps = minimize_accumulators(res.graph, wl.input_range)
    s = summarize(reps)
    for r in reps:
        print(f"  {r.op_type} K={r.K}: SIRA {r.sira_bits}b vs "
              f"datatype-bound {r.datatype_bits}b")
    print(f"accumulators: {s['reduction_vs_datatype']:.0%} below the "
          f"datatype bound (paper: 22%)")

    # 4) threshold conversion (paper §4.1.3)
    g2, specs = convert_tails_to_thresholds(res.graph, wl.input_range)
    print(f"thresholding: {len(specs)} layer tails collapsed to "
          f"MultiThreshold nodes")

    # 5) equivalence: the whole pipeline is numerically exact
    rng = np.random.default_rng(0)
    x = np.abs(rng.uniform(0, 1, size=wl.input_shape))
    y0 = wl.graph.execute({"X": x})[wl.graph.outputs[0]]
    y2 = g2.execute({"X": x})[g2.outputs[0]]
    assert np.allclose(y0, y2), "pipeline must be exact"
    print("equivalence: original == streamlined+thresholded (exact)")


if __name__ == "__main__":
    main()
