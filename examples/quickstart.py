"""Quickstart: SIRA on a quantized MLP with the SiraModel pass pipeline —
analyze, streamline, threshold, minimize accumulators, verify, all driven
by one declarative build flow with a cached range analysis.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import SiraModel, build_flow, summarize
from repro.core.workloads import make_tfc


def main() -> None:
    wl = make_tfc()
    model = SiraModel.from_workload(wl)
    print(f"=== {model.name}: {len(model.graph.nodes)} nodes ===")

    # 1) SIRA analysis: ranges, scales, biases for every tensor — computed
    #    once, cached on the model, invalidated only by graph mutation
    n_si = sum(r.is_scaled_int for r in model.ranges.values())
    print(f"SIRA: {len(model.ranges)} tensors analyzed, "
          f"{n_si} scaled-integer")

    # 2) the whole optimization pipeline as one declarative flow
    #    (explicitize → aggregate → threshold → accumulators → verify),
    #    with per-step numerical-equivalence checks armed
    result = build_flow(model, verify="equivalence")
    for step in result.steps:
        print(f"  step {step.name:28s} modified={str(step.modified):5s} "
              f"analyses={step.analysis_calls} {step.seconds * 1e3:7.1f} ms")
    print(f"streamlined: {len(wl.graph.nodes)} → "
          f"{len(result.graph.nodes)} nodes, "
          f"{len(result.aggregation.erased)} scale/bias constants "
          f"aggregated, {len(result.threshold_specs)} layer tails "
          f"collapsed to MultiThreshold nodes")

    # 3) accumulator minimization (paper §4.2) — report from the flow
    reps = result.accumulator_reports
    s = summarize(reps)
    for r in reps:
        print(f"  {r.op_type} K={r.K}: SIRA {r.sira_bits}b vs "
              f"datatype-bound {r.datatype_bits}b")
    print(f"accumulators: {s['reduction_vs_datatype']:.0%} below the "
          f"datatype bound (paper: 22%)")

    # 4) empirical verification (paper §6.1) ran as the final flow step
    print(f"verification: contained={result.verification.contained} over "
          f"{len(result.verification.observed)} tensors")

    # 5) equivalence: the whole pipeline is numerically exact
    rng = np.random.default_rng(0)
    x = np.abs(rng.uniform(0, 1, size=wl.input_shape))
    y0 = wl.graph.execute({"X": x})[wl.graph.outputs[0]]
    y2 = result.graph.execute({"X": x})[result.graph.outputs[0]]
    assert np.allclose(y0, y2), "pipeline must be exact"
    print("equivalence: original == streamlined+thresholded (exact)")

    # 6) compiled backend: one jitted JAX callable routed through the
    #    Pallas kernels (int_matmul with the SIRA accumulator width,
    #    fused multithreshold/quantize), batched
    compiled = result.model.compile()
    xb = np.abs(rng.uniform(0, 1, size=(32,) + wl.input_shape[1:]))
    yc = compiled({"X": xb})[result.graph.outputs[0]]
    yi = result.graph.execute({"X": xb})[result.graph.outputs[0]]
    assert np.allclose(yc, yi, rtol=1e-5, atol=1e-5)
    print(f"compiled backend: {compiled.kernel_calls} — matches the "
          f"interpreter on a batch of {xb.shape[0]}")


if __name__ == "__main__":
    main()
