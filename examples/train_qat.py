"""End-to-end QAT training driver example: train a reduced assigned arch
with w8a8 fake-quant for a few hundred steps, with checkpoint/resume.

    PYTHONPATH=src python examples/train_qat.py            # ~2 min on CPU
    PYTHONPATH=src python examples/train_qat.py --steps 300 --arch glm4-9b
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    args = sys.argv[1:]
    defaults = ["--arch", "qwen2-1.5b", "--reduced", "--steps", "200",
                "--batch", "8", "--seq", "64", "--quant-bits", "8",
                "--ckpt-dir", "/tmp/repro_qat_ckpt", "--ckpt-every", "100"]
    # user args override defaults
    known = {a for a in args if a.startswith("--")}
    merged = list(args)
    i = 0
    while i < len(defaults):
        if defaults[i] not in known:
            merged.append(defaults[i])
            if i + 1 < len(defaults) and not defaults[i + 1].startswith("--"):
                merged.append(defaults[i + 1])
                i += 1
        elif i + 1 < len(defaults) and not defaults[i + 1].startswith("--"):
            i += 1
        i += 1
    raise SystemExit(main(merged))
