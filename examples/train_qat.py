"""Accumulator-aware QAT example: train a small QNN under an
accumulator-bit budget (A2Q/A2Q+ projection, ``repro.qat``), then run
the trained weights through SIRA + the dataflow DSE and print the
proven-bits / resource report — the paper stack's train -> analyze ->
optimize -> price loop in one command.

    PYTHONPATH=src python examples/train_qat.py                 # ~30 s
    PYTHONPATH=src python examples/train_qat.py --budget 12
    PYTHONPATH=src python examples/train_qat.py --budget 12 --zero-center
    PYTHONPATH=src python examples/train_qat.py --budget 0      # off

(The generic LM-arch QAT trainer lives at ``python -m
repro.launch.train``; this example drives the accumulator-budget loop.)
"""
import argparse

from repro.dataflow import compare_sira_vs_baseline
from repro.qat import (QATConfig, check_budget_invariant,
                       proven_layer_bits, run_qat)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=int, default=14,
                    help="target accumulator bits per layer "
                         "(0 = unconstrained)")
    ap.add_argument("--zero-center", action="store_true",
                    help="A2Q+ zero-centering variant (asymmetric caps; "
                         "roughly 2x the feasible weight mass)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--hidden", type=int, nargs="+", default=[32, 32])
    ap.add_argument("--weight-bits", type=int, default=4)
    ap.add_argument("--act-bits", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--device", default="pynq-z1")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint/resume directory (optional)")
    args = ap.parse_args()

    cfg = QATConfig(budget=args.budget, zero_center=args.zero_center,
                    steps=args.steps, hidden=tuple(args.hidden),
                    weight_bits=args.weight_bits, act_bits=args.act_bits,
                    seed=args.seed, ckpt_dir=args.ckpt_dir)
    tag = (f"budget {args.budget}b"
           + (" + zero-center" if args.zero_center else "")
           if args.budget else "unconstrained")
    print(f"=== accumulator-aware QAT: {tag}, "
          f"w{cfg.weight_bits}a{cfg.act_bits}, {cfg.steps} steps ===")
    res = run_qat(cfg)
    if res.resumed_from:
        print(f"resumed from step {res.resumed_from}")
    print(f"task loss {res.losses[0]:.4f} -> {res.final_loss:.4f}")

    result, bits = proven_layer_bits(res.model, res.state.params)
    budgets = res.model.budgets()
    print(f"\n{'layer':12s} {'K':>5s} {'budget':>7s} {'proven':>7s}")
    for i, (b, budget) in enumerate(zip(bits, budgets)):
        k = res.model.layer_dims[i][0]
        tgt = f"{budget.bits}b" if budget else "-"
        print(f"l{i}_matmul    {k:5d} {tgt:>7s} {b:6d}b")
    if args.budget:
        check_budget_invariant(res.model, res.state.params, bits)
        print("A2Q invariant holds: proven bits <= budget on every layer")

    comp = compare_sira_vs_baseline(result.model, device=args.device)
    b = comp.baseline
    print(f"\nDSE on {args.device} (SIRA vs datatype-bound baseline):")
    print(f"  LUTs {b.luts:,.0f} -> {comp.sira.luts:,.0f} "
          f"(-{comp.lut_reduction:.0%})")
    print(f"  DSPs {b.dsps} -> {comp.sira.dsps} "
          f"(-{comp.dsp_reduction:.0%})")
    print(f"  mean accumulator {comp.mean_acc_bits_datatype:.1f}b -> "
          f"{comp.mean_acc_bits_sira:.1f}b")


if __name__ == "__main__":
    main()
