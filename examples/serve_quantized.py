"""Continuous-batching serving on the SIRA-optimized integer path:
int8 packed weights + a paged KV cache whose int8 storage scales come
from SIRA range analysis of the exported K/V projection graphs.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import get_model
from repro.quant.quantizer import pack_weights_int8
from repro.serve import (Request, ServingConfig, ServingEngine,
                         derive_kv_spec)


def main() -> None:
    cfg = get_config("qwen2-1.5b", reduced=True)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    # a queue twice as deep as the slot count: the scheduler streams
    # requests through freed slots instead of serving fixed waves
    reqs = [Request(prompt=rng.integers(0, cfg.vocab,
                                        size=(int(rng.integers(4, 12)),)),
                    max_new_tokens=int(rng.integers(8, 24)))
            for _ in range(8)]

    eng_fp = ServingEngine(model, params,
                           ServingConfig(batch_slots=4, max_seq=64))
    t0 = time.time()
    out_fp = eng_fp.generate(reqs)
    t_fp = time.time() - t0

    # int8 weights + SIRA-derived int8 KV cache (scales are per layer and
    # per KV head, with fp fallback for any layer whose proven range is
    # too wide — see serve/kv_cache.py).  The spec must be derived from
    # the weights actually served: packing perturbs each projection by up
    # to half a quant step, so fp-derived ranges would not cover it.
    params_q = pack_weights_int8(params, min_size=64)
    spec = derive_kv_spec(model, params_q)
    eng_q = ServingEngine(model, params_q,
                          ServingConfig(batch_slots=4, max_seq=64,
                                        kv_cache=spec))
    t0 = time.time()
    out_q = eng_q.generate(reqs)
    t_q = time.time() - t0

    agree = np.mean([a == b for fa, fb in zip(out_fp, out_q)
                     for a, b in zip(fa, fb)])
    m_fp, m_q = eng_fp.metrics.summary(), eng_q.metrics.summary()
    print(f"fp serving:    {t_fp:.2f}s  "
          f"ttft={m_fp['mean_ttft_s'] * 1e3:.1f}ms  "
          f"occupancy={m_fp['slot_occupancy']:.2f}  "
          f"kv={eng_fp.cache.hbm_bytes() / 1024:.0f} KiB")
    print(f"int8 serving:  {t_q:.2f}s  "
          f"ttft={m_q['mean_ttft_s'] * 1e3:.1f}ms  "
          f"occupancy={m_q['slot_occupancy']:.2f}  "
          f"kv={eng_q.cache.hbm_bytes() / 1024:.0f} KiB  "
          f"({spec.n_int8}/{len(spec.layers)} layers int8)")
    print(f"greedy token agreement: {agree:.0%}")
    print("(int8 weights halve HBM weight traffic; the int8 paged cache "
          "quarters KV storage vs f32 and frees pages the moment a "
          "request finishes — the scales are proven ranges, so "
          "saturation cannot occur in-range: A2Q-style guarantee)")


if __name__ == "__main__":
    main()
