"""Batched serving with the SIRA-optimized integer path: int8 packed
weights + int8 scaled-integer KV cache, compared to the bf16 baseline.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import get_model
from repro.quant.quantizer import pack_weights_int8
from repro.serve import Request, ServingEngine


def main() -> None:
    cfg = get_config("qwen2-1.5b", reduced=True)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=(8,)),
                    max_new_tokens=16) for _ in range(4)]

    eng_fp = ServingEngine(model, params, batch_slots=4, max_seq=64)
    t0 = time.time()
    out_fp = eng_fp.generate(reqs)
    t_fp = time.time() - t0

    params_q = pack_weights_int8(params, min_size=64)
    eng_q = ServingEngine(model, params_q, batch_slots=4, max_seq=64)
    t0 = time.time()
    out_q = eng_q.generate(reqs)
    t_q = time.time() - t0

    agree = np.mean([a == b for fa, fb in zip(out_fp, out_q)
                     for a, b in zip(fa, fb)])
    print(f"bf16 serving:  {t_fp:.2f}s  tokens: {out_fp[0][:8]}")
    print(f"int8 serving:  {t_q:.2f}s  tokens: {out_q[0][:8]}")
    print(f"greedy token agreement: {agree:.0%}")
    print("(int8 weights halve HBM weight traffic on TPU; with the int8 "
          "KV cache the decode memory term drops ~57% — EXPERIMENTS.md "
          "§Perf)")


if __name__ == "__main__":
    main()
