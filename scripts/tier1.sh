#!/usr/bin/env bash
# Tier-1 verification: the full test suite, fail-fast, from the repo root
# (includes the kernel interpret-mode sweeps and the compiled-backend
# equivalence tests), then the benchmark smoke runs which emit
# BENCH_backend.json, BENCH_serving.json, BENCH_dataflow.json and
# BENCH_qat.json, then the perf-regression gate comparing them against
# the committed benchmarks/baselines/.
#   bash scripts/tier1.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python benchmarks/bench_backend.py \
    --quick --out BENCH_backend.json --trace trace.json
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python benchmarks/bench_serving.py \
    --quick --out BENCH_serving.json
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python benchmarks/bench_dataflow.py \
    --quick --out BENCH_dataflow.json
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python benchmarks/bench_qat.py \
    --quick --out BENCH_qat.json
# CHECK_BENCH_ARGS lets CI widen the absolute-timing envelope for runner
# hardware that differs from the baseline machine (ratios/exacts still gate)
python scripts/check_bench.py ${CHECK_BENCH_ARGS:-}
