#!/usr/bin/env bash
# Tier-1 verification: the full test suite, fail-fast, from the repo root
# (includes the kernel interpret-mode sweeps and the compiled-backend
# equivalence tests), then the benchmark smoke runs which emit
# BENCH_backend.json and BENCH_serving.json.
#   bash scripts/tier1.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python benchmarks/bench_backend.py \
    --quick --out BENCH_backend.json
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python benchmarks/bench_serving.py \
    --quick --out BENCH_serving.json
