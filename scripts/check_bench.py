#!/usr/bin/env python
"""CI perf-regression gate: compare freshly emitted benchmark artifacts
(BENCH_backend.json / BENCH_serving.json) against the committed baselines
in ``benchmarks/baselines/`` with per-metric tolerances.

Metric classes (see ``RULES``):

* ``exact``  — plan/node/token counts, pool sizes: any drift fails (a
  changed lowering plan or changed greedy tokens is a correctness event,
  not noise — re-baseline deliberately);
* ``timing`` — absolute CPU timings (lower is better): fail when the
  fresh value is more than ``--timing-tol`` above baseline; faster is
  always fine (a big improvement prints a re-baseline hint);
* ``ratio``  — derived ratios (speedups, occupancy, acceptance; higher
  is better): fail when below baseline by more than ``--ratio-tol``,
  with optional hard floors (compiled must never lose to the
  interpreter: ``speedup >= 1.0``);
* ``estimate`` — deterministic analytical-model outputs (the dataflow
  DSE resource/FPS numbers): no machine noise, so they get a tight
  two-sided ``--estimate-tol`` band that only absorbs deliberate small
  coefficient tweaks, plus hard floors where the paper's claim is
  directional (SIRA must *reduce* LUTs/DSPs/accumulator bits:
  ``*_reduction > 0``).

Failures print a metric-by-metric diff table (also appended to
``$GITHUB_STEP_SUMMARY`` when set, so the regression is readable from
the job page without scrolling logs).

    python scripts/check_bench.py                      # gate (CI / tier1)
    python scripts/check_bench.py --update             # re-baseline
    python scripts/check_bench.py --fresh-dir . --baseline-dir benchmarks/baselines
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
from pathlib import Path
from typing import List, Optional, Tuple

# metric -> (class, hard_floor)
# class: exact | timing | throughput | ratio — timing/throughput are
# absolute measurements (machine-load-sensitive, gated at --timing-tol);
# ratio metrics divide out load and get the tighter --ratio-tol
RULES = {
    "BENCH_backend.json": {
        "key": ("workload",),
        "context": ("batch", "repeat"),          # must match to compare
        "metrics": {
            "nodes": ("exact", None),
            "plan": ("exact", None),
            "interpreter_us_per_sample": ("timing", None),
            "compiled_us_per_sample": ("timing", None),
            "speedup": ("ratio", 1.0),
            # disabled/enabled compiled-path time with the obs tracer
            # (emitted on the TFC row only): enabled tracing may never
            # cost more than ~5% on the dispatch-bound compiled path
            "trace_off_on_ratio": ("ratio", 0.95),
        },
    },
    "BENCH_serving.json": {
        "key": ("engine", "batch_slots"),
        "context": ("arch", "requests", "int8_layers",
                    "load_slots", "load_requests"),
        "metrics": {
            "tokens": ("exact", None),
            "int8_layers": ("exact", None),
            "kv_hbm_bytes": ("exact", None),
            "decode_steps": ("exact", None),
            "tokens_per_s": ("throughput", None),
            "seconds": ("timing", None),
            "mean_ttft_s": ("timing", None),
            "slot_occupancy": ("ratio", None),
            "speedup_vs_static": ("ratio", None),
            "speedup_vs_per_token": ("ratio", None),
            "acceptance_rate": ("ratio", None),
            "tokens_per_decode_step": ("ratio", None),
            # prefix-cache rows (repeat-system-prompt workload): the
            # paper-level serving claim as hard floors — warm prefill
            # must reuse > 90% of prompt tokens and cut TTFT to at most
            # half of a cold prefill; fork/page counts are deterministic
            # bookkeeping, so any drift is a sharing-logic change
            "prefix_hit_rate": ("ratio", 0.9),
            "prefix_ttft_speedup": ("ratio", 2.0),
            "prefix_forks": ("exact", None),
            "cached_pages": ("exact", None),
            "shared_pool_occupancy": ("ratio", None),
            # open-loop Poisson row: tail latency under arrival pressure
            # (timing class — machine-load-sensitive, like `seconds`)
            "p50_ttft_s": ("timing", None),
            "p99_ttft_s": ("timing", None),
            "p50_token_latency_s": ("timing", None),
            "p99_token_latency_s": ("timing", None),
        },
    },
    "BENCH_dataflow.json": {
        "key": ("workload",),
        "context": ("device", "target_fps"),
        "metrics": {
            # topology + decisions: purely structural, any drift is a
            # changed extraction/selection algorithm — exact
            "graph_nodes": ("exact", None),
            "compute_nodes": ("exact", None),
            "fifos": ("exact", None),
            "styles": ("exact", None),
            "baseline_styles": ("exact", None),
            # threshold-conversion outcomes under monotonicity
            # certificates: counts and certificate statuses are
            # decisions, not measurements — exact
            "tails_total": ("exact", None),
            "tails_converted": ("exact", None),
            "tails_meta_kernel": ("exact", None),
            "tail_certificates": ("exact", None),
            "mean_acc_bits_sira": ("exact", None),
            "mean_acc_bits_datatype": ("exact", None),
            "fold_feasible": ("exact", None),
            "fold_binding": ("exact", None),
            "infeasible_binding": ("exact", None),
            # analytical resource estimates: banded, with the paper's
            # directional claims as hard floors (reduction must stay > 1%)
            "sira_luts": ("estimate", None),
            "sira_dsps": ("estimate", None),
            "sira_brams": ("estimate", None),
            "baseline_luts": ("estimate", None),
            "baseline_dsps": ("estimate", None),
            "baseline_brams": ("estimate", None),
            "lut_reduction": ("estimate", 0.01),
            # floor 0: SIRA may never *increase* DSPs, but the HSW row
            # legitimately breaks even (its MVAUs all map to LUT MACs;
            # the remaining DSPs are scaled elementwise Mul/Div on both
            # sides) — the per-row estimate band still pins the four
            # paper workloads at their reduced counts
            "dsp_reduction": ("estimate", 0.0),
            "acc_bits_reduction": ("estimate", 0.01),
            "tail_lut_ratio": ("estimate", None),
            "fold_fps": ("estimate", None),
            "max_fps": ("estimate", None),
            # interval-vs-affine domain comparison: summed proven
            # accumulator bits are structural (exact); the saved-bits /
            # saved-LUT deltas carry the soundness-ordering claim as a
            # hard floor — the affine reduced product may never prove
            # *worse* than the interval domain (floor 0, strict <)
            "acc_bits_sum_interval": ("exact", None),
            "acc_bits_sum_affine": ("exact", None),
            "affine_acc_bits_saved": ("ratio", 0.0),
            "interval_luts_unfolded": ("estimate", None),
            "affine_luts_unfolded": ("estimate", None),
            "interval_dsps_unfolded": ("exact", None),
            "affine_dsps_unfolded": ("exact", None),
            "affine_luts_saved": ("ratio", 0.0),
            "seconds": ("timing", None),
        },
    },
    "BENCH_qat.json": {
        "key": ("budget",),
        "context": ("arch", "weight_bits", "act_bits", "zero_center",
                    "steps", "seed", "device"),
        "metrics": {
            # the A2Q guarantee: SIRA-proven accumulator bits may never
            # exceed the trained budget (min over constrained layers of
            # budget - proven_bits; a theorem given the toz quantizer +
            # frozen scales, so floor 0 is hard — emitted only on
            # constrained rows)
            "budget_headroom": ("ratio", 0.0),
            # proven bits / layer counts are integers derived from the
            # deterministic training run — exact
            "constrained_layers": ("exact", None),
            "proven_bits": ("exact", None),
            "proven_bits_max": ("exact", None),
            "proven_bits_sum": ("exact", None),
            # DSE resources must be monotone non-increasing as the
            # budget tightens (computed in-bench vs the previous,
            # looser row) — any False is a cost-model ordering bug
            "luts_le_prev": ("exact", None),
            "dsps_le_prev": ("exact", None),
            # analytical DSE estimates on the exported trained graph
            "sira_luts": ("estimate", None),
            "sira_dsps": ("estimate", None),
            "baseline_luts": ("estimate", None),
            "baseline_dsps": ("estimate", None),
            # task loss: lower is better and load-insensitive, but it
            # rides the fp stack across jax versions — gate it like a
            # timing (order-of-magnitude guard, not a band)
            "task_loss": ("timing", None),
            "seconds": ("timing", None),
        },
    },
}


class Row:
    """One comparison outcome for the diff table."""

    def __init__(self, where: str, metric: str, base, fresh,
                 verdict: str, note: str = ""):
        self.where, self.metric = where, metric
        self.base, self.fresh = base, fresh
        self.verdict, self.note = verdict, note

    @property
    def failed(self) -> bool:
        return self.verdict == "FAIL"


def _fmt(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.4g}"
    if isinstance(v, dict):
        return json.dumps(v, sort_keys=True)
    return str(v)


def _compare_metric(where: str, metric: str, kind: str,
                    floor: Optional[float], base, fresh,
                    timing_tol: float, ratio_tol: float,
                    estimate_tol: float,
                    base_path: Optional[Path] = None) -> Row:
    if base is None and fresh is None:
        return Row(where, metric, base, fresh, "ok")
    if base is None or fresh is None:
        # name the metric class and the file the metric was expected in,
        # so a failure after re-baselining is self-explanatory
        missing_in = (f"baseline {base_path}" if base is None
                      else "fresh artifact")
        return Row(where, metric, base, fresh, "FAIL",
                   f"{kind} metric missing from {missing_in} — "
                   f"re-baseline with --update if the metric was "
                   f"deliberately added/removed")
    if kind == "exact":
        if base == fresh:
            return Row(where, metric, base, fresh, "ok")
        return Row(where, metric, base, fresh, "FAIL", "exact mismatch")
    base_f, fresh_f = float(base), float(fresh)
    if kind == "timing":                       # lower is better
        limit = base_f * (1.0 + timing_tol)
        if fresh_f > limit:
            return Row(where, metric, base, fresh, "FAIL",
                       f"slower than baseline +{timing_tol:.0%}")
        if fresh_f < base_f * (1.0 - timing_tol):
            return Row(where, metric, base, fresh, "ok",
                       "much faster — consider --update")
        return Row(where, metric, base, fresh, "ok")
    if kind == "throughput":                   # higher better, absolute:
        #                                        load-sensitive like timing
        limit = base_f / (1.0 + timing_tol)
        if fresh_f < limit:
            return Row(where, metric, base, fresh, "FAIL",
                       f"throughput below baseline/{1 + timing_tol:g}")
        if fresh_f > base_f * (1.0 + timing_tol):
            return Row(where, metric, base, fresh, "ok",
                       "much faster — consider --update")
        return Row(where, metric, base, fresh, "ok")
    if kind == "estimate":                     # deterministic model output
        if floor is not None and fresh_f < floor:
            return Row(where, metric, base, fresh, "FAIL",
                       f"below hard floor {floor:g}")
        band = abs(base_f) * estimate_tol
        if abs(fresh_f - base_f) > band:
            return Row(where, metric, base, fresh, "FAIL",
                       f"analytical estimate drifted beyond "
                       f"±{estimate_tol:.0%}")
        return Row(where, metric, base, fresh, "ok")
    if kind == "ratio":                        # higher is better
        if floor is not None and fresh_f < floor:
            return Row(where, metric, base, fresh, "FAIL",
                       f"below hard floor {floor:g}")
        limit = base_f * (1.0 - ratio_tol)
        if fresh_f < limit:
            return Row(where, metric, base, fresh, "FAIL",
                       f"below baseline -{ratio_tol:.0%}")
        if fresh_f > base_f * (1.0 + ratio_tol):
            return Row(where, metric, base, fresh, "ok",
                       "much better — consider --update")
        return Row(where, metric, base, fresh, "ok")
    raise ValueError(kind)


def check_file(name: str, fresh_path: Path, base_path: Path,
               timing_tol: float, ratio_tol: float,
               estimate_tol: float) -> List[Row]:
    rules = RULES[name]
    rows: List[Row] = []
    if not fresh_path.exists():
        return [Row(name, "<file>", "committed", "missing", "FAIL",
                    "fresh artifact was not emitted")]
    fresh = json.loads(fresh_path.read_text())
    base = json.loads(base_path.read_text())

    for field in rules["context"]:
        if base.get(field) != fresh.get(field):
            rows.append(Row(name, field, base.get(field), fresh.get(field),
                            "FAIL", "bench configuration drifted — "
                            "re-baseline with --update"))
    if any(r.failed for r in rows):
        return rows                       # timings aren't comparable

    def key_of(row) -> Tuple:
        return tuple(row.get(k) for k in rules["key"])

    base_rows = {key_of(r): r for r in base["results"]}
    fresh_rows = {key_of(r): r for r in fresh["results"]}
    for k in base_rows.keys() | fresh_rows.keys():
        where = f"{name}:{'/'.join(str(p) for p in k)}"
        b, f = base_rows.get(k), fresh_rows.get(k)
        if b is None or f is None:
            rows.append(Row(where, "<row>",
                            "present" if b else "absent",
                            "present" if f else "absent", "FAIL",
                            "result row added/removed — re-baseline"))
            continue
        for metric, (kind, floor) in rules["metrics"].items():
            if metric not in b and metric not in f:
                continue                  # metric not produced by this row
            rows.append(_compare_metric(
                where, metric, kind, floor, b.get(metric), f.get(metric),
                timing_tol, ratio_tol, estimate_tol,
                base_path=base_path))
    return rows


def render_table(rows: List[Row], markdown: bool) -> str:
    headers = ("where", "metric", "baseline", "fresh", "verdict", "note")
    cells = [(r.where, r.metric, _fmt(r.base), _fmt(r.fresh),
              r.verdict, r.note) for r in rows]
    if markdown:
        out = ["| " + " | ".join(headers) + " |",
               "|" + "|".join("---" for _ in headers) + "|"]
        out += ["| " + " | ".join(c) + " |" for c in cells]
        return "\n".join(out)
    widths = [max(len(h), *(len(c[i]) for c in cells)) if cells else len(h)
              for i, h in enumerate(headers)]
    line = "  ".join(h.ljust(w) for h, w in zip(headers, widths))
    out = [line, "-" * len(line)]
    out += ["  ".join(c.ljust(w) for c, w in zip(cell, widths))
            for cell in cells]
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fresh-dir", default=".",
                    help="where the freshly emitted BENCH_*.json live")
    ap.add_argument("--baseline-dir", default="benchmarks/baselines")
    ap.add_argument("--timing-tol", type=float, default=1.5,
                    help="allowed relative slowdown on absolute CPU "
                         "timings (default 1.5 — absolute timings swing "
                         "~2x with machine load; they only catch order-"
                         "of-magnitude regressions, the ratio metrics "
                         "and exact plan/count checks do the real work)")
    ap.add_argument("--ratio-tol", type=float, default=0.5,
                    help="allowed relative drop on speedup/occupancy/"
                         "acceptance ratios (default 0.5; ratios divide "
                         "out machine load but CPU jitter remains)")
    ap.add_argument("--estimate-tol", type=float, default=0.05,
                    help="two-sided band on deterministic analytical "
                         "estimates (dataflow DSE resources/FPS; default "
                         "0.05 — these have no machine noise, the band "
                         "only absorbs deliberate coefficient tweaks)")
    ap.add_argument("--update", action="store_true",
                    help="copy the fresh artifacts over the baselines "
                         "(deliberate re-baseline; commit the result)")
    ap.add_argument("--only", choices=sorted(RULES), action="append",
                    help="check a subset of artifacts")
    args = ap.parse_args(argv)

    fresh_dir, base_dir = Path(args.fresh_dir), Path(args.baseline_dir)
    names = args.only or sorted(RULES)

    if args.update:
        base_dir.mkdir(parents=True, exist_ok=True)
        for name in names:
            src = fresh_dir / name
            if not src.exists():
                print(f"cannot re-baseline {name}: {src} missing")
                return 2
            shutil.copy(src, base_dir / name)
            print(f"re-baselined {base_dir / name}")
        return 0

    all_rows: List[Row] = []
    for name in names:
        base_path = base_dir / name
        if not base_path.exists():
            print(f"no baseline for {name} ({base_path} missing) — run "
                  f"scripts/check_bench.py --update and commit it")
            return 2
        all_rows += check_file(name, fresh_dir / name, base_path,
                               args.timing_tol, args.ratio_tol,
                               args.estimate_tol)

    failures = [r for r in all_rows if r.failed]
    shown = failures if failures else \
        [r for r in all_rows if r.note] or all_rows
    print(render_table(shown, markdown=False))
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as fh:
            fh.write("## Benchmark regression gate: "
                     + ("FAILED\n\n" if failures else "passed\n\n"))
            fh.write(render_table(shown, markdown=True) + "\n")
    if failures:
        print(f"\n{len(failures)} metric(s) regressed beyond tolerance "
              f"(timing ±{args.timing_tol:.0%}, ratio -{args.ratio_tol:.0%})."
              f"  Intentional?  Re-baseline with:\n"
              f"  python scripts/check_bench.py --update   # then commit "
              f"{base_dir}/*.json")
        return 1
    print(f"\nbenchmark gate passed ({len(all_rows)} metrics across "
          f"{len(names)} artifacts)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
