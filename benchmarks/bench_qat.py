"""Accumulator-budget sweep: the end-to-end train -> SIRA -> DSE chain.

For each accumulator budget B (0 = unconstrained, then tightening): run
accumulator-aware QAT (`repro.qat`), export the trained weights to a
SiraModel, run the default build flow, and report the SIRA-*proven*
accumulator bits, the task loss, and the unfolded LUT/DSP estimates from
the dataflow DSE — the paper-stack's "training knob -> proven bits ->
resources" curve.

Two invariants are asserted in-bench and again as hard floors in
``scripts/check_bench.py``:

  * ``proven_bits <= budget`` on every constrained layer (the A2Q
    guarantee, a theorem given the toz quantizer + frozen scales — any
    violation is a soundness bug, not noise);
  * SIRA LUT/DSP estimates are monotone non-increasing as the budget
    tightens (``luts_le_prev`` / ``dsps_le_prev``).

    PYTHONPATH=src python benchmarks/bench_qat.py \
        [--quick] [--budgets 0,14,12,10] [--out BENCH_qat.json]
"""
from __future__ import annotations

import argparse
import time


def bench_budget(budget: int, prev: dict, args) -> dict:
    from repro.dataflow import compare_sira_vs_baseline
    from repro.qat import (QATConfig, check_budget_invariant,
                           proven_layer_bits, run_qat)

    t0 = time.perf_counter()
    cfg = QATConfig(in_dim=args.in_dim,
                    hidden=tuple(args.hidden),
                    classes=args.classes,
                    weight_bits=args.weight_bits,
                    act_bits=args.act_bits,
                    budget=budget,
                    zero_center=args.zero_center,
                    steps=args.steps,
                    seed=args.seed)
    res = run_qat(cfg)
    result, bits = proven_layer_bits(
        res.model, res.state.params, name=f"qat-b{budget}")
    if budget:
        check_budget_invariant(res.model, res.state.params, bits)
    comp = compare_sira_vs_baseline(result.model, device=args.device)

    row = dict(
        budget=budget,
        constrained_layers=len(bits) if budget else 0,
        proven_bits=bits,
        proven_bits_max=max(bits),
        proven_bits_sum=sum(bits),
        task_loss=round(res.final_loss, 4),
        sira_luts=round(comp.sira.luts, 1),
        sira_dsps=comp.sira.dsps,
        baseline_luts=round(comp.baseline.luts, 1),
        baseline_dsps=comp.baseline.dsps,
        seconds=time.perf_counter() - t0,
    )
    if budget:
        # the A2Q guarantee as a number: min over layers of
        # (budget - proven bits); the gate holds it >= 0 as a hard floor
        row["budget_headroom"] = budget - row["proven_bits_max"]
    if prev:
        # budgets sweep loosest-first, so resources may only shrink
        row["luts_le_prev"] = bool(row["sira_luts"]
                                   <= prev["sira_luts"] + 1e-9)
        row["dsps_le_prev"] = bool(row["sira_dsps"] <= prev["sira_dsps"])
        assert row["luts_le_prev"] and row["dsps_le_prev"], (
            f"budget {budget}: DSE resources grew vs looser budget "
            f"{prev['budget']} ({prev['sira_luts']}->{row['sira_luts']} "
            f"LUTs, {prev['sira_dsps']}->{row['sira_dsps']} DSPs)")
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--budgets", default="0,14,12,10",
                    help="comma list, loosest first; 0 = unconstrained")
    ap.add_argument("--in-dim", type=int, default=16)
    ap.add_argument("--hidden", type=int, nargs="+", default=[32, 32])
    ap.add_argument("--classes", type=int, default=4)
    ap.add_argument("--weight-bits", type=int, default=4)
    ap.add_argument("--act-bits", type=int, default=4)
    ap.add_argument("--zero-center", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--device", default="pynq-z1")
    ap.add_argument("--quick", action="store_true",
                    help="shorter training (tier1/CI gating mode — the "
                         "committed baseline is generated from this)")
    ap.add_argument("--out", default="BENCH_qat.json")
    args = ap.parse_args()
    if args.quick:
        args.steps = min(args.steps, 80)

    budgets = [int(b) for b in args.budgets.split(",")]
    results, prev = [], {}
    for budget in budgets:
        row = bench_budget(budget, prev, args)
        results.append(row)
        prev = row
        print(f"budget {budget or '-':>3}: proven {row['proven_bits']} "
              f"(max {row['proven_bits_max']})  "
              f"loss {row['task_loss']:.3f}  "
              f"LUT {row['sira_luts']:.0f}  DSP {row['sira_dsps']}",
              flush=True)

    payload = dict(arch=f"mlp{args.in_dim}-"
                        f"{'x'.join(map(str, args.hidden))}-{args.classes}",
                   weight_bits=args.weight_bits, act_bits=args.act_bits,
                   zero_center=args.zero_center, steps=args.steps,
                   seed=args.seed, device=args.device, results=results)
    from repro.obs.metrics import export_bench
    export_bench(payload, args.out, key=("budget",))
    print(f"wrote {args.out} (+ Prometheus text next to it)")


if __name__ == "__main__":
    main()
