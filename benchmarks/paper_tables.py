"""Benchmark functions — one per paper table/figure.

Each returns a list of (name, us_per_call, derived) rows for the CSV
contract of benchmarks/run.py.  FPGA resource numbers come from the
paper's own analytical models (§5.4) since no synthesis tool exists here;
end-to-end deltas are therefore model-projected (DESIGN.md §7.1) and are
printed next to the paper's measured numbers for comparison.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

Row = Tuple[str, float, str]


def _timeit(fn, n=3) -> float:
    fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


# --------------------------------------------------------------- Table 1

def t1_qat_scales() -> List[Row]:
    """QAT accuracy vs scale flexibility (paper Table 1): train a small
    QNN classifier at 4/3-bit with PoT-per-tensor vs float-per-tensor vs
    float-per-channel weight scales; expressive scales must win at 3-bit."""
    import jax
    import jax.numpy as jnp
    from repro.quant import QuantSpec, compute_scale, fake_quant

    rng = np.random.default_rng(0)
    d_in, d_h, n_cls, n = 16, 32, 4, 1024
    Wt = rng.normal(size=(d_in, n_cls))
    X = rng.normal(size=(n, d_in))
    y = (X @ Wt).argmax(-1)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)

    def train(bits, pot, granularity, steps=150, seed=0):
        k = jax.random.PRNGKey(seed)
        k1, k2 = jax.random.split(k)
        params = {"w1": jax.random.normal(k1, (d_in, d_h)) * d_in**-0.5,
                  "w2": jax.random.normal(k2, (d_h, n_cls)) * d_h**-0.5}
        spec = QuantSpec(bits=bits, pot=pot, granularity=granularity)

        def apply(p, x):
            def q(w):
                s, z = compute_scale(jax.lax.stop_gradient(w), spec)
                return fake_quant(w, s, z, spec)
            h = jax.nn.relu(x @ q(p["w1"]))
            return h @ q(p["w2"])

        def loss(p):
            lg = apply(p, Xj)
            return -jnp.mean(jax.nn.log_softmax(lg)[jnp.arange(n), yj])

        g = jax.jit(jax.grad(loss))
        for _ in range(steps):
            grads = g(params)
            params = jax.tree.map(lambda p, gr: p - 0.5 * gr, params,
                                  grads)
        acc = float((apply(params, Xj).argmax(-1) == yj).mean())
        return acc

    rows: List[Row] = []
    results = {}
    for bits in (4, 3):
        for label, pot, gran in [("pot_per_tensor", True, "per_tensor"),
                                 ("float_per_tensor", False, "per_tensor"),
                                 ("float_per_channel", False,
                                  "per_channel")]:
            t0 = time.perf_counter()
            accs = [train(bits, pot, gran, seed=s) for s in range(3)]
            us = (time.perf_counter() - t0) * 1e6 / 3
            acc = float(np.mean(accs))
            results[(bits, label)] = acc
            rows.append((f"t1_qat_w{bits}a{bits}_{label}", us,
                         f"top1={acc:.3f}"))
    # ordering sanity (paper: expressiveness matters more at 3 bits)
    gap3 = results[(3, "float_per_channel")] - results[(3,
                                                        "pot_per_tensor")]
    gap4 = results[(4, "float_per_channel")] - results[(4,
                                                        "pot_per_tensor")]
    rows.append(("t1_expressiveness_gap", 0.0,
                 f"gap3={gap3:.3f};gap4={gap4:.3f};paper_gap3=0.024"))
    return rows


# --------------------------------------------------------------- Table 3

def t3_worked_example() -> List[Row]:
    """SIRA ranges on the paper's worked example (§3.3) + transform time."""
    from repro.core import (ScaledIntRange, SiraModel, Streamline, analyze,
                            Graph)
    from tests.test_worked_example import example as _  # noqa: F401  (doc)

    g = Graph(inputs=["X"], outputs=["Y"])
    qs_X = g.add_initializer(0.7, "qs_X")
    zp = g.add_initializer(0.0)
    b4 = g.add_initializer(4.0)
    g.add_node("Quant", ["X", qs_X, zp, b4], ["Xq"], dict(signed=1))
    W = g.add_initializer(np.array([[-2.10, 5.00, -1.30],
                                    [3.10, 0.00, -3.20]]), "W")
    qs_W = g.add_initializer(np.array([0.20, 0.30, 0.10]), "qs_W")
    g.add_node("Quant", [W, qs_W, g.add_initializer(0.0),
                         g.add_initializer(4.0)], ["Wq"], dict(signed=1))
    g.add_node("MatMul", ["Xq", "Wq"], ["mm"])
    g.add_node("Add", [
        "mm", g.add_initializer(np.array([-3.30, 1.20, 0.50]), "B")],
        ["gemm"])
    g.add_node("Mul", [
        "gemm", g.add_initializer(np.array([0.60, 0.20, 0.40]), "M")],
        ["bnm"])
    g.add_node("Add", [
        "bnm", g.add_initializer(np.array([-0.20, -0.40, 1.10]), "N")],
        ["bn"])
    g.add_node("Relu", ["bn"], ["act"])
    g.add_node("Quant", ["act", g.add_initializer(0.10, "qs_Y"),
                         g.add_initializer(0.0), g.add_initializer(4.0)],
               ["Y"], dict(signed=0))
    inp = {"X": ScaledIntRange(lo=np.array([-5.10, -3.80]),
                               hi=np.array([5.10, 3.80]))}
    model = SiraModel(g, inp)
    us_analyze = _timeit(lambda: analyze(g, inp), n=10)
    us_stream = _timeit(lambda: model.transform(Streamline()), n=10)
    r = model.ranges["mm"]
    return [
        ("t3_sira_analysis", us_analyze,
         f"mm_int_range=[{int(r.int_lo.min())},{int(r.int_hi.max())}]"),
        ("t3_streamline", us_stream, "fig9_structure=verified_in_tests"),
    ]


# --------------------------------------------------------------- Table 4

def t4_elementwise_model() -> List[Row]:
    """Elementwise meta-kernel analytical LUT model (Table 4 / Fig 18)."""
    from repro.core.costmodel import lut_add, lut_max, lut_mul, lut_toint
    rows: List[Row] = []
    for (ni, np_, pe) in [(8, 8, 1), (16, 16, 2), (32, 16, 4)]:
        rows.append((f"t4_mul_ni{ni}_np{np_}_pe{pe}", 0.0,
                     f"luts={lut_mul(ni, np_, pe):.0f}"))
        rows.append((f"t4_add_ni{ni}_np{np_}_pe{pe}", 0.0,
                     f"luts={lut_add(ni, np_, pe):.0f}"))
        rows.append((f"t4_toint_ni{ni}_pe{pe}", 0.0,
                     f"luts={lut_toint(ni, pe):.0f}"))
        rows.append((f"t4_max_ni{ni}_pe{pe}", 0.0,
                     f"luts={lut_max(ni, pe):.0f}"))
    return rows


# --------------------------------------------------------------- Table 5

def t5_dataflow_resources() -> List[Row]:
    """Whole-accelerator resources (Table 5 analogue): per-workload
    LUT/DSP/BRAM and mean accumulator width, SIRA vs the datatype-bound
    baseline, from the dataflow DSE subsystem's graph-level models."""
    from repro.core import build_flow
    from repro.core.workloads import WORKLOADS
    from repro.dataflow import compare_sira_vs_baseline

    rows: List[Row] = []
    for name, maker in WORKLOADS.items():
        t0 = time.perf_counter()
        model = build_flow(maker()).model
        comp = compare_sira_vs_baseline(model)
        us = (time.perf_counter() - t0) * 1e6
        s, b = comp.sira, comp.baseline
        rows.append((
            f"t5_{name}", us,
            f"luts={b.luts:.0f}->{s.luts:.0f}"
            f"(-{comp.lut_reduction:.0%});"
            f"dsps={b.dsps}->{s.dsps}(-{comp.dsp_reduction:.0%});"
            f"brams={b.brams}->{s.brams};"
            f"acc={comp.mean_acc_bits_datatype:.1f}->"
            f"{comp.mean_acc_bits_sira:.1f}b"
            f"(-{comp.acc_bits_reduction:.0%});paper=-17%LUT,-66%DSP,"
            f"-22%acc"))
    return rows


# --------------------------------------------------------------- Table 6

def t6_workloads() -> List[Row]:
    """End-to-end QNN workloads (Table 6 analogue): SIRA opts on the four
    paper topologies via one build_flow; the layer-tail rLUT now comes
    from the dataflow DSE subsystem's per-node estimates (same models,
    graph-aware geometry) instead of ad-hoc per-report math."""
    from repro.core import build_flow, summarize
    from repro.core.costmodel import tpu_tail_bytes
    from repro.core.workloads import WORKLOADS
    from repro.dataflow import compare_sira_vs_baseline

    rows: List[Row] = []
    paper = {"TFC-w2a2": (0.77, 0.0), "CNV-w2a2": (0.95, 0.0),
             "RN8-w3a3": (0.86, 0.48), "MNv1-w4a4": (0.74, 0.86)}
    for name, maker in WORKLOADS.items():
        wl = maker()
        t0 = time.perf_counter()
        result = build_flow(wl)
        us = (time.perf_counter() - t0) * 1e6
        reps = result.accumulator_reports
        specs = result.threshold_specs
        s = summarize(reps)
        comp = compare_sira_vs_baseline(result.model)
        rlut = comp.tail_lut_ratio
        C = 128
        hbm_base = tpu_tail_bytes(1 << 20, 32, wl.act_bits, C,
                                  "composite", fused=False)
        hbm_opt = tpu_tail_bytes(1 << 20, int(s["mean_sira"]),
                                 wl.act_bits, C, "thresholding")
        rows.append((
            f"t6_{name}", us,
            f"tails={len(specs)};acc_red_vs_dtype="
            f"{s['reduction_vs_datatype']:.2f};tail_rLUT={rlut:.2f};"
            f"paper_rLUT={paper[name][0]:.2f};"
            f"tpu_tail_rHBM={hbm_opt / hbm_base:.2f}"))
    return rows


# ------------------------------------------------------ domain comparison

def t6b_domains() -> List[Row]:
    """Interval vs affine-form (zonotope) abstract domain on the four
    paper workloads: summed proven accumulator bits and unfolded LUTs at
    the same design point.  The affine reduced product may tighten but
    never loosen the interval bounds, so saved >= 0 always."""
    from repro.core import build_flow
    from repro.core.workloads import WORKLOADS
    from repro.dataflow import estimate

    rows: List[Row] = []
    for name, maker in WORKLOADS.items():
        t0 = time.perf_counter()
        m_int = build_flow(maker()).model
        m_aff = build_flow(maker(), domain="affine").model
        us = (time.perf_counter() - t0) * 1e6
        acc_i = sum(r.sira_bits
                    for r in m_int.metadata["accumulator_reports"])
        acc_a = sum(r.sira_bits
                    for r in m_aff.metadata["accumulator_reports"])
        luts_i = estimate(m_int, widths="sira").luts
        luts_a = estimate(m_aff, widths="sira").luts
        rows.append((
            f"t6b_{name}", us,
            f"accbits={acc_i}->{acc_a}(saved={acc_i - acc_a});"
            f"luts={luts_i:.0f}->{luts_a:.0f}"
            f"(saved={luts_i - luts_a:.0f})"))
    return rows


# --------------------------------------------------------------- Table 7

def t7_layer_tails() -> List[Row]:
    """Layer-tail microbenchmarks (Table 7): thresholding vs composite
    float32/fixed16.8/fixed32.16 LUTs across bits/granularity."""
    from repro.core.costmodel import (lut_composite_total,
                                      lut_threshold_total)
    rows: List[Row] = []
    C, pe = 256, 4
    for n_i in (8, 16, 24):
        for n_o in (2, 4, 8):
            thr = lut_threshold_total(n_i, n_o, C, pe)
            fx16 = lut_composite_total(n_i, 16, C, pe)
            fx32 = lut_composite_total(n_i, 32, C, pe)
            best = ("thresholding" if thr <= min(fx16, fx32)
                    else "fixed16.8" if fx16 <= fx32 else "fixed32.16")
            rows.append((f"t7_ni{n_i}_no{n_o}", 0.0,
                         f"thr={thr:.0f};fx16={fx16:.0f};fx32={fx32:.0f};"
                         f"best={best}"))
    return rows


# --------------------------------------------------------------- Fig 22

def f22_accumulators() -> List[Row]:
    """Accumulator width histograms (Fig 22): paper QNNs + LM arch blocks."""
    from repro.core import (MinimizeAccumulators, SiraModel, Streamline,
                            summarize)
    from repro.core.workloads import WORKLOADS
    from repro.models.export import export_block_graph
    from repro.configs import get_config, list_archs

    pipeline = (Streamline(), MinimizeAccumulators())
    rows: List[Row] = []
    all_s, all_d = [], []
    for name, maker in WORKLOADS.items():
        wl = maker()
        model = SiraModel.from_workload(wl).transform(*pipeline)
        reps = model.metadata["accumulator_reports"]
        s = summarize(reps)
        all_s += [r.sira_bits for r in reps]
        all_d += [r.datatype_bits for r in reps]
        rows.append((f"f22_{name}", 0.0,
                     f"mu_S={s['mean_sira']:.1f};mu_D="
                     f"{s['mean_datatype']:.1f};"
                     f"red={s['reduction_vs_datatype']:.2f}"))
    for arch in list_archs():
        cfg = get_config(arch, reduced=True)
        try:
            g, inp = export_block_graph(cfg, w_bits=4, a_bits=4)
        except NotImplementedError:
            continue
        model = SiraModel(g, inp, name=arch).transform(*pipeline)
        reps = model.metadata["accumulator_reports"]
        if not reps:
            continue
        s = summarize(reps)
        all_s += [r.sira_bits for r in reps]
        all_d += [r.datatype_bits for r in reps]
        rows.append((f"f22_{arch}", 0.0,
                     f"mu_S={s['mean_sira']:.1f};mu_D="
                     f"{s['mean_datatype']:.1f};"
                     f"red={s['reduction_vs_datatype']:.2f}"))
    red = 1 - np.mean(all_s) / np.mean(all_d)
    red32 = 1 - np.mean(all_s) / 32.0
    rows.append(("f22_overall", 0.0,
                 f"red_vs_dtype={red:.2f};paper=0.22;"
                 f"red_vs_32b={red32:.2f};paper32=0.63"))
    return rows


# --------------------------------------------------------------- Fig 23

def f23_crossover() -> List[Row]:
    """Crossover analysis (Fig 23): thresholding vs composite as channels
    and PE scale."""
    from repro.core.costmodel import select_tail_style
    rows: List[Row] = []
    for C in (64, 256, 1024):
        for pe in (1, 4, 16):
            styles = [select_tail_style(24, n_o, 16, C, pe)
                      for n_o in range(2, 11)]
            cross = next((n_o for n_o, s in zip(range(2, 11), styles)
                          if s == "composite"), None)
            rows.append((f"f23_C{C}_pe{pe}", 0.0,
                         f"crossover_bits={cross};styles={''.join(s[0] for s in styles)}"))
    return rows
