"""Dataflow-accelerator DSE report over the QNN workloads (paper four +
the hard-swish/Silu MLP exercising monotonicity certification).

For each workload: run the default build flow, then the DSE subsystem —
SIRA-vs-datatype-baseline resource estimates (same topology and folding;
only the widths/styles differ), the folding search toward a target FPS on
the target device (plus a deliberately infeasible budget to exercise the
binding-constraint reporting), and the max-throughput design point.

Every number here is produced by deterministic analytical models, so the
CI gate (``scripts/check_bench.py``) holds node counts, style choices and
bitwidths **exactly** and the resource estimates to a tight band — this
is the accelerator-level mirror of the paper's −LUTs/−DSPs/−accumulator
claims.

    PYTHONPATH=src python benchmarks/bench_dataflow.py \
        [--device pynq-z1] [--target-fps 1000] [--out BENCH_dataflow.json]
"""
from __future__ import annotations

import argparse
import time


def bench_workload(name: str, device: str, target_fps: float) -> dict:
    from repro.core import build_flow
    from repro.core.workloads import ALL_WORKLOADS
    from repro.dataflow import (DeviceBudget, compare_sira_vs_baseline,
                                estimate, extract_dataflow, max_throughput,
                                search_folding)

    t0 = time.perf_counter()
    model = build_flow(ALL_WORKLOADS[name]()).model
    dfg = extract_dataflow(model)       # shared: extraction is pure
    fold = search_folding(model, target_fps=target_fps, device=device,
                          dataflow_graph=dfg)
    folding = fold.folding if fold.feasible else None
    comp = compare_sira_vs_baseline(model, device=device, folding=folding,
                                    dataflow_graph=dfg)
    # a budget no workload fits: exercises binding-constraint reporting
    tiny = DeviceBudget("tiny", luts=400, dsps=1, brams=1)
    infeasible = search_folding(model, target_fps=target_fps, device=tiny,
                                dataflow_graph=dfg)
    best = max_throughput(model, device=device, dataflow_graph=dfg)

    # interval-vs-affine domain comparison: proven accumulator bits plus
    # LUT/DSP at a fixed (fully folded, PE=SIMD=1) design point.  The two
    # flows generate different fresh tensor names, so the affine model
    # gets its own extraction; node *counts* and totals stay comparable.
    model_aff = build_flow(ALL_WORKLOADS[name](), domain="affine").model
    acc_int = sum(r.sira_bits for r in
                  model.metadata["accumulator_reports"])
    acc_aff = sum(r.sira_bits for r in
                  model_aff.metadata["accumulator_reports"])
    est_int_unf = estimate(model, widths="sira", device=device,
                           dataflow_graph=dfg)
    est_aff_unf = estimate(model_aff, widths="sira", device=device)
    seconds = time.perf_counter() - t0

    # threshold-conversion outcomes: how many layer tails converted under
    # a monotonicity certificate vs stayed elementwise (meta-kernel), and
    # the certificate statuses that drove the decision
    reports = model.metadata.get("tail_reports", [])
    statuses: dict = {}
    for r in reports:
        statuses[r.status] = statuses.get(r.status, 0) + 1

    est = comp.sira
    return dict(
        workload=name,
        graph_nodes=len(model.graph.nodes),
        compute_nodes=len(est.nodes),
        fifos=len(est.fifos),
        styles=est.style_counts(),
        baseline_styles=comp.baseline.style_counts(),
        tails_total=len(reports),
        tails_converted=sum(1 for r in reports if r.converted),
        tails_meta_kernel=sum(1 for r in reports if not r.converted),
        tail_certificates=statuses,
        mean_acc_bits_sira=round(comp.mean_acc_bits_sira, 4),
        mean_acc_bits_datatype=round(comp.mean_acc_bits_datatype, 4),
        acc_bits_reduction=round(comp.acc_bits_reduction, 4),
        sira_luts=round(est.luts, 1),
        sira_dsps=est.dsps,
        sira_brams=est.brams,
        baseline_luts=round(comp.baseline.luts, 1),
        baseline_dsps=comp.baseline.dsps,
        baseline_brams=comp.baseline.brams,
        lut_reduction=round(comp.lut_reduction, 4),
        dsp_reduction=round(comp.dsp_reduction, 4),
        tail_lut_ratio=round(comp.tail_lut_ratio, 4),
        fold_feasible=fold.feasible,
        fold_binding=fold.binding,
        fold_fps=round(fold.achieved_fps, 1),
        infeasible_binding=infeasible.binding,
        max_fps=round(best.achieved_fps, 1),
        # interval-vs-affine domain columns (fixed folding: PE=SIMD=1)
        acc_bits_sum_interval=acc_int,
        acc_bits_sum_affine=acc_aff,
        affine_acc_bits_saved=acc_int - acc_aff,
        interval_luts_unfolded=round(est_int_unf.luts, 1),
        affine_luts_unfolded=round(est_aff_unf.luts, 1),
        interval_dsps_unfolded=est_int_unf.dsps,
        affine_dsps_unfolded=est_aff_unf.dsps,
        affine_luts_saved=round(est_int_unf.luts - est_aff_unf.luts, 1),
        seconds=seconds,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--device", default="pynq-z1")
    ap.add_argument("--target-fps", type=float, default=1000.0)
    ap.add_argument("--quick", action="store_true",
                    help="accepted for tier1.sh uniformity (the analytical "
                         "models are already fast; no reduced mode needed)")
    ap.add_argument("--out", default="BENCH_dataflow.json")
    args = ap.parse_args()

    from repro.core.workloads import ALL_WORKLOADS

    results = []
    for name in ALL_WORKLOADS:
        row = bench_workload(name, args.device, args.target_fps)
        results.append(row)
        print(f"{name:10s} LUT {row['baseline_luts']:8.0f}→"
              f"{row['sira_luts']:7.0f} (-{row['lut_reduction']:.0%})  "
              f"DSP {row['baseline_dsps']:3d}→{row['sira_dsps']:3d} "
              f"(-{row['dsp_reduction']:.0%})  "
              f"acc {row['mean_acc_bits_datatype']:.1f}→"
              f"{row['mean_acc_bits_sira']:.1f}b  "
              f"fold@{args.target_fps:g}fps="
              f"{'ok' if row['fold_feasible'] else row['fold_binding']}  "
              f"tiny→{row['infeasible_binding']}  "
              f"tails {row['tails_converted']}/{row['tails_total']}thr "
              f"{row['tails_meta_kernel']}meta  "
              f"affine accΣ {row['acc_bits_sum_interval']}→"
              f"{row['acc_bits_sum_affine']}b", flush=True)
    payload = dict(device=args.device, target_fps=args.target_fps,
                   results=results)
    from repro.obs.metrics import export_bench
    export_bench(payload, args.out, key=("workload",))
    print(f"wrote {args.out} (+ Prometheus text next to it)")


if __name__ == "__main__":
    main()
