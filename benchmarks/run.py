"""Benchmark harness: one function per paper table/figure + kernel micro-
benchmarks.  Prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks.paper_tables import (f22_accumulators, f23_crossover,
                                         t1_qat_scales, t3_worked_example,
                                         t4_elementwise_model,
                                         t5_dataflow_resources,
                                         t6_workloads, t6b_domains,
                                         t7_layer_tails)
    from benchmarks.kernels_bench import kernel_benchmarks

    suites = [
        ("t1", t1_qat_scales),
        ("t3", t3_worked_example),
        ("t4", t4_elementwise_model),
        ("t5", t5_dataflow_resources),
        ("t6", t6_workloads),
        ("t6b", t6b_domains),
        ("t7", t7_layer_tails),
        ("f22", f22_accumulators),
        ("f23", f23_crossover),
        ("kernels", kernel_benchmarks),
    ]
    print("name,us_per_call,derived")
    failed = 0
    for tag, fn in suites:
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception:
            failed += 1
            print(f"{tag}_FAILED,0,{traceback.format_exc(limit=2)!r}",
                  flush=True)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
