"""Interpreter vs compiled-backend throughput on the four QNN workloads.

For each workload: run the optimized graph through the per-node numpy
interpreter (``Graph.execute``) and through the compiled backend
(``SiraModel.compile()`` — jitted JAX routed through the kernel wrappers;
jnp reference path on CPU, Pallas on TPU), on the same batched inputs,
and record per-sample latency + speedup.

    PYTHONPATH=src python benchmarks/bench_backend.py \
        [--batch 64] [--repeat 5] [--quick] [--out BENCH_backend.json]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def _time(fn, repeat: int) -> float:
    fn()                                 # warmup (trace/compile)
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_workload(name: str, batch: int, repeat: int) -> dict:
    from repro.core import build_flow
    from repro.core.workloads import WORKLOADS

    model = build_flow(WORKLOADS[name]()).model
    (inp,) = model.graph.inputs
    shape = (batch,) + tuple(model.metadata["input_shape"][1:])
    r = model.input_ranges[inp]
    rng = np.random.default_rng(0)
    lo = np.broadcast_to(np.asarray(r.lo, np.float64), shape)
    hi = np.broadcast_to(np.asarray(r.hi, np.float64), shape)
    x = rng.uniform(lo, hi, size=shape)
    feeds = {inp: x}

    interp_s = _time(lambda: model.execute(feeds), repeat)
    compiled = model.compile()
    compiled_s = _time(lambda: compiled(feeds), repeat)

    return dict(
        workload=name,
        batch=batch,
        nodes=len(model.graph.nodes),
        plan=compiled.kernel_calls,
        interpreter_us_per_sample=interp_s / batch * 1e6,
        compiled_us_per_sample=compiled_s / batch * 1e6,
        speedup=interp_s / compiled_s,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--repeat", type=int, default=5)
    ap.add_argument("--quick", action="store_true",
                    help="small batch (CI smoke); keeps enough repeats "
                         "that best-of-N is stable — the tiny dispatch-"
                         "bound workloads (TFC) need ~20 samples for the "
                         "regression gate to be meaningful")
    ap.add_argument("--out", default="BENCH_backend.json")
    args = ap.parse_args()
    if args.quick:
        args.batch, args.repeat = 8, 20

    from repro.core.workloads import WORKLOADS

    results = []
    for name in WORKLOADS:
        row = bench_workload(name, args.batch, args.repeat)
        results.append(row)
        print(f"{name:10s} batch={row['batch']:3d} "
              f"interp={row['interpreter_us_per_sample']:9.1f} us/sample "
              f"compiled={row['compiled_us_per_sample']:9.1f} us/sample "
              f"speedup={row['speedup']:6.1f}x", flush=True)
    import jax
    payload = dict(backend=jax.default_backend(),
                   batch=args.batch, repeat=args.repeat,
                   results=results)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
